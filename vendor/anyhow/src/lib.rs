//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Error chains are stored as rendered
//! strings; `{:#}` formatting walks the chain like real anyhow.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message.
    pub fn to_msg_string(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Note: `Error` deliberately does not implement `std::error::Error`, so
// this blanket conversion (used by `?`) cannot overlap with `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = Vec::new();
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut chain = None;
        for m in msgs.into_iter().rev() {
            chain = Some(Box::new(Error { msg: m, source: chain }));
        }
        Error { msg: e.to_string(), source: chain }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "inner 7");
    }

    #[test]
    fn context_chains_in_alternate() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<f64> {
            Ok("nope".parse::<f64>()?)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: usize) -> Result<usize> {
            ensure!(v < 10, "too big: {v}");
            Ok(v)
        }
        assert!(check(3).is_ok());
        assert!(check(30).is_err());
    }
}
