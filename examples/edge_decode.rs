//! EDGE DECODE CORE (ROADMAP §scenario breadth): the wasm32-shaped
//! serving path, exercised natively so its token identity is checkable
//! in CI without a wasm runtime:
//!
//!   1. quantize + pack a small model (LLaMA-shaped by default — the
//!      cross-architecture leg; `--arch rwkv6` packs RWKV instead),
//!   2. reload the checkpoint **from bytes** (`QuantizedModel::open_bytes`
//!      — the loader a filesystem-less host uses: no mmap, no `std::fs`
//!      on the open path),
//!   3. greedy-decode through [`EdgeSession`] — the sequential,
//!      thread-free, clock-free tick path that compiles for
//!      `wasm32-unknown-unknown` (CI checks exactly this example and the
//!      library against that target),
//!   4. serve the same prompts through the native batched serve loop and
//!      assert the tokens are **identical** — the edge core is the same
//!      decoder and the same argmax, minus the platform machinery.
//!
//! ```sh
//! cargo run --release --example edge_decode
//! cargo run --release --example edge_decode -- --arch rwkv6
//! # what CI gates for the edge build:
//! cargo check --target wasm32-unknown-unknown --lib --example edge_decode
//! ```

use rwkvquant::config::{ModelConfig, QuantConfig};
use rwkvquant::coordinator::edge::EdgeSession;
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{decoder_for, serve_collect, Request};
use rwkvquant::model::QuantizedModel;
use rwkvquant::util::caps;
use rwkvquant::util::cli::Args;
use rwkvquant::util::rng::Rng;
use std::time::Duration;

fn main() -> rwkvquant::Result<()> {
    let args = Args::from_env();
    let arch = args.get_or("arch", "llama");
    println!("platform capabilities: {}", caps::summary());

    // ---- 1. quantize + pack a small model ----
    let cfg = match arch {
        "llama" => ModelConfig::llama(2, 16, 64),
        "rwkv6" => ModelConfig::rwkv6(2, 16, 64),
        other => anyhow::bail!("--arch expects llama|rwkv6, got '{other}'"),
    };
    let mut rng = Rng::new(808);
    let m = match arch {
        "llama" => rwkvquant::model::llama::init_params(&cfg, &mut rng),
        _ => rwkvquant::model::rwkv::init_params(&cfg, &mut rng),
    };
    let qc = QuantConfig { kmeans_iters: 6, vq_bits: 6, ..QuantConfig::default() };
    let (q, rep) = quantize_model(&m, None, &qc, 0);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    let ckpt = std::env::temp_dir().join("edge_decode_demo.rwkvq2");
    qm.save(&ckpt)?;
    let bytes = std::fs::read(&ckpt)?;
    std::fs::remove_file(&ckpt).ok();
    println!(
        "packed {arch} (L{} d{} vocab {}) at avg {:.3} bpw -> {} bytes",
        cfg.n_layer,
        cfg.d_model,
        cfg.vocab,
        rep.avg_bpw,
        bytes.len(),
    );

    // ---- 2 + 3. bytes -> EdgeSession greedy decode ----
    // on a real edge host the bytes arrive by fetch/embedding; from here
    // down, nothing touches the filesystem, threads, or clocks
    let edge_model = QuantizedModel::open_bytes(&bytes)?;
    let prompts: Vec<Vec<usize>> =
        (0..4).map(|i| vec![(i * 13 + 1) % cfg.vocab, 2, 7]).collect();
    let gen_len = 12usize;
    let mut session = EdgeSession::new(&edge_model)?;
    let mut edge_tokens = Vec::new();
    for p in &prompts {
        session.reset();
        edge_tokens.push(session.generate(p, gen_len));
    }
    println!("edge session decoded {} prompts x {gen_len} tokens", prompts.len());

    // ---- 4. native twin: the batched serve loop over the same pack ----
    let mut dec = decoder_for(&qm)?;
    let requests: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), gen_len))
        .collect();
    let (_, responses) = serve_collect(&mut dec, requests, 4, Duration::from_millis(1))?;
    for (i, r) in responses.iter().enumerate() {
        anyhow::ensure!(
            r.tokens == edge_tokens[i],
            "edge/native divergence on prompt {i}: {:?} vs {:?}",
            edge_tokens[i],
            r.tokens
        );
    }
    println!(
        "edge decode core is token-identical to the native serve loop on all {} prompts ✓",
        prompts.len()
    );
    Ok(())
}
