//! END-TO-END DRIVER (DESIGN.md §End-to-end): loads the *trained* tiny
//! RWKV produced by `make artifacts` (python/compile/train.py), then:
//!
//!   1. evaluates fp perplexity + corpus zero-shot accuracy (Rust eval),
//!   2. quantizes it with the full RWKVQuant pipeline (proxy-guided
//!      hybrid + §3.2 ew-mult codebooks, calibrated on captured
//!      activations),
//!   3. re-evaluates the quantized model **on the packed path** — the
//!      eval harness consumes the `QuantizedModel` weight provider, so
//!      no dense fp32 matrix is materialised for quantized matmuls,
//!   4. verifies the AOT PJRT decode graph agrees with the Rust forward
//!      (requires the `pjrt` cargo feature),
//!   5. serves the same batched request set twice through the
//!      continuous batcher — dense fp32 vs packed quantized — checks the
//!      greedy outputs against the dequantized reference and reports the
//!      decode tokens/sec speedup,
//!   6. reports the fp→quant memory saving,
//!   7. brings up the HTTP gateway on a loopback port over the reopened
//!      RWKVQ2 checkpoint and checks that tokens streamed over a real
//!      socket (SSE) are identical to the in-process serving of step 6,
//!      then drains it gracefully.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serve
//! # fan each batch tick over 4 persistent pool lanes (token-identical):
//! cargo run --release --example e2e_serve -- --tick-threads 4
//! # or auto-detect one lane per hardware thread:
//! cargo run --release --example e2e_serve -- --tick-threads 0
//! ```

use rwkvquant::calib::CalibSet;
use rwkvquant::config::QuantConfig;
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{
    resolve_tick_threads, serve_collect_pool_with, Decoder, PoolOpts, Request, Response,
    RunnerDecoder, ServeOpts, ServeStats,
};
use rwkvquant::data::{make_task_from_corpus, BinCorpus};
use rwkvquant::eval::{dequantized_model, ppl, zeroshot};
use rwkvquant::model::{ModelWeights, QuantizedModel, WeightProvider};
use rwkvquant::quant::exec;
use rwkvquant::report::{Cell, Table};
use rwkvquant::runtime::artifacts_dir;
use rwkvquant::util::cli::Args;
use std::time::Duration;
use std::time::Instant;

/// Serve a fixed request set drawn from the corpus through a decoder
/// pool (one decoder per tick worker; `&mut [d]` of one is sequential).
/// Prompts prefill in chunks of 8 — one tick per whole prompt here —
/// which is token-identical to one-per-tick prefill by construction.
fn serve_requests<D: Decoder + Send>(
    decoders: &mut [D],
    corpus: &BinCorpus,
    n_req: u64,
) -> rwkvquant::Result<(ServeStats, Vec<Response>)> {
    let requests: Vec<Request> = (0..n_req)
        .map(|id| {
            let start = (id as usize * 37) % (corpus.valid.len() - 20);
            Request::new(id, corpus.valid[start..start + 8].to_vec(), 16)
        })
        .collect();
    let opts = ServeOpts::new(8, Duration::from_millis(2)).with_prefill_chunk(8);
    serve_collect_pool_with(decoders, requests, &opts, PoolOpts::default())
}

fn main() -> rwkvquant::Result<()> {
    let args = Args::from_env();
    let requested_threads = args.get_usize("tick-threads", 1);
    // serve_requests ticks with max_batch = 8; auto-detect caps there
    let tick_threads = resolve_tick_threads(requested_threads, 8);
    let dir = artifacts_dir();
    if !dir.join("tiny_rwkv.bin").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let model = ModelWeights::load(&dir.join("tiny_rwkv.bin"))?;
    let corpus = BinCorpus::load(&dir.join("corpus.bin"))?;
    println!(
        "loaded trained rwkv6 L{} d{} vocab {} ({} params) + corpus ({} valid tokens)",
        model.config.n_layer,
        model.config.d_model,
        model.config.vocab,
        model.n_params(),
        corpus.valid.len()
    );

    // ---- 1. fp eval ----
    let toks = &corpus.valid[..1200.min(corpus.valid.len())];
    let tasks = make_task_from_corpus(&corpus.valid, corpus.vocab, 80, 16, 2, 5);
    let fp_ppl = ppl::perplexity(&model, toks);
    let fp_acc = zeroshot::accuracy(&model, &tasks);

    // ---- 2. quantize (full RWKVQuant) ----
    let calib = CalibSet::capture(&model, &corpus.calib_windows(8, 16, 3), 128);
    let qcfg = QuantConfig { vq_bits: 9, kmeans_iters: 12, ..QuantConfig::default() };
    let t0 = Instant::now();
    let (quant, rep) = quantize_model(&model, Some(&calib), &qcfg, 0);
    println!(
        "quantized {} layers in {:.2}s on {} workers — avg {:.3} bpw, SQ share {:.0}%, τ_c {:.3} τ_f {:.2}",
        rep.layers.len(),
        t0.elapsed().as_secs_f64(),
        rep.n_workers,
        rep.avg_bpw,
        rep.sq_share() * 100.0,
        rep.taus.map(|t| t.tau_c).unwrap_or(f64::NAN),
        rep.taus.map(|t| t.tau_f).unwrap_or(f64::NAN),
    );

    // ---- 3. quantized eval on the packed path ----
    let qm = QuantizedModel::from_parts(&model, &quant);
    println!(
        "assembled QuantizedModel: {} packed matmul layers at {:.3} bpw, {:.2} MB served",
        qm.n_packed(),
        qm.packed_bpw(),
        qm.served_storage_bits() as f64 / 8e6
    );
    let q_ppl = ppl::perplexity(&qm, toks);
    let q_acc = zeroshot::accuracy(&qm, &tasks);

    let mut t = Table::new(
        "e2e — trained tiny RWKV, fp vs RWKVQuant 3.275-bpw",
        &["", "ppl (valid)", "0-shot acc %", "weight bits"],
    );
    let fp_bits: usize = model
        .quantizable_indices()
        .iter()
        .map(|&i| model.layers[i].1.numel() * 16)
        .sum();
    let q_bits: usize = quant.values().map(|l| l.storage_bits()).sum();
    t.row(vec![Cell::s("FloatingPoint"), Cell::f(fp_ppl, 2), Cell::f(fp_acc, 1), Cell::Int(fp_bits as i64)]);
    t.row(vec![Cell::s("RWKVQuant"), Cell::f(q_ppl, 2), Cell::f(q_acc, 1), Cell::Int(q_bits as i64)]);
    t.print();
    println!("memory saving (quantizable weights): {:.2}x", fp_bits as f64 / q_bits as f64);

    // ---- 4. PJRT graph agreement (needs the `pjrt` feature) ----
    #[cfg(feature = "pjrt")]
    if dir.join("rwkv_step.hlo.txt").exists() {
        use rwkvquant::runtime::rwkv_graph::RwkvSession;
        let mut session = RwkvSession::load(&dir, &model)?;
        let mut reference = rwkvquant::model::rwkv::RwkvRunner::new(&model);
        let mut worst = 0.0f32;
        for &t in &corpus.valid[..16] {
            let a = session.step(t)?;
            let b = reference.forward_token(t);
            for c in 0..a.len() {
                worst = worst.max((a[c] - b[c]).abs());
            }
        }
        println!("PJRT decode graph vs Rust reference: max |Δlogit| = {worst:.5} over 16 steps ✓");
    }
    #[cfg(not(feature = "pjrt"))]
    println!(
        "(PJRT graph check skipped — needs the `pjrt` feature plus the `xla` \
         crate from the full offline vendor set; see Cargo.toml)"
    );

    // ---- 5. batched serving: dense fp32 vs packed quantized ----
    println!(
        "serving with the {} matvec kernel, {} tick thread{}{} (persistent pool)",
        exec::active_kernel().name(),
        tick_threads,
        if tick_threads == 1 { "" } else { "s" },
        if requested_threads == 0 { " — auto-detected" } else { "" },
    );
    let n_req = 24u64;
    let mut fp_decs: Vec<_> = (0..tick_threads).map(|_| RunnerDecoder::new(&model)).collect();
    let (fp_stats, _fp_resp) = serve_requests(&mut fp_decs, &corpus, n_req)?;
    let mut q_decs: Vec<_> = (0..tick_threads).map(|_| RunnerDecoder::new(&qm)).collect();
    let (q_stats, q_resp) = serve_requests(&mut q_decs, &corpus, n_req)?;
    // greedy outputs from the packed path must match the dequantized twin
    let dq = dequantized_model(&model, &quant);
    let mut dq_decs = vec![RunnerDecoder::new(&dq)];
    let (_, dq_resp) = serve_requests(&mut dq_decs, &corpus, n_req)?;
    let mismatches = q_resp
        .iter()
        .zip(&dq_resp)
        .filter(|(a, b)| a.tokens != b.tokens)
        .count();
    assert_eq!(
        mismatches, 0,
        "packed serving diverged from the dequantized reference on {mismatches}/{n_req} requests"
    );
    println!("packed greedy outputs match the dequantized reference on all {n_req} requests ✓");
    for (label, stats) in [("fp32 dense", &fp_stats), ("packed quant", &q_stats)] {
        println!(
            "  {label:<12} {} req / {} tok (+{} prefill) in {:.2}s — {:.1} tok/s, \
             ttft p50 {:?}, p50 {:?} p95 {:?} p99 {:?}",
            stats.completed,
            stats.total_tokens,
            stats.prompt_tokens,
            stats.wall.as_secs_f64(),
            stats.tokens_per_sec(),
            stats.p50_ttft,
            stats.p50_latency,
            stats.p95_latency,
            stats.p99_latency
        );
    }
    let speedup = q_stats.tokens_per_sec() / fp_stats.tokens_per_sec().max(1e-9);
    println!(
        "decode speedup (packed vs fp32): {speedup:.2}x at {:.3} vs 32 bits/weight",
        qm.packed_bpw()
    );

    // ---- 6. RWKVQ2 packed checkpoint: pack, reopen zero-copy, re-serve ----
    // the f16-resident twin already carries the on-disk dense rounding,
    // so the reopened checkpoint must serve token-identically to it
    let mut qm16 = qm.clone();
    qm16.dense_to_f16();
    let ckpt = std::env::temp_dir().join("e2e_tiny_rwkv.rwkvq2");
    qm16.save(&ckpt)?;
    let ckpt_bytes = std::fs::metadata(&ckpt)?.len();
    let t0 = Instant::now();
    let reopened = QuantizedModel::open(&ckpt)?;
    let open_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut twin_decs = vec![RunnerDecoder::new(&qm16)];
    let (_, twin_resp) = serve_requests(&mut twin_decs, &corpus, n_req)?;
    let mut re_decs = vec![RunnerDecoder::new(&reopened)];
    let (_, re_resp) = serve_requests(&mut re_decs, &corpus, n_req)?;
    let re_mismatches = re_resp
        .iter()
        .zip(&twin_resp)
        .filter(|(a, b)| a.tokens != b.tokens)
        .count();
    assert_eq!(
        re_mismatches, 0,
        "RWKVQ2-reopened serving diverged from the in-memory twin on \
         {re_mismatches}/{n_req} requests"
    );
    println!(
        "RWKVQ2 checkpoint: {:.2} MB on disk, opened in {open_ms:.1} ms ({}/{} payloads \
         borrowed zero-copy), dense resident {:.2} MB f16 — greedy outputs identical ✓",
        ckpt_bytes as f64 / 1e6,
        reopened.n_mapped(),
        reopened.entries.len(),
        reopened.dense_storage_bits() as f64 / 8e6,
    );
    std::fs::remove_file(&ckpt).ok();

    // ---- 7. HTTP gateway over the packed checkpoint ----
    // the gateway runs the SAME serve loop on the SAME store, so the
    // bytes on the wire must decode to the tokens of step 6
    use rwkvquant::server::gateway::{sse_tokens, tokens_json};
    use rwkvquant::server::http::http_request;
    use rwkvquant::server::{Gateway, GatewayConfig};
    let mut gcfg = GatewayConfig::new("127.0.0.1:0");
    gcfg.max_batch = 4;
    let gateway = Gateway::bind(gcfg, reopened.config.vocab)?;
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut gw_decs = vec![RunnerDecoder::new(&reopened)];
    let n_http = 2usize;
    std::thread::scope(|s| -> rwkvquant::Result<()> {
        let server = s.spawn(|| gateway.serve(&mut gw_decs));
        let drive = || -> rwkvquant::Result<()> {
            let health = http_request(addr, "GET", "/healthz", None)?;
            anyhow::ensure!(health.status == 200, "healthz answered {}", health.status);
            for (i, twin) in re_resp.iter().take(n_http).enumerate() {
                // same prompts as serve_requests builds for ids 0..n_http
                let start = (i * 37) % (corpus.valid.len() - 20);
                let prompt = tokens_json(&corpus.valid[start..start + 8]);
                let body = format!("{{\"prompt\":{prompt},\"gen_len\":16}}");
                let resp = http_request(addr, "POST", "/v1/generate", Some(&body))?;
                anyhow::ensure!(resp.status == 200, "generate answered {}", resp.status);
                let tokens = sse_tokens(&resp.body_str())?;
                anyhow::ensure!(
                    tokens == twin.tokens,
                    "HTTP stream {i} diverged from in-process serving"
                );
            }
            let metrics = http_request(addr, "GET", "/metrics", None)?;
            anyhow::ensure!(
                metrics.body_str().contains("rwkvquant_served_tokens_total"),
                "metrics endpoint is missing the token counter"
            );
            anyhow::ensure!(
                metrics.body_str().contains("rwkvquant_ttft_seconds"),
                "metrics endpoint is missing the TTFT summary"
            );
            Ok(())
        };
        // always drain, even when a check above failed — otherwise the
        // scope would join a server thread that never exits
        let outcome = drive();
        handle.shutdown();
        let stats = server.join().expect("gateway thread panicked")?;
        outcome?;
        anyhow::ensure!(stats.completed == n_http, "gateway completed {}", stats.completed);
        Ok(())
    })?;
    println!(
        "HTTP gateway on {addr}: {n_http} SSE streams token-identical to in-process serving, \
         /healthz + /metrics live, drained cleanly ✓"
    );
    println!("e2e OK");
    Ok(())
}
