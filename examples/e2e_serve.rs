//! END-TO-END DRIVER (DESIGN.md §End-to-end): loads the *trained* tiny
//! RWKV produced by `make artifacts` (python/compile/train.py), then:
//!
//!   1. evaluates fp perplexity + corpus zero-shot accuracy (Rust eval),
//!   2. quantizes it with the full RWKVQuant pipeline (proxy-guided
//!      hybrid + §3.2 ew-mult codebooks, calibrated on captured
//!      activations),
//!   3. re-evaluates the quantized model,
//!   4. verifies the AOT PJRT decode graph agrees with the Rust forward,
//!   5. serves batched generation requests through the continuous
//!      batcher and reports tokens/s + latency percentiles,
//!   6. reports the fp→quant memory saving.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use rwkvquant::calib::CalibSet;
use rwkvquant::config::QuantConfig;
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{serve, Request, RunnerDecoder};
use rwkvquant::data::{make_task_from_corpus, BinCorpus};
use rwkvquant::eval::{dequantized_model, ppl, zeroshot};
use rwkvquant::model::ModelWeights;
use rwkvquant::report::{Cell, Table};
use rwkvquant::runtime::artifacts_dir;
use rwkvquant::runtime::rwkv_graph::RwkvSession;
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn main() -> rwkvquant::Result<()> {
    let dir = artifacts_dir();
    if !dir.join("tiny_rwkv.bin").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let model = ModelWeights::load(&dir.join("tiny_rwkv.bin"))?;
    let corpus = BinCorpus::load(&dir.join("corpus.bin"))?;
    println!(
        "loaded trained rwkv6 L{} d{} vocab {} ({} params) + corpus ({} valid tokens)",
        model.config.n_layer,
        model.config.d_model,
        model.config.vocab,
        model.n_params(),
        corpus.valid.len()
    );

    // ---- 1. fp eval ----
    let toks = &corpus.valid[..1200.min(corpus.valid.len())];
    let tasks = make_task_from_corpus(&corpus.valid, corpus.vocab, 80, 16, 2, 5);
    let fp_ppl = ppl::perplexity(&model, toks);
    let fp_acc = zeroshot::accuracy(&model, &tasks);

    // ---- 2. quantize (full RWKVQuant) ----
    let calib = CalibSet::capture(&model, &corpus.calib_windows(8, 16, 3), 128);
    let qcfg = QuantConfig { vq_bits: 9, kmeans_iters: 12, ..QuantConfig::default() };
    let t0 = Instant::now();
    let (quant, rep) = quantize_model(&model, Some(&calib), &qcfg, 0);
    println!(
        "quantized {} layers in {:.2}s on {} workers — avg {:.3} bpw, SQ share {:.0}%, τ_c {:.3} τ_f {:.2}",
        rep.layers.len(),
        t0.elapsed().as_secs_f64(),
        rep.n_workers,
        rep.avg_bpw,
        rep.sq_share() * 100.0,
        rep.taus.map(|t| t.tau_c).unwrap_or(f64::NAN),
        rep.taus.map(|t| t.tau_f).unwrap_or(f64::NAN),
    );

    // ---- 3. quantized eval ----
    let dq = dequantized_model(&model, &quant);
    let q_ppl = ppl::perplexity(&dq, toks);
    let q_acc = zeroshot::accuracy(&dq, &tasks);

    let mut t = Table::new(
        "e2e — trained tiny RWKV, fp vs RWKVQuant 3.275-bpw",
        &["", "ppl (valid)", "0-shot acc %", "weight bits"],
    );
    let fp_bits: usize = model
        .quantizable_indices()
        .iter()
        .map(|&i| model.layers[i].1.numel() * 16)
        .sum();
    let q_bits: usize = quant.values().map(|l| l.storage_bits()).sum();
    t.row(vec![Cell::s("FloatingPoint"), Cell::f(fp_ppl, 2), Cell::f(fp_acc, 1), Cell::Int(fp_bits as i64)]);
    t.row(vec![Cell::s("RWKVQuant"), Cell::f(q_ppl, 2), Cell::f(q_acc, 1), Cell::Int(q_bits as i64)]);
    t.print();
    println!("memory saving (quantizable weights): {:.2}x", fp_bits as f64 / q_bits as f64);

    // ---- 4. PJRT graph agreement ----
    if dir.join("rwkv_step.hlo.txt").exists() {
        let mut session = RwkvSession::load(&dir, &model)?;
        let mut reference = rwkvquant::model::rwkv::RwkvRunner::new(&model);
        let mut worst = 0.0f32;
        for &t in &corpus.valid[..16] {
            let a = session.step(t)?;
            let b = reference.forward_token(t);
            for c in 0..a.len() {
                worst = worst.max((a[c] - b[c]).abs());
            }
        }
        println!("PJRT decode graph vs Rust reference: max |Δlogit| = {worst:.5} over 16 steps ✓");
    }

    // ---- 5. batched serving (quantized weights) ----
    let mut dec = RunnerDecoder::new(&dq);
    let (tx_req, rx_req) = mpsc::channel();
    let (tx_resp, rx_resp) = mpsc::channel();
    let n_req = 24u64;
    for id in 0..n_req {
        let start = (id as usize * 37) % (corpus.valid.len() - 20);
        tx_req.send(Request {
            id,
            prompt: corpus.valid[start..start + 8].to_vec(),
            gen_len: 16,
        })?;
    }
    drop(tx_req);
    let stats = serve(&mut dec, rx_req, tx_resp, 8, Duration::from_millis(2))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let responses: Vec<_> = rx_resp.iter().collect();
    println!(
        "served {} requests / {} generated tokens in {:.2}s — {:.1} tok/s, p50 {:?}, p95 {:?}",
        stats.completed,
        stats.total_tokens,
        stats.wall.as_secs_f64(),
        stats.tokens_per_sec(),
        stats.p50_latency,
        stats.p95_latency
    );
    assert_eq!(responses.len() as u64, n_req);
    println!("e2e OK");
    Ok(())
}
