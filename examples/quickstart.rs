//! Quickstart: quantize a synthetic RWKV model with RWKVQuant and
//! compare against GPTQ / GPTVQ on reconstruction + output divergence.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use rwkvquant::calib::CalibSet;
use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::data::Corpus;
use rwkvquant::eval::{dequantized_model, output_divergence};
use rwkvquant::model::synthetic::{generate_rwkv, Family};
use rwkvquant::report::{Cell, Table};

fn main() {
    // 1. a synthetic RWKV-6 with realistic weight distributions
    let cfg = ModelConfig::rwkv6(4, 128, 256);
    let model = generate_rwkv(&cfg, Family::Rwkv, 42);
    println!(
        "model: rwkv6 L{} d{} — {} params, {} quantizable layers",
        cfg.n_layer,
        cfg.d_model,
        model.n_params(),
        model.quantizable_indices().len()
    );

    // 2. calibration activations captured from the real forward pass
    let corpus = Corpus::build(cfg.vocab, 4000, 1500, 7);
    let calib = CalibSet::from_corpus(&model, &corpus, 128, 16, 9);

    // 3. quantize three ways and compare
    let probes: Vec<Vec<usize>> = corpus.calib_windows(4, 12, 31);
    let mut t = Table::new(
        "quickstart — RWKVQuant vs single-method baselines",
        &["Method", "avg bpw", "SQ share", "output divergence"],
    );
    for (method, bpw) in [
        (Method::Gptq, 3.5),
        (Method::Gptvq, 3.5),
        (Method::RwkvQuant, 3.275),
    ] {
        let mut qc = QuantConfig::baseline(method, bpw);
        qc.method = method;
        qc.kmeans_iters = 10;
        qc.vq_bits = qc.vq_bits.min(9);
        let (q, rep) = quantize_model(&model, Some(&calib), &qc, 0);
        let d = output_divergence(&model, &dequantized_model(&model, &q), &probes);
        t.row(vec![
            Cell::s(method.name()),
            Cell::f(rep.avg_bpw, 3),
            Cell::s(if rep.taus.is_some() {
                format!("{:.0}%", rep.sq_share() * 100.0)
            } else {
                "-".into()
            }),
            Cell::F64(d, 5),
        ]);
    }
    t.print();
    println!("lower divergence at lower bpw = the paper's headline effect");
}
