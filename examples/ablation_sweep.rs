//! Ablation playground: sweep the hybrid's SQ fraction and the VQ
//! codebook width on one model, reporting divergence vs bpw — the
//! compression/quality trade-off curve behind the paper's 3.275-bpw
//! operating point (and its §A.5 future-work directions).
//!
//! ```sh
//! cargo run --release --example ablation_sweep -- --size 1B
//! ```

use rwkvquant::config::Method;
use rwkvquant::experiments::*;
use rwkvquant::report::{Cell, Table};
use rwkvquant::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let size = args.get_or("size", "0.5B");
    let model = build_model("rwkv6", size, 123);
    let ps = probes(model.config.vocab, 3, 10, 7);

    let mut t = Table::new(
        format!("sq-fraction sweep — rwkv6-{size}"),
        &["SQ fraction", "avg bpw", "divergence"],
    );
    for frac in [0.0, 0.25, 0.5, 0.75, 0.9, 1.0] {
        let mut cfg = bench_config(Method::RwkvQuant, 3.275, 55);
        cfg.sq_fraction = frac;
        let cell = run_cell(&model, None, &cfg, &ps);
        t.row(vec![Cell::f(frac, 2), Cell::f(cell.avg_bpw, 3), Cell::F64(cell.divergence, 5)]);
    }
    t.print();

    let mut t2 = Table::new(
        format!("vq codebook width sweep — rwkv6-{size}"),
        &["vq bits", "avg bpw", "divergence"],
    );
    for bits in [6u32, 7, 8, 9] {
        let mut cfg = bench_config(Method::Gptvq, 3.5, 56);
        cfg.vq_bits = bits;
        let cell = run_cell(&model, None, &cfg, &ps);
        t2.row(vec![Cell::Int(bits as i64), Cell::f(cell.avg_bpw, 3), Cell::F64(cell.divergence, 5)]);
    }
    t2.print();
}
