//! Figure 6/7/8 companion: scan a model with the coarse-to-fine proxy
//! and dump the classification of every layer (uniform / non-uniform /
//! uniform-with-outliers), plus the Fig. 5 SQ/VQ proportions.
//!
//! ```sh
//! cargo run --release --example proxy_scan -- --arch rwkv6 --size 1B
//! ```

use rwkvquant::experiments::build_model;
use rwkvquant::model::synthetic::{generate_llama, size_config};
use rwkvquant::quant::hybrid::{calibrate_taus, decide, Choice};
use rwkvquant::quant::proxy;
use rwkvquant::report::{Cell, Table};
use rwkvquant::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let arch = args.get_or("arch", "rwkv6");
    let size = args.get_or("size", "1B");
    let model = if arch == "llama" {
        generate_llama(&size_config(arch, size), 77)
    } else {
        build_model(arch, size, 77)
    };

    let idx = model.quantizable_indices();
    let pairs: Vec<proxy::ProxyPair> = idx
        .iter()
        .map(|&i| proxy::compute(&model.layers[i].1.data, 4))
        .collect();
    let cal = calibrate_taus(&pairs, 0.9);
    println!(
        "auto-calibrated τ_c = {:.3}, τ_f = {:.2} (SQ share {:.0}%)",
        cal.tau_c,
        cal.tau_f,
        cal.sq_share * 100.0
    );

    let mut t = Table::new(
        format!("proxy scan — {arch}-{size}"),
        &["Layer", "P_c", "P_f", "class", "Eq.18"],
    );
    for (pos, &i) in idx.iter().enumerate() {
        let p = pairs[pos];
        let class = if p.p_c >= cal.tau_c {
            "non-uniform (Fig.7)"
        } else if p.p_f >= cal.tau_f {
            "uniform+outliers (Fig.8)"
        } else {
            "uniform (Fig.6)"
        };
        let ch = decide(p, cal.tau_c, cal.tau_f);
        t.row(vec![
            Cell::s(model.layers[i].0.name.clone()),
            Cell::f(p.p_c, 3),
            Cell::f(p.p_f, 2),
            Cell::s(class),
            Cell::s(if ch == Choice::Sq { "SQ" } else { "VQ" }),
        ]);
    }
    t.print();
}
