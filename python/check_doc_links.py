#!/usr/bin/env python3
"""Fail on broken relative links in markdown files.

Usage:
    python3 python/check_doc_links.py README.md docs [more files or dirs...]

Walks every argument (directories are scanned recursively for *.md),
extracts inline markdown links and images (``[text](target)``), and
checks that each *relative* target exists on disk, resolved against the
linking file's directory. Skipped targets:

  * absolute URLs (``http://``, ``https://``, ``mailto:`` or any
    ``scheme:`` prefix),
  * pure in-page anchors (``#section``),
  * absolute paths (deliberate: docs should link relatively so they work
    on GitHub and in checkouts alike — an absolute path is reported).

A ``target#anchor`` suffix is stripped before the existence check (the
file must exist; anchors inside it are not validated).

Exit status: 0 when every link resolves, 1 otherwise (each broken link
is printed as ``file:line: broken link -> target``).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links/images: [text](target) / ![alt](target), target ends at
# the first unescaped ')' — titles ("...") after the target are dropped
LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
SCHEME_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def markdown_files(args: list[str]) -> list[Path]:
    files: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"warning: {a} does not exist, skipping", file=sys.stderr)
    return files


def strip_code(text: str) -> str:
    """Blank out fenced code blocks and inline code spans.

    Links inside code are examples, not navigation — `](` sequences in
    shell snippets must not be flagged. Line structure is preserved so
    reported line numbers stay correct.
    """
    out: list[str] = []
    in_fence = False
    for line in text.splitlines():
        stripped = line.lstrip()
        if stripped.startswith("```") or stripped.startswith("~~~"):
            in_fence = not in_fence
            out.append("")
            continue
        if in_fence:
            out.append("")
        else:
            out.append(re.sub(r"`[^`]*`", "", line))
    return "\n".join(out)


def check_file(md: Path) -> list[str]:
    errors: list[str] = []
    text = strip_code(md.read_text(encoding="utf-8"))
    for lineno, line in enumerate(text.splitlines(), start=1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if SCHEME_RE.match(target) or target.startswith("#"):
                continue
            if target.startswith("/"):
                errors.append(
                    f"{md}:{lineno}: absolute path (use a relative link) -> {target}"
                )
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (md.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{md}:{lineno}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    files = markdown_files(argv)
    if not files:
        print("error: no markdown files found", file=sys.stderr)
        return 2
    errors: list[str] = []
    checked = 0
    for md in files:
        errors.extend(check_file(md))
        checked += 1
    for e in errors:
        print(e)
    print(f"checked {checked} markdown file(s): {len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
