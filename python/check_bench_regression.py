#!/usr/bin/env python3
"""CI perf-trajectory gate over BENCH_serve.json.

Compares the packed served throughput of a fresh bench run against the
committed baseline and exits non-zero when it regresses by more than the
threshold. BENCH_serve.json is written by

    RWKVQUANT_BENCH_FAST=1 cargo bench --bench table4_speed_memory

Behaviour matrix:

* healthy baseline           -> prints a trajectory-delta summary over
  the headline metrics, then gates on ``--key``.
* ``"provisional": true``    -> summary of the current run only; never
  fails (the gate arms itself the first time a measured BENCH_serve.json
  is committed).
* malformed baseline (bad JSON, missing keys, not a bench file) ->
  reports exactly what is wrong in the job log, treats the baseline as
  provisional, exits 0 — a broken baseline must be loud, not a silent
  traceback, and must not mask the current run's numbers.
* malformed CURRENT file     -> hard failure (exit 2); the bench run
  itself is broken and that must gate.

Two further CI modes:

* ``--history PATH``         -> append this run's headline metrics
  (keyed by commit SHA + date) to a JSONL trajectory file and print the
  last-5-run table, so the workflow summary shows where the numbers are
  *heading*, not just the delta against one baseline.
* ``--require-armed``        -> exit 3 with a copy-paste arming
  instruction when the committed baseline is still provisional or
  malformed; exit 0 when a measured baseline is committed. Run on main
  so an unarmed gate is a red build, not a silent footnote.

When ``GITHUB_STEP_SUMMARY`` is set, the trajectory tables are also
appended there so they show on the workflow summary page.

Usage:
    python3 python/check_bench_regression.py BASELINE CURRENT \
        [--key speedup] [--max-key batch64.ttft_ms] \
        [--threshold 0.10] [--no-summary] \
        [--history bench_history.jsonl] [--sha SHA] [--run-date DATE] \
        [--require-armed]
"""

import argparse
import datetime
import json
import os
import sys

# Headline metrics reported in the trajectory summary (missing keys are
# skipped silently — older baselines predate some of them).
SUMMARY_KEYS = [
    "speedup",
    "fp32.tokens_per_sec",
    "quant.tokens_per_sec",
    "quant_threaded.tokens_per_sec",
    "pool_vs_spawn",
    "batch64.tokens_per_sec",
    "batch64.prefill_tokens_per_sec",
    "batch64.ttft_ms",
]

# Columns of the --history table: (header, dotted key in BENCH_serve).
HISTORY_COLUMNS = [
    ("speedup", "speedup"),
    ("quant tok/s", "quant.tokens_per_sec"),
    ("fp32 tok/s", "fp32.tokens_per_sec"),
    ("pool tok/s", "quant_threaded.tokens_per_sec"),
    ("pool/spawn", "pool_vs_spawn"),
    ("b64 tok/s", "batch64.tokens_per_sec"),
    ("b64 ttft ms", "batch64.ttft_ms"),
]

HISTORY_SHOWN_RUNS = 5


def lookup(obj, dotted_key):
    """Walk a dotted key ("quant.tokens_per_sec") through nested dicts."""
    node = obj
    for part in dotted_key.split("."):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"key '{dotted_key}' missing at '{part}'")
        node = node[part]
    return float(node)


def try_lookup(obj, dotted_key):
    try:
        return lookup(obj, dotted_key)
    except (KeyError, TypeError, ValueError):
        return None


def load_json(path):
    """Return (parsed, error_string); exactly one is None."""
    try:
        with open(path) as fh:
            return json.load(fh), None
    except OSError as e:
        return None, f"cannot read {path}: {e}"
    except json.JSONDecodeError as e:
        return None, f"{path} is not valid JSON: {e}"


def trajectory_summary(base, cur, gate_key, threshold, max_key=None):
    """Render the delta table; returns the lines (also printed)."""
    lines = ["", "perf trajectory (baseline -> current):"]
    for key in SUMMARY_KEYS:
        new = try_lookup(cur, key)
        if new is None:
            continue
        old = try_lookup(base, key) if base is not None else None
        if key == gate_key:
            gate_mark = "  [gated -{:.0%}]".format(threshold)
        elif key == max_key:
            gate_mark = "  [gated +{:.0%}]".format(threshold)
        else:
            gate_mark = ""
        if old in (None, 0.0):
            lines.append(f"  {key:<30} {'-':>10} -> {new:10.2f}{gate_mark}")
        else:
            delta = new / old - 1.0
            lines.append(
                f"  {key:<30} {old:10.2f} -> {new:10.2f}  ({delta:+.1%}){gate_mark}"
            )
    kernel = (cur or {}).get("kernel")
    if kernel:
        lines.append(f"  kernel: {kernel}")
    lines.append("")
    print("\n".join(lines))
    append_step_summary(lines)
    return lines


def append_step_summary(lines):
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        try:
            with open(step_summary, "a") as fh:
                fh.write("```\n" + "\n".join(lines).strip() + "\n```\n")
        except OSError:
            pass  # the job log already has the table


def update_history(path, cur, sha, run_date):
    """Append this run's headline metrics to the JSONL trajectory file
    and print the last-N-run table (also to the step summary)."""
    entry = {"sha": sha, "date": run_date, "kernel": (cur or {}).get("kernel")}
    for _, key in HISTORY_COLUMNS:
        val = try_lookup(cur, key)
        if val is not None:
            entry[key] = val
    runs = []
    try:
        with open(path) as fh:
            for ln, raw in enumerate(fh, 1):
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    runs.append(json.loads(raw))
                except json.JSONDecodeError:
                    print(f"WARNING: {path}:{ln} is not valid JSON — dropping the line")
    except OSError:
        pass  # first run: no history yet
    runs.append(entry)
    try:
        with open(path, "w") as fh:
            for run in runs:
                fh.write(json.dumps(run) + "\n")
    except OSError as e:
        print(f"WARNING: cannot write bench history {path}: {e}")

    shown = runs[-HISTORY_SHOWN_RUNS:]
    lines = ["", f"bench trajectory (last {len(shown)} of {len(runs)} recorded runs):"]
    header = f"  {'sha':<9} {'date':<11}"
    for title, _ in HISTORY_COLUMNS:
        header += f" {title:>12}"
    lines.append(header + "  kernel")
    for run in shown:
        row = f"  {str(run.get('sha', '?'))[:8]:<9} {str(run.get('date', '?')):<11}"
        for _, key in HISTORY_COLUMNS:
            val = run.get(key)
            row += f" {val:12.2f}" if isinstance(val, (int, float)) else f" {'-':>12}"
        lines.append(row + f"  {run.get('kernel') or '-'}")
    lines.append("")
    print("\n".join(lines))
    append_step_summary(lines)


def require_armed(baseline_path, key):
    """Exit code for the main-branch arming check: 0 once a measured
    baseline is committed, 3 (with a copy-paste instruction) before."""
    base, base_err = load_json(baseline_path)
    if not isinstance(base, dict):
        base = None  # valid JSON but not a bench object — still unarmed
        base_err = base_err or f"{baseline_path} is not a bench-result object"
    measured = (
        base is not None
        and not base.get("provisional")
        and try_lookup(base, key) is not None
    )
    if measured:
        armed_line = (
            f"OK: committed baseline is measured ({key} = {lookup(base, key):.2f}) — gate armed"
        )
        print(armed_line)
        # the step summary must say so explicitly: an armed gate that is
        # only visible in the job log reads the same as an unarmed one
        append_step_summary([armed_line])
        return 0
    reason = base_err or (
        "baseline is provisional" if base is not None and base.get("provisional")
        else f"baseline has no '{key}' metric"
    )
    print(f"FAIL: the perf-regression gate is NOT armed — {reason}.")
    print("")
    print("This run produced a measured BENCH_serve.json (uploaded as the")
    print("'BENCH_serve' artifact). Arm the gate with either:")
    print("")
    print("  # a) guarded auto-commit from CI:")
    print("  gh workflow run ci.yml -f commit_baseline=true")
    print("")
    print("  # b) or commit the artifact by hand:")
    print("  gh run download --name BENCH_serve --dir .")
    print("  git add BENCH_serve.json")
    print('  git commit -m "ci: arm the bench gate with the first measured baseline"')
    print("  git push")
    return 3


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("baseline", help="committed BENCH_serve.json")
    parser.add_argument("current", help="BENCH_serve.json from this run")
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="max tolerated fractional regression (default 0.10 = 10%%)",
    )
    parser.add_argument(
        "--key",
        default="quant.tokens_per_sec",
        help="dotted metric key to gate on (default: packed served throughput)",
    )
    parser.add_argument(
        "--max-key",
        default=None,
        help="dotted metric key gated UPWARD — higher is worse (e.g. "
        "batch64.ttft_ms): fail when it grows past baseline*(1+threshold)",
    )
    parser.add_argument(
        "--no-summary",
        action="store_true",
        help="skip the trajectory table (second gate invocation in CI)",
    )
    parser.add_argument(
        "--history",
        metavar="PATH",
        help="append this run to a JSONL trajectory file and print the "
        f"last-{HISTORY_SHOWN_RUNS}-run table",
    )
    parser.add_argument(
        "--sha",
        default=os.environ.get("GITHUB_SHA", "local"),
        help="commit SHA recorded in --history entries (default: $GITHUB_SHA)",
    )
    parser.add_argument(
        "--run-date",
        default=None,
        help="date recorded in --history entries (default: today, UTC)",
    )
    parser.add_argument(
        "--require-armed",
        action="store_true",
        help="exit 3 with an arming instruction if the baseline is still "
        "provisional (run on main so an unarmed gate fails loudly)",
    )
    args = parser.parse_args()

    if args.require_armed:
        return require_armed(args.baseline, args.key)

    cur, cur_err = load_json(args.current)
    if cur_err is not None:
        print(f"FAIL: current bench output is unusable — {cur_err}")
        return 2
    new = try_lookup(cur, args.key)
    if new is None:
        print(f"FAIL: current bench output has no '{args.key}' metric")
        return 2
    print(f"current  {args.key} = {new:.2f}")
    max_new = None
    if args.max_key:
        max_new = try_lookup(cur, args.max_key)
        if max_new is None:
            print(f"FAIL: current bench output has no '{args.max_key}' metric")
            return 2
        print(f"current  {args.max_key} = {max_new:.2f}")

    if args.history:
        run_date = args.run_date or datetime.datetime.now(datetime.timezone.utc).date().isoformat()
        update_history(args.history, cur, args.sha, run_date)

    base, base_err = load_json(args.baseline)
    if base is None or try_lookup(base, args.key) is None:
        reason = base_err or f"baseline has no '{args.key}' metric"
        print(f"WARNING: malformed baseline — {reason}")
        print("treating baseline as provisional: reporting only, gate skipped")
        if not args.no_summary:
            trajectory_summary(None, cur, args.key, args.threshold)
        print("commit this run's BENCH_serve.json artifact to restore the gate")
        return 0

    if base.get("provisional"):
        print("baseline is provisional (no measured CI run committed yet) — gate skipped")
        if not args.no_summary:
            trajectory_summary(None, cur, args.key, args.threshold)
        print("commit this run's BENCH_serve.json artifact to arm the regression gate")
        return 0

    old = lookup(base, args.key)
    floor = old * (1.0 - args.threshold)
    print(f"baseline {args.key} = {old:.2f} (floor at -{args.threshold:.0%}: {floor:.2f})")
    if not args.no_summary:
        trajectory_summary(base, cur, args.key, args.threshold, args.max_key)
    if new < floor:
        print(
            f"FAIL: {args.key} regressed {1.0 - new / old:.1%} "
            f"(> {args.threshold:.0%} allowed)"
        )
        return 1
    delta = new / old - 1.0
    print(f"OK: {args.key} changed {delta:+.1%}")

    # upward-bound gate: latency-style metrics regress by GROWING
    if args.max_key:
        old_max = try_lookup(base, args.max_key)
        if old_max is None:
            print(
                f"WARNING: baseline has no '{args.max_key}' metric — "
                "upward gate skipped until a newer baseline is committed"
            )
        elif old_max <= 0.0:
            print(
                f"WARNING: baseline '{args.max_key}' is {old_max:.2f} — "
                "upward gate skipped (unmeasured placeholder value)"
            )
        else:
            ceiling = old_max * (1.0 + args.threshold)
            print(
                f"baseline {args.max_key} = {old_max:.2f} "
                f"(ceiling at +{args.threshold:.0%}: {ceiling:.2f})"
            )
            if max_new > ceiling:
                print(
                    f"FAIL: {args.max_key} grew {max_new / old_max - 1.0:+.1%} "
                    f"(> +{args.threshold:.0%} allowed)"
                )
                return 1
            print(f"OK: {args.max_key} changed {max_new / old_max - 1.0:+.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
