#!/usr/bin/env python3
"""CI perf-trajectory gate over BENCH_serve.json.

Compares the packed served throughput of a fresh bench run against the
committed baseline and exits non-zero when it regresses by more than the
threshold. BENCH_serve.json is written by

    RWKVQUANT_BENCH_FAST=1 cargo bench --bench table4_speed_memory

Baselines carrying ``"provisional": true`` (committed before any
measured CI run exists) report the current numbers but never fail — the
gate arms itself the first time a measured BENCH_serve.json is
committed.

Usage:
    python3 python/check_bench_regression.py BASELINE CURRENT [--threshold 0.10]
"""

import argparse
import json
import sys


def lookup(obj, dotted_key):
    """Walk a dotted key ("quant.tokens_per_sec") through nested dicts."""
    node = obj
    for part in dotted_key.split("."):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"key '{dotted_key}' missing at '{part}'")
        node = node[part]
    return float(node)


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("baseline", help="committed BENCH_serve.json")
    parser.add_argument("current", help="BENCH_serve.json from this run")
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="max tolerated fractional regression (default 0.10 = 10%%)",
    )
    parser.add_argument(
        "--key",
        default="quant.tokens_per_sec",
        help="dotted metric key to gate on (default: packed served throughput)",
    )
    args = parser.parse_args()

    with open(args.baseline) as fh:
        base = json.load(fh)
    with open(args.current) as fh:
        cur = json.load(fh)

    new = lookup(cur, args.key)
    print(f"current  {args.key} = {new:.2f}")

    if base.get("provisional"):
        print("baseline is provisional (no measured CI run committed yet) — gate skipped")
        print("commit this run's BENCH_serve.json artifact to arm the regression gate")
        return 0

    old = lookup(base, args.key)
    floor = old * (1.0 - args.threshold)
    print(f"baseline {args.key} = {old:.2f} (floor at -{args.threshold:.0%}: {floor:.2f})")
    if new < floor:
        print(
            f"FAIL: {args.key} regressed {1.0 - new / old:.1%} "
            f"(> {args.threshold:.0%} allowed)"
        )
        return 1
    delta = new / old - 1.0
    print(f"OK: {args.key} changed {delta:+.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
