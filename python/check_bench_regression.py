#!/usr/bin/env python3
"""CI perf-trajectory gate over BENCH_serve.json.

Compares the packed served throughput of a fresh bench run against the
committed baseline and exits non-zero when it regresses by more than the
threshold. BENCH_serve.json is written by

    RWKVQUANT_BENCH_FAST=1 cargo bench --bench table4_speed_memory

Behaviour matrix:

* healthy baseline           -> prints a trajectory-delta summary over
  the headline metrics, then gates on ``--key``.
* ``"provisional": true``    -> summary of the current run only; never
  fails (the gate arms itself the first time a measured BENCH_serve.json
  is committed).
* malformed baseline (bad JSON, missing keys, not a bench file) ->
  reports exactly what is wrong in the job log, treats the baseline as
  provisional, exits 0 — a broken baseline must be loud, not a silent
  traceback, and must not mask the current run's numbers.
* malformed CURRENT file     -> hard failure (exit 2); the bench run
  itself is broken and that must gate.

When ``GITHUB_STEP_SUMMARY`` is set, the trajectory table is also
appended there so the delta shows on the workflow summary page.

Usage:
    python3 python/check_bench_regression.py BASELINE CURRENT \
        [--key speedup] [--threshold 0.10] [--no-summary]
"""

import argparse
import json
import os
import sys

# Headline metrics reported in the trajectory summary (missing keys are
# skipped silently — older baselines predate some of them).
SUMMARY_KEYS = [
    "speedup",
    "fp32.tokens_per_sec",
    "quant.tokens_per_sec",
    "quant_threaded.tokens_per_sec",
]


def lookup(obj, dotted_key):
    """Walk a dotted key ("quant.tokens_per_sec") through nested dicts."""
    node = obj
    for part in dotted_key.split("."):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"key '{dotted_key}' missing at '{part}'")
        node = node[part]
    return float(node)


def try_lookup(obj, dotted_key):
    try:
        return lookup(obj, dotted_key)
    except (KeyError, TypeError, ValueError):
        return None


def load_json(path):
    """Return (parsed, error_string); exactly one is None."""
    try:
        with open(path) as fh:
            return json.load(fh), None
    except OSError as e:
        return None, f"cannot read {path}: {e}"
    except json.JSONDecodeError as e:
        return None, f"{path} is not valid JSON: {e}"


def trajectory_summary(base, cur, gate_key, threshold):
    """Render the delta table; returns the lines (also printed)."""
    lines = ["", "perf trajectory (baseline -> current):"]
    for key in SUMMARY_KEYS:
        new = try_lookup(cur, key)
        if new is None:
            continue
        old = try_lookup(base, key) if base is not None else None
        gate_mark = "  [gated ±{:.0%}]".format(threshold) if key == gate_key else ""
        if old in (None, 0.0):
            lines.append(f"  {key:<30} {'-':>10} -> {new:10.2f}{gate_mark}")
        else:
            delta = new / old - 1.0
            lines.append(
                f"  {key:<30} {old:10.2f} -> {new:10.2f}  ({delta:+.1%}){gate_mark}"
            )
    kernel = (cur or {}).get("kernel")
    if kernel:
        lines.append(f"  kernel: {kernel}")
    lines.append("")
    print("\n".join(lines))
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        try:
            with open(step_summary, "a") as fh:
                fh.write("```\n" + "\n".join(lines).strip() + "\n```\n")
        except OSError:
            pass  # the job log already has the table
    return lines


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("baseline", help="committed BENCH_serve.json")
    parser.add_argument("current", help="BENCH_serve.json from this run")
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="max tolerated fractional regression (default 0.10 = 10%%)",
    )
    parser.add_argument(
        "--key",
        default="quant.tokens_per_sec",
        help="dotted metric key to gate on (default: packed served throughput)",
    )
    parser.add_argument(
        "--no-summary",
        action="store_true",
        help="skip the trajectory table (second gate invocation in CI)",
    )
    args = parser.parse_args()

    cur, cur_err = load_json(args.current)
    if cur_err is not None:
        print(f"FAIL: current bench output is unusable — {cur_err}")
        return 2
    new = try_lookup(cur, args.key)
    if new is None:
        print(f"FAIL: current bench output has no '{args.key}' metric")
        return 2
    print(f"current  {args.key} = {new:.2f}")

    base, base_err = load_json(args.baseline)
    if base is None or try_lookup(base, args.key) is None:
        reason = base_err or f"baseline has no '{args.key}' metric"
        print(f"WARNING: malformed baseline — {reason}")
        print("treating baseline as provisional: reporting only, gate skipped")
        if not args.no_summary:
            trajectory_summary(None, cur, args.key, args.threshold)
        print("commit this run's BENCH_serve.json artifact to restore the gate")
        return 0

    if base.get("provisional"):
        print("baseline is provisional (no measured CI run committed yet) — gate skipped")
        if not args.no_summary:
            trajectory_summary(None, cur, args.key, args.threshold)
        print("commit this run's BENCH_serve.json artifact to arm the regression gate")
        return 0

    old = lookup(base, args.key)
    floor = old * (1.0 - args.threshold)
    print(f"baseline {args.key} = {old:.2f} (floor at -{args.threshold:.0%}: {floor:.2f})")
    if not args.no_summary:
        trajectory_summary(base, cur, args.key, args.threshold)
    if new < floor:
        print(
            f"FAIL: {args.key} regressed {1.0 - new / old:.1%} "
            f"(> {args.threshold:.0%} allowed)"
        )
        return 1
    delta = new / old - 1.0
    print(f"OK: {args.key} changed {delta:+.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
