"""Training-loop correctness: grammar structure, corpus codec, Adam
actually descending, and the trainable-parameter policy."""

import os
import struct
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M
from compile import train as T


def test_grammar_sampling_respects_structure():
    rng = np.random.default_rng(0)
    g = T.build_grammar(64, branch=4, rng=rng)
    toks = T.sample_grammar(g, 500, rng)
    assert toks.min() >= 0 and toks.max() < 64
    # successor sets are sparse: conditional diversity far below vocab
    seen = {}
    for i in range(2, len(toks)):
        key = (int(toks[i - 2]) % 8, int(toks[i - 1]))
        seen.setdefault(key, set()).add(int(toks[i]))
    max_succ = max(len(v) for v in seen.values())
    assert max_succ <= 4, f"observed {max_succ} successors for one state"


def test_corpus_codec_round_trip():
    rng = np.random.default_rng(1)
    train = rng.integers(0, 256, 100).astype(np.int32)
    valid = rng.integers(0, 256, 40).astype(np.int32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.bin")
        T.save_corpus(path, 256, train, valid)
        raw = open(path, "rb").read()
        assert raw[:8] == b"RWKVC1\x00\x00"
        vocab, tlen, vlen = struct.unpack("<IQQ", raw[8:28])
        assert (vocab, tlen, vlen) == (256, 100, 40)
        got_train = np.frombuffer(raw[28:28 + 400], dtype=np.uint32)
        np.testing.assert_array_equal(got_train, train.astype(np.uint32))


def test_adam_descends_on_fixed_batch():
    cfg = M.Config("rwkv6", n_layer=1, d_model=128, vocab=32)
    rng = np.random.default_rng(2)
    params = T.init_params(cfg, rng)
    toks = jnp.asarray(rng.integers(0, 32, (2, 17)), jnp.int32)

    def batch_loss(p, t):
        return jnp.mean(jax.vmap(lambda s: M.sequence_loss(p, cfg, s))(t))

    loss_grad = jax.jit(jax.value_and_grad(batch_loss))
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v) for k, v in params.items()}
    l0, _ = loss_grad(params, toks)
    for step in range(30):
        loss, grads = loss_grad(params, toks)
        params, m, v = T.adam_update(params, grads, m, v, step, 5e-3)
    l1, _ = loss_grad(params, toks)
    assert float(l1) < float(l0) - 0.2, f"{float(l0)} -> {float(l1)}"


def test_frozen_parameters_stay_frozen():
    cfg = M.Config("rwkv6", n_layer=1, d_model=128, vocab=32)
    rng = np.random.default_rng(3)
    params = T.init_params(cfg, rng)
    decay_before = np.asarray(params["blocks.0.att.decay"]).copy()
    toks = jnp.asarray(rng.integers(0, 32, (1, 9)), jnp.int32)

    def batch_loss(p, t):
        return jnp.mean(jax.vmap(lambda s: M.sequence_loss(p, cfg, s))(t))

    loss_grad = jax.jit(jax.value_and_grad(batch_loss))
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v) for k, v in params.items()}
    for step in range(3):
        _, grads = loss_grad(params, toks)
        params, m, v = T.adam_update(params, grads, m, v, step, 1e-2)
    np.testing.assert_array_equal(np.asarray(params["blocks.0.att.decay"]), decay_before)


def test_is_trainable_policy():
    assert T.is_trainable("blocks.0.att.w_r")
    assert T.is_trainable("blocks.0.ffn.mu_k")
    assert T.is_trainable("emb") and T.is_trainable("head")
    assert not T.is_trainable("blocks.0.att.decay")
    assert not T.is_trainable("blocks.0.att.bonus")
