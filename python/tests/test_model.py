"""L2 correctness: model step (pallas path vs jnp reference path), store
codec round-trip, and sequence-loss sanity."""

import os
import tempfile

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model as M
from compile.train import init_params


@pytest.fixture(scope="module")
def tiny():
    cfg = M.Config("rwkv6", n_layer=2, d_model=128, vocab=64)
    params = init_params(cfg, np.random.default_rng(0))
    return cfg, params


def test_pallas_step_matches_ref_step(tiny):
    cfg, params = tiny
    state = M.init_state(cfg)
    for tok in [0, 5, 63]:
        lp, sp = M.model_step(params, cfg, tok, state, use_pallas=True)
        lr, sr = M.model_step(params, cfg, tok, state, use_pallas=False)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-4, atol=1e-4)
        for k in sp:
            np.testing.assert_allclose(np.asarray(sp[k]), np.asarray(sr[k]),
                                       rtol=1e-4, atol=1e-4)


def test_state_threading_changes_logits(tiny):
    cfg, params = tiny
    state = M.init_state(cfg)
    _, s1 = M.model_step(params, cfg, 1, state)
    la, _ = M.model_step(params, cfg, 2, s1)
    lb, _ = M.model_step(params, cfg, 2, state)
    assert np.abs(np.asarray(la) - np.asarray(lb)).max() > 1e-5


def test_store_round_trip(tiny):
    cfg, params = tiny
    classes = M.param_classes(cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.bin")
        M.save_store(path, cfg, {k: np.asarray(v) for k, v in params.items()}, classes)
        cfg2, params2 = M.load_store(path)
        assert cfg2.arch == cfg.arch and cfg2.d_model == cfg.d_model
        assert set(params2) == set(params)
        for k in params:
            want = np.asarray(params[k])
            if want.ndim == 1:
                want = want[None, :]
            np.testing.assert_array_equal(params2[k], want)


def test_param_classes_cover_all_params(tiny):
    cfg, params = tiny
    classes = M.param_classes(cfg)
    assert set(classes) == set(params)


def test_sequence_loss_finite_and_near_uniform(tiny):
    cfg, params = tiny
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 64, 24), jnp.int32)
    loss = float(M.sequence_loss(params, cfg, toks))
    assert np.isfinite(loss)
    assert 1.0 < loss < 10.0  # untrained ~ log(64) = 4.16


def test_rwkv7_variant_runs():
    cfg = M.Config("rwkv7", n_layer=1, d_model=128, vocab=32)
    rng = np.random.default_rng(2)
    params = init_params(M.Config("rwkv6", 1, 128, 32), rng)
    # add the gate params the rwkv7 path needs
    params["blocks.0.att.mu_g"] = jnp.asarray(
        rng.uniform(0.3, 0.7, (1, 128)).astype(np.float32))
    params["blocks.0.att.w_g"] = jnp.asarray(
        (rng.standard_normal((128, 128)) * 0.05).astype(np.float32))
    logits, _ = M.model_step(params, cfg, 3, M.init_state(cfg))
    assert np.isfinite(np.asarray(logits)).all()
