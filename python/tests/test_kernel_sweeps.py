"""Hypothesis-style randomized sweeps over the Pallas kernels — shapes,
dtypes-adjacent ranges and adversarial values, asserting against ref.py.
(The hypothesis package is not in this image; sweeps are seeded numpy.)"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import dequant_matmul as dq
from compile.kernels import ewmix as ewmix_k
from compile.kernels import ref
from compile.kernels import wkv as wkv_k


CASES = 12


@pytest.mark.parametrize("case", range(CASES))
def test_wkv_step_random_sweep(case):
    r = np.random.default_rng(1000 + case)
    d = int(r.choice([128, 256, 384, 512]))
    scale = float(r.uniform(0.1, 5.0))
    k = (r.standard_normal(d) * scale).astype(np.float32)
    v = (r.standard_normal(d) * scale).astype(np.float32)
    w = r.uniform(0.05, 8.0, d).astype(np.float32)
    u = (r.standard_normal(d)).astype(np.float32)
    aa = (r.standard_normal(d) * scale).astype(np.float32)
    bb = r.uniform(0.1, 3.0, d).astype(np.float32)
    pp = r.uniform(-5, 5, d).astype(np.float32)
    got = wkv_k.wkv_step(*map(jnp.asarray, (k, v, w, u, aa, bb, pp)))
    want_wkv, (waa, wbb, wpp) = ref.wkv_step_ref(k, v, w, u, aa, bb, pp)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want_wkv),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got[3]), np.asarray(wpp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", range(CASES))
def test_ewmix_random_sweep(case):
    r = np.random.default_rng(2000 + case)
    d = int(r.choice([128, 256, 512, 1024]))
    mu = r.uniform(0, 1, d).astype(np.float32)
    # adversarial: exact 0/1 pins and large activations
    mu[: d // 8] = 0.0
    mu[d // 8: d // 4] = 1.0
    a = (r.standard_normal(d) * 100).astype(np.float32)
    b = (r.standard_normal(d) * 100).astype(np.float32)
    got = ewmix_k.ewmix(jnp.asarray(mu), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), ref.ewmix_ref(mu, a, b),
                               rtol=1e-6, atol=1e-4)


@pytest.mark.parametrize("case", range(8))
def test_vq_matvec_random_sweep(case):
    r = np.random.default_rng(3000 + case)
    d = int(r.choice([2, 4, 8]))
    oc = int(r.choice([64, 128, 192]))
    ic = int(r.choice([128, 256]))
    if ic % d != 0 or oc % 64 != 0:
        pytest.skip("shape not tile-aligned")
    k_bits = int(r.choice([4, 6, 8]))
    n_entries = 1 << k_bits
    cb = (r.standard_normal((n_entries, d)) * 0.1).astype(np.float32)
    idx = r.integers(0, n_entries, oc * ic // d).astype(np.int32)
    x = r.standard_normal(ic).astype(np.float32)
    got = dq.dequant_matvec(jnp.asarray(cb), jnp.asarray(idx), jnp.asarray(x),
                            oc=oc, ic=ic)
    want = ref.dequant_matvec_ref(cb, idx, x, oc, ic)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_wkv_step_extreme_state_values():
    """pp starting at -1e30 (fresh state) and huge k spikes must not
    produce NaNs — the stabilised form's whole point."""
    d = 128
    k = np.full(d, 80.0, np.float32)  # exp(80) overflows fp32 if naive
    v = np.ones(d, np.float32)
    w = np.full(d, 0.5, np.float32)
    u = np.full(d, 1.0, np.float32)
    aa = np.zeros(d, np.float32)
    bb = np.zeros(d, np.float32)
    pp = np.full(d, -1e30, np.float32)
    out, aa2, bb2, pp2 = wkv_k.wkv_step(*map(jnp.asarray, (k, v, w, u, aa, bb, pp)))
    for arr in (out, aa2, bb2, pp2):
        assert np.isfinite(np.asarray(arr)).all()
    # with a single huge-k token, wkv ≈ v
    np.testing.assert_allclose(np.asarray(out), v, rtol=1e-4)
