"""AOT lowering contract: HLO text is produced, is parseable-looking, and
the input-manifest naming matches the flatten order the Rust runtime
relies on (rust/src/runtime/rwkv_graph.rs)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M
from compile.train import init_params


def test_smoke_hlo_text_shape():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_flat_input_names_order():
    tok = jax.ShapeDtypeStruct((), jnp.int32)
    state = {"aa": jax.ShapeDtypeStruct((2, 4), jnp.float32),
             "bb": jax.ShapeDtypeStruct((2, 4), jnp.float32)}
    params = {"emb": jax.ShapeDtypeStruct((8, 4), jnp.float32),
              "blocks.0.att.w_r": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    names = aot.flat_input_names((tok, state, params))
    assert names[0] == "0"
    assert names[1] == "1/aa" and names[2] == "1/bb"
    # dict order is sorted by key in jax pytrees
    assert names[3] == "2/blocks.0.att.w_r"
    assert names[4] == "2/emb"


def test_rwkv_step_lowering_roundtrip(tmp_path):
    cfg = M.Config("rwkv6", n_layer=1, d_model=128, vocab=32)
    params = {k: np.asarray(v) for k, v in init_params(cfg, np.random.default_rng(3)).items()}
    aot.lower_rwkv_step(cfg, params, str(tmp_path))
    hlo = (tmp_path / "rwkv_step.hlo.txt").read_text()
    assert "HloModule" in hlo
    manifest = (tmp_path / "rwkv_step.inputs.txt").read_text().strip().splitlines()
    # token + 5 state tensors + all params
    assert manifest[0] == "0"
    assert len(manifest) == 1 + 5 + len(params)
    assert all(line.startswith(("0", "1/", "2/")) for line in manifest)
