"""Behaviour matrix of python/check_bench_regression.py — the CI
perf-trajectory gate must report deltas, arm/disarm on provisional or
malformed baselines, and only hard-fail on real regressions (or a broken
current run)."""

import json
import subprocess
import sys
from pathlib import Path

SCRIPT = Path(__file__).resolve().parents[1] / "check_bench_regression.py"

CURRENT = {
    "kernel": "avx2",
    "fp32": {"tokens_per_sec": 100.0},
    "quant": {"tokens_per_sec": 250.0},
    "quant_threaded": {"tokens_per_sec": 400.0},
    "speedup": 2.5,
}


def run_gate(tmp_path, baseline, current, *extra):
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(baseline if isinstance(baseline, str) else json.dumps(baseline))
    cur.write_text(current if isinstance(current, str) else json.dumps(current))
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), str(base), str(cur), *extra],
        capture_output=True,
        text=True,
    )
    return proc.returncode, proc.stdout


def test_provisional_baseline_reports_but_never_fails(tmp_path):
    rc, out = run_gate(tmp_path, {"provisional": True, "speedup": 0}, CURRENT, "--key", "speedup")
    assert rc == 0
    assert "provisional" in out
    assert "perf trajectory" in out


def test_malformed_baseline_is_loud_and_skips_gate(tmp_path):
    rc, out = run_gate(tmp_path, "this is not json {", CURRENT, "--key", "speedup")
    assert rc == 0
    assert "malformed baseline" in out
    assert "perf trajectory" in out  # current numbers still reported


def test_missing_gate_key_in_baseline_skips_gate(tmp_path):
    rc, out = run_gate(tmp_path, {"other": 1}, CURRENT, "--key", "speedup")
    assert rc == 0
    assert "malformed baseline" in out


def test_healthy_baseline_passes_and_prints_deltas(tmp_path):
    base = {"fp32": {"tokens_per_sec": 90.0}, "quant": {"tokens_per_sec": 240.0}, "speedup": 2.4}
    rc, out = run_gate(tmp_path, base, CURRENT, "--key", "speedup", "--threshold", "0.10")
    assert rc == 0
    assert "perf trajectory" in out
    assert "OK: speedup" in out


def test_regression_beyond_threshold_fails(tmp_path):
    base = {"speedup": 3.5}
    rc, out = run_gate(tmp_path, base, CURRENT, "--key", "speedup", "--threshold", "0.10")
    assert rc == 1
    assert "FAIL" in out


def test_broken_current_run_hard_fails(tmp_path):
    rc, out = run_gate(tmp_path, {"speedup": 2.4}, "nope{", "--key", "speedup")
    assert rc == 2
    assert "unusable" in out
