"""Behaviour matrix of python/check_bench_regression.py — the CI
perf-trajectory gate must report deltas, arm/disarm on provisional or
malformed baselines, and only hard-fail on real regressions (or a broken
current run)."""

import json
import subprocess
import sys
from pathlib import Path

SCRIPT = Path(__file__).resolve().parents[1] / "check_bench_regression.py"

CURRENT = {
    "kernel": "avx2",
    "fp32": {"tokens_per_sec": 100.0},
    "quant": {"tokens_per_sec": 250.0},
    "quant_threaded": {"tokens_per_sec": 400.0},
    "batch64": {"tokens_per_sec": 900.0, "ttft_ms": 12.0},
    "speedup": 2.5,
}


def run_gate(tmp_path, baseline, current, *extra):
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(baseline if isinstance(baseline, str) else json.dumps(baseline))
    cur.write_text(current if isinstance(current, str) else json.dumps(current))
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), str(base), str(cur), *extra],
        capture_output=True,
        text=True,
    )
    return proc.returncode, proc.stdout


def test_provisional_baseline_reports_but_never_fails(tmp_path):
    rc, out = run_gate(tmp_path, {"provisional": True, "speedup": 0}, CURRENT, "--key", "speedup")
    assert rc == 0
    assert "provisional" in out
    assert "perf trajectory" in out


def test_malformed_baseline_is_loud_and_skips_gate(tmp_path):
    rc, out = run_gate(tmp_path, "this is not json {", CURRENT, "--key", "speedup")
    assert rc == 0
    assert "malformed baseline" in out
    assert "perf trajectory" in out  # current numbers still reported


def test_missing_gate_key_in_baseline_skips_gate(tmp_path):
    rc, out = run_gate(tmp_path, {"other": 1}, CURRENT, "--key", "speedup")
    assert rc == 0
    assert "malformed baseline" in out


def test_healthy_baseline_passes_and_prints_deltas(tmp_path):
    base = {"fp32": {"tokens_per_sec": 90.0}, "quant": {"tokens_per_sec": 240.0}, "speedup": 2.4}
    rc, out = run_gate(tmp_path, base, CURRENT, "--key", "speedup", "--threshold", "0.10")
    assert rc == 0
    assert "perf trajectory" in out
    assert "OK: speedup" in out


def test_regression_beyond_threshold_fails(tmp_path):
    base = {"speedup": 3.5}
    rc, out = run_gate(tmp_path, base, CURRENT, "--key", "speedup", "--threshold", "0.10")
    assert rc == 1
    assert "FAIL" in out


def test_max_key_passes_when_latency_holds(tmp_path):
    base = {"speedup": 2.4, "batch64": {"ttft_ms": 11.5}}
    rc, out = run_gate(
        tmp_path, base, CURRENT, "--key", "speedup",
        "--max-key", "batch64.ttft_ms", "--threshold", "0.10",
    )
    assert rc == 0
    assert "OK: batch64.ttft_ms" in out
    # the trajectory table marks both gates, in opposite directions
    assert "[gated -10%]" in out
    assert "[gated +10%]" in out


def test_max_key_fails_when_latency_grows_past_ceiling(tmp_path):
    # current ttft 12.0 vs baseline 10.0 = +20% > the 10% ceiling
    base = {"speedup": 2.4, "batch64": {"ttft_ms": 10.0}}
    rc, out = run_gate(
        tmp_path, base, CURRENT, "--key", "speedup",
        "--max-key", "batch64.ttft_ms", "--threshold", "0.10",
    )
    assert rc == 1
    assert "FAIL: batch64.ttft_ms grew" in out


def test_max_key_skips_on_old_baseline_without_the_metric(tmp_path):
    # baselines predating the batch64 section must not fail the gate
    base = {"speedup": 2.4}
    rc, out = run_gate(
        tmp_path, base, CURRENT, "--key", "speedup",
        "--max-key", "batch64.ttft_ms",
    )
    assert rc == 0
    assert "upward gate skipped" in out


def test_max_key_skips_on_placeholder_zero_baseline(tmp_path):
    # a provisional-style 0 would make ANY measured ttft a failure
    base = {"speedup": 2.4, "batch64": {"ttft_ms": 0}}
    rc, out = run_gate(
        tmp_path, base, CURRENT, "--key", "speedup",
        "--max-key", "batch64.ttft_ms",
    )
    assert rc == 0
    assert "upward gate skipped" in out


def test_max_key_missing_in_current_run_hard_fails(tmp_path):
    cur = {k: v for k, v in CURRENT.items() if k != "batch64"}
    rc, out = run_gate(
        tmp_path, {"speedup": 2.4, "batch64": {"ttft_ms": 10.0}}, cur,
        "--key", "speedup", "--max-key", "batch64.ttft_ms",
    )
    assert rc == 2
    assert "no 'batch64.ttft_ms' metric" in out


def test_broken_current_run_hard_fails(tmp_path):
    rc, out = run_gate(tmp_path, {"speedup": 2.4}, "nope{", "--key", "speedup")
    assert rc == 2
    assert "unusable" in out


def test_require_armed_fails_on_provisional_with_instruction(tmp_path):
    rc, out = run_gate(
        tmp_path, {"provisional": True, "speedup": 0}, CURRENT, "--key", "speedup",
        "--require-armed",
    )
    assert rc == 3
    assert "NOT armed" in out
    # the failure must be copy-paste actionable
    assert "commit_baseline=true" in out
    assert "git add BENCH_serve.json" in out


def test_require_armed_fails_on_malformed_baseline(tmp_path):
    rc, out = run_gate(tmp_path, "junk {", CURRENT, "--key", "speedup", "--require-armed")
    assert rc == 3
    assert "NOT armed" in out


def test_require_armed_handles_non_object_baseline(tmp_path):
    # valid JSON that is not a bench object must exit 3, not traceback
    rc, out = run_gate(tmp_path, "[1, 2, 3]", CURRENT, "--key", "speedup", "--require-armed")
    assert rc == 3
    assert "NOT armed" in out
    assert "Traceback" not in out


def test_require_armed_passes_on_measured_baseline(tmp_path):
    rc, out = run_gate(
        tmp_path, {"speedup": 2.4}, CURRENT, "--key", "speedup", "--require-armed"
    )
    assert rc == 0
    assert "gate armed" in out


def test_history_appends_and_prints_last_five(tmp_path):
    hist = tmp_path / "bench_history.jsonl"
    base = {"speedup": 2.4}
    for i in range(6):
        cur = dict(CURRENT, speedup=2.2 + i / 10.0)
        rc, out = run_gate(
            tmp_path, base, cur, "--key", "speedup",
            "--history", str(hist), "--sha", f"sha{i}{i}{i}{i}{i}{i}{i}{i}",
            "--run-date", f"2026-07-{20 + i}",
        )
        assert rc == 0
    lines = [ln for ln in hist.read_text().splitlines() if ln.strip()]
    assert len(lines) == 6
    assert json.loads(lines[-1])["speedup"] == 2.7
    assert json.loads(lines[0])["sha"].startswith("sha0")
    # the table shows only the last 5 runs: run 0 aged out, run 5 present
    assert "bench trajectory (last 5 of 6" in out
    assert "sha55555" in out
    assert "sha00000" not in out


def test_history_survives_a_corrupt_line(tmp_path):
    hist = tmp_path / "bench_history.jsonl"
    hist.write_text('{"sha": "aaaa", "date": "2026-07-01", "speedup": 2.0}\nnot json\n')
    rc, out = run_gate(
        tmp_path, {"speedup": 2.4}, CURRENT, "--key", "speedup",
        "--history", str(hist), "--sha", "bbbbbbbb", "--run-date", "2026-07-29",
    )
    assert rc == 0
    assert "dropping the line" in out
    lines = [ln for ln in hist.read_text().splitlines() if ln.strip()]
    assert len(lines) == 2  # corrupt line dropped, new entry appended
    assert "aaaa" in out and "bbbbbbbb" in out


def test_history_records_gate_failures_too(tmp_path):
    # a regressing run must still land in the trajectory before the gate
    # fails — the history is how the regression gets diagnosed
    hist = tmp_path / "bench_history.jsonl"
    rc, out = run_gate(
        tmp_path, {"speedup": 3.5}, CURRENT, "--key", "speedup",
        "--history", str(hist), "--sha", "cccccccc", "--run-date", "2026-07-29",
    )
    assert rc == 1
    assert "FAIL" in out
    assert hist.exists() and "cccccccc" in hist.read_text()
