"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
swept over shapes/values (hand-rolled hypothesis-style sweeps — the
hypothesis package is not available in this image)."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import dequant_matmul as dq
from compile.kernels import ewmix as ewmix_k
from compile.kernels import ref
from compile.kernels import wkv as wkv_k


def rng_for(seed):
    return np.random.default_rng(seed)


DS = [128, 256, 384]


@pytest.mark.parametrize("d", DS)
@pytest.mark.parametrize("seed", [0, 1])
def test_ewmix_matches_ref(d, seed):
    r = rng_for(seed)
    mu = r.uniform(0, 1, d).astype(np.float32)
    a = r.standard_normal(d).astype(np.float32)
    b = r.standard_normal(d).astype(np.float32)
    got = ewmix_k.ewmix(jnp.asarray(mu), jnp.asarray(a), jnp.asarray(b))
    want = ref.ewmix_ref(mu, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("d", DS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_wkv_step_matches_ref(d, seed):
    r = rng_for(100 + seed)
    k = r.standard_normal(d).astype(np.float32)
    v = r.standard_normal(d).astype(np.float32)
    w = r.uniform(0.2, 4.0, d).astype(np.float32)
    u = r.uniform(0, 1, d).astype(np.float32)
    aa = r.standard_normal(d).astype(np.float32)
    bb = r.uniform(0.5, 2.0, d).astype(np.float32)
    pp = r.uniform(-2, 2, d).astype(np.float32)
    got_wkv, got_aa, got_bb, got_pp = wkv_k.wkv_step(
        *map(jnp.asarray, (k, v, w, u, aa, bb, pp)))
    want_wkv, (want_aa, want_bb, want_pp) = ref.wkv_step_ref(k, v, w, u, aa, bb, pp)
    for got, want in [(got_wkv, want_wkv), (got_aa, want_aa),
                      (got_bb, want_bb), (got_pp, want_pp)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("t,d", [(4, 128), (16, 128), (8, 256)])
def test_wkv_sequence_matches_ref(t, d):
    r = rng_for(7 * t + d)
    ks = r.standard_normal((t, d)).astype(np.float32)
    vs = r.standard_normal((t, d)).astype(np.float32)
    w = r.uniform(0.2, 4.0, d).astype(np.float32)
    u = r.uniform(0, 1, d).astype(np.float32)
    aa = np.zeros(d, np.float32)
    bb = np.zeros(d, np.float32)
    pp = np.full(d, -1e30, np.float32)
    got, (gaa, gbb, gpp) = wkv_k.wkv_sequence(
        *map(jnp.asarray, (ks, vs, w, u, aa, bb, pp)))
    want, (waa, wbb, wpp) = ref.wkv_sequence_ref(ks, vs, w, u, aa, bb, pp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gaa), np.asarray(waa), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gpp), np.asarray(wpp), rtol=1e-5, atol=1e-5)


def test_wkv_sequence_equals_repeated_steps():
    """Sequence kernel == folding the step kernel (state contract)."""
    d, t = 128, 6
    r = rng_for(42)
    ks = r.standard_normal((t, d)).astype(np.float32)
    vs = r.standard_normal((t, d)).astype(np.float32)
    w = r.uniform(0.2, 4.0, d).astype(np.float32)
    u = r.uniform(0, 1, d).astype(np.float32)
    aa = np.zeros(d, np.float32)
    bb = np.zeros(d, np.float32)
    pp = np.full(d, -1e30, np.float32)
    seq_out, (saa, sbb, spp) = wkv_k.wkv_sequence(
        *map(jnp.asarray, (ks, vs, w, u, aa, bb, pp)))
    caa, cbb, cpp = map(jnp.asarray, (aa, bb, pp))
    for i in range(t):
        step_out, caa, cbb, cpp = wkv_k.wkv_step(
            jnp.asarray(ks[i]), jnp.asarray(vs[i]),
            jnp.asarray(w), jnp.asarray(u), caa, cbb, cpp)
        np.testing.assert_allclose(np.asarray(seq_out[i]), np.asarray(step_out),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(saa), np.asarray(caa), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("oc,ic,d,k", [(128, 128, 4, 8), (64, 128, 4, 6), (128, 256, 8, 7)])
def test_vq_dequant_matvec_matches_ref(oc, ic, d, k):
    r = rng_for(oc + ic + d)
    n_entries = 1 << k
    cb = r.standard_normal((n_entries, d)).astype(np.float32)
    idx = r.integers(0, n_entries, oc * ic // d).astype(np.int32)
    x = r.standard_normal(ic).astype(np.float32)
    got = dq.dequant_matvec(jnp.asarray(cb), jnp.asarray(idx), jnp.asarray(x),
                            oc=oc, ic=ic)
    want = ref.dequant_matvec_ref(cb, idx, x, oc, ic)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("oc,ic,group", [(64, 128, 32), (128, 128, 64)])
def test_sq_dequant_matvec_matches_ref(oc, ic, group):
    r = rng_for(oc * ic)
    codes = r.integers(0, 8, oc * ic).astype(np.int32)
    n_groups = oc * ic // group
    scales = r.uniform(0.001, 0.05, n_groups).astype(np.float32)
    mins = -scales * 3.5
    x = r.standard_normal(ic).astype(np.float32)
    got = dq.sq_dequant_matvec(jnp.asarray(codes), jnp.asarray(scales),
                               jnp.asarray(mins), jnp.asarray(x),
                               oc=oc, ic=ic, group=group)
    want = ref.sq_dequant_matvec_ref(codes, scales, mins, group, x, oc, ic)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_wkv_long_horizon_stability():
    """1000 steps of the recurrence stay finite (the stabilised form)."""
    d = 128
    r = rng_for(9)
    w = r.uniform(0.2, 4.0, d).astype(np.float32)
    u = r.uniform(0, 1, d).astype(np.float32)
    aa = jnp.zeros(d)
    bb = jnp.zeros(d)
    pp = jnp.full((d,), -1e30)
    for i in range(1000):
        k = jnp.asarray(r.standard_normal(d).astype(np.float32)) * 3.0
        v = jnp.asarray(r.standard_normal(d).astype(np.float32))
        out, aa, bb, pp = wkv_k.wkv_step(k, v, jnp.asarray(w), jnp.asarray(u), aa, bb, pp)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(aa)).all()
