#!/usr/bin/env python3
"""End-to-end smoke of the HTTP serving gateway (CI `http-smoke` job).

Stdlib only. The script:

  1. packs a tiny synthetic model into an RWKVQ2 checkpoint,
  2. starts `rwkvquant serve --http` on it and waits for /healthz,
  3. streams a completion (SSE over chunked transfer), checks the
     incremental token events agree with the final `done` event,
  4. repeats the request with `"stream": false` and requires identical
     tokens, then sends a 96-token prompt through the chunked-prefill
     path and requires `0 < ttft_ms < latency_ms` in the response,
  5. runs the in-process twin (`serve --prompt ... --print-tokens`) on
     the same store and **gates on token-identical output**,
  6. observability: the server runs with `--log-json` — the request id
     from the SSE `done` event must also appear in a structured
     `request done` log line on stderr and resolve on
     `GET /admin/trace/{id}`; a long request is observed mid-decode on
     `GET /admin/inflight`,
  7. scrapes /metrics, checks the serving counters plus the lane
     utilization and kernel attribution families, and lints the whole
     exposition with `check_metrics.lint_exposition`,
  8. sends SIGTERM and requires a graceful exit with code 0,
  9. then re-serves as a two-model fleet (`--model a=… --model b=…`):
     requests route by their `"model"` field (model `a` must reproduce
     the single-model tokens from step 3 on the same store),
     `GET /v1/models` lists both, `/metrics` carries `model="…"` labels,
     a hot swap (`POST /admin/models/a`) lands mid-flight without
     losing the in-flight request, post-swap output serves the new
     store's bytes, and an unknown model is a 404 with
     `code: model_not_found`.

Usage: python3 python/http_smoke.py --bin target/release/rwkvquant
"""

import argparse
import http.client
import json
import re
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

import check_metrics

GEN_LEN = 8
PROMPT = [3, 1, 2]

# request id of the most recent generate() response (SSE done event /
# JSON document), for the structured-log and /admin/trace assertions
last_request_id: int | None = None


def log(msg: str) -> None:
    print(f"[http-smoke] {msg}", flush=True)


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def wait_healthy(port: int, proc: subprocess.Popen, timeout: float = 120.0) -> None:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise SystemExit(f"server exited early with code {proc.returncode}")
        try:
            conn = http.client.HTTPConnection("127.0.0.1", port, timeout=2)
            conn.request("GET", "/healthz")
            resp = conn.getresponse()
            body = resp.read()
            conn.close()
            if resp.status == 200 and body.strip() == b"ok":
                return
        except OSError:
            pass
        time.sleep(0.2)
    raise SystemExit("server never became healthy")


def generate(port: int, stream: bool) -> list[int]:
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
    payload = json.dumps({"prompt": PROMPT, "gen_len": GEN_LEN, "stream": stream})
    conn.request(
        "POST", "/v1/generate", body=payload, headers={"Content-Type": "application/json"}
    )
    resp = conn.getresponse()
    body = resp.read().decode()
    conn.close()
    global last_request_id
    if resp.status != 200:
        raise SystemExit(f"/v1/generate (stream={stream}) answered {resp.status}: {body}")
    if not stream:
        doc = json.loads(body)
        last_request_id = doc.get("id")
        return doc["tokens"]
    if "text/event-stream" not in (resp.getheader("Content-Type") or ""):
        raise SystemExit(f"streamed response has wrong content type: {resp.getheader('Content-Type')}")
    events = [json.loads(line[len("data: "):]) for line in body.splitlines() if line.startswith("data: ")]
    incremental = [e["token"] for e in events if "token" in e]
    done = [e for e in events if e.get("done")]
    if len(done) != 1:
        raise SystemExit(f"expected exactly one done event, got {len(done)}: {body!r}")
    if incremental != done[0]["tokens"]:
        raise SystemExit(
            f"incremental tokens {incremental} disagree with done event {done[0]['tokens']}"
        )
    last_request_id = done[0].get("id")
    return incremental


def scrape_metrics(port: int) -> str:
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=10)
    conn.request("GET", "/metrics")
    resp = conn.getresponse()
    text = resp.read().decode()
    conn.close()
    if resp.status != 200:
        raise SystemExit(f"/metrics answered {resp.status}")
    return text


def metric_value(text: str, name: str) -> float:
    m = re.search(rf"^{re.escape(name)} (\S+)$", text, re.MULTILINE)
    if not m:
        raise SystemExit(f"metric {name} missing from /metrics:\n{text}")
    return float(m.group(1))


def labeled_metric(text: str, name: str, model: str) -> float:
    series = f'{name}{{model="{model}"}}'
    m = re.search(rf"^{re.escape(series)} (\S+)$", text, re.MULTILINE)
    if not m:
        raise SystemExit(f"metric {series} missing from /metrics:\n{text}")
    return float(m.group(1))


def api(port: int, method: str, path: str, payload: dict | None = None, timeout: float = 60):
    """One JSON request; returns (status, parsed-or-raw body)."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    body = json.dumps(payload) if payload is not None else None
    conn.request(method, path, body=body, headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    text = resp.read().decode()
    conn.close()
    try:
        return resp.status, json.loads(text)
    except ValueError:
        return resp.status, text


def lint_metrics(text: str, where: str) -> None:
    problems = check_metrics.lint_exposition(text)
    if problems:
        listing = "\n".join(problems)
        raise SystemExit(f"/metrics ({where}) failed the Prometheus lint:\n{listing}\n{text}")


def wait_log_line(logpath: Path, needle: str, timeout: float = 15.0) -> str:
    """First stderr log line containing `needle` (the writes are
    unbuffered line appends, so polling the file is race-free)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        for line in logpath.read_text().splitlines():
            if needle in line:
                return line
        time.sleep(0.05)
    raise SystemExit(f"log line containing {needle!r} never appeared:\n{logpath.read_text()}")


def observability_checks(port: int, logpath: Path) -> None:
    """Step 6: request ids thread HTTP → logs → trace; inflight is live."""
    if not isinstance(last_request_id, int):
        raise SystemExit(f"done event carried no integer request id: {last_request_id!r}")
    rid = last_request_id

    # the id from the SSE done event appears in a structured JSON log
    line = wait_log_line(logpath, f'"id":{rid}')
    entry = json.loads(line)  # must be one valid JSON document per line
    if entry.get("msg") != "request done" or entry.get("level") != "info":
        raise SystemExit(f"unexpected log entry for request {rid}: {line}")
    log(f"request id {rid} found in the JSON log stream OK")

    # ... and resolves to spans on the trace endpoint
    status, doc = api(port, "GET", f"/admin/trace/{rid}")
    if status != 200 or doc.get("id") != rid or not doc.get("spans"):
        raise SystemExit(f"/admin/trace/{rid} answered {status}: {doc}")
    stages = {s["stage"] for s in doc["spans"]}
    if not {"queue", "decode"} <= stages:
        raise SystemExit(f"trace for {rid} misses core stages: {sorted(stages)}")
    log(f"/admin/trace/{rid} serves {len(doc['spans'])} spans ({sorted(stages)}) OK")

    # a long request is visible on /admin/inflight while it decodes
    result: dict = {}

    def long_request() -> None:
        status, doc = api(
            port, "POST", "/v1/generate",
            {"prompt": PROMPT, "gen_len": 64, "stream": False},
        )
        result["status"], result["doc"] = status, doc

    t = threading.Thread(target=long_request)
    t.start()
    seq = None
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline and seq is None:
        status, doc = api(port, "GET", "/admin/inflight", timeout=10)
        if status != 200:
            raise SystemExit(f"/admin/inflight answered {status}: {doc}")
        if doc["sequences"]:
            seq = doc["sequences"][0]
    t.join(timeout=120)
    if seq is None:
        raise SystemExit("the long request never showed up on /admin/inflight")
    if result.get("status") != 200 or len(result["doc"]["tokens"]) != 64:
        raise SystemExit(f"long request failed under inflight polling: {result}")
    if seq["gen_len"] != 64 or seq["stage"] not in ("prefill", "decode", "parked"):
        raise SystemExit(f"malformed inflight entry: {seq}")
    log(f"/admin/inflight saw the live sequence (stage {seq['stage']}) OK")


def fleet_generate(port: int, model: str, gen_len: int = GEN_LEN) -> list[int]:
    status, doc = api(
        port, "POST", "/v1/generate",
        {"model": model, "prompt": PROMPT, "gen_len": gen_len, "stream": False},
    )
    if status != 200:
        raise SystemExit(f"/v1/generate (model={model}) answered {status}: {doc}")
    return doc["tokens"]


def fleet_smoke(binary: str, store_a: Path, store_b: Path, single_tokens: list[int]) -> None:
    """Step 8: two-model fleet serving with a hot swap under load."""
    port = free_port()
    log(f"starting fleet gateway on 127.0.0.1:{port} (models a, b) …")
    server = subprocess.Popen(
        [
            binary, "serve",
            "--model", f"a={store_a}", "--model", f"b={store_b}",
            "--http", f"127.0.0.1:{port}",
            "--max-queue", "8", "--batch", "4",
        ]
    )
    try:
        wait_healthy(port, server)

        status, doc = api(port, "GET", "/v1/models")
        ids = sorted(m["id"] for m in doc["data"])
        if status != 200 or ids != ["a", "b"]:
            raise SystemExit(f"/v1/models answered {status} with ids {ids}")
        log("/v1/models lists both models OK")

        tokens_a = fleet_generate(port, "a")
        tokens_b = fleet_generate(port, "b")
        if tokens_a != single_tokens:
            raise SystemExit(
                f"model 'a' (same store as single-model phase) diverged: "
                f"{tokens_a} != {single_tokens}"
            )
        log("fleet routing is token-identical to the single-model serve OK")

        status, doc = api(
            port, "POST", "/v1/generate",
            {"model": "nope", "prompt": PROMPT, "gen_len": 2, "stream": False},
        )
        if status != 404 or doc.get("error", {}).get("code") != "model_not_found":
            raise SystemExit(f"unknown model answered {status}: {doc}")
        log("unknown model 404s with model_not_found OK")

        # hot swap under load: keep a long request in flight on 'a',
        # then point 'a' at store_b mid-decode — the in-flight request
        # must still complete in full
        long_gen = 64
        inflight: dict = {}

        def long_request():
            inflight["tokens"] = fleet_generate(port, "a", gen_len=long_gen)

        t = threading.Thread(target=long_request)
        t.start()
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            text = scrape_metrics(port)
            if labeled_metric(text, "rwkvquant_served_tokens_total", "a") > len(single_tokens):
                break
            time.sleep(0.005)
        status, doc = api(port, "POST", "/admin/models/a", {"path": str(store_b)})
        if status != 200:
            raise SystemExit(f"hot swap answered {status}: {doc}")
        t.join(timeout=120)
        if t.is_alive() or len(inflight.get("tokens", [])) != long_gen:
            raise SystemExit(f"in-flight request lost across the swap: {inflight}")
        log(f"hot swap landed (version {doc['version']}), in-flight request survived OK")

        # post-swap, 'a' serves store_b's bytes: identical to model 'b'
        if fleet_generate(port, "a") != tokens_b:
            raise SystemExit("post-swap model 'a' does not serve the new store's output")
        log("post-swap output matches the new store OK")

        text = scrape_metrics(port)
        lint_metrics(text, "fleet")
        for model in ("a", "b"):
            labeled_metric(text, "rwkvquant_generate_requests_total", model)
            labeled_metric(text, "rwkvquant_served_tokens_total", model)
            labeled_metric(text, "rwkvquant_queue_depth", model)
            labeled_metric(text, "rwkvquant_mapped_stores", model)
        metric_value(text, "rwkvquant_http_requests_total")  # gateway-level, unlabeled
        log("per-model /metrics labels OK (fleet exposition lints clean)")

        log("sending SIGTERM for a graceful fleet drain …")
        server.send_signal(signal.SIGTERM)
        code = server.wait(timeout=60)
        if code != 0:
            raise SystemExit(f"fleet server exited {code} after SIGTERM (want 0)")
        log("graceful fleet drain OK (exit 0)")
    finally:
        if server.poll() is None:
            server.kill()
            server.wait(timeout=10)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", required=True, help="path to the rwkvquant binary")
    args = ap.parse_args()
    binary = str(Path(args.bin).resolve())

    tmp = Path(tempfile.mkdtemp(prefix="rwkvq_http_smoke_"))
    store = tmp / "smoke.rwkvq2"
    log("packing tiny model …")
    subprocess.run(
        [binary, "pack", "--size", "0.1B", "--seed", "7", "--out", str(store)],
        check=True,
    )

    port = free_port()
    logpath = tmp / "gateway.stderr.jsonl"
    log(f"starting gateway on 127.0.0.1:{port} (--log-json → {logpath.name}) …")
    with open(logpath, "w", encoding="utf-8") as logfile:
        server = subprocess.Popen(
            [
                binary, "serve", "--store", str(store),
                "--http", f"127.0.0.1:{port}",
                "--max-queue", "8", "--batch", "4", "--tick-threads", "2",
                "--prefill-chunk", "16", "--log-json",
            ],
            stderr=logfile,
        )
    try:
        wait_healthy(port, server)
        log("healthz OK")

        streamed = generate(port, stream=True)
        log(f"streamed tokens: {streamed}")
        if len(streamed) != GEN_LEN:
            raise SystemExit(f"expected {GEN_LEN} tokens, got {len(streamed)}")

        collected = generate(port, stream=False)
        if collected != streamed:
            raise SystemExit(f"stream={streamed} != collected={collected}")
        log("stream / non-stream agreement OK")

        # long prompt: chunked prefill (16 tokens/tick here) must report
        # a first-token time strictly inside the total request latency
        long_prompt = [(i * 7 + 1) % 512 for i in range(96)]
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        payload = json.dumps({"prompt": long_prompt, "gen_len": GEN_LEN, "stream": False})
        conn.request(
            "POST", "/v1/generate", body=payload,
            headers={"Content-Type": "application/json"},
        )
        resp = conn.getresponse()
        body = resp.read().decode()
        conn.close()
        if resp.status != 200:
            raise SystemExit(f"long-prompt request answered {resp.status}: {body}")
        doc = json.loads(body)
        ttft, latency = doc["ttft_ms"], doc["latency_ms"]
        if not 0.0 < ttft < latency:
            raise SystemExit(f"TTFT {ttft}ms must sit strictly inside latency {latency}ms")
        if len(doc["tokens"]) != GEN_LEN:
            raise SystemExit(f"long-prompt generation returned {len(doc['tokens'])} tokens")
        log(f"long-prompt TTFT OK ({ttft:.3f} ms of {latency:.3f} ms)")

        # in-process twin on the same store must produce identical tokens
        twin = subprocess.run(
            [
                binary, "serve", "--store", str(store),
                "--requests", "1", "--gen-len", str(GEN_LEN),
                "--prompt", ",".join(str(t) for t in PROMPT),
                "--print-tokens",
            ],
            check=True, capture_output=True, text=True,
        )
        m = re.search(r"^tokens\[0\]: (.+)$", twin.stdout, re.MULTILINE)
        if not m:
            raise SystemExit(f"twin output has no token line:\n{twin.stdout}")
        twin_tokens = [int(t) for t in m.group(1).split(",")]
        if twin_tokens != streamed:
            raise SystemExit(
                f"TOKEN MISMATCH: http={streamed} vs in-process={twin_tokens}"
            )
        log("token-identical to the in-process twin OK")

        observability_checks(port, logpath)

        text = scrape_metrics(port)
        lint_metrics(text, "single-model")
        served = metric_value(text, "rwkvquant_served_tokens_total")
        if served < 2 * GEN_LEN:
            raise SystemExit(f"served_tokens_total {served} < {2 * GEN_LEN}")
        metric_value(text, "rwkvquant_requests_shed_total")  # present even at 0
        metric_value(text, "rwkvquant_served_tokens_per_sec")
        metric_value(text, "rwkvquant_queue_depth")
        prefill = metric_value(text, "rwkvquant_prefill_tokens_total")
        if prefill < len(long_prompt):
            raise SystemExit(f"prefill_tokens_total {prefill} < {len(long_prompt)}")
        if metric_value(text, "rwkvquant_ttft_seconds_count") < 3:
            raise SystemExit("ttft summary saw fewer requests than we sent")
        # observability families: lane utilization (2 tick threads →
        # lead lane 0 + worker lane 1), kernel attribution over the
        # packed store, and the process gauges
        for lane in (0, 1):
            if not re.search(
                rf'^rwkvquant_lane_busy_seconds_total{{lane="{lane}"}} ', text, re.MULTILINE
            ):
                raise SystemExit(f"lane {lane} busy series missing from /metrics:\n{text}")
        kernel_calls = 0.0
        for m in re.finditer(
            r'^rwkvquant_kernel_matvec_calls_total\{op="(?:sq|vq)",kernel="\w+"\} (\S+)$',
            text, re.MULTILINE,
        ):
            kernel_calls += float(m.group(1))
        if kernel_calls <= 0:
            raise SystemExit(f"no Sq/Vq matvecs attributed on /metrics:\n{text}")
        metric_value(text, "rwkvquant_mapped_stores")
        metric_value(text, "rwkvquant_inflight_sequences")
        if sys.platform.startswith("linux"):
            if metric_value(text, "rwkvquant_process_resident_bytes") <= 0:
                raise SystemExit("resident-set gauge is zero on Linux")
        log("metrics OK (incl. lane/kernel/process observability families)")

        log("sending SIGTERM for a graceful drain …")
        server.send_signal(signal.SIGTERM)
        code = server.wait(timeout=60)
        if code != 0:
            raise SystemExit(f"server exited {code} after SIGTERM (want 0)")
        log("graceful drain OK (exit 0)")
    finally:
        if server.poll() is None:
            server.kill()
            server.wait(timeout=10)

    store_b = tmp / "smoke_b.rwkvq2"
    log("packing second tiny model for the fleet phase …")
    subprocess.run(
        [binary, "pack", "--size", "0.1B", "--seed", "11", "--out", str(store_b)],
        check=True,
    )
    fleet_smoke(binary, store, store_b, streamed)

    log("PASS")


if __name__ == "__main__":
    sys.exit(main())
