#!/usr/bin/env python3
"""Prometheus text-exposition (format 0.0.4) linter.

Python mirror of `rust/src/server/metrics.rs::lint_exposition`, so the
CI smoke can hold the *live* `/metrics` endpoint to the same rules the
Rust unit tests enforce on the render paths:

  - every sample's family carries exactly one `# HELP` and one `# TYPE`
    line (with a known type) before its samples,
  - label sets parse: `name{k="v",k2="v2"} value` with balanced quotes
    and only `\\\\`, `\\"`, `\\n` escapes inside values,
  - sample values parse as floats,
  - no series (name + label set) appears twice,
  - `_count` / `_sum` / `_bucket` children resolve to their parent
    summary/histogram family.

Importable (`lint_exposition(text) -> list[str]`, empty means clean) and
runnable: `python3 check_metrics.py dump.prom` or pipe on stdin.
"""

import re
import sys

KNOWN_TYPES = {"counter", "gauge", "summary", "histogram", "untyped"}
_LABEL_NAME = re.compile(r"^[A-Za-z0-9_]+$")


def _parse_label_body(body: str) -> str | None:
    """Parse `k="v",k2="v2"`; return a problem string or None."""
    i, n = 0, len(body)
    while True:
        eq = body.find("=", i)
        name = body[i:eq] if eq != -1 else ""
        if not name or not _LABEL_NAME.match(name):
            return f"bad label name {name!r}"
        i = eq + 1
        if i >= n or body[i] != '"':
            return f"label {name} value not quoted"
        i += 1
        closed = False
        while i < n:
            c = body[i]
            if c == "\\":
                if i + 1 >= n or body[i + 1] not in ('\\', '"', "n"):
                    esc = body[i + 1] if i + 1 < n else None
                    return f"bad escape {esc!r} in label {name}"
                i += 2
                continue
            i += 1
            if c == '"':
                closed = True
                break
        if not closed:
            return f"unterminated value for label {name}"
        if i == n:
            return None
        if body[i] != ",":
            return f"unexpected {body[i]!r} after label {name}"
        i += 1


def lint_exposition(text: str) -> list[str]:
    """Return the list of problems in a text exposition (empty = clean)."""
    problems: list[str] = []
    help_count: dict[str, int] = {}
    type_count: dict[str, int] = {}
    seen_series: dict[str, int] = {}
    for ln, line in enumerate(text.splitlines(), start=1):
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP "):].split()
            if not parts:
                problems.append(f"line {ln}: HELP without a family name")
                continue
            help_count[parts[0]] = help_count.get(parts[0], 0) + 1
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split()
            if len(parts) < 2:
                problems.append(f"line {ln}: malformed TYPE line")
                continue
            name, kind = parts[0], parts[1]
            if kind not in KNOWN_TYPES:
                problems.append(f"line {ln}: unknown type {kind!r} for {name}")
            type_count[name] = type_count.get(name, 0) + 1
            continue
        if line.startswith("#"):
            continue  # free-form comment
        # sample line: name{labels}? value
        series, sep, value = line.rpartition(" ")
        if not sep:
            problems.append(f"line {ln}: sample without a value")
            continue
        try:
            float(value)
        except ValueError:
            problems.append(f"line {ln}: unparsable sample value {value!r}")
        name = series
        if "{" in series:
            name, _, labels = series.partition("{")
            if labels.endswith("}"):
                err = _parse_label_body(labels[:-1])
                if err is not None:
                    problems.append(f"line {ln}: bad label set: {err}")
            else:
                problems.append(f"line {ln}: unclosed label set")
        family = name
        for suf in ("_count", "_sum", "_bucket"):
            if name.endswith(suf) and name[: -len(suf)] in type_count:
                family = name[: -len(suf)]
                break
        if family not in type_count:
            problems.append(f"line {ln}: sample {name} has no preceding # TYPE")
        if family not in help_count:
            problems.append(f"line {ln}: sample {name} has no preceding # HELP")
        if series in seen_series:
            problems.append(
                f"line {ln}: duplicate series {series} (first at line {seen_series[series]})"
            )
        else:
            seen_series[series] = ln
    for name, n in help_count.items():
        if n > 1:
            problems.append(f"family {name}: {n} HELP lines")
    for name, n in type_count.items():
        if n > 1:
            problems.append(f"family {name}: {n} TYPE lines")
    return sorted(problems)


def _selftest() -> None:
    clean = (
        "# HELP a_total Things.\n# TYPE a_total counter\n"
        'a_total{model="x",lane="0"} 3\na_total{model="y\\"z"} 1\n'
        "# HELP lat Latency.\n# TYPE lat summary\n"
        'lat{quantile="0.5"} 0.1\nlat_count 2\nlat_sum 0.4\n'
    )
    assert lint_exposition(clean) == [], lint_exposition(clean)
    bad = 'orphan_total 1\n# TYPE b gauge\nb{k="v} 2\nb 1\nb 1\nb nope\n'
    found = "\n".join(lint_exposition(bad))
    for needle in ("no preceding # TYPE", "unterminated value", "duplicate series", "unparsable"):
        assert needle in found, f"{needle!r} not caught:\n{found}"


def main(argv: list[str]) -> int:
    _selftest()
    if len(argv) > 1:
        text = open(argv[1], encoding="utf-8").read()
    else:
        text = sys.stdin.read()
    problems = lint_exposition(text)
    for p in problems:
        print(p)
    if problems:
        print(f"FAIL: {len(problems)} problem(s)")
        return 1
    samples = sum(
        1 for l in text.splitlines() if l and not l.startswith("#")
    )
    print(f"OK: {samples} samples lint clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
