#!/usr/bin/env python3
"""End-to-end smoke of the OpenAI-compatible text API (CI `openai-api-smoke` job).

Stdlib only. The script:

  1. packs a tiny synthetic model into an RWKVQ2 checkpoint,
  2. starts `rwkvquant serve --http` on it and waits for /healthz,
  3. sends a greedy (temperature=0) `/v1/completions` request and gates
     it **token-identical** against the raw `/v1/generate` path on the
     same prompt (decoded through the synthetic `w{i} ` vocab),
  4. sends the same *seeded sampling* request twice and requires the
     parsed `choices` + `usage` to be byte-identical (the JSON emitter
     renders keys sorted, so equal objects mean equal bytes),
  5. streams a `/v1/chat/completions` request and checks the OpenAI
     delta protocol: opening role chunk, per-token content deltas, a
     final chunk carrying `finish_reason`, and the `data: [DONE]`
     terminator — and that the accumulated deltas equal the
     non-streaming `message.content` for the same greedy request,
  6. opens a raw socket, starts a long streaming completion, drops the
     connection mid-generation, and asserts /metrics records the
     cancellation (`rwkvquant_requests_cancelled_total`) and the queue
     drains back to zero,
  7. sends SIGTERM and requires a graceful exit with code 0.

Usage: python3 python/openai_smoke.py --bin target/release/rwkvquant
"""

import argparse
import http.client
import json
import re
import signal
import socket
import subprocess
import sys
import tempfile
import time
from pathlib import Path

MAX_TOKENS = 8
PROMPT_TEXT = "w3 w1 w2 "
PROMPT_IDS = [3, 1, 2]


def log(msg: str) -> None:
    print(f"[openai-smoke] {msg}", flush=True)


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def wait_healthy(port: int, proc: subprocess.Popen, timeout: float = 120.0) -> None:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise SystemExit(f"server exited early with code {proc.returncode}")
        try:
            conn = http.client.HTTPConnection("127.0.0.1", port, timeout=2)
            conn.request("GET", "/healthz")
            resp = conn.getresponse()
            body = resp.read()
            conn.close()
            if resp.status == 200 and body.strip() == b"ok":
                return
        except OSError:
            pass
        time.sleep(0.2)
    raise SystemExit("server never became healthy")


def post(port: int, path: str, payload: dict, timeout: float = 60.0):
    """POST JSON, return (status, headers, decoded body)."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    conn.request(
        "POST", path, body=json.dumps(payload),
        headers={"Content-Type": "application/json"},
    )
    resp = conn.getresponse()
    body = resp.read().decode()
    headers = {k.lower(): v for k, v in resp.getheaders()}
    conn.close()
    return resp.status, headers, body


def post_json(port: int, path: str, payload: dict) -> dict:
    status, _, body = post(port, path, payload)
    if status != 200:
        raise SystemExit(f"{path} answered {status}: {body}")
    return json.loads(body)


def sse_payloads(body: str) -> list[str]:
    return [line[len("data: "):] for line in body.splitlines() if line.startswith("data: ")]


def decode_ids(tokens: list[int]) -> str:
    """The synthetic vocab the server builds for a packed 0.1B store:
    id 0 is `<unk>`, id i is the literal text `w{i} `."""
    return "".join("<unk>" if t == 0 else f"w{t} " for t in tokens)


def scrape_metrics(port: int) -> str:
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=10)
    conn.request("GET", "/metrics")
    resp = conn.getresponse()
    text = resp.read().decode()
    conn.close()
    if resp.status != 200:
        raise SystemExit(f"/metrics answered {resp.status}")
    return text


def metric_value(text: str, name: str) -> float:
    m = re.search(rf"^{re.escape(name)} (\S+)$", text, re.MULTILINE)
    if not m:
        raise SystemExit(f"metric {name} missing from /metrics:\n{text}")
    return float(m.group(1))


def check_greedy_twin(port: int) -> list[int]:
    """temperature=0 through /v1/completions must be token-identical to
    the raw /v1/generate greedy path on the same store."""
    raw = post_json(port, "/v1/generate", {"prompt": PROMPT_IDS, "gen_len": MAX_TOKENS})
    expected_text = decode_ids(raw["tokens"])

    doc = post_json(
        port, "/v1/completions",
        {"prompt": PROMPT_TEXT, "max_tokens": MAX_TOKENS, "temperature": 0},
    )
    if doc.get("object") != "text_completion":
        raise SystemExit(f"wrong object: {doc.get('object')}")
    choice = doc["choices"][0]
    if choice["finish_reason"] != "length":
        raise SystemExit(f"greedy finish_reason {choice['finish_reason']!r}, want 'length'")
    if choice["text"] != expected_text:
        raise SystemExit(
            f"GREEDY TWIN MISMATCH:\n  /v1/completions: {choice['text']!r}\n"
            f"  /v1/generate:    {expected_text!r}"
        )
    usage = doc["usage"]
    if usage != {
        "completion_tokens": MAX_TOKENS,
        "prompt_tokens": len(PROMPT_IDS),
        "total_tokens": MAX_TOKENS + len(PROMPT_IDS),
    }:
        raise SystemExit(f"unexpected usage block: {usage}")
    return raw["tokens"]


def check_seeded_determinism(port: int) -> None:
    """The same seeded sampling request twice must yield byte-identical
    choices + usage (ids/created legitimately differ between requests)."""
    payload = {
        "prompt": PROMPT_TEXT, "max_tokens": MAX_TOKENS,
        "temperature": 0.9, "top_k": 8, "top_p": 0.95, "seed": 7,
    }
    a = post_json(port, "/v1/completions", payload)
    b = post_json(port, "/v1/completions", payload)
    for field in ("choices", "usage"):
        ra = json.dumps(a[field], sort_keys=True)
        rb = json.dumps(b[field], sort_keys=True)
        if ra != rb:
            raise SystemExit(f"NONDETERMINISTIC seeded sampling ({field}):\n  {ra}\n  {rb}")
    text = a["choices"][0]["text"]
    if not text:
        raise SystemExit("seeded sampling produced empty text")
    log(f"seeded text: {text!r}")


def check_chat_stream(port: int) -> None:
    """Streaming chat must speak the OpenAI delta protocol and agree
    with the non-streaming flavour of the same greedy request."""
    payload = {
        "messages": [{"role": "user", "content": PROMPT_TEXT}],
        "max_tokens": 4, "temperature": 0,
    }
    status, headers, body = post(port, "/v1/chat/completions", {**payload, "stream": True})
    if status != 200:
        raise SystemExit(f"streaming chat answered {status}: {body}")
    if "text/event-stream" not in headers.get("content-type", ""):
        raise SystemExit(f"streamed chat has wrong content type: {headers.get('content-type')}")
    payloads = sse_payloads(body)
    if not payloads or payloads[-1] != "[DONE]":
        raise SystemExit(f"stream did not end with data: [DONE]: {payloads[-3:]}")
    chunks = [json.loads(p) for p in payloads[:-1]]
    if len(chunks) < 3:
        raise SystemExit(f"expected role + content + finish chunks, got {len(chunks)}")
    content = ""
    finish = None
    for i, chunk in enumerate(chunks):
        if chunk.get("object") != "chat.completion.chunk":
            raise SystemExit(f"chunk {i} has object {chunk.get('object')!r}")
        delta = chunk["choices"][0]["delta"]
        if i == 0 and delta.get("role") != "assistant":
            raise SystemExit(f"first chunk must carry the assistant role: {delta}")
        content += delta.get("content", "")
        finish = chunk["choices"][0]["finish_reason"] or finish
    if finish != "length":
        raise SystemExit(f"streamed chat finish_reason {finish!r}, want 'length'")
    if not content:
        raise SystemExit("streamed chat produced no content deltas")

    doc = post_json(port, "/v1/chat/completions", payload)
    if doc.get("object") != "chat.completion":
        raise SystemExit(f"wrong chat object: {doc.get('object')}")
    message = doc["choices"][0]["message"]
    if message["role"] != "assistant" or message["content"] != content:
        raise SystemExit(
            f"stream/non-stream chat disagreement: {content!r} vs {message['content']!r}"
        )
    log(f"chat content: {content!r}")


def check_stop_sequence(port: int, greedy_tokens: list[int]) -> None:
    """A stop string equal to the first greedy token must end generation
    after exactly one token with finish_reason 'stop' (matched text is
    included in the output)."""
    stop = decode_ids(greedy_tokens[:1])
    doc = post_json(
        port, "/v1/completions",
        {"prompt": PROMPT_TEXT, "max_tokens": MAX_TOKENS, "temperature": 0, "stop": stop},
    )
    choice = doc["choices"][0]
    if choice["finish_reason"] != "stop":
        raise SystemExit(f"stop finish_reason {choice['finish_reason']!r}, want 'stop'")
    if choice["text"] != stop:
        raise SystemExit(f"stop text {choice['text']!r}, want {stop!r}")
    if doc["usage"]["completion_tokens"] != 1:
        raise SystemExit(f"stop should halt after 1 token: {doc['usage']}")


def check_cancellation(port: int) -> None:
    """Drop the socket mid-stream; the serve loop must notice the dead
    client on its next chunk write, retire the sequence, free the slab,
    and count the cancellation in /metrics."""
    payload = json.dumps(
        {"prompt": PROMPT_TEXT, "max_tokens": 400, "temperature": 0, "stream": True}
    ).encode()
    request = (
        b"POST /v1/completions HTTP/1.1\r\n"
        b"Host: 127.0.0.1\r\n"
        b"Content-Type: application/json\r\n"
        b"Content-Length: " + str(len(payload)).encode() + b"\r\n"
        b"\r\n" + payload
    )
    sock = socket.create_connection(("127.0.0.1", port), timeout=30)
    sock.sendall(request)
    seen = b""
    deadline = time.monotonic() + 30
    while b'"content"' not in seen and b'"text"' not in seen:
        if time.monotonic() > deadline:
            raise SystemExit(f"no streamed delta before disconnect: {seen!r}")
        chunk = sock.recv(4096)
        if not chunk:
            raise SystemExit("stream closed before the first delta")
        seen += chunk
    sock.close()
    log("socket dropped mid-generation, waiting for the cancel sweep …")

    deadline = time.monotonic() + 30
    while True:
        text = scrape_metrics(port)
        if metric_value(text, "rwkvquant_requests_cancelled_total") >= 1.0:
            break
        if time.monotonic() > deadline:
            raise SystemExit("cancellation never reached rwkvquant_requests_cancelled_total")
        time.sleep(0.2)

    # the orphaned sequence must have released its state-pool slab: the
    # queue drains to zero and a follow-up request is admitted normally
    deadline = time.monotonic() + 30
    while metric_value(scrape_metrics(port), "rwkvquant_queue_depth") != 0.0:
        if time.monotonic() > deadline:
            raise SystemExit("queue depth never returned to zero after the cancel")
        time.sleep(0.2)
    doc = post_json(
        port, "/v1/completions", {"prompt": "w5 ", "max_tokens": 2, "temperature": 0}
    )
    if doc["choices"][0]["finish_reason"] != "length":
        raise SystemExit("follow-up request after the cancel did not complete")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", required=True, help="path to the rwkvquant binary")
    args = ap.parse_args()
    binary = str(Path(args.bin).resolve())

    tmp = Path(tempfile.mkdtemp(prefix="rwkvq_openai_smoke_"))
    store = tmp / "smoke.rwkvq2"
    log("packing tiny model …")
    subprocess.run(
        [binary, "pack", "--size", "0.1B", "--seed", "7", "--out", str(store)],
        check=True,
    )

    port = free_port()
    log(f"starting gateway on 127.0.0.1:{port} …")
    server = subprocess.Popen(
        [
            binary, "serve", "--store", str(store),
            "--http", f"127.0.0.1:{port}",
            "--max-queue", "8", "--batch", "4", "--tick-threads", "2",
            "--prefill-chunk", "16",
        ]
    )
    try:
        wait_healthy(port, server)
        log("healthz OK")

        greedy_tokens = check_greedy_twin(port)
        log(f"greedy /v1/completions token-identical to /v1/generate OK ({greedy_tokens})")

        check_seeded_determinism(port)
        log("same-seed sampling reproducible OK")

        check_chat_stream(port)
        log("chat SSE delta protocol + [DONE] OK")

        check_stop_sequence(port, greedy_tokens)
        log("stop sequence honoured (finish_reason=stop) OK")

        check_cancellation(port)
        log("disconnect cancellation OK")

        text = scrape_metrics(port)
        if metric_value(text, "rwkvquant_text_requests_total") < 7:
            raise SystemExit("text_requests_total saw fewer requests than we sent")
        if metric_value(text, "rwkvquant_requests_cancelled_total") != 1.0:
            raise SystemExit("expected exactly one cancelled request")
        if metric_value(text, "rwkvquant_sampled_tokens_total") < 2 * MAX_TOKENS:
            raise SystemExit("sampled_tokens_total did not count the seeded runs")
        log("metrics OK")

        log("sending SIGTERM for a graceful drain …")
        server.send_signal(signal.SIGTERM)
        code = server.wait(timeout=60)
        if code != 0:
            raise SystemExit(f"server exited {code} after SIGTERM (want 0)")
        log("graceful drain OK (exit 0)")
    finally:
        if server.poll() is None:
            server.kill()
            server.wait(timeout=10)

    log("PASS")


if __name__ == "__main__":
    sys.exit(main())
