"""AOT lowering: JAX (+Pallas) → HLO **text** → artifacts/ for the Rust
PJRT runtime.

HLO text, not ``.serialize()``: the image's xla_extension 0.5.1 rejects
jax ≥ 0.5 serialized protos (64-bit instruction ids); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
* ``rwkv_step.hlo.txt``    — one decode token of the trained tiny RWKV;
  weights are *runtime inputs* (uploaded once as PJRT buffers by Rust),
  so the same graph serves fp and dequantized-quantized weights.
* ``rwkv_step.inputs.txt`` — the flattened input ordering contract.
* ``vq_matvec.hlo.txt``    — the fused codebook-gather matvec kernel
  (L1, Table 4's quantized hot path), lowered standalone.
* ``smoke.hlo.txt``        — tiny matmul graph for runtime smoke tests.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import dequant_matmul as dq


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flat_input_names(args_tree):
    """Names of the flattened inputs, in lowering order."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(args_tree)[0]
    names = []
    for path, _leaf in leaves_with_paths:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("/".join(parts) if parts else "arg")
    return names


def lower_rwkv_step(cfg, params, out_dir):
    """Decode-step graph with (token, state, params) as runtime inputs."""

    def step(token, state, params):
        logits, ns = M.model_step(params, cfg, token, state, use_pallas=True)
        return (logits, ns["aa"], ns["bb"], ns["pp"], ns["x_att"], ns["x_ffn"])

    tok_spec = jax.ShapeDtypeStruct((), jnp.int32)
    state_spec = {
        k: jax.ShapeDtypeStruct((cfg.n_layer, cfg.d_model), jnp.float32)
        for k in ["aa", "bb", "pp", "x_att", "x_ffn"]
    }
    param_spec = {
        k: jax.ShapeDtypeStruct(np.asarray(v).shape, jnp.float32)
        for k, v in params.items()
    }
    lowered = jax.jit(step).lower(tok_spec, state_spec, param_spec)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "rwkv_step.hlo.txt"), "w") as f:
        f.write(text)

    names = flat_input_names((tok_spec, state_spec, param_spec))
    with open(os.path.join(out_dir, "rwkv_step.inputs.txt"), "w") as f:
        f.write("\n".join(names) + "\n")

    meta = {
        "arch": cfg.arch,
        "n_layer": cfg.n_layer,
        "d_model": cfg.d_model,
        "vocab": cfg.vocab,
        "ffn_dim": cfg.ffn_dim,
        "outputs": ["logits", "aa", "bb", "pp", "x_att", "x_ffn"],
        "n_inputs": len(names),
    }
    with open(os.path.join(out_dir, "rwkv_step.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"rwkv_step.hlo.txt: {len(text)} chars, {len(names)} inputs")


def lower_vq_matvec(out_dir, n_entries=256, d=4, oc=128, ic=128):
    """Standalone fused VQ dequant-matvec (L1 kernel) artifact."""

    def fn(codebook, idx, x):
        return (dq.dequant_matvec(codebook, idx, x, oc=oc, ic=ic),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n_entries, d), jnp.float32),
        jax.ShapeDtypeStruct((oc * ic // d,), jnp.int32),
        jax.ShapeDtypeStruct((ic,), jnp.float32),
    )
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "vq_matvec.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, "vq_matvec.meta.json"), "w") as f:
        json.dump({"n_entries": n_entries, "d": d, "oc": oc, "ic": ic}, f)
    print(f"vq_matvec.hlo.txt: {len(text)} chars")


def lower_smoke(out_dir):
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    with open(os.path.join(out_dir, "smoke.hlo.txt"), "w") as f:
        f.write(text)
    print(f"smoke.hlo.txt: {len(text)} chars")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    lower_smoke(args.out)
    lower_vq_matvec(args.out)

    store = os.path.join(args.out, "tiny_rwkv.bin")
    if os.path.exists(store):
        cfg, params = M.load_store(store)
        lower_rwkv_step(cfg, params, args.out)
    else:
        print(f"warning: {store} missing — run compile.train first; "
              "skipping rwkv_step artifact")


if __name__ == "__main__":
    main()
