"""L2: the RWKV forward pass in JAX, mirroring ``rust/src/model/rwkv.rs``
equation-for-equation (same parameter names, same WKV stabilisation, same
token-shift/channel-mixing structure), calling the L1 Pallas kernels.

Also implements the ``RWKVQ1`` binary weight-store codec shared with the
Rust crate (``rust/src/model/store.rs``) so weights flow
train.py → artifacts/tiny_rwkv.bin → {aot.py, rust}.
"""

import struct

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ewmix as ewmix_k
from .kernels import ref as kref
from .kernels import wkv as wkv_k

# ParamClass tags (must match rust/src/model/store.rs)
CLASS_MATMUL = 0
CLASS_ELEMENTWISE = 1
CLASS_VECTOR = 2
CLASS_EMBEDDING = 3

MAGIC = b"RWKVQ1\x00\x00"


class Config:
    def __init__(self, arch, n_layer, d_model, vocab, head_dim=64, ffn_ratio=3.5):
        self.arch = arch
        self.n_layer = n_layer
        self.d_model = d_model
        self.vocab = vocab
        self.head_dim = head_dim
        self.ffn_ratio = ffn_ratio

    @property
    def ffn_dim(self):
        # mirrors ModelConfig::ffn_dim in rust/src/config/mod.rs
        return max(int(self.d_model * self.ffn_ratio) // 32, 1) * 32

    @property
    def gated(self):
        return self.arch == "rwkv7"


# ---------------------------------------------------------------------------
# RWKVQ1 store codec
# ---------------------------------------------------------------------------

def save_store(path, cfg, params, classes):
    """Write params (dict name -> np.ndarray 2-D) in RWKVQ1 format."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        arch = cfg.arch.encode()
        f.write(struct.pack("<I", len(arch)))
        f.write(arch)
        f.write(struct.pack("<IIII", cfg.n_layer, cfg.d_model, cfg.vocab, cfg.head_dim))
        f.write(struct.pack("<d", cfg.ffn_ratio))
        f.write(struct.pack("<I", len(params)))
        for name, arr in params.items():
            arr = np.asarray(arr, dtype=np.float32)
            if arr.ndim == 1:
                arr = arr[None, :]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", classes[name]))
            f.write(struct.pack("<QQ", arr.shape[0], arr.shape[1]))
            f.write(arr.tobytes())


def load_store(path):
    """Read an RWKVQ1 store; returns (Config, dict name -> np.ndarray)."""
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, f"bad magic in {path}"
        (alen,) = struct.unpack("<I", f.read(4))
        arch = f.read(alen).decode()
        n_layer, d_model, vocab, head_dim = struct.unpack("<IIII", f.read(16))
        (ffn_ratio,) = struct.unpack("<d", f.read(8))
        cfg = Config(arch, n_layer, d_model, vocab, head_dim, ffn_ratio)
        (count,) = struct.unpack("<I", f.read(4))
        params = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            (_cls,) = struct.unpack("<B", f.read(1))
            rows, cols = struct.unpack("<QQ", f.read(16))
            data = np.frombuffer(f.read(rows * cols * 4), dtype=np.float32)
            params[name] = data.reshape(rows, cols).copy()
        return cfg, params


def param_classes(cfg):
    """name -> ParamClass for every parameter of this config
    (mirrors rwkv::init_params)."""
    classes = {"emb": CLASS_EMBEDDING, "head": CLASS_EMBEDDING,
               "ln_out.g": CLASS_VECTOR, "ln_out.b": CLASS_VECTOR}
    for b in range(cfg.n_layer):
        p = f"blocks.{b}."
        for v in ["ln1.g", "ln1.b", "ln2.g", "ln2.b", "att.decay", "att.bonus"]:
            classes[p + v] = CLASS_VECTOR
        mus = ["att.mu_r", "att.mu_k", "att.mu_v", "ffn.mu_r", "ffn.mu_k"]
        mats = ["att.w_r", "att.w_k", "att.w_v", "att.w_o",
                "ffn.w_r", "ffn.w_k", "ffn.w_v"]
        if cfg.gated:
            mus.append("att.mu_g")
            mats.append("att.w_g")
        for v in mus:
            classes[p + v] = CLASS_ELEMENTWISE
        for v in mats:
            classes[p + v] = CLASS_MATMUL
    return classes


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def init_state(cfg):
    """Fresh recurrence state: dict of (n_layer, d) arrays."""
    z = jnp.zeros((cfg.n_layer, cfg.d_model), jnp.float32)
    return {
        "aa": z,
        "bb": z,
        "pp": jnp.full((cfg.n_layer, cfg.d_model), -1e30, jnp.float32),
        "x_att": z,
        "x_ffn": z,
    }


def _vec(params, name):
    return params[name].reshape(-1)


def model_step(params, cfg, token, state, use_pallas=True):
    """One decode token. Returns (logits, new_state).

    `use_pallas=True` routes the token-shift mixes and the WKV recurrence
    through the L1 Pallas kernels (the AOT serving graph);
    `use_pallas=False` uses the jnp reference path (differentiable, used
    by train.py).
    """
    mix = ewmix_k.ewmix if use_pallas else kref.ewmix_ref
    d = cfg.d_model
    x = params["emb"][token]

    new_state = {k: [] for k in ("aa", "bb", "pp", "x_att", "x_ffn")}
    for b in range(cfg.n_layer):
        p = f"blocks.{b}."
        xx = kref.layer_norm_ref(x, _vec(params, p + "ln1.g"), _vec(params, p + "ln1.b"))
        xa = state["x_att"][b]
        r_in = mix(_vec(params, p + "att.mu_r"), xx, xa)
        k_in = mix(_vec(params, p + "att.mu_k"), xx, xa)
        v_in = mix(_vec(params, p + "att.mu_v"), xx, xa)
        r = params[p + "att.w_r"] @ r_in
        k = params[p + "att.w_k"] @ k_in
        v = params[p + "att.w_v"] @ v_in

        if use_pallas:
            wkv, aa2, bb2, pp2 = wkv_k.wkv_step(
                k, v, _vec(params, p + "att.decay"), _vec(params, p + "att.bonus"),
                state["aa"][b], state["bb"][b], state["pp"][b],
            )
        else:
            wkv, (aa2, bb2, pp2) = kref.wkv_step_ref(
                k, v, _vec(params, p + "att.decay"), _vec(params, p + "att.bonus"),
                state["aa"][b], state["bb"][b], state["pp"][b],
            )

        gate = jax.nn.sigmoid(r)
        out = gate * wkv
        if cfg.gated:
            g_in = mix(_vec(params, p + "att.mu_g"), xx, xa)
            g = params[p + "att.w_g"] @ g_in
            out = out * jax.nn.sigmoid(g) * 2.0
        x = x + params[p + "att.w_o"] @ out

        xc = kref.layer_norm_ref(x, _vec(params, p + "ln2.g"), _vec(params, p + "ln2.b"))
        xf = state["x_ffn"][b]
        rp_in = mix(_vec(params, p + "ffn.mu_r"), xc, xf)
        kp_in = mix(_vec(params, p + "ffn.mu_k"), xc, xf)
        rp = params[p + "ffn.w_r"] @ rp_in
        kp = params[p + "ffn.w_k"] @ kp_in
        kp = jnp.maximum(kp, 0.0) ** 2
        x = x + jax.nn.sigmoid(rp) * (params[p + "ffn.w_v"] @ kp)

        new_state["aa"].append(aa2)
        new_state["bb"].append(bb2)
        new_state["pp"].append(pp2)
        new_state["x_att"].append(xx)
        new_state["x_ffn"].append(xc)

    xo = kref.layer_norm_ref(x, _vec(params, "ln_out.g"), _vec(params, "ln_out.b"))
    logits = params["head"] @ xo
    ns = {k: jnp.stack(v) for k, v in new_state.items()}
    return logits, ns


def forward_sequence(params, cfg, tokens):
    """Teacher-forced logits over a token sequence (jnp reference path,
    differentiable; used by train.py). tokens: (T,) int32.
    Returns (T, vocab) logits."""

    def step(state, tok):
        logits, ns = model_step(params, cfg, tok, state, use_pallas=False)
        return ns, logits

    _, logits = jax.lax.scan(step, init_state(cfg), tokens)
    return logits


def sequence_loss(params, cfg, tokens):
    """Mean next-token cross-entropy of `tokens` (T,)."""
    logits = forward_sequence(params, cfg, tokens[:-1])
    targets = tokens[1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=1).squeeze(1)
    return jnp.mean(nll)
