"""Tiny-corpus training (build-time only).

Trains a small RWKV-6 on a synthetic order-2 Markov grammar corpus for a
few hundred Adam steps — producing the *real small model* used by the
end-to-end serving example, the Table 5/7 ablations and the perplexity
evaluations. Writes:

* ``artifacts/tiny_rwkv.bin``  — trained weights (RWKVQ1 store)
* ``artifacts/corpus.bin``     — the token corpus (RWKVC1, read by Rust)
* ``artifacts/train_log.txt``  — step/loss curve (quoted in EXPERIMENTS.md)

Usage: python -m compile.train --out ../artifacts [--steps N]
"""

import argparse
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


# ---------------------------------------------------------------------------
# Grammar corpus (written to corpus.bin; Rust reads it back for eval)
# ---------------------------------------------------------------------------

def build_grammar(vocab, branch, rng):
    """Sparse order-2 Markov grammar: (8 buckets × vocab) states, each with
    `branch` weighted successors (Zipf-ish)."""
    buckets = 8
    succ_tok = rng.integers(0, vocab, size=(buckets * vocab, branch))
    succ_w = rng.gamma(0.7, 1.0, size=(buckets * vocab, branch)) + 0.05
    return {"vocab": vocab, "buckets": buckets, "tok": succ_tok, "w": succ_w}


def sample_grammar(g, length, rng):
    out = np.empty(length, dtype=np.int32)
    p2 = int(rng.integers(g["vocab"]))
    p1 = int(rng.integers(g["vocab"]))
    for i in range(length):
        s = (p2 % g["buckets"]) * g["vocab"] + p1
        w = g["w"][s]
        t = int(g["tok"][s][rng.choice(len(w), p=w / w.sum())])
        out[i] = t
        p2, p1 = p1, t
    return out


def save_corpus(path, vocab, train_toks, valid_toks):
    with open(path, "wb") as f:
        f.write(b"RWKVC1\x00\x00")
        f.write(struct.pack("<IQQ", vocab, len(train_toks), len(valid_toks)))
        f.write(np.asarray(train_toks, dtype=np.uint32).tobytes())
        f.write(np.asarray(valid_toks, dtype=np.uint32).tobytes())


# ---------------------------------------------------------------------------
# Parameter init (mirrors rwkv::init_params in spirit; trained anyway)
# ---------------------------------------------------------------------------

def init_params(cfg, rng):
    d, ffn, v = cfg.d_model, cfg.ffn_dim, cfg.vocab
    p = {}

    def mat(rows, cols, std=1.0):
        return (rng.standard_normal((rows, cols)) * std / np.sqrt(cols)).astype(np.float32)

    p["emb"] = (rng.standard_normal((v, d)) * 0.02).astype(np.float32)
    for b in range(cfg.n_layer):
        pre = f"blocks.{b}."
        p[pre + "ln1.g"] = np.ones((1, d), np.float32)
        p[pre + "ln1.b"] = np.zeros((1, d), np.float32)
        for mu in ["att.mu_r", "att.mu_k", "att.mu_v"]:
            ratio = np.arange(d, dtype=np.float32) / d
            depth = b / max(cfg.n_layer, 1)
            p[pre + mu] = (ratio ** (1.0 - depth * 0.5) * 0.9 + 0.05)[None, :]
        p[pre + "att.w_r"] = mat(d, d)
        p[pre + "att.w_k"] = mat(d, d)
        p[pre + "att.w_v"] = mat(d, d)
        p[pre + "att.w_o"] = mat(d, d, 0.5)
        decay = 0.3 + 5.7 * (np.arange(d, dtype=np.float32) / max(d, 1)) ** 2
        p[pre + "att.decay"] = decay[None, :].astype(np.float32)
        p[pre + "att.bonus"] = rng.uniform(0, 1, (1, d)).astype(np.float32)
        p[pre + "ln2.g"] = np.ones((1, d), np.float32)
        p[pre + "ln2.b"] = np.zeros((1, d), np.float32)
        p[pre + "ffn.mu_r"] = rng.uniform(0.2, 0.9, (1, d)).astype(np.float32)
        p[pre + "ffn.mu_k"] = rng.uniform(0.2, 0.9, (1, d)).astype(np.float32)
        p[pre + "ffn.w_r"] = mat(d, d, 0.8)
        p[pre + "ffn.w_k"] = mat(ffn, d)
        p[pre + "ffn.w_v"] = mat(d, ffn, 0.5)
    p["ln_out.g"] = np.ones((1, d), np.float32)
    p["ln_out.b"] = np.zeros((1, d), np.float32)
    p["head"] = mat(v, d, 0.5)
    return {k: jnp.asarray(v) for k, v in p.items()}


# the recurrence/norm parameters stay frozen during the short run: the
# decay/bonus dynamics are part of the architecture under study and the
# paper quantizes projection + μ weights only.
TRAINABLE_PRED = ("w_", "mu_", "emb", "head", "ln")


def is_trainable(name):
    return any(t in name for t in TRAINABLE_PRED) and "decay" not in name and "bonus" not in name


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.99, eps=1e-8):
    new_p, new_m, new_v = {}, {}, {}
    t = step + 1
    for k in params:
        if not is_trainable(k):
            new_p[k], new_m[k], new_v[k] = params[k], m[k], v[k]
            continue
        g = grads[k]
        m_k = b1 * m[k] + (1 - b1) * g
        v_k = b2 * v[k] + (1 - b2) * g * g
        mhat = m_k / (1 - b1**t)
        vhat = v_k / (1 - b2**t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m_k, v_k
    return new_p, new_m, new_v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=4e-3)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layer", type=int, default=4)
    ap.add_argument("--seed", type=int, default=1234)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    rng = np.random.default_rng(args.seed)
    grammar = build_grammar(args.vocab, branch=6, rng=rng)
    print("sampling corpus...", flush=True)
    train_toks = sample_grammar(grammar, 60_000, rng)
    valid_toks = sample_grammar(grammar, 8_000, rng)
    save_corpus(os.path.join(args.out, "corpus.bin"), args.vocab, train_toks, valid_toks)

    cfg = M.Config("rwkv6", args.n_layer, args.d_model, args.vocab)
    params = init_params(cfg, rng)
    n_params = sum(int(np.prod(v.shape)) for v in params.values())
    print(f"model: rwkv6 L={cfg.n_layer} d={cfg.d_model} ffn={cfg.ffn_dim} "
          f"vocab={cfg.vocab} params={n_params/1e6:.2f}M", flush=True)

    def batch_loss(p, toks):
        return jnp.mean(jax.vmap(lambda t: M.sequence_loss(p, cfg, t))(toks))

    loss_grad = jax.jit(jax.value_and_grad(batch_loss))

    m_state = {k: jnp.zeros_like(v) for k, v in params.items()}
    v_state = {k: jnp.zeros_like(v) for k, v in params.items()}

    log_lines = [f"# rwkv6 L={cfg.n_layer} d={cfg.d_model} vocab={cfg.vocab} "
                 f"params={n_params} steps={args.steps} seq={args.seq} batch={args.batch}"]
    t0 = time.time()
    for step in range(args.steps):
        starts = rng.integers(0, len(train_toks) - args.seq - 1, size=args.batch)
        toks = np.stack([train_toks[s:s + args.seq + 1] for s in starts])
        loss, grads = loss_grad(params, jnp.asarray(toks))
        params, m_state, v_state = adam_update(
            params, grads, m_state, v_state, step, args.lr)
        if step % 10 == 0 or step == args.steps - 1:
            line = f"step {step:4d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)"
            print(line, flush=True)
            log_lines.append(line)

    # held-out perplexity
    val = jnp.asarray(valid_toks[: args.seq * 16].reshape(16, args.seq))
    val_loss = float(batch_loss(params, val))
    uniform = float(np.log(args.vocab))
    log_lines.append(f"valid loss {val_loss:.4f}  ppl {np.exp(val_loss):.2f} "
                     f"(uniform {uniform:.2f} / ppl {args.vocab})")
    print(log_lines[-1], flush=True)

    classes = M.param_classes(cfg)
    M.save_store(os.path.join(args.out, "tiny_rwkv.bin"), cfg,
                 {k: np.asarray(v) for k, v in params.items()}, classes)
    with open(os.path.join(args.out, "train_log.txt"), "w") as f:
        f.write("\n".join(log_lines) + "\n")
    print("wrote", os.path.join(args.out, "tiny_rwkv.bin"), flush=True)


if __name__ == "__main__":
    main()
