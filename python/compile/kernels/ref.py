"""Pure-jnp oracles for the Pallas kernels (the L1 correctness contract).

Every Pallas kernel in this package is validated against these functions
by ``python/tests/test_kernels.py`` before ``aot.py`` lowers anything.
The WKV recurrence mirrors ``rust/src/model/rwkv.rs`` exactly (same
stabilisation, same state layout), so Rust, JAX-ref and Pallas all agree.
"""

import jax.numpy as jnp
from jax import lax


def ewmix_ref(mu, a, b):
    """Token-shift interpolation: mu ⊙ a + (1 - mu) ⊙ b (Eqs. 20-22)."""
    return mu * a + (1.0 - mu) * b


def wkv_step_ref(k, v, w, u, aa, bb, pp):
    """One token of the stabilised channel-wise WKV recurrence (Eq. 23).

    Args:
      k, v: (d,) current key/value.
      w:    (d,) positive per-channel decay.
      u:    (d,) bonus for the current token.
      aa, bb, pp: (d,) recurrence state (numerator, denominator, max-exp).

    Returns: (wkv, (aa', bb', pp')).
    """
    ww = u + k
    p = jnp.maximum(pp, ww)
    e1 = jnp.exp(pp - p)
    e2 = jnp.exp(ww - p)
    wkv = (e1 * aa + e2 * v) / jnp.maximum(e1 * bb + e2, 1e-30)

    ww2 = pp - w
    p2 = jnp.maximum(ww2, k)
    ea = jnp.exp(ww2 - p2)
    eb = jnp.exp(k - p2)
    aa2 = ea * aa + eb * v
    bb2 = ea * bb + eb
    return wkv, (aa2, bb2, p2)


def wkv_sequence_ref(ks, vs, w, u, aa, bb, pp):
    """Scan `wkv_step_ref` over a (T, d) sequence. Returns (T, d) wkv
    outputs and the final state."""

    def step(state, kv):
        saa, sbb, spp = state
        k, v = kv
        out, (aa2, bb2, pp2) = wkv_step_ref(k, v, w, u, saa, sbb, spp)
        return (aa2, bb2, pp2), out

    (aa_f, bb_f, pp_f), outs = lax.scan(step, (aa, bb, pp), (ks, vs))
    return outs, (aa_f, bb_f, pp_f)


def dequant_matvec_ref(codebook, idx, x, oc, ic):
    """VQ dequantize-then-matvec oracle.

    Args:
      codebook: (2^k, d) float entries.
      idx: (oc*ic//d,) int32 codebook indices (row-major over W).
      x: (ic,) activation.

    Returns: (oc,) y = W @ x with W = codebook[idx].reshape(oc, ic).
    """
    w = codebook[idx].reshape(oc, ic)
    return w @ x


def sq_dequant_matvec_ref(codes, scales, mins, group, x, oc, ic):
    """SQ dequantize-then-matvec oracle: w = min_g + scale_g * code."""
    g = jnp.arange(oc * ic) // group
    flat = mins[g] + scales[g] * codes.astype(jnp.float32)
    return flat.reshape(oc, ic) @ x


def layer_norm_ref(x, g, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b
