"""Pallas fused codebook-dequantize matvec kernel (L1).

The serving hot-spot of a VQ-quantized RWKV decode step: gather codebook
entries by index and contract with the activation without materialising
the full fp weight in HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the codebook is tiny
(2^k × d fp16/fp32) and lives wholly in VMEM — the analogue of the CUDA
shared-memory LUT in VPTQ's kernels; the index stream is the only
weight-proportional HBM traffic (k bits/weight after packing). The grid
tiles the output dimension; each program gathers its `(block_oc × ic)`
weight tile and reduces against the VMEM-resident activation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dq_matvec_kernel(cb_ref, idx_ref, x_ref, out_ref, *, ic, d):
    # idx tile: (block_oc * ic // d,) indices for this tile's rows
    idx = idx_ref[...]
    gathered = cb_ref[idx, :]  # (tile_vecs, d)
    block_oc = out_ref.shape[0]
    w = gathered.reshape(block_oc, ic)
    out_ref[...] = w @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("oc", "ic", "block_oc"))
def dequant_matvec(codebook, idx, x, oc, ic, block_oc=64):
    """y = (codebook[idx].reshape(oc, ic)) @ x, fused gather+matvec.

    Args:
      codebook: (n_entries, d) float32.
      idx: (oc * ic // d,) int32, row-major over the weight.
      x: (ic,) float32.
    """
    n_entries, d = codebook.shape
    assert (oc * ic) % d == 0 and ic % d == 0
    block_oc = min(block_oc, oc)
    assert oc % block_oc == 0
    vecs_per_block = block_oc * ic // d
    return pl.pallas_call(
        functools.partial(_dq_matvec_kernel, ic=ic, d=d),
        grid=(oc // block_oc,),
        in_specs=[
            pl.BlockSpec((n_entries, d), lambda i: (0, 0)),  # codebook resident
            pl.BlockSpec((vecs_per_block,), lambda i: (i,)),
            pl.BlockSpec((ic,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_oc,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((oc,), jnp.float32),
        interpret=True,
    )(codebook, idx, x)


def _sq_dq_matvec_kernel(codes_ref, scales_ref, mins_ref, x_ref, out_ref,
                         *, ic, group):
    block_oc = out_ref.shape[0]
    codes = codes_ref[...].astype(jnp.float32).reshape(block_oc, ic)
    # per-(row, column-group) grids, row-major group order within the tile
    n_groups_row = ic // group
    scales = scales_ref[...].reshape(block_oc, n_groups_row)
    mins = mins_ref[...].reshape(block_oc, n_groups_row)
    s = jnp.repeat(scales, group, axis=1)
    m = jnp.repeat(mins, group, axis=1)
    w = m + s * codes
    out_ref[...] = w @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("oc", "ic", "group", "block_oc"))
def sq_dequant_matvec(codes, scales, mins, x, oc, ic, group, block_oc=64):
    """y = dequant(codes; scales, mins) @ x for group-wise SQ weights.

    Args:
      codes: (oc*ic,) uint8/int32 quantized codes (row-major).
      scales/mins: (oc*ic//group,) per-group grid parameters.
      x: (ic,) float32.
    """
    assert ic % group == 0
    block_oc = min(block_oc, oc)
    assert oc % block_oc == 0
    groups_per_block = block_oc * ic // group
    return pl.pallas_call(
        functools.partial(_sq_dq_matvec_kernel, ic=ic, group=group),
        grid=(oc // block_oc,),
        in_specs=[
            pl.BlockSpec((block_oc * ic,), lambda i: (i,)),
            pl.BlockSpec((groups_per_block,), lambda i: (i,)),
            pl.BlockSpec((groups_per_block,), lambda i: (i,)),
            pl.BlockSpec((ic,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_oc,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((oc,), jnp.float32),
        interpret=True,
    )(codes, scales, mins, x)
