"""Pallas WKV kernels — the RWKV compute hot-spot (L1).

TPU-oriented design (see DESIGN.md §Hardware-Adaptation): the recurrence
state lives in VMEM-resident channel tiles; the grid partitions the
channel dimension so each program instance owns a `(block_d,)` state
slice, the analogue of the CUDA per-head threadblock in the reference
RWKV kernels. The sequence kernel walks time inside the kernel with
`fori_loop`, streaming `(T, block_d)` key/value tiles HBM→VMEM via
`BlockSpec`.

All kernels run `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO so the AOT
artifacts run anywhere (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 128  # one VPU lane row; d is padded to a multiple by callers


def _wkv_step_kernel(k_ref, v_ref, w_ref, u_ref, aa_ref, bb_ref, pp_ref,
                     out_ref, aa2_ref, bb2_ref, pp2_ref):
    k = k_ref[...]
    v = v_ref[...]
    w = w_ref[...]
    u = u_ref[...]
    aa = aa_ref[...]
    bb = bb_ref[...]
    pp = pp_ref[...]

    ww = u + k
    p = jnp.maximum(pp, ww)
    e1 = jnp.exp(pp - p)
    e2 = jnp.exp(ww - p)
    out_ref[...] = (e1 * aa + e2 * v) / jnp.maximum(e1 * bb + e2, 1e-30)

    ww2 = pp - w
    p2 = jnp.maximum(ww2, k)
    ea = jnp.exp(ww2 - p2)
    eb = jnp.exp(k - p2)
    aa2_ref[...] = ea * aa + eb * v
    bb2_ref[...] = ea * bb + eb
    pp2_ref[...] = p2


@functools.partial(jax.jit, static_argnames=("block_d",))
def wkv_step(k, v, w, u, aa, bb, pp, block_d=DEFAULT_BLOCK_D):
    """One decode token of the WKV recurrence for all channels.

    Shapes: all (d,) with d % block_d == 0 (callers pad).
    Returns (wkv, aa', bb', pp').
    """
    (d,) = k.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, f"d={d} not a multiple of block_d={block_d}"
    grid = (d // block_d,)
    spec = pl.BlockSpec((block_d,), lambda i: (i,))
    out_shape = [jax.ShapeDtypeStruct((d,), jnp.float32)] * 4
    return tuple(
        pl.pallas_call(
            _wkv_step_kernel,
            grid=grid,
            in_specs=[spec] * 7,
            out_specs=[spec] * 4,
            out_shape=out_shape,
            interpret=True,
        )(k, v, w, u, aa, bb, pp)
    )


def _wkv_seq_kernel(ks_ref, vs_ref, w_ref, u_ref, aa_ref, bb_ref, pp_ref,
                    out_ref, aa2_ref, bb2_ref, pp2_ref, *, seq_len):
    w = w_ref[...]
    u = u_ref[...]

    def body(t, state):
        aa, bb, pp = state
        k = ks_ref[t, :]
        v = vs_ref[t, :]
        ww = u + k
        p = jnp.maximum(pp, ww)
        e1 = jnp.exp(pp - p)
        e2 = jnp.exp(ww - p)
        out_ref[t, :] = (e1 * aa + e2 * v) / jnp.maximum(e1 * bb + e2, 1e-30)
        ww2 = pp - w
        p2 = jnp.maximum(ww2, k)
        ea = jnp.exp(ww2 - p2)
        eb = jnp.exp(k - p2)
        return ea * aa + eb * v, ea * bb + eb, p2

    aa, bb, pp = jax.lax.fori_loop(
        0, seq_len, body, (aa_ref[...], bb_ref[...], pp_ref[...])
    )
    aa2_ref[...] = aa
    bb2_ref[...] = bb
    pp2_ref[...] = pp


@functools.partial(jax.jit, static_argnames=("block_d",))
def wkv_sequence(ks, vs, w, u, aa, bb, pp, block_d=DEFAULT_BLOCK_D):
    """Full-sequence WKV scan: ks/vs are (T, d); returns ((T, d), state').

    Grid over channel blocks; state stays in VMEM across the whole T loop
    (the TPU translation of the CUDA persistent-threadblock scan).
    """
    t, d = ks.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    grid = (d // block_d,)
    vec = pl.BlockSpec((block_d,), lambda i: (i,))
    seq = pl.BlockSpec((t, block_d), lambda i: (0, i))
    outs = pl.pallas_call(
        functools.partial(_wkv_seq_kernel, seq_len=t),
        grid=grid,
        in_specs=[seq, seq, vec, vec, vec, vec, vec],
        out_specs=[seq, vec, vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        interpret=True,
    )(ks, vs, w, u, aa, bb, pp)
    return outs[0], (outs[1], outs[2], outs[3])
