"""Pallas element-wise mixing kernel (L1).

The token-shift interpolation `mu ⊙ a + (1-mu) ⊙ b` that precedes every
RWKV projection (Eqs. 20-22, 25-26) — the operator whose weights get the
§3.2 codebook optimisation. Pure VPU work tiled to lanes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ewmix_kernel(mu_ref, a_ref, b_ref, out_ref):
    mu = mu_ref[...]
    out_ref[...] = mu * a_ref[...] + (1.0 - mu) * b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d",))
def ewmix(mu, a, b, block_d=128):
    """mu ⊙ a + (1-mu) ⊙ b over (d,) vectors, d % block_d == 0."""
    (d,) = mu.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    spec = pl.BlockSpec((block_d,), lambda i: (i,))
    return pl.pallas_call(
        _ewmix_kernel,
        grid=(d // block_d,),
        in_specs=[spec] * 3,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(mu, a, b)
