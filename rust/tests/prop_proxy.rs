//! Property tests for the §3.1 coarse-to-fine proxy.

use rwkvquant::quant::proxy::{self, entropy, moments, GPrime};
use rwkvquant::util::ptest::{check, Gen};

#[test]
fn prop_pc_nonnegative_and_scale_invariant() {
    check("P_c ≥ 0 and scale-invariant", 60, |g| {
        let mut w = g.vec_normal(64..2048, 0.1);
        if w.len() < 2 {
            return Ok(());
        }
        let p1 = proxy::compute(&w, 4);
        if p1.p_c < -1e-9 {
            return Err(format!("P_c negative: {}", p1.p_c));
        }
        let s = g.f32_in(0.1..50.0);
        for v in w.iter_mut() {
            *v *= s;
        }
        let p2 = proxy::compute(&w, 4);
        if (p1.p_c - p2.p_c).abs() > 1e-3 * (1.0 + p1.p_c) {
            return Err(format!("scale changed P_c: {} vs {}", p1.p_c, p2.p_c));
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_grid_minimises_pc() {
    check("evenly spaced weights have (near-)zero proxies", 30, |g| {
        let n = g.usize_in(32..512);
        let step = g.f32_in(0.001..1.0);
        let w: Vec<f32> = (0..n.max(3)).map(|i| i as f32 * step).collect();
        let p = proxy::compute(&w, 4);
        if p.p_c < 1e-4 && p.p_f < 1e-3 {
            Ok(())
        } else {
            Err(format!("P_c={} P_f={}", p.p_c, p.p_f))
        }
    });
}

#[test]
fn prop_outlier_injection_never_decreases_pf() {
    check("adding an extreme outlier raises P_f", 40, |g| {
        let n = g.usize_in(128..1024).max(16);
        let step = 0.01f32;
        let mut w: Vec<f32> = (0..n).map(|i| i as f32 * step).collect();
        let before = proxy::compute(&w, 4).p_f;
        let pos = g.rng().below(n);
        w[pos] = n as f32 * step * g.f32_in(20.0..200.0);
        let after = proxy::compute(&w, 4).p_f;
        if after > before {
            Ok(())
        } else {
            Err(format!("P_f {before} -> {after} after outlier"))
        }
    });
}

#[test]
fn prop_pf_terms_all_nonnegative() {
    check("every |M_k| v_k term is ≥ 0 and sums to P_f", 40, |g| {
        let w = g.vec_normal(64..512, 1.0);
        if w.len() < 8 {
            return Ok(());
        }
        let gp = GPrime::from_weights(&w);
        let terms = moments::moment_terms(&gp, 5);
        if terms.iter().any(|&t| t < 0.0) {
            return Err(format!("negative term in {terms:?}"));
        }
        let sum: f64 = terms.iter().sum();
        let pf = moments::p_f(&gp, 5);
        if (sum - pf).abs() > 1e-9 * (1.0 + pf) {
            return Err(format!("sum {sum} != P_f {pf}"));
        }
        Ok(())
    });
}

#[test]
fn prop_entropy_via_t_equals_direct_definition() {
    check("stable P_c == ln n − H(G') computed directly", 30, |g| {
        let w = g.vec_normal(64..512, 0.3);
        if w.len() < 8 {
            return Ok(());
        }
        let gp = GPrime::from_weights(&w);
        let stable = entropy::p_c(&gp);
        // direct: rebuild G' = t/n and compute ln n + Σ g ln g
        let n = gp.n() as f64;
        let mut h = 0.0f64;
        for &t in &gp.t {
            let gi = t / n;
            if gi > 0.0 {
                h -= gi * gi.ln();
            }
        }
        let direct = (n.ln() - h).max(0.0);
        if (stable - direct).abs() < 1e-6 * (1.0 + direct) {
            Ok(())
        } else {
            Err(format!("stable {stable} vs direct {direct}"))
        }
    });
}
