//! Property tests for the SIMD matvec kernels: every kernel the host
//! can run ([`Kernel::available`]) must agree with the portable scalar
//! reference within 1e-5 across bit-widths, group sizes, odd row
//! lengths, AWQ-scaled layers, VQ vector dims, and f16 dense tensors
//! (where the widen itself must be bit-exact, not just close). On hosts
//! without a SIMD unit the properties degenerate to scalar-vs-scalar
//! (still exercising both matvec entry points).

use rwkvquant::quant::exec::{self, Kernel};
use rwkvquant::quant::{sq, vq, CalibData};
use rwkvquant::tensor::f16::{f16_to_f32, F16Tensor};
use rwkvquant::tensor::Matrix;
use rwkvquant::util::ptest::{check, close_slices, Gen};
use rwkvquant::util::rng::Rng;

const ATOL: f32 = 1e-5;
const RTOL: f32 = 1e-5;

fn rand_weight(g: &mut Gen, rows: usize, cols: usize) -> Matrix {
    let mut w = Matrix::zeros(rows, cols);
    let mut rng = Rng::new(g.seed() ^ 0x77ee);
    rng.fill_normal(&mut w.data, 0.0, 0.05);
    w
}

fn rand_x(g: &mut Gen, cols: usize) -> Vec<f32> {
    let mut rng = Rng::new(g.seed() ^ 0x5eed);
    (0..cols).map(|_| rng.normal() as f32).collect()
}

#[test]
fn simd_sq_matches_scalar_across_shapes() {
    check("simd matvec_sq ≡ scalar", 48, |g| {
        let rows = g.usize_in(1..40);
        // odd col counts force the straddling general path; multiples of
        // the group size take the aligned SIMD path — cover both
        let cols = g.usize_in(1..200);
        let bits = *g.choose(&[3u32, 4, 5, 8]);
        let group = *g.choose(&[8usize, 24, 32, 64]);
        let w = rand_weight(g, rows, cols);
        let q = sq::rtn::quantize(&w, bits, group);
        let x = rand_x(g, cols);
        let mut want = vec![0.0f32; rows];
        exec::matvec_sq_with(Kernel::Scalar, &q, &x, &mut want);
        for k in Kernel::available() {
            let mut got = vec![0.0f32; rows];
            exec::matvec_sq_with(k, &q, &x, &mut got);
            close_slices(&got, &want, ATOL, RTOL).map_err(|e| {
                format!("{} vs scalar, {rows}x{cols} bits={bits} group={group}: {e}", k.name())
            })?;
        }
        Ok(())
    });
}

#[test]
fn simd_sq_matches_scalar_on_awq_scaled_layers() {
    check("simd matvec_sq ≡ scalar (AWQ col_inv_scale)", 24, |g| {
        let rows = g.usize_in(1..32);
        let cols = *g.choose(&[32usize, 64, 96, 160]);
        let bits = *g.choose(&[3u32, 4]);
        let w = rand_weight(g, rows, cols);
        // calibration with hot channels so AWQ produces real scales
        let mut calib_x = Matrix::zeros(32, cols);
        let mut rng = Rng::new(g.seed() ^ 0xca11b);
        rng.fill_normal(&mut calib_x.data, 0.0, 1.0);
        for r in 0..calib_x.rows {
            for c in 0..4.min(cols) {
                *calib_x.at_mut(r, c) *= 8.0;
            }
        }
        let q = sq::awq::quantize(&w, bits, 32, Some(&CalibData { x: calib_x }));
        if q.col_inv_scale.is_none() {
            return Err("AWQ must produce column scales".into());
        }
        let x = rand_x(g, cols);
        let mut want = vec![0.0f32; rows];
        exec::matvec_sq_with(Kernel::Scalar, &q, &x, &mut want);
        for k in Kernel::available() {
            let mut got = vec![0.0f32; rows];
            exec::matvec_sq_with(k, &q, &x, &mut got);
            close_slices(&got, &want, ATOL, RTOL)
                .map_err(|e| format!("{} vs scalar (AWQ), {rows}x{cols}: {e}", k.name()))?;
        }
        Ok(())
    });
}

#[test]
fn simd_vq_matches_scalar_across_vector_dims() {
    check("simd matvec_vq ≡ scalar", 32, |g| {
        let rows = g.usize_in(1..32);
        let d = *g.choose(&[2usize, 3, 4, 8]);
        let cols = d * g.usize_in(1..24);
        let k_bits = *g.choose(&[4u32, 5, 6]);
        let w = rand_weight(g, rows, cols);
        let mut rng = Rng::new(g.seed() ^ 0x6b6d);
        let q = vq::kmeans::quantize(&w, k_bits, d, 4, &mut rng);
        let x = rand_x(g, cols);
        let mut want = vec![0.0f32; rows];
        exec::matvec_vq_with(Kernel::Scalar, &q, &x, &mut want);
        for k in Kernel::available() {
            let mut got = vec![0.0f32; rows];
            exec::matvec_vq_with(k, &q, &x, &mut got);
            close_slices(&got, &want, ATOL, RTOL).map_err(|e| {
                format!("{} vs scalar, {rows}x{cols} d={d} k={k_bits}: {e}", k.name())
            })?;
        }
        Ok(())
    });
}

#[test]
fn simd_f16_matvec_matches_scalar_across_shapes() {
    check("simd matvec_f16 ≡ scalar", 32, |g| {
        let rows = g.usize_in(1..32);
        // odd col counts exercise the scalar tail after the 8/4-lane loop
        let cols = g.usize_in(1..130);
        let w = rand_weight(g, rows, cols);
        let t = F16Tensor::from_matrix(&w);
        let x = rand_x(g, cols);
        let mut want = vec![0.0f32; rows];
        exec::matvec_f16_with(Kernel::Scalar, &t, &x, &mut want);
        for k in Kernel::available() {
            let mut got = vec![0.0f32; rows];
            exec::matvec_f16_with(k, &t, &x, &mut got);
            close_slices(&got, &want, ATOL, RTOL)
                .map_err(|e| format!("{} vs scalar, {rows}x{cols}: {e}", k.name()))?;
        }
        Ok(())
    });
}

#[test]
fn simd_f16_widen_is_bit_exact_on_random_payloads() {
    // the widen is conversion, not arithmetic: every kernel must produce
    // the exact f32 bits of the scalar f16_to_f32 reference, including
    // subnormal and extreme-exponent payloads the normal path never hits
    check("widen_f16 bit-exact", 32, |g| {
        let n = g.usize_in(1..200);
        let mut rng = Rng::new(g.seed() ^ 0xf16);
        let bits: Vec<u16> = (0..n).map(|_| rng.below(1 << 16) as u16).collect();
        for k in Kernel::available() {
            let mut out = vec![0.0f32; n];
            exec::widen_f16_into(k, &bits, &mut out);
            for (i, (&b, &got)) in bits.iter().zip(&out).enumerate() {
                let want = f16_to_f32(b);
                if want.is_nan() {
                    if !got.is_nan() {
                        return Err(format!("{}: [{i}] {b:#06x} lost NaN", k.name()));
                    }
                } else if got.to_bits() != want.to_bits() {
                    return Err(format!(
                        "{}: [{i}] {b:#06x} -> {got} want {want}",
                        k.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn public_matvecs_use_a_host_supported_kernel() {
    // the default entry points must dispatch to whatever detect() found
    // and agree with the scalar reference on a fixed layer
    let mut rng = Rng::new(77);
    let mut w = Matrix::zeros(24, 96);
    rng.fill_normal(&mut w.data, 0.0, 0.05);
    let q = sq::rtn::quantize(&w, 3, 32);
    let x: Vec<f32> = (0..96).map(|_| rng.normal() as f32).collect();
    let mut via_default = vec![0.0f32; 24];
    exec::matvec_sq(&q, &x, &mut via_default);
    let mut via_scalar = vec![0.0f32; 24];
    exec::matvec_sq_with(Kernel::Scalar, &q, &x, &mut via_scalar);
    close_slices(&via_default, &via_scalar, ATOL, RTOL).unwrap();
    assert!(Kernel::available().contains(&exec::active_kernel()));
}
