//! Property/fuzz-style tests over the gateway's HTTP request parser:
//! random byte soup, systematic truncation of valid requests (including
//! chunked framing), hostile header/body sizes and random mutations must
//! all map to clean `HttpError`s — never a panic, never an unbounded
//! allocation. Valid requests must round-trip field-for-field.

use rwkvquant::server::http::{read_request, HttpError, HttpRequest, Limits};
use rwkvquant::util::ptest::{check, Gen};
use std::io::Cursor;

fn parse(bytes: &[u8]) -> Result<Option<HttpRequest>, HttpError> {
    read_request(&mut Cursor::new(bytes), &Limits::default())
}

/// Build a syntactically valid request from generator choices, returning
/// the wire bytes and the expected body.
fn gen_valid_request(g: &mut Gen) -> (Vec<u8>, String, Vec<u8>) {
    let method = ["GET", "POST", "PUT", "DELETE"][g.rng().below(4)].to_string();
    let path = format!("/p{}?q={}", g.rng().below(100), g.rng().below(10));
    let n_headers = g.rng().below(5);
    let mut wire = format!("{method} {path} HTTP/1.1\r\n");
    for i in 0..n_headers {
        wire.push_str(&format!("X-H{i}: v{}\r\n", g.rng().below(1000)));
    }
    let body_len = g.rng().below(64) + 1; // ≥ 1 so every strict prefix truncates
    let body: Vec<u8> = (0..body_len).map(|_| (g.rng().below(256)) as u8).collect();
    if g.prob(0.5) {
        // Content-Length framing
        wire.push_str(&format!("Content-Length: {body_len}\r\n\r\n"));
        let mut bytes = wire.into_bytes();
        bytes.extend_from_slice(&body);
        (bytes, method, body)
    } else {
        // chunked framing, body split into 1..=3 chunks
        wire.push_str("Transfer-Encoding: chunked\r\n\r\n");
        let mut bytes = wire.into_bytes();
        let cuts = g.rng().below(3) + 1;
        let mut rest: &[u8] = &body;
        for i in 0..cuts {
            if rest.is_empty() {
                break;
            }
            let take = if i + 1 == cuts {
                rest.len()
            } else {
                (g.rng().below(rest.len()) + 1).min(rest.len())
            };
            let (chunk, tail) = rest.split_at(take);
            bytes.extend_from_slice(format!("{:x}\r\n", chunk.len()).as_bytes());
            bytes.extend_from_slice(chunk);
            bytes.extend_from_slice(b"\r\n");
            rest = tail;
        }
        bytes.extend_from_slice(b"0\r\n\r\n");
        (bytes, method, body)
    }
}

#[test]
fn random_bytes_never_panic() {
    check("random bytes parse to Ok or a clean error", 300, |g| {
        let n = g.rng().below(512);
        let soup: Vec<u8> = (0..n).map(|_| g.rng().below(256) as u8).collect();
        // any outcome is fine — reaching this line without a panic is
        // the property; errors must carry a mappable status or be Io
        if let Err(e) = parse(&soup) {
            let _ = e.status();
            let _ = e.message();
        }
        Ok(())
    });
}

#[test]
fn valid_requests_round_trip() {
    check("generated requests parse field-for-field", 200, |g| {
        let (wire, method, body) = gen_valid_request(g);
        match parse(&wire) {
            Ok(Some(req)) => {
                if req.method != method {
                    return Err(format!("method {} != {method}", req.method));
                }
                if req.body != body {
                    return Err(format!(
                        "body mismatch: {} vs {} bytes",
                        req.body.len(),
                        body.len()
                    ));
                }
                Ok(())
            }
            other => Err(format!("valid request failed to parse: {other:?}")),
        }
    });
}

#[test]
fn every_strict_prefix_is_a_clean_4xx() {
    check("truncations map to 4xx, never panic", 80, |g| {
        let (wire, _, _) = gen_valid_request(g);
        let cut = g.rng().below(wire.len() - 1) + 1; // 1..len-1: strictly inside
        match parse(&wire[..cut]) {
            Ok(Some(req)) => Err(format!(
                "truncated at {cut}/{} parsed as a full request ({} body bytes)",
                wire.len(),
                req.body.len()
            )),
            Ok(None) => Err(format!("truncated at {cut} read as clean EOF")),
            Err(e) => match e.status() {
                Some(s) if (400..500).contains(&s) => Ok(()),
                other => Err(format!("truncation at {cut} mapped to {other:?}")),
            },
        }
    });
}

#[test]
fn random_mutations_never_panic() {
    check("byte mutations parse or error cleanly", 200, |g| {
        let (mut wire, _, _) = gen_valid_request(g);
        // flip up to 4 bytes anywhere in the message
        for _ in 0..(g.rng().below(4) + 1) {
            let i = g.rng().below(wire.len());
            wire[i] = g.rng().below(256) as u8;
        }
        let _ = parse(&wire); // no panic is the property
        Ok(())
    });
}

#[test]
fn hostile_sizes_do_not_allocate_unbounded() {
    // a Content-Length of usize::MAX must be rejected before any
    // allocation happens (the parser checks the limit first)
    let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", usize::MAX);
    assert_eq!(parse(huge.as_bytes()).err().unwrap().status(), Some(413));

    // a header line that never ends is cut off at the line cap, not
    // buffered forever — the parser reads it bounded and errors
    let mut endless = b"GET / HTTP/1.1\r\nX-Endless: ".to_vec();
    endless.resize(endless.len() + (1 << 20), b'a');
    assert_eq!(parse(&endless).err().unwrap().status(), Some(431));

    // a chunked stream claiming an enormous chunk is rejected at the
    // size line, before reading the (absent) payload
    let big_chunk = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nffffffffff\r\n";
    assert_eq!(parse(big_chunk).err().unwrap().status(), Some(413));

    // an over-long chunk-size line cannot buffer unbounded either
    let mut long_size = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
    long_size.resize(long_size.len() + (1 << 20), b'1');
    let e = parse(&long_size).err().unwrap();
    assert!(e.status().is_some_and(|s| (400..500).contains(&s)), "{e}");
}

#[test]
fn pathological_but_valid_inputs_parse() {
    // header value with embedded colons, odd casing, whitespace padding
    let req = parse(
        b"GET /x HTTP/1.1\r\ncOnTeNt-TyPe:   a:b:c  \r\n\r\n",
    )
    .unwrap()
    .unwrap();
    assert_eq!(req.header("content-type"), Some("a:b:c"));

    // empty chunked body
    let req = parse(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n")
        .unwrap()
        .unwrap();
    assert!(req.body.is_empty());

    // maximum allowed header count exactly at the limit
    let lim = Limits::default();
    let headers: String = (0..lim.max_headers).map(|i| format!("H{i}: v\r\n")).collect();
    let wire = format!("GET / HTTP/1.1\r\n{headers}\r\n");
    assert!(parse(wire.as_bytes()).unwrap().is_some());
}
