//! Integration: the generation server over a quantized model — the
//! serving loop answers every request, batching does not change
//! outputs, and the quantized model serves with the expected memory
//! footprint reduction.

use rwkvquant::config::{ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{
    serve, serve_collect, serve_collect_per_tick_spawn, serve_collect_pool,
    serve_collect_pool_with, with_tick_pool, Decoder, PoolOpts, Request, Response, RunnerDecoder,
    ServeOpts,
};
use rwkvquant::eval::dequantized_model;
use rwkvquant::model::synthetic::{generate_rwkv, Family};
use rwkvquant::model::QuantizedModel;
use std::sync::mpsc;
use std::time::Duration;

#[test]
fn quantized_model_serves_batched_requests() {
    let cfg = ModelConfig::rwkv6(2, 64, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 5);
    let qc = QuantConfig { kmeans_iters: 5, ..QuantConfig::default() };
    let (q, rep) = quantize_model(&m, None, &qc, 0);
    // serve straight from the packed payloads
    let qm = QuantizedModel::from_parts(&m, &q);

    let mut dec = RunnerDecoder::new(&qm);
    let (tx_req, rx_req) = mpsc::channel();
    let (tx_resp, rx_resp) = mpsc::channel();
    for id in 0..10u64 {
        tx_req.send(Request::new(id, vec![(id as usize) % 128, 3, 5], 6)).unwrap();
    }
    drop(tx_req);
    let stats = serve(&mut dec, rx_req, tx_resp, 4, Duration::from_millis(2)).unwrap();
    assert_eq!(stats.completed, 10);
    assert_eq!(stats.total_tokens, 60);
    assert!(stats.tokens_per_sec() > 0.0);

    let responses: Vec<Response> = rx_resp.iter().collect();
    assert_eq!(responses.len(), 10);
    assert!(responses.iter().all(|r| r.tokens.len() == 6));
    assert!(responses.iter().all(|r| r.tokens.iter().all(|&t| t < 128)));

    // footprint: quantized store must be far below fp32
    let fp_bits: usize = m
        .quantizable_indices()
        .iter()
        .map(|&i| m.layers[i].1.numel() * 32)
        .sum();
    let q_bits: usize = q.values().map(|l| l.storage_bits()).sum();
    assert!(
        (q_bits as f64) < fp_bits as f64 * 0.15,
        "quantized {} vs fp {} bits",
        q_bits,
        fp_bits
    );
    assert!(rep.avg_bpw < 4.0);
}

#[test]
fn batch_size_does_not_change_greedy_outputs() {
    let cfg = ModelConfig::rwkv6(1, 32, 64);
    let m = generate_rwkv(&cfg, Family::Rwkv, 6);

    let run_with_batch = |max_batch: usize| -> Vec<(u64, Vec<usize>)> {
        let mut dec = RunnerDecoder::new(&m);
        let (tx_req, rx_req) = mpsc::channel();
        let (tx_resp, rx_resp) = mpsc::channel();
        for id in 0..5u64 {
            tx_req.send(Request::new(id, vec![(id as usize) + 1], 5)).unwrap();
        }
        drop(tx_req);
        serve(&mut dec, rx_req, tx_resp, max_batch, Duration::from_millis(0)).unwrap();
        let mut out: Vec<(u64, Vec<usize>)> =
            rx_resp.iter().map(|r| (r.id, r.tokens)).collect();
        out.sort();
        out
    };

    assert_eq!(run_with_batch(1), run_with_batch(4));
}

#[test]
fn threaded_ticks_serve_token_identical_to_sequential() {
    // tick_threads > 1 must be a pure wall-clock change: sequence state
    // is fully swapped per tick, so the pooled decode is deterministic
    let cfg = ModelConfig::rwkv6(2, 48, 96);
    let m = generate_rwkv(&cfg, Family::Rwkv, 21);
    let qc = QuantConfig { kmeans_iters: 4, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 0);
    let qm = QuantizedModel::from_parts(&m, &q);

    let requests = || -> Vec<Request> {
        (0..12u64)
            .map(|id| Request::new(id, vec![(id as usize * 11 + 2) % 96, 7, 3], 6))
            .collect()
    };
    let mut seq_dec = RunnerDecoder::new(&qm);
    let (seq_stats, seq) =
        serve_collect(&mut seq_dec, requests(), 4, Duration::from_millis(1)).unwrap();
    assert_eq!(seq_stats.completed, 12);

    for threads in [2usize, 4] {
        let mut decoders: Vec<_> = (0..threads).map(|_| RunnerDecoder::new(&qm)).collect();
        let (stats, pooled) =
            serve_collect_pool(&mut decoders, requests(), 4, Duration::from_millis(1)).unwrap();
        assert_eq!(stats.completed, 12);
        assert_eq!(stats.total_tokens, seq_stats.total_tokens);
        let want: Vec<_> = seq.iter().map(|r| (r.id, r.tokens.clone())).collect();
        let got: Vec<_> = pooled.iter().map(|r| (r.id, r.tokens.clone())).collect();
        assert_eq!(got, want, "{threads} tick threads changed the served tokens");
    }
}

#[test]
fn one_pool_serves_consecutive_sessions_token_identically() {
    // the lifecycle contract of the persistent pool on a real quantized
    // model: two full serving sessions back-to-back on ONE pool, no
    // worker re-creation between them, tokens identical to the
    // sequential reference in both — and the legacy per-tick-spawn
    // engine still agrees (it is the pool's perf baseline)
    let cfg = ModelConfig::rwkv6(2, 48, 96);
    let m = generate_rwkv(&cfg, Family::Rwkv, 31);
    let qc = QuantConfig { kmeans_iters: 4, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 0);
    let qm = QuantizedModel::from_parts(&m, &q);

    let requests = || -> Vec<Request> {
        (0..10u64)
            .map(|id| Request::new(id, vec![(id as usize * 13 + 1) % 96, 5], 6))
            .collect()
    };
    let mut seq_dec = RunnerDecoder::new(&qm);
    let (_, seq) = serve_collect(&mut seq_dec, requests(), 4, Duration::from_millis(1)).unwrap();
    let want: Vec<_> = seq.iter().map(|r| (r.id, r.tokens.clone())).collect();

    let mut spawn_decs: Vec<_> = (0..3).map(|_| RunnerDecoder::new(&qm)).collect();
    let (_, spawned) =
        serve_collect_per_tick_spawn(&mut spawn_decs, requests(), 4, Duration::from_millis(1))
            .unwrap();
    let got: Vec<_> = spawned.iter().map(|r| (r.id, r.tokens.clone())).collect();
    assert_eq!(got, want, "per-tick spawn engine diverged");

    let mut decs: Vec<_> = (0..3).map(|_| RunnerDecoder::new(&qm)).collect();
    with_tick_pool(&mut decs, |pool| {
        assert_eq!(pool.spawned_workers(), 2);
        for session in 0..2 {
            let (tx_req, rx_req) = mpsc::channel();
            let (tx_resp, rx_resp) = mpsc::channel();
            for r in requests() {
                tx_req.send(r).unwrap();
            }
            drop(tx_req);
            let stats = pool.serve(rx_req, tx_resp, 4, Duration::from_millis(1)).unwrap();
            assert_eq!(stats.completed, 10, "session {session}");
            let mut got: Vec<_> = rx_resp.iter().map(|r| (r.id, r.tokens)).collect();
            got.sort();
            assert_eq!(got, want, "session {session} diverged from sequential");
            // no worker churn: the distinct thread set stays within the
            // spawned pool across sessions (per-tick spawning would mint
            // new threads every tick)
            assert!(
                pool.distinct_worker_threads() <= pool.spawned_workers(),
                "session {session}: worker threads leaked"
            );
        }
        assert!(pool.ticks() > 0);
    });
}

#[test]
fn packed_decoder_completes_with_same_tokens_as_dequantized_twin() {
    let cfg = ModelConfig::rwkv6(2, 64, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 11);
    let qc = QuantConfig { kmeans_iters: 5, vq_bits: 7, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 0);
    let qm = QuantizedModel::from_parts(&m, &q);
    let dq = dequantized_model(&m, &q);

    fn run<D: Decoder>(dec: &mut D) -> Vec<(u64, Vec<usize>)> {
        let requests: Vec<Request> = (0..6u64)
            .map(|id| Request::new(id, vec![(id as usize * 17 + 1) % 128, 9, 4], 5))
            .collect();
        let (_, responses) =
            serve_collect(dec, requests, 3, Duration::from_millis(1)).unwrap();
        responses.into_iter().map(|r| (r.id, r.tokens)).collect()
    }

    let mut packed_dec = RunnerDecoder::new(&qm);
    let mut dense_dec = RunnerDecoder::new(&dq);
    let packed_out = run(&mut packed_dec);
    let dense_out = run(&mut dense_dec);
    assert_eq!(
        packed_out, dense_out,
        "packed serving must produce the dequantized twin's greedy tokens"
    );
    assert!(qm.n_packed() > 0, "the packed decoder must actually serve packed layers");
}

/// Quantize a tiny synthetic model, round-trip it through an RWKVQ2
/// checkpoint, and serve from the reopened (packed) store — the prefill
/// and state-pool acceptance tests below run on the real packed path.
fn packed_store(tag: &str, seed: u64) -> QuantizedModel {
    use rwkvquant::model::rwkv::init_params;
    use rwkvquant::util::rng::Rng;
    let m = init_params(&ModelConfig::rwkv6(1, 16, 32), &mut Rng::new(seed));
    let qc = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 2);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    let path = std::env::temp_dir().join(format!("serve_{tag}.rwkvq2"));
    qm.save(&path).unwrap();
    let opened = QuantizedModel::open(&path).unwrap();
    std::fs::remove_file(path).ok();
    opened
}

#[test]
fn long_prompt_prefill_reaches_first_token_in_a_quarter_of_the_ticks() {
    // the tentpole acceptance criterion: a 512-token prompt must reach
    // its first generated token in ≤ 1/4 the ticks of one-token-per-tick
    // prefill, with identical tokens, on a packed RWKVQ2 store
    let qm = packed_store("prefill", 51);
    assert!(qm.n_packed() > 0);
    let prompt: Vec<usize> = (0..512).map(|i| (i * 7 + 3) % 32).collect();
    let gen_len = 8usize;
    let mut run = |chunk: usize| -> (Vec<usize>, u64) {
        let mut decs = [RunnerDecoder::new(&qm)];
        with_tick_pool(&mut decs, |pool| {
            let (tx_req, rx_req) = mpsc::channel();
            let (tx_resp, rx_resp) = mpsc::channel();
            tx_req.send(Request::new(0, prompt.clone(), gen_len)).unwrap();
            drop(tx_req);
            let opts = ServeOpts::new(1, Duration::from_millis(1)).with_prefill_chunk(chunk);
            let stats = pool
                .serve_with(rx_req, tx_resp, &opts, &rwkvquant::coordinator::serve::NoopObserver)
                .unwrap();
            assert_eq!(stats.completed, 1);
            assert_eq!(stats.prompt_tokens, 512);
            assert!(stats.p50_ttft > Duration::ZERO);
            assert!(stats.p50_ttft <= stats.p50_latency);
            let resp: Vec<Response> = rx_resp.iter().collect();
            (resp[0].tokens.clone(), pool.ticks())
        })
    };
    let (tokens_one, ticks_one) = run(1);
    let (tokens_chunked, ticks_chunked) = run(64);
    assert_eq!(tokens_one, tokens_chunked, "prefill chunking changed the generated tokens");
    assert_eq!(tokens_one.len(), gen_len);
    // 512 one-token prefill ticks + 8 generation vs ⌈512/64⌉ + 8
    assert_eq!(ticks_one, 520);
    assert_eq!(ticks_chunked, 16);
    assert!(
        ticks_chunked * 4 <= ticks_one,
        "chunked prefill took {ticks_chunked} ticks vs {ticks_one} — not a 4x cut"
    );
}

#[test]
fn bounded_state_pool_serves_more_sequences_than_slots_token_identically() {
    // slab-arena acceptance: 12 concurrent sequences through 4 slabs
    // must park/evict/resume and still match the unbounded twin exactly,
    // on the packed RWKVQ2 path
    let qm = packed_store("slabs", 53);
    let requests = || -> Vec<Request> {
        (0..12u64)
            .map(|id| {
                let prompt: Vec<usize> =
                    (0..10).map(|i| (id as usize * 11 + i * 3 + 1) % 32).collect();
                Request::new(id, prompt, 6)
            })
            .collect()
    };
    let mut free_dec = RunnerDecoder::new(&qm);
    let (free_stats, want) =
        serve_collect(&mut free_dec, requests(), 12, Duration::from_millis(1)).unwrap();
    assert_eq!(free_stats.state_parks, 0);

    let mut decs: Vec<_> = (0..2).map(|_| RunnerDecoder::new(&qm)).collect();
    let opts = ServeOpts::new(12, Duration::from_millis(1))
        .with_state_slots(4)
        .with_prefill_chunk(8);
    let (stats, got) =
        serve_collect_pool_with(&mut decs, requests(), &opts, PoolOpts::default()).unwrap();
    assert_eq!(stats.completed, 12);
    assert!(stats.state_parks > 0, "12 sequences over 4 slabs must evict");
    assert!(stats.state_resumes > stats.state_parks);
    let a: Vec<_> = want.iter().map(|r| (r.id, r.tokens.clone())).collect();
    let b: Vec<_> = got.iter().map(|r| (r.id, r.tokens.clone())).collect();
    assert_eq!(a, b, "bounded state arena changed the served tokens");
}
