//! Integration: the RWKVQ2 packed checkpoint format — the CI
//! format/round-trip matrix.
//!
//! A tiny hybrid-quantized model is packed to RWKVQ2 and re-opened both
//! memory-mapped and buffered; both reopened models must produce
//! **bit-identical logits and token-identical greedy output** against
//! the in-memory `QuantizedModel` twin (which took the same dense f16
//! rounding via `dense_to_f16`). The mmap path must borrow every packed
//! payload zero-copy from the mapping, and dense 2-D entries must be
//! resident at 16 bits/element.

use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::model::rwkv::{init_params, RwkvRunner};
use rwkvquant::model::store::{detect_format, open_rwkvq2};
use rwkvquant::model::{
    LoadMode, ModelWeights, QuantizedModel, ServedParam, StoreFormat, WeightProvider,
};
use rwkvquant::util::mmap::Mmap;
use rwkvquant::util::rng::Rng;

fn packed_tiny(seed: u64) -> (ModelWeights, QuantizedModel) {
    let m = init_params(&ModelConfig::rwkv6(2, 32, 64), &mut Rng::new(seed));
    let cfg = QuantConfig { kmeans_iters: 5, vq_bits: 6, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &cfg, 0);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    // resident dense entries take the on-disk f16 rounding up front, so
    // the reopened checkpoint serves bit-identically to this twin
    qm.dense_to_f16();
    (m, qm)
}

fn greedy<W: WeightProvider>(w: &W, prompt: &[usize], n: usize) -> Vec<usize> {
    let argmax = |l: &[f32]| {
        l.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    };
    let mut run = RwkvRunner::new(w);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = run.forward_token(t);
    }
    let mut out = Vec::with_capacity(n);
    let mut tok = argmax(&logits);
    for _ in 0..n {
        out.push(tok);
        tok = argmax(&run.forward_token(tok));
    }
    out
}

#[test]
fn rwkvq2_round_trip_serves_token_identical_in_both_load_modes() {
    let (_, qm) = packed_tiny(11);
    let path = std::env::temp_dir().join("rwkvq2_roundtrip_matrix.bin");
    qm.save(&path).unwrap();
    assert_eq!(detect_format(&path).unwrap(), StoreFormat::V2Packed);

    let mut modes = vec![(LoadMode::Buffered, false)];
    if Mmap::supported() {
        modes.push((LoadMode::Mmap, true));
    }
    for (mode, mapped) in modes {
        let back = open_rwkvq2(&path, mode).unwrap();
        assert_eq!(back.config, qm.config);
        assert_eq!(back.entries.len(), qm.entries.len());

        // per-entry: same names/shapes, and bit-identical logits — the
        // reopened payloads reproduce the twin's dequantization exactly
        for i in 0..qm.n_entries() {
            assert_eq!(qm.entry_name(i), back.entry_name(i));
            let a = qm.materialize_at(i).into_owned();
            let b = back.materialize_at(i).into_owned();
            assert_eq!(a, b, "entry '{}' drifted ({mode:?})", qm.entry_name(i));
        }
        let mut run_a = RwkvRunner::new(&qm);
        let mut run_b = RwkvRunner::new(&back);
        for t in [0usize, 3, 17, 63, 5] {
            assert_eq!(run_a.forward_token(t), run_b.forward_token(t), "logits drifted at {t}");
        }

        // greedy decode twin check (fresh state on both sides)
        for seed_tok in [1usize, 9, 40] {
            let want = greedy(&qm, &[seed_tok, 2, 7], 16);
            let got = greedy(&back, &[seed_tok, 2, 7], 16);
            assert_eq!(want, got, "greedy output diverged ({mode:?})");
        }

        // zero-copy + residency assertions
        if mapped {
            for (desc, p) in &back.entries {
                if p.is_packed() {
                    assert!(p.is_mapped(), "'{}' packed payload was copied", desc.name);
                }
                if let ServedParam::DenseF16(t) = p {
                    assert!(t.is_mapped(), "'{}' f16 payload was copied", desc.name);
                }
            }
            assert!(back.n_mapped() > 0);
        } else {
            assert_eq!(back.n_mapped(), 0, "buffered load must own its payloads");
        }
        for (desc, p) in &back.entries {
            match p {
                ServedParam::DenseF16(_) => {
                    assert_eq!(p.storage_bits(), p.numel() * 16, "'{}' not 16b", desc.name)
                }
                ServedParam::Dense(m) => {
                    assert_eq!(m.rows, 1, "only 1-D vectors may stay f32: '{}'", desc.name)
                }
                ServedParam::Packed(_) => {}
            }
        }
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn rwkvq2_halves_dense_and_beats_v1_on_disk() {
    let (m, qm) = packed_tiny(23);
    let v1 = std::env::temp_dir().join("rwkvq2_size_v1.bin");
    let v2 = std::env::temp_dir().join("rwkvq2_size_v2.bin");
    m.save(&v1).unwrap();
    qm.save(&v2).unwrap();
    let s1 = std::fs::metadata(&v1).unwrap().len();
    let s2 = std::fs::metadata(&v2).unwrap().len();
    // packed + f16 dense must undercut the dense fp32 interchange store
    assert!(s2 * 2 < s1, "RWKVQ2 {s2}B not < half of RWKVQ1 {s1}B");
    // resident dense storage is 16 bits/elem for every 2-D dense entry
    let dense16: usize = qm
        .entries
        .iter()
        .filter(|(_, p)| matches!(p, ServedParam::DenseF16(_)))
        .map(|(_, p)| p.numel())
        .sum();
    assert!(dense16 > 0);
    std::fs::remove_file(v1).ok();
    std::fs::remove_file(v2).ok();
}

#[test]
fn rwkvq2_quarot_fallback_round_trips_dense() {
    // QuaRot payloads cannot be served packed — from_parts stores them
    // dense, and the checkpoint must carry them as f16 dense entries
    let m = init_params(&ModelConfig::rwkv6(1, 32, 64), &mut Rng::new(31));
    let cfg = QuantConfig { method: Method::QuaRot, kmeans_iters: 4, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &cfg, 0);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    assert_eq!(qm.n_packed(), 0);
    let path = std::env::temp_dir().join("rwkvq2_quarot.bin");
    qm.save(&path).unwrap();
    let back = open_rwkvq2(&path, LoadMode::Auto).unwrap();
    assert_eq!(back.n_packed(), 0);
    let mut run_a = RwkvRunner::new(&qm);
    let mut run_b = RwkvRunner::new(&back);
    for t in [2usize, 8, 33] {
        assert_eq!(run_a.forward_token(t), run_b.forward_token(t));
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn v1_interchange_still_round_trips() {
    // v1 compatibility: the dense fp32 store written by the Python build
    // path keeps loading bit-exactly alongside the new format
    let m = init_params(&ModelConfig::rwkv6(1, 16, 32), &mut Rng::new(7));
    let path = std::env::temp_dir().join("rwkvq2_v1_compat.bin");
    m.save(&path).unwrap();
    assert_eq!(detect_format(&path).unwrap(), StoreFormat::V1Dense);
    let back = ModelWeights::load(&path).unwrap();
    assert_eq!(back.config, m.config);
    assert_eq!(back.layers.len(), m.layers.len());
    for ((da, ma), (db, mb)) in m.layers.iter().zip(&back.layers) {
        assert_eq!(da.name, db.name);
        assert_eq!(ma, mb);
    }
    // and a v2 opener must refuse it cleanly
    assert!(open_rwkvq2(&path, LoadMode::Buffered).is_err());
    std::fs::remove_file(path).ok();
}
