//! Property tests for bit-packing — the storage layer every quantized
//! artifact depends on.

use rwkvquant::quant::packing::PackedInts;
use rwkvquant::util::ptest::{check, Gen};

fn gen_values(g: &mut Gen, bits: u32) -> Vec<u32> {
    let n = g.usize_in(0..2000);
    let lim = 1u64 << bits;
    (0..n).map(|_| (g.rng().next_u64() % lim) as u32).collect()
}

#[test]
fn prop_pack_unpack_identity() {
    check("pack/unpack is the identity", 80, |g| {
        let bits = 1 + g.rng().below(24) as u32;
        let vals = gen_values(g, bits);
        let p = PackedInts::pack(&vals, bits);
        if p.unpack() == vals {
            Ok(())
        } else {
            Err(format!("round-trip failed at bits={bits} n={}", vals.len()))
        }
    });
}

#[test]
fn prop_random_access_matches_unpack() {
    check("get(i) == unpack()[i]", 50, |g| {
        let bits = 1 + g.rng().below(16) as u32;
        let vals = gen_values(g, bits);
        if vals.is_empty() {
            return Ok(());
        }
        let p = PackedInts::pack(&vals, bits);
        for _ in 0..20 {
            let i = g.rng().below(vals.len());
            if p.get(i) != vals[i] {
                return Err(format!("get({i}) = {} != {}", p.get(i), vals[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_payload_bits_exact() {
    check("payload_bits == len * bits", 50, |g| {
        let bits = 1 + g.rng().below(20) as u32;
        let vals = gen_values(g, bits);
        let p = PackedInts::pack(&vals, bits);
        if p.payload_bits() == vals.len() * bits as usize {
            Ok(())
        } else {
            Err(format!("{} != {}", p.payload_bits(), vals.len() * bits as usize))
        }
    });
}

#[test]
fn prop_get_range_consistent() {
    check("get_range == slice of unpack", 40, |g| {
        let bits = 1 + g.rng().below(12) as u32;
        let vals = gen_values(g, bits);
        if vals.len() < 4 {
            return Ok(());
        }
        let p = PackedInts::pack(&vals, bits);
        let start = g.rng().below(vals.len() - 2);
        let len = 1 + g.rng().below(vals.len() - start - 1);
        let mut out = vec![0u32; len];
        p.get_range(start, &mut out);
        if out == vals[start..start + len] {
            Ok(())
        } else {
            Err(format!("range [{start}, {start}+{len}) mismatch"))
        }
    });
}
