//! Socket-level integration tests for the observability subsystem,
//! driven against a **packed RWKVQ2 store**, proving the acceptance
//! criteria of the observability PR:
//!
//! 1. the per-request spans served by `GET /admin/trace/{id}` tile the
//!    request: their durations sum to the gateway-reported end-to-end
//!    latency (queued + latency) within 5%,
//! 2. the per-kernel matvec attribution families appear on `/metrics`
//!    with nonzero Sq/Vq/DenseF16 counts after traffic over the packed
//!    store, and the whole exposition passes the Prometheus linter,
//! 3. `GET /admin/inflight` reports a live sequence mid-decode and
//!    empties once it retires,
//! 4. tracing never perturbs tokens: a gateway with tracing on is
//!    token-identical to one with tracing off and to the in-process
//!    twin, and the off gateway serves no spans.

use rwkvquant::config::{ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{serve_collect, Decoder, Request, RunnerDecoder};
use rwkvquant::model::rwkv::init_params;
use rwkvquant::model::QuantizedModel;
use rwkvquant::report::json::Json;
use rwkvquant::server::gateway::{sse_tokens, tokens_json};
use rwkvquant::server::http::http_request;
use rwkvquant::server::metrics::lint_exposition;
use rwkvquant::server::{Gateway, GatewayConfig};
use rwkvquant::util::rng::Rng;
use std::time::{Duration, Instant};

/// Quantize a synthetic model, round-trip it through an RWKVQ2
/// checkpoint, and serve from the reopened (packed) store. The span
/// tiling test needs real per-token compute, so the dims are dialled
/// by the caller.
fn packed_store(tag: &str, cfg: &ModelConfig, seed: u64) -> QuantizedModel {
    let m = init_params(cfg, &mut Rng::new(seed));
    let qc = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 2);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    let path = std::env::temp_dir().join(format!("obs_{tag}.rwkvq2"));
    qm.save(&path).unwrap();
    let opened = QuantizedModel::open(&path).unwrap();
    std::fs::remove_file(path).ok();
    opened
}

fn twin_tokens(qm: &QuantizedModel, prompt: &[usize], gen_len: usize) -> Vec<usize> {
    let mut dec = RunnerDecoder::new(qm);
    let (_, resp) = serve_collect(
        &mut dec,
        vec![Request::new(0, prompt.to_vec(), gen_len)],
        1,
        Duration::from_millis(0),
    )
    .unwrap();
    resp[0].tokens.clone()
}

/// Decoder wrapper that sleeps per step so a request stays in flight
/// long enough for `/admin/inflight` to observe it.
struct Throttled<'a> {
    inner: RunnerDecoder<'a, QuantizedModel>,
    delay: Duration,
}

impl Decoder for Throttled<'_> {
    fn reset(&mut self) {
        self.inner.reset();
    }

    fn step(&mut self, token: usize) -> Vec<f32> {
        std::thread::sleep(self.delay);
        self.inner.step(token)
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn save_state(&self) -> Vec<Vec<f32>> {
        self.inner.save_state()
    }

    fn load_state(&mut self, state: &[Vec<f32>]) {
        self.inner.load_state(state);
    }
}

struct ShutdownOnDrop(rwkvquant::server::GatewayHandle);

impl Drop for ShutdownOnDrop {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

/// `Gateway::serve` toggles the process-global kernel-attribution
/// switch from its `trace` flag, so the test that asserts nonzero
/// counts and the test that runs an untraced gateway must not overlap.
static KSTATS_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Sum the values of every series of a labeled family.
fn family_sum(text: &str, family: &str) -> f64 {
    text.lines()
        .filter(|l| l.starts_with(family) && !l.starts_with('#'))
        .filter_map(|l| l.rsplit(' ').next()?.parse::<f64>().ok())
        .sum()
}

#[test]
fn trace_spans_tile_the_request_and_kernels_are_attributed() {
    let _gate = KSTATS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    // large enough that per-tick compute dwarfs the serve loop's
    // per-iteration bookkeeping — the 5% criterion measures real work
    let qm = packed_store("tile", &ModelConfig::rwkv6(2, 192, 512), 83);
    assert!(qm.n_packed() > 0, "the store must actually serve packed payloads");
    let vocab = qm.config.vocab;
    let prompt: Vec<usize> = (0..24).map(|i| (i * 7 + 3) % vocab).collect();
    let gen_len = 48usize;

    let mut cfg = GatewayConfig::new("127.0.0.1:0");
    cfg.max_batch = 2;
    cfg.prefill_chunk = 8;
    assert!(cfg.trace, "tracing must default on");
    let gateway = Gateway::bind(cfg, vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut decoders = vec![RunnerDecoder::new(&qm)];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());

        let body = format!(
            "{{\"prompt\":{},\"gen_len\":{gen_len},\"stream\":false}}",
            tokens_json(&prompt)
        );
        let resp = http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        let id = parsed.get("id").and_then(Json::as_usize).unwrap();
        let queued_ms = parsed.get("queued_ms").and_then(Json::as_f64).unwrap();
        let latency_ms = parsed.get("latency_ms").and_then(Json::as_f64).unwrap();
        let e2e_us = (queued_ms + latency_ms) * 1e3;

        // the recorded spans tile the request end to end (5% criterion)
        let trace = http_request(addr, "GET", &format!("/admin/trace/{id}"), None).unwrap();
        assert_eq!(trace.status, 200, "{}", trace.body_str());
        let tr = rwkvquant::server::json::parse(&trace.body_str()).unwrap();
        assert_eq!(tr.get("id").and_then(Json::as_usize), Some(id));
        let spans = tr.get("spans").and_then(Json::as_array).unwrap();
        let total_us = tr.get("total_us").and_then(Json::as_f64).unwrap();
        let sum_us: f64 = spans
            .iter()
            .map(|sp| sp.get("dur_us").and_then(Json::as_f64).unwrap())
            .sum();
        assert_eq!(sum_us, total_us, "total_us must be the span-duration sum");
        let diff = (sum_us - e2e_us).abs();
        assert!(
            diff <= e2e_us * 0.05 + 2_000.0,
            "span sum {sum_us}us vs e2e {e2e_us}us (diff {diff}us > 5%)\n{}",
            trace.body_str()
        );

        // stage inventory: queued on the control lane (-1), prefill
        // ticks for the 24-token prompt, then sample+decode per token
        let stage_of = |sp: &Json| sp.get("stage").and_then(Json::as_str).unwrap().to_string();
        let queue: Vec<&Json> = spans.iter().filter(|sp| stage_of(sp) == "queue").collect();
        assert_eq!(queue.len(), 1);
        assert_eq!(queue[0].get("lane").and_then(Json::as_f64), Some(-1.0));
        let n_prefill = spans.iter().filter(|sp| stage_of(sp) == "prefill").count();
        assert_eq!(n_prefill, 3, "24-token prompt at chunk 8 must prefill in 3 ticks");
        let n_decode = spans.iter().filter(|sp| stage_of(sp) == "decode").count();
        let n_sample = spans.iter().filter(|sp| stage_of(sp) == "sample").count();
        assert_eq!(n_decode, gen_len);
        assert_eq!(n_sample, gen_len);

        // unknown / malformed ids are clean errors
        let miss = http_request(addr, "GET", "/admin/trace/999999999", None).unwrap();
        assert_eq!(miss.status, 404);
        let bad = http_request(addr, "GET", "/admin/trace/abc", None).unwrap();
        assert_eq!(bad.status, 400);

        // per-kernel attribution on /metrics: the packed store decodes
        // through Sq + Vq + the DenseF16 head, so all three ops count
        let text = http_request(addr, "GET", "/metrics", None).unwrap().body_str().into_owned();
        for op in ["sq", "vq", "f16"] {
            let calls = family_sum(&text, &format!("rwkvquant_kernel_matvec_calls_total{{op=\"{op}\""));
            assert!(calls > 0.0, "no {op} matvecs attributed:\n{text}");
        }
        assert!(
            family_sum(&text, "rwkvquant_kernel_matvec_seconds_total{") > 0.0,
            "kernel seconds stayed zero:\n{text}"
        );
        // the live exposition passes the Prometheus lint used in CI
        assert_eq!(lint_exposition(&text), Vec::<String>::new());

        handle.shutdown();
        server.join().unwrap().unwrap();
    });
}

#[test]
fn admin_inflight_sees_the_sequence_then_empties() {
    let qm = packed_store("inflight", &ModelConfig::rwkv6(1, 16, 32), 89);
    let vocab = qm.config.vocab;
    let cfg = GatewayConfig::new("127.0.0.1:0");
    let gateway = Gateway::bind(cfg, vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let gen_len = 30usize;
    let mut decoders =
        vec![Throttled { inner: RunnerDecoder::new(&qm), delay: Duration::from_millis(3) }];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());
        let client = s.spawn(move || {
            let body = format!("{{\"prompt\":[3,1,4],\"gen_len\":{gen_len}}}");
            http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap()
        });

        // poll until the sequence shows up mid-flight
        let t0 = Instant::now();
        let seq = loop {
            assert!(t0.elapsed() < Duration::from_secs(10), "sequence never appeared");
            let resp = http_request(addr, "GET", "/admin/inflight", None).unwrap();
            assert_eq!(resp.status, 200);
            let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
            let seqs = parsed.get("sequences").and_then(Json::as_array).unwrap();
            if let Some(sq) = seqs.first() {
                break sq.clone();
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(seq.get("model").and_then(Json::as_str), Some("rwkvquant"));
        assert_eq!(seq.get("prompt_len").and_then(Json::as_usize), Some(3));
        assert_eq!(seq.get("gen_len").and_then(Json::as_usize), Some(gen_len));
        let stage = seq.get("stage").and_then(Json::as_str).unwrap();
        assert!(
            ["prefill", "decode", "parked"].contains(&stage),
            "unexpected stage '{stage}'"
        );
        assert!(seq.get("age_ms").and_then(Json::as_f64).unwrap() >= 0.0);

        // once the stream retires the listing empties again
        let resp = client.join().unwrap();
        assert_eq!(resp.status, 200);
        let t0 = Instant::now();
        loop {
            let text = http_request(addr, "GET", "/admin/inflight", None).unwrap().body_str().into_owned();
            let parsed = rwkvquant::server::json::parse(&text).unwrap();
            if parsed.get("sequences").and_then(Json::as_array).unwrap().is_empty() {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "sequence never retired: {text}");
            std::thread::sleep(Duration::from_millis(2));
        }

        handle.shutdown();
        server.join().unwrap().unwrap();
    });
}

#[test]
fn tracing_on_and_off_are_token_identical_to_the_twin() {
    let _gate = KSTATS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let qm = packed_store("twin", &ModelConfig::rwkv6(1, 16, 32), 97);
    let vocab = qm.config.vocab;
    let prompt = vec![5usize, 2, 9];
    let gen_len = 8usize;
    let want = twin_tokens(&qm, &prompt, gen_len);

    let mut streamed = Vec::new();
    for trace in [true, false] {
        let mut cfg = GatewayConfig::new("127.0.0.1:0");
        cfg.trace = trace;
        let gateway = Gateway::bind(cfg, vocab).unwrap();
        let addr = gateway.local_addr();
        let handle = gateway.handle();
        let mut decoders = vec![RunnerDecoder::new(&qm)];
        std::thread::scope(|s| {
            let server = s.spawn(|| gateway.serve(&mut decoders));
            let _drain = ShutdownOnDrop(handle.clone());
            let body = format!("{{\"prompt\":{},\"gen_len\":{gen_len}}}", tokens_json(&prompt));
            let resp = http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
            assert_eq!(resp.status, 200, "{}", resp.body_str());
            streamed.push(sse_tokens(&resp.body_str()).unwrap());
            if !trace {
                // the untraced gateway retains no spans: request 0 404s
                let miss = http_request(addr, "GET", "/admin/trace/0", None).unwrap();
                assert_eq!(miss.status, 404, "{}", miss.body_str());
            }
            handle.shutdown();
            server.join().unwrap().unwrap();
        });
    }
    assert_eq!(streamed[0], want, "traced gateway diverged from the twin");
    assert_eq!(streamed[1], want, "untraced gateway diverged from the twin");
}
