//! Property tests for the packed serving path: a `QuantizedModel`
//! (matmuls served from packed payloads through `LinearOp`) must produce
//! the same forward pass as dequantize-then-dense-run, across SQ, VQ,
//! AWQ and hybrid configurations.

use rwkvquant::calib::CalibSet;
use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::eval::dequantized_model;
use rwkvquant::model::rwkv::RwkvRunner;
use rwkvquant::model::synthetic::{generate_rwkv, Family};
use rwkvquant::model::{ModelWeights, QuantizedModel, WeightProvider};

fn small_model(seed: u64) -> ModelWeights {
    generate_rwkv(&ModelConfig::rwkv6(2, 32, 64), Family::Rwkv, seed)
}

fn cfg_for(method: Method) -> QuantConfig {
    QuantConfig {
        method,
        kmeans_iters: 4,
        vq_bits: 6,
        calib_samples: 32,
        ..QuantConfig::default()
    }
}

/// Max |Δlogit| between the packed-path runner and the dequantized dense
/// runner over a short probe sequence.
fn packed_vs_dense_gap(m: &ModelWeights, method: Method, with_calib: bool) -> (f32, usize) {
    let cfg = cfg_for(method);
    let calib = if with_calib { Some(CalibSet::synthetic(m, 24, 7)) } else { None };
    let (q, _) = quantize_model(m, calib.as_ref(), &cfg, 2);
    let qm = QuantizedModel::from_parts(m, &q);
    let dq = dequantized_model(m, &q);
    let mut packed = RwkvRunner::new(&qm);
    let mut dense = RwkvRunner::new(&dq);
    let mut worst = 0.0f32;
    for t in [1usize, 9, 33, 2, 61, 17, 5, 40] {
        let a = packed.forward_token(t);
        let b = dense.forward_token(t);
        assert_eq!(a.len(), b.len());
        for c in 0..a.len() {
            assert!(a[c].is_finite(), "{method:?}: non-finite logit");
            worst = worst.max((a[c] - b[c]).abs());
        }
    }
    (worst, qm.n_packed())
}

#[test]
fn packed_matches_dense_for_sq_rtn() {
    let m = small_model(1);
    let (gap, packed) = packed_vs_dense_gap(&m, Method::Rtn, false);
    assert!(packed > 0);
    assert!(gap < 1e-2, "RTN packed vs dense gap {gap}");
}

#[test]
fn packed_matches_dense_for_sq_gptq_with_calib() {
    let m = small_model(2);
    let (gap, packed) = packed_vs_dense_gap(&m, Method::Gptq, true);
    assert!(packed > 0);
    assert!(gap < 1e-2, "GPTQ packed vs dense gap {gap}");
}

#[test]
fn packed_matches_dense_for_awq_col_inv_scale() {
    // AWQ produces col_inv_scale layers — the folded-scale kernel path
    let m = small_model(3);
    let (gap, packed) = packed_vs_dense_gap(&m, Method::Awq, true);
    assert!(packed > 0);
    assert!(gap < 1e-2, "AWQ packed vs dense gap {gap}");
}

#[test]
fn packed_matches_dense_for_vq_kmeans() {
    let m = small_model(4);
    let (gap, packed) = packed_vs_dense_gap(&m, Method::KMeans, false);
    assert!(packed > 0);
    assert!(gap < 1e-2, "kMeans packed vs dense gap {gap}");
}

#[test]
fn packed_matches_dense_for_hybrid() {
    let m = small_model(5);
    let (gap, packed) = packed_vs_dense_gap(&m, Method::RwkvQuant, true);
    assert!(packed > 0);
    assert!(gap < 1e-2, "hybrid packed vs dense gap {gap}");
}

#[test]
fn quarot_serves_identically_via_dense_fallback() {
    // QuaRot rotations cannot run fused; the provider must fall back to
    // the dequantized dense copy and match it exactly.
    let m = small_model(6);
    let cfg = cfg_for(Method::QuaRot);
    let (q, _) = quantize_model(&m, None, &cfg, 2);
    let qm = QuantizedModel::from_parts(&m, &q);
    assert_eq!(qm.n_packed(), 0, "rotated layers must not be packed");
    let dq = dequantized_model(&m, &q);
    let mut served = RwkvRunner::new(&qm);
    let mut dense = RwkvRunner::new(&dq);
    for t in [1usize, 50, 8] {
        assert_eq!(served.forward_token(t), dense.forward_token(t));
    }
}

#[test]
fn packed_eval_harness_agrees_with_dense() {
    // ppl on the packed path vs the dequantized model — same numbers
    // within fp tolerance, no dense materialisation on the packed side
    let m = small_model(7);
    let cfg = cfg_for(Method::RwkvQuant);
    let (q, _) = quantize_model(&m, None, &cfg, 2);
    let qm = QuantizedModel::from_parts(&m, &q);
    let dq = dequantized_model(&m, &q);
    let toks: Vec<usize> = (0..60).map(|i| (i * 11) % 64).collect();
    let a = rwkvquant::eval::ppl::perplexity(&qm, &toks);
    let b = rwkvquant::eval::ppl::perplexity(&dq, &toks);
    assert!((a - b).abs() / b < 1e-3, "packed ppl {a} vs dense ppl {b}");
}

#[test]
fn served_storage_is_much_smaller_than_dense() {
    let m = small_model(8);
    let cfg = cfg_for(Method::RwkvQuant);
    let (q, _) = quantize_model(&m, None, &cfg, 2);
    let qm = QuantizedModel::from_parts(&m, &q);
    // quantizable weights dominate this shape; the served footprint must
    // be far below fp32 while embeddings/norms stay dense
    let dense_bits = m.served_storage_bits();
    let served_bits = qm.served_storage_bits();
    assert!(
        (served_bits as f64) < dense_bits as f64 * 0.7,
        "served {served_bits} vs dense {dense_bits}"
    );
    assert!(qm.packed_bpw() < 8.0);
}
