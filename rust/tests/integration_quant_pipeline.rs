//! Integration: the full quantization pipeline over synthetic models —
//! proxies → τ calibration → hybrid quantization → reconstruction, with
//! calibration captured from the real Rust forward.

use rwkvquant::calib::CalibSet;
use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::data::Corpus;
use rwkvquant::eval::{dequantized_model, output_divergence};
use rwkvquant::model::synthetic::{generate_rwkv, Family};
use rwkvquant::quant::hybrid::Choice;

fn model_and_calib() -> (rwkvquant::model::ModelWeights, CalibSet, Corpus) {
    let cfg = ModelConfig::rwkv6(2, 64, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 77);
    let corpus = Corpus::build(128, 2000, 800, 3);
    let calib = CalibSet::from_corpus(&m, &corpus, 64, 16, 9);
    (m, calib, corpus)
}

#[test]
fn hybrid_with_calibration_end_to_end() {
    let (m, calib, _corpus) = model_and_calib();
    let cfg = QuantConfig { kmeans_iters: 8, ..QuantConfig::default() };
    let (q, rep) = quantize_model(&m, Some(&calib), &cfg, 0);

    // every quantizable layer quantized, at a sane bpw
    assert_eq!(q.len(), m.quantizable_indices().len());
    assert!(rep.avg_bpw < 4.0, "bpw {}", rep.avg_bpw);

    // reconstruction is usable: output divergence is finite and bounded
    let dq = dequantized_model(&m, &q);
    let probes = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
    let d = output_divergence(&m, &dq, &probes);
    assert!(d.is_finite() && d < 10.0, "divergence {d}");
}

#[test]
fn hybrid_beats_pure_sq_and_pure_vq_on_rwkv_family() {
    let (m, calib, _corpus) = model_and_calib();
    let probes: Vec<Vec<usize>> = (0..4)
        .map(|i| (0..12).map(|j| (i * 13 + j * 7) % 128).collect())
        .collect();

    let run = |method: Method| {
        let cfg = QuantConfig {
            method,
            kmeans_iters: 8,
            ..QuantConfig::baseline(method, 3.25)
        };
        let cfg = if method == Method::RwkvQuant {
            QuantConfig { method, kmeans_iters: 8, ..QuantConfig::default() }
        } else {
            cfg
        };
        let (q, _) = quantize_model(&m, Some(&calib), &cfg, 0);
        output_divergence(&m, &dequantized_model(&m, &q), &probes)
    };

    let ours = run(Method::RwkvQuant);
    let rtn = run(Method::Rtn);
    // the hybrid must not be worse than the weakest baseline
    assert!(
        ours <= rtn * 1.2,
        "hybrid divergence {ours} should be competitive with RTN {rtn}"
    );
}

#[test]
fn elementwise_layers_get_vq_when_chosen() {
    let (m, calib, _corpus) = model_and_calib();
    let cfg = QuantConfig {
        // force everything to VQ: μ layers must flow through §3.2
        tau_c: Some(-1.0),
        tau_f: Some(-1.0),
        kmeans_iters: 8,
        ..QuantConfig::default()
    };
    let (q, rep) = quantize_model(&m, Some(&calib), &cfg, 0);
    assert!(rep.layers.iter().all(|l| l.choice == Some(Choice::Vq)));
    for (name, layer) in &q {
        assert!(layer.is_vq(), "{name} should be VQ");
    }
}

#[test]
fn report_layers_cover_model_in_order() {
    let (m, calib, _corpus) = model_and_calib();
    let cfg = QuantConfig { method: Method::Gptq, kmeans_iters: 5, ..Default::default() };
    let (_, rep) = quantize_model(&m, Some(&calib), &cfg, 3);
    let expect: Vec<String> = m
        .quantizable_indices()
        .iter()
        .map(|&i| m.layers[i].0.name.clone())
        .collect();
    let got: Vec<String> = rep.layers.iter().map(|l| l.name.clone()).collect();
    assert_eq!(got, expect);
}
