//! Property tests on coordinator invariants: batching admission,
//! pipeline coverage/accounting, and τ-calibration consistency.

use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::batcher::DynamicBatcher;
use rwkvquant::coordinator::quantize_model;
use rwkvquant::model::synthetic::{generate_rwkv, Family};
use rwkvquant::quant::hybrid::{calibrate_taus, decide, Choice};
use rwkvquant::quant::proxy::ProxyPair;
use rwkvquant::util::ptest::{check, Gen};
use std::time::{Duration, Instant};

#[test]
fn prop_batcher_never_exceeds_limits_or_reorders() {
    check("batcher: FIFO, ≤ max_batch, ≤ slots, no loss", 50, |g| {
        let max_batch = 1 + g.rng().below(8);
        let mut b: DynamicBatcher<usize> =
            DynamicBatcher::new(max_batch, Duration::from_millis(0));
        let n = g.usize_in(1..40);
        let t = Instant::now();
        for i in 0..n {
            b.push(i, t);
        }
        let mut drained = Vec::new();
        let mut guard = 0;
        while b.queue_len() > 0 {
            let slots = 1 + g.rng().below(max_batch + 2);
            let batch = b.admit(slots, t + Duration::from_millis(1));
            if batch.len() > slots.min(max_batch) {
                return Err(format!("admitted {} > limit", batch.len()));
            }
            drained.extend(batch.into_iter().map(|p| p.item));
            guard += 1;
            if guard > 1000 {
                return Err("no progress".into());
            }
        }
        if drained == (0..n).collect::<Vec<_>>() {
            Ok(())
        } else {
            Err(format!("reordered or lost: {drained:?}"))
        }
    });
}

#[test]
fn prop_tau_calibration_share_within_one_layer() {
    check("calibrated SQ share within 1/M of target", 40, |g| {
        let m = g.usize_in(10..200).max(10);
        let proxies: Vec<ProxyPair> = (0..m)
            .map(|_| ProxyPair {
                p_c: g.rng().gamma(2.0, 0.6),
                p_f: g.rng().gamma(2.0, 15.0),
            })
            .collect();
        let frac = *g.choose(&[0.5f64, 0.8, 0.9, 1.0]);
        let cal = calibrate_taus(&proxies, frac);
        let tol = 1.5 / m as f64 + 0.02;
        if (cal.sq_share - frac).abs() <= tol {
            Ok(())
        } else {
            Err(format!("share {} target {frac} (m={m})", cal.sq_share))
        }
    });
}

#[test]
fn prop_decide_consistent_with_calibration() {
    check("decide() reproduces the calibrated share exactly", 30, |g| {
        let m = g.usize_in(5..120).max(5);
        let proxies: Vec<ProxyPair> = (0..m)
            .map(|_| ProxyPair {
                p_c: g.rng().gamma(1.5, 1.0),
                p_f: g.rng().gamma(1.5, 20.0),
            })
            .collect();
        let cal = calibrate_taus(&proxies, 0.85);
        let share = proxies
            .iter()
            .filter(|&&p| decide(p, cal.tau_c, cal.tau_f) == Choice::Sq)
            .count() as f64
            / m as f64;
        if (share - cal.sq_share).abs() < 1e-12 {
            Ok(())
        } else {
            Err(format!("{share} vs {}", cal.sq_share))
        }
    });
}

#[test]
fn prop_pipeline_covers_all_layers_any_worker_count() {
    check("pipeline covers every quantizable layer", 6, |g| {
        let cfg = ModelConfig::rwkv6(1, 32, 64);
        let m = generate_rwkv(&cfg, Family::Rwkv, g.seed());
        let workers = 1 + g.rng().below(6);
        let qc = QuantConfig {
            method: *g.choose(&[Method::Rtn, Method::Gptq, Method::RwkvQuant]),
            kmeans_iters: 3,
            seed: g.seed(),
            ..Default::default()
        };
        let (q, rep) = quantize_model(&m, None, &qc, workers);
        let want = m.quantizable_indices().len();
        if q.len() != want {
            return Err(format!("{} layers quantized, want {want}", q.len()));
        }
        // bpw accounting consistent with per-layer storage
        let bits: usize = q.values().map(|l| l.storage_bits()).sum();
        let numel: usize = q.values().map(|l| l.numel()).sum();
        let bpw = bits as f64 / numel as f64;
        if (bpw - rep.avg_bpw).abs() > 1e-9 {
            return Err(format!("report bpw {} != recomputed {bpw}", rep.avg_bpw));
        }
        Ok(())
    });
}
