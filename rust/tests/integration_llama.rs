//! Cross-architecture parity: a small LLaMA config runs the full
//! quantize → RWKVQ2 pack → serve path and must emit greedy tokens
//! identical to its dense twin — the same identity contract the RWKV
//! stores are held to, through the identical tick machinery
//! (`decoder_for` dispatches on the store's arch header).

use rwkvquant::config::{ModelConfig, QuantConfig};
use rwkvquant::coordinator::edge::EdgeSession;
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{decoder_for, serve_collect, Request};
use rwkvquant::model::llama::init_params;
use rwkvquant::model::store::LoadMode;
use rwkvquant::model::{QuantizedModel, WeightProvider};
use rwkvquant::util::rng::Rng;
use std::collections::HashMap;
use std::time::Duration;

const VOCAB: usize = 48;

fn small_llama() -> rwkvquant::model::ModelWeights {
    init_params(&ModelConfig::llama(2, 16, VOCAB), &mut Rng::new(2025))
}

fn greedy_requests() -> Vec<Request> {
    (0..6u64)
        .map(|id| Request::new(id, vec![(id as usize * 7 + 1) % VOCAB, 2, 5], 8))
        .collect()
}

/// Serve the fixed greedy request set through the arch-dispatched
/// decoder and return each request's generated tokens (sorted by id).
fn serve_tokens<W: WeightProvider>(w: &W) -> Vec<Vec<usize>> {
    let mut dec = decoder_for(w).unwrap();
    let (stats, resp) =
        serve_collect(&mut dec, greedy_requests(), 4, Duration::from_millis(1)).unwrap();
    assert_eq!(stats.completed, 6);
    resp.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn dense_twin_and_all_dense_pack_serve_identical_tokens() {
    // a QuantizedModel with zero quantized layers is the dense model in
    // the serving container — the twin must be exactly token-identical
    let m = small_llama();
    let twin = QuantizedModel::from_parts(&m, &HashMap::new());
    assert_eq!(serve_tokens(&m), serve_tokens(&twin));
}

#[test]
fn packed_llama_roundtrips_token_identical_through_disk() {
    // the real pipeline: proxy-guided hybrid quantization, f16 dense
    // narrowing, RWKVQ2 serialization — the in-memory pack and every
    // reopened form (buffered read, auto/mmap, raw bytes) must serve
    // the same greedy tokens
    let m = small_llama();
    let qc = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 2);
    assert!(!q.is_empty(), "hybrid must quantize some llama layers");
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    let reference = serve_tokens(&qm);

    let path = std::env::temp_dir().join("itest_llama_pack.rwkvq2");
    qm.save(&path).unwrap();
    for mode in [LoadMode::Buffered, LoadMode::Auto] {
        let back = QuantizedModel::open_with(&path, mode).unwrap();
        assert_eq!(back.config.arch, "llama", "arch survives the pack header");
        assert_eq!(serve_tokens(&back), reference, "mode {mode:?}");
    }
    let bytes = std::fs::read(&path).unwrap();
    let from_bytes = QuantizedModel::open_bytes(&bytes).unwrap();
    assert_eq!(serve_tokens(&from_bytes), reference, "bytes loader");
    std::fs::remove_file(path).ok();
}

#[test]
fn edge_session_matches_native_serve_greedy_tokens() {
    // the wasm-shaped path (bytes in, sequential EdgeSession decode) and
    // the native serve loop must agree token-for-token
    let m = small_llama();
    let qc = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 2);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    let path = std::env::temp_dir().join("itest_llama_edge.rwkvq2");
    qm.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let edge_model = QuantizedModel::open_bytes(&bytes).unwrap();
    let mut edge = EdgeSession::new(&edge_model).unwrap();
    let native = serve_tokens(&qm);
    for (i, req) in greedy_requests().into_iter().enumerate() {
        edge.reset();
        assert_eq!(edge.generate(&req.prompt, 8), native[i], "request {i}");
    }
}
