//! Property tests over the quantization engines (via the in-repo
//! `ptest` mini-framework; proptest is not in the offline vendor set).

use rwkvquant::config::{Method, QuantConfig};
use rwkvquant::quant::hybrid::quantize_with_method;
use rwkvquant::quant::{sq, vq, LayerKind, QuantizedLayer};
use rwkvquant::tensor::Matrix;
use rwkvquant::util::ptest::{check, Gen};
use rwkvquant::util::rng::Rng;

fn gen_weight(g: &mut Gen) -> Matrix {
    let rows = g.usize_in(2..24);
    let cols = *g.choose(&[16usize, 32, 64]);
    let std = g.f32_in(0.005..0.3);
    let mut m = Matrix::zeros(rows, cols);
    g.rng().fill_normal(&mut m.data, 0.0, std);
    if g.prob(0.3) {
        // inject outliers
        for _ in 0..(m.numel() / 50).max(1) {
            let i = g.rng().below(m.numel());
            m.data[i] = g.rng().normal_ms(0.0, std as f64 * 20.0) as f32;
        }
    }
    m
}

#[test]
fn prop_rtn_error_bounded_by_grid_step() {
    check("rtn error ≤ s/2 per element", 40, |g| {
        let w = gen_weight(g);
        let bits = *g.choose(&[3u32, 4, 8]);
        let group = *g.choose(&[16usize, 32]);
        let q = sq::rtn::quantize(&w, bits, group);
        let deq = q.dequantize();
        for i in 0..w.numel() {
            let grp = i / q.group_size;
            let tol = q.scales[grp] * 0.5 + 1e-6;
            let err = (deq.data[i] - w.data[i]).abs();
            if err > tol {
                return Err(format!("elem {i}: err {err} > tol {tol}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_every_method_reconstructs_finite_same_shape() {
    check("all methods finite + shape-preserving", 20, |g| {
        let w = gen_weight(g);
        let method = *g.choose(Method::all_baselines());
        let cfg = QuantConfig {
            method,
            kmeans_iters: 4,
            seed: g.seed(),
            ..Default::default()
        };
        let mut rng = Rng::new(g.seed());
        let q = quantize_with_method(&w, LayerKind::MatMul, method, None, &cfg, &mut rng);
        let deq = q.dequantize();
        if (deq.rows, deq.cols) != (w.rows, w.cols) {
            return Err(format!("{method:?} changed shape"));
        }
        if !deq.data.iter().all(|v| v.is_finite()) {
            return Err(format!("{method:?} produced non-finite values"));
        }
        Ok(())
    });
}

#[test]
fn prop_more_bits_never_much_worse() {
    check("sq error decreases with bits", 25, |g| {
        let w = gen_weight(g);
        let e3 = QuantizedLayer::Sq(sq::rtn::quantize(&w, 3, 32)).mse(&w);
        let e6 = QuantizedLayer::Sq(sq::rtn::quantize(&w, 6, 32)).mse(&w);
        if e6 <= e3 * 1.01 + 1e-12 {
            Ok(())
        } else {
            Err(format!("e6 {e6} > e3 {e3}"))
        }
    });
}

#[test]
fn prop_vq_bpw_within_budget() {
    check("vq bpw ≤ k/d + codebook + 1", 25, |g| {
        let w = gen_weight(g);
        let k = *g.choose(&[6u32, 8, 12]);
        let mut rng = Rng::new(g.seed());
        let q = vq::kmeans::quantize(&w, k, 4, 4, &mut rng);
        let payload = q.k as f64 / q.d as f64;
        let codebook = (q.codebook.len() * 16) as f64 / q.numel() as f64;
        let expect = payload + codebook + (q.tail.len() * 16) as f64 / q.numel() as f64;
        if (q.bpw() - expect).abs() < 1e-9 {
            Ok(())
        } else {
            Err(format!("bpw {} != expected {expect}", q.bpw()))
        }
    });
}

#[test]
fn prop_quantized_storage_below_fp16() {
    check("storage strictly below fp16 for 3-bit configs", 20, |g| {
        let w = gen_weight(g);
        let q = sq::rtn::quantize(&w, 3, 32);
        if q.storage_bits() < w.numel() * 16 {
            Ok(())
        } else {
            Err(format!("{} bits vs fp16 {}", q.storage_bits(), w.numel() * 16))
        }
    });
}

#[test]
fn prop_gptq_identity_hessian_equals_column_independence() {
    check("gptq(no calib) error within 2x of rtn", 15, |g| {
        let w = gen_weight(g);
        let gq = QuantizedLayer::Sq(sq::gptq::quantize(&w, 4, 32, None, 0.01)).mse(&w);
        let rt = QuantizedLayer::Sq(sq::rtn::quantize(&w, 4, 32)).mse(&w);
        if gq <= rt * 2.0 + 1e-12 {
            Ok(())
        } else {
            Err(format!("gptq {gq} vs rtn {rt}"))
        }
    });
}
