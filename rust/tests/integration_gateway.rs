//! Socket-level integration tests for the HTTP serving gateway, driven
//! against a **packed RWKVQ2 store** (quantize → save → zero-copy open),
//! proving the acceptance criteria of the gateway PR:
//!
//! 1. tokens streamed over HTTP for concurrent connections are
//!    token-identical to the in-process `serve_collect` twin,
//! 2. requests beyond `max_queue` are shed with a 429 and counted in
//!    `/metrics`,
//! 3. SIGTERM drains in-flight requests to completion (exit path
//!    returns cleanly, nothing is cut off mid-stream),
//! 4. the OpenAI text endpoints (`/v1/completions`,
//!    `/v1/chat/completions`) are token-identical to `/v1/generate` at
//!    temperature 0, byte-reproducible under a fixed sampling seed,
//!    honour `stop`/`max_tokens` with the right `finish_reason`, and
//!    cancel mid-decode when the client disconnects.

use rwkvquant::config::{ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{serve_collect, Decoder, Request, RunnerDecoder};
use rwkvquant::data::tokenizer::Tokenizer;
use rwkvquant::model::rwkv::init_params;
use rwkvquant::model::QuantizedModel;
use rwkvquant::report::json::Json;
use rwkvquant::server::gateway::{sse_data, sse_tokens, tokens_json};
use rwkvquant::server::http::http_request;
use rwkvquant::server::{Gateway, GatewayConfig};
use rwkvquant::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Barrier;
use std::time::{Duration, Instant};

/// Quantize a tiny synthetic model, round-trip it through an RWKVQ2
/// checkpoint, and serve from the reopened (packed) store.
fn packed_store(tag: &str, seed: u64) -> QuantizedModel {
    let m = init_params(&ModelConfig::rwkv6(1, 16, 32), &mut Rng::new(seed));
    let qc = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 2);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    let path = std::env::temp_dir().join(format!("gateway_{tag}.rwkvq2"));
    qm.save(&path).unwrap();
    let opened = QuantizedModel::open(&path).unwrap();
    std::fs::remove_file(path).ok();
    opened
}

/// Greedy tokens for one prompt through the in-process serve loop — the
/// twin every HTTP stream must match (greedy decoding is deterministic
/// and batching-independent, as the serve tests assert).
fn twin_tokens(qm: &QuantizedModel, prompt: &[usize], gen_len: usize) -> Vec<usize> {
    let mut dec = RunnerDecoder::new(qm);
    let (_, resp) = serve_collect(
        &mut dec,
        vec![Request::new(0, prompt.to_vec(), gen_len)],
        1,
        Duration::from_millis(0),
    )
    .unwrap();
    resp[0].tokens.clone()
}

/// Decoder wrapper that sleeps per step so requests overlap reliably
/// (tiny models decode too fast to build a queue otherwise).
struct Throttled<'a> {
    inner: RunnerDecoder<'a, QuantizedModel>,
    delay: Duration,
}

impl Decoder for Throttled<'_> {
    fn reset(&mut self) {
        self.inner.reset();
    }

    fn step(&mut self, token: usize) -> Vec<f32> {
        std::thread::sleep(self.delay);
        self.inner.step(token)
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn save_state(&self) -> Vec<Vec<f32>> {
        self.inner.save_state()
    }

    fn load_state(&mut self, state: &[Vec<f32>]) {
        self.inner.load_state(state);
    }
}

/// Requests a gateway drain when dropped, so a failing assertion inside
/// a `thread::scope` unwinds into a shutdown instead of hanging the
/// scope's join on a server thread that never exits.
struct ShutdownOnDrop(rwkvquant::server::GatewayHandle);

impl Drop for ShutdownOnDrop {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        l.strip_prefix(name)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse().ok())
    })
}

#[test]
fn concurrent_http_streams_match_the_in_process_twin() {
    let qm = packed_store("twin", 41);
    assert!(qm.n_packed() > 0, "the store must actually serve packed payloads");
    let prompts: Vec<Vec<usize>> = vec![vec![3, 1, 4], vec![7, 7, 2], vec![9, 2, 1, 5]];
    let gen_len = 6usize;
    let twins: Vec<Vec<usize>> =
        prompts.iter().map(|p| twin_tokens(&qm, p, gen_len)).collect();

    let mut cfg = GatewayConfig::new("127.0.0.1:0");
    cfg.max_batch = 4;
    let gateway = Gateway::bind(cfg, qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut decoders = vec![RunnerDecoder::new(&qm), RunnerDecoder::new(&qm)];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());

        // basic endpoints answer while serving
        let health = http_request(addr, "GET", "/healthz", None).unwrap();
        assert_eq!(health.status, 200);
        assert_eq!(health.body_str().as_ref(), "ok\n");
        let miss = http_request(addr, "GET", "/nope", None).unwrap();
        assert_eq!(miss.status, 404);
        let wrong = http_request(addr, "GET", "/v1/generate", None).unwrap();
        assert_eq!(wrong.status, 405);

        // ≥ 2 concurrent streaming connections (acceptance criterion)
        let streamed: Vec<Vec<usize>> = std::thread::scope(|cs| {
            let clients: Vec<_> = prompts
                .iter()
                .map(|p| {
                    cs.spawn(move || {
                        let body = format!(
                            "{{\"prompt\":{},\"gen_len\":{gen_len}}}",
                            tokens_json(p)
                        );
                        let resp =
                            http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
                        assert_eq!(resp.status, 200, "{}", resp.body_str());
                        assert_eq!(resp.header("content-type"), Some("text/event-stream"));
                        sse_tokens(&resp.body_str()).unwrap()
                    })
                })
                .collect();
            clients.into_iter().map(|c| c.join().unwrap()).collect()
        });
        for (i, (got, want)) in streamed.iter().zip(&twins).enumerate() {
            assert_eq!(got, want, "HTTP stream {i} diverged from the in-process twin");
        }

        // non-streamed mode returns the same tokens as one JSON document
        let prompt0 = tokens_json(&prompts[0]);
        let body = format!("{{\"prompt\":{prompt0},\"gen_len\":{gen_len},\"stream\":false}}");
        let resp = http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        let tokens: Vec<usize> = parsed
            .get("tokens")
            .and_then(rwkvquant::report::json::Json::as_array)
            .unwrap()
            .iter()
            .map(|t| t.as_usize().unwrap())
            .collect();
        assert_eq!(tokens, twins[0]);

        // malformed bodies are clean 400s, not connection drops
        let bad = http_request(addr, "POST", "/v1/generate", Some("{\"prompt\":[999]}")).unwrap();
        assert_eq!(bad.status, 400);

        handle.shutdown();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.completed, prompts.len() + 1);
        assert_eq!(stats.shed, 0);
    });
}

#[test]
fn overflow_is_shed_with_429_and_counted_in_metrics() {
    let qm = packed_store("shed", 43);
    let prompts: Vec<Vec<usize>> =
        (0..8usize).map(|i| vec![(i * 5 + 1) % 32, 2]).collect();
    let gen_len = 4usize;
    let twins: Vec<Vec<usize>> =
        prompts.iter().map(|p| twin_tokens(&qm, p, gen_len)).collect();

    // one lane, batch 1, queue 1 and a slowed decoder: eight
    // simultaneous requests cannot all fit — some MUST shed
    let mut cfg = GatewayConfig::new("127.0.0.1:0");
    cfg.max_batch = 1;
    cfg.max_queue = 1;
    let gateway = Gateway::bind(cfg, qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut decoders =
        vec![Throttled { inner: RunnerDecoder::new(&qm), delay: Duration::from_millis(3) }];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());
        let barrier = Barrier::new(prompts.len());
        let outcomes: Vec<(u16, Option<Vec<usize>>)> = std::thread::scope(|cs| {
            let clients: Vec<_> = prompts
                .iter()
                .map(|p| {
                    let barrier = &barrier;
                    cs.spawn(move || {
                        barrier.wait();
                        let body =
                            format!("{{\"prompt\":{},\"gen_len\":{gen_len}}}", tokens_json(p));
                        let resp =
                            http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
                        match resp.status {
                            200 => (200u16, Some(sse_tokens(&resp.body_str()).unwrap())),
                            other => (other, None),
                        }
                    })
                })
                .collect();
            clients.into_iter().map(|c| c.join().unwrap()).collect()
        });

        let n_429 = outcomes.iter().filter(|(s, _)| *s == 429).count();
        let n_200 = outcomes.iter().filter(|(s, _)| *s == 200).count();
        assert_eq!(n_200 + n_429, prompts.len(), "unexpected statuses: {outcomes:?}");
        assert!(n_429 >= 1, "8 simultaneous requests into queue=1 must shed at least one");
        assert!(n_200 >= 1, "the admitted request must succeed");
        // the served responses are still token-identical to the twin
        for (i, (status, tokens)) in outcomes.iter().enumerate() {
            if *status == 200 {
                assert_eq!(tokens.as_ref().unwrap(), &twins[i], "request {i} diverged");
            }
        }

        // shed requests are counted in /metrics (acceptance criterion)
        let metrics = http_request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(metrics.status, 200);
        let text = metrics.body_str().into_owned();
        assert_eq!(
            metric_value(&text, "rwkvquant_requests_shed_total"),
            Some(n_429 as f64),
            "metrics:\n{text}"
        );
        assert_eq!(
            metric_value(&text, "rwkvquant_requests_completed_total"),
            Some(n_200 as f64)
        );
        let served = metric_value(&text, "rwkvquant_served_tokens_total").unwrap();
        assert!(served >= (n_200 * gen_len) as f64, "served {served}");
        assert!(metric_value(&text, "rwkvquant_served_tokens_per_sec").is_some());
        assert!(metric_value(&text, "rwkvquant_queue_depth").is_some());

        handle.shutdown();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.completed, n_200);
        assert_eq!(stats.shed, n_429);
    });
}

#[test]
fn bounded_state_pool_under_flood_answers_correct_or_429() {
    let qm = packed_store("pool", 53);
    let prompts: Vec<Vec<usize>> = (0..10usize)
        .map(|i| (0..8).map(|j| (i * 7 + j * 3 + 1) % 32).collect())
        .collect();
    let gen_len = 4usize;
    let twins: Vec<Vec<usize>> =
        prompts.iter().map(|p| twin_tokens(&qm, p, gen_len)).collect();

    // four batch slots but only TWO state slabs: any tick with ≥ 3
    // resident sequences must park/evict through the bounded arena,
    // while admission overflow beyond queue=2 sheds with a 429
    let mut cfg = GatewayConfig::new("127.0.0.1:0");
    cfg.max_batch = 4;
    cfg.max_queue = 2;
    cfg.state_slots = 2;
    cfg.prefill_chunk = 4;
    let gateway = Gateway::bind(cfg, qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut decoders = vec![
        Throttled { inner: RunnerDecoder::new(&qm), delay: Duration::from_millis(2) },
        Throttled { inner: RunnerDecoder::new(&qm), delay: Duration::from_millis(2) },
    ];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());
        let barrier = Barrier::new(prompts.len());
        let outcomes: Vec<(u16, Option<Vec<usize>>)> = std::thread::scope(|cs| {
            let clients: Vec<_> = prompts
                .iter()
                .map(|p| {
                    let barrier = &barrier;
                    cs.spawn(move || {
                        barrier.wait();
                        let body =
                            format!("{{\"prompt\":{},\"gen_len\":{gen_len}}}", tokens_json(p));
                        let resp =
                            http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
                        match resp.status {
                            200 => (200u16, Some(sse_tokens(&resp.body_str()).unwrap())),
                            other => (other, None),
                        }
                    })
                })
                .collect();
            clients.into_iter().map(|c| c.join().unwrap()).collect()
        });

        // exhaustion is CLEAN: every outcome is a finished stream with
        // the twin's exact tokens or an explicit 429 — never a panic,
        // a hang or a truncated stream
        for (i, (status, tokens)) in outcomes.iter().enumerate() {
            match status {
                200 => assert_eq!(tokens.as_ref().unwrap(), &twins[i], "request {i} diverged"),
                429 => {}
                other => panic!("request {i}: unexpected status {other}"),
            }
        }
        let n_200 = outcomes.iter().filter(|(s, _)| *s == 200).count();
        assert!(n_200 >= 1, "at least the first admitted request must complete");

        // a follow-up non-streamed request reports its TTFT, which can
        // never exceed the full request latency
        let body = format!(
            "{{\"prompt\":{},\"gen_len\":{gen_len},\"stream\":false}}",
            tokens_json(&prompts[0])
        );
        let resp = http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        let ttft_ms = parsed
            .get("ttft_ms")
            .and_then(rwkvquant::report::json::Json::as_f64)
            .unwrap();
        let latency_ms = parsed
            .get("latency_ms")
            .and_then(rwkvquant::report::json::Json::as_f64)
            .unwrap();
        assert!(
            ttft_ms > 0.0 && ttft_ms <= latency_ms,
            "ttft {ttft_ms}ms vs latency {latency_ms}ms"
        );

        handle.shutdown();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.completed, n_200 + 1);
        assert_eq!(stats.shed, prompts.len() - n_200);
        // park/resume accounting stays internally consistent even when
        // the flood happened to never exceed the resident slabs
        assert!(stats.state_resumes >= stats.state_parks);
    });
}

#[test]
fn openai_completions_match_the_generate_twin_and_are_reproducible() {
    let qm = packed_store("openai", 61);
    let tok = Tokenizer::synthetic(qm.config.vocab);
    let prompt_ids = vec![3usize, 1, 2]; // the text "w3 w1 w2 "
    let gen_len = 6usize;
    let twin = twin_tokens(&qm, &prompt_ids, gen_len);
    let expected_text = tok.decode(&twin);

    let cfg = GatewayConfig::new("127.0.0.1:0");
    let gateway = Gateway::bind(cfg, qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut decoders = vec![RunnerDecoder::new(&qm)];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());

        // greedy /v1/completions ≡ the /v1/generate twin (acceptance
        // criterion), with OpenAI response shape and usage accounting
        let body = format!(
            "{{\"prompt\":\"w3 w1 w2 \",\"max_tokens\":{gen_len},\"temperature\":0}}"
        );
        let resp = http_request(addr, "POST", "/v1/completions", Some(&body)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        assert_eq!(parsed.get("object").and_then(Json::as_str), Some("text_completion"));
        let choice = &parsed.get("choices").and_then(Json::as_array).unwrap()[0];
        assert_eq!(choice.get("finish_reason").and_then(Json::as_str), Some("length"));
        assert_eq!(choice.get("text").and_then(Json::as_str), Some(expected_text.as_str()));
        let usage = parsed.get("usage").unwrap();
        assert_eq!(usage.get("prompt_tokens").and_then(Json::as_usize), Some(3));
        assert_eq!(usage.get("completion_tokens").and_then(Json::as_usize), Some(gen_len));
        assert_eq!(usage.get("total_tokens").and_then(Json::as_usize), Some(3 + gen_len));

        // the streamed variant delivers the same text as delta chunks,
        // a final finish_reason chunk and the [DONE] terminator
        let body = format!(
            "{{\"prompt\":\"w3 w1 w2 \",\"max_tokens\":{gen_len},\"temperature\":0,\
             \"stream\":true}}"
        );
        let resp = http_request(addr, "POST", "/v1/completions", Some(&body)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("text/event-stream"));
        let body_text = resp.body_str().into_owned();
        let payloads = sse_data(&body_text);
        assert_eq!(payloads.last(), Some(&"[DONE]"), "stream must end with [DONE]");
        let mut text = String::new();
        let mut finish = None;
        for p in &payloads[..payloads.len() - 1] {
            let v = rwkvquant::server::json::parse(p).unwrap();
            assert_eq!(v.get("object").and_then(Json::as_str), Some("text_completion"));
            let c = &v.get("choices").and_then(Json::as_array).unwrap()[0];
            if let Some(t) = c.get("text").and_then(Json::as_str) {
                text.push_str(t);
            }
            if let Some(f) = c.get("finish_reason").and_then(Json::as_str) {
                finish = Some(f.to_string());
            }
        }
        assert_eq!(text, expected_text, "streamed deltas diverged from the whole document");
        assert_eq!(finish.as_deref(), Some("length"));

        // a seeded sampling request is byte-reproducible: identical
        // choices and usage on a second identical request (only the
        // request id / created stamp may differ)
        let body = "{\"prompt\":\"w3 w1 w2 \",\"max_tokens\":8,\"temperature\":0.9,\
                    \"top_k\":8,\"top_p\":0.95,\"seed\":7}";
        let a = http_request(addr, "POST", "/v1/completions", Some(body)).unwrap();
        let b = http_request(addr, "POST", "/v1/completions", Some(body)).unwrap();
        assert_eq!(a.status, 200);
        assert_eq!(b.status, 200);
        let pa = rwkvquant::server::json::parse(&a.body_str()).unwrap();
        let pb = rwkvquant::server::json::parse(&b.body_str()).unwrap();
        assert_eq!(
            pa.get("choices").unwrap().render(),
            pb.get("choices").unwrap().render(),
            "same seed must reproduce the same tokens"
        );
        assert_eq!(pa.get("usage").unwrap().render(), pb.get("usage").unwrap().render());

        // a stop sequence set to the first greedy token retires the
        // request with finish_reason "stop" after exactly that token
        let stop_text = tok.decode(&twin[..1]);
        let body = format!(
            "{{\"prompt\":\"w3 w1 w2 \",\"max_tokens\":{gen_len},\"temperature\":0,\
             \"stop\":{}}}",
            Json::Str(stop_text.clone()).render()
        );
        let resp = http_request(addr, "POST", "/v1/completions", Some(&body)).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        let choice = &parsed.get("choices").and_then(Json::as_array).unwrap()[0];
        assert_eq!(choice.get("finish_reason").and_then(Json::as_str), Some("stop"));
        assert_eq!(choice.get("text").and_then(Json::as_str), Some(stop_text.as_str()));
        let usage = parsed.get("usage").unwrap();
        assert_eq!(usage.get("completion_tokens").and_then(Json::as_usize), Some(1));

        handle.shutdown();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.cancelled, 0);
    });
}

#[test]
fn chat_completions_stream_the_openai_delta_protocol() {
    let qm = packed_store("chat", 67);
    let cfg = GatewayConfig::new("127.0.0.1:0");
    let gateway = Gateway::bind(cfg, qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut decoders = vec![RunnerDecoder::new(&qm)];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());

        let body = "{\"messages\":[{\"role\":\"user\",\"content\":\"w3 w1 \"}],\
                    \"max_tokens\":3,\"temperature\":0,\"stream\":true}";
        let resp = http_request(addr, "POST", "/v1/chat/completions", Some(body)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let text = resp.body_str().into_owned();
        let payloads = sse_data(&text);
        assert_eq!(payloads.last(), Some(&"[DONE]"));
        let chunks: Vec<Json> = payloads[..payloads.len() - 1]
            .iter()
            .map(|p| rwkvquant::server::json::parse(p).unwrap())
            .collect();
        assert!(chunks.len() >= 3, "role chunk + ≥1 delta + finish chunk, got {payloads:?}");
        for c in &chunks {
            assert_eq!(c.get("object").and_then(Json::as_str), Some("chat.completion.chunk"));
        }
        let first = &chunks[0].get("choices").and_then(Json::as_array).unwrap()[0];
        assert_eq!(
            first.get("delta").and_then(|d| d.get("role")).and_then(Json::as_str),
            Some("assistant"),
            "the opening chunk must announce the role"
        );
        let mut content = String::new();
        for c in &chunks {
            let choice = &c.get("choices").and_then(Json::as_array).unwrap()[0];
            if let Some(t) = choice.get("delta").and_then(|d| d.get("content")).and_then(Json::as_str)
            {
                content.push_str(t);
            }
        }
        assert!(!content.is_empty(), "no content deltas in {payloads:?}");
        let last = &chunks[chunks.len() - 1].get("choices").and_then(Json::as_array).unwrap()[0];
        assert_eq!(last.get("finish_reason").and_then(Json::as_str), Some("length"));

        // the non-streamed flavour agrees on the generated text
        let body = "{\"messages\":[{\"role\":\"user\",\"content\":\"w3 w1 \"}],\
                    \"max_tokens\":3,\"temperature\":0}";
        let resp = http_request(addr, "POST", "/v1/chat/completions", Some(body)).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        assert_eq!(parsed.get("object").and_then(Json::as_str), Some("chat.completion"));
        let choice = &parsed.get("choices").and_then(Json::as_array).unwrap()[0];
        assert_eq!(
            choice.get("message").and_then(|m| m.get("content")).and_then(Json::as_str),
            Some(content.as_str()),
            "streamed and whole-document chat content diverged"
        );

        handle.shutdown();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.completed, 2);
    });
}

#[test]
fn client_disconnect_cancels_the_in_flight_sequence() {
    use std::io::{Read, Write};

    let qm = packed_store("cancel", 71);
    let cfg = GatewayConfig::new("127.0.0.1:0");
    let gateway = Gateway::bind(cfg, qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let metrics = handle.metrics();
    // slowed decoder: a 400-token budget runs ≳ 1.2 s, leaving ample
    // time to disconnect mid-decode
    let mut decoders =
        vec![Throttled { inner: RunnerDecoder::new(&qm), delay: Duration::from_millis(3) }];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());

        // raw socket: stream a long completion, read until the first
        // token delta arrives, then hang up without warning
        let body = r#"{"prompt":"w3 w1 w2 ","max_tokens":400,"temperature":0,"stream":true}"#;
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(
            sock,
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut seen = Vec::new();
        let mut buf = [0u8; 1024];
        while !String::from_utf8_lossy(&seen).contains("\"text\":") {
            let n = sock.read(&mut buf).unwrap();
            assert!(n > 0, "server closed the stream before the first token");
            seen.extend_from_slice(&buf[..n]);
        }
        drop(sock);

        // the serve loop must notice (next chunk write fails → cancel
        // flag → sweep) and release the sequence well before its
        // 400-token budget would elapse
        let t0 = Instant::now();
        while metrics.cancelled.load(Ordering::Relaxed) == 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "disconnect was never detected as a cancellation"
            );
            std::thread::sleep(Duration::from_millis(5));
        }

        // the lane is healthy again: a follow-up request completes, and
        // the cancellation shows up in the Prometheus exposition with
        // the queue drained
        let body = r#"{"prompt":"w5 ","max_tokens":2,"temperature":0}"#;
        let resp = http_request(addr, "POST", "/v1/completions", Some(body)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let text = http_request(addr, "GET", "/metrics", None).unwrap().body_str().into_owned();
        assert_eq!(metric_value(&text, "rwkvquant_requests_cancelled_total"), Some(1.0));
        assert_eq!(metric_value(&text, "rwkvquant_queue_depth"), Some(0.0));

        handle.shutdown();
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.cancelled, 1, "the orphaned sequence must retire as cancelled");
        assert_eq!(stats.completed, 1, "only the follow-up request completed");
    });
}

#[cfg(unix)]
extern "C" {
    fn raise(sig: std::os::raw::c_int) -> std::os::raw::c_int;
}

#[cfg(unix)]
#[test]
fn sigterm_drains_in_flight_requests_to_completion() {
    use rwkvquant::server::signal;
    signal::install_shutdown_signals();
    signal::clear_shutdown_signal();

    let qm = packed_store("drain", 47);
    let prompt = vec![5usize, 1, 3];
    let gen_len = 40usize; // ~3ms/step × 43 steps ≳ 120ms of decode
    let want = twin_tokens(&qm, &prompt, gen_len);

    // only THIS gateway heeds the process-wide signal flag, so the
    // raise below cannot leak into the other tests' gateways
    let mut cfg = GatewayConfig::new("127.0.0.1:0");
    cfg.heed_signals = true;
    let gateway = Gateway::bind(cfg, qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let gateway_handle = gateway.handle();
    let metrics = gateway_handle.metrics();
    let mut decoders =
        vec![Throttled { inner: RunnerDecoder::new(&qm), delay: Duration::from_millis(3) }];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(gateway_handle.clone());
        let client = s.spawn(move || {
            let prompt_json = tokens_json(&prompt);
            let body = format!("{{\"prompt\":{prompt_json},\"gen_len\":{gen_len}}}");
            http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap()
        });

        // wait until the request is demonstrably mid-flight (first
        // tokens produced), then deliver a real SIGTERM to the process
        let t0 = Instant::now();
        while metrics.tokens.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "request never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        // SAFETY: raising a signal for which install_shutdown_signals
        // registered a flag-setting handler above.
        unsafe {
            raise(15); // SIGTERM
        }

        // the in-flight stream must run to completion, not be cut off
        let resp = client.join().unwrap();
        assert_eq!(resp.status, 200);
        let tokens = sse_tokens(&resp.body_str()).unwrap();
        assert_eq!(tokens.len(), gen_len, "drain cut the stream short");
        assert_eq!(tokens, want, "drained stream diverged from the twin");

        // ...and the gateway returns cleanly with the work accounted
        let stats = server.join().unwrap().unwrap();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.total_tokens, gen_len);
    });

    // (no post-drain connect probe: the ephemeral port may be rebound
    // by a parallel test the instant the listener closes, so "refused"
    // would be flaky — the drain itself is proven by the join above)
    signal::clear_shutdown_signal();
}

#[test]
fn single_mode_models_listing_admin_guard_and_error_schema() {
    let qm = packed_store("surface", 53);
    let gateway = Gateway::bind(GatewayConfig::new("127.0.0.1:0"), qm.config.vocab).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let mut decoders = vec![RunnerDecoder::new(&qm)];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve(&mut decoders));
        let _drain = ShutdownOnDrop(handle.clone());

        // /v1/models lists exactly the anonymous default model
        let resp = http_request(addr, "GET", "/v1/models", None).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        assert_eq!(parsed.get("object").and_then(Json::as_str), Some("list"));
        let data = parsed.get("data").and_then(Json::as_array).unwrap();
        assert_eq!(data.len(), 1);
        assert_eq!(data[0].get("id").and_then(Json::as_str), Some("rwkvquant"));
        assert_eq!(data[0].get("object").and_then(Json::as_str), Some("model"));
        assert_eq!(data[0].get("owned_by").and_then(Json::as_str), Some("rwkvquant"));

        // the default name routes; any other model 404s with the
        // machine-readable code, inside the OpenAI error envelope
        let ok = http_request(
            addr,
            "POST",
            "/v1/generate",
            Some(r#"{"model":"rwkvquant","prompt":[1,2],"gen_len":2}"#),
        )
        .unwrap();
        assert_eq!(ok.status, 200, "{}", ok.body_str());
        let miss = http_request(
            addr,
            "POST",
            "/v1/generate",
            Some(r#"{"model":"other","prompt":[1,2],"gen_len":2}"#),
        )
        .unwrap();
        assert_eq!(miss.status, 404, "{}", miss.body_str());
        let err = rwkvquant::server::json::parse(&miss.body_str()).unwrap();
        let err = err.get("error").expect("errors are wrapped in an 'error' object");
        assert_eq!(err.get("code").and_then(Json::as_str), Some("model_not_found"));
        assert_eq!(err.get("type").and_then(Json::as_str), Some("invalid_request_error"));
        assert!(err.get("message").and_then(Json::as_str).unwrap().contains("other"));

        // a non-string model is a 400 from both body parsers
        for (path, body) in [
            ("/v1/generate", r#"{"model":7,"prompt":[1],"gen_len":1}"#),
            ("/v1/completions", r#"{"model":7,"prompt":"w1 ","max_tokens":1}"#),
        ] {
            let resp = http_request(addr, "POST", path, Some(body)).unwrap();
            assert_eq!(resp.status, 400, "{path}: {}", resp.body_str());
            let err = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
            assert_eq!(
                err.get("error").and_then(|e| e.get("type")).and_then(Json::as_str),
                Some("invalid_request_error"),
            );
        }

        // admin routes sit in the table (404/405 come from it) but are
        // disabled without a registry; empty and traversal params bounce
        let resp =
            http_request(addr, "POST", "/admin/models/x", Some(r#"{"path":"x"}"#)).unwrap();
        assert_eq!(resp.status, 400, "{}", resp.body_str());
        assert!(resp.body_str().contains("--model"), "{}", resp.body_str());
        let resp =
            http_request(addr, "POST", "/admin/models/", Some(r#"{"path":"x"}"#)).unwrap();
        assert_eq!(resp.status, 404);
        let resp = http_request(addr, "PUT", "/admin/models/x", None).unwrap();
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("Allow"), Some("POST, DELETE"));
        let resp = http_request(addr, "GET", "/v1/generate", None).unwrap();
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("Allow"), Some("POST"));

        handle.shutdown();
        server.join().unwrap().unwrap();
    });
}
