//! Socket-level integration tests for multi-model fleet serving — the
//! acceptance criteria of the fleet PR:
//!
//! 1. two models served concurrently are each token-identical to their
//!    own single-model in-process twin,
//! 2. a hot swap under active traffic loses zero in-flight requests —
//!    admitted sequences finish on the old engine, new admissions land
//!    on the new one, and post-swap output matches a fresh serve of the
//!    new store,
//! 3. `GET /v1/models` lists the registry in OpenAI shape, unknown
//!    models 404 with code `model_not_found`, and the admin routes
//!    validate their path parameter,
//! 4. `/metrics` carries a `model` label on every serve-level family.

use rwkvquant::config::{ModelConfig, QuantConfig};
use rwkvquant::coordinator::fleet::{Fleet, FleetConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{serve_collect, Request, RunnerDecoder};
use rwkvquant::model::rwkv::init_params;
use rwkvquant::model::QuantizedModel;
use rwkvquant::report::json::Json;
use rwkvquant::server::gateway::{sse_tokens, tokens_json};
use rwkvquant::server::http::http_request;
use rwkvquant::server::{Gateway, GatewayConfig};
use rwkvquant::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Barrier;
use std::time::{Duration, Instant};

/// Quantize a tiny synthetic model and leave the packed checkpoint on
/// disk (the fleet loads by path; callers clean up).
fn pack_store(tag: &str, seed: u64) -> PathBuf {
    let m = init_params(&ModelConfig::rwkv6(1, 16, 32), &mut Rng::new(seed));
    let qc = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
    let (q, _) = quantize_model(&m, None, &qc, 2);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    let path = std::env::temp_dir().join(format!("fleet_it_{tag}.rwkvq2"));
    qm.save(&path).unwrap();
    path
}

/// Greedy twin for one prompt against a store file — what every HTTP
/// response routed to that store must reproduce exactly.
fn twin_tokens(path: &PathBuf, prompt: &[usize], gen_len: usize) -> Vec<usize> {
    let qm = QuantizedModel::open(path).unwrap();
    let mut dec = RunnerDecoder::new(&qm);
    let (_, resp) = serve_collect(
        &mut dec,
        vec![Request::new(0, prompt.to_vec(), gen_len)],
        1,
        Duration::from_millis(0),
    )
    .unwrap();
    resp[0].tokens.clone()
}

struct ShutdownOnDrop(rwkvquant::server::GatewayHandle);

impl Drop for ShutdownOnDrop {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

/// One labeled sample from the fleet exposition:
/// `name{model="…"} value`.
fn labeled_metric(text: &str, name: &str, model: &str) -> Option<f64> {
    let prefix = format!("{name}{{model=\"{model}\"}} ");
    text.lines().find_map(|l| l.strip_prefix(&prefix).and_then(|v| v.trim().parse().ok()))
}

fn error_code(body: &str) -> Option<String> {
    let v = rwkvquant::server::json::parse(body).ok()?;
    v.get("error")?.get("code").and_then(Json::as_str).map(str::to_string)
}

#[test]
fn two_models_route_by_name_and_match_their_twins() {
    let pa = pack_store("alpha", 101);
    let pb = pack_store("beta", 203);
    let prompt = vec![3usize, 1, 4];
    let gen_len = 6usize;
    let twin_a = twin_tokens(&pa, &prompt, gen_len);
    let twin_b = twin_tokens(&pb, &prompt, gen_len);
    assert_ne!(twin_a, twin_b, "the two stores must be distinguishable");

    let fleet = Fleet::new(FleetConfig::default());
    fleet.load("alpha", &pa).unwrap();
    fleet.load("beta", &pb).unwrap();
    let gateway = Gateway::bind(GatewayConfig::new("127.0.0.1:0"), 32).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();
    let jobs: [&str; 3] = ["alpha", "beta", "alpha"];

    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve_fleet(&fleet));
        let _drain = ShutdownOnDrop(handle.clone());

        // both models stream concurrently, each matching its own twin
        let got: Vec<(&str, Vec<usize>)> = std::thread::scope(|cs| {
            let clients: Vec<_> = jobs
                .iter()
                .map(|&model| {
                    let prompt = &prompt;
                    cs.spawn(move || {
                        let body = format!(
                            "{{\"model\":\"{model}\",\"prompt\":{},\"gen_len\":{gen_len}}}",
                            tokens_json(prompt)
                        );
                        let resp =
                            http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
                        assert_eq!(resp.status, 200, "{}", resp.body_str());
                        (model, sse_tokens(&resp.body_str()).unwrap())
                    })
                })
                .collect();
            clients.into_iter().map(|c| c.join().unwrap()).collect()
        });
        for &(model, ref tokens) in &got {
            let want = if model == "alpha" { &twin_a } else { &twin_b };
            assert_eq!(tokens, want, "model '{model}' diverged from its twin");
        }

        // the OpenAI text endpoint routes by the same field and stamps
        // the model name on the reply
        let body = format!(
            "{{\"model\":\"beta\",\"prompt\":\"w3 w1 w4 \",\"max_tokens\":{gen_len},\
             \"temperature\":0}}"
        );
        let resp = http_request(addr, "POST", "/v1/completions", Some(&body)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        assert_eq!(parsed.get("model").and_then(Json::as_str), Some("beta"));

        // /v1/models lists the registry in OpenAI shape, sorted by name
        let resp = http_request(addr, "GET", "/v1/models", None).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        assert_eq!(parsed.get("object").and_then(Json::as_str), Some("list"));
        let data = parsed.get("data").and_then(Json::as_array).unwrap();
        let ids: Vec<&str> =
            data.iter().map(|m| m.get("id").and_then(Json::as_str).unwrap()).collect();
        assert_eq!(ids, vec!["alpha", "beta"]);
        for m in data {
            assert_eq!(m.get("object").and_then(Json::as_str), Some("model"));
            assert_eq!(m.get("owned_by").and_then(Json::as_str), Some("rwkvquant"));
            assert!(m.get("created").and_then(Json::as_usize).unwrap() > 0);
        }

        // unknown model (and the unregistered default) 404 with the
        // machine-readable code; a non-string model is a 400
        for body in [
            format!("{{\"model\":\"nope\",\"prompt\":{},\"gen_len\":2}}", tokens_json(&prompt)),
            format!("{{\"prompt\":{},\"gen_len\":2}}", tokens_json(&prompt)),
        ] {
            let resp = http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
            assert_eq!(resp.status, 404, "{}", resp.body_str());
            assert_eq!(
                error_code(&resp.body_str()).as_deref(),
                Some("model_not_found"),
                "{}",
                resp.body_str()
            );
        }
        let bad = format!("{{\"model\":7,\"prompt\":{},\"gen_len\":2}}", tokens_json(&prompt));
        let resp = http_request(addr, "POST", "/v1/generate", Some(&bad)).unwrap();
        assert_eq!(resp.status, 400, "{}", resp.body_str());

        // every serve-level family carries one labeled sample per model
        let text = http_request(addr, "GET", "/metrics", None).unwrap().body_str().into_owned();
        assert_eq!(
            labeled_metric(&text, "rwkvquant_generate_requests_total", "alpha"),
            Some(2.0),
            "metrics:\n{text}"
        );
        assert_eq!(labeled_metric(&text, "rwkvquant_generate_requests_total", "beta"), Some(1.0));
        assert_eq!(labeled_metric(&text, "rwkvquant_text_requests_total", "beta"), Some(1.0));
        assert_eq!(labeled_metric(&text, "rwkvquant_requests_completed_total", "alpha"), Some(2.0));
        for family in [
            "rwkvquant_served_tokens_total",
            "rwkvquant_served_tokens_per_sec",
            "rwkvquant_queue_depth",
        ] {
            for model in ["alpha", "beta"] {
                assert!(
                    labeled_metric(&text, family, model).is_some(),
                    "missing {family}{{model=\"{model}\"}} in:\n{text}"
                );
            }
        }
        // gateway-level families stay unlabeled
        assert!(text.lines().any(|l| l.starts_with("rwkvquant_http_requests_total ")));

        handle.shutdown();
        server.join().unwrap().unwrap();
    });

    let stats = fleet.drain();
    assert_eq!(stats.len(), 2);
    let completed: usize = stats
        .iter()
        .map(|(name, s)| s.as_ref().unwrap_or_else(|e| panic!("engine '{name}': {e:#}")).completed)
        .sum();
    assert_eq!(completed, 4, "three generates + one completion decoded to completion");
    std::fs::remove_file(pa).ok();
    std::fs::remove_file(pb).ok();
}

#[test]
fn hot_swap_under_traffic_loses_no_in_flight_requests() {
    let pa = pack_store("swap_old", 307);
    let pb = pack_store("swap_new", 409);
    let prompt = vec![5usize, 2, 1];
    let gen_len = 24usize;
    let twin_a = twin_tokens(&pa, &prompt, gen_len);
    let twin_b = twin_tokens(&pb, &prompt, gen_len);
    assert_ne!(twin_a, twin_b);

    // throttled decode (~2ms/token) keeps the first wave in flight
    // long enough to swap the store underneath it
    let fleet = Fleet::new(FleetConfig {
        step_delay: Duration::from_millis(2),
        ..FleetConfig::default()
    });
    let first = fleet.load("m", &pa).unwrap();
    let old_metrics = first.metrics();
    let v0 = first.version();
    let gateway = Gateway::bind(GatewayConfig::new("127.0.0.1:0"), 32).unwrap();
    let addr = gateway.local_addr();
    let handle = gateway.handle();

    let n_clients = 6usize;
    std::thread::scope(|s| {
        let server = s.spawn(|| gateway.serve_fleet(&fleet));
        let _drain = ShutdownOnDrop(handle.clone());
        let barrier = Barrier::new(n_clients + 1);

        let results: Vec<Vec<usize>> = std::thread::scope(|cs| {
            let clients: Vec<_> = (0..n_clients)
                .map(|_| {
                    let barrier = &barrier;
                    let prompt = &prompt;
                    cs.spawn(move || {
                        barrier.wait();
                        let body = format!(
                            "{{\"model\":\"m\",\"prompt\":{},\"gen_len\":{gen_len}}}",
                            tokens_json(prompt)
                        );
                        let resp =
                            http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
                        assert_eq!(
                            resp.status,
                            200,
                            "in-flight request lost: {}",
                            resp.body_str()
                        );
                        sse_tokens(&resp.body_str()).unwrap()
                    })
                })
                .collect();
            barrier.wait();

            // wait until the old engine is demonstrably mid-decode…
            let t0 = Instant::now();
            while old_metrics.tokens.load(Ordering::Relaxed) == 0 {
                assert!(t0.elapsed() < Duration::from_secs(10), "traffic never started");
                std::thread::sleep(Duration::from_millis(1));
            }

            // …then hot-swap the name to the new store over the admin API
            let body =
                format!("{{\"path\":{}}}", Json::Str(pb.display().to_string()).render());
            let resp = http_request(addr, "POST", "/admin/models/m", Some(&body)).unwrap();
            assert_eq!(resp.status, 200, "{}", resp.body_str());
            let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
            assert_eq!(parsed.get("id").and_then(Json::as_str), Some("m"));
            let v1 = parsed.get("version").and_then(Json::as_usize).unwrap() as u64;
            assert!(v1 > v0, "a swap must bump the version ({v0} -> {v1})");

            clients.into_iter().map(|c| c.join().unwrap()).collect()
        });

        // zero in-flight requests lost: every client completes with a
        // full stream matching one of the two stores' twins
        assert_eq!(results.len(), n_clients);
        for (i, tokens) in results.iter().enumerate() {
            assert_eq!(tokens.len(), gen_len, "request {i} was truncated by the swap");
            assert!(
                tokens == &twin_a || tokens == &twin_b,
                "request {i} matches neither store: {tokens:?}"
            );
        }

        // post-swap admissions serve the NEW store, exactly as a fresh
        // single-model serve of it would
        let body = format!(
            "{{\"model\":\"m\",\"prompt\":{},\"gen_len\":{gen_len}}}",
            tokens_json(&prompt)
        );
        let resp = http_request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(
            sse_tokens(&resp.body_str()).unwrap(),
            twin_b,
            "post-swap output must match a fresh serve of the new store"
        );

        // admin path-parameter validation: traversal is a 400, an empty
        // name segment falls off the route table as a 404, and single
        // deletes are idempotent-clean
        let resp = http_request(addr, "POST", "/admin/models/..", Some("{\"path\":\"x\"}")).unwrap();
        assert_eq!(resp.status, 400, "{}", resp.body_str());
        let resp = http_request(addr, "DELETE", "/admin/models/", None).unwrap();
        assert_eq!(resp.status, 404);
        let resp = http_request(addr, "DELETE", "/admin/models/m", None).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        assert_eq!(parsed.get("deleted").and_then(Json::as_bool), Some(true));
        let resp = http_request(addr, "DELETE", "/admin/models/m", None).unwrap();
        assert_eq!(resp.status, 404);
        assert_eq!(error_code(&resp.body_str()).as_deref(), Some("model_not_found"));
        let resp = http_request(addr, "GET", "/v1/models", None).unwrap();
        let parsed = rwkvquant::server::json::parse(&resp.body_str()).unwrap();
        assert!(parsed.get("data").and_then(Json::as_array).unwrap().is_empty());

        handle.shutdown();
        server.join().unwrap().unwrap();
    });

    let stats = fleet.drain();
    // both engines (swapped-out old + deleted new) retire cleanly with
    // every admitted request decoded to completion
    let mut completed = 0usize;
    for (name, s) in &stats {
        let s = s.as_ref().unwrap_or_else(|e| panic!("engine '{name}': {e:#}"));
        assert_eq!(s.shed, 0, "engine '{name}' shed under the default queue bound");
        completed += s.completed;
    }
    assert_eq!(completed, 7, "6 in-flight + 1 post-swap, none lost");
    std::fs::remove_file(pa).ok();
    std::fs::remove_file(pb).ok();
}
