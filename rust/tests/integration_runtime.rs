//! Integration: AOT artifacts (JAX+Pallas → HLO text) executed through
//! the PJRT runtime, validated against the pure-Rust reference forward.
//! Requires `make artifacts` (skips politely when absent, so unit CI
//! without the python toolchain still passes). The PJRT-executing tests
//! additionally require the `pjrt` cargo feature (the `xla` crate from
//! the full offline vendor set); without it only the pure-Rust checks
//! run.

use rwkvquant::model::ModelWeights;
use rwkvquant::runtime::artifacts_dir;

fn artifacts_ready() -> Option<std::path::PathBuf> {
    let dir = artifacts_dir();
    if dir.join("rwkv_step.hlo.txt").exists() && dir.join("tiny_rwkv.bin").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn trained_model_beats_uniform_ppl_in_rust() {
    let Some(dir) = artifacts_ready() else { return };
    let weights = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
    let corpus = rwkvquant::data::BinCorpus::load(&dir.join("corpus.bin")).unwrap();
    let toks = &corpus.valid[..600.min(corpus.valid.len())];
    let ppl = rwkvquant::eval::ppl::perplexity(&weights, toks);
    let uniform = corpus.vocab as f64;
    assert!(
        ppl < uniform / 3.0,
        "trained ppl {ppl} must beat uniform {uniform} clearly"
    );
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::{artifacts_ready, ModelWeights};
    use rwkvquant::model::rwkv::RwkvRunner;
    use rwkvquant::runtime::rwkv_graph::RwkvSession;
    use rwkvquant::runtime::{artifacts_dir, literal_f32, Engine};

    #[test]
    fn smoke_graph_loads_and_runs() {
        let dir = artifacts_dir();
        if !dir.join("smoke.hlo.txt").exists() {
            eprintln!("skipping: smoke.hlo.txt missing");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let g = engine.load_hlo_text(&dir.join("smoke.hlo.txt")).unwrap();
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
        let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
        let outs = g.run_literals(&[x, y]).unwrap();
        let vals = literal_f32(&outs[0]).unwrap();
        assert_eq!(vals, vec![5., 5., 9., 9.]);
    }

    #[test]
    fn vq_matvec_graph_matches_host_dequant() {
        let dir = artifacts_dir();
        if !dir.join("vq_matvec.hlo.txt").exists() {
            eprintln!("skipping: vq_matvec.hlo.txt missing");
            return;
        }
        let engine = Engine::cpu().unwrap();
        let g = engine.load_hlo_text(&dir.join("vq_matvec.hlo.txt")).unwrap();
        // matches vq_matvec.meta.json defaults: 256 entries, d=4, oc=ic=128
        let (n_entries, d, oc, ic) = (256usize, 4usize, 128usize, 128usize);
        let mut rng = rwkvquant::util::rng::Rng::new(5);
        let cb: Vec<f32> = (0..n_entries * d).map(|_| rng.normal() as f32).collect();
        let idx: Vec<i32> = (0..oc * ic / d).map(|_| rng.below(n_entries) as i32).collect();
        let x: Vec<f32> = (0..ic).map(|_| rng.normal() as f32).collect();

        let cb_lit = xla::Literal::vec1(&cb).reshape(&[n_entries as i64, d as i64]).unwrap();
        let idx_lit = xla::Literal::vec1(&idx);
        let x_lit = xla::Literal::vec1(&x);
        let outs = g.run_literals(&[cb_lit, idx_lit, x_lit]).unwrap();
        let got = literal_f32(&outs[0]).unwrap();

        // host-side dequant + matvec oracle
        let mut want = vec![0.0f32; oc];
        for r in 0..oc {
            let mut acc = 0.0f32;
            for c in 0..ic {
                let flat = r * ic + c;
                let e = idx[flat / d] as usize;
                let w = cb[e * d + flat % d];
                acc += w * x[c];
            }
            want[r] = acc;
        }
        for i in 0..oc {
            assert!(
                (got[i] - want[i]).abs() < 1e-3 + want[i].abs() * 1e-4,
                "row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn rwkv_step_graph_matches_rust_reference() {
        let Some(dir) = artifacts_ready() else { return };
        let weights = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
        let mut session = RwkvSession::load(&dir, &weights).unwrap();
        let mut reference = RwkvRunner::new(&weights);

        let tokens = [3usize, 17, 99, 5, 200, 42, 7];
        for (i, &t) in tokens.iter().enumerate() {
            let got = session.step(t).unwrap();
            let want = reference.forward_token(t);
            assert_eq!(got.len(), want.len());
            let max_abs: f32 = want.iter().fold(0.0, |m, v| m.max(v.abs()));
            for c in 0..got.len() {
                assert!(
                    (got[c] - want[c]).abs() < 1e-2 + max_abs * 1e-3,
                    "step {i} logit {c}: pjrt {} vs rust {}",
                    got[c],
                    want[c]
                );
            }
        }
    }

    #[test]
    fn rwkv_session_greedy_generation_is_deterministic() {
        let Some(dir) = artifacts_ready() else { return };
        let weights = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
        let mut session = RwkvSession::load(&dir, &weights).unwrap();
        let a = session.generate_greedy(&[1, 2, 3], 8).unwrap();
        let b = session.generate_greedy(&[1, 2, 3], 8).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&t| t < weights.config.vocab));
    }

    #[test]
    fn rwkv_session_loads_from_quantized_provider() {
        // quantized serving through the PJRT path: packed layers are
        // materialised per-layer at upload, never as a whole dense model
        let Some(dir) = artifacts_ready() else { return };
        let weights = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
        let cfg = rwkvquant::config::QuantConfig {
            kmeans_iters: 4,
            vq_bits: 6,
            ..rwkvquant::config::QuantConfig::default()
        };
        let (q, _) = rwkvquant::coordinator::quantize_model(&weights, None, &cfg, 0);
        let qm = rwkvquant::model::QuantizedModel::from_parts(&weights, &q);
        let mut session = RwkvSession::load(&dir, &qm).unwrap();
        let logits = session.step(3).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}
