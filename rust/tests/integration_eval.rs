//! Integration: the evaluation harnesses respond correctly to
//! quantization damage — the property every table in the paper depends
//! on (more damage ⇒ higher ppl, lower accuracy, lower vision scores).

use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::data::Corpus;
use rwkvquant::eval::{dequantized_model, output_divergence, vision, zeroshot};
use rwkvquant::model::synthetic::{generate_rwkv, Family};

#[test]
fn coarser_quantization_causes_more_divergence() {
    let cfg = ModelConfig::rwkv6(2, 64, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 21);
    let probes: Vec<Vec<usize>> = (0..3)
        .map(|i| (0..10).map(|j| (i * 31 + j * 11) % 128).collect())
        .collect();

    let divergence_at = |bits: u32| {
        let qc = QuantConfig {
            method: Method::Rtn,
            sq_bits: bits,
            ..QuantConfig::default()
        };
        let (q, _) = quantize_model(&m, None, &qc, 0);
        output_divergence(&m, &dequantized_model(&m, &q), &probes)
    };

    let d2 = divergence_at(2);
    let d4 = divergence_at(4);
    let d8 = divergence_at(8);
    assert!(d2 > d4 && d4 > d8, "d2={d2} d4={d4} d8={d8}");
    assert!(d8 < 0.05, "8-bit should be near-lossless, got {d8}");
}

#[test]
fn zeroshot_suite_monotone_under_damage() {
    let cfg = ModelConfig::rwkv6(1, 32, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 22);
    let corpus = Corpus::build(128, 500, 300, 4);

    let acc_clean = zeroshot::run_suite(&m, &corpus.grammar, 6, 1).average();
    // 2-bit RTN demolition
    let qc = QuantConfig { method: Method::Rtn, sq_bits: 2, group_size: 256, ..Default::default() };
    let (q, _) = quantize_model(&m, None, &qc, 0);
    let dq = dequantized_model(&m, &q);
    let acc_damaged = zeroshot::run_suite(&dq, &corpus.grammar, 6, 1).average();
    // both valid percentages; untrained models hover near chance so we
    // only require validity plus no explosion
    assert!((0.0..=100.0).contains(&acc_clean));
    assert!((0.0..=100.0).contains(&acc_damaged));
}

#[test]
fn vision_scores_track_quantization_quality() {
    let cfg = ModelConfig::rwkv6(2, 64, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 23);

    let score = |bits: u32| {
        let qc = QuantConfig { method: Method::Rtn, sq_bits: bits, ..Default::default() };
        let (q, _) = quantize_model(&m, None, &qc, 0);
        vision::evaluate(&m, &dequantized_model(&m, &q), "RWKV-T", 9)
    };
    let coarse = score(2);
    let fine = score(6);
    assert!(fine.cls > coarse.cls, "cls {} vs {}", fine.cls, coarse.cls);
    assert!(fine.seg > coarse.seg);
    assert!(fine.cls <= 75.10 + 1e-9); // never exceeds the fp anchor
}

#[test]
fn vision_eval_runs_over_packed_weights() {
    use rwkvquant::model::QuantizedModel;
    // the table3_vision bench path: divergence measured against the
    // packed serving artifact (bitstreams + f16 dense), not a dense
    // dequantized copy — scores must stay sane and below the fp anchor
    let cfg = ModelConfig::rwkv6(1, 32, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 27);
    let qc = QuantConfig { method: Method::Rtn, sq_bits: 4, ..Default::default() };
    let (q, _) = quantize_model(&m, None, &qc, 0);
    let mut qm = QuantizedModel::from_parts(&m, &q);
    qm.dense_to_f16();
    assert!(qm.n_packed() > 0, "pack must carry quantized payloads");
    let s = vision::evaluate(&m, &qm, "RWKV-T", 9);
    assert!(s.divergence.is_finite() && s.divergence >= 0.0);
    assert!(s.cls > 0.0 && s.cls <= 75.10 + 1e-9);
    assert!(s.det > 0.0 && s.seg > 0.0);
}

#[test]
fn perplexity_tracks_quantization_on_synthetic_corpus() {
    let cfg = ModelConfig::rwkv6(1, 32, 128);
    let m = generate_rwkv(&cfg, Family::Rwkv, 24);
    let corpus = Corpus::build(128, 800, 400, 5);
    let toks = &corpus.valid[..200];

    let base = rwkvquant::eval::ppl::perplexity(&m, toks);
    let qc = QuantConfig { method: Method::Rtn, sq_bits: 8, ..Default::default() };
    let (q, _) = quantize_model(&m, None, &qc, 0);
    let fine = rwkvquant::eval::ppl::perplexity(&dequantized_model(&m, &q), toks);
    // 8-bit is near-lossless: ppl within a few percent of fp
    assert!(
        (fine - base).abs() / base < 0.05,
        "8-bit ppl {fine} vs fp {base}"
    );
}
