//! Table 12 (appendix): sensitivity of the hybrid to τ_c and τ_f —
//! fixed-threshold sweep on three models. Expected shape: a sweet spot
//! near the auto-calibrated values; too-large τ_c ≈ pure SQ, too-small ≈
//! pure VQ.

use rwkvquant::config::Method;
use rwkvquant::experiments::*;
use rwkvquant::report::{Cell, Table};

fn main() {
    let models = [
        ("RWKV7-0.1B", "rwkv7", "0.1B", 43.02, 14.21),
        ("RWKV7-0.5B", "rwkv7", "0.5B", 48.67, 7.21),
        ("RWKV7-1.47B", "rwkv7", "1.47B", 55.08, 4.80),
    ];
    let tau_cs = [1.0, 1.5, 2.0];
    let tau_fs = [20.0, 30.0, 40.0];
    let mut t = Table::new(
        "Table 12 — τ_c / τ_f sweep (fixed thresholds)",
        &["tau_c", "tau_f", "Model", "SQ share", "0-shot9", "LambA."],
    );
    for (label, arch, size, fp_acc, fp_ppl) in models {
        let model = build_model(arch, size, 1000);
        let ps = probes(model.config.vocab, 3, 10, 7);
        let ac = auto_calib(&model);
        let map = language_map(fp_acc, fp_ppl);
        for &tc in &tau_cs {
            for &tf in &tau_fs {
                let mut cfg = bench_config(Method::RwkvQuant, 3.275, 17);
                cfg.tau_c = Some(tc);
                cfg.tau_f = Some(tf);
                let cell = run_cell(&model, ac.as_ref(), &cfg, &ps);
                t.row(vec![
                    Cell::f(tc, 2),
                    Cell::f(tf, 1),
                    Cell::s(label),
                    Cell::f(cell.report.taus.map(|x| x.sq_share).unwrap_or(f64::NAN), 3),
                    Cell::f(map.acc(cell.divergence), 2),
                    Cell::f(map.ppl(cell.divergence), 2),
                ]);
            }
        }
    }
    t.print();
    t.save_csv("table12_tau_sweep");
    println!("paper shape: best row near τ_c=1.5; τ_f matters mostly at the right τ_c");
}
