//! Table 3/8: Vision-RWKV classification / detection / segmentation
//! under quantization (VRWKV-shaped synthetic model, fidelity-mapped
//! divergence on patch probes — DESIGN.md §Substitutions).
//!
//! Divergence is measured against the **packed** [`QuantizedModel`] —
//! the artifact that actually serves — not a dense dequantized copy, so
//! the scores include bitstream round-trip and f16 dense narrowing.

use rwkvquant::config::Method;
use rwkvquant::eval::vision;
use rwkvquant::experiments::{bench_config, build_model};
use rwkvquant::model::QuantizedModel;
use rwkvquant::report::{Cell, Table};

fn main() {
    let variants = [("RWKV-T", "0.1B"), ("RWKV-S", "0.5B"), ("RWKV-B", "1B")];
    let methods = [
        (Method::Gptq, 3.5),
        (Method::Awq, 3.5),
        (Method::Gptvq, 3.5),
        (Method::Vptq, 3.5),
        (Method::RwkvQuant, 3.275),
    ];
    let mut t = Table::new(
        "Table 3/8 — VRWKV: Top-1 cls / Box AP det / mIoU seg",
        &["Bpw", "Method", "Variant", "Cls.", "Det.", "Seg."],
    );
    for (variant, size) in variants {
        let m = build_model("rwkv6", size, 2000);
        let a = vision::anchors(variant);
        t.row(vec![
            Cell::s("16"),
            Cell::s("FloatingPoint"),
            Cell::s(variant),
            Cell::f(a.cls_top1, 2),
            Cell::f(a.det_ap, 2),
            Cell::f(a.seg_miou, 2),
        ]);
        for (method, bpw) in methods {
            let cfg = bench_config(method, bpw, 5);
            let (q, _) = rwkvquant::coordinator::quantize_model(&m, None, &cfg, 0);
            let mut qm = QuantizedModel::from_parts(&m, &q);
            qm.dense_to_f16();
            let s = vision::evaluate(&m, &qm, variant, 31);
            t.row(vec![
                Cell::f(bpw, 3),
                Cell::s(method.name()),
                Cell::s(variant),
                Cell::f(s.cls, 2),
                Cell::f(s.det, 2),
                Cell::f(s.seg, 2),
            ]);
        }
    }
    t.print();
    t.save_csv("table3_vision");
    println!("paper shape: Ours top (or within noise of top) on Cls and Seg; VPTQ weakest");
}
