//! Table 2: the main comparison — 7 baselines × {3.25, 3.5} bpw + ours
//! at 3.275 bpw, across the seven-model lineup; 0-shot⁹ average and
//! LAMBADA-style perplexity via the fidelity-mapped measured divergence
//! (DESIGN.md §Substitutions). Expected shape: ours best or near-best on
//! every model, clearly ahead of same-bpw baselines.

use rwkvquant::config::Method;
use rwkvquant::experiments::*;
use rwkvquant::report::{Cell, Table};

fn main() {
    let lineup: Vec<_> = if fast_mode() {
        LANGUAGE_LINEUP[..3].to_vec()
    } else {
        LANGUAGE_LINEUP.to_vec()
    };
    let mut t = Table::new(
        "Table 2 — 0-shot⁹ avg (↑) / LAMBADA ppl (↓) per model and method",
        &["Bpw", "Method", "Model", "0-shot9", "LambA."],
    );
    for (label, arch, size, fp_acc, fp_ppl) in &lineup {
        let model = build_model(arch, size, 1000);
        let ps = probes(model.config.vocab, 3, 10, 7);
        let ac = auto_calib(&model);
        let map = language_map(*fp_acc, *fp_ppl);
        t.row(vec![
            Cell::s("16"),
            Cell::s("FloatingPoint"),
            Cell::s(*label),
            Cell::f(*fp_acc, 2),
            Cell::f(*fp_ppl, 2),
        ]);
        for (method, bpw) in table2_methods() {
            let cfg = bench_config(method, bpw, 11);
            let cell = run_cell(&model, ac.as_ref(), &cfg, &ps);
            t.row(vec![
                Cell::f(if method == Method::RwkvQuant { 3.275 } else { bpw }, 3),
                Cell::s(method.name()),
                Cell::s(*label),
                Cell::f(map.acc(cell.divergence), 2),
                Cell::f(map.ppl(cell.divergence), 2),
            ]);
            eprintln!(
                "  [{label} {} {bpw}] divergence {:.4} bpw {:.3}",
                method.name(),
                cell.divergence,
                cell.avg_bpw
            );
        }
    }
    t.print();
    t.save_csv("table2_main");
    println!("paper shape: Ours(3.275) ≥ all 3.25-bpw baselines and ≥ most 3.5-bpw ones");
}
