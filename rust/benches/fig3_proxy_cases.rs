//! Figure 3: the three archetype weights and what the proxies say about
//! them — (a) non-uniform (high P_c → VQ), (b) uniform with outliers
//! (low P_c, high P_f → VQ), (c) uniform (both low → SQ) — with the
//! per-weight SQ/VQ reconstruction error confirming the choice.

use rwkvquant::model::synthetic::Archetype;
use rwkvquant::quant::{proxy, sq, vq, QuantizedLayer};
use rwkvquant::report::{Cell, Table};
use rwkvquant::tensor::Matrix;
use rwkvquant::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(33);
    let cases = [
        ("(a) non-uniform (clustered)", Archetype::Clustered),
        ("(b) uniform + outliers", Archetype::UniformOutliers),
        ("(c) uniform, no outliers", Archetype::Uniform),
    ];
    let mut t = Table::new(
        "Figure 3 — proxies and per-weight SQ/VQ error on archetype weights",
        &["Case", "P_c", "P_f", "SQ mse", "VQ mse", "Eq.18 @ (1.5, 30)"],
    );
    for (name, arch) in cases {
        let mut w = Matrix::zeros(64, 256);
        arch.fill(&mut w.data, 0.04, &mut rng);
        let p = proxy::compute(&w.data, 4);
        let sq_mse = QuantizedLayer::Sq(sq::gptq::quantize(&w, 3, 64, None, 0.01)).mse(&w);
        let vq_mse =
            QuantizedLayer::Vq(vq::gptvq::quantize(&w, 9, 4, None, 0.01, 10, &mut rng)).mse(&w);
        let choice = rwkvquant::quant::hybrid::decide(p, 1.5, 30.0);
        t.row(vec![
            Cell::s(name),
            Cell::f(p.p_c, 3),
            Cell::f(p.p_f, 2),
            Cell::F64(sq_mse, 8),
            Cell::F64(vq_mse, 8),
            Cell::s(format!("{choice:?}")),
        ]);
    }
    t.print();
    t.save_csv("fig3_proxy_cases");
    println!("paper shape: (a),(b) → VQ wins & chosen; (c) → SQ wins & chosen");
}
