//! Table 5: hybrid-quantization ablation — GPTQ alone vs GPTVQ alone vs
//! the proxy-guided hybrid, with REAL metrics on the trained tiny RWKV
//! (ppl on the held-out corpus + corpus-derived zero-shot), plus
//! fidelity-mapped results on the synthetic lineup.

use rwkvquant::config::Method;
use rwkvquant::data::{make_task_from_corpus, BinCorpus};
use rwkvquant::eval::{dequantized_model, ppl, zeroshot};
use rwkvquant::experiments::*;
use rwkvquant::model::ModelWeights;
use rwkvquant::report::{Cell, Table};
use rwkvquant::runtime::artifacts_dir;

fn main() {
    // ---- real-metric section: trained tiny model ----
    let dir = artifacts_dir();
    if dir.join("tiny_rwkv.bin").exists() && dir.join("corpus.bin").exists() {
        let m = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
        let corpus = BinCorpus::load(&dir.join("corpus.bin")).unwrap();
        let toks = &corpus.valid[..800.min(corpus.valid.len())];
        let tasks = make_task_from_corpus(&corpus.valid, corpus.vocab, 60, 16, 2, 5);
        let calib = rwkvquant::calib::CalibSet::capture(
            &m,
            &corpus.calib_windows(8, 16, 3),
            128,
        );
        let mut t = Table::new(
            "Table 5 (real metrics, trained tiny RWKV): ppl ↓ / corpus 0-shot acc ↑",
            &["Method", "ppl", "acc %", "avg bpw"],
        );
        let fp_ppl = ppl::perplexity(&m, toks);
        let fp_acc = zeroshot::accuracy(&m, &tasks);
        t.row(vec![Cell::s("FloatingPoint"), Cell::f(fp_ppl, 2), Cell::f(fp_acc, 1), Cell::f(32.0, 2)]);
        for (method, bpw) in [(Method::Gptq, 3.5), (Method::Gptvq, 3.5), (Method::RwkvQuant, 3.275)] {
            let cfg = bench_config(method, bpw, 9);
            let (q, rep) = rwkvquant::coordinator::quantize_model(&m, Some(&calib), &cfg, 0);
            let dq = dequantized_model(&m, &q);
            t.row(vec![
                Cell::s(method.name()),
                Cell::f(ppl::perplexity(&dq, toks), 2),
                Cell::f(zeroshot::accuracy(&dq, &tasks), 1),
                Cell::f(rep.avg_bpw, 3),
            ]);
        }
        t.print();
        t.save_csv("table5_real");
    } else {
        eprintln!("(artifacts missing — skipping real-metric section)");
    }

    // ---- fidelity-mapped section across the lineup ----
    let lineup: Vec<_> = if fast_mode() { LANGUAGE_LINEUP[..3].to_vec() } else { LANGUAGE_LINEUP.to_vec() };
    let mut t = Table::new(
        "Table 5 (lineup): GPTQ vs GPTVQ vs Ours",
        &["Model", "Method", "0-shot9", "LambA."],
    );
    for (label, arch, size, fp_acc, fp_ppl) in &lineup {
        let model = build_model(arch, size, 1000);
        let ps = probes(model.config.vocab, 3, 10, 7);
        let ac = auto_calib(&model);
        let map = language_map(*fp_acc, *fp_ppl);
        for (method, bpw) in [(Method::Gptq, 3.5), (Method::Gptvq, 3.5), (Method::RwkvQuant, 3.275)] {
            let cfg = bench_config(method, bpw, 9);
            let cell = run_cell(&model, ac.as_ref(), &cfg, &ps);
            t.row(vec![
                Cell::s(*label),
                Cell::s(method.name()),
                Cell::f(map.acc(cell.divergence), 2),
                Cell::f(map.ppl(cell.divergence), 2),
            ]);
        }
    }
    t.print();
    t.save_csv("table5_hybrid_ablation");
    println!("paper shape: hybrid beats both single-method baselines on nearly all models");
}
