//! Perf bench: the hot paths the §Perf pass optimises — WKV recurrence
//! step, dense vs quantized matvec, proxy computation, the pipeline's
//! parallel speedup, and (when artifacts exist) the PJRT decode step.

use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::model::rwkv::{init_params, RwkvRunner};
use rwkvquant::model::synthetic::{generate_rwkv, Family};
use rwkvquant::quant::exec::{self, Kernel};
use rwkvquant::quant::{proxy, sq, vq};
use rwkvquant::tensor::{linalg, Matrix};
use rwkvquant::util::benchkit::{throughput, Bencher};
use rwkvquant::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(7);
    println!("detected matvec kernel: {}", exec::active_kernel().name());

    // L3 hot loop: rust reference decode step (d=512 model)
    let cfg = ModelConfig::rwkv6(12, 384, 512);
    let m = init_params(&cfg, &mut rng);
    let mut runner = RwkvRunner::new(&m);
    let mut tok = 0usize;
    let s = b.bench("rust decode step (L12 d384)", || {
        tok = (tok + 1) % 512;
        runner.forward_token(tok)
    });
    println!("decode: {:.1} tokens/s", throughput(1.0, s));

    // dense vs quantized matvec at serving dims, scalar vs detected SIMD
    for &dim in &[1024usize, 2048] {
        let mut w = Matrix::zeros(dim, dim);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let q3 = sq::rtn::quantize(&w, 3, 64);
        // few k-means iters: the bench measures the matvec, not the fit
        let qv = vq::kmeans::quantize(&w, 6, 4, 2, &mut Rng::new(dim as u64));
        let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; dim];
        b.bench(&format!("matvec fp32 {dim}x{dim}"), || linalg::matvec_into(&w, &x, &mut y));
        for k in Kernel::available() {
            b.bench(&format!("matvec q3 {} {dim}x{dim}", k.name()), || {
                exec::matvec_sq_with(k, &q3, &x, &mut y)
            });
            b.bench(&format!("matvec vq {} {dim}x{dim}", k.name()), || {
                exec::matvec_vq_with(k, &qv, &x, &mut y)
            });
        }
    }

    // proxy cost on a realistic layer
    let mut w = Matrix::zeros(512, 512);
    rng.fill_normal(&mut w.data, 0.0, 0.05);
    b.bench("proxy P_c+P_f on 512x512", || proxy::compute(&w.data, 4));

    // pipeline parallel speedup
    let model = generate_rwkv(&ModelConfig::rwkv6(4, 128, 256), Family::Rwkv, 3);
    let qc = QuantConfig { method: Method::Gptq, kmeans_iters: 5, ..Default::default() };
    let (_, t1) = b.once("pipeline 1 worker", || quantize_model(&model, None, &qc, 1));
    let (_, tn) = b.once("pipeline N workers", || quantize_model(&model, None, &qc, 0));
    println!(
        "pipeline speedup: {:.2}x ({} cores)",
        t1.as_secs_f64() / tn.as_secs_f64().max(1e-9),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );

    // PJRT decode step (if artifacts present and the pjrt feature is on)
    #[cfg(feature = "pjrt")]
    {
        use rwkvquant::model::ModelWeights;
        use rwkvquant::runtime::artifacts_dir;
        let dir = artifacts_dir();
        if dir.join("rwkv_step.hlo.txt").exists() && dir.join("tiny_rwkv.bin").exists() {
            let weights = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
            let mut session =
                rwkvquant::runtime::rwkv_graph::RwkvSession::load(&dir, &weights).unwrap();
            let mut t = 1usize;
            let s = b.bench("PJRT decode step (tiny rwkv)", || {
                t = (t + 1) % weights.config.vocab;
                session.step(t).unwrap()
            });
            println!("pjrt decode: {:.1} tokens/s", throughput(1.0, s));
        }
    }

    b.report();
}
