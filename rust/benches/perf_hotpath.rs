//! Perf bench: the hot paths the §Perf pass optimises — WKV recurrence
//! step, dense vs quantized matvec (incl. the f16 widen), the persistent
//! tick pool vs per-tick thread spawning, proxy computation, the
//! pipeline's parallel speedup, and (when artifacts exist) the PJRT
//! decode step.

use rwkvquant::config::{Method, ModelConfig, QuantConfig};
use rwkvquant::coordinator::quantize_model;
use rwkvquant::coordinator::serve::{
    serve_collect_per_tick_spawn, serve_collect_pool, serve_collect_pool_with, PoolOpts,
    Request, RunnerDecoder, ServeOpts,
};
use rwkvquant::experiments::build_model;
use rwkvquant::model::rwkv::{init_params, RwkvRunner};
use rwkvquant::model::synthetic::{generate_rwkv, Family};
use rwkvquant::quant::exec::{self, Kernel};
use rwkvquant::quant::{proxy, sq, vq};
use rwkvquant::tensor::f16::F16Tensor;
use rwkvquant::tensor::{linalg, Matrix};
use rwkvquant::util::benchkit::{throughput, Bencher};
use rwkvquant::util::rng::Rng;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(7);
    println!("detected matvec kernel: {}", exec::active_kernel().name());

    // L3 hot loop: rust reference decode step (d=512 model)
    let cfg = ModelConfig::rwkv6(12, 384, 512);
    let m = init_params(&cfg, &mut rng);
    let mut runner = RwkvRunner::new(&m);
    let mut tok = 0usize;
    let s = b.bench("rust decode step (L12 d384)", || {
        tok = (tok + 1) % 512;
        runner.forward_token(tok)
    });
    println!("decode: {:.1} tokens/s", throughput(1.0, s));

    // dense vs quantized matvec at serving dims, scalar vs detected SIMD
    for &dim in &[1024usize, 2048] {
        let mut w = Matrix::zeros(dim, dim);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let q3 = sq::rtn::quantize(&w, 3, 64);
        // few k-means iters: the bench measures the matvec, not the fit
        let qv = vq::kmeans::quantize(&w, 6, 4, 2, &mut Rng::new(dim as u64));
        let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; dim];
        b.bench(&format!("matvec fp32 {dim}x{dim}"), || linalg::matvec_into(&w, &x, &mut y));
        let f16 = F16Tensor::from_matrix(&w);
        for k in Kernel::available() {
            b.bench(&format!("matvec q3 {} {dim}x{dim}", k.name()), || {
                exec::matvec_sq_with(k, &q3, &x, &mut y)
            });
            b.bench(&format!("matvec vq {} {dim}x{dim}", k.name()), || {
                exec::matvec_vq_with(k, &qv, &x, &mut y)
            });
            // the DenseF16 head/emb path: widen (scalar vs F16C/NEON) + dot
            b.bench(&format!("matvec f16 {} {dim}x{dim}", k.name()), || {
                exec::matvec_f16_with(k, &f16, &x, &mut y)
            });
        }
    }

    // persistent tick pool vs per-tick thread spawning, batch 4 on the
    // synthetic 3B config (ROADMAP: the pool must win once spawn cost
    // and cold per-thread scratch are off the per-token path)
    {
        let m3 = build_model("rwkv6", "3B", 13);
        let vocab = m3.config.vocab;
        let requests = || -> Vec<Request> {
            (0..12u64)
                .map(|id| Request::new(id, vec![(id as usize * 29 + 1) % vocab, 2, 3], 6))
                .collect()
        };
        let lanes = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(2, 4);
        let mut decs: Vec<_> = (0..lanes).map(|_| RunnerDecoder::new(&m3)).collect();
        // warm up page cache / branch predictors on both paths once
        serve_collect_pool(&mut decs, requests(), 4, Duration::from_millis(1)).unwrap();
        let (spawn_out, t_spawn) = b.once(&format!("serve 3B batch4 per-tick spawn x{lanes}"), || {
            serve_collect_per_tick_spawn(&mut decs, requests(), 4, Duration::from_millis(1))
                .unwrap()
        });
        let (pool_out, t_pool) = b.once(&format!("serve 3B batch4 persistent pool x{lanes}"), || {
            serve_collect_pool(&mut decs, requests(), 4, Duration::from_millis(1)).unwrap()
        });
        let spawn_tps = spawn_out.0.tokens_per_sec();
        let pool_tps = pool_out.0.tokens_per_sec();
        println!(
            "tick pool vs per-tick spawn at batch 4 (3B, {lanes} lanes): \
             {pool_tps:.1} vs {spawn_tps:.1} tok/s ({:.2}x, wall {:.0} ms vs {:.0} ms)",
            pool_tps / spawn_tps.max(1e-9),
            t_pool.as_secs_f64() * 1e3,
            t_spawn.as_secs_f64() * 1e3,
        );
    }

    // chunked prefill vs legacy one-token-per-tick on a long prompt
    // (same tokens by construction; the win is ticks and TTFT, not
    // per-step work — see coordinator::serve::TickParams)
    {
        let m3 = build_model("rwkv6", "3B", 17);
        let vocab = m3.config.vocab;
        let requests = || -> Vec<Request> {
            (0..4u64)
                .map(|id| {
                    let prompt: Vec<usize> =
                        (0..128).map(|i| (id as usize * 29 + i * 3 + 1) % vocab).collect();
                    Request::new(id, prompt, 8)
                })
                .collect()
        };
        let lanes = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(2, 4);
        let mut decs: Vec<_> = (0..lanes).map(|_| RunnerDecoder::new(&m3)).collect();
        let run = |decs: &mut Vec<_>, chunk: usize| {
            let opts = ServeOpts::new(4, Duration::from_millis(1)).with_prefill_chunk(chunk);
            serve_collect_pool_with(decs, requests(), &opts, PoolOpts::default()).unwrap().0
        };
        run(&mut decs, 32); // warm-up
        let (one, t_one) =
            b.once("prefill 128-tok prompt, chunk 1", || run(&mut decs, 1));
        let (chunked, t_chunked) =
            b.once("prefill 128-tok prompt, chunk 32", || run(&mut decs, 32));
        println!(
            "prefill chunk 32 vs 1 (128-tok prompts, {lanes} lanes): \
             ttft p50 {:?} vs {:?} ({:.0} ms vs {:.0} ms wall)",
            chunked.p50_ttft,
            one.p50_ttft,
            t_chunked.as_secs_f64() * 1e3,
            t_one.as_secs_f64() * 1e3,
        );
    }

    // observability overhead: identical serve runs with tracing off
    // (NoopObserver → disabled TraceHub fast path, kstats cold) vs on
    // (Metrics observer with an enabled hub + kernel attribution). The
    // off run is the tier-1 hot path and must not regress; the on run
    // prices the spans + counters for docs/OBSERVABILITY.md.
    {
        use rwkvquant::coordinator::serve::{
            decoder_for, serve_pool_with, NoopObserver, Response, ServeStats,
        };
        use rwkvquant::model::QuantizedModel;
        use rwkvquant::quant::exec::kstats;
        use rwkvquant::server::Metrics;
        use std::sync::mpsc;

        // quantized decoder so the traced run exercises the instrumented
        // Sq/Vq/DenseF16 matvecs, not the dense reference runner
        let mq = generate_rwkv(&ModelConfig::rwkv6(6, 256, 512), Family::Rwkv, 19);
        let qc = QuantConfig { kmeans_iters: 3, ..Default::default() };
        let (q, _) = quantize_model(&mq, None, &qc, 0);
        let qm = QuantizedModel::from_parts(&mq, &q);
        let vocab = qm.config.vocab;
        let requests = || -> Vec<Request> {
            (0..8u64)
                .map(|id| Request::new(id, vec![(id as usize * 31 + 1) % vocab, 4, 5], 8))
                .collect()
        };
        let lanes = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(2, 4);
        let mut decs = (0..lanes)
            .map(|_| decoder_for(&qm))
            .collect::<rwkvquant::Result<Vec<_>>>()
            .unwrap();
        let run = |decs: &mut Vec<_>,
                   obs: &dyn rwkvquant::coordinator::serve::ServeObserver|
         -> (ServeStats, Vec<Response>) {
            let (tx_req, rx_req) = mpsc::channel();
            let (tx_resp, rx_resp) = mpsc::channel();
            for r in requests() {
                tx_req.send(r).unwrap();
            }
            drop(tx_req);
            let opts = ServeOpts::new(4, Duration::from_millis(1)).with_prefill_chunk(16);
            let stats = serve_pool_with(decs, rx_req, tx_resp, &opts, obs).unwrap();
            let mut out: Vec<Response> = rx_resp.iter().collect();
            out.sort_by_key(|r| r.id);
            (stats, out)
        };
        run(&mut decs, &NoopObserver); // warm-up
        let ((off, off_toks), t_off) = b.once(&format!("serve quantized tracing off x{lanes}"), || {
            run(&mut decs, &NoopObserver)
        });
        let metrics = Metrics::new();
        metrics.trace().set_enabled(true);
        kstats::set_enabled(true);
        let ((on, on_toks), t_on) = b.once(&format!("serve quantized tracing on x{lanes}"), || {
            run(&mut decs, &metrics)
        });
        kstats::set_enabled(false);
        // tracing must never perturb the token stream (twin identity)
        for (a, c) in off_toks.iter().zip(&on_toks) {
            assert_eq!(a.tokens, c.tokens, "tracing changed tokens for request {}", a.id);
        }
        let attributed: u64 = kstats::snapshot().iter().map(|&(_, _, calls, _)| calls).sum();
        println!(
            "tracing overhead at batch 4 (quantized L6 d256, {lanes} lanes): \
             {:.1} tok/s off vs {:.1} tok/s on \
             ({:.2}x, wall {:.0} ms vs {:.0} ms, {attributed} matvecs attributed)",
            off.tokens_per_sec(),
            on.tokens_per_sec(),
            on.tokens_per_sec() / off.tokens_per_sec().max(1e-9),
            t_off.as_secs_f64() * 1e3,
            t_on.as_secs_f64() * 1e3,
        );
    }

    // proxy cost on a realistic layer
    let mut w = Matrix::zeros(512, 512);
    rng.fill_normal(&mut w.data, 0.0, 0.05);
    b.bench("proxy P_c+P_f on 512x512", || proxy::compute(&w.data, 4));

    // pipeline parallel speedup
    let model = generate_rwkv(&ModelConfig::rwkv6(4, 128, 256), Family::Rwkv, 3);
    let qc = QuantConfig { method: Method::Gptq, kmeans_iters: 5, ..Default::default() };
    let (_, t1) = b.once("pipeline 1 worker", || quantize_model(&model, None, &qc, 1));
    let (_, tn) = b.once("pipeline N workers", || quantize_model(&model, None, &qc, 0));
    println!(
        "pipeline speedup: {:.2}x ({} cores)",
        t1.as_secs_f64() / tn.as_secs_f64().max(1e-9),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );

    // PJRT decode step (if artifacts present and the pjrt feature is on)
    #[cfg(feature = "pjrt")]
    {
        use rwkvquant::model::ModelWeights;
        use rwkvquant::runtime::artifacts_dir;
        let dir = artifacts_dir();
        if dir.join("rwkv_step.hlo.txt").exists() && dir.join("tiny_rwkv.bin").exists() {
            let weights = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
            let mut session =
                rwkvquant::runtime::rwkv_graph::RwkvSession::load(&dir, &weights).unwrap();
            let mut t = 1usize;
            let s = b.bench("PJRT decode step (tiny rwkv)", || {
                t = (t + 1) % weights.config.vocab;
                session.step(t).unwrap()
            });
            println!("pjrt decode: {:.1} tokens/s", throughput(1.0, s));
        }
    }

    b.report();
}
