//! Figure 1: accuracy vs model size — best SQ (GPTQ), best VQ (GPTVQ)
//! and ours across the lineup. Expected shape: ours on/above both curves
//! at every size, with the gap largest on small models.

use rwkvquant::config::Method;
use rwkvquant::experiments::*;
use rwkvquant::report::Series;

fn main() {
    let lineup: Vec<_> = if fast_mode() { LANGUAGE_LINEUP[..3].to_vec() } else { LANGUAGE_LINEUP.to_vec() };
    let mut s = Series::new(
        "Figure 1 — zero-shot accuracy vs model size (synthetic lineup)",
        "model#",
        &["FP16", "SQ(GPTQ 3.5)", "VQ(GPTVQ 3.5)", "Ours 3.275"],
    );
    for (i, (label, arch, size, fp_acc, fp_ppl)) in lineup.iter().enumerate() {
        let model = build_model(arch, size, 1000);
        let ps = probes(model.config.vocab, 3, 10, 7);
        let ac = auto_calib(&model);
        let map = language_map(*fp_acc, *fp_ppl);
        let acc_of = |method: Method, bpw: f64| {
            let cfg = bench_config(method, bpw, 19);
            map.acc(run_cell(&model, ac.as_ref(), &cfg, &ps).divergence)
        };
        let sq = acc_of(Method::Gptq, 3.5);
        let vq = acc_of(Method::Gptvq, 3.5);
        let ours = acc_of(Method::RwkvQuant, 3.275);
        eprintln!("  {label}: fp {fp_acc:.2} sq {sq:.2} vq {vq:.2} ours {ours:.2}");
        s.point(i as f64, vec![*fp_acc, sq, vq, ours]);
    }
    s.print();
    println!("paper shape: Ours curve dominates SQ-only and VQ-only at every size");
}
