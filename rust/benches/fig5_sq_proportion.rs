//! Figure 5: proportion of layers the coarse-to-fine proxy sends to SQ,
//! RWKV vs LLaMA families at τ_c = 1.5, τ_f = 50 (§4.4: ≈60% vs ≈10%).

use rwkvquant::experiments::build_model;
use rwkvquant::model::synthetic::{generate_llama, size_config};
use rwkvquant::quant::proxy;
use rwkvquant::report::{Cell, Table};

fn share(m: &rwkvquant::model::ModelWeights) -> f64 {
    let idx = m.quantizable_indices();
    let sq = idx
        .iter()
        .filter(|&&i| {
            let p = proxy::compute(&m.layers[i].1.data, 4);
            p.p_c < 1.5 && p.p_f < 50.0
        })
        .count();
    100.0 * sq as f64 / idx.len() as f64
}

fn main() {
    let mut t = Table::new(
        "Figure 5 — SQ-suitable layer proportion (τ_c=1.5, τ_f=50)",
        &["Family", "Model", "SQ %"],
    );
    for size in ["1B", "3B", "7B"] {
        let m = build_model("rwkv6", size, 4242);
        t.row(vec![Cell::s("RWKV"), Cell::s(format!("rwkv6-{size}")), Cell::f(share(&m), 1)]);
    }
    for size in ["1B", "3B", "7B"] {
        let m = generate_llama(&size_config("llama", size), 4242);
        t.row(vec![Cell::s("LLaMA"), Cell::s(format!("llama-{size}")), Cell::f(share(&m), 1)]);
    }
    t.print();
    t.save_csv("fig5_sq_proportion");
    println!("paper: ≈60% of RWKV layers SQ-suitable vs ≈10% for LLaMA");
}
