//! Table 7/11: §3.2 codebook-optimisation ablation for element-wise
//! multiplication weights — hybrid with vs without the X²-weighted
//! codebook, on the trained tiny model (real ppl) and the synthetic
//! lineup (fidelity-mapped).

use rwkvquant::config::{Method, QuantConfig};
use rwkvquant::data::BinCorpus;
use rwkvquant::eval::{dequantized_model, ppl};
use rwkvquant::experiments::*;
use rwkvquant::model::ModelWeights;
use rwkvquant::report::{Cell, Table};
use rwkvquant::runtime::artifacts_dir;

fn main() {
    // real metrics on the trained tiny model
    let dir = artifacts_dir();
    if dir.join("tiny_rwkv.bin").exists() && dir.join("corpus.bin").exists() {
        let m = ModelWeights::load(&dir.join("tiny_rwkv.bin")).unwrap();
        let corpus = BinCorpus::load(&dir.join("corpus.bin")).unwrap();
        let toks = &corpus.valid[..800.min(corpus.valid.len())];
        let calib = rwkvquant::calib::CalibSet::capture(&m, &corpus.calib_windows(8, 16, 3), 128);
        let mut t = Table::new(
            "Table 7 (real): ew-mult codebook optimisation on trained tiny RWKV",
            &["Config", "ppl"],
        );
        t.row(vec![Cell::s("FloatingPoint"), Cell::f(ppl::perplexity(&m, toks), 2)]);
        for (tag, ew) in [("w. (ours)", true), ("wo.", false)] {
            let cfg = QuantConfig {
                ewmul_opt: ew,
                // stress the μ layers: force all layers to VQ
                tau_c: Some(-1.0),
                tau_f: Some(-1.0),
                kmeans_iters: 8,
                vq_bits: 9,
                ..QuantConfig::default()
            };
            let (q, _) = rwkvquant::coordinator::quantize_model(&m, Some(&calib), &cfg, 0);
            let dq = dequantized_model(&m, &q);
            t.row(vec![Cell::s(tag), Cell::f(ppl::perplexity(&dq, toks), 2)]);
        }
        t.print();
        t.save_csv("table7_real");
    }

    // lineup section
    let lineup: Vec<_> = if fast_mode() { LANGUAGE_LINEUP[..3].to_vec() } else { LANGUAGE_LINEUP.to_vec() };
    let mut t = Table::new(
        "Table 7 (lineup): hybrid w./wo. ew-mult codebook optimisation",
        &["Model", "Config", "0-shot9", "LambA."],
    );
    for (label, arch, size, fp_acc, fp_ppl) in &lineup {
        let model = build_model(arch, size, 1000);
        let ps = probes(model.config.vocab, 3, 10, 7);
        let ac = auto_calib(&model);
        let map = language_map(*fp_acc, *fp_ppl);
        for (tag, ew) in [("w.", true), ("wo.", false)] {
            let mut cfg = bench_config(Method::RwkvQuant, 3.275, 21);
            cfg.ewmul_opt = ew;
            // μ layers only matter under VQ; keep default hybrid split
            let cell = run_cell(&model, ac.as_ref(), &cfg, &ps);
            t.row(vec![
                Cell::s(*label),
                Cell::s(tag),
                Cell::f(map.acc(cell.divergence), 2),
                Cell::f(map.ppl(cell.divergence), 2),
            ]);
        }
    }
    t.print();
    t.save_csv("table7_ewmul_ablation");
    println!("paper shape: 'w.' ≥ 'wo.' on ppl for every model (largest gaps on small models)");
}
