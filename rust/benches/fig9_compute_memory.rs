//! Figure 9 / §A.3: compute-to-memory-access ratio per model, plus the
//! §1 QuaRot online-rotation FLOP overhead on RWKV.

use rwkvquant::config::ModelConfig;
use rwkvquant::model::flops::*;
use rwkvquant::model::synthetic::size_config;
use rwkvquant::report::{Cell, Table};

fn main() {
    let mut t = Table::new(
        "Figure 9 — FLOPs/byte: RWKV edge decode (B=1) vs transformer serving (B=8)",
        &["Model", "setting", "FLOPs/token", "bytes/token", "ratio"],
    );
    for size in ["1B", "3B", "7B", "14B"] {
        let cfg = size_config("rwkv6", size);
        let c = rwkv_step(&cfg, &CostModel::edge_decode());
        t.row(vec![
            Cell::s(format!("RWKV6-{size}")),
            Cell::s("edge B=1"),
            Cell::f(c.flops, 0),
            Cell::f(c.bytes, 0),
            Cell::f(c.ratio(), 2),
        ]);
    }
    for size in ["7B", "14B"] {
        let cfg = size_config("llama", size);
        let c = llama_step(&cfg, &CostModel { batch: 8, context: 256, weight_bytes: 2.0 });
        t.row(vec![
            Cell::s(format!("LLaMA-{size}")),
            Cell::s("serving B=8"),
            Cell::f(c.flops, 0),
            Cell::f(c.bytes, 0),
            Cell::f(c.ratio(), 2),
        ]);
    }
    t.print();
    t.save_csv("fig9_compute_memory");

    let mut t2 = Table::new(
        "§1 — QuaRot online-rotation overhead on RWKV (fusion blocked by non-linear ops)",
        &["Model", "base matmul FLOPs", "rotation FLOPs", "overhead %"],
    );
    for (arch, size) in [("rwkv7", "0.1B"), ("rwkv7", "1.47B"), ("rwkv6", "7B")] {
        let cfg: ModelConfig = size_config(arch, size);
        let base = rwkv_base_flops(&cfg) as f64;
        let over = quarot_overhead_flops(&cfg) as f64;
        t2.row(vec![
            Cell::s(format!("{arch}-{size}")),
            Cell::f(base, 0),
            Cell::f(over, 0),
            Cell::f(100.0 * over / base, 1),
        ]);
    }
    t2.print();
    t2.save_csv("fig9_quarot_overhead");
    println!("paper: RWKV ratio ≈0.97 (lowest); QuaRot overhead >99% on RWKV-7");
}
