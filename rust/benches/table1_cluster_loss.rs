//! Table 1: average relative K-Means cluster loss of weights, RWKV vs
//! LLaMA family, at 8 and 16 clusters. Paper shape: RWKV ≈ 2× the loss
//! of LLaMA at 8 clusters (uniform weights cluster poorly).

use rwkvquant::model::synthetic::{generate_llama, generate_rwkv, size_config, Family};
use rwkvquant::model::ParamClass;
use rwkvquant::quant::vq::codebook::relative_cluster_loss;
use rwkvquant::report::{Cell, Table};
use rwkvquant::util::rng::Rng;

fn family_loss(model: &rwkvquant::model::ModelWeights, k: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    let mut n = 0usize;
    for (desc, w) in &model.layers {
        if desc.class != ParamClass::MatMul {
            continue;
        }
        total += relative_cluster_loss(&w.data, k, 15, &mut rng);
        n += 1;
    }
    total / n.max(1) as f64
}

fn main() {
    let mut t = Table::new(
        "Table 1 — avg relative cluster loss (KMeans), % of total variance",
        &["Family", "Model", "8 Clusters", "16 Clusters"],
    );
    let rows = [
        ("RWKV", "rwkv6", "7B"),
        ("RWKV", "rwkv6", "14B"),
        ("LLaMA", "llama", "7B"),
        ("LLaMA", "llama", "14B"),
    ];
    for (fam, arch, size) in rows {
        let cfg = size_config(arch, size);
        let m = if fam == "RWKV" {
            generate_rwkv(&cfg, Family::Rwkv, 42)
        } else {
            generate_llama(&cfg, 42)
        };
        let l8 = family_loss(&m, 8, 8);
        let l16 = family_loss(&m, 16, 16);
        t.row(vec![
            Cell::s(fam),
            Cell::s(format!("{}-{}", if fam == "RWKV" { "6" } else { "2" }, size)),
            Cell::f(l8, 2),
            Cell::f(l16, 2),
        ]);
    }
    t.print();
    t.save_csv("table1_cluster_loss");
    println!("paper: RWKV 2.01/0.78 & 1.98/0.78 vs LLaMA 0.96/0.65 & 0.89/0.64 — \
              expect RWKV clearly above LLaMA at both cluster counts");
}
