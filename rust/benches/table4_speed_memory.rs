//! Table 4: generation speed and memory before/after 3.275-bpw
//! quantization. Reproduced four ways on this CPU testbed:
//!   (a) measured weight-storage bytes fp32/fp16 vs packed quantized,
//!   (b) measured decode-matvec throughput, dense fp32 vs packed
//!       quantized streaming (`quant::exec`), at the lineup's layer
//!       sizes — the memory-bound regime where the paper's speedup
//!       comes from,
//!   (c) the analytic memory-traffic model (model::flops) at each
//!       model scale,
//!   (d) the **served** speedup: the same request set pushed through
//!       `coordinator::serve` with a dense fp32 decoder and a packed
//!       `QuantizedModel` decoder — the number a deployment actually
//!       sees, recorded to `BENCH_serve.json` as the perf baseline for
//!       future PRs.

use rwkvquant::config::Method;
use rwkvquant::coordinator::serve::{
    serve_collect_per_tick_spawn, serve_collect_pool, serve_collect_pool_with, PoolOpts,
    Request, RunnerDecoder, ServeOpts, ServeStats,
};
use rwkvquant::experiments::{bench_config, build_model, fast_mode};
use rwkvquant::model::flops::{rwkv_step, CostModel};
use rwkvquant::model::synthetic::size_config;
use rwkvquant::model::{ModelWeights, QuantizedModel, WeightProvider};
use rwkvquant::quant::exec::{self, Kernel};
use rwkvquant::quant::sq;
use rwkvquant::report::json::Json;
use rwkvquant::report::{Cell, Table};
use rwkvquant::tensor::{linalg, Matrix};
use rwkvquant::util::benchkit::Bencher;
use rwkvquant::util::rng::Rng;
use std::time::Duration;

/// Push a fixed request set through `serve` over the given provider,
/// with `tick_threads` decode lanes per batch tick — on the persistent
/// pool, or on the legacy per-tick-spawn engine when `spawn` is set (the
/// pool's measurement baseline).
fn serve_tokens_per_sec<W: WeightProvider>(
    weights: &W,
    n_req: u64,
    gen_len: usize,
    tick_threads: usize,
    spawn: bool,
) -> ServeStats {
    let vocab = weights.config().vocab;
    let mut decoders: Vec<_> =
        (0..tick_threads.max(1)).map(|_| RunnerDecoder::new(weights)).collect();
    let requests: Vec<Request> = (0..n_req)
        .map(|id| Request::new(id, vec![(id as usize * 13) % vocab, 1, 2, 3], gen_len))
        .collect();
    let (stats, _) = if spawn {
        serve_collect_per_tick_spawn(&mut decoders, requests, 8, Duration::from_millis(1))
            .unwrap()
    } else {
        serve_collect_pool(&mut decoders, requests, 8, Duration::from_millis(1)).unwrap()
    };
    stats
}

fn main() {
    let simd = exec::active_kernel();
    // ---- (b) hot-loop decode matvec: dense fp32 vs packed 3-bit,
    //          scalar vs the detected SIMD kernel ----
    let mut t2 = Table::new(
        format!("Table 4b — decode matvec, fp32 vs packed 3-bit (simd = {})", simd.name()),
        &["dim", "fp32 µs", "scalar µs", "simd µs", "simd/scalar", "fp32/simd", "bytes quant"],
    );
    let mut b = Bencher::new();
    let mut matvec_rows: Vec<Json> = Vec::new();
    for &dim in &[512usize, 1024, 2048] {
        let mut rng = Rng::new(dim as u64);
        let mut w = Matrix::zeros(dim, dim);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let q = sq::rtn::quantize(&w, 3, 64);
        let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; dim];
        let fp = b.bench(&format!("fp32 matvec {dim}"), || {
            linalg::matvec_into(&w, &x, &mut y)
        });
        let fp_ns = fp.median_ns();
        let sc = b.bench(&format!("quant matvec scalar {dim}"), || {
            exec::matvec_sq_with(Kernel::Scalar, &q, &x, &mut y)
        });
        let sc_ns = sc.median_ns();
        let sd = b.bench(&format!("quant matvec {} {dim}", simd.name()), || {
            exec::matvec_sq_with(simd, &q, &x, &mut y)
        });
        let sd_ns = sd.median_ns();
        t2.row(vec![
            Cell::Int(dim as i64),
            Cell::f(fp_ns / 1e3, 1),
            Cell::f(sc_ns / 1e3, 1),
            Cell::f(sd_ns / 1e3, 1),
            Cell::f(sc_ns / sd_ns, 2),
            Cell::f(fp_ns / sd_ns, 2),
            Cell::Int((q.storage_bits() / 8) as i64),
        ]);
        matvec_rows.push(
            Json::obj()
                .set("dim", dim)
                .set("fp32_us", fp_ns / 1e3)
                .set("scalar_us", sc_ns / 1e3)
                .set("simd_us", sd_ns / 1e3)
                .set("simd_speedup", sc_ns / sd_ns),
        );
    }
    t2.print();
    t2.save_csv("table4_matvec");

    // ---- (a)+(c) per-model memory + analytic speedup ----
    let mut t = Table::new(
        "Table 4 — memory use and projected decode speed-up at 3.275 bpw",
        &["Model", "fp16 MB", "Quant MB", "Mem. saving", "analytic speed-up"],
    );
    for &(label, size) in &[("3B", "3B"), ("7B", "7B"), ("14B", "14B")] {
        let m = build_model("rwkv6", size, 77);
        let cfg = bench_config(Method::RwkvQuant, 3.275, 3);
        let (q, rep) = rwkvquant::coordinator::quantize_model(&m, None, &cfg, 0);
        let q_bits: usize = q.values().map(|l| l.storage_bits()).sum();
        let fp_bits: usize = q.values().map(|l| l.numel() * 16).sum();
        // analytic: decode time ∝ bytes moved (memory-bound, Fig. 9)
        let mcfg = size_config("rwkv6", size);
        let fp_cost = rwkv_step(&mcfg, &CostModel { weight_bytes: 2.0, ..CostModel::edge_decode() });
        let q_cost = rwkv_step(
            &mcfg,
            &CostModel { weight_bytes: rep.avg_bpw / 8.0, ..CostModel::edge_decode() },
        );
        t.row(vec![
            Cell::s(format!("RWKV6-{label} (synthetic)")),
            Cell::f(fp_bits as f64 / 8e6, 2),
            Cell::f(q_bits as f64 / 8e6, 2),
            Cell::s(format!("{:.2}x", fp_bits as f64 / q_bits as f64)),
            Cell::s(format!("{:.2}x", fp_cost.bytes / q_cost.bytes)),
        ]);
    }
    t.print();
    t.save_csv("table4_speed_memory");

    // ---- (d) served speedup through coordinator::serve ----
    let (size, n_req, gen_len) = if fast_mode() { ("3B", 8u64, 8usize) } else { ("7B", 16, 16) };
    let m: ModelWeights = build_model("rwkv6", size, 99);
    let cfg = bench_config(Method::RwkvQuant, 3.275, 9);
    let (q, rep) = rwkvquant::coordinator::quantize_model(&m, None, &cfg, 0);
    let qm = QuantizedModel::from_parts(&m, &q);
    let fp_stats = serve_tokens_per_sec(&m, n_req, gen_len, 1, false);
    let q_stats = serve_tokens_per_sec(&qm, n_req, gen_len, 1, false);
    let tick_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4);
    let q_mt_stats = serve_tokens_per_sec(&qm, n_req, gen_len, tick_threads, false);
    let q_spawn_stats = serve_tokens_per_sec(&qm, n_req, gen_len, tick_threads, true);
    let speedup = q_stats.tokens_per_sec() / fp_stats.tokens_per_sec().max(1e-9);
    let mt_speedup = q_mt_stats.tokens_per_sec() / q_stats.tokens_per_sec().max(1e-9);
    let pool_vs_spawn = q_mt_stats.tokens_per_sec() / q_spawn_stats.tokens_per_sec().max(1e-9);
    let mut t3 = Table::new(
        format!("Table 4d — served decode throughput ({} kernel)", simd.name()),
        &["path", "tok/s", "bits/weight", "p50", "p99"],
    );
    t3.row(vec![
        Cell::s("fp32 dense"),
        Cell::f(fp_stats.tokens_per_sec(), 1),
        Cell::f(32.0, 1),
        Cell::s(format!("{:?}", fp_stats.p50_latency)),
        Cell::s(format!("{:?}", fp_stats.p99_latency)),
    ]);
    t3.row(vec![
        Cell::s("packed quant"),
        Cell::f(q_stats.tokens_per_sec(), 1),
        Cell::f(qm.packed_bpw(), 3),
        Cell::s(format!("{:?}", q_stats.p50_latency)),
        Cell::s(format!("{:?}", q_stats.p99_latency)),
    ]);
    t3.row(vec![
        Cell::s(format!("packed quant ×{tick_threads} pool")),
        Cell::f(q_mt_stats.tokens_per_sec(), 1),
        Cell::f(qm.packed_bpw(), 3),
        Cell::s(format!("{:?}", q_mt_stats.p50_latency)),
        Cell::s(format!("{:?}", q_mt_stats.p99_latency)),
    ]);
    t3.row(vec![
        Cell::s(format!("packed quant ×{tick_threads} spawn")),
        Cell::f(q_spawn_stats.tokens_per_sec(), 1),
        Cell::f(qm.packed_bpw(), 3),
        Cell::s(format!("{:?}", q_spawn_stats.p50_latency)),
        Cell::s(format!("{:?}", q_spawn_stats.p99_latency)),
    ]);
    t3.print();
    println!("served speedup (packed vs fp32): {speedup:.2}x");
    println!("threaded-tick speedup (×{tick_threads} pool vs sequential): {mt_speedup:.2}x");
    println!("persistent pool vs per-tick spawn (×{tick_threads}): {pool_vs_spawn:.2}x");

    // ---- (e) batch-64 saturation: chunked prefill TTFT on the packed
    //          path — the time-to-first-token a loaded deployment sees ----
    let (b64_prompt, b64_gen) = if fast_mode() { (16usize, 2usize) } else { (64, 8) };
    let b64_chunk = 32usize;
    let b64_req = 64u64;
    let mut b64_decoders: Vec<_> =
        (0..tick_threads.max(1)).map(|_| RunnerDecoder::new(&qm)).collect();
    let b64_requests: Vec<Request> = (0..b64_req)
        .map(|id| {
            let prompt: Vec<usize> =
                (0..b64_prompt).map(|i| (id as usize * 13 + i * 5 + 1) % qm.config.vocab).collect();
            Request::new(id, prompt, b64_gen)
        })
        .collect();
    let b64_opts =
        ServeOpts::new(64, Duration::from_millis(1)).with_prefill_chunk(b64_chunk);
    let (b64_stats, _) =
        serve_collect_pool_with(&mut b64_decoders, b64_requests, &b64_opts, PoolOpts::default())
            .unwrap();
    let b64_ttft_ms = b64_stats.p50_ttft.as_secs_f64() * 1e3;
    println!(
        "batch-64 (prompt {b64_prompt}, chunk {b64_chunk}): {:.1} tok/s gen, \
         {:.1} tok/s prefill, ttft p50 {:?}",
        b64_stats.tokens_per_sec(),
        b64_stats.prefill_tokens_per_sec(),
        b64_stats.p50_ttft,
    );

    // perf-trajectory baseline for future PRs (the CI bench-baseline job
    // gates on `speedup`, with an absolute quant.tokens_per_sec backstop
    // — see python/check_bench_regression.py)
    let bench = Json::obj()
        .set("bench", "table4d_served")
        .set("model", format!("rwkv6-{size}-synthetic"))
        .set("requests", n_req as usize)
        .set("gen_len", gen_len)
        .set("avg_bpw", rep.avg_bpw)
        .set("kernel", simd.name())
        .set("matvec_simd", Json::Arr(matvec_rows))
        .set(
            "fp32",
            Json::obj()
                .set("tokens_per_sec", fp_stats.tokens_per_sec())
                .set("bits_per_weight", 32.0),
        )
        .set(
            "quant",
            Json::obj()
                .set("tokens_per_sec", q_stats.tokens_per_sec())
                .set("bits_per_weight", qm.packed_bpw()),
        )
        .set(
            "quant_threaded",
            Json::obj()
                .set("tokens_per_sec", q_mt_stats.tokens_per_sec())
                .set("tick_threads", tick_threads)
                .set("engine", "persistent-pool")
                .set("spawn_tokens_per_sec", q_spawn_stats.tokens_per_sec()),
        )
        .set("pool_vs_spawn", pool_vs_spawn)
        .set(
            "batch64",
            Json::obj()
                .set("requests", b64_req as usize)
                .set("prompt_len", b64_prompt)
                .set("gen_len", b64_gen)
                .set("prefill_chunk", b64_chunk)
                .set("tokens_per_sec", b64_stats.tokens_per_sec())
                .set("prefill_tokens_per_sec", b64_stats.prefill_tokens_per_sec())
                .set("ttft_ms", b64_ttft_ms),
        )
        .set("speedup", speedup);
    match std::fs::write("BENCH_serve.json", bench.render()) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }

    b.report();
    println!("paper: 1.55x/2.03x/2.14x speed-up, 3.56x/3.27x/2.83x memory saving");
}
