//! Figure 4: percentile clipping for calibration-batch integration —
//! outliers drag the plain average away from the distribution centre;
//! clipping restores it.

use rwkvquant::quant::ewmul::integrate_batch;
use rwkvquant::report::{Cell, Table};
use rwkvquant::tensor::Matrix;
use rwkvquant::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(44);
    let (samples, n) = (128usize, 256usize);
    // approximately normal activations with injected extreme outliers
    let mut x = Matrix::zeros(samples, n);
    rng.fill_normal(&mut x.data, 0.0, 1.0);
    for _ in 0..samples * n / 200 {
        let i = rng.below(samples * n);
        x.data[i] = rng.normal_ms(0.0, 60.0) as f32;
    }
    let mut t = Table::new(
        "Figure 4 — representative-feature distance to true centre vs clip percentile",
        &["clip %", "max |feature|", "rms distance to 0"],
    );
    for pct in [100.0, 99.9, 99.0, 97.5, 95.0, 90.0] {
        let rep = integrate_batch(&x, pct);
        let maxabs = rep.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let rms = (rep.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / n as f64).sqrt();
        t.row(vec![Cell::f(pct, 1), Cell::f(maxabs as f64, 4), Cell::f(rms, 4)]);
    }
    t.print();
    t.save_csv("fig4_clipping");
    println!("paper shape: distance drops sharply once outliers are clipped (≤99%)");
}
