//! Table 6: proxy ablation — Variance / CV / Range / MAD / MSE / IE
//! versus the coarse-to-fine pair, each driving the same hybrid budget
//! on three lineup models.

use rwkvquant::config::Method;
use rwkvquant::eval::{dequantized_model, output_divergence};
use rwkvquant::experiments::*;
use rwkvquant::quant::proxy::baselines::BaselineProxy;
use rwkvquant::report::{Cell, Table};

fn main() {
    let models = [
        ("RWKV7-0.1B", "rwkv7", "0.1B", 43.02, 14.21),
        ("RWKV7-0.5B", "rwkv7", "0.5B", 48.67, 7.21),
        ("RWKV7-1.47B", "rwkv7", "1.47B", 55.08, 4.80),
    ];
    let mut t = Table::new(
        "Table 6 — proxy ablation (hybrid budget fixed at 90% SQ)",
        &["Proxy", "Model", "0-shot9", "LambA."],
    );
    for (label, arch, size, fp_acc, fp_ppl) in models {
        let model = build_model(arch, size, 1000);
        let ps = probes(model.config.vocab, 3, 10, 7);
        let ac = auto_calib(&model);
        let map = language_map(fp_acc, fp_ppl);
        let cfg = bench_config(Method::RwkvQuant, 3.275, 13);

        for proxy in BaselineProxy::all() {
            let choices = choices_from_baseline(&model, *proxy, 0.9, ac.as_ref(), &cfg);
            let q = quantize_with_choices(&model, ac.as_ref(), &cfg, &choices);
            let d = output_divergence(&model, &dequantized_model(&model, &q), &ps);
            t.row(vec![
                Cell::s(proxy.name()),
                Cell::s(label),
                Cell::f(map.acc(d), 2),
                Cell::f(map.ppl(d), 2),
            ]);
        }
        // ours: coarse-to-fine pair
        let cell = run_cell(&model, ac.as_ref(), &cfg, &ps);
        t.row(vec![
            Cell::s("Ours"),
            Cell::s(label),
            Cell::f(map.acc(cell.divergence), 2),
            Cell::f(map.ppl(cell.divergence), 2),
        ]);
    }
    t.print();
    t.save_csv("table6_proxy_ablation");
    println!("paper shape: Ours best on all three models; IE second; MSE (greedy local) notably worse");
}
