//! Synthetic grammar corpus + tokenizer.
//!
//! Stand-in for the paper's LAMBADA / Wiki2 text (repro substitution —
//! see DESIGN.md): a seeded sparse order-2 Markov grammar over a small
//! vocabulary. It has real learnable structure (the tiny RWKV trained by
//! `python/compile/train.py` reaches well-below-uniform perplexity on
//! it), a held-out split for perplexity, and generators for the nine
//! synthetic zero-shot choice tasks used by [`crate::eval::zeroshot`].

pub mod tokenizer;

use crate::util::rng::Rng;

/// Sparse order-2 Markov grammar over `vocab` tokens.
pub struct Grammar {
    pub vocab: usize,
    /// per (prev2-bucket, prev) state: candidate successors + weights
    succ: Vec<Vec<(usize, f64)>>,
    buckets: usize,
}

impl Grammar {
    /// Build a grammar with `branch` successors per state.
    pub fn new(vocab: usize, branch: usize, seed: u64) -> Grammar {
        let buckets = 8; // prev2 folded into 8 buckets keeps the table small
        let mut rng = Rng::new(seed ^ 0x6772_616d);
        let mut succ = Vec::with_capacity(buckets * vocab);
        for _ in 0..buckets * vocab {
            let mut cands = Vec::with_capacity(branch);
            for _ in 0..branch {
                // Zipf-ish successor weights: few dominant continuations
                let tok = rng.below(vocab);
                let w = rng.gamma(0.7, 1.0) + 0.05;
                cands.push((tok, w));
            }
            succ.push(cands);
        }
        Grammar { vocab, succ, buckets }
    }

    #[inline]
    fn state(&self, prev2: usize, prev: usize) -> usize {
        (prev2 % self.buckets) * self.vocab + prev
    }

    /// Sample the next token given the two previous ones.
    pub fn next(&self, prev2: usize, prev: usize, rng: &mut Rng) -> usize {
        let cands = &self.succ[self.state(prev2, prev)];
        let weights: Vec<f64> = cands.iter().map(|c| c.1).collect();
        cands[rng.categorical(&weights)].0
    }

    /// True conditional probability of `tok` (for task construction).
    pub fn prob(&self, prev2: usize, prev: usize, tok: usize) -> f64 {
        let cands = &self.succ[self.state(prev2, prev)];
        let total: f64 = cands.iter().map(|c| c.1).sum();
        cands
            .iter()
            .filter(|c| c.0 == tok)
            .map(|c| c.1)
            .sum::<f64>()
            / total
    }

    /// The most likely continuation of a state.
    pub fn argmax_next(&self, prev2: usize, prev: usize) -> usize {
        let cands = &self.succ[self.state(prev2, prev)];
        cands
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|c| c.0)
            .unwrap()
    }

    /// Sample a sequence of `len` tokens.
    pub fn sample(&self, len: usize, rng: &mut Rng) -> Vec<usize> {
        let mut out = Vec::with_capacity(len);
        let mut prev2 = rng.below(self.vocab);
        let mut prev = rng.below(self.vocab);
        for _ in 0..len {
            let t = self.next(prev2, prev, rng);
            out.push(t);
            prev2 = prev;
            prev = t;
        }
        out
    }
}

/// A train/validation corpus drawn from one grammar.
pub struct Corpus {
    pub grammar: Grammar,
    pub train: Vec<usize>,
    pub valid: Vec<usize>,
}

impl Corpus {
    pub fn build(vocab: usize, train_len: usize, valid_len: usize, seed: u64) -> Corpus {
        let grammar = Grammar::new(vocab, 6, seed);
        let mut rng = Rng::new(seed ^ 0x636f_7270);
        let train = grammar.sample(train_len, &mut rng);
        let valid = grammar.sample(valid_len, &mut rng);
        Corpus { grammar, train, valid }
    }

    /// Calibration token windows (§4.1: 128 samples from the test set).
    pub fn calib_windows(&self, n: usize, window: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(seed ^ 0x6361_6c69);
        (0..n)
            .map(|_| {
                let start = rng.below(self.valid.len().saturating_sub(window).max(1));
                self.valid[start..(start + window).min(self.valid.len())].to_vec()
            })
            .collect()
    }
}

/// The token corpus written by `python/compile/train.py` (`RWKVC1`):
/// the *same* stream the tiny model was trained on, so Rust-side
/// perplexity is measured against real training distribution.
#[derive(Debug, Clone)]
pub struct BinCorpus {
    pub vocab: usize,
    pub train: Vec<usize>,
    pub valid: Vec<usize>,
}

impl BinCorpus {
    pub fn load(path: &std::path::Path) -> crate::Result<BinCorpus> {
        use anyhow::{bail, Context};
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() < 28 || &bytes[..8] != b"RWKVC1\0\0" {
            bail!("bad corpus magic in {path:?}");
        }
        let vocab = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let tlen = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let vlen = u64::from_le_bytes(bytes[20..28].try_into().unwrap()) as usize;
        let need = 28 + (tlen + vlen) * 4;
        if bytes.len() < need {
            bail!("corpus truncated: {} < {need}", bytes.len());
        }
        let read_tokens = |off: usize, n: usize| {
            (0..n)
                .map(|i| {
                    u32::from_le_bytes(
                        bytes[off + i * 4..off + i * 4 + 4].try_into().unwrap(),
                    ) as usize
                })
                .collect::<Vec<_>>()
        };
        Ok(BinCorpus {
            vocab,
            train: read_tokens(28, tlen),
            valid: read_tokens(28 + tlen * 4, vlen),
        })
    }

    /// Calibration windows from the validation split.
    pub fn calib_windows(&self, n: usize, window: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(seed ^ 0x6269_6e63);
        (0..n)
            .map(|_| {
                let start = rng.below(self.valid.len().saturating_sub(window).max(1));
                self.valid[start..(start + window).min(self.valid.len())].to_vec()
            })
            .collect()
    }
}

/// One zero-shot multiple-choice instance.
#[derive(Debug, Clone)]
pub struct ChoiceTask {
    pub context: Vec<usize>,
    pub choices: Vec<Vec<usize>>,
    pub answer: usize,
}

/// The nine synthetic zero-shot suites (names mirror the paper's tasks;
/// each differs in context length, continuation length and distractor
/// hardness, giving a spread of difficulties like the real suite).
pub const ZERO_SHOT_TASKS: [(&str, usize, usize, f64); 9] = [
    // (name, context_len, cont_len, distractor_temperature)
    ("ARC-c", 24, 3, 0.9),
    ("ARC-e", 16, 2, 0.5),
    ("HQA.", 32, 4, 0.8),
    ("HellaS.", 48, 6, 0.7),
    ("Lam.", 64, 1, 0.6),
    ("OBQA", 20, 3, 1.0),
    ("PIQA", 28, 2, 0.6),
    ("SCIQ", 12, 2, 0.4),
    ("WinoG.", 36, 2, 0.8),
];

/// Generate `n` instances of one task spec from the grammar. The correct
/// choice is a grammar continuation of the context; distractors are
/// random token strings tempered towards plausible unigrams.
pub fn make_task(
    g: &Grammar,
    n: usize,
    ctx_len: usize,
    cont_len: usize,
    hardness: f64,
    seed: u64,
) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let context = g.sample(ctx_len, &mut rng);
            let (mut p2, mut p1) = (
                context[context.len() - 2],
                context[context.len() - 1],
            );
            // correct continuation: greedy grammar path (unambiguous signal)
            let mut correct = Vec::with_capacity(cont_len);
            for _ in 0..cont_len {
                let t = g.argmax_next(p2, p1);
                correct.push(t);
                p2 = p1;
                p1 = t;
            }
            let mut choices = vec![correct];
            for _ in 0..3 {
                // distractor: grammar-sampled with probability `hardness`,
                // else uniform noise — harder tasks have plausible distractors
                let mut d = Vec::with_capacity(cont_len);
                let (mut q2, mut q1) = (
                    context[context.len() - 2],
                    context[context.len() - 1],
                );
                for _ in 0..cont_len {
                    let t = if rng.f64() < hardness {
                        // a non-argmax grammar-plausible token
                        let s = g.next(q2, q1, &mut rng);
                        if s == g.argmax_next(q2, q1) {
                            rng.below(g.vocab)
                        } else {
                            s
                        }
                    } else {
                        rng.below(g.vocab)
                    };
                    d.push(t);
                    q2 = q1;
                    q1 = t;
                }
                choices.push(d);
            }
            // guard against accidental duplicates of the correct answer
            let correct_copy = choices[0].clone();
            for c in choices.iter_mut().skip(1) {
                if *c == correct_copy {
                    c[0] = (c[0] + 1) % g.vocab;
                }
            }
            let answer = rng.below(4);
            choices.swap(0, answer);
            ChoiceTask { context, choices, answer }
        })
        .collect()
}


/// Build choice tasks directly from a token corpus: the correct choice
/// is the *actual* continuation of a validation window, distractors are
/// random token strings. A model trained on the corpus scores above
/// chance; quantization damage pushes it back towards chance — the
/// real-metric path used with the trained tiny model (Tables 5/7, e2e).
pub fn make_task_from_corpus(
    tokens: &[usize],
    vocab: usize,
    n: usize,
    ctx_len: usize,
    cont_len: usize,
    seed: u64,
) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed ^ 0x636f_7230);
    let span = ctx_len + cont_len;
    assert!(tokens.len() > span + 1);
    (0..n)
        .map(|_| {
            let start = rng.below(tokens.len() - span - 1);
            let context = tokens[start..start + ctx_len].to_vec();
            let correct = tokens[start + ctx_len..start + span].to_vec();
            let mut choices = vec![correct.clone()];
            for _ in 0..3 {
                let mut d: Vec<usize> =
                    (0..cont_len).map(|_| rng.below(vocab)).collect();
                if d == correct {
                    d[0] = (d[0] + 1) % vocab;
                }
                choices.push(d);
            }
            let answer = rng.below(4);
            choices.swap(0, answer);
            ChoiceTask { context, choices, answer }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tasks_have_real_continuations() {
        let toks: Vec<usize> = (0..500).map(|i| (i * 7) % 64).collect();
        let tasks = make_task_from_corpus(&toks, 64, 20, 8, 3, 1);
        for t in &tasks {
            assert_eq!(t.choices.len(), 4);
            assert!(t.answer < 4);
        }
    }

    #[test]
    fn grammar_is_deterministic_per_seed() {
        let g1 = Grammar::new(64, 4, 9);
        let g2 = Grammar::new(64, 4, 9);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(g1.sample(50, &mut r1), g2.sample(50, &mut r2));
    }

    #[test]
    fn grammar_has_low_entropy_structure() {
        // the argmax continuation must be much likelier than uniform
        let g = Grammar::new(64, 4, 3);
        let mut better = 0;
        for p1 in 0..64 {
            let am = g.argmax_next(0, p1);
            if g.prob(0, p1, am) > 2.0 / 64.0 {
                better += 1;
            }
        }
        assert!(better > 56, "only {better}/64 states structured");
    }

    #[test]
    fn corpus_splits_differ() {
        let c = Corpus::build(64, 500, 200, 11);
        assert_eq!(c.train.len(), 500);
        assert_eq!(c.valid.len(), 200);
        assert_ne!(&c.train[..200], &c.valid[..]);
    }

    #[test]
    fn calib_windows_shapes() {
        let c = Corpus::build(64, 500, 400, 12);
        let w = c.calib_windows(128, 32, 1);
        assert_eq!(w.len(), 128);
        assert!(w.iter().all(|x| x.len() == 32));
    }

    #[test]
    fn tasks_have_valid_answers() {
        let g = Grammar::new(64, 4, 5);
        let tasks = make_task(&g, 50, 16, 3, 0.7, 2);
        for t in &tasks {
            assert_eq!(t.choices.len(), 4);
            assert!(t.answer < 4);
            assert!(t.choices.iter().all(|c| c.len() == 3));
            // the correct choice differs from all distractors
            let correct = &t.choices[t.answer];
            let dups = t
                .choices
                .iter()
                .enumerate()
                .filter(|(i, c)| *i != t.answer && *c == correct)
                .count();
            assert_eq!(dups, 0);
        }
    }

    #[test]
    fn nine_task_specs() {
        assert_eq!(ZERO_SHOT_TASKS.len(), 9);
    }
}
