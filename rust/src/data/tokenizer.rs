//! Self-contained greedy longest-match tokenizer over a JSON vocab.
//!
//! Dependency-free text ↔ token-id mapping for the OpenAI-compatible
//! endpoints. The algorithm is greedy longest-match ("greedy BPE over a
//! flattened merge table"): at each position the longest vocab entry
//! that prefixes the remaining text wins. That makes encoding a pure
//! function of `(vocab, text)` — no merge ranks, no regex pre-splits —
//! which keeps the determinism story of the serving stack intact.
//!
//! Vocab files are parsed with the existing [`crate::server::json`]
//! parser and accept two shapes:
//!
//! * an array of strings — index is the token id:
//!   `["<unk>", "hello", " world"]`
//! * an object mapping token → id: `{"<unk>": 0, "hello": 1}`
//!
//! Characters no vocab entry covers fall back to the `<unk>` entry when
//! the vocab defines one (the whole char is consumed, so encoding always
//! terminates) and are dropped otherwise. Decoding an out-of-range id
//! likewise produces the `<unk>` string (or nothing). Both behaviours
//! are deliberately lossy-but-total: the serve path must never reject a
//! request because of an exotic byte.

use std::collections::BTreeMap;
use std::path::Path;

/// Conventional unknown-token string; a vocab entry with exactly this
/// text becomes the fallback for uncovered characters.
pub const UNK_TOKEN: &str = "<unk>";

/// Greedy longest-match tokenizer. Construction is O(vocab), encoding
/// is O(text · max_token_len) with `BTreeMap` lookups.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// id → token text (empty string for ids the vocab never named)
    tokens: Vec<String>,
    /// token text → id (first id wins on duplicate strings)
    index: BTreeMap<String, usize>,
    /// longest vocab entry, in bytes — bounds the match window
    max_len: usize,
    /// id of the `<unk>` entry, when the vocab has one
    unk: Option<usize>,
}

impl Tokenizer {
    /// Build from an id-ordered token list.
    pub fn from_tokens(tokens: Vec<String>) -> Tokenizer {
        let mut index = BTreeMap::new();
        let mut max_len = 0;
        let mut unk = None;
        for (id, t) in tokens.iter().enumerate() {
            if t.is_empty() {
                continue; // unnamed id — decodable as nothing, never encoded
            }
            max_len = max_len.max(t.len());
            if t == UNK_TOKEN && unk.is_none() {
                unk = Some(id);
            }
            index.entry(t.clone()).or_insert(id);
        }
        Tokenizer { tokens, index, max_len, unk }
    }

    /// The built-in vocab for synthetic models: id 0 is `<unk>`, id `i`
    /// is the word `"w{i} "` (trailing space included, so decoded text
    /// is naturally word-separated and greedy matching is unambiguous:
    /// `"w12 "` always beats the shorter `"w1"` prefix candidates).
    pub fn synthetic(vocab: usize) -> Tokenizer {
        let tokens: Vec<String> = (0..vocab)
            .map(|i| if i == 0 { UNK_TOKEN.to_string() } else { format!("w{i} ") })
            .collect();
        Tokenizer::from_tokens(tokens)
    }

    /// Parse a vocab document (array-of-strings or token→id object).
    pub fn from_json_str(s: &str) -> Result<Tokenizer, String> {
        use crate::report::json::Json;
        let doc = crate::server::json::parse(s)?;
        match &doc {
            Json::Arr(items) => {
                let mut tokens = Vec::with_capacity(items.len());
                for (i, item) in items.iter().enumerate() {
                    match item.as_str() {
                        Some(t) => tokens.push(t.to_string()),
                        None => return Err(format!("vocab[{i}] is not a string")),
                    }
                }
                Ok(Tokenizer::from_tokens(tokens))
            }
            Json::Obj(map) => {
                let mut pairs = Vec::with_capacity(map.len());
                let mut max_id = 0usize;
                for (tok, id) in map {
                    let id = id
                        .as_usize()
                        .ok_or_else(|| format!("vocab id for {tok:?} is not a non-negative integer"))?;
                    max_id = max_id.max(id);
                    pairs.push((tok.clone(), id));
                }
                if max_id >= pairs.len().saturating_mul(16).max(1024 * 1024) {
                    return Err(format!("vocab id {max_id} is implausibly sparse"));
                }
                let mut tokens = vec![String::new(); max_id + 1];
                for (tok, id) in pairs {
                    if !tokens[id].is_empty() && tokens[id] != tok {
                        return Err(format!("vocab ids collide at {id}"));
                    }
                    tokens[id] = tok;
                }
                Ok(Tokenizer::from_tokens(tokens))
            }
            _ => Err("vocab must be a JSON array of strings or a token→id object".into()),
        }
    }

    /// Load a vocab file from disk.
    pub fn load(path: &Path) -> Result<Tokenizer, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read vocab {}: {e}", path.display()))?;
        Tokenizer::from_json_str(&text)
    }

    /// Number of ids (dense; includes unnamed gap ids for object vocabs).
    pub fn vocab(&self) -> usize {
        self.tokens.len()
    }

    /// Token text for an id, if the id is in range and named.
    pub fn token(&self, id: usize) -> Option<&str> {
        self.tokens.get(id).map(String::as_str).filter(|t| !t.is_empty())
    }

    /// The `<unk>` id, when the vocab defines one.
    pub fn unk_id(&self) -> Option<usize> {
        self.unk
    }

    /// Greedy longest-match encode. Total: every input char is consumed,
    /// either by a vocab entry or by the `<unk>` fallback (dropped when
    /// the vocab has no `<unk>`).
    pub fn encode(&self, text: &str) -> Vec<usize> {
        let mut out = Vec::new();
        let bytes = text.len();
        let mut i = 0;
        while i < bytes {
            let window = self.max_len.min(bytes - i);
            let mut matched = 0;
            for l in (1..=window).rev() {
                if !text.is_char_boundary(i + l) {
                    continue;
                }
                if let Some(&id) = self.index.get(&text[i..i + l]) {
                    out.push(id);
                    matched = l;
                    break;
                }
            }
            if matched == 0 {
                if let Some(unk) = self.unk {
                    out.push(unk);
                }
                // skip one whole char (i is always a boundary here)
                let ch = text[i..].chars().next().expect("non-empty remainder");
                matched = ch.len_utf8();
            }
            i += matched;
        }
        out
    }

    /// Concatenate the token strings for a sequence of ids. Out-of-range
    /// or unnamed ids decode as `<unk>` (or nothing without one).
    pub fn decode(&self, ids: &[usize]) -> String {
        let mut out = String::new();
        for &id in ids {
            match self.token(id) {
                Some(t) => out.push_str(t),
                None => {
                    if self.unk.is_some() {
                        out.push_str(UNK_TOKEN);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_vocab_entry_round_trips() {
        let tok = Tokenizer::synthetic(512);
        assert_eq!(tok.vocab(), 512);
        for id in 0..tok.vocab() {
            let text = tok.token(id).unwrap().to_string();
            assert_eq!(tok.encode(&text), vec![id], "entry {id} ({text:?})");
            assert_eq!(tok.decode(&[id]), text);
        }
    }

    #[test]
    fn greedy_longest_match_beats_prefixes() {
        // "w51 " and "w511 " share a prefix; longest must win
        let tok = Tokenizer::synthetic(512);
        assert_eq!(tok.encode("w511 w51 w5 "), vec![511, 51, 5]);
        let ids = tok.encode("w3 w1 w2 ");
        assert_eq!(ids, vec![3, 1, 2]);
        assert_eq!(tok.decode(&ids), "w3 w1 w2 ");
    }

    #[test]
    fn unknown_chars_fall_back_to_unk() {
        let tok = Tokenizer::synthetic(16);
        // 'x', 'y' are uncovered; each char maps to one <unk>
        assert_eq!(tok.encode("xy"), vec![0, 0]);
        // multi-byte uncovered chars consume the whole char, not one byte
        assert_eq!(tok.encode("日本"), vec![0, 0]);
        assert_eq!(tok.decode(&[0]), "<unk>");
        // out-of-range ids decode as <unk> too
        assert_eq!(tok.decode(&[9999]), "<unk>");
    }

    #[test]
    fn utf8_vocab_round_trips() {
        let tok = Tokenizer::from_tokens(
            ["<unk>", "héllo", " wörld", "日本語", "é", "🦀"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        );
        for id in 0..tok.vocab() {
            let text = tok.token(id).unwrap().to_string();
            assert_eq!(tok.encode(&text), vec![id], "entry {id} ({text:?})");
        }
        let text = "héllo wörld日本語é🦀";
        let ids = tok.encode(text);
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn array_vocab_parses() {
        let tok = Tokenizer::from_json_str(r#"["<unk>", "ab", "abc", "b"]"#).unwrap();
        assert_eq!(tok.vocab(), 4);
        assert_eq!(tok.encode("abcab"), vec![2, 1]);
        assert_eq!(tok.unk_id(), Some(0));
    }

    #[test]
    fn object_vocab_parses_with_gaps() {
        let tok =
            Tokenizer::from_json_str(r#"{"<unk>": 0, "hi": 3, " there": 1}"#).unwrap();
        assert_eq!(tok.vocab(), 4);
        assert_eq!(tok.encode("hi there"), vec![3, 1]);
        assert_eq!(tok.token(2), None); // gap id decodes as <unk>
        assert_eq!(tok.decode(&[3, 2]), "hi<unk>");
    }

    #[test]
    fn malformed_vocabs_are_rejected() {
        assert!(Tokenizer::from_json_str("42").is_err());
        assert!(Tokenizer::from_json_str(r#"[1, 2]"#).is_err());
        assert!(Tokenizer::from_json_str(r#"{"a": -1}"#).is_err());
        assert!(Tokenizer::from_json_str(r#"{"a": 0, "b": 0}"#).is_err());
    }

    #[test]
    fn vocab_without_unk_drops_unknown_chars() {
        let tok = Tokenizer::from_tokens(vec!["ab".into(), "c".into()]);
        assert_eq!(tok.encode("abzc"), vec![0, 1]);
        assert_eq!(tok.decode(&[0, 7]), "ab"); // out-of-range id: nothing
    }
}
