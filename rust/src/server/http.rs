//! Dependency-free HTTP/1.1 substrate for the serving gateway: a
//! hardened request parser, response writers (fixed-length and chunked),
//! and a small client used by the tests, the e2e example and the CI
//! smoke driver.
//!
//! The parser is written for a network boundary, not a friendly peer:
//! every length is bounded ([`Limits`]), both `Content-Length` and
//! `chunked` request bodies are supported, and **every** malformed,
//! truncated or oversized input maps to a clean [`HttpError`] with an
//! HTTP status — never a panic (fuzzed in `rust/tests/prop_http.rs`).
//! Bytes are read one at a time through `BufRead`, so a hostile peer
//! cannot make a header line allocate beyond its cap.

use std::io::{self, BufRead, Read, Write};

/// Parser bounds. Exceeding a header bound maps to 431, a body bound to
/// 413; everything else malformed is a 400.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Longest accepted request line (method + target + version).
    pub max_request_line: usize,
    /// Longest accepted single header line.
    pub max_header_line: usize,
    /// Most accepted header fields.
    pub max_headers: usize,
    /// Largest accepted body, whatever the framing.
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_request_line: 8 << 10,
            max_header_line: 8 << 10,
            max_headers: 64,
            max_body: 1 << 20,
        }
    }
}

/// Why a request could not be parsed (or a socket died). `status()`
/// says what to answer — `None` means the connection is beyond help
/// (I/O failure), just close it.
#[derive(Debug)]
pub enum HttpError {
    /// Malformed syntax, truncated framing, invalid lengths → 400.
    Bad(String),
    /// Body beyond [`Limits::max_body`] → 413.
    BodyTooLarge(String),
    /// Header section beyond its limits → 431.
    HeadersTooLarge(String),
    /// A `Transfer-Encoding` this server does not implement → 501.
    Unsupported(String),
    /// An HTTP version this server does not speak → 505.
    Version(String),
    /// The socket failed mid-request; no response can be delivered.
    Io(io::Error),
}

impl HttpError {
    /// Status line to answer with, `None` for dead-socket errors.
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::Bad(_) => Some(400),
            HttpError::BodyTooLarge(_) => Some(413),
            HttpError::HeadersTooLarge(_) => Some(431),
            HttpError::Unsupported(_) => Some(501),
            HttpError::Version(_) => Some(505),
            HttpError::Io(_) => None,
        }
    }

    pub fn message(&self) -> String {
        match self {
            HttpError::Bad(m)
            | HttpError::BodyTooLarge(m)
            | HttpError::HeadersTooLarge(m)
            | HttpError::Unsupported(m)
            | HttpError::Version(m) => m.clone(),
            HttpError::Io(e) => e.to_string(),
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.status() {
            Some(code) => write!(f, "{} {}: {}", code, reason_phrase(code), self.message()),
            None => write!(f, "connection error: {}", self.message()),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<io::Error> for HttpError {
    fn from(e: io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

/// A parsed request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    /// Raw request target (`/path?query`).
    pub target: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header with this name (case-insensitive), trimmed.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Target path without the query string.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// Query string after `?`, if any.
    pub fn query(&self) -> Option<&str> {
        self.target.split_once('?').map(|(_, q)| q)
    }
}

/// Read one CRLF/LF-terminated line, capped at `cap` bytes (excluding
/// the terminator). `Ok(None)` = clean EOF before any byte.
fn read_line<R: BufRead>(
    r: &mut R,
    cap: usize,
    over: impl Fn(String) -> HttpError,
) -> Result<Option<Vec<u8>>, HttpError> {
    let mut line: Vec<u8> = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                return if line.is_empty() {
                    Ok(None)
                } else {
                    Err(HttpError::Bad("connection closed mid-line".into()))
                };
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return Ok(Some(line));
                }
                if line.len() >= cap {
                    return Err(over(format!("line exceeds {cap} bytes")));
                }
                line.push(byte[0]);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
}

fn read_exact_body<R: BufRead>(r: &mut R, out: &mut Vec<u8>, n: usize) -> Result<(), HttpError> {
    let start = out.len();
    out.resize(start + n, 0);
    r.read_exact(&mut out[start..]).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            HttpError::Bad("body truncated before the declared length".into())
        } else {
            HttpError::Io(e)
        }
    })
}

fn valid_token(s: &str) -> bool {
    !s.is_empty()
        && s.bytes().all(|b| b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b))
}

/// Parse one request off the stream. `Ok(None)` = the peer closed the
/// connection cleanly between requests (normal keep-alive end).
pub fn read_request<R: BufRead>(
    r: &mut R,
    limits: &Limits,
) -> Result<Option<HttpRequest>, HttpError> {
    let Some(line) = read_line(r, limits.max_request_line, HttpError::HeadersTooLarge)? else {
        return Ok(None);
    };
    let line = String::from_utf8(line)
        .map_err(|_| HttpError::Bad("request line is not UTF-8".into()))?;
    let mut parts = line.split(' ').filter(|p| !p.is_empty());
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v.to_string()),
        _ => return Err(HttpError::Bad(format!("malformed request line '{line}'"))),
    };
    if !valid_token(&method) {
        return Err(HttpError::Bad(format!("invalid method '{method}'")));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::Version(format!("unsupported version '{version}'")));
    }

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = read_line(r, limits.max_header_line, HttpError::HeadersTooLarge)?
            .ok_or_else(|| HttpError::Bad("connection closed inside the header block".into()))?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(HttpError::HeadersTooLarge(format!(
                "more than {} header fields",
                limits.max_headers
            )));
        }
        let line = String::from_utf8(line)
            .map_err(|_| HttpError::Bad("header line is not UTF-8".into()))?;
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::Bad(format!("header line without ':': '{line}'")))?;
        if !valid_token(name) {
            return Err(HttpError::Bad(format!("invalid header name '{name}'")));
        }
        headers.push((name.to_string(), value.trim().to_string()));
    }

    let mut req = HttpRequest { method, target, version, headers, body: Vec::new() };
    // owned copies: the borrows must end before the body is filled in
    let content_length = req.header("content-length").map(str::to_string);
    let transfer_encoding = req.header("transfer-encoding").map(str::to_string);
    match (content_length.as_deref(), transfer_encoding.as_deref()) {
        (Some(_), Some(_)) => {
            return Err(HttpError::Bad(
                "both Content-Length and Transfer-Encoding present".into(),
            ));
        }
        (Some(cl), None) => {
            let n: usize = cl
                .parse()
                .map_err(|_| HttpError::Bad(format!("invalid Content-Length '{cl}'")))?;
            if n > limits.max_body {
                return Err(HttpError::BodyTooLarge(format!(
                    "Content-Length {n} exceeds the {}-byte limit",
                    limits.max_body
                )));
            }
            read_exact_body(r, &mut req.body, n)?;
        }
        (None, Some(te)) => {
            if !te.eq_ignore_ascii_case("chunked") {
                return Err(HttpError::Unsupported(format!(
                    "Transfer-Encoding '{te}' is not implemented"
                )));
            }
            read_chunked_body(r, &mut req.body, limits)?;
        }
        (None, None) => {}
    }
    Ok(Some(req))
}

/// Decode a `Transfer-Encoding: chunked` body into `out`, bounded by
/// `limits.max_body` across all chunks.
fn read_chunked_body<R: BufRead>(
    r: &mut R,
    out: &mut Vec<u8>,
    limits: &Limits,
) -> Result<(), HttpError> {
    loop {
        let line = read_line(r, limits.max_header_line, HttpError::Bad)?
            .ok_or_else(|| HttpError::Bad("truncated chunked body (missing size line)".into()))?;
        let line = String::from_utf8(line)
            .map_err(|_| HttpError::Bad("chunk size line is not UTF-8".into()))?;
        // chunk extensions (";ext=val") are tolerated and ignored
        let size_text = line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_text, 16)
            .map_err(|_| HttpError::Bad(format!("invalid chunk size '{size_text}'")))?;
        if size == 0 {
            // trailer section: lines until the final empty line
            loop {
                let t = read_line(r, limits.max_header_line, HttpError::Bad)?.ok_or_else(
                    || HttpError::Bad("truncated chunked body (missing final CRLF)".into()),
                )?;
                if t.is_empty() {
                    return Ok(());
                }
            }
        }
        if out.len().saturating_add(size) > limits.max_body {
            return Err(HttpError::BodyTooLarge(format!(
                "chunked body exceeds the {}-byte limit",
                limits.max_body
            )));
        }
        read_exact_body(r, out, size)?;
        let sep = read_line(r, 2, HttpError::Bad)?
            .ok_or_else(|| HttpError::Bad("truncated chunked body (missing chunk CRLF)".into()))?;
        if !sep.is_empty() {
            return Err(HttpError::Bad("chunk data not followed by CRLF".into()));
        }
    }
}

/// Canonical reason phrase for the status codes this server emits.
pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (status line, `headers`,
/// `Content-Length`, body) and flush.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    headers: &[(&str, &str)],
    body: &[u8],
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, reason_phrase(status))?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Content-Length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()
}

/// Incremental `Transfer-Encoding: chunked` response writer — the SSE
/// stream's transport. Every [`ChunkedWriter::chunk`] is flushed so a
/// token reaches the client as soon as the tick produced it.
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the response head (with `Transfer-Encoding: chunked`) and
    /// hand back the body writer.
    pub fn begin(mut w: W, status: u16, headers: &[(&str, &str)]) -> io::Result<ChunkedWriter<W>> {
        write!(w, "HTTP/1.1 {} {}\r\n", status, reason_phrase(status))?;
        for (k, v) in headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "Transfer-Encoding: chunked\r\n\r\n")?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        write!(self.w, "\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream (`0\r\n\r\n`).
    pub fn finish(mut self) -> io::Result<()> {
        write!(self.w, "0\r\n\r\n")?;
        self.w.flush()
    }
}

// ---- client side (tests, e2e example, CI smoke twin) ----

/// A parsed response: status, headers, body (chunked transfer decoded).
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }
}

/// Read one full response (client side). Generous limits — this side
/// talks to our own server, not the internet.
pub fn read_response<R: BufRead>(r: &mut R) -> Result<HttpResponse, HttpError> {
    let limits = Limits { max_body: 64 << 20, max_headers: 256, ..Limits::default() };
    let line = read_line(r, limits.max_header_line, HttpError::Bad)?
        .ok_or_else(|| HttpError::Bad("connection closed before the status line".into()))?;
    let line = String::from_utf8(line)
        .map_err(|_| HttpError::Bad("status line is not UTF-8".into()))?;
    let mut parts = line.splitn(3, ' ');
    let (version, code) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Bad(format!("malformed status line '{line}'")));
    }
    let status: u16 = code
        .parse()
        .map_err(|_| HttpError::Bad(format!("invalid status code '{code}'")))?;
    let mut headers = Vec::new();
    loop {
        let line = read_line(r, limits.max_header_line, HttpError::Bad)?
            .ok_or_else(|| HttpError::Bad("connection closed inside response headers".into()))?;
        if line.is_empty() {
            break;
        }
        let line = String::from_utf8(line)
            .map_err(|_| HttpError::Bad("response header is not UTF-8".into()))?;
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.to_string(), v.trim().to_string()));
        }
    }
    let mut resp = HttpResponse { status, headers, body: Vec::new() };
    let te_chunked = resp
        .header("transfer-encoding")
        .is_some_and(|v| v.eq_ignore_ascii_case("chunked"));
    let content_length = resp.header("content-length").map(str::to_string);
    if te_chunked {
        read_chunked_body(r, &mut resp.body, &limits)?;
    } else if let Some(cl) = content_length {
        let n: usize =
            cl.parse().map_err(|_| HttpError::Bad(format!("invalid Content-Length '{cl}'")))?;
        if n > limits.max_body {
            return Err(HttpError::BodyTooLarge(format!("response body {n} too large")));
        }
        read_exact_body(r, &mut resp.body, n)?;
    } else {
        // no framing: body runs to connection close
        r.read_to_end(&mut resp.body).map_err(HttpError::Io)?;
    }
    Ok(resp)
}

/// One-shot client request against `addr` (connect → send → read →
/// close). `body = None` sends no body and no Content-Length.
pub fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> crate::Result<HttpResponse> {
    use anyhow::Context;
    let stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connect to {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut w = stream.try_clone().context("clone client socket")?;
    write!(w, "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n")?;
    match body {
        Some(b) => {
            write!(w, "Content-Type: application/json\r\nContent-Length: {}\r\n\r\n", b.len())?;
            w.write_all(b.as_bytes())?;
        }
        None => write!(w, "\r\n")?,
    }
    w.flush()?;
    let mut r = io::BufReader::new(stream);
    read_response(&mut r).map_err(|e| anyhow::anyhow!("reading response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(bytes: &[u8]) -> Result<Option<HttpRequest>, HttpError> {
        read_request(&mut Cursor::new(bytes), &Limits::default())
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse(b"GET /healthz?probe=1 HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path(), "/healthz");
        assert_eq!(req.query(), Some("probe=1"));
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_content_length_body() {
        let req = parse(b"POST /v1/generate HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello")
            .unwrap()
            .unwrap();
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn parses_chunked_body_with_extensions_and_trailers() {
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    4;ext=1\r\nwiki\r\n5\r\npedia\r\n0\r\nTrailer: v\r\n\r\n";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.body, b"wikipedia");
    }

    #[test]
    fn clean_eof_is_none_truncation_is_error() {
        assert!(parse(b"").unwrap().is_none());
        let full = b"POST /x HTTP/1.1\r\nContent-Length: 4\r\n\r\nbody";
        for cut in 1..full.len() {
            let r = parse(&full[..cut]);
            assert!(r.is_err(), "prefix of {cut} bytes must be an error");
            assert!(
                r.err().unwrap().status().is_some_and(|s| (400..500).contains(&s)),
                "prefix of {cut} bytes must map to a 4xx"
            );
        }
    }

    #[test]
    fn truncated_chunked_bodies_are_4xx() {
        let full = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nwiki\r\n0\r\n\r\n";
        for cut in 1..full.len() {
            let r = parse(&full[..cut]);
            assert!(r.is_err(), "chunked prefix of {cut} bytes must error");
        }
    }

    #[test]
    fn oversized_inputs_map_to_413_and_431() {
        let big_header = format!("GET / HTTP/1.1\r\nX-Big: {}\r\n\r\n", "a".repeat(10 << 10));
        assert_eq!(parse(big_header.as_bytes()).err().unwrap().status(), Some(431));

        let many: String = (0..100).map(|i| format!("H{i}: v\r\n")).collect();
        let too_many = format!("GET / HTTP/1.1\r\n{many}\r\n");
        assert_eq!(parse(too_many.as_bytes()).err().unwrap().status(), Some(431));

        let big_body = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 2 << 20);
        assert_eq!(parse(big_body.as_bytes()).err().unwrap().status(), Some(413));

        let big_chunk = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nfffffff\r\n";
        assert_eq!(parse(big_chunk).err().unwrap().status(), Some(413));
    }

    #[test]
    fn protocol_violations_have_specific_statuses() {
        assert_eq!(parse(b"GET / HTTP/2.0\r\n\r\n").err().unwrap().status(), Some(505));
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n").err().unwrap().status(),
            Some(501)
        );
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 1\r\nTransfer-Encoding: chunked\r\n\r\nx")
                .err()
                .unwrap()
                .status(),
            Some(400)
        );
        assert_eq!(parse(b"GET/ HTTP/1.1\r\n\r\n").err().unwrap().status(), Some(400));
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n").err().unwrap().status(),
            Some(400)
        );
        assert_eq!(parse(b"G@T / HTTP/1.1\r\n\r\n").err().unwrap().status(), Some(400));
        let no_colon = parse(b"GET / HTTP/1.1\r\nNo-Colon-Here\r\n\r\n");
        assert_eq!(no_colon.err().unwrap().status(), Some(400));
    }

    #[test]
    fn chunked_writer_round_trips_through_the_client_reader() {
        let mut wire = Vec::new();
        {
            let mut cw = ChunkedWriter::begin(
                &mut wire,
                200,
                &[("Content-Type", "text/event-stream")],
            )
            .unwrap();
            cw.chunk(b"data: {\"token\":5}\n\n").unwrap();
            cw.chunk(b"").unwrap(); // no-op, must not terminate
            cw.chunk(b"data: {\"done\":true}\n\n").unwrap();
            cw.finish().unwrap();
        }
        let resp = read_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("text/event-stream"));
        assert_eq!(
            resp.body_str(),
            "data: {\"token\":5}\n\ndata: {\"done\":true}\n\n"
        );
    }

    #[test]
    fn write_response_round_trips() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, &[("Retry-After", "1")], b"{\"error\":\"queue full\"}")
            .unwrap();
        let resp = read_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.body, b"{\"error\":\"queue full\"}");
    }

    #[test]
    fn bare_lf_line_endings_are_tolerated() {
        let req = parse(b"GET / HTTP/1.1\nHost: x\n\n").unwrap().unwrap();
        assert_eq!(req.header("host"), Some("x"));
    }
}
