//! The HTTP serving front end — the first network boundary of the
//! codebase, built dependency-free on `std::net`:
//!
//! * [`http`] — hardened HTTP/1.1 request parser (bounded, fuzzed,
//!   chunked-body capable), response writers (fixed + chunked), and the
//!   small client the tests and smoke drivers use.
//! * [`json`] — JSON parser for request bodies, sharing the
//!   [`crate::report::json::Json`] value type with the emitter.
//! * [`metrics`] — live Prometheus-text metrics registry, fed by the
//!   serve loop through [`crate::coordinator::serve::ServeObserver`].
//! * [`signal`] — SIGINT/SIGTERM → graceful-drain flag (raw `signal(2)`,
//!   no `signal_hook` in the offline vendor set).
//! * [`gateway`] — the connection loop tying it together: JSON requests
//!   in, SSE token streams out, bounded admission with 429 shedding,
//!   `/healthz` + `/metrics`, drain-to-completion shutdown, plus the
//!   OpenAI-compatible text endpoints (`/v1/completions`,
//!   `/v1/chat/completions`) with seeded sampling, stop sequences and
//!   disconnect cancellation over [`crate::data::tokenizer`].
//!
//! The gateway and the CLI's in-process mode share one engine: both run
//! `coordinator::serve` over a persistent `TickPool`, so HTTP serving is
//! token-identical to `serve_collect` on the same store by construction
//! (and asserted over real sockets in `rust/tests/integration_gateway.rs`).

pub mod gateway;
pub mod http;
pub mod json;
pub mod metrics;
pub mod signal;

pub use gateway::{Gateway, GatewayConfig, GatewayHandle};
pub use metrics::Metrics;
