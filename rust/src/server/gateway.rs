//! The HTTP serving gateway: the first network boundary in the
//! codebase. A `TcpListener` accept loop feeds per-connection handler
//! threads; each generation request is parsed ([`super::http`] +
//! [`super::json`]), forwarded into the **same** `coordinator::serve`
//! loop the CLI uses (over a persistent `TickPool`), and its tokens are
//! streamed back incrementally as Server-Sent Events over chunked
//! transfer — one SSE chunk per tick-produced token.
//!
//! Operational behaviour:
//!
//! * **Admission control** — the serve loop's bounded queue
//!   (`--max-queue`) sheds overflow; a shed request is answered `429
//!   Too Many Requests` (with `Retry-After`) and counted in `/metrics`.
//!   A connection cap answers `503` before parsing when the handler
//!   pool is exhausted.
//! * **Observability** — `GET /healthz` for probes, `GET /metrics` in
//!   Prometheus text format ([`Metrics`]): served tokens/sec, queue
//!   depth + high-water mark, shed count, latency and admission-wait
//!   quantiles.
//! * **Graceful drain** — [`GatewayHandle::shutdown`] (or
//!   SIGINT/SIGTERM when [`GatewayConfig::heed_signals`] is set) stops
//!   the accept loop, closes the listener, lets every in-flight request
//!   decode to completion through the tick pool, then returns the
//!   session's [`ServeStats`]. The process exits 0 — never mid-tick.
//!
//! Beyond the raw token-id endpoint (`POST /v1/generate`) the gateway
//! speaks the OpenAI text protocol: `POST /v1/completions` and `POST
//! /v1/chat/completions` accept text, tokenize it with the gateway's
//! [`Tokenizer`], decode under per-request [`SampleParams`] (seeded, so
//! identical requests produce identical bytes), honour `max_tokens` and
//! `stop` sequences with the matching `finish_reason`, and answer
//! either one JSON document or OpenAI-style SSE delta chunks terminated
//! by `data: [DONE]`. Stop sequences are matched on token boundaries
//! and the matched text is **included** in the output.
//!
//! Request cancellation is cooperative: when a streaming write fails
//! (the client hung up mid-response) the handler raises the request's
//! cancel flag, and the serve loop retires the sequence on its next
//! tick — the state-pool slab and tick budget are released instead of
//! decoding an orphan to completion. Cancelled requests are counted in
//! `/metrics` and finish with reason `cancelled`. A non-streaming
//! request writes nothing until it completes, so a disconnect there is
//! only discovered (and the response discarded) at the final write.

use crate::coordinator::sampler::SampleParams;
use crate::coordinator::serve::{
    with_tick_pool_opts, Decoder, FinishReason, PoolOpts, Request, Response, ServeOpts,
    ServeStats, StreamEvent,
};
use crate::data::tokenizer::Tokenizer;
use crate::report::json::Json;
use crate::server::http::{self, ChunkedWriter, HttpRequest, Limits};
use crate::server::metrics::Metrics;
use crate::server::{json, signal};
use crate::Result;
use anyhow::Context;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Accept-loop poll cadence while idle (the listener is non-blocking so
/// the loop can observe the shutdown flag).
const ACCEPT_POLL: Duration = Duration::from_millis(15);
/// Per-connection read timeout: bounds how long an idle keep-alive
/// connection can delay a drain.
const CONN_READ_TIMEOUT: Duration = Duration::from_secs(5);
/// Per-connection write timeout: a client that stops reading its
/// response cannot park a handler thread (and its admission-channel
/// clone) forever — the stalled write errors out and the connection is
/// dropped, so a drain always completes.
const CONN_WRITE_TIMEOUT: Duration = Duration::from_secs(30);
/// Longest accepted prompt, in tokens.
const MAX_PROMPT: usize = 4096;

/// Gateway policy. `addr` is `host:port` (`:0` binds an ephemeral port,
/// reported by [`Gateway::local_addr`]).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub addr: String,
    /// Continuous-batching width of the serve session.
    pub max_batch: usize,
    /// Batch-forming wait of the serve session.
    pub max_wait: Duration,
    /// Bounded admission queue: overflow is shed with a 429.
    pub max_queue: usize,
    /// Per-request `gen_len` cap (400 beyond it).
    pub max_gen_len: usize,
    /// Concurrent-connection cap (503 beyond it).
    pub max_connections: usize,
    /// Prompt tokens a prefilling sequence consumes per tick
    /// (`ServeOpts::prefill_chunk`; 1 = legacy one-per-tick).
    pub prefill_chunk: usize,
    /// State-arena slabs (`ServeOpts::state_slots`); `0` = one per
    /// batch slot.
    pub state_slots: usize,
    /// Pin tick worker lanes to CPUs (`PoolOpts::pin_workers`).
    pub pin_workers: bool,
    /// Also drain on SIGINT/SIGTERM (requires
    /// [`signal::install_shutdown_signals`]; the CLI sets this, tests
    /// use the explicit handle so a test-raised signal cannot leak into
    /// unrelated gateways).
    pub heed_signals: bool,
}

impl GatewayConfig {
    pub fn new(addr: impl Into<String>) -> GatewayConfig {
        GatewayConfig {
            addr: addr.into(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_queue: 64,
            max_gen_len: 512,
            max_connections: 128,
            prefill_chunk: 32,
            state_slots: 0,
            pin_workers: false,
            heed_signals: false,
        }
    }
}

/// A bound (but not yet serving) gateway. Two-phase so callers learn
/// the ephemeral port and can clone a [`GatewayHandle`] before the
/// blocking [`Gateway::serve`] call.
pub struct Gateway {
    listener: TcpListener,
    cfg: GatewayConfig,
    vocab: usize,
    tokenizer: Tokenizer,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
}

/// Clonable remote control for a running gateway.
#[derive(Clone)]
pub struct GatewayHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
}

impl GatewayHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin a graceful drain: stop accepting, finish in-flight work,
    /// return from [`Gateway::serve`].
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

impl Gateway {
    /// Bind the listener; serving starts with [`Gateway::serve`]. The
    /// text endpoints start out on the synthetic `w{i} ` vocab —
    /// override with [`Gateway::with_tokenizer`] for real models.
    pub fn bind(cfg: GatewayConfig, vocab: usize) -> Result<Gateway> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        Ok(Gateway {
            listener,
            cfg,
            vocab,
            tokenizer: Tokenizer::synthetic(vocab),
            shutdown: Arc::new(AtomicBool::new(false)),
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Replace the tokenizer backing the text endpoints (e.g. one
    /// loaded from a `--vocab` JSON file).
    pub fn with_tokenizer(mut self, tokenizer: Tokenizer) -> Gateway {
        self.tokenizer = tokenizer;
        self
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local addr")
    }

    pub fn handle(&self) -> GatewayHandle {
        GatewayHandle {
            addr: self.local_addr(),
            shutdown: self.shutdown.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Run the gateway until a drain is requested: the calling thread
    /// becomes the accept loop, a scoped sibling thread runs the serve
    /// session on a persistent `TickPool` over `decoders` (one lane
    /// per decoder), and each connection gets a scoped handler thread.
    /// Returns the serve session's stats once every in-flight request
    /// has decoded to completion.
    pub fn serve<D: Decoder + Send>(self, decoders: &mut [D]) -> Result<ServeStats> {
        anyhow::ensure!(!decoders.is_empty(), "the gateway needs at least one decoder");
        let Gateway { listener, cfg, vocab, tokenizer, shutdown, metrics } = self;
        listener.set_nonblocking(true).context("set listener non-blocking")?;
        let (tx_req, rx_req) = mpsc::channel::<Request>();
        let (tx_resp, rx_resp) = mpsc::channel::<Response>();
        // final Responses are redundant here — every handler consumes
        // its own event stream — and the serve loop tolerates a closed
        // response channel, so drop the receiver up front
        drop(rx_resp);
        let mut opts = ServeOpts::new(cfg.max_batch, cfg.max_wait)
            .with_max_queue(cfg.max_queue)
            .with_prefill_chunk(cfg.prefill_chunk);
        if cfg.state_slots > 0 {
            opts = opts.with_state_slots(cfg.state_slots);
        }
        let popts = PoolOpts::default().with_pin_workers(cfg.pin_workers);
        let next_id = AtomicU64::new(0);
        let metrics_ref: &Metrics = &metrics;
        let opts_ref = &opts;
        let shared = Shared {
            vocab,
            tokenizer: &tokenizer,
            cfg: &cfg,
            next_id: &next_id,
            metrics: metrics_ref,
            shutdown: &shutdown,
        };
        let sh = &shared;

        std::thread::scope(|s| {
            let engine = s.spawn(move || {
                with_tick_pool_opts(decoders, popts, |pool| {
                    pool.serve_with(rx_req, tx_resp, opts_ref, metrics_ref)
                })
            });

            loop {
                if sh.draining() {
                    break;
                }
                if engine.is_finished() {
                    // the serve loop died (decoder fault) — stop
                    // accepting and surface the panic via join below
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let open = sh.metrics.open_connections.load(Ordering::Relaxed);
                        if open >= sh.cfg.max_connections as u64 {
                            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
                            let mut w = stream;
                            w.set_nonblocking(false).ok();
                            w.set_write_timeout(Some(CONN_WRITE_TIMEOUT)).ok();
                            let _ = http::write_response(
                                &mut w,
                                503,
                                &[("Content-Type", "application/json"), ("Connection", "close")],
                                br#"{"error":"too many connections"}"#,
                            );
                            continue;
                        }
                        sh.metrics.open_connections.fetch_add(1, Ordering::Relaxed);
                        let tx = tx_req.clone();
                        s.spawn(move || {
                            // a handler panic must not tear down the
                            // whole gateway at scope join
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                handle_connection(stream, sh, tx);
                            }));
                            sh.metrics.open_connections.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        eprintln!("gateway: accept error: {e}");
                        std::thread::sleep(ACCEPT_POLL);
                    }
                }
            }

            // drain: stop accepting (new connects are refused), close
            // admissions once the in-flight handlers hang up, and wait
            // for the serve loop to finish every admitted sequence
            drop(listener);
            drop(tx_req);
            engine.join().expect("serve engine thread panicked")
        })
    }
}

/// Everything a connection handler needs besides its socket: gateway
/// policy plus the references shared by every handler thread.
struct Shared<'a> {
    vocab: usize,
    tokenizer: &'a Tokenizer,
    cfg: &'a GatewayConfig,
    next_id: &'a AtomicU64,
    metrics: &'a Metrics,
    shutdown: &'a AtomicBool,
}

impl Shared<'_> {
    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
            || (self.cfg.heed_signals && signal::shutdown_signalled())
    }
}

fn handle_connection(stream: TcpStream, sh: &Shared<'_>, tx_req: mpsc::Sender<Request>) {
    // the listener is non-blocking and BSD-family kernels (macOS) let
    // accepted sockets inherit O_NONBLOCK — undo it explicitly, the
    // handler wants blocking reads bounded by the timeouts below
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT)).ok();
    stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT)).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let limits = Limits::default();
    loop {
        if sh.draining() {
            break;
        }
        match http::read_request(&mut reader, &limits) {
            Ok(None) => break, // clean keep-alive close
            Ok(Some(req)) => {
                sh.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
                let close_requested = req
                    .header("connection")
                    .is_some_and(|v| v.eq_ignore_ascii_case("close"));
                if route(&mut writer, &req, sh, &tx_req).is_err() {
                    break; // client hung up mid-response
                }
                if close_requested || sh.draining() {
                    break;
                }
            }
            Err(e) => {
                // a timed-out idle keep-alive read lands here too
                // (Io → no status → just close)
                if let Some(status) = e.status() {
                    sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = http::write_response(
                        &mut writer,
                        status,
                        &[("Content-Type", "application/json"), ("Connection", "close")],
                        error_body(&e.message()).as_bytes(),
                    );
                }
                break;
            }
        }
    }
}

fn error_body(msg: &str) -> String {
    Json::obj().set("error", msg).render()
}

fn route(
    w: &mut TcpStream,
    req: &HttpRequest,
    sh: &Shared<'_>,
    tx_req: &mpsc::Sender<Request>,
) -> std::io::Result<()> {
    const JSON_CT: (&str, &str) = ("Content-Type", "application/json");
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => {
            http::write_response(w, 200, &[("Content-Type", "text/plain")], b"ok\n")
        }
        ("GET", "/metrics") => {
            let text = sh.metrics.render_prometheus();
            http::write_response(
                w,
                200,
                &[("Content-Type", "text/plain; version=0.0.4")],
                text.as_bytes(),
            )
        }
        ("POST", "/v1/generate") => generate(w, req, sh, tx_req),
        ("POST", "/v1/completions") => completions(w, req, false, sh, tx_req),
        ("POST", "/v1/chat/completions") => completions(w, req, true, sh, tx_req),
        (_, "/healthz" | "/metrics") => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                405,
                &[JSON_CT, ("Allow", "GET")],
                error_body("method not allowed").as_bytes(),
            )
        }
        (_, "/v1/generate" | "/v1/completions" | "/v1/chat/completions") => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                405,
                &[JSON_CT, ("Allow", "POST")],
                error_body("method not allowed").as_bytes(),
            )
        }
        _ => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(w, 404, &[JSON_CT], error_body("no such endpoint").as_bytes())
        }
    }
}

/// A validated `/v1/generate` body.
struct GenRequest {
    prompt: Vec<usize>,
    gen_len: usize,
    stream: bool,
}

fn parse_generate_body(
    body: &[u8],
    vocab: usize,
    max_gen_len: usize,
) -> std::result::Result<GenRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let arr = v
        .get("prompt")
        .and_then(Json::as_array)
        .ok_or_else(|| "missing 'prompt' (array of token ids)".to_string())?;
    if arr.is_empty() {
        return Err("'prompt' must not be empty".to_string());
    }
    if arr.len() > MAX_PROMPT {
        return Err(format!("'prompt' longer than {MAX_PROMPT} tokens"));
    }
    let prompt = arr
        .iter()
        .map(|t| {
            t.as_usize()
                .filter(|&t| t < vocab)
                .ok_or_else(|| format!("prompt tokens must be integers below the vocab ({vocab})"))
        })
        .collect::<std::result::Result<Vec<usize>, String>>()?;
    let gen_len = match v.get("gen_len") {
        None => 16,
        Some(g) => g
            .as_usize()
            .filter(|&n| (1..=max_gen_len).contains(&n))
            .ok_or_else(|| format!("'gen_len' must be an integer in 1..={max_gen_len}"))?,
    };
    let stream = match v.get("stream") {
        None => true,
        Some(s) => s.as_bool().ok_or_else(|| "'stream' must be a boolean".to_string())?,
    };
    Ok(GenRequest { prompt, gen_len, stream })
}

/// Render token ids as a JSON array (`[1,2,30]`) — shared with the
/// tests and examples that build request bodies by hand.
pub fn tokens_json(tokens: &[usize]) -> String {
    let mut s = String::from("[");
    for (i, t) in tokens.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&t.to_string());
    }
    s.push(']');
    s
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn generate(
    w: &mut TcpStream,
    req: &HttpRequest,
    sh: &Shared<'_>,
    tx_req: &mpsc::Sender<Request>,
) -> std::io::Result<()> {
    const JSON_CT: (&str, &str) = ("Content-Type", "application/json");
    let gen = match parse_generate_body(&req.body, sh.vocab, sh.cfg.max_gen_len) {
        Ok(g) => g,
        Err(msg) => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            return http::write_response(w, 400, &[JSON_CT], error_body(&msg).as_bytes());
        }
    };
    sh.metrics.generate_requests.fetch_add(1, Ordering::Relaxed);
    let (tx_ev, rx_ev) = mpsc::channel();
    let id = sh.next_id.fetch_add(1, Ordering::Relaxed);
    let request = Request::new(id, gen.prompt, gen.gen_len).with_stream(tx_ev);
    if tx_req.send(request).is_err() {
        sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
        return http::write_response(
            w,
            503,
            &[JSON_CT, ("Connection", "close")],
            error_body("server is draining").as_bytes(),
        );
    }
    // the first event decides the status line: Shed → 429 before any
    // body byte, Admitted → 200 and the stream begins
    match rx_ev.recv() {
        Err(_) => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                500,
                &[JSON_CT],
                error_body("serve loop dropped the request").as_bytes(),
            )
        }
        Ok(StreamEvent::Shed) => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                429,
                &[JSON_CT, ("Retry-After", "1")],
                error_body("admission queue full").as_bytes(),
            )
        }
        Ok(first) => {
            if gen.stream {
                stream_sse(w, id, first, rx_ev)
            } else {
                collect_json(w, id, first, rx_ev)
            }
        }
    }
}

/// A validated OpenAI-style body (`/v1/completions` accepts a string
/// `prompt`, `/v1/chat/completions` a `messages` array rendered through
/// the plain `"{role}: {content}\n"` template plus an `assistant:`
/// generation cue).
struct TextRequest {
    prompt: Vec<usize>,
    max_tokens: usize,
    stream: bool,
    sample: SampleParams,
    stop: Vec<Vec<usize>>,
    model: String,
}

fn text_num(v: &Json, key: &str, default: f32) -> std::result::Result<f32, String> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(x) => x
            .as_f64()
            .map(|n| n as f32)
            .ok_or_else(|| format!("'{key}' must be a number")),
    }
}

fn parse_text_body(
    body: &[u8],
    chat: bool,
    tokenizer: &Tokenizer,
    max_gen_len: usize,
) -> std::result::Result<TextRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let prompt_text = if chat {
        let msgs = v
            .get("messages")
            .and_then(Json::as_array)
            .ok_or_else(|| "missing 'messages' (array of {role, content})".to_string())?;
        if msgs.is_empty() {
            return Err("'messages' must not be empty".to_string());
        }
        let mut s = String::new();
        for (i, m) in msgs.iter().enumerate() {
            let role = m
                .get("role")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("messages[{i}] is missing a string 'role'"))?;
            let content = m
                .get("content")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("messages[{i}] is missing a string 'content'"))?;
            s.push_str(role);
            s.push_str(": ");
            s.push_str(content);
            s.push('\n');
        }
        s.push_str("assistant:");
        s
    } else {
        v.get("prompt")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing 'prompt' (string)".to_string())?
            .to_string()
    };
    let prompt = tokenizer.encode(&prompt_text);
    if prompt.is_empty() {
        return Err("prompt encodes to zero tokens".to_string());
    }
    if prompt.len() > MAX_PROMPT {
        return Err(format!("prompt longer than {MAX_PROMPT} tokens"));
    }
    let max_tokens = match v.get("max_tokens") {
        None | Some(Json::Null) => 16,
        Some(g) => g
            .as_usize()
            .filter(|&n| (1..=max_gen_len).contains(&n))
            .ok_or_else(|| format!("'max_tokens' must be an integer in 1..={max_gen_len}"))?,
    };
    let top_k = match v.get("top_k") {
        None | Some(Json::Null) => 0,
        Some(k) => k
            .as_usize()
            .ok_or_else(|| "'top_k' must be a non-negative integer".to_string())?,
    };
    let seed = match v.get("seed") {
        None | Some(Json::Null) => 0,
        Some(s) => s
            .as_usize()
            .ok_or_else(|| "'seed' must be a non-negative integer".to_string())?
            as u64,
    };
    let sample = SampleParams {
        temperature: text_num(&v, "temperature", 1.0)?,
        top_k,
        top_p: text_num(&v, "top_p", 1.0)?,
        repetition_penalty: text_num(&v, "repetition_penalty", 1.0)?,
        seed,
    };
    sample.validate()?;
    let stop_strings: Vec<String> = match v.get("stop") {
        None | Some(Json::Null) => Vec::new(),
        Some(Json::Str(s)) => vec![s.clone()],
        Some(Json::Arr(xs)) => xs
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| "'stop' entries must be strings".to_string())
            })
            .collect::<std::result::Result<_, _>>()?,
        Some(_) => return Err("'stop' must be a string or an array of strings".to_string()),
    };
    if stop_strings.len() > 4 {
        return Err("'stop' allows at most 4 sequences".to_string());
    }
    let mut stop = Vec::with_capacity(stop_strings.len());
    for s in &stop_strings {
        let ids = tokenizer.encode(s);
        if ids.is_empty() {
            return Err(format!("stop sequence {s:?} encodes to zero tokens"));
        }
        stop.push(ids);
    }
    let stream = match v.get("stream") {
        None | Some(Json::Null) => false, // OpenAI defaults to non-streaming
        Some(s) => s.as_bool().ok_or_else(|| "'stream' must be a boolean".to_string())?,
    };
    let model = v.get("model").and_then(Json::as_str).unwrap_or("rwkvquant").to_string();
    Ok(TextRequest { prompt, max_tokens, stream, sample, stop, model })
}

/// Labels the OpenAI response writers stamp onto every chunk/body.
struct TextReply<'a> {
    id: u64,
    chat: bool,
    model: &'a str,
    tokenizer: &'a Tokenizer,
    prompt_tokens: usize,
    created: u64,
}

impl TextReply<'_> {
    fn reply_id(&self) -> String {
        format!("{}-{}", if self.chat { "chatcmpl" } else { "cmpl" }, self.id)
    }

    fn object(&self, streamed: bool) -> &'static str {
        match (self.chat, streamed) {
            (true, true) => "chat.completion.chunk",
            (true, false) => "chat.completion",
            // OpenAI uses the same object name for streamed and whole
            // text completions
            (false, _) => "text_completion",
        }
    }

    /// One streamed SSE chunk: `choices[0]` carries either a chat
    /// `delta` or a completion `text` fragment.
    fn chunk_json(&self, delta: &str, role: bool, finish: Option<FinishReason>) -> String {
        let finish_val = match finish {
            Some(f) => Json::Str(f.as_str().to_string()),
            None => Json::Null,
        };
        let choice = if self.chat {
            let mut d = Json::obj();
            if role {
                d = d.set("role", "assistant");
            }
            if !delta.is_empty() {
                d = d.set("content", delta);
            }
            Json::obj().set("delta", d).set("finish_reason", finish_val).set("index", 0usize)
        } else {
            Json::obj().set("finish_reason", finish_val).set("index", 0usize).set("text", delta)
        };
        Json::obj()
            .set("choices", Json::Arr(vec![choice]))
            .set("created", self.created as f64)
            .set("id", self.reply_id())
            .set("model", self.model)
            .set("object", self.object(true))
            .render()
    }

    /// The whole-document (non-streaming) response body.
    fn body_json(&self, text: &str, completion_tokens: usize, finish: FinishReason) -> String {
        let choice = if self.chat {
            Json::obj()
                .set("finish_reason", finish.as_str())
                .set("index", 0usize)
                .set("message", Json::obj().set("content", text).set("role", "assistant"))
        } else {
            Json::obj().set("finish_reason", finish.as_str()).set("index", 0usize).set("text", text)
        };
        Json::obj()
            .set("choices", Json::Arr(vec![choice]))
            .set("created", self.created as f64)
            .set("id", self.reply_id())
            .set("model", self.model)
            .set("object", self.object(false))
            .set(
                "usage",
                Json::obj()
                    .set("completion_tokens", completion_tokens)
                    .set("prompt_tokens", self.prompt_tokens)
                    .set("total_tokens", self.prompt_tokens + completion_tokens),
            )
            .render()
    }
}

fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

fn completions(
    w: &mut TcpStream,
    req: &HttpRequest,
    chat: bool,
    sh: &Shared<'_>,
    tx_req: &mpsc::Sender<Request>,
) -> std::io::Result<()> {
    const JSON_CT: (&str, &str) = ("Content-Type", "application/json");
    let t = match parse_text_body(&req.body, chat, sh.tokenizer, sh.cfg.max_gen_len) {
        Ok(t) => t,
        Err(msg) => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            return http::write_response(w, 400, &[JSON_CT], error_body(&msg).as_bytes());
        }
    };
    sh.metrics.text_requests.fetch_add(1, Ordering::Relaxed);
    let (tx_ev, rx_ev) = mpsc::channel();
    let id = sh.next_id.fetch_add(1, Ordering::Relaxed);
    let cancel = Arc::new(AtomicBool::new(false));
    let reply = TextReply {
        id,
        chat,
        model: &t.model,
        tokenizer: sh.tokenizer,
        prompt_tokens: t.prompt.len(),
        created: unix_now(),
    };
    let request = Request::new(id, t.prompt, t.max_tokens)
        .with_stream(tx_ev)
        .with_sampling(t.sample)
        .with_stop(t.stop)
        .with_cancel(cancel.clone());
    if tx_req.send(request).is_err() {
        sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
        return http::write_response(
            w,
            503,
            &[JSON_CT, ("Connection", "close")],
            error_body("server is draining").as_bytes(),
        );
    }
    match rx_ev.recv() {
        Err(_) => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                500,
                &[JSON_CT],
                error_body("serve loop dropped the request").as_bytes(),
            )
        }
        Ok(StreamEvent::Shed) => {
            sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                429,
                &[JSON_CT, ("Retry-After", "1")],
                error_body("admission queue full").as_bytes(),
            )
        }
        Ok(first) => {
            let r = if t.stream {
                stream_openai(w, &reply, first, rx_ev)
            } else {
                collect_openai(w, &reply, first, rx_ev)
            };
            if r.is_err() {
                // client hung up mid-response: raise the cancel flag so
                // the serve loop frees the slab instead of decoding an
                // orphan to completion
                cancel.store(true, Ordering::Relaxed);
            }
            r
        }
    }
}

/// Stream an OpenAI completion as SSE delta chunks: one chunk per
/// decoded token, a final chunk carrying the `finish_reason`, then the
/// protocol's `data: [DONE]` terminator.
fn stream_openai(
    w: &mut TcpStream,
    r: &TextReply<'_>,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let id_text = r.id.to_string();
    let mut cw = ChunkedWriter::begin(
        &mut *w,
        200,
        &[
            ("Content-Type", "text/event-stream"),
            ("Cache-Control", "no-cache"),
            ("X-Request-Id", &id_text),
        ],
    )?;
    if r.chat {
        // the opening chunk announces the assistant role, per protocol
        cw.chunk(format!("data: {}\n\n", r.chunk_json("", true, None)).as_bytes())?;
    }
    let mut finished: Option<FinishReason> = None;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop gone; truncate the stream
            },
        };
        match e {
            StreamEvent::Admitted { .. } => {} // no OpenAI analogue
            StreamEvent::Token(t) => {
                let piece = r.tokenizer.decode(&[t]);
                cw.chunk(format!("data: {}\n\n", r.chunk_json(&piece, false, None)).as_bytes())?;
            }
            StreamEvent::Done { finish, .. } => {
                finished = Some(finish);
                break;
            }
            StreamEvent::Shed => break,
        }
    }
    if let Some(finish) = finished {
        cw.chunk(format!("data: {}\n\n", r.chunk_json("", false, Some(finish))).as_bytes())?;
        cw.chunk(b"data: [DONE]\n\n")?;
    }
    cw.finish()
}

/// `"stream": false` — wait for completion, answer one OpenAI
/// completion object (with `usage` accounting). As with
/// [`collect_json`], a missing `Done` is a 500, never a truncated body.
fn collect_openai(
    w: &mut TcpStream,
    r: &TextReply<'_>,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let mut tokens: Vec<usize> = Vec::new();
    let mut finished: Option<FinishReason> = None;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break,
            },
        };
        match e {
            StreamEvent::Admitted { .. } => {}
            StreamEvent::Token(t) => tokens.push(t),
            StreamEvent::Done { finish, .. } => {
                finished = Some(finish);
                break;
            }
            StreamEvent::Shed => break,
        }
    }
    let Some(finish) = finished else {
        return http::write_response(
            w,
            500,
            &[("Content-Type", "application/json")],
            error_body("generation aborted before completion").as_bytes(),
        );
    };
    let text = r.tokenizer.decode(&tokens);
    let body = r.body_json(&text, tokens.len(), finish);
    let id_text = r.id.to_string();
    http::write_response(
        w,
        200,
        &[("Content-Type", "application/json"), ("X-Request-Id", &id_text)],
        body.as_bytes(),
    )
}

/// Stream one request's events as SSE over chunked transfer: one
/// `data:` chunk per token as the tick produces it, a final `done`
/// event carrying the full token list and timings.
fn stream_sse(
    w: &mut TcpStream,
    id: u64,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let id_text = id.to_string();
    let mut cw = ChunkedWriter::begin(
        &mut *w,
        200,
        &[
            ("Content-Type", "text/event-stream"),
            ("Cache-Control", "no-cache"),
            ("X-Request-Id", &id_text),
        ],
    )?;
    let mut tokens: Vec<usize> = Vec::new();
    let mut queued_ms = 0.0f64;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop gone; terminate the stream
            },
        };
        match e {
            StreamEvent::Admitted { queued } => {
                queued_ms = ms(queued);
                cw.chunk(
                    format!("data: {{\"admitted\":true,\"queued_ms\":{queued_ms:.3}}}\n\n")
                        .as_bytes(),
                )?;
            }
            StreamEvent::Token(t) => {
                tokens.push(t);
                cw.chunk(format!("data: {{\"token\":{t}}}\n\n").as_bytes())?;
            }
            StreamEvent::Done { latency, ttft, finish } => {
                cw.chunk(
                    format!(
                        "data: {{\"done\":true,\"finish_reason\":\"{}\",\"id\":{id},\
                         \"tokens\":{},\"queued_ms\":{queued_ms:.3},\"ttft_ms\":{:.3},\
                         \"latency_ms\":{:.3}}}\n\n",
                        finish.as_str(),
                        tokens_json(&tokens),
                        ms(ttft),
                        ms(latency),
                    )
                    .as_bytes(),
                )?;
                break;
            }
            // unreachable after admission; terminate defensively
            StreamEvent::Shed => break,
        }
    }
    cw.finish()
}

/// `"stream": false` — wait for completion, answer one JSON document.
/// Nothing has been written yet when the serve loop dies mid-request,
/// so a missing `Done` is answered as a 500 — a truncated token list
/// must never masquerade as a completed generation.
fn collect_json(
    w: &mut TcpStream,
    id: u64,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let mut tokens: Vec<usize> = Vec::new();
    let mut queued_ms = 0.0f64;
    let mut ttft_ms = 0.0f64;
    let mut latency_ms = 0.0f64;
    let mut finished: Option<FinishReason> = None;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop died before Done
            },
        };
        match e {
            StreamEvent::Admitted { queued } => queued_ms = ms(queued),
            StreamEvent::Token(t) => tokens.push(t),
            StreamEvent::Done { latency, ttft, finish } => {
                latency_ms = ms(latency);
                ttft_ms = ms(ttft);
                finished = Some(finish);
                break;
            }
            StreamEvent::Shed => break,
        }
    }
    let Some(finish) = finished else {
        return http::write_response(
            w,
            500,
            &[("Content-Type", "application/json")],
            error_body("generation aborted before completion").as_bytes(),
        );
    };
    let body = format!(
        "{{\"finish_reason\":\"{}\",\"id\":{id},\"tokens\":{},\
         \"queued_ms\":{queued_ms:.3},\"ttft_ms\":{ttft_ms:.3},\
         \"latency_ms\":{latency_ms:.3}}}",
        finish.as_str(),
        tokens_json(&tokens)
    );
    http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
}

/// Split an SSE body into its `data: ` payloads (client-side helper for
/// the tests, the e2e example and the smoke driver).
pub fn sse_data(body: &str) -> Vec<&str> {
    body.lines().filter_map(|l| l.strip_prefix("data: ")).collect()
}

/// Extract the streamed tokens from an SSE body: the incremental
/// `token` events, checked against the final `done` event's list.
pub fn sse_tokens(body: &str) -> Result<Vec<usize>> {
    let mut streamed = Vec::new();
    let mut done_tokens: Option<Vec<usize>> = None;
    for payload in sse_data(body) {
        let v = json::parse(payload).map_err(|e| anyhow::anyhow!("bad SSE payload: {e}"))?;
        if let Some(t) = v.get("token").and_then(Json::as_usize) {
            streamed.push(t);
        }
        if v.get("done").and_then(Json::as_bool) == Some(true) {
            let list = v
                .get("tokens")
                .and_then(Json::as_array)
                .context("done event without tokens")?
                .iter()
                .map(|t| t.as_usize().context("non-integer token in done event"))
                .collect::<Result<Vec<usize>>>()?;
            done_tokens = Some(list);
        }
    }
    let done = done_tokens.context("SSE stream ended without a done event")?;
    anyhow::ensure!(
        streamed == done,
        "incrementally streamed tokens {streamed:?} disagree with the done event {done:?}"
    );
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_body_validation() {
        let ok = parse_generate_body(br#"{"prompt":[1,2,3],"gen_len":4}"#, 32, 64).unwrap();
        assert_eq!(ok.prompt, vec![1, 2, 3]);
        assert_eq!(ok.gen_len, 4);
        assert!(ok.stream, "stream defaults to true");

        let ok = parse_generate_body(br#"{"prompt":[0],"stream":false}"#, 32, 64).unwrap();
        assert_eq!(ok.gen_len, 16, "gen_len defaults to 16");
        assert!(!ok.stream);

        for (bad, why) in [
            (&br#"{"gen_len":4}"#[..], "missing prompt"),
            (br#"{"prompt":[]}"#, "empty prompt"),
            (br#"{"prompt":[99]}"#, "token >= vocab"),
            (br#"{"prompt":[-1]}"#, "negative token"),
            (br#"{"prompt":[1.5]}"#, "fractional token"),
            (br#"{"prompt":[1],"gen_len":0}"#, "gen_len 0"),
            (br#"{"prompt":[1],"gen_len":65}"#, "gen_len beyond cap"),
            (br#"{"prompt":[1],"stream":"yes"}"#, "non-bool stream"),
            (br#"{"prompt":"abc"}"#, "non-array prompt"),
            (b"not json", "not json"),
            (&[0xff, 0xfe][..], "not utf-8"),
        ] {
            assert!(parse_generate_body(bad, 32, 64).is_err(), "{why} must be rejected");
        }
    }

    #[test]
    fn sse_token_extraction_checks_consistency() {
        let body = "data: {\"admitted\":true,\"queued_ms\":0.1}\n\n\
                    data: {\"token\":5}\n\ndata: {\"token\":9}\n\n\
                    data: {\"done\":true,\"finish_reason\":\"length\",\"id\":0,\
                    \"tokens\":[5,9],\"queued_ms\":0.1,\
                    \"ttft_ms\":1.2,\"latency_ms\":2.0}\n\n";
        assert_eq!(sse_tokens(body).unwrap(), vec![5, 9]);

        let inconsistent = body.replace("[5,9]", "[5,8]");
        assert!(sse_tokens(&inconsistent).is_err());
        assert!(sse_tokens("data: {\"token\":5}\n\n").is_err(), "missing done must error");
    }

    #[test]
    fn tokens_json_renders_plain_arrays() {
        assert_eq!(tokens_json(&[]), "[]");
        assert_eq!(tokens_json(&[7]), "[7]");
        assert_eq!(tokens_json(&[1, 2, 30]), "[1,2,30]");
    }

    #[test]
    fn text_body_validation() {
        let tok = Tokenizer::synthetic(512);

        let ok = parse_text_body(br#"{"prompt":"w3 w1 w2 "}"#, false, &tok, 64).unwrap();
        assert_eq!(ok.prompt, vec![3, 1, 2]);
        assert_eq!(ok.max_tokens, 16, "max_tokens defaults to 16");
        assert!(!ok.stream, "OpenAI requests default to non-streaming");
        assert_eq!(ok.sample.temperature, 1.0);
        assert_eq!(ok.sample.seed, 0, "unseeded requests are still deterministic");
        assert!(ok.stop.is_empty());
        assert_eq!(ok.model, "rwkvquant");

        let ok = parse_text_body(
            br#"{"prompt":"w7 ","max_tokens":4,"temperature":0,"stream":true,
                 "stop":"w9 ","model":"m","seed":42}"#,
            false,
            &tok,
            64,
        )
        .unwrap();
        assert!(ok.sample.is_greedy());
        assert_eq!(ok.max_tokens, 4);
        assert!(ok.stream);
        assert_eq!(ok.stop, vec![vec![9]]);
        assert_eq!(ok.sample.seed, 42);
        assert_eq!(ok.model, "m");

        let ok = parse_text_body(
            br#"{"prompt":"w7 ","stop":["w9 ","w10 w11 "]}"#,
            false,
            &tok,
            64,
        )
        .unwrap();
        assert_eq!(ok.stop, vec![vec![9], vec![10, 11]]);

        // the chat template renders "user: w3 w1 \nassistant:" — the
        // covered words survive, everything else tokenizes to <unk>
        let ok = parse_text_body(
            br#"{"messages":[{"role":"user","content":"w3 w1 "}]}"#,
            true,
            &tok,
            64,
        )
        .unwrap();
        assert!(ok.prompt.contains(&3) && ok.prompt.contains(&1));

        for (bad, why) in [
            (&br#"{"max_tokens":4}"#[..], "missing prompt"),
            (br#"{"prompt":""}"#, "empty prompt"),
            (br#"{"prompt":[1,2]}"#, "token-id prompt on the text endpoint"),
            (br#"{"prompt":"w1 ","max_tokens":0}"#, "max_tokens 0"),
            (br#"{"prompt":"w1 ","max_tokens":65}"#, "max_tokens beyond cap"),
            (br#"{"prompt":"w1 ","temperature":-1}"#, "negative temperature"),
            (br#"{"prompt":"w1 ","top_p":0}"#, "top_p out of (0,1]"),
            (br#"{"prompt":"w1 ","repetition_penalty":0}"#, "zero repetition penalty"),
            (br#"{"prompt":"w1 ","stop":7}"#, "non-string stop"),
            (br#"{"prompt":"w1 ","stop":[7]}"#, "non-string stop entry"),
            (br#"{"prompt":"w1 ","stop":["a","b","c","d","e"]}"#, "more than 4 stops"),
            (br#"{"prompt":"w1 ","seed":-4}"#, "negative seed"),
            (br#"{"prompt":"w1 ","stream":"yes"}"#, "non-bool stream"),
            (b"not json", "not json"),
        ] {
            assert!(parse_text_body(bad, false, &tok, 64).is_err(), "{why} must be rejected");
        }
        assert!(
            parse_text_body(br#"{"messages":[]}"#, true, &tok, 64).is_err(),
            "empty messages must be rejected"
        );
        assert!(
            parse_text_body(br#"{"messages":[{"role":"user"}]}"#, true, &tok, 64).is_err(),
            "message without content must be rejected"
        );
    }

    #[test]
    fn openai_bodies_render_to_protocol_shape() {
        let tok = Tokenizer::synthetic(16);
        let r = TextReply {
            id: 3,
            chat: false,
            model: "m",
            tokenizer: &tok,
            prompt_tokens: 2,
            created: 1700000000,
        };
        assert_eq!(
            r.body_json("w5 ", 1, FinishReason::Stop),
            "{\"choices\":[{\"finish_reason\":\"stop\",\"index\":0,\"text\":\"w5 \"}],\
             \"created\":1700000000,\"id\":\"cmpl-3\",\"model\":\"m\",\
             \"object\":\"text_completion\",\"usage\":{\"completion_tokens\":1,\
             \"prompt_tokens\":2,\"total_tokens\":3}}"
        );
        assert_eq!(
            r.chunk_json("w5 ", false, None),
            "{\"choices\":[{\"finish_reason\":null,\"index\":0,\"text\":\"w5 \"}],\
             \"created\":1700000000,\"id\":\"cmpl-3\",\"model\":\"m\",\
             \"object\":\"text_completion\"}"
        );

        let r = TextReply { chat: true, ..r };
        let body = r.body_json("hi", 1, FinishReason::Length);
        assert!(body.contains("\"object\":\"chat.completion\""), "{body}");
        assert!(body.contains("\"id\":\"chatcmpl-3\""), "{body}");
        assert!(
            body.contains("\"message\":{\"content\":\"hi\",\"role\":\"assistant\"}"),
            "{body}"
        );
        let role = r.chunk_json("", true, None);
        assert!(role.contains("\"delta\":{\"role\":\"assistant\"}"), "{role}");
        assert!(role.contains("\"object\":\"chat.completion.chunk\""), "{role}");
        let delta = r.chunk_json("hi", false, None);
        assert!(delta.contains("\"delta\":{\"content\":\"hi\"}"), "{delta}");
        let last = r.chunk_json("", false, Some(FinishReason::Cancelled));
        assert!(
            last.contains("\"delta\":{},\"finish_reason\":\"cancelled\""),
            "{last}"
        );
    }
}
