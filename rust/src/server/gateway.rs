//! The HTTP serving gateway: the first network boundary in the
//! codebase. A `TcpListener` accept loop feeds per-connection handler
//! threads; each generation request is parsed ([`super::http`] +
//! [`super::json`]), forwarded into the **same** `coordinator::serve`
//! loop the CLI uses (over a persistent `TickPool`), and its tokens are
//! streamed back incrementally as Server-Sent Events over chunked
//! transfer — one SSE chunk per tick-produced token.
//!
//! Operational behaviour:
//!
//! * **Admission control** — the serve loop's bounded queue
//!   (`--max-queue`) sheds overflow; a shed request is answered `429
//!   Too Many Requests` (with `Retry-After`) and counted in `/metrics`.
//!   A connection cap answers `503` before parsing when the handler
//!   pool is exhausted.
//! * **Observability** — `GET /healthz` for probes, `GET /metrics` in
//!   Prometheus text format ([`Metrics`]): served tokens/sec, queue
//!   depth + high-water mark, shed count, latency and admission-wait
//!   quantiles.
//! * **Graceful drain** — [`GatewayHandle::shutdown`] (or
//!   SIGINT/SIGTERM when [`GatewayConfig::heed_signals`] is set) stops
//!   the accept loop, closes the listener, lets every in-flight request
//!   decode to completion through the tick pool, then returns the
//!   session's [`ServeStats`]. The process exits 0 — never mid-tick.
//!
//! Beyond the raw token-id endpoint (`POST /v1/generate`) the gateway
//! speaks the OpenAI text protocol: `POST /v1/completions` and `POST
//! /v1/chat/completions` accept text, tokenize it with the gateway's
//! [`Tokenizer`], decode under per-request [`SampleParams`] (seeded, so
//! identical requests produce identical bytes), honour `max_tokens` and
//! `stop` sequences with the matching `finish_reason`, and answer
//! either one JSON document or OpenAI-style SSE delta chunks terminated
//! by `data: [DONE]`. Stop sequences are matched on token boundaries
//! and the matched text is **included** in the output.
//!
//! Request cancellation is cooperative: when a streaming write fails
//! (the client hung up mid-response) the handler raises the request's
//! cancel flag, and the serve loop retires the sequence on its next
//! tick — the state-pool slab and tick budget are released instead of
//! decoding an orphan to completion. Cancelled requests are counted in
//! `/metrics` and finish with reason `cancelled`. A non-streaming
//! request writes nothing until it completes, so a disconnect there is
//! only discovered (and the response discarded) at the final write.
//!
//! Dispatch is table-driven: [`ROUTES`] declares the whole HTTP
//! surface (method, path pattern with `{param}` segments, handler) and
//! `match_route` derives uniform `404`s and `405 Allow: …` responses
//! from it. Every error answers the OpenAI error schema
//! `{"error":{"code","message","type"}}`.
//!
//! The gateway serves either a single engine ([`Gateway::serve`], the
//! legacy `--store` path: one serve loop, the request's `model` field
//! must be absent or [`DEFAULT_MODEL`]) or a whole
//! [`Fleet`] ([`Gateway::serve_fleet`]): the `model` field routes each
//! request to its per-model engine, `GET /v1/models` lists the
//! registry, `POST`/`DELETE /admin/models/{name}` hot-swap and retire
//! models with zero downtime, and `/metrics` carries a `model` label
//! on every serve-level family.

use crate::coordinator::fleet::{Fleet, SubmitError};
use crate::coordinator::sampler::SampleParams;
use crate::coordinator::serve::{
    with_tick_pool_opts, Decoder, FinishReason, PoolOpts, Request, Response, ServeOpts,
    ServeStats, StreamEvent,
};
use crate::data::tokenizer::Tokenizer;
use crate::quant::exec::kstats;
use crate::report::json::Json;
use crate::server::http::{self, ChunkedWriter, HttpRequest, Limits};
use crate::server::metrics::{render_exposition, InflightEntry, Metrics};
use crate::server::{json, signal};
use crate::util::log::{self, RateLimit};
use crate::util::trace::CONTROL_LANE;
use crate::Result;
use anyhow::Context;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Accept-loop poll cadence while idle (the listener is non-blocking so
/// the loop can observe the shutdown flag).
const ACCEPT_POLL: Duration = Duration::from_millis(15);
/// Per-connection read timeout: bounds how long an idle keep-alive
/// connection can delay a drain.
const CONN_READ_TIMEOUT: Duration = Duration::from_secs(5);
/// Per-connection write timeout: a client that stops reading its
/// response cannot park a handler thread (and its admission-channel
/// clone) forever — the stalled write errors out and the connection is
/// dropped, so a drain always completes.
const CONN_WRITE_TIMEOUT: Duration = Duration::from_secs(30);
/// Longest accepted prompt, in tokens.
const MAX_PROMPT: usize = 4096;

/// Gateway policy. `addr` is `host:port` (`:0` binds an ephemeral port,
/// reported by [`Gateway::local_addr`]).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub addr: String,
    /// Continuous-batching width of the serve session.
    pub max_batch: usize,
    /// Batch-forming wait of the serve session.
    pub max_wait: Duration,
    /// Bounded admission queue: overflow is shed with a 429.
    pub max_queue: usize,
    /// Per-request `gen_len` cap (400 beyond it).
    pub max_gen_len: usize,
    /// Concurrent-connection cap (503 beyond it).
    pub max_connections: usize,
    /// Prompt tokens a prefilling sequence consumes per tick
    /// (`ServeOpts::prefill_chunk`; 1 = legacy one-per-tick).
    pub prefill_chunk: usize,
    /// State-arena slabs (`ServeOpts::state_slots`); `0` = one per
    /// batch slot.
    pub state_slots: usize,
    /// Pin tick worker lanes to CPUs (`PoolOpts::pin_workers`).
    pub pin_workers: bool,
    /// Also drain on SIGINT/SIGTERM (requires
    /// [`signal::install_shutdown_signals`]; the CLI sets this, tests
    /// use the explicit handle so a test-raised signal cannot leak into
    /// unrelated gateways).
    pub heed_signals: bool,
    /// Per-request span tracing + kernel attribution (`/admin/trace`,
    /// `/admin/inflight`, the `rwkvquant_kernel_*` families). On by
    /// default; `--no-trace` clears it, leaving every record site one
    /// relaxed load.
    pub trace: bool,
}

impl GatewayConfig {
    pub fn new(addr: impl Into<String>) -> GatewayConfig {
        GatewayConfig {
            addr: addr.into(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_queue: 64,
            max_gen_len: 512,
            max_connections: 128,
            prefill_chunk: 32,
            state_slots: 0,
            pin_workers: false,
            heed_signals: false,
            trace: true,
        }
    }
}

/// A bound (but not yet serving) gateway. Two-phase so callers learn
/// the ephemeral port and can clone a [`GatewayHandle`] before the
/// blocking [`Gateway::serve`] call.
pub struct Gateway {
    listener: TcpListener,
    cfg: GatewayConfig,
    vocab: usize,
    tokenizer: Tokenizer,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
}

/// Clonable remote control for a running gateway.
#[derive(Clone)]
pub struct GatewayHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
}

impl GatewayHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin a graceful drain: stop accepting, finish in-flight work,
    /// return from [`Gateway::serve`].
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

impl Gateway {
    /// Bind the listener; serving starts with [`Gateway::serve`]. The
    /// text endpoints start out on the synthetic `w{i} ` vocab —
    /// override with [`Gateway::with_tokenizer`] for real models.
    pub fn bind(cfg: GatewayConfig, vocab: usize) -> Result<Gateway> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        Ok(Gateway {
            listener,
            cfg,
            vocab,
            tokenizer: Tokenizer::synthetic(vocab),
            shutdown: Arc::new(AtomicBool::new(false)),
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Replace the tokenizer backing the text endpoints (e.g. one
    /// loaded from a `--vocab` JSON file).
    pub fn with_tokenizer(mut self, tokenizer: Tokenizer) -> Gateway {
        self.tokenizer = tokenizer;
        self
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local addr")
    }

    pub fn handle(&self) -> GatewayHandle {
        GatewayHandle {
            addr: self.local_addr(),
            shutdown: self.shutdown.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Run the gateway until a drain is requested: the calling thread
    /// becomes the accept loop, a scoped sibling thread runs the serve
    /// session on a persistent `TickPool` over `decoders` (one lane
    /// per decoder), and each connection gets a scoped handler thread.
    /// Returns the serve session's stats once every in-flight request
    /// has decoded to completion.
    pub fn serve<D: Decoder + Send>(self, decoders: &mut [D]) -> Result<ServeStats> {
        anyhow::ensure!(!decoders.is_empty(), "the gateway needs at least one decoder");
        let Gateway { listener, cfg, vocab, tokenizer, shutdown, metrics } = self;
        listener.set_nonblocking(true).context("set listener non-blocking")?;
        // before the engine spawns: the serve loop resolves its trace
        // hub once at session start
        metrics.trace().set_enabled(cfg.trace);
        kstats::set_enabled(cfg.trace);
        let (tx_req, rx_req) = mpsc::channel::<Request>();
        let (tx_resp, rx_resp) = mpsc::channel::<Response>();
        // final Responses are redundant here — every handler consumes
        // its own event stream — and the serve loop tolerates a closed
        // response channel, so drop the receiver up front
        drop(rx_resp);
        let mut opts = ServeOpts::new(cfg.max_batch, cfg.max_wait)
            .with_max_queue(cfg.max_queue)
            .with_prefill_chunk(cfg.prefill_chunk);
        if cfg.state_slots > 0 {
            opts = opts.with_state_slots(cfg.state_slots);
        }
        let popts = PoolOpts::default().with_pin_workers(cfg.pin_workers);
        let next_id = AtomicU64::new(0);
        let metrics_ref: &Metrics = &metrics;
        let opts_ref = &opts;
        let shared = Shared {
            vocab,
            tokenizer: &tokenizer,
            cfg: &cfg,
            next_id: &next_id,
            metrics: &metrics,
            shutdown: &shutdown,
            started_unix: unix_now(),
        };
        let sh = &shared;

        std::thread::scope(|s| {
            let engine = s.spawn(move || {
                with_tick_pool_opts(decoders, popts, |pool| {
                    pool.serve_with(rx_req, tx_resp, opts_ref, metrics_ref)
                })
            });

            loop {
                if sh.draining() {
                    break;
                }
                if engine.is_finished() {
                    // the serve loop died (decoder fault) — stop
                    // accepting and surface the panic via join below
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let open = sh.metrics.open_connections.load(Ordering::Relaxed);
                        if open >= sh.cfg.max_connections as u64 {
                            refuse_connection(stream, sh);
                            continue;
                        }
                        sh.metrics.open_connections.fetch_add(1, Ordering::Relaxed);
                        let tx = tx_req.clone();
                        s.spawn(move || {
                            // a handler panic must not tear down the
                            // whole gateway at scope join
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                handle_connection(stream, sh, Conn::Single(tx));
                            }));
                            sh.metrics.open_connections.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        log_accept_error(&e);
                        std::thread::sleep(ACCEPT_POLL);
                    }
                }
            }

            // drain: stop accepting (new connects are refused), close
            // admissions once the in-flight handlers hang up, and wait
            // for the serve loop to finish every admitted sequence
            drop(listener);
            drop(tx_req);
            engine.join().expect("serve engine thread panicked")
        })
    }

    /// Run the gateway over a [`Fleet`] registry until a drain is
    /// requested. Unlike [`Gateway::serve`] the engines live inside
    /// the fleet (one per model, spawned by `Fleet::load` — including
    /// loads that arrive later over the admin API), so this call only
    /// runs the accept loop; call [`Fleet::drain`] afterwards to
    /// retire the engines and collect per-model stats. Each request's
    /// `model` field picks the engine; an unknown model answers `404`
    /// with code `model_not_found`.
    pub fn serve_fleet(self, fleet: &Fleet) -> Result<()> {
        let Gateway { listener, cfg, vocab, tokenizer, shutdown, metrics } = self;
        listener.set_nonblocking(true).context("set listener non-blocking")?;
        // per-model hubs are enabled at Fleet::load (FleetConfig::trace);
        // the kernel grid is process-global
        kstats::set_enabled(cfg.trace);
        let next_id = AtomicU64::new(0);
        let shared = Shared {
            vocab,
            tokenizer: &tokenizer,
            cfg: &cfg,
            next_id: &next_id,
            metrics: &metrics,
            shutdown: &shutdown,
            started_unix: unix_now(),
        };
        let sh = &shared;

        std::thread::scope(|s| {
            loop {
                if sh.draining() {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let open = sh.metrics.open_connections.load(Ordering::Relaxed);
                        if open >= sh.cfg.max_connections as u64 {
                            refuse_connection(stream, sh);
                            continue;
                        }
                        sh.metrics.open_connections.fetch_add(1, Ordering::Relaxed);
                        s.spawn(move || {
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                handle_connection(stream, sh, Conn::Fleet(fleet));
                            }));
                            sh.metrics.open_connections.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        log_accept_error(&e);
                        std::thread::sleep(ACCEPT_POLL);
                    }
                }
            }
            // stop accepting; in-flight handlers finish against the
            // still-running fleet engines before the scope joins them
            drop(listener);
        });
        Ok(())
    }
}

/// Flood control for the accept loops: an error storm (fd exhaustion,
/// say) repeats the same failure once per poll tick, so the structured
/// line is budgeted — at most 5 per 10-second window, the overflow
/// folded into the next line's `suppressed` count.
static ACCEPT_ERR_LIMIT: RateLimit = RateLimit::new(5, 10);

fn log_accept_error(e: &std::io::Error) {
    if let Some(suppressed) = ACCEPT_ERR_LIMIT.allow() {
        log::warn(
            "gateway",
            "accept error",
            &[("err", e.to_string()), ("suppressed", suppressed.to_string())],
        );
    }
}

/// Answer `503` on a socket accepted past the connection cap.
fn refuse_connection(stream: TcpStream, sh: &Shared<'_>) {
    sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
    let mut w = stream;
    w.set_nonblocking(false).ok();
    w.set_write_timeout(Some(CONN_WRITE_TIMEOUT)).ok();
    let _ = http::write_response(
        &mut w,
        503,
        &[("Content-Type", "application/json"), ("Connection", "close")],
        error_json(503, "too many connections", None).as_bytes(),
    );
}

/// The default model name: what a single-engine gateway serves under
/// and what requests without a `model` field route to.
pub const DEFAULT_MODEL: &str = "rwkvquant";

/// Everything a connection handler needs besides its socket: gateway
/// policy plus the references shared by every handler thread.
struct Shared<'a> {
    vocab: usize,
    tokenizer: &'a Tokenizer,
    cfg: &'a GatewayConfig,
    next_id: &'a AtomicU64,
    metrics: &'a Arc<Metrics>,
    shutdown: &'a AtomicBool,
    started_unix: u64,
}

impl Shared<'_> {
    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
            || (self.cfg.heed_signals && signal::shutdown_signalled())
    }
}

/// Where a connection's requests are submitted: the single shared
/// serve engine (legacy `--store` mode; one clone of the admission
/// sender per connection, so a drain observes handler hang-ups), or
/// the fleet registry (per-model engines resolved per request).
enum Conn<'a> {
    Single(mpsc::Sender<Request>),
    Fleet(&'a Fleet),
}

/// A per-request routing decision: the model, its vocab for prompt
/// validation, and whose metrics registry the request counts against.
struct Target {
    model: String,
    vocab: usize,
    metrics: Arc<Metrics>,
}

/// HTTP-shaped failure: status, message, optional machine-readable
/// code (`model_not_found`).
type ApiError = (u16, String, Option<&'static str>);

fn model_not_found(model: &str) -> ApiError {
    (404, format!("model '{model}' not found"), Some("model_not_found"))
}

fn resolve_target(
    sh: &Shared<'_>,
    conn: &Conn<'_>,
    model: String,
) -> std::result::Result<Target, ApiError> {
    match conn {
        Conn::Single(_) => {
            if model != DEFAULT_MODEL {
                return Err(model_not_found(&model));
            }
            Ok(Target { model, vocab: sh.vocab, metrics: sh.metrics.clone() })
        }
        Conn::Fleet(fleet) => match fleet.resolve(&model) {
            Some(entry) => Ok(Target { model, vocab: entry.vocab(), metrics: entry.metrics() }),
            None => Err(model_not_found(&model)),
        },
    }
}

/// Hand a request to the target's engine. In fleet mode the engine may
/// have been hot-swapped since `resolve_target` — the fleet
/// re-resolves on submit, so a swap never loses the request and a
/// raced delete answers `404`.
fn submit_request(
    conn: &Conn<'_>,
    model: &str,
    request: Request,
) -> std::result::Result<(), ApiError> {
    match conn {
        Conn::Single(tx) => {
            tx.send(request).map_err(|_| (503, "server is draining".to_string(), None))
        }
        Conn::Fleet(fleet) => fleet.submit(model, request).map_err(|e| match e {
            SubmitError::UnknownModel => model_not_found(model),
            SubmitError::Closed => (503, "model engine is draining".to_string(), None),
        }),
    }
}

fn handle_connection(stream: TcpStream, sh: &Shared<'_>, conn: Conn<'_>) {
    // the listener is non-blocking and BSD-family kernels (macOS) let
    // accepted sockets inherit O_NONBLOCK — undo it explicitly, the
    // handler wants blocking reads bounded by the timeouts below
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT)).ok();
    stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT)).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let limits = Limits::default();
    loop {
        if sh.draining() {
            break;
        }
        match http::read_request(&mut reader, &limits) {
            Ok(None) => break, // clean keep-alive close
            Ok(Some(req)) => {
                sh.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
                let close_requested = req
                    .header("connection")
                    .is_some_and(|v| v.eq_ignore_ascii_case("close"));
                if route(&mut writer, &req, sh, &conn).is_err() {
                    break; // client hung up mid-response
                }
                if close_requested || sh.draining() {
                    break;
                }
            }
            Err(e) => {
                // a timed-out idle keep-alive read lands here too
                // (Io → no status → just close)
                if let Some(status) = e.status() {
                    sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = http::write_response(
                        &mut writer,
                        status,
                        &[("Content-Type", "application/json"), ("Connection", "close")],
                        error_json(status, &e.message(), None).as_bytes(),
                    );
                }
                break;
            }
        }
    }
}

/// The OpenAI error `type` for a status code.
fn error_type(status: u16) -> &'static str {
    match status {
        429 => "rate_limit_error",
        500..=599 => "server_error",
        _ => "invalid_request_error",
    }
}

/// Every error response speaks the OpenAI error schema:
/// `{"error":{"code":…,"message":…,"type":…}}`. `code` is `null`
/// unless a machine-readable discriminator applies.
fn error_json(status: u16, msg: &str, code: Option<&str>) -> String {
    let code_val = match code {
        Some(c) => Json::Str(c.to_string()),
        None => Json::Null,
    };
    Json::obj()
        .set(
            "error",
            Json::obj().set("code", code_val).set("message", msg).set("type", error_type(status)),
        )
        .render()
}

/// Count and write an error response. `extra` carries per-status
/// headers (`Retry-After`, `Allow`, `Connection: close`).
fn write_error(
    w: &mut TcpStream,
    sh: &Shared<'_>,
    status: u16,
    msg: &str,
    code: Option<&str>,
    extra: &[(&str, &str)],
) -> std::io::Result<()> {
    sh.metrics.http_errors.fetch_add(1, Ordering::Relaxed);
    let mut headers = vec![("Content-Type", "application/json")];
    headers.extend_from_slice(extra);
    http::write_response(w, status, &headers, error_json(status, msg, code).as_bytes())
}

fn write_api_error(w: &mut TcpStream, sh: &Shared<'_>, err: ApiError) -> std::io::Result<()> {
    let (status, msg, code) = err;
    let extra: &[(&str, &str)] = match status {
        429 => &[("Retry-After", "1")],
        503 => &[("Connection", "close")],
        _ => &[],
    };
    write_error(w, sh, status, &msg, code, extra)
}

/// Handlers the route table can dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HandlerId {
    Healthz,
    MetricsScrape,
    Generate,
    Completions,
    ChatCompletions,
    ModelsList,
    AdminLoadModel,
    AdminDeleteModel,
    AdminTrace,
    AdminInflight,
}

/// The gateway's entire HTTP surface, declaratively: method + path
/// pattern (`{param}` segments match any single non-empty segment) +
/// handler. `match_route` derives uniform `404`s and `405 Allow: …`
/// responses from this table, so adding an endpoint is one row plus a
/// `HandlerId` arm in `route`.
const ROUTES: &[(&str, &str, HandlerId)] = &[
    ("GET", "/healthz", HandlerId::Healthz),
    ("GET", "/metrics", HandlerId::MetricsScrape),
    ("POST", "/v1/generate", HandlerId::Generate),
    ("POST", "/v1/completions", HandlerId::Completions),
    ("POST", "/v1/chat/completions", HandlerId::ChatCompletions),
    ("GET", "/v1/models", HandlerId::ModelsList),
    ("POST", "/admin/models/{name}", HandlerId::AdminLoadModel),
    ("DELETE", "/admin/models/{name}", HandlerId::AdminDeleteModel),
    ("GET", "/admin/trace/{id}", HandlerId::AdminTrace),
    ("GET", "/admin/inflight", HandlerId::AdminInflight),
];

enum RouteMatch {
    Matched { handler: HandlerId, params: Vec<(&'static str, String)> },
    /// The path exists but not under this method; `allow` is the
    /// comma-joined method list for the `Allow` header.
    WrongMethod { allow: String },
    NotFound,
}

/// Match `path` against one route pattern, extracting `{param}`
/// segments. A parameter never matches an empty segment, so
/// `/admin/models/` is a 404 rather than an empty name.
fn path_params(pattern: &'static str, path: &str) -> Option<Vec<(&'static str, String)>> {
    let mut params = Vec::new();
    let mut pat = pattern.split('/');
    let mut got = path.split('/');
    loop {
        match (pat.next(), got.next()) {
            (None, None) => return Some(params),
            (Some(p), Some(g)) => {
                if let Some(name) = p.strip_prefix('{').and_then(|n| n.strip_suffix('}')) {
                    if g.is_empty() {
                        return None;
                    }
                    params.push((name, g.to_string()));
                } else if p != g {
                    return None;
                }
            }
            _ => return None,
        }
    }
}

fn match_route(method: &str, path: &str) -> RouteMatch {
    let mut allow: Vec<&'static str> = Vec::new();
    for (m, pattern, handler) in ROUTES {
        if let Some(params) = path_params(pattern, path) {
            if *m == method {
                return RouteMatch::Matched { handler: *handler, params };
            }
            if !allow.contains(m) {
                allow.push(m);
            }
        }
    }
    if allow.is_empty() {
        RouteMatch::NotFound
    } else {
        RouteMatch::WrongMethod { allow: allow.join(", ") }
    }
}

fn route(
    w: &mut TcpStream,
    req: &HttpRequest,
    sh: &Shared<'_>,
    conn: &Conn<'_>,
) -> std::io::Result<()> {
    match match_route(&req.method, req.path()) {
        RouteMatch::NotFound => write_error(w, sh, 404, "no such endpoint", None, &[]),
        RouteMatch::WrongMethod { allow } => {
            write_error(w, sh, 405, "method not allowed", None, &[("Allow", &allow)])
        }
        RouteMatch::Matched { handler, params } => {
            let param = |name: &str| {
                params.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str()).unwrap_or("")
            };
            match handler {
                HandlerId::Healthz => {
                    http::write_response(w, 200, &[("Content-Type", "text/plain")], b"ok\n")
                }
                HandlerId::MetricsScrape => {
                    let text = match conn {
                        Conn::Single(_) => sh.metrics.render_prometheus(),
                        Conn::Fleet(fleet) => {
                            let models = fleet.model_metrics();
                            let refs: Vec<(&str, &Metrics)> =
                                models.iter().map(|(n, m)| (n.as_str(), &**m)).collect();
                            render_exposition(sh.metrics, &refs)
                        }
                    };
                    http::write_response(
                        w,
                        200,
                        &[("Content-Type", "text/plain; version=0.0.4")],
                        text.as_bytes(),
                    )
                }
                HandlerId::Generate => generate(w, req, sh, conn),
                HandlerId::Completions => completions(w, req, false, sh, conn),
                HandlerId::ChatCompletions => completions(w, req, true, sh, conn),
                HandlerId::ModelsList => models_list(w, sh, conn),
                HandlerId::AdminLoadModel => admin_load(w, req, sh, conn, param("name")),
                HandlerId::AdminDeleteModel => admin_delete(w, sh, conn, param("name")),
                HandlerId::AdminTrace => admin_trace(w, sh, conn, param("id")),
                HandlerId::AdminInflight => admin_inflight(w, sh, conn),
            }
        }
    }
}

/// `GET /v1/models` — the OpenAI model listing. A single-engine
/// gateway reports the one default model (`created` = gateway start);
/// a fleet gateway lists the registry (`created` = store file mtime).
fn models_list(w: &mut TcpStream, sh: &Shared<'_>, conn: &Conn<'_>) -> std::io::Result<()> {
    let data: Vec<Json> = match conn {
        Conn::Single(_) => vec![model_json(DEFAULT_MODEL, sh.started_unix)],
        Conn::Fleet(fleet) => {
            fleet.list().iter().map(|e| model_json(e.name(), e.created())).collect()
        }
    };
    let body = Json::obj().set("data", Json::Arr(data)).set("object", "list").render();
    http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
}

fn model_json(id: &str, created: u64) -> Json {
    Json::obj()
        .set("created", created as f64)
        .set("id", id)
        .set("object", "model")
        .set("owned_by", "rwkvquant")
}

/// Model names admissible over the admin API: path-safe, no traversal.
fn valid_model_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 128
        && !name.contains("..")
        && name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | ':'))
}

fn admin_body_path(body: &[u8]) -> std::result::Result<String, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    v.get("path")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| "missing 'path' (string path to a packed .rwkvq2 store)".to_string())
}

/// `POST /admin/models/{name}` with body `{"path": "store.rwkvq2"}` —
/// load a new model, or hot-swap an existing name with zero downtime
/// (in-flight sequences finish on the old engine, new admissions land
/// on the new one). Fleet mode only.
fn admin_load(
    w: &mut TcpStream,
    req: &HttpRequest,
    sh: &Shared<'_>,
    conn: &Conn<'_>,
    name: &str,
) -> std::io::Result<()> {
    let Conn::Fleet(fleet) = conn else {
        return write_error(
            w,
            sh,
            400,
            "model registry is not enabled (start the gateway with --model)",
            None,
            &[],
        );
    };
    if !valid_model_name(name) {
        return write_error(w, sh, 400, "invalid model name", None, &[]);
    }
    let path = match admin_body_path(&req.body) {
        Ok(p) => p,
        Err(msg) => return write_error(w, sh, 400, &msg, None, &[]),
    };
    match fleet.load(name, std::path::Path::new(&path)) {
        Ok(entry) => {
            let body = model_json(entry.name(), entry.created())
                .set("version", entry.version() as f64)
                .render();
            http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
        }
        Err(e) => write_error(w, sh, 400, &format!("cannot load '{name}': {e:#}"), None, &[]),
    }
}

/// `DELETE /admin/models/{name}` — drain-then-drop: the name stops
/// resolving immediately, in-flight sequences decode to completion on
/// the retired engine, and the store unmaps when it exits. Fleet mode
/// only.
fn admin_delete(
    w: &mut TcpStream,
    sh: &Shared<'_>,
    conn: &Conn<'_>,
    name: &str,
) -> std::io::Result<()> {
    let Conn::Fleet(fleet) = conn else {
        return write_error(
            w,
            sh,
            400,
            "model registry is not enabled (start the gateway with --model)",
            None,
            &[],
        );
    };
    if !valid_model_name(name) {
        return write_error(w, sh, 400, "invalid model name", None, &[]);
    }
    match fleet.remove(name) {
        Some(entry) => {
            let body = Json::obj()
                .set("deleted", true)
                .set("id", entry.name())
                .set("object", "model")
                .render();
            http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
        }
        None => write_api_error(w, sh, model_not_found(name)),
    }
}

/// `GET /admin/trace/{id}` — every retained span for one request, in
/// start order, with the per-stage durations and their sum. Answers
/// `404` when no spans survive in the ring buffers (tracing off, or the
/// request's spans have been overwritten). Request ids are unique
/// across a fleet (one gateway counter), so merging the per-model hubs
/// cannot mix two requests.
fn admin_trace(
    w: &mut TcpStream,
    sh: &Shared<'_>,
    conn: &Conn<'_>,
    id: &str,
) -> std::io::Result<()> {
    use std::fmt::Write as _;
    let Ok(id) = id.parse::<u64>() else {
        return write_error(w, sh, 400, "request id must be an integer", None, &[]);
    };
    let mut spans = match conn {
        Conn::Single(_) => sh.metrics.trace().spans_for(id),
        Conn::Fleet(fleet) => {
            let mut all = Vec::new();
            for (_, m) in fleet.model_metrics() {
                all.extend(m.trace().spans_for(id));
            }
            all
        }
    };
    spans.sort_by_key(|s| (s.start_us, s.dur_us));
    if spans.is_empty() {
        let msg = format!("no spans retained for request {id} (tracing off, or evicted)");
        return write_error(w, sh, 404, &msg, None, &[]);
    }
    let total_us: u64 = spans.iter().map(|s| s.dur_us).sum();
    let mut body = String::with_capacity(80 * spans.len() + 64);
    let _ = write!(body, "{{\"id\":{id},\"spans\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        // the control thread's spans (queue/park/resume) carry lane -1
        let lane: i64 = if s.lane == CONTROL_LANE { -1 } else { s.lane as i64 };
        let _ = write!(
            body,
            "{{\"stage\":\"{}\",\"lane\":{lane},\"start_us\":{},\"dur_us\":{}}}",
            s.stage.name(),
            s.start_us,
            s.dur_us
        );
    }
    let _ = write!(body, "],\"total_us\":{total_us}}}");
    http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
}

/// `GET /admin/inflight` — every sequence currently in an active set:
/// stage (`prefill`/`decode`/`parked`), generated-token count, resident
/// slab slot (or `null` while parked), and age since admission. Empty
/// list when tracing is off or nothing is decoding.
fn admin_inflight(w: &mut TcpStream, sh: &Shared<'_>, conn: &Conn<'_>) -> std::io::Result<()> {
    use std::fmt::Write as _;
    let groups: Vec<(String, Vec<InflightEntry>)> = match conn {
        Conn::Single(_) => vec![(DEFAULT_MODEL.to_string(), sh.metrics.inflight_snapshot())],
        Conn::Fleet(fleet) => fleet
            .model_metrics()
            .into_iter()
            .map(|(n, m)| (n, m.inflight_snapshot()))
            .collect(),
    };
    let mut body = String::from("{\"sequences\":[");
    let mut first = true;
    for (model, entries) in &groups {
        for e in entries {
            if !first {
                body.push(',');
            }
            first = false;
            let slab = e.slab.map_or("null".to_string(), |s| s.to_string());
            let _ = write!(
                body,
                "{{\"id\":{},\"model\":\"{}\",\"stage\":\"{}\",\"generated\":{},\
                 \"prompt_len\":{},\"gen_len\":{},\"slab\":{slab},\"age_ms\":{:.3}}}",
                e.id,
                model,
                e.stage,
                e.generated,
                e.prompt_len,
                e.gen_len,
                ms(e.age),
            );
        }
    }
    body.push_str("]}");
    http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
}

/// Pre-parse pass for the `model` field alone (the raw-token endpoint
/// has no other use for the field). A body that is not JSON resolves
/// to the default model so the endpoint's own parser produces the real
/// 400; a present non-string `model` is rejected here.
fn extract_model(body: &[u8]) -> std::result::Result<String, String> {
    let Ok(text) = std::str::from_utf8(body) else {
        return Ok(DEFAULT_MODEL.to_string());
    };
    let Ok(v) = json::parse(text) else {
        return Ok(DEFAULT_MODEL.to_string());
    };
    match v.get("model") {
        None | Some(Json::Null) => Ok(DEFAULT_MODEL.to_string()),
        Some(m) => {
            m.as_str().map(str::to_string).ok_or_else(|| "'model' must be a string".to_string())
        }
    }
}

/// A validated `/v1/generate` body.
struct GenRequest {
    prompt: Vec<usize>,
    gen_len: usize,
    stream: bool,
}

fn parse_generate_body(
    body: &[u8],
    vocab: usize,
    max_gen_len: usize,
) -> std::result::Result<GenRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let arr = v
        .get("prompt")
        .and_then(Json::as_array)
        .ok_or_else(|| "missing 'prompt' (array of token ids)".to_string())?;
    if arr.is_empty() {
        return Err("'prompt' must not be empty".to_string());
    }
    if arr.len() > MAX_PROMPT {
        return Err(format!("'prompt' longer than {MAX_PROMPT} tokens"));
    }
    let prompt = arr
        .iter()
        .map(|t| {
            t.as_usize()
                .filter(|&t| t < vocab)
                .ok_or_else(|| format!("prompt tokens must be integers below the vocab ({vocab})"))
        })
        .collect::<std::result::Result<Vec<usize>, String>>()?;
    let gen_len = match v.get("gen_len") {
        None => 16,
        Some(g) => g
            .as_usize()
            .filter(|&n| (1..=max_gen_len).contains(&n))
            .ok_or_else(|| format!("'gen_len' must be an integer in 1..={max_gen_len}"))?,
    };
    let stream = match v.get("stream") {
        None => true,
        Some(s) => s.as_bool().ok_or_else(|| "'stream' must be a boolean".to_string())?,
    };
    Ok(GenRequest { prompt, gen_len, stream })
}

/// Render token ids as a JSON array (`[1,2,30]`) — shared with the
/// tests and examples that build request bodies by hand.
pub fn tokens_json(tokens: &[usize]) -> String {
    let mut s = String::from("[");
    for (i, t) in tokens.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&t.to_string());
    }
    s.push(']');
    s
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn generate(
    w: &mut TcpStream,
    req: &HttpRequest,
    sh: &Shared<'_>,
    conn: &Conn<'_>,
) -> std::io::Result<()> {
    let model = match extract_model(&req.body) {
        Ok(m) => m,
        Err(msg) => return write_error(w, sh, 400, &msg, None, &[]),
    };
    let target = match resolve_target(sh, conn, model) {
        Ok(t) => t,
        Err(e) => return write_api_error(w, sh, e),
    };
    let gen = match parse_generate_body(&req.body, target.vocab, sh.cfg.max_gen_len) {
        Ok(g) => g,
        Err(msg) => return write_error(w, sh, 400, &msg, None, &[]),
    };
    target.metrics.generate_requests.fetch_add(1, Ordering::Relaxed);
    let (tx_ev, rx_ev) = mpsc::channel();
    let id = sh.next_id.fetch_add(1, Ordering::Relaxed);
    let request = Request::new(id, gen.prompt, gen.gen_len).with_stream(tx_ev);
    if let Err(e) = submit_request(conn, &target.model, request) {
        return write_api_error(w, sh, e);
    }
    // the first event decides the status line: Shed → 429 before any
    // body byte, Admitted → 200 and the stream begins
    match rx_ev.recv() {
        Err(_) => write_error(w, sh, 500, "serve loop dropped the request", None, &[]),
        Ok(StreamEvent::Shed) => {
            write_error(w, sh, 429, "admission queue full", None, &[("Retry-After", "1")])
        }
        Ok(first) => {
            let r = if gen.stream {
                stream_sse(w, id, first, rx_ev)
            } else {
                collect_json(w, id, first, rx_ev)
            };
            // `id` is the join key: same number in the SSE done event,
            // the X-Request-Id header and /admin/trace/{id}
            log::info(
                "gateway",
                "request done",
                &[("id", id.to_string()), ("model", target.model)],
            );
            r
        }
    }
}

/// A validated OpenAI-style body (`/v1/completions` accepts a string
/// `prompt`, `/v1/chat/completions` a `messages` array rendered through
/// the plain `"{role}: {content}\n"` template plus an `assistant:`
/// generation cue).
struct TextRequest {
    prompt: Vec<usize>,
    max_tokens: usize,
    stream: bool,
    sample: SampleParams,
    stop: Vec<Vec<usize>>,
    model: String,
}

fn text_num(v: &Json, key: &str, default: f32) -> std::result::Result<f32, String> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(x) => x
            .as_f64()
            .map(|n| n as f32)
            .ok_or_else(|| format!("'{key}' must be a number")),
    }
}

fn parse_text_body(
    body: &[u8],
    chat: bool,
    tokenizer: &Tokenizer,
    max_gen_len: usize,
) -> std::result::Result<TextRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let prompt_text = if chat {
        let msgs = v
            .get("messages")
            .and_then(Json::as_array)
            .ok_or_else(|| "missing 'messages' (array of {role, content})".to_string())?;
        if msgs.is_empty() {
            return Err("'messages' must not be empty".to_string());
        }
        let mut s = String::new();
        for (i, m) in msgs.iter().enumerate() {
            let role = m
                .get("role")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("messages[{i}] is missing a string 'role'"))?;
            let content = m
                .get("content")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("messages[{i}] is missing a string 'content'"))?;
            s.push_str(role);
            s.push_str(": ");
            s.push_str(content);
            s.push('\n');
        }
        s.push_str("assistant:");
        s
    } else {
        v.get("prompt")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing 'prompt' (string)".to_string())?
            .to_string()
    };
    let prompt = tokenizer.encode(&prompt_text);
    if prompt.is_empty() {
        return Err("prompt encodes to zero tokens".to_string());
    }
    if prompt.len() > MAX_PROMPT {
        return Err(format!("prompt longer than {MAX_PROMPT} tokens"));
    }
    let max_tokens = match v.get("max_tokens") {
        None | Some(Json::Null) => 16,
        Some(g) => g
            .as_usize()
            .filter(|&n| (1..=max_gen_len).contains(&n))
            .ok_or_else(|| format!("'max_tokens' must be an integer in 1..={max_gen_len}"))?,
    };
    let top_k = match v.get("top_k") {
        None | Some(Json::Null) => 0,
        Some(k) => k
            .as_usize()
            .ok_or_else(|| "'top_k' must be a non-negative integer".to_string())?,
    };
    let seed = match v.get("seed") {
        None | Some(Json::Null) => 0,
        Some(s) => s
            .as_usize()
            .ok_or_else(|| "'seed' must be a non-negative integer".to_string())?
            as u64,
    };
    let sample = SampleParams {
        temperature: text_num(&v, "temperature", 1.0)?,
        top_k,
        top_p: text_num(&v, "top_p", 1.0)?,
        repetition_penalty: text_num(&v, "repetition_penalty", 1.0)?,
        seed,
    };
    sample.validate()?;
    let stop_strings: Vec<String> = match v.get("stop") {
        None | Some(Json::Null) => Vec::new(),
        Some(Json::Str(s)) => vec![s.clone()],
        Some(Json::Arr(xs)) => xs
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| "'stop' entries must be strings".to_string())
            })
            .collect::<std::result::Result<_, _>>()?,
        Some(_) => return Err("'stop' must be a string or an array of strings".to_string()),
    };
    if stop_strings.len() > 4 {
        return Err("'stop' allows at most 4 sequences".to_string());
    }
    let mut stop = Vec::with_capacity(stop_strings.len());
    for s in &stop_strings {
        let ids = tokenizer.encode(s);
        if ids.is_empty() {
            return Err(format!("stop sequence {s:?} encodes to zero tokens"));
        }
        stop.push(ids);
    }
    let stream = match v.get("stream") {
        None | Some(Json::Null) => false, // OpenAI defaults to non-streaming
        Some(s) => s.as_bool().ok_or_else(|| "'stream' must be a boolean".to_string())?,
    };
    let model = match v.get("model") {
        None | Some(Json::Null) => DEFAULT_MODEL.to_string(),
        Some(m) => m
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| "'model' must be a string".to_string())?,
    };
    Ok(TextRequest { prompt, max_tokens, stream, sample, stop, model })
}

/// Labels the OpenAI response writers stamp onto every chunk/body.
struct TextReply<'a> {
    id: u64,
    chat: bool,
    model: &'a str,
    tokenizer: &'a Tokenizer,
    prompt_tokens: usize,
    created: u64,
}

impl TextReply<'_> {
    fn reply_id(&self) -> String {
        format!("{}-{}", if self.chat { "chatcmpl" } else { "cmpl" }, self.id)
    }

    fn object(&self, streamed: bool) -> &'static str {
        match (self.chat, streamed) {
            (true, true) => "chat.completion.chunk",
            (true, false) => "chat.completion",
            // OpenAI uses the same object name for streamed and whole
            // text completions
            (false, _) => "text_completion",
        }
    }

    /// One streamed SSE chunk: `choices[0]` carries either a chat
    /// `delta` or a completion `text` fragment.
    fn chunk_json(&self, delta: &str, role: bool, finish: Option<FinishReason>) -> String {
        let finish_val = match finish {
            Some(f) => Json::Str(f.as_str().to_string()),
            None => Json::Null,
        };
        let choice = if self.chat {
            let mut d = Json::obj();
            if role {
                d = d.set("role", "assistant");
            }
            if !delta.is_empty() {
                d = d.set("content", delta);
            }
            Json::obj().set("delta", d).set("finish_reason", finish_val).set("index", 0usize)
        } else {
            Json::obj().set("finish_reason", finish_val).set("index", 0usize).set("text", delta)
        };
        Json::obj()
            .set("choices", Json::Arr(vec![choice]))
            .set("created", self.created as f64)
            .set("id", self.reply_id())
            .set("model", self.model)
            .set("object", self.object(true))
            .render()
    }

    /// The whole-document (non-streaming) response body.
    fn body_json(&self, text: &str, completion_tokens: usize, finish: FinishReason) -> String {
        let choice = if self.chat {
            Json::obj()
                .set("finish_reason", finish.as_str())
                .set("index", 0usize)
                .set("message", Json::obj().set("content", text).set("role", "assistant"))
        } else {
            Json::obj().set("finish_reason", finish.as_str()).set("index", 0usize).set("text", text)
        };
        Json::obj()
            .set("choices", Json::Arr(vec![choice]))
            .set("created", self.created as f64)
            .set("id", self.reply_id())
            .set("model", self.model)
            .set("object", self.object(false))
            .set(
                "usage",
                Json::obj()
                    .set("completion_tokens", completion_tokens)
                    .set("prompt_tokens", self.prompt_tokens)
                    .set("total_tokens", self.prompt_tokens + completion_tokens),
            )
            .render()
    }
}

fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

fn completions(
    w: &mut TcpStream,
    req: &HttpRequest,
    chat: bool,
    sh: &Shared<'_>,
    conn: &Conn<'_>,
) -> std::io::Result<()> {
    let t = match parse_text_body(&req.body, chat, sh.tokenizer, sh.cfg.max_gen_len) {
        Ok(t) => t,
        Err(msg) => return write_error(w, sh, 400, &msg, None, &[]),
    };
    let target = match resolve_target(sh, conn, t.model.clone()) {
        Ok(tg) => tg,
        Err(e) => return write_api_error(w, sh, e),
    };
    // the tokenizer is gateway-wide but vocabs are per-model: a prompt
    // that encodes past this model's vocab must bounce, not index OOB
    if let Some(&bad) = t.prompt.iter().find(|&&tok| tok >= target.vocab) {
        let msg = format!(
            "prompt token {bad} is outside model '{}' vocab ({})",
            target.model, target.vocab
        );
        return write_error(w, sh, 400, &msg, None, &[]);
    }
    target.metrics.text_requests.fetch_add(1, Ordering::Relaxed);
    let (tx_ev, rx_ev) = mpsc::channel();
    let id = sh.next_id.fetch_add(1, Ordering::Relaxed);
    let cancel = Arc::new(AtomicBool::new(false));
    let reply = TextReply {
        id,
        chat,
        model: &t.model,
        tokenizer: sh.tokenizer,
        prompt_tokens: t.prompt.len(),
        created: unix_now(),
    };
    let request = Request::new(id, t.prompt, t.max_tokens)
        .with_stream(tx_ev)
        .with_sampling(t.sample)
        .with_stop(t.stop)
        .with_cancel(cancel.clone());
    if let Err(e) = submit_request(conn, &target.model, request) {
        return write_api_error(w, sh, e);
    }
    match rx_ev.recv() {
        Err(_) => write_error(w, sh, 500, "serve loop dropped the request", None, &[]),
        Ok(StreamEvent::Shed) => {
            write_error(w, sh, 429, "admission queue full", None, &[("Retry-After", "1")])
        }
        Ok(first) => {
            let r = if t.stream {
                stream_openai(w, &reply, first, rx_ev)
            } else {
                collect_openai(w, &reply, first, rx_ev)
            };
            if r.is_err() {
                // client hung up mid-response: raise the cancel flag so
                // the serve loop frees the slab instead of decoding an
                // orphan to completion
                cancel.store(true, Ordering::Relaxed);
            }
            log::info(
                "gateway",
                "request done",
                &[("id", id.to_string()), ("model", target.model)],
            );
            r
        }
    }
}

/// Stream an OpenAI completion as SSE delta chunks: one chunk per
/// decoded token, a final chunk carrying the `finish_reason`, then the
/// protocol's `data: [DONE]` terminator.
fn stream_openai(
    w: &mut TcpStream,
    r: &TextReply<'_>,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let id_text = r.id.to_string();
    let mut cw = ChunkedWriter::begin(
        &mut *w,
        200,
        &[
            ("Content-Type", "text/event-stream"),
            ("Cache-Control", "no-cache"),
            ("X-Request-Id", &id_text),
        ],
    )?;
    if r.chat {
        // the opening chunk announces the assistant role, per protocol
        cw.chunk(format!("data: {}\n\n", r.chunk_json("", true, None)).as_bytes())?;
    }
    let mut finished: Option<FinishReason> = None;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop gone; truncate the stream
            },
        };
        match e {
            StreamEvent::Admitted { .. } => {} // no OpenAI analogue
            StreamEvent::Token(t) => {
                let piece = r.tokenizer.decode(&[t]);
                cw.chunk(format!("data: {}\n\n", r.chunk_json(&piece, false, None)).as_bytes())?;
            }
            StreamEvent::Done { finish, .. } => {
                finished = Some(finish);
                break;
            }
            StreamEvent::Shed => break,
        }
    }
    if let Some(finish) = finished {
        cw.chunk(format!("data: {}\n\n", r.chunk_json("", false, Some(finish))).as_bytes())?;
        cw.chunk(b"data: [DONE]\n\n")?;
    }
    cw.finish()
}

/// `"stream": false` — wait for completion, answer one OpenAI
/// completion object (with `usage` accounting). As with
/// [`collect_json`], a missing `Done` is a 500, never a truncated body.
fn collect_openai(
    w: &mut TcpStream,
    r: &TextReply<'_>,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let mut tokens: Vec<usize> = Vec::new();
    let mut finished: Option<FinishReason> = None;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break,
            },
        };
        match e {
            StreamEvent::Admitted { .. } => {}
            StreamEvent::Token(t) => tokens.push(t),
            StreamEvent::Done { finish, .. } => {
                finished = Some(finish);
                break;
            }
            StreamEvent::Shed => break,
        }
    }
    let Some(finish) = finished else {
        return http::write_response(
            w,
            500,
            &[("Content-Type", "application/json")],
            error_json(500, "generation aborted before completion", None).as_bytes(),
        );
    };
    let text = r.tokenizer.decode(&tokens);
    let body = r.body_json(&text, tokens.len(), finish);
    let id_text = r.id.to_string();
    http::write_response(
        w,
        200,
        &[("Content-Type", "application/json"), ("X-Request-Id", &id_text)],
        body.as_bytes(),
    )
}

/// Stream one request's events as SSE over chunked transfer: one
/// `data:` chunk per token as the tick produces it, a final `done`
/// event carrying the full token list and timings.
fn stream_sse(
    w: &mut TcpStream,
    id: u64,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let id_text = id.to_string();
    let mut cw = ChunkedWriter::begin(
        &mut *w,
        200,
        &[
            ("Content-Type", "text/event-stream"),
            ("Cache-Control", "no-cache"),
            ("X-Request-Id", &id_text),
        ],
    )?;
    let mut tokens: Vec<usize> = Vec::new();
    let mut queued_ms = 0.0f64;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop gone; terminate the stream
            },
        };
        match e {
            StreamEvent::Admitted { queued } => {
                queued_ms = ms(queued);
                cw.chunk(
                    format!("data: {{\"admitted\":true,\"queued_ms\":{queued_ms:.3}}}\n\n")
                        .as_bytes(),
                )?;
            }
            StreamEvent::Token(t) => {
                tokens.push(t);
                cw.chunk(format!("data: {{\"token\":{t}}}\n\n").as_bytes())?;
            }
            StreamEvent::Done { latency, ttft, finish } => {
                cw.chunk(
                    format!(
                        "data: {{\"done\":true,\"finish_reason\":\"{}\",\"id\":{id},\
                         \"tokens\":{},\"queued_ms\":{queued_ms:.3},\"ttft_ms\":{:.3},\
                         \"latency_ms\":{:.3}}}\n\n",
                        finish.as_str(),
                        tokens_json(&tokens),
                        ms(ttft),
                        ms(latency),
                    )
                    .as_bytes(),
                )?;
                break;
            }
            // unreachable after admission; terminate defensively
            StreamEvent::Shed => break,
        }
    }
    cw.finish()
}

/// `"stream": false` — wait for completion, answer one JSON document.
/// Nothing has been written yet when the serve loop dies mid-request,
/// so a missing `Done` is answered as a 500 — a truncated token list
/// must never masquerade as a completed generation.
fn collect_json(
    w: &mut TcpStream,
    id: u64,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let mut tokens: Vec<usize> = Vec::new();
    let mut queued_ms = 0.0f64;
    let mut ttft_ms = 0.0f64;
    let mut latency_ms = 0.0f64;
    let mut finished: Option<FinishReason> = None;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop died before Done
            },
        };
        match e {
            StreamEvent::Admitted { queued } => queued_ms = ms(queued),
            StreamEvent::Token(t) => tokens.push(t),
            StreamEvent::Done { latency, ttft, finish } => {
                latency_ms = ms(latency);
                ttft_ms = ms(ttft);
                finished = Some(finish);
                break;
            }
            StreamEvent::Shed => break,
        }
    }
    let Some(finish) = finished else {
        return http::write_response(
            w,
            500,
            &[("Content-Type", "application/json")],
            error_json(500, "generation aborted before completion", None).as_bytes(),
        );
    };
    let body = format!(
        "{{\"finish_reason\":\"{}\",\"id\":{id},\"tokens\":{},\
         \"queued_ms\":{queued_ms:.3},\"ttft_ms\":{ttft_ms:.3},\
         \"latency_ms\":{latency_ms:.3}}}",
        finish.as_str(),
        tokens_json(&tokens)
    );
    http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
}

/// Split an SSE body into its `data: ` payloads (client-side helper for
/// the tests, the e2e example and the smoke driver).
pub fn sse_data(body: &str) -> Vec<&str> {
    body.lines().filter_map(|l| l.strip_prefix("data: ")).collect()
}

/// Extract the streamed tokens from an SSE body: the incremental
/// `token` events, checked against the final `done` event's list.
pub fn sse_tokens(body: &str) -> Result<Vec<usize>> {
    let mut streamed = Vec::new();
    let mut done_tokens: Option<Vec<usize>> = None;
    for payload in sse_data(body) {
        let v = json::parse(payload).map_err(|e| anyhow::anyhow!("bad SSE payload: {e}"))?;
        if let Some(t) = v.get("token").and_then(Json::as_usize) {
            streamed.push(t);
        }
        if v.get("done").and_then(Json::as_bool) == Some(true) {
            let list = v
                .get("tokens")
                .and_then(Json::as_array)
                .context("done event without tokens")?
                .iter()
                .map(|t| t.as_usize().context("non-integer token in done event"))
                .collect::<Result<Vec<usize>>>()?;
            done_tokens = Some(list);
        }
    }
    let done = done_tokens.context("SSE stream ended without a done event")?;
    anyhow::ensure!(
        streamed == done,
        "incrementally streamed tokens {streamed:?} disagree with the done event {done:?}"
    );
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_body_validation() {
        let ok = parse_generate_body(br#"{"prompt":[1,2,3],"gen_len":4}"#, 32, 64).unwrap();
        assert_eq!(ok.prompt, vec![1, 2, 3]);
        assert_eq!(ok.gen_len, 4);
        assert!(ok.stream, "stream defaults to true");

        let ok = parse_generate_body(br#"{"prompt":[0],"stream":false}"#, 32, 64).unwrap();
        assert_eq!(ok.gen_len, 16, "gen_len defaults to 16");
        assert!(!ok.stream);

        for (bad, why) in [
            (&br#"{"gen_len":4}"#[..], "missing prompt"),
            (br#"{"prompt":[]}"#, "empty prompt"),
            (br#"{"prompt":[99]}"#, "token >= vocab"),
            (br#"{"prompt":[-1]}"#, "negative token"),
            (br#"{"prompt":[1.5]}"#, "fractional token"),
            (br#"{"prompt":[1],"gen_len":0}"#, "gen_len 0"),
            (br#"{"prompt":[1],"gen_len":65}"#, "gen_len beyond cap"),
            (br#"{"prompt":[1],"stream":"yes"}"#, "non-bool stream"),
            (br#"{"prompt":"abc"}"#, "non-array prompt"),
            (b"not json", "not json"),
            (&[0xff, 0xfe][..], "not utf-8"),
        ] {
            assert!(parse_generate_body(bad, 32, 64).is_err(), "{why} must be rejected");
        }
    }

    #[test]
    fn sse_token_extraction_checks_consistency() {
        let body = "data: {\"admitted\":true,\"queued_ms\":0.1}\n\n\
                    data: {\"token\":5}\n\ndata: {\"token\":9}\n\n\
                    data: {\"done\":true,\"finish_reason\":\"length\",\"id\":0,\
                    \"tokens\":[5,9],\"queued_ms\":0.1,\
                    \"ttft_ms\":1.2,\"latency_ms\":2.0}\n\n";
        assert_eq!(sse_tokens(body).unwrap(), vec![5, 9]);

        let inconsistent = body.replace("[5,9]", "[5,8]");
        assert!(sse_tokens(&inconsistent).is_err());
        assert!(sse_tokens("data: {\"token\":5}\n\n").is_err(), "missing done must error");
    }

    #[test]
    fn tokens_json_renders_plain_arrays() {
        assert_eq!(tokens_json(&[]), "[]");
        assert_eq!(tokens_json(&[7]), "[7]");
        assert_eq!(tokens_json(&[1, 2, 30]), "[1,2,30]");
    }

    #[test]
    fn text_body_validation() {
        let tok = Tokenizer::synthetic(512);

        let ok = parse_text_body(br#"{"prompt":"w3 w1 w2 "}"#, false, &tok, 64).unwrap();
        assert_eq!(ok.prompt, vec![3, 1, 2]);
        assert_eq!(ok.max_tokens, 16, "max_tokens defaults to 16");
        assert!(!ok.stream, "OpenAI requests default to non-streaming");
        assert_eq!(ok.sample.temperature, 1.0);
        assert_eq!(ok.sample.seed, 0, "unseeded requests are still deterministic");
        assert!(ok.stop.is_empty());
        assert_eq!(ok.model, "rwkvquant");

        let ok = parse_text_body(
            br#"{"prompt":"w7 ","max_tokens":4,"temperature":0,"stream":true,
                 "stop":"w9 ","model":"m","seed":42}"#,
            false,
            &tok,
            64,
        )
        .unwrap();
        assert!(ok.sample.is_greedy());
        assert_eq!(ok.max_tokens, 4);
        assert!(ok.stream);
        assert_eq!(ok.stop, vec![vec![9]]);
        assert_eq!(ok.sample.seed, 42);
        assert_eq!(ok.model, "m");

        let ok = parse_text_body(
            br#"{"prompt":"w7 ","stop":["w9 ","w10 w11 "]}"#,
            false,
            &tok,
            64,
        )
        .unwrap();
        assert_eq!(ok.stop, vec![vec![9], vec![10, 11]]);

        // the chat template renders "user: w3 w1 \nassistant:" — the
        // covered words survive, everything else tokenizes to <unk>
        let ok = parse_text_body(
            br#"{"messages":[{"role":"user","content":"w3 w1 "}]}"#,
            true,
            &tok,
            64,
        )
        .unwrap();
        assert!(ok.prompt.contains(&3) && ok.prompt.contains(&1));

        for (bad, why) in [
            (&br#"{"max_tokens":4}"#[..], "missing prompt"),
            (br#"{"prompt":""}"#, "empty prompt"),
            (br#"{"prompt":[1,2]}"#, "token-id prompt on the text endpoint"),
            (br#"{"prompt":"w1 ","max_tokens":0}"#, "max_tokens 0"),
            (br#"{"prompt":"w1 ","max_tokens":65}"#, "max_tokens beyond cap"),
            (br#"{"prompt":"w1 ","temperature":-1}"#, "negative temperature"),
            (br#"{"prompt":"w1 ","top_p":0}"#, "top_p out of (0,1]"),
            (br#"{"prompt":"w1 ","repetition_penalty":0}"#, "zero repetition penalty"),
            (br#"{"prompt":"w1 ","stop":7}"#, "non-string stop"),
            (br#"{"prompt":"w1 ","stop":[7]}"#, "non-string stop entry"),
            (br#"{"prompt":"w1 ","stop":["a","b","c","d","e"]}"#, "more than 4 stops"),
            (br#"{"prompt":"w1 ","seed":-4}"#, "negative seed"),
            (br#"{"prompt":"w1 ","stream":"yes"}"#, "non-bool stream"),
            (br#"{"prompt":"w1 ","model":7}"#, "non-string model"),
            (br#"{"prompt":"w1 ","model":["a"]}"#, "array model"),
            (b"not json", "not json"),
        ] {
            assert!(parse_text_body(bad, false, &tok, 64).is_err(), "{why} must be rejected");
        }
        assert!(
            parse_text_body(br#"{"messages":[]}"#, true, &tok, 64).is_err(),
            "empty messages must be rejected"
        );
        assert!(
            parse_text_body(br#"{"messages":[{"role":"user"}]}"#, true, &tok, 64).is_err(),
            "message without content must be rejected"
        );
    }

    #[test]
    fn openai_bodies_render_to_protocol_shape() {
        let tok = Tokenizer::synthetic(16);
        let r = TextReply {
            id: 3,
            chat: false,
            model: "m",
            tokenizer: &tok,
            prompt_tokens: 2,
            created: 1700000000,
        };
        assert_eq!(
            r.body_json("w5 ", 1, FinishReason::Stop),
            "{\"choices\":[{\"finish_reason\":\"stop\",\"index\":0,\"text\":\"w5 \"}],\
             \"created\":1700000000,\"id\":\"cmpl-3\",\"model\":\"m\",\
             \"object\":\"text_completion\",\"usage\":{\"completion_tokens\":1,\
             \"prompt_tokens\":2,\"total_tokens\":3}}"
        );
        assert_eq!(
            r.chunk_json("w5 ", false, None),
            "{\"choices\":[{\"finish_reason\":null,\"index\":0,\"text\":\"w5 \"}],\
             \"created\":1700000000,\"id\":\"cmpl-3\",\"model\":\"m\",\
             \"object\":\"text_completion\"}"
        );

        let r = TextReply { chat: true, ..r };
        let body = r.body_json("hi", 1, FinishReason::Length);
        assert!(body.contains("\"object\":\"chat.completion\""), "{body}");
        assert!(body.contains("\"id\":\"chatcmpl-3\""), "{body}");
        assert!(
            body.contains("\"message\":{\"content\":\"hi\",\"role\":\"assistant\"}"),
            "{body}"
        );
        let role = r.chunk_json("", true, None);
        assert!(role.contains("\"delta\":{\"role\":\"assistant\"}"), "{role}");
        assert!(role.contains("\"object\":\"chat.completion.chunk\""), "{role}");
        let delta = r.chunk_json("hi", false, None);
        assert!(delta.contains("\"delta\":{\"content\":\"hi\"}"), "{delta}");
        let last = r.chunk_json("", false, Some(FinishReason::Cancelled));
        assert!(
            last.contains("\"delta\":{},\"finish_reason\":\"cancelled\""),
            "{last}"
        );
    }

    #[test]
    fn route_table_matches_methods_paths_and_params() {
        match match_route("GET", "/healthz") {
            RouteMatch::Matched { handler, params } => {
                assert_eq!(handler, HandlerId::Healthz);
                assert!(params.is_empty());
            }
            _ => panic!("GET /healthz must match"),
        }
        match match_route("POST", "/admin/models/rwkv-6b") {
            RouteMatch::Matched { handler, params } => {
                assert_eq!(handler, HandlerId::AdminLoadModel);
                assert_eq!(params, vec![("name", "rwkv-6b".to_string())]);
            }
            _ => panic!("admin load must match and bind {{name}}"),
        }
        match match_route("DELETE", "/admin/models/a") {
            RouteMatch::Matched { handler, .. } => {
                assert_eq!(handler, HandlerId::AdminDeleteModel)
            }
            _ => panic!("admin delete must match"),
        }

        // wrong method on an existing path lists the allowed methods
        match match_route("GET", "/v1/generate") {
            RouteMatch::WrongMethod { allow } => assert_eq!(allow, "POST"),
            _ => panic!("GET on a POST route must be WrongMethod"),
        }
        match match_route("PUT", "/admin/models/x") {
            RouteMatch::WrongMethod { allow } => assert_eq!(allow, "POST, DELETE"),
            _ => panic!("PUT on the admin path must be WrongMethod"),
        }

        // unknown paths — including an empty {name} segment — are 404s
        for (method, path) in [
            ("GET", "/nope"),
            ("POST", "/admin/models"),
            ("POST", "/admin/models/"),
            ("POST", "/admin/models/a/b"),
            ("GET", "/v1/models/extra"),
            ("GET", ""),
        ] {
            assert!(
                matches!(match_route(method, path), RouteMatch::NotFound),
                "{method} {path} must be NotFound"
            );
        }
    }

    #[test]
    fn error_schema_is_openai_shaped() {
        assert_eq!(
            error_json(404, "model 'x' not found", Some("model_not_found")),
            "{\"error\":{\"code\":\"model_not_found\",\
             \"message\":\"model 'x' not found\",\"type\":\"invalid_request_error\"}}"
        );
        assert_eq!(
            error_json(429, "admission queue full", None),
            "{\"error\":{\"code\":null,\"message\":\"admission queue full\",\
             \"type\":\"rate_limit_error\"}}"
        );
        assert!(error_json(503, "draining", None).contains("\"type\":\"server_error\""));
        assert!(error_json(400, "bad", None).contains("\"type\":\"invalid_request_error\""));
    }

    #[test]
    fn model_extraction_and_name_validation() {
        assert_eq!(extract_model(br#"{"prompt":[1],"model":"m"}"#).unwrap(), "m");
        assert_eq!(extract_model(br#"{"prompt":[1]}"#).unwrap(), DEFAULT_MODEL);
        assert_eq!(extract_model(br#"{"model":null}"#).unwrap(), DEFAULT_MODEL);
        // a non-JSON body defers to the endpoint parser's own 400
        assert_eq!(extract_model(b"not json").unwrap(), DEFAULT_MODEL);
        assert_eq!(extract_model(&[0xff, 0xfe]).unwrap(), DEFAULT_MODEL);
        assert!(extract_model(br#"{"model":7}"#).is_err(), "non-string model must error");

        assert!(valid_model_name("rwkv-6b_v1.2:q4"));
        assert!(!valid_model_name(""));
        assert!(!valid_model_name(".."));
        assert!(!valid_model_name("a..b"));
        assert!(!valid_model_name("a/b"));
        assert!(!valid_model_name("a b"));
        assert!(!valid_model_name(&"x".repeat(129)));
    }

    #[test]
    fn model_listing_renders_openai_shape() {
        let body = Json::obj()
            .set("data", Json::Arr(vec![model_json("m", 1700000000)]))
            .set("object", "list")
            .render();
        assert_eq!(
            body,
            "{\"data\":[{\"created\":1700000000,\"id\":\"m\",\"object\":\"model\",\
             \"owned_by\":\"rwkvquant\"}],\"object\":\"list\"}"
        );
    }
}
