//! The HTTP serving gateway: the first network boundary in the
//! codebase. A `TcpListener` accept loop feeds per-connection handler
//! threads; each generation request is parsed ([`super::http`] +
//! [`super::json`]), forwarded into the **same** `coordinator::serve`
//! loop the CLI uses (over a persistent `TickPool`), and its tokens are
//! streamed back incrementally as Server-Sent Events over chunked
//! transfer — one SSE chunk per tick-produced token.
//!
//! Operational behaviour:
//!
//! * **Admission control** — the serve loop's bounded queue
//!   (`--max-queue`) sheds overflow; a shed request is answered `429
//!   Too Many Requests` (with `Retry-After`) and counted in `/metrics`.
//!   A connection cap answers `503` before parsing when the handler
//!   pool is exhausted.
//! * **Observability** — `GET /healthz` for probes, `GET /metrics` in
//!   Prometheus text format ([`Metrics`]): served tokens/sec, queue
//!   depth + high-water mark, shed count, latency and admission-wait
//!   quantiles.
//! * **Graceful drain** — [`GatewayHandle::shutdown`] (or
//!   SIGINT/SIGTERM when [`GatewayConfig::heed_signals`] is set) stops
//!   the accept loop, closes the listener, lets every in-flight request
//!   decode to completion through the tick pool, then returns the
//!   session's [`ServeStats`]. The process exits 0 — never mid-tick.
//!
//! There is no request cancellation: a client that disconnects
//! mid-stream stops receiving tokens, but its sequence decodes to
//! completion (events into a dropped channel are discarded).

use crate::coordinator::serve::{
    with_tick_pool_opts, Decoder, PoolOpts, Request, Response, ServeOpts, ServeStats, StreamEvent,
};
use crate::report::json::Json;
use crate::server::http::{self, ChunkedWriter, HttpRequest, Limits};
use crate::server::metrics::Metrics;
use crate::server::{json, signal};
use crate::Result;
use anyhow::Context;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Accept-loop poll cadence while idle (the listener is non-blocking so
/// the loop can observe the shutdown flag).
const ACCEPT_POLL: Duration = Duration::from_millis(15);
/// Per-connection read timeout: bounds how long an idle keep-alive
/// connection can delay a drain.
const CONN_READ_TIMEOUT: Duration = Duration::from_secs(5);
/// Per-connection write timeout: a client that stops reading its
/// response cannot park a handler thread (and its admission-channel
/// clone) forever — the stalled write errors out and the connection is
/// dropped, so a drain always completes.
const CONN_WRITE_TIMEOUT: Duration = Duration::from_secs(30);
/// Longest accepted prompt, in tokens.
const MAX_PROMPT: usize = 4096;

/// Gateway policy. `addr` is `host:port` (`:0` binds an ephemeral port,
/// reported by [`Gateway::local_addr`]).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub addr: String,
    /// Continuous-batching width of the serve session.
    pub max_batch: usize,
    /// Batch-forming wait of the serve session.
    pub max_wait: Duration,
    /// Bounded admission queue: overflow is shed with a 429.
    pub max_queue: usize,
    /// Per-request `gen_len` cap (400 beyond it).
    pub max_gen_len: usize,
    /// Concurrent-connection cap (503 beyond it).
    pub max_connections: usize,
    /// Prompt tokens a prefilling sequence consumes per tick
    /// (`ServeOpts::prefill_chunk`; 1 = legacy one-per-tick).
    pub prefill_chunk: usize,
    /// State-arena slabs (`ServeOpts::state_slots`); `0` = one per
    /// batch slot.
    pub state_slots: usize,
    /// Pin tick worker lanes to CPUs (`PoolOpts::pin_workers`).
    pub pin_workers: bool,
    /// Also drain on SIGINT/SIGTERM (requires
    /// [`signal::install_shutdown_signals`]; the CLI sets this, tests
    /// use the explicit handle so a test-raised signal cannot leak into
    /// unrelated gateways).
    pub heed_signals: bool,
}

impl GatewayConfig {
    pub fn new(addr: impl Into<String>) -> GatewayConfig {
        GatewayConfig {
            addr: addr.into(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_queue: 64,
            max_gen_len: 512,
            max_connections: 128,
            prefill_chunk: 32,
            state_slots: 0,
            pin_workers: false,
            heed_signals: false,
        }
    }
}

/// A bound (but not yet serving) gateway. Two-phase so callers learn
/// the ephemeral port and can clone a [`GatewayHandle`] before the
/// blocking [`Gateway::serve`] call.
pub struct Gateway {
    listener: TcpListener,
    cfg: GatewayConfig,
    vocab: usize,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
}

/// Clonable remote control for a running gateway.
#[derive(Clone)]
pub struct GatewayHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
}

impl GatewayHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin a graceful drain: stop accepting, finish in-flight work,
    /// return from [`Gateway::serve`].
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

impl Gateway {
    /// Bind the listener; serving starts with [`Gateway::serve`].
    pub fn bind(cfg: GatewayConfig, vocab: usize) -> Result<Gateway> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        Ok(Gateway {
            listener,
            cfg,
            vocab,
            shutdown: Arc::new(AtomicBool::new(false)),
            metrics: Arc::new(Metrics::new()),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local addr")
    }

    pub fn handle(&self) -> GatewayHandle {
        GatewayHandle {
            addr: self.local_addr(),
            shutdown: self.shutdown.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Run the gateway until a drain is requested: the calling thread
    /// becomes the accept loop, a scoped sibling thread runs the serve
    /// session on a persistent `TickPool` over `decoders` (one lane
    /// per decoder), and each connection gets a scoped handler thread.
    /// Returns the serve session's stats once every in-flight request
    /// has decoded to completion.
    pub fn serve<D: Decoder + Send>(self, decoders: &mut [D]) -> Result<ServeStats> {
        anyhow::ensure!(!decoders.is_empty(), "the gateway needs at least one decoder");
        let Gateway { listener, cfg, vocab, shutdown, metrics } = self;
        listener.set_nonblocking(true).context("set listener non-blocking")?;
        let (tx_req, rx_req) = mpsc::channel::<Request>();
        let (tx_resp, rx_resp) = mpsc::channel::<Response>();
        // final Responses are redundant here — every handler consumes
        // its own event stream — and the serve loop tolerates a closed
        // response channel, so drop the receiver up front
        drop(rx_resp);
        let mut opts = ServeOpts::new(cfg.max_batch, cfg.max_wait)
            .with_max_queue(cfg.max_queue)
            .with_prefill_chunk(cfg.prefill_chunk);
        if cfg.state_slots > 0 {
            opts = opts.with_state_slots(cfg.state_slots);
        }
        let popts = PoolOpts::default().with_pin_workers(cfg.pin_workers);
        let next_id = AtomicU64::new(0);
        let metrics_ref: &Metrics = &metrics;
        let shutdown_ref: &AtomicBool = &shutdown;
        let cfg_ref = &cfg;
        let next_id_ref = &next_id;
        let opts_ref = &opts;

        std::thread::scope(|s| {
            let engine = s.spawn(move || {
                with_tick_pool_opts(decoders, popts, |pool| {
                    pool.serve_with(rx_req, tx_resp, opts_ref, metrics_ref)
                })
            });

            loop {
                if draining(cfg_ref, shutdown_ref) {
                    break;
                }
                if engine.is_finished() {
                    // the serve loop died (decoder fault) — stop
                    // accepting and surface the panic via join below
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let open = metrics_ref.open_connections.load(Ordering::Relaxed);
                        if open >= cfg_ref.max_connections as u64 {
                            metrics_ref.http_errors.fetch_add(1, Ordering::Relaxed);
                            let mut w = stream;
                            w.set_nonblocking(false).ok();
                            w.set_write_timeout(Some(CONN_WRITE_TIMEOUT)).ok();
                            let _ = http::write_response(
                                &mut w,
                                503,
                                &[("Content-Type", "application/json"), ("Connection", "close")],
                                br#"{"error":"too many connections"}"#,
                            );
                            continue;
                        }
                        metrics_ref.open_connections.fetch_add(1, Ordering::Relaxed);
                        let tx = tx_req.clone();
                        s.spawn(move || {
                            // a handler panic must not tear down the
                            // whole gateway at scope join
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                handle_connection(
                                    stream,
                                    vocab,
                                    cfg_ref,
                                    tx,
                                    next_id_ref,
                                    metrics_ref,
                                    shutdown_ref,
                                );
                            }));
                            metrics_ref.open_connections.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        eprintln!("gateway: accept error: {e}");
                        std::thread::sleep(ACCEPT_POLL);
                    }
                }
            }

            // drain: stop accepting (new connects are refused), close
            // admissions once the in-flight handlers hang up, and wait
            // for the serve loop to finish every admitted sequence
            drop(listener);
            drop(tx_req);
            engine.join().expect("serve engine thread panicked")
        })
    }
}

fn draining(cfg: &GatewayConfig, shutdown: &AtomicBool) -> bool {
    shutdown.load(Ordering::SeqCst) || (cfg.heed_signals && signal::shutdown_signalled())
}

fn handle_connection(
    stream: TcpStream,
    vocab: usize,
    cfg: &GatewayConfig,
    tx_req: mpsc::Sender<Request>,
    next_id: &AtomicU64,
    metrics: &Metrics,
    shutdown: &AtomicBool,
) {
    // the listener is non-blocking and BSD-family kernels (macOS) let
    // accepted sockets inherit O_NONBLOCK — undo it explicitly, the
    // handler wants blocking reads bounded by the timeouts below
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT)).ok();
    stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT)).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let limits = Limits::default();
    loop {
        if draining(cfg, shutdown) {
            break;
        }
        match http::read_request(&mut reader, &limits) {
            Ok(None) => break, // clean keep-alive close
            Ok(Some(req)) => {
                metrics.http_requests.fetch_add(1, Ordering::Relaxed);
                let close_requested = req
                    .header("connection")
                    .is_some_and(|v| v.eq_ignore_ascii_case("close"));
                if route(&mut writer, &req, vocab, cfg, &tx_req, next_id, metrics).is_err() {
                    break; // client hung up mid-response
                }
                if close_requested || draining(cfg, shutdown) {
                    break;
                }
            }
            Err(e) => {
                // a timed-out idle keep-alive read lands here too
                // (Io → no status → just close)
                if let Some(status) = e.status() {
                    metrics.http_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = http::write_response(
                        &mut writer,
                        status,
                        &[("Content-Type", "application/json"), ("Connection", "close")],
                        error_body(&e.message()).as_bytes(),
                    );
                }
                break;
            }
        }
    }
}

fn error_body(msg: &str) -> String {
    Json::obj().set("error", msg).render()
}

fn route(
    w: &mut TcpStream,
    req: &HttpRequest,
    vocab: usize,
    cfg: &GatewayConfig,
    tx_req: &mpsc::Sender<Request>,
    next_id: &AtomicU64,
    metrics: &Metrics,
) -> std::io::Result<()> {
    const JSON_CT: (&str, &str) = ("Content-Type", "application/json");
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => {
            http::write_response(w, 200, &[("Content-Type", "text/plain")], b"ok\n")
        }
        ("GET", "/metrics") => {
            let text = metrics.render_prometheus();
            http::write_response(
                w,
                200,
                &[("Content-Type", "text/plain; version=0.0.4")],
                text.as_bytes(),
            )
        }
        ("POST", "/v1/generate") => generate(w, req, vocab, cfg, tx_req, next_id, metrics),
        (_, "/healthz" | "/metrics") => {
            metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                405,
                &[JSON_CT, ("Allow", "GET")],
                error_body("method not allowed").as_bytes(),
            )
        }
        (_, "/v1/generate") => {
            metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                405,
                &[JSON_CT, ("Allow", "POST")],
                error_body("method not allowed").as_bytes(),
            )
        }
        _ => {
            metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(w, 404, &[JSON_CT], error_body("no such endpoint").as_bytes())
        }
    }
}

/// A validated `/v1/generate` body.
struct GenRequest {
    prompt: Vec<usize>,
    gen_len: usize,
    stream: bool,
}

fn parse_generate_body(
    body: &[u8],
    vocab: usize,
    max_gen_len: usize,
) -> std::result::Result<GenRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let arr = v
        .get("prompt")
        .and_then(Json::as_array)
        .ok_or_else(|| "missing 'prompt' (array of token ids)".to_string())?;
    if arr.is_empty() {
        return Err("'prompt' must not be empty".to_string());
    }
    if arr.len() > MAX_PROMPT {
        return Err(format!("'prompt' longer than {MAX_PROMPT} tokens"));
    }
    let prompt = arr
        .iter()
        .map(|t| {
            t.as_usize()
                .filter(|&t| t < vocab)
                .ok_or_else(|| format!("prompt tokens must be integers below the vocab ({vocab})"))
        })
        .collect::<std::result::Result<Vec<usize>, String>>()?;
    let gen_len = match v.get("gen_len") {
        None => 16,
        Some(g) => g
            .as_usize()
            .filter(|&n| (1..=max_gen_len).contains(&n))
            .ok_or_else(|| format!("'gen_len' must be an integer in 1..={max_gen_len}"))?,
    };
    let stream = match v.get("stream") {
        None => true,
        Some(s) => s.as_bool().ok_or_else(|| "'stream' must be a boolean".to_string())?,
    };
    Ok(GenRequest { prompt, gen_len, stream })
}

/// Render token ids as a JSON array (`[1,2,30]`) — shared with the
/// tests and examples that build request bodies by hand.
pub fn tokens_json(tokens: &[usize]) -> String {
    let mut s = String::from("[");
    for (i, t) in tokens.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&t.to_string());
    }
    s.push(']');
    s
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn generate(
    w: &mut TcpStream,
    req: &HttpRequest,
    vocab: usize,
    cfg: &GatewayConfig,
    tx_req: &mpsc::Sender<Request>,
    next_id: &AtomicU64,
    metrics: &Metrics,
) -> std::io::Result<()> {
    const JSON_CT: (&str, &str) = ("Content-Type", "application/json");
    let gen = match parse_generate_body(&req.body, vocab, cfg.max_gen_len) {
        Ok(g) => g,
        Err(msg) => {
            metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            return http::write_response(w, 400, &[JSON_CT], error_body(&msg).as_bytes());
        }
    };
    metrics.generate_requests.fetch_add(1, Ordering::Relaxed);
    let (tx_ev, rx_ev) = mpsc::channel();
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let request = Request::new(id, gen.prompt, gen.gen_len).with_stream(tx_ev);
    if tx_req.send(request).is_err() {
        metrics.http_errors.fetch_add(1, Ordering::Relaxed);
        return http::write_response(
            w,
            503,
            &[JSON_CT, ("Connection", "close")],
            error_body("server is draining").as_bytes(),
        );
    }
    // the first event decides the status line: Shed → 429 before any
    // body byte, Admitted → 200 and the stream begins
    match rx_ev.recv() {
        Err(_) => {
            metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                500,
                &[JSON_CT],
                error_body("serve loop dropped the request").as_bytes(),
            )
        }
        Ok(StreamEvent::Shed) => {
            metrics.http_errors.fetch_add(1, Ordering::Relaxed);
            http::write_response(
                w,
                429,
                &[JSON_CT, ("Retry-After", "1")],
                error_body("admission queue full").as_bytes(),
            )
        }
        Ok(first) => {
            if gen.stream {
                stream_sse(w, id, first, rx_ev)
            } else {
                collect_json(w, id, first, rx_ev)
            }
        }
    }
}

/// Stream one request's events as SSE over chunked transfer: one
/// `data:` chunk per token as the tick produces it, a final `done`
/// event carrying the full token list and timings.
fn stream_sse(
    w: &mut TcpStream,
    id: u64,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let id_text = id.to_string();
    let mut cw = ChunkedWriter::begin(
        &mut *w,
        200,
        &[
            ("Content-Type", "text/event-stream"),
            ("Cache-Control", "no-cache"),
            ("X-Request-Id", &id_text),
        ],
    )?;
    let mut tokens: Vec<usize> = Vec::new();
    let mut queued_ms = 0.0f64;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop gone; terminate the stream
            },
        };
        match e {
            StreamEvent::Admitted { queued } => {
                queued_ms = ms(queued);
                cw.chunk(
                    format!("data: {{\"admitted\":true,\"queued_ms\":{queued_ms:.3}}}\n\n")
                        .as_bytes(),
                )?;
            }
            StreamEvent::Token(t) => {
                tokens.push(t);
                cw.chunk(format!("data: {{\"token\":{t}}}\n\n").as_bytes())?;
            }
            StreamEvent::Done { latency, ttft } => {
                cw.chunk(
                    format!(
                        "data: {{\"done\":true,\"id\":{id},\"tokens\":{},\
                         \"queued_ms\":{queued_ms:.3},\"ttft_ms\":{:.3},\
                         \"latency_ms\":{:.3}}}\n\n",
                        tokens_json(&tokens),
                        ms(ttft),
                        ms(latency),
                    )
                    .as_bytes(),
                )?;
                break;
            }
            // unreachable after admission; terminate defensively
            StreamEvent::Shed => break,
        }
    }
    cw.finish()
}

/// `"stream": false` — wait for completion, answer one JSON document.
/// Nothing has been written yet when the serve loop dies mid-request,
/// so a missing `Done` is answered as a 500 — a truncated token list
/// must never masquerade as a completed generation.
fn collect_json(
    w: &mut TcpStream,
    id: u64,
    first: StreamEvent,
    rx: mpsc::Receiver<StreamEvent>,
) -> std::io::Result<()> {
    let mut tokens: Vec<usize> = Vec::new();
    let mut queued_ms = 0.0f64;
    let mut ttft_ms = 0.0f64;
    let mut latency_ms = 0.0f64;
    let mut finished = false;
    let mut ev = Some(first);
    loop {
        let e = match ev.take() {
            Some(e) => e,
            None => match rx.recv() {
                Ok(e) => e,
                Err(_) => break, // serve loop died before Done
            },
        };
        match e {
            StreamEvent::Admitted { queued } => queued_ms = ms(queued),
            StreamEvent::Token(t) => tokens.push(t),
            StreamEvent::Done { latency, ttft } => {
                latency_ms = ms(latency);
                ttft_ms = ms(ttft);
                finished = true;
                break;
            }
            StreamEvent::Shed => break,
        }
    }
    if !finished {
        return http::write_response(
            w,
            500,
            &[("Content-Type", "application/json")],
            error_body("generation aborted before completion").as_bytes(),
        );
    }
    let body = format!(
        "{{\"id\":{id},\"tokens\":{},\"queued_ms\":{queued_ms:.3},\
         \"ttft_ms\":{ttft_ms:.3},\"latency_ms\":{latency_ms:.3}}}",
        tokens_json(&tokens)
    );
    http::write_response(w, 200, &[("Content-Type", "application/json")], body.as_bytes())
}

/// Split an SSE body into its `data: ` payloads (client-side helper for
/// the tests, the e2e example and the smoke driver).
pub fn sse_data(body: &str) -> Vec<&str> {
    body.lines().filter_map(|l| l.strip_prefix("data: ")).collect()
}

/// Extract the streamed tokens from an SSE body: the incremental
/// `token` events, checked against the final `done` event's list.
pub fn sse_tokens(body: &str) -> Result<Vec<usize>> {
    let mut streamed = Vec::new();
    let mut done_tokens: Option<Vec<usize>> = None;
    for payload in sse_data(body) {
        let v = json::parse(payload).map_err(|e| anyhow::anyhow!("bad SSE payload: {e}"))?;
        if let Some(t) = v.get("token").and_then(Json::as_usize) {
            streamed.push(t);
        }
        if v.get("done").and_then(Json::as_bool) == Some(true) {
            let list = v
                .get("tokens")
                .and_then(Json::as_array)
                .context("done event without tokens")?
                .iter()
                .map(|t| t.as_usize().context("non-integer token in done event"))
                .collect::<Result<Vec<usize>>>()?;
            done_tokens = Some(list);
        }
    }
    let done = done_tokens.context("SSE stream ended without a done event")?;
    anyhow::ensure!(
        streamed == done,
        "incrementally streamed tokens {streamed:?} disagree with the done event {done:?}"
    );
    Ok(done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_body_validation() {
        let ok = parse_generate_body(br#"{"prompt":[1,2,3],"gen_len":4}"#, 32, 64).unwrap();
        assert_eq!(ok.prompt, vec![1, 2, 3]);
        assert_eq!(ok.gen_len, 4);
        assert!(ok.stream, "stream defaults to true");

        let ok = parse_generate_body(br#"{"prompt":[0],"stream":false}"#, 32, 64).unwrap();
        assert_eq!(ok.gen_len, 16, "gen_len defaults to 16");
        assert!(!ok.stream);

        for (bad, why) in [
            (&br#"{"gen_len":4}"#[..], "missing prompt"),
            (br#"{"prompt":[]}"#, "empty prompt"),
            (br#"{"prompt":[99]}"#, "token >= vocab"),
            (br#"{"prompt":[-1]}"#, "negative token"),
            (br#"{"prompt":[1.5]}"#, "fractional token"),
            (br#"{"prompt":[1],"gen_len":0}"#, "gen_len 0"),
            (br#"{"prompt":[1],"gen_len":65}"#, "gen_len beyond cap"),
            (br#"{"prompt":[1],"stream":"yes"}"#, "non-bool stream"),
            (br#"{"prompt":"abc"}"#, "non-array prompt"),
            (b"not json", "not json"),
            (&[0xff, 0xfe][..], "not utf-8"),
        ] {
            assert!(parse_generate_body(bad, 32, 64).is_err(), "{why} must be rejected");
        }
    }

    #[test]
    fn sse_token_extraction_checks_consistency() {
        let body = "data: {\"admitted\":true,\"queued_ms\":0.1}\n\n\
                    data: {\"token\":5}\n\ndata: {\"token\":9}\n\n\
                    data: {\"done\":true,\"id\":0,\"tokens\":[5,9],\"queued_ms\":0.1,\
                    \"ttft_ms\":1.2,\"latency_ms\":2.0}\n\n";
        assert_eq!(sse_tokens(body).unwrap(), vec![5, 9]);

        let inconsistent = body.replace("[5,9]", "[5,8]");
        assert!(sse_tokens(&inconsistent).is_err());
        assert!(sse_tokens("data: {\"token\":5}\n\n").is_err(), "missing done must error");
    }

    #[test]
    fn tokens_json_renders_plain_arrays() {
        assert_eq!(tokens_json(&[]), "[]");
        assert_eq!(tokens_json(&[7]), "[7]");
        assert_eq!(tokens_json(&[1, 2, 30]), "[1,2,30]");
    }
}
