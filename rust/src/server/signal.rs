//! SIGINT/SIGTERM → graceful-drain flag, with no `signal_hook` crate in
//! the offline vendor set: the raw POSIX `signal(2)` entry point is
//! declared here (the same idiom as `util::mmap`'s raw `mmap`), and the
//! handler does the only async-signal-safe thing — set a process-wide
//! atomic the gateway's accept loop polls.
//!
//! On non-unix hosts installation reports `false` and the gateway's
//! explicit [`super::gateway::GatewayHandle::shutdown`] is the only stop
//! signal.

use std::sync::atomic::{AtomicBool, Ordering};

static SIGNALLED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sys {
    use std::os::raw::c_int;

    pub const SIGINT: c_int = 2;
    pub const SIGTERM: c_int = 15;
    /// `signal(2)` handler values are word-sized on every unix ABI we
    /// build for; `SIG_ERR` is the all-ones sentinel.
    pub const SIG_ERR: usize = usize::MAX;

    extern "C" {
        pub fn signal(signum: c_int, handler: usize) -> usize;
    }
}

#[cfg(unix)]
extern "C" fn on_signal(_sig: std::os::raw::c_int) {
    // async-signal-safe: one atomic store, nothing else
    SIGNALLED.store(true, Ordering::SeqCst);
}

/// Install the SIGINT/SIGTERM handler. Returns whether the handler is
/// actually installed (always `false` off unix). Idempotent.
pub fn install_shutdown_signals() -> bool {
    #[cfg(unix)]
    {
        // SAFETY: on_signal only performs an atomic store, which is
        // async-signal-safe; re-installation is harmless.
        unsafe {
            let handler = on_signal as extern "C" fn(std::os::raw::c_int) as usize;
            let a = sys::signal(sys::SIGINT, handler);
            let b = sys::signal(sys::SIGTERM, handler);
            a != sys::SIG_ERR && b != sys::SIG_ERR
        }
    }
    #[cfg(not(unix))]
    {
        false
    }
}

/// Has SIGINT/SIGTERM been received since the last
/// [`clear_shutdown_signal`]?
pub fn shutdown_signalled() -> bool {
    SIGNALLED.load(Ordering::SeqCst)
}

/// Reset the flag (start of a serve session, and test isolation).
pub fn clear_shutdown_signal() {
    SIGNALLED.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_set_and_clear() {
        clear_shutdown_signal();
        assert!(!shutdown_signalled());
        SIGNALLED.store(true, Ordering::SeqCst);
        assert!(shutdown_signalled());
        clear_shutdown_signal();
        assert!(!shutdown_signalled());
    }

    #[cfg(unix)]
    #[test]
    fn handler_installs() {
        assert!(install_shutdown_signals());
    }
}
