//! Live gateway metrics: an atomic registry fed by the serving loop
//! (via [`ServeObserver`]) and the connection handlers, rendered as
//! Prometheus text exposition on `GET /metrics`.
//!
//! Counters and gauges are plain relaxed atomics — every update site is
//! a single monotonic increment or gauge store, so no cross-field
//! consistency is promised (exactly the Prometheus scrape model).
//! Latency and admission-wait quantiles come from fixed-size ring
//! windows over the most recent samples, sorted per scrape with the same
//! ceil-rank [`percentile`] convention as `ServeStats`.

use crate::coordinator::serve::{percentile, ServeObserver};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Samples kept per quantile window. Big enough that p99 is meaningful,
/// small enough that a scrape's sort is trivial.
const WINDOW: usize = 512;

/// Ring window of the most recent duration samples.
struct Window {
    buf: Vec<Duration>,
    next: usize,
}

impl Window {
    fn new() -> Window {
        Window { buf: Vec::with_capacity(WINDOW), next: 0 }
    }

    fn push(&mut self, d: Duration) {
        if self.buf.len() < WINDOW {
            self.buf.push(d);
        } else {
            self.buf[self.next] = d;
            self.next = (self.next + 1) % WINDOW;
        }
    }

    fn sorted(&self) -> Vec<Duration> {
        let mut v = self.buf.clone();
        v.sort();
        v
    }
}

/// The gateway's live metrics registry. One instance per gateway,
/// shared (`Arc`) between the serve loop, every connection handler and
/// the `/metrics` scraper.
pub struct Metrics {
    start: Instant,
    /// HTTP requests parsed off a socket (any route).
    pub http_requests: AtomicU64,
    /// Requests answered with an error status (4xx/5xx).
    pub http_errors: AtomicU64,
    /// Generation requests forwarded into the serve loop.
    pub generate_requests: AtomicU64,
    /// OpenAI-style text requests (`/v1/completions`, `/v1/chat/…`).
    pub text_requests: AtomicU64,
    /// Generation requests decoded to completion.
    pub completed: AtomicU64,
    /// Generation requests shed at admission (answered 429).
    pub shed: AtomicU64,
    /// Requests cancelled mid-decode (client disconnect).
    pub cancelled: AtomicU64,
    /// Tokens chosen by the stochastic sampler (greedy picks excluded).
    pub sampled_tokens: AtomicU64,
    /// Generated (non-prompt) tokens served.
    pub tokens: AtomicU64,
    /// Prompt tokens consumed by prefill ticks.
    pub prefill_tokens: AtomicU64,
    /// Current admission-queue depth (gauge).
    pub queue_depth: AtomicU64,
    /// Deepest the admission queue has been.
    pub queue_hwm: AtomicU64,
    /// Currently open client connections (gauge).
    pub open_connections: AtomicU64,
    latencies: Mutex<Window>,
    admission_waits: Mutex<Window>,
    ttfts: Mutex<Window>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            start: Instant::now(),
            http_requests: AtomicU64::new(0),
            http_errors: AtomicU64::new(0),
            generate_requests: AtomicU64::new(0),
            text_requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            sampled_tokens: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            queue_hwm: AtomicU64::new(0),
            open_connections: AtomicU64::new(0),
            latencies: Mutex::new(Window::new()),
            admission_waits: Mutex::new(Window::new()),
            ttfts: Mutex::new(Window::new()),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Lifetime-average served tokens per second.
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens.load(Ordering::Relaxed) as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    /// Render the Prometheus text exposition format (version 0.0.4).
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(2048);
        let mut counter = |name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        counter(
            "rwkvquant_http_requests_total",
            "HTTP requests parsed off a socket (any route).",
            self.http_requests.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_http_errors_total",
            "HTTP requests answered with an error status.",
            self.http_errors.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_generate_requests_total",
            "Generation requests forwarded to the serve loop.",
            self.generate_requests.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_text_requests_total",
            "OpenAI-style text requests forwarded to the serve loop.",
            self.text_requests.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_requests_completed_total",
            "Generation requests decoded to completion.",
            self.completed.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_requests_shed_total",
            "Generation requests shed at admission (HTTP 429).",
            self.shed.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_requests_cancelled_total",
            "Requests cancelled mid-decode (client disconnect).",
            self.cancelled.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_sampled_tokens_total",
            "Tokens chosen by the stochastic sampler (greedy excluded).",
            self.sampled_tokens.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_served_tokens_total",
            "Generated (non-prompt) tokens streamed to clients.",
            self.tokens.load(Ordering::Relaxed),
        );
        counter(
            "rwkvquant_prefill_tokens_total",
            "Prompt tokens consumed by prefill ticks.",
            self.prefill_tokens.load(Ordering::Relaxed),
        );
        let mut gauge = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        };
        gauge(
            "rwkvquant_served_tokens_per_sec",
            "Lifetime-average served tokens per second.",
            self.tokens_per_sec(),
        );
        gauge(
            "rwkvquant_queue_depth",
            "Current admission-queue depth.",
            self.queue_depth.load(Ordering::Relaxed) as f64,
        );
        gauge(
            "rwkvquant_queue_depth_high_water_mark",
            "Deepest the admission queue has been.",
            self.queue_hwm.load(Ordering::Relaxed) as f64,
        );
        gauge(
            "rwkvquant_open_connections",
            "Currently open client connections.",
            self.open_connections.load(Ordering::Relaxed) as f64,
        );
        gauge(
            "rwkvquant_uptime_seconds",
            "Seconds since the gateway started.",
            self.start.elapsed().as_secs_f64(),
        );
        let mut quantiles = |name: &str, help: &str, w: &Mutex<Window>| {
            let sorted = w.lock().unwrap_or_else(|e| e.into_inner()).sorted();
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} summary");
            for (q, label) in [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                let _ = writeln!(
                    out,
                    "{name}{{quantile=\"{label}\"}} {}",
                    percentile(&sorted, q).as_secs_f64()
                );
            }
            let _ = writeln!(out, "{name}_count {}", sorted.len());
        };
        quantiles(
            "rwkvquant_request_latency_seconds",
            "Admission-to-completion latency (last 512 requests).",
            &self.latencies,
        );
        quantiles(
            "rwkvquant_admission_wait_seconds",
            "Arrival-to-admission wait (last 512 requests).",
            &self.admission_waits,
        );
        quantiles(
            "rwkvquant_ttft_seconds",
            "Admission-to-first-generated-token delay (last 512 requests).",
            &self.ttfts,
        );
        out
    }
}

impl ServeObserver for Metrics {
    fn on_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.queue_hwm.fetch_max(depth as u64, Ordering::Relaxed);
    }

    fn on_admitted(&self, wait: Duration) {
        self.admission_waits.lock().unwrap_or_else(|e| e.into_inner()).push(wait);
    }

    fn on_tokens(&self, n: usize) {
        self.tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn on_prefill_tokens(&self, n: usize) {
        self.prefill_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn on_first_token(&self, ttft: Duration) {
        self.ttfts.lock().unwrap_or_else(|e| e.into_inner()).push(ttft);
    }

    fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    fn on_completed(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap_or_else(|e| e.into_inner()).push(latency);
    }

    fn on_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    fn on_sampled_tokens(&self, n: usize) {
        self.sampled_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observer_updates_land_in_the_exposition() {
        let m = Metrics::new();
        m.on_queue_depth(3);
        m.on_queue_depth(1);
        m.on_admitted(Duration::from_millis(4));
        m.on_tokens(7);
        m.on_tokens(5);
        m.on_prefill_tokens(32);
        m.on_prefill_tokens(9);
        m.on_first_token(Duration::from_millis(6));
        m.on_shed();
        m.on_completed(Duration::from_millis(20));
        m.on_cancelled();
        m.on_sampled_tokens(4);
        m.on_sampled_tokens(2);
        m.http_requests.fetch_add(2, Ordering::Relaxed);
        m.text_requests.fetch_add(1, Ordering::Relaxed);
        let text = m.render_prometheus();
        assert!(text.contains("rwkvquant_served_tokens_total 12"), "{text}");
        assert!(text.contains("rwkvquant_prefill_tokens_total 41"));
        assert!(text.contains("rwkvquant_requests_shed_total 1"));
        assert!(text.contains("rwkvquant_requests_completed_total 1"));
        assert!(text.contains("rwkvquant_requests_cancelled_total 1"));
        assert!(text.contains("rwkvquant_sampled_tokens_total 6"));
        assert!(text.contains("rwkvquant_text_requests_total 1"));
        assert!(text.contains("rwkvquant_queue_depth 1"));
        assert!(text.contains("rwkvquant_queue_depth_high_water_mark 3"));
        assert!(text.contains("rwkvquant_http_requests_total 2"));
        assert!(text.contains("rwkvquant_request_latency_seconds{quantile=\"0.99\"} 0.02"));
        assert!(text.contains("rwkvquant_request_latency_seconds_count 1"));
        assert!(text.contains("rwkvquant_admission_wait_seconds{quantile=\"0.5\"} 0.004"));
        assert!(text.contains("rwkvquant_ttft_seconds{quantile=\"0.5\"} 0.006"));
        assert!(text.contains("rwkvquant_ttft_seconds_count 1"));
    }

    #[test]
    fn window_wraps_and_keeps_recent_samples() {
        let mut w = Window::new();
        for i in 0..(WINDOW + 10) {
            w.push(Duration::from_micros(i as u64));
        }
        let sorted = w.sorted();
        assert_eq!(sorted.len(), WINDOW);
        // the 10 oldest samples were overwritten
        assert_eq!(sorted[0], Duration::from_micros(10));
        assert_eq!(sorted[WINDOW - 1], Duration::from_micros((WINDOW + 9) as u64));
    }
}
