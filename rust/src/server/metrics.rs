//! Live gateway metrics: an atomic registry fed by the serving loop
//! (via [`ServeObserver`]) and the connection handlers, rendered as
//! Prometheus text exposition on `GET /metrics`.
//!
//! Counters and gauges are plain relaxed atomics — every update site is
//! a single monotonic increment or gauge store, so no cross-field
//! consistency is promised (exactly the Prometheus scrape model).
//! Latency and admission-wait quantiles come from fixed-size ring
//! windows over the most recent samples, sorted per scrape with the same
//! ceil-rank [`percentile`] convention as `ServeStats`.

use crate::coordinator::serve::{percentile, ServeObserver};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Samples kept per quantile window. Big enough that p99 is meaningful,
/// small enough that a scrape's sort is trivial.
const WINDOW: usize = 512;

/// Ring window of the most recent duration samples.
struct Window {
    buf: Vec<Duration>,
    next: usize,
}

impl Window {
    fn new() -> Window {
        Window { buf: Vec::with_capacity(WINDOW), next: 0 }
    }

    fn push(&mut self, d: Duration) {
        if self.buf.len() < WINDOW {
            self.buf.push(d);
        } else {
            self.buf[self.next] = d;
            self.next = (self.next + 1) % WINDOW;
        }
    }

    fn sorted(&self) -> Vec<Duration> {
        let mut v = self.buf.clone();
        v.sort();
        v
    }
}

/// The gateway's live metrics registry. One instance per gateway,
/// shared (`Arc`) between the serve loop, every connection handler and
/// the `/metrics` scraper.
pub struct Metrics {
    start: Instant,
    /// HTTP requests parsed off a socket (any route).
    pub http_requests: AtomicU64,
    /// Requests answered with an error status (4xx/5xx).
    pub http_errors: AtomicU64,
    /// Generation requests forwarded into the serve loop.
    pub generate_requests: AtomicU64,
    /// OpenAI-style text requests (`/v1/completions`, `/v1/chat/…`).
    pub text_requests: AtomicU64,
    /// Generation requests decoded to completion.
    pub completed: AtomicU64,
    /// Generation requests shed at admission (answered 429).
    pub shed: AtomicU64,
    /// Requests cancelled mid-decode (client disconnect).
    pub cancelled: AtomicU64,
    /// Tokens chosen by the stochastic sampler (greedy picks excluded).
    pub sampled_tokens: AtomicU64,
    /// Generated (non-prompt) tokens served.
    pub tokens: AtomicU64,
    /// Prompt tokens consumed by prefill ticks.
    pub prefill_tokens: AtomicU64,
    /// Current admission-queue depth (gauge).
    pub queue_depth: AtomicU64,
    /// Deepest the admission queue has been.
    pub queue_hwm: AtomicU64,
    /// Currently open client connections (gauge).
    pub open_connections: AtomicU64,
    latencies: Mutex<Window>,
    admission_waits: Mutex<Window>,
    ttfts: Mutex<Window>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            start: Instant::now(),
            http_requests: AtomicU64::new(0),
            http_errors: AtomicU64::new(0),
            generate_requests: AtomicU64::new(0),
            text_requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            sampled_tokens: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            queue_hwm: AtomicU64::new(0),
            open_connections: AtomicU64::new(0),
            latencies: Mutex::new(Window::new()),
            admission_waits: Mutex::new(Window::new()),
            ttfts: Mutex::new(Window::new()),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Lifetime-average served tokens per second.
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens.load(Ordering::Relaxed) as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    /// Render the Prometheus text exposition format (version 0.0.4) for
    /// a single-model gateway: every family sourced from this registry,
    /// no labels (this output shape is asserted line-by-line in tests
    /// and scraped by `python/http_smoke.py`, so it must stay stable).
    pub fn render_prometheus(&self) -> String {
        render_exposition(self, &[("", self)])
    }
}

/// Escape a Prometheus label value (`\` → `\\`, `"` → `\"`, newline →
/// `\n`).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// `{model="name"}` for a named series, empty for the anonymous
/// single-model gateway (keeps that exposition byte-identical to the
/// pre-fleet output).
fn model_label(name: &str) -> String {
    if name.is_empty() {
        String::new()
    } else {
        format!("{{model=\"{}\"}}", escape_label(name))
    }
}

/// Family-grouped Prometheus exposition for a fleet: process-level
/// families (HTTP traffic, connections, uptime) come from the gateway's
/// own registry unlabeled, serve-loop families emit one sample per model
/// with a `model="name"` label. Each family's `# HELP`/`# TYPE` header
/// appears exactly once regardless of model count, which is what the
/// exposition format requires. `render_prometheus` is the degenerate
/// single-model call — gateway and the sole (unlabeled) model are the
/// same registry.
pub fn render_exposition(gateway: &Metrics, models: &[(&str, &Metrics)]) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(2048 * models.len().max(1));
    let mut counter = |name: &str, help: &str, rows: &[(&str, u64)]| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        for (model, v) in rows {
            let _ = writeln!(out, "{name}{} {v}", model_label(model));
        }
    };
    let per_model = |f: &dyn Fn(&Metrics) -> u64| -> Vec<(&str, u64)> {
        models.iter().map(|(n, m)| (*n, f(m))).collect()
    };
    counter(
        "rwkvquant_http_requests_total",
        "HTTP requests parsed off a socket (any route).",
        &[("", gateway.http_requests.load(Ordering::Relaxed))],
    );
    counter(
        "rwkvquant_http_errors_total",
        "HTTP requests answered with an error status.",
        &[("", gateway.http_errors.load(Ordering::Relaxed))],
    );
    counter(
        "rwkvquant_generate_requests_total",
        "Generation requests forwarded to the serve loop.",
        &per_model(&|m| m.generate_requests.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_text_requests_total",
        "OpenAI-style text requests forwarded to the serve loop.",
        &per_model(&|m| m.text_requests.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_requests_completed_total",
        "Generation requests decoded to completion.",
        &per_model(&|m| m.completed.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_requests_shed_total",
        "Generation requests shed at admission (HTTP 429).",
        &per_model(&|m| m.shed.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_requests_cancelled_total",
        "Requests cancelled mid-decode (client disconnect).",
        &per_model(&|m| m.cancelled.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_sampled_tokens_total",
        "Tokens chosen by the stochastic sampler (greedy excluded).",
        &per_model(&|m| m.sampled_tokens.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_served_tokens_total",
        "Generated (non-prompt) tokens streamed to clients.",
        &per_model(&|m| m.tokens.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_prefill_tokens_total",
        "Prompt tokens consumed by prefill ticks.",
        &per_model(&|m| m.prefill_tokens.load(Ordering::Relaxed)),
    );
    let mut gauge = |name: &str, help: &str, rows: &[(&str, f64)]| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        for (model, v) in rows {
            let _ = writeln!(out, "{name}{} {v}", model_label(model));
        }
    };
    let per_model_f = |f: &dyn Fn(&Metrics) -> f64| -> Vec<(&str, f64)> {
        models.iter().map(|(n, m)| (*n, f(m))).collect()
    };
    gauge(
        "rwkvquant_served_tokens_per_sec",
        "Lifetime-average served tokens per second.",
        &per_model_f(&|m| m.tokens_per_sec()),
    );
    gauge(
        "rwkvquant_queue_depth",
        "Current admission-queue depth.",
        &per_model_f(&|m| m.queue_depth.load(Ordering::Relaxed) as f64),
    );
    gauge(
        "rwkvquant_queue_depth_high_water_mark",
        "Deepest the admission queue has been.",
        &per_model_f(&|m| m.queue_hwm.load(Ordering::Relaxed) as f64),
    );
    gauge(
        "rwkvquant_open_connections",
        "Currently open client connections.",
        &[("", gateway.open_connections.load(Ordering::Relaxed) as f64)],
    );
    gauge(
        "rwkvquant_uptime_seconds",
        "Seconds since the gateway started.",
        &[("", gateway.start.elapsed().as_secs_f64())],
    );
    let mut quantiles = |name: &str, help: &str, pick: &dyn Fn(&Metrics) -> &Mutex<Window>| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} summary");
        for (model, m) in models {
            let sorted = pick(m).lock().unwrap_or_else(|e| e.into_inner()).sorted();
            for (q, label) in [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                // the quantile label joins the model label inside one
                // brace set: {model="a",quantile="0.5"}
                let series = if model.is_empty() {
                    format!("{{quantile=\"{label}\"}}")
                } else {
                    format!("{{model=\"{}\",quantile=\"{label}\"}}", escape_label(model))
                };
                let _ = writeln!(out, "{name}{series} {}", percentile(&sorted, q).as_secs_f64());
            }
            let _ = writeln!(out, "{name}_count{} {}", model_label(model), sorted.len());
        }
    };
    quantiles(
        "rwkvquant_request_latency_seconds",
        "Admission-to-completion latency (last 512 requests).",
        &|m| &m.latencies,
    );
    quantiles(
        "rwkvquant_admission_wait_seconds",
        "Arrival-to-admission wait (last 512 requests).",
        &|m| &m.admission_waits,
    );
    quantiles(
        "rwkvquant_ttft_seconds",
        "Admission-to-first-generated-token delay (last 512 requests).",
        &|m| &m.ttfts,
    );
    out
}

impl ServeObserver for Metrics {
    fn on_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.queue_hwm.fetch_max(depth as u64, Ordering::Relaxed);
    }

    fn on_admitted(&self, wait: Duration) {
        self.admission_waits.lock().unwrap_or_else(|e| e.into_inner()).push(wait);
    }

    fn on_tokens(&self, n: usize) {
        self.tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn on_prefill_tokens(&self, n: usize) {
        self.prefill_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn on_first_token(&self, ttft: Duration) {
        self.ttfts.lock().unwrap_or_else(|e| e.into_inner()).push(ttft);
    }

    fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    fn on_completed(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap_or_else(|e| e.into_inner()).push(latency);
    }

    fn on_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    fn on_sampled_tokens(&self, n: usize) {
        self.sampled_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observer_updates_land_in_the_exposition() {
        let m = Metrics::new();
        m.on_queue_depth(3);
        m.on_queue_depth(1);
        m.on_admitted(Duration::from_millis(4));
        m.on_tokens(7);
        m.on_tokens(5);
        m.on_prefill_tokens(32);
        m.on_prefill_tokens(9);
        m.on_first_token(Duration::from_millis(6));
        m.on_shed();
        m.on_completed(Duration::from_millis(20));
        m.on_cancelled();
        m.on_sampled_tokens(4);
        m.on_sampled_tokens(2);
        m.http_requests.fetch_add(2, Ordering::Relaxed);
        m.text_requests.fetch_add(1, Ordering::Relaxed);
        let text = m.render_prometheus();
        assert!(text.contains("rwkvquant_served_tokens_total 12"), "{text}");
        assert!(text.contains("rwkvquant_prefill_tokens_total 41"));
        assert!(text.contains("rwkvquant_requests_shed_total 1"));
        assert!(text.contains("rwkvquant_requests_completed_total 1"));
        assert!(text.contains("rwkvquant_requests_cancelled_total 1"));
        assert!(text.contains("rwkvquant_sampled_tokens_total 6"));
        assert!(text.contains("rwkvquant_text_requests_total 1"));
        assert!(text.contains("rwkvquant_queue_depth 1"));
        assert!(text.contains("rwkvquant_queue_depth_high_water_mark 3"));
        assert!(text.contains("rwkvquant_http_requests_total 2"));
        assert!(text.contains("rwkvquant_request_latency_seconds{quantile=\"0.99\"} 0.02"));
        assert!(text.contains("rwkvquant_request_latency_seconds_count 1"));
        assert!(text.contains("rwkvquant_admission_wait_seconds{quantile=\"0.5\"} 0.004"));
        assert!(text.contains("rwkvquant_ttft_seconds{quantile=\"0.5\"} 0.006"));
        assert!(text.contains("rwkvquant_ttft_seconds_count 1"));
    }

    #[test]
    fn fleet_exposition_labels_serve_families_per_model() {
        let gw = Metrics::new();
        gw.http_requests.fetch_add(9, Ordering::Relaxed);
        let a = Metrics::new();
        let b = Metrics::new();
        a.on_tokens(11);
        a.on_completed(Duration::from_millis(8));
        b.on_tokens(3);
        let text = render_exposition(&gw, &[("alpha", &a), ("beta", &b)]);
        // process-level families stay unlabeled, sourced from the gateway
        assert!(text.contains("rwkvquant_http_requests_total 9"), "{text}");
        assert!(!text.contains("rwkvquant_http_requests_total{"));
        // serve families: one labeled sample per model under one header
        assert!(text.contains("rwkvquant_served_tokens_total{model=\"alpha\"} 11"));
        assert!(text.contains("rwkvquant_served_tokens_total{model=\"beta\"} 3"));
        assert_eq!(text.matches("# TYPE rwkvquant_served_tokens_total counter").count(), 1);
        // summaries carry both labels in one brace set, counts labeled too
        assert!(text.contains("rwkvquant_request_latency_seconds{model=\"alpha\",quantile=\"0.99\"} 0.008"));
        assert!(text.contains("rwkvquant_request_latency_seconds_count{model=\"alpha\"} 1"));
        assert!(text.contains("rwkvquant_request_latency_seconds_count{model=\"beta\"} 0"));
        // uptime from the gateway, once
        assert_eq!(text.matches("rwkvquant_uptime_seconds ").count(), 1);
    }

    #[test]
    fn single_model_render_carries_no_model_labels() {
        let m = Metrics::new();
        m.on_tokens(5);
        m.on_completed(Duration::from_millis(2));
        m.http_requests.fetch_add(1, Ordering::Relaxed);
        let text = m.render_prometheus();
        assert!(!text.contains("model="), "anonymous gateway must stay label-free: {text}");
        assert!(text.contains("rwkvquant_served_tokens_total 5"));
        assert!(text.contains("rwkvquant_request_latency_seconds{quantile=\"0.5\"} 0.002"));
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(model_label("a\"b\\c"), "{model=\"a\\\"b\\\\c\"}");
        assert_eq!(model_label(""), "");
    }

    #[test]
    fn window_wraps_and_keeps_recent_samples() {
        let mut w = Window::new();
        for i in 0..(WINDOW + 10) {
            w.push(Duration::from_micros(i as u64));
        }
        let sorted = w.sorted();
        assert_eq!(sorted.len(), WINDOW);
        // the 10 oldest samples were overwritten
        assert_eq!(sorted[0], Duration::from_micros(10));
        assert_eq!(sorted[WINDOW - 1], Duration::from_micros((WINDOW + 9) as u64));
    }
}
