//! Live gateway metrics: an atomic registry fed by the serving loop
//! (via [`ServeObserver`]) and the connection handlers, rendered as
//! Prometheus text exposition on `GET /metrics`.
//!
//! Counters and gauges are plain relaxed atomics — every update site is
//! a single monotonic increment or gauge store, so no cross-field
//! consistency is promised (exactly the Prometheus scrape model).
//! Latency and admission-wait quantiles come from fixed-size ring
//! windows over the most recent samples, sorted per scrape with the same
//! ceil-rank [`percentile`] convention as `ServeStats`.

use crate::coordinator::serve::{percentile, ServeObserver};
use crate::quant::exec::kstats;
use crate::util::trace::{SeqStage, TraceHub};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Samples kept per quantile window. Big enough that p99 is meaningful,
/// small enough that a scrape's sort is trivial.
const WINDOW: usize = 512;

/// Ring window of the most recent duration samples.
struct Window {
    buf: Vec<Duration>,
    next: usize,
}

impl Window {
    fn new() -> Window {
        Window { buf: Vec::with_capacity(WINDOW), next: 0 }
    }

    fn push(&mut self, d: Duration) {
        if self.buf.len() < WINDOW {
            self.buf.push(d);
        } else {
            self.buf[self.next] = d;
            self.next = (self.next + 1) % WINDOW;
        }
    }

    fn sorted(&self) -> Vec<Duration> {
        let mut v = self.buf.clone();
        v.sort();
        v
    }
}

/// The gateway's live metrics registry. One instance per gateway,
/// shared (`Arc`) between the serve loop, every connection handler and
/// the `/metrics` scraper.
pub struct Metrics {
    start: Instant,
    /// HTTP requests parsed off a socket (any route).
    pub http_requests: AtomicU64,
    /// Requests answered with an error status (4xx/5xx).
    pub http_errors: AtomicU64,
    /// Generation requests forwarded into the serve loop.
    pub generate_requests: AtomicU64,
    /// OpenAI-style text requests (`/v1/completions`, `/v1/chat/…`).
    pub text_requests: AtomicU64,
    /// Generation requests decoded to completion.
    pub completed: AtomicU64,
    /// Generation requests shed at admission (answered 429).
    pub shed: AtomicU64,
    /// Requests cancelled mid-decode (client disconnect).
    pub cancelled: AtomicU64,
    /// Tokens chosen by the stochastic sampler (greedy picks excluded).
    pub sampled_tokens: AtomicU64,
    /// Generated (non-prompt) tokens served.
    pub tokens: AtomicU64,
    /// Prompt tokens consumed by prefill ticks.
    pub prefill_tokens: AtomicU64,
    /// Current admission-queue depth (gauge).
    pub queue_depth: AtomicU64,
    /// Deepest the admission queue has been.
    pub queue_hwm: AtomicU64,
    /// Currently open client connections (gauge).
    pub open_connections: AtomicU64,
    /// Memory-mapped weight stores behind this registry's model (gauge;
    /// 0 for a fully heap-loaded model).
    pub mapped_stores: AtomicU64,
    latencies: Mutex<Window>,
    admission_waits: Mutex<Window>,
    ttfts: Mutex<Window>,
    /// Per-request span sink (`/admin/trace/{id}`); starts disabled.
    trace: TraceHub,
    /// Live per-sequence positions (`/admin/inflight`), keyed by request
    /// id. Maintained only while `trace` is enabled.
    inflight: Mutex<HashMap<u64, Inflight>>,
    /// Latest cumulative per-lane busy nanoseconds from the tick engine
    /// (index = lane, 0 = lead). Empty until a traced tick reports.
    lane_busy: Mutex<Vec<u64>>,
}

/// What [`Metrics`] tracks per in-flight sequence.
struct Inflight {
    stage: SeqStage,
    generated: usize,
    slab: Option<usize>,
    prompt_len: usize,
    gen_len: usize,
    admitted: Instant,
}

/// One row of [`Metrics::inflight_snapshot`] — the `/admin/inflight`
/// response shape.
pub struct InflightEntry {
    pub id: u64,
    /// Wire spelling of the sequence's stage (`prefill`/`decode`/`parked`).
    pub stage: &'static str,
    /// Generated tokens so far.
    pub generated: usize,
    /// Resident state-arena slab slot, or `None` while parked.
    pub slab: Option<usize>,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// Time since admission.
    pub age: Duration,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            start: Instant::now(),
            http_requests: AtomicU64::new(0),
            http_errors: AtomicU64::new(0),
            generate_requests: AtomicU64::new(0),
            text_requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            sampled_tokens: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            queue_hwm: AtomicU64::new(0),
            open_connections: AtomicU64::new(0),
            mapped_stores: AtomicU64::new(0),
            latencies: Mutex::new(Window::new()),
            admission_waits: Mutex::new(Window::new()),
            ttfts: Mutex::new(Window::new()),
            trace: TraceHub::new(),
            inflight: Mutex::new(HashMap::new()),
            lane_busy: Mutex::new(Vec::new()),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Lifetime-average served tokens per second.
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens.load(Ordering::Relaxed) as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    /// This registry's span sink — the gateway enables it at startup
    /// (unless `--no-trace`) and `/admin/trace/{id}` reads it.
    pub fn trace(&self) -> &TraceHub {
        &self.trace
    }

    /// Snapshot of every in-flight sequence, sorted by request id — the
    /// `/admin/inflight` payload. Empty unless tracing is enabled.
    pub fn inflight_snapshot(&self) -> Vec<InflightEntry> {
        let map = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        let mut out: Vec<InflightEntry> = map
            .iter()
            .map(|(&id, f)| InflightEntry {
                id,
                stage: f.stage.name(),
                generated: f.generated,
                slab: f.slab,
                prompt_len: f.prompt_len,
                gen_len: f.gen_len,
                age: f.admitted.elapsed(),
            })
            .collect();
        out.sort_by_key(|e| e.id);
        out
    }

    /// Render the Prometheus text exposition format (version 0.0.4) for
    /// a single-model gateway: every family sourced from this registry,
    /// no labels (this output shape is asserted line-by-line in tests
    /// and scraped by `python/http_smoke.py`, so it must stay stable).
    pub fn render_prometheus(&self) -> String {
        render_exposition(self, &[("", self)])
    }
}

/// Escape a Prometheus label value (`\` → `\\`, `"` → `\"`, newline →
/// `\n`).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// `{model="name"}` for a named series, empty for the anonymous
/// single-model gateway (keeps that exposition byte-identical to the
/// pre-fleet output).
fn model_label(name: &str) -> String {
    if name.is_empty() {
        String::new()
    } else {
        format!("{{model=\"{}\"}}", escape_label(name))
    }
}

/// Family-grouped Prometheus exposition for a fleet: process-level
/// families (HTTP traffic, connections, uptime) come from the gateway's
/// own registry unlabeled, serve-loop families emit one sample per model
/// with a `model="name"` label. Each family's `# HELP`/`# TYPE` header
/// appears exactly once regardless of model count, which is what the
/// exposition format requires. `render_prometheus` is the degenerate
/// single-model call — gateway and the sole (unlabeled) model are the
/// same registry.
pub fn render_exposition(gateway: &Metrics, models: &[(&str, &Metrics)]) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(2048 * models.len().max(1));
    let mut counter = |name: &str, help: &str, rows: &[(&str, u64)]| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        for (model, v) in rows {
            let _ = writeln!(out, "{name}{} {v}", model_label(model));
        }
    };
    let per_model = |f: &dyn Fn(&Metrics) -> u64| -> Vec<(&str, u64)> {
        models.iter().map(|(n, m)| (*n, f(m))).collect()
    };
    counter(
        "rwkvquant_http_requests_total",
        "HTTP requests parsed off a socket (any route).",
        &[("", gateway.http_requests.load(Ordering::Relaxed))],
    );
    counter(
        "rwkvquant_http_errors_total",
        "HTTP requests answered with an error status.",
        &[("", gateway.http_errors.load(Ordering::Relaxed))],
    );
    counter(
        "rwkvquant_generate_requests_total",
        "Generation requests forwarded to the serve loop.",
        &per_model(&|m| m.generate_requests.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_text_requests_total",
        "OpenAI-style text requests forwarded to the serve loop.",
        &per_model(&|m| m.text_requests.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_requests_completed_total",
        "Generation requests decoded to completion.",
        &per_model(&|m| m.completed.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_requests_shed_total",
        "Generation requests shed at admission (HTTP 429).",
        &per_model(&|m| m.shed.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_requests_cancelled_total",
        "Requests cancelled mid-decode (client disconnect).",
        &per_model(&|m| m.cancelled.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_sampled_tokens_total",
        "Tokens chosen by the stochastic sampler (greedy excluded).",
        &per_model(&|m| m.sampled_tokens.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_served_tokens_total",
        "Generated (non-prompt) tokens streamed to clients.",
        &per_model(&|m| m.tokens.load(Ordering::Relaxed)),
    );
    counter(
        "rwkvquant_prefill_tokens_total",
        "Prompt tokens consumed by prefill ticks.",
        &per_model(&|m| m.prefill_tokens.load(Ordering::Relaxed)),
    );
    let mut gauge = |name: &str, help: &str, rows: &[(&str, f64)]| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        for (model, v) in rows {
            let _ = writeln!(out, "{name}{} {v}", model_label(model));
        }
    };
    let per_model_f = |f: &dyn Fn(&Metrics) -> f64| -> Vec<(&str, f64)> {
        models.iter().map(|(n, m)| (*n, f(m))).collect()
    };
    gauge(
        "rwkvquant_served_tokens_per_sec",
        "Lifetime-average served tokens per second.",
        &per_model_f(&|m| m.tokens_per_sec()),
    );
    gauge(
        "rwkvquant_queue_depth",
        "Current admission-queue depth.",
        &per_model_f(&|m| m.queue_depth.load(Ordering::Relaxed) as f64),
    );
    gauge(
        "rwkvquant_queue_depth_high_water_mark",
        "Deepest the admission queue has been.",
        &per_model_f(&|m| m.queue_hwm.load(Ordering::Relaxed) as f64),
    );
    gauge(
        "rwkvquant_open_connections",
        "Currently open client connections.",
        &[("", gateway.open_connections.load(Ordering::Relaxed) as f64)],
    );
    gauge(
        "rwkvquant_uptime_seconds",
        "Seconds since the gateway started.",
        &[("", gateway.start.elapsed().as_secs_f64())],
    );
    let mut quantiles = |name: &str, help: &str, pick: &dyn Fn(&Metrics) -> &Mutex<Window>| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} summary");
        for (model, m) in models {
            let sorted = pick(m).lock().unwrap_or_else(|e| e.into_inner()).sorted();
            for (q, label) in [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                // the quantile label joins the model label inside one
                // brace set: {model="a",quantile="0.5"}
                let series = if model.is_empty() {
                    format!("{{quantile=\"{label}\"}}")
                } else {
                    format!("{{model=\"{}\",quantile=\"{label}\"}}", escape_label(model))
                };
                let _ = writeln!(out, "{name}{series} {}", percentile(&sorted, q).as_secs_f64());
            }
            let _ = writeln!(out, "{name}_count{} {}", model_label(model), sorted.len());
        }
    };
    quantiles(
        "rwkvquant_request_latency_seconds",
        "Admission-to-completion latency (last 512 requests).",
        &|m| &m.latencies,
    );
    quantiles(
        "rwkvquant_admission_wait_seconds",
        "Arrival-to-admission wait (last 512 requests).",
        &|m| &m.admission_waits,
    );
    quantiles(
        "rwkvquant_ttft_seconds",
        "Admission-to-first-generated-token delay (last 512 requests).",
        &|m| &m.ttfts,
    );
    // --- observability families ---
    let _ = writeln!(out, "# HELP rwkvquant_inflight_sequences Sequences currently in the active set (tracing on).");
    let _ = writeln!(out, "# TYPE rwkvquant_inflight_sequences gauge");
    for (model, m) in models {
        let n = m.inflight.lock().unwrap_or_else(|e| e.into_inner()).len();
        let _ = writeln!(out, "rwkvquant_inflight_sequences{} {n}", model_label(model));
    }
    let _ = writeln!(out, "# HELP rwkvquant_mapped_stores Memory-mapped weight stores behind the model.");
    let _ = writeln!(out, "# TYPE rwkvquant_mapped_stores gauge");
    for (model, m) in models {
        let v = m.mapped_stores.load(Ordering::Relaxed);
        let _ = writeln!(out, "rwkvquant_mapped_stores{} {v}", model_label(model));
    }
    let _ = writeln!(out, "# HELP rwkvquant_lane_busy_seconds_total Cumulative tick-lane busy time (lane 0 is the lead).");
    let _ = writeln!(out, "# TYPE rwkvquant_lane_busy_seconds_total counter");
    for (model, m) in models {
        let lanes = m.lane_busy.lock().unwrap_or_else(|e| e.into_inner()).clone();
        for (lane, ns) in lanes.iter().enumerate() {
            // lane joins the model label inside one brace set, like the
            // summary quantiles above
            let series = if model.is_empty() {
                format!("{{lane=\"{lane}\"}}")
            } else {
                format!("{{model=\"{}\",lane=\"{lane}\"}}", escape_label(model))
            };
            let _ = writeln!(
                out,
                "rwkvquant_lane_busy_seconds_total{series} {}",
                *ns as f64 / 1e9
            );
        }
    }
    if let Some(bytes) = resident_set_bytes() {
        // Linux only — the family is absent where procfs is
        let _ = writeln!(out, "# HELP rwkvquant_process_resident_bytes Resident-set size of the gateway process.");
        let _ = writeln!(out, "# TYPE rwkvquant_process_resident_bytes gauge");
        let _ = writeln!(out, "rwkvquant_process_resident_bytes {bytes}");
    }
    // per-kernel matvec attribution is process-global (the kernel grid
    // is shared by every model), so it renders once, unlabeled by model
    let kern = kstats::snapshot();
    let _ = writeln!(out, "# HELP rwkvquant_kernel_matvec_calls_total Matvec calls by quantization op and SIMD kernel.");
    let _ = writeln!(out, "# TYPE rwkvquant_kernel_matvec_calls_total counter");
    for (op, kernel, calls, _) in &kern {
        let _ = writeln!(
            out,
            "rwkvquant_kernel_matvec_calls_total{{op=\"{op}\",kernel=\"{kernel}\"}} {calls}"
        );
    }
    let _ = writeln!(out, "# HELP rwkvquant_kernel_matvec_seconds_total Matvec wall time by quantization op and SIMD kernel.");
    let _ = writeln!(out, "# TYPE rwkvquant_kernel_matvec_seconds_total counter");
    for (op, kernel, _, secs) in &kern {
        let _ = writeln!(
            out,
            "rwkvquant_kernel_matvec_seconds_total{{op=\"{op}\",kernel=\"{kernel}\"}} {secs}"
        );
    }
    out
}

/// Resident-set size of this process in bytes, from the second field of
/// `/proc/self/statm` (pages; the kernel's page size on every Linux
/// target this crate builds for is 4096). `None` where that procfs
/// surface does not exist (macOS, wasm32).
pub fn resident_set_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(pages * 4096)
}

/// Lint a Prometheus text exposition (format 0.0.4): every sample's
/// family must carry exactly one `# HELP` and one `# TYPE` (with a known
/// type), label sets must parse with balanced quotes and escaped values,
/// and no series (name + label set) may appear twice. Returns the list
/// of problems — empty means clean. Used by the metrics tests and
/// mirrored by `python/check_metrics.py` for the live endpoint.
pub fn lint_exposition(text: &str) -> Vec<String> {
    let mut problems = Vec::new();
    let mut help: HashMap<&str, usize> = HashMap::new();
    let mut types: HashMap<&str, usize> = HashMap::new();
    let mut seen_series: HashMap<String, usize> = HashMap::new();
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let Some(name) = rest.split_whitespace().next() else {
                problems.push(format!("line {ln}: HELP without a family name"));
                continue;
            };
            *help.entry(name).or_insert(0) += 1;
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (Some(name), Some(kind)) = (it.next(), it.next()) else {
                problems.push(format!("line {ln}: malformed TYPE line"));
                continue;
            };
            if !["counter", "gauge", "summary", "histogram", "untyped"].contains(&kind) {
                problems.push(format!("line {ln}: unknown type {kind:?} for {name}"));
            }
            *types.entry(name).or_insert(0) += 1;
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }
        // sample line: name{labels}? value
        let (series, value) = match line.rsplit_once(' ') {
            Some(parts) => parts,
            None => {
                problems.push(format!("line {ln}: sample without a value"));
                continue;
            }
        };
        if value.parse::<f64>().is_err() {
            problems.push(format!("line {ln}: unparsable sample value {value:?}"));
        }
        let name = match series.split_once('{') {
            Some((name, labels)) => {
                match labels.strip_suffix('}') {
                    Some(body) => {
                        if let Err(e) = parse_label_body(body) {
                            problems.push(format!("line {ln}: bad label set: {e}"));
                        }
                    }
                    None => problems.push(format!("line {ln}: unclosed label set")),
                }
                name
            }
            None => series,
        };
        // summary/histogram child series belong to the parent family
        let family = ["_count", "_sum", "_bucket"]
            .iter()
            .find_map(|suf| name.strip_suffix(suf).filter(|base| types.contains_key(base)))
            .unwrap_or(name);
        if !types.contains_key(family) {
            problems.push(format!("line {ln}: sample {name} has no preceding # TYPE"));
        }
        if !help.contains_key(family) {
            problems.push(format!("line {ln}: sample {name} has no preceding # HELP"));
        }
        if let Some(first) = seen_series.insert(series.to_string(), ln) {
            problems.push(format!("line {ln}: duplicate series {series} (first at line {first})"));
        }
    }
    for (name, n) in &help {
        if *n > 1 {
            problems.push(format!("family {name}: {n} HELP lines"));
        }
    }
    for (name, n) in &types {
        if *n > 1 {
            problems.push(format!("family {name}: {n} TYPE lines"));
        }
    }
    problems.sort();
    problems
}

/// Parse `k="v",k2="v2"` (the inside of a label brace set), enforcing
/// quote balance and `\\`/`\"`/`\n` escaping.
fn parse_label_body(body: &str) -> std::result::Result<(), String> {
    let mut chars = body.chars();
    loop {
        // label name up to '='
        let mut name = String::new();
        for c in chars.by_ref() {
            if c == '=' {
                break;
            }
            name.push(c);
        }
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return Err(format!("bad label name {name:?}"));
        }
        if chars.next() != Some('"') {
            return Err(format!("label {name} value not quoted"));
        }
        let mut closed = false;
        while let Some(c) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some('\\') | Some('"') | Some('n') => {}
                    other => return Err(format!("bad escape {other:?} in label {name}")),
                },
                '"' => {
                    closed = true;
                    break;
                }
                _ => {}
            }
        }
        if !closed {
            return Err(format!("unterminated value for label {name}"));
        }
        match chars.next() {
            None => return Ok(()),
            Some(',') => continue,
            Some(c) => return Err(format!("unexpected {c:?} after label {name}")),
        }
    }
}

impl ServeObserver for Metrics {
    fn on_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.queue_hwm.fetch_max(depth as u64, Ordering::Relaxed);
    }

    fn on_admitted(&self, wait: Duration) {
        self.admission_waits.lock().unwrap_or_else(|e| e.into_inner()).push(wait);
    }

    fn on_tokens(&self, n: usize) {
        self.tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn on_prefill_tokens(&self, n: usize) {
        self.prefill_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn on_first_token(&self, ttft: Duration) {
        self.ttfts.lock().unwrap_or_else(|e| e.into_inner()).push(ttft);
    }

    fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    fn on_completed(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap_or_else(|e| e.into_inner()).push(latency);
    }

    fn on_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    fn on_sampled_tokens(&self, n: usize) {
        self.sampled_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    fn trace_hub(&self) -> Option<&TraceHub> {
        Some(&self.trace)
    }

    fn on_seq_admitted(&self, id: u64, prompt_len: usize, gen_len: usize) {
        self.inflight.lock().unwrap_or_else(|e| e.into_inner()).insert(
            id,
            Inflight {
                stage: SeqStage::Prefill,
                generated: 0,
                slab: None,
                prompt_len,
                gen_len,
                admitted: Instant::now(),
            },
        );
    }

    fn on_seq_progress(&self, id: u64, stage: SeqStage, generated: usize, slab: Option<usize>) {
        if let Some(f) = self.inflight.lock().unwrap_or_else(|e| e.into_inner()).get_mut(&id) {
            f.stage = stage;
            f.generated = generated;
            f.slab = slab;
        }
    }

    fn on_seq_done(&self, id: u64) {
        self.inflight.lock().unwrap_or_else(|e| e.into_inner()).remove(&id);
    }

    fn on_lane_busy(&self, busy_ns: &[u64]) {
        let mut lanes = self.lane_busy.lock().unwrap_or_else(|e| e.into_inner());
        lanes.resize(busy_ns.len(), 0);
        lanes.copy_from_slice(busy_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observer_updates_land_in_the_exposition() {
        let m = Metrics::new();
        m.on_queue_depth(3);
        m.on_queue_depth(1);
        m.on_admitted(Duration::from_millis(4));
        m.on_tokens(7);
        m.on_tokens(5);
        m.on_prefill_tokens(32);
        m.on_prefill_tokens(9);
        m.on_first_token(Duration::from_millis(6));
        m.on_shed();
        m.on_completed(Duration::from_millis(20));
        m.on_cancelled();
        m.on_sampled_tokens(4);
        m.on_sampled_tokens(2);
        m.http_requests.fetch_add(2, Ordering::Relaxed);
        m.text_requests.fetch_add(1, Ordering::Relaxed);
        let text = m.render_prometheus();
        assert!(text.contains("rwkvquant_served_tokens_total 12"), "{text}");
        assert!(text.contains("rwkvquant_prefill_tokens_total 41"));
        assert!(text.contains("rwkvquant_requests_shed_total 1"));
        assert!(text.contains("rwkvquant_requests_completed_total 1"));
        assert!(text.contains("rwkvquant_requests_cancelled_total 1"));
        assert!(text.contains("rwkvquant_sampled_tokens_total 6"));
        assert!(text.contains("rwkvquant_text_requests_total 1"));
        assert!(text.contains("rwkvquant_queue_depth 1"));
        assert!(text.contains("rwkvquant_queue_depth_high_water_mark 3"));
        assert!(text.contains("rwkvquant_http_requests_total 2"));
        assert!(text.contains("rwkvquant_request_latency_seconds{quantile=\"0.99\"} 0.02"));
        assert!(text.contains("rwkvquant_request_latency_seconds_count 1"));
        assert!(text.contains("rwkvquant_admission_wait_seconds{quantile=\"0.5\"} 0.004"));
        assert!(text.contains("rwkvquant_ttft_seconds{quantile=\"0.5\"} 0.006"));
        assert!(text.contains("rwkvquant_ttft_seconds_count 1"));
    }

    #[test]
    fn fleet_exposition_labels_serve_families_per_model() {
        let gw = Metrics::new();
        gw.http_requests.fetch_add(9, Ordering::Relaxed);
        let a = Metrics::new();
        let b = Metrics::new();
        a.on_tokens(11);
        a.on_completed(Duration::from_millis(8));
        b.on_tokens(3);
        let text = render_exposition(&gw, &[("alpha", &a), ("beta", &b)]);
        // process-level families stay unlabeled, sourced from the gateway
        assert!(text.contains("rwkvquant_http_requests_total 9"), "{text}");
        assert!(!text.contains("rwkvquant_http_requests_total{"));
        // serve families: one labeled sample per model under one header
        assert!(text.contains("rwkvquant_served_tokens_total{model=\"alpha\"} 11"));
        assert!(text.contains("rwkvquant_served_tokens_total{model=\"beta\"} 3"));
        assert_eq!(text.matches("# TYPE rwkvquant_served_tokens_total counter").count(), 1);
        // summaries carry both labels in one brace set, counts labeled too
        assert!(text.contains("rwkvquant_request_latency_seconds{model=\"alpha\",quantile=\"0.99\"} 0.008"));
        assert!(text.contains("rwkvquant_request_latency_seconds_count{model=\"alpha\"} 1"));
        assert!(text.contains("rwkvquant_request_latency_seconds_count{model=\"beta\"} 0"));
        // uptime from the gateway, once
        assert_eq!(text.matches("rwkvquant_uptime_seconds ").count(), 1);
    }

    #[test]
    fn single_model_render_carries_no_model_labels() {
        let m = Metrics::new();
        m.on_tokens(5);
        m.on_completed(Duration::from_millis(2));
        m.http_requests.fetch_add(1, Ordering::Relaxed);
        let text = m.render_prometheus();
        assert!(!text.contains("model="), "anonymous gateway must stay label-free: {text}");
        assert!(text.contains("rwkvquant_served_tokens_total 5"));
        assert!(text.contains("rwkvquant_request_latency_seconds{quantile=\"0.5\"} 0.002"));
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(model_label("a\"b\\c"), "{model=\"a\\\"b\\\\c\"}");
        assert_eq!(model_label(""), "");
    }

    #[test]
    fn lint_passes_both_render_paths() {
        let m = Metrics::new();
        m.on_tokens(5);
        m.on_completed(Duration::from_millis(2));
        m.on_lane_busy(&[1_000_000, 2_000_000]);
        assert_eq!(lint_exposition(&m.render_prometheus()), Vec::<String>::new());
        let gw = Metrics::new();
        let a = Metrics::new();
        let b = Metrics::new();
        a.on_lane_busy(&[5_000_000]);
        let text = render_exposition(&gw, &[("alpha", &a), ("be\"ta", &b)]);
        assert_eq!(lint_exposition(&text), Vec::<String>::new());
    }

    #[test]
    fn lint_catches_malformed_expositions() {
        // sample without a TYPE header
        let p = lint_exposition("orphan_total 3\n");
        assert!(p.iter().any(|e| e.contains("no preceding # TYPE")), "{p:?}");
        // duplicate series
        let text = "# HELP x_total x.\n# TYPE x_total counter\nx_total 1\nx_total 2\n";
        let p = lint_exposition(text);
        assert!(p.iter().any(|e| e.contains("duplicate series")), "{p:?}");
        // unescaped quote inside a label value
        let text = "# HELP y y.\n# TYPE y gauge\ny{model=\"a\"b\"} 1\n";
        assert!(!lint_exposition(text).is_empty());
        // unparsable value
        let text = "# HELP z z.\n# TYPE z gauge\nz NaNish\n";
        let p = lint_exposition(text);
        assert!(p.iter().any(|e| e.contains("unparsable")), "{p:?}");
    }

    #[test]
    fn inflight_tracks_admit_progress_done() {
        let m = Metrics::new();
        m.trace().set_enabled(true);
        m.on_seq_admitted(7, 12, 32);
        m.on_seq_admitted(9, 4, 8);
        m.on_seq_progress(7, SeqStage::Decode, 3, Some(1));
        m.on_seq_progress(9, SeqStage::Parked, 0, None);
        let snap = m.inflight_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].id, 7);
        assert_eq!(snap[0].stage, "decode");
        assert_eq!(snap[0].generated, 3);
        assert_eq!(snap[0].slab, Some(1));
        assert_eq!(snap[1].stage, "parked");
        assert_eq!(snap[1].slab, None);
        assert_eq!(snap[1].prompt_len, 4);
        let text = m.render_prometheus();
        assert!(text.contains("rwkvquant_inflight_sequences 2"), "{text}");
        m.on_seq_done(7);
        m.on_seq_done(9);
        assert!(m.inflight_snapshot().is_empty());
    }

    #[test]
    fn new_families_render_with_expected_labels() {
        let m = Metrics::new();
        m.mapped_stores.store(3, Ordering::Relaxed);
        m.on_lane_busy(&[2_000_000_000, 500_000_000]);
        let text = m.render_prometheus();
        assert!(text.contains("rwkvquant_mapped_stores 3"), "{text}");
        assert!(text.contains("rwkvquant_lane_busy_seconds_total{lane=\"0\"} 2"));
        assert!(text.contains("rwkvquant_lane_busy_seconds_total{lane=\"1\"} 0.5"));
        // the kernel grid renders all nine op × kernel series
        for op in kstats::OPS {
            for kernel in kstats::KERNELS {
                let series =
                    format!("rwkvquant_kernel_matvec_calls_total{{op=\"{op}\",kernel=\"{kernel}\"}}");
                assert!(text.contains(&series), "missing {series} in {text}");
            }
        }
        assert!(text.contains("rwkvquant_kernel_matvec_seconds_total{op=\"sq\",kernel=\"scalar\"}"));
        // fleet render keeps the model label first in the brace set
        let gw = Metrics::new();
        let text = render_exposition(&gw, &[("alpha", &m)]);
        assert!(text.contains("rwkvquant_lane_busy_seconds_total{model=\"alpha\",lane=\"1\"} 0.5"));
        assert!(text.contains("rwkvquant_mapped_stores{model=\"alpha\"} 3"));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn resident_set_is_reported_on_linux() {
        let rss = resident_set_bytes().expect("procfs statm present on linux");
        assert!(rss > 0);
        let m = Metrics::new();
        assert!(m.render_prometheus().contains("rwkvquant_process_resident_bytes "));
    }

    #[test]
    fn window_wraps_and_keeps_recent_samples() {
        let mut w = Window::new();
        for i in 0..(WINDOW + 10) {
            w.push(Duration::from_micros(i as u64));
        }
        let sorted = w.sorted();
        assert_eq!(sorted.len(), WINDOW);
        // the 10 oldest samples were overwritten
        assert_eq!(sorted[0], Duration::from_micros(10));
        assert_eq!(sorted[WINDOW - 1], Duration::from_micros((WINDOW + 9) as u64));
    }
}
