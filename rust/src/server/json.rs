//! Minimal JSON parser for the gateway's request bodies (no serde in
//! the offline vendor set). Produces the crate's existing
//! [`crate::report::json::Json`] value type, so the emitter and the
//! parser share one representation.
//!
//! Scope: full JSON syntax (objects, arrays, strings with escapes and
//! `\uXXXX` incl. surrogate pairs, numbers, literals), with two
//! deliberate hardening limits for a network-facing parser — a nesting
//! depth cap and "last key wins" duplicate-object-key semantics. Input
//! is `&str`, so UTF-8 validity is the caller's concern (the HTTP layer
//! rejects invalid UTF-8 bodies with a 400 before parsing).

use crate::report::json::Json;
use std::collections::BTreeMap;

/// Nesting cap: a request body has no business nesting deeper, and the
/// recursive-descent parser must not let a hostile body overflow the
/// stack.
const MAX_DEPTH: usize = 64;

/// Parse one complete JSON value (surrounding whitespace allowed;
/// trailing garbage is an error).
pub fn parse(s: &str) -> Result<Json, String> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing bytes after JSON value at offset {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        match self.bump() {
            Some(got) if got == c => Ok(()),
            Some(got) => Err(format!(
                "expected '{}' at offset {}, got '{}'",
                c as char,
                self.pos - 1,
                got as char
            )),
            None => Err(format!("expected '{}', got end of input", c as char)),
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected byte 0x{c:02x} at offset {}", self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at offset {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii number bytes");
        let n: f64 =
            text.parse().map_err(|_| format!("invalid number '{text}' at offset {start}"))?;
        if !n.is_finite() {
            return Err(format!("non-finite number '{text}' at offset {start}"));
        }
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => out.push(self.unicode_escape()?),
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x20 => {
                    return Err(format!("raw control byte 0x{c:02x} in string"));
                }
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: the input is a valid &str, so
                    // re-decode the sequence starting one byte back
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    let ch = s.chars().next().ok_or("invalid UTF-8 in string")?;
                    out.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or("truncated \\u escape")?;
            let d = (c as char).to_digit(16).ok_or("non-hex digit in \\u escape")?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        // surrogate pair: \uD800-\uDBFF must be followed by \uDC00-\uDFFF
        if (0xD800..=0xDBFF).contains(&hi) {
            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                return Err("lone high surrogate".into());
            }
            let lo = self.hex4()?;
            if !(0xDC00..=0xDFFF).contains(&lo) {
                return Err("invalid low surrogate".into());
            }
            let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            return char::from_u32(code).ok_or_else(|| "invalid surrogate pair".into());
        }
        if (0xDC00..=0xDFFF).contains(&hi) {
            return Err("lone low surrogate".into());
        }
        char::from_u32(hi).ok_or_else(|| "invalid \\u escape".into())
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(xs)),
                _ => return Err(format!("expected ',' or ']' at offset {}", self.pos - 1)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            m.insert(key, val); // duplicate keys: last one wins
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.pos - 1)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generation_request_shape() {
        let v = parse(r#"{"prompt": [3, 1, 2], "gen_len": 8, "stream": false}"#).unwrap();
        let prompt: Vec<usize> = v
            .get("prompt")
            .and_then(Json::as_array)
            .unwrap()
            .iter()
            .map(|t| t.as_usize().unwrap())
            .collect();
        assert_eq!(prompt, vec![3, 1, 2]);
        assert_eq!(v.get("gen_len").and_then(Json::as_usize), Some(8));
        assert_eq!(v.get("stream").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn round_trips_through_the_emitter() {
        let text = r#"{"a":[1,2.5,true,null,"x\ny"],"b":{"c":-3}}"#;
        let v = parse(text).unwrap();
        assert_eq!(parse(&v.render()).unwrap().render(), v.render());
    }

    #[test]
    fn string_escapes_and_unicode() {
        assert_eq!(parse(r#""\u0041\t\"\\""#).unwrap().as_str(), Some("A\t\"\\"));
        // surrogate pair for 𝄞 (U+1D11E)
        assert_eq!(parse(r#""\uD834\uDD1E""#).unwrap().as_str(), Some("𝄞"));
        // raw multi-byte UTF-8 passes through
        assert_eq!(parse("\"héllo — 日本\"").unwrap().as_str(), Some("héllo — 日本"));
        assert!(parse(r#""\uD834""#).is_err(), "lone surrogate must error");
        assert!(parse("\"a\nb\"").is_err(), "raw control byte must error");
    }

    #[test]
    fn malformed_inputs_error_not_panic() {
        for bad in [
            "", "{", "}", "[", "[1,", "[1 2]", "{\"a\"}", "{\"a\":}", "{a:1}", "tru", "nul",
            "01a", "1.2.3", "--1", "\"unterminated", "{\"a\":1}x", "[1]]", "1e999",
            "\"\\q\"", "\"\\u12\"", "[,]",
        ] {
            assert!(parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn depth_limit_enforced() {
        let deep: String = "[".repeat(MAX_DEPTH + 2) + &"]".repeat(MAX_DEPTH + 2);
        assert!(parse(&deep).is_err());
        let ok: String = "[".repeat(MAX_DEPTH / 2) + &"]".repeat(MAX_DEPTH / 2);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn numbers_parse_and_reject_non_finite() {
        assert_eq!(parse("-0.5e2").unwrap().as_f64(), Some(-50.0));
        assert_eq!(parse("12").unwrap().as_usize(), Some(12));
        assert!(parse("1e400").is_err(), "overflowing number must be rejected");
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let v = parse(r#"{"a":1,"a":2}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_usize), Some(2));
    }
}
