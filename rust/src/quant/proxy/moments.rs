//! Fine-grained proxy `P_f` (Eqs. 10–17): weighted high-order central
//! moments of `G'`, from the order-K Taylor expansion of `P_c` around the
//! uniform point — sensitive to *local* outliers that barely move the
//! global entropy (Fig. 3b vs 3c).
//!
//! Paper form: `P_f = Σ_{k=2}^{K} v_k |M_k|`, `v_k = n^k / (k(k−1))`,
//! `M_k = E[(G' − E[G'])^k]`. Since `E[G'] = 1/n` exactly, substituting
//! `t = n·G'` gives the numerically-stable equivalent
//! `v_k·M_k = E[(t−1)^k] / (k(k−1))` — no `n^k` overflow, no `δ^k`
//! underflow, mathematically identical.

use super::GPrime;

/// Fine-grained proxy with Taylor truncation order `K ≥ 2`.
pub fn p_f(g: &GPrime, order: u32) -> f64 {
    assert!(order >= 2, "P_f needs K >= 2");
    let n = g.n();
    if n == 0 {
        return 0.0;
    }
    let mut sum = 0.0f64;
    for k in 2..=order {
        // E[(t-1)^k]
        let mut m = 0.0f64;
        for &t in &g.t {
            m += (t - 1.0).powi(k as i32);
        }
        m /= n as f64;
        sum += m.abs() / (k as f64 * (k as f64 - 1.0));
    }
    sum
}

/// The individual scaled moment terms (for diagnostics / Fig. 3 dumps).
pub fn moment_terms(g: &GPrime, order: u32) -> Vec<f64> {
    let n = g.n().max(1) as f64;
    (2..=order)
        .map(|k| {
            let m: f64 =
                g.t.iter().map(|&t| (t - 1.0).powi(k as i32)).sum::<f64>() / n;
            m.abs() / (k as f64 * (k as f64 - 1.0))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::proxy::{entropy, GPrime};
    use crate::util::rng::Rng;

    /// Mix a uniform grid with a few extreme outliers — `P_c` barely
    /// moves (the paper's motivation) but `P_f` fires.
    fn uniform_with_outliers(n: usize, n_out: usize, mag: f32, rng: &mut Rng) -> Vec<f32> {
        let mut w: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
        for _ in 0..n_out {
            let i = rng.below(n);
            w[i] = mag * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        w
    }

    #[test]
    fn zero_for_uniform() {
        let w: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let g = GPrime::from_weights(&w);
        assert!(p_f(&g, 4) < 1e-6);
    }

    #[test]
    fn fires_on_local_outliers_where_pc_does_not() {
        let mut rng = Rng::new(1);
        let clean: Vec<f32> = (0..4096).map(|i| i as f32 / 4096.0).collect();
        let dirty = uniform_with_outliers(4096, 4, 50.0, &mut rng);
        let gc = GPrime::from_weights(&clean);
        let gd = GPrime::from_weights(&dirty);
        // coarse proxy moves little...
        let dpc = entropy::p_c(&gd) - entropy::p_c(&gc);
        // ...fine proxy explodes
        let dpf = p_f(&gd, 4) - p_f(&gc, 4);
        assert!(dpf > 100.0 * dpc.max(1e-9), "dpf={dpf} dpc={dpc}");
        assert!(p_f(&gd, 4) > 10.0, "P_f={}", p_f(&gd, 4));
    }

    #[test]
    fn higher_order_more_sensitive_to_tails() {
        let mut rng = Rng::new(2);
        let dirty = uniform_with_outliers(2048, 2, 100.0, &mut rng);
        let g = GPrime::from_weights(&dirty);
        let terms = moment_terms(&g, 4);
        // kurtosis-like term dominates variance term on extreme outliers
        assert!(terms[2] > terms[0], "terms={terms:?}");
    }

    #[test]
    fn monotone_in_outlier_magnitude() {
        let mut rng = Rng::new(3);
        let a = uniform_with_outliers(1024, 3, 5.0, &mut rng);
        let mut rng = Rng::new(3);
        let b = uniform_with_outliers(1024, 3, 500.0, &mut rng);
        let pa = p_f(&GPrime::from_weights(&a), 4);
        let pb = p_f(&GPrime::from_weights(&b), 4);
        assert!(pb > pa, "{pb} vs {pa}");
    }

    #[test]
    #[should_panic(expected = "K >= 2")]
    fn rejects_order_below_two() {
        let g = GPrime::from_weights(&[0.0, 1.0, 2.0]);
        p_f(&g, 1);
    }
}
