//! Coarse-grained proxy `P_c` (Eqs. 7–9): the information-entropy gap
//! between the observed interval distribution `G'` and the perfectly
//! uniform reference `Ĝ'`.
//!
//! `P_c(G') = H(Ĝ') − H(G') = ln n − (−Σ G'_i ln G'_i) ≥ 0`, with
//! equality iff the weight values are exactly evenly spaced. Large `P_c`
//! ⇒ strongly non-uniform weights ⇒ cluster-friendly ⇒ VQ (Fig. 3a).

use super::GPrime;

/// Entropy of `G'` relative to uniform, computed stably in the scaled
/// variable `t = n·G'`:
/// `P_c = ln n − H(G') = (1/n)·Σ t_i ln t_i · ... ` — concretely,
/// `H(G') = −Σ (t/n)·ln(t/n) = ln n − (1/n)Σ t ln t`, so
/// `P_c = (1/n) Σ t_i ln t_i` (terms with t=0 contribute 0).
pub fn p_c(g: &GPrime) -> f64 {
    let n = g.n() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mut s = 0.0f64;
    for &t in &g.t {
        if t > 0.0 {
            s += t * t.ln();
        }
    }
    (s / n).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::proxy::GPrime;
    use crate::util::rng::Rng;

    #[test]
    fn zero_for_uniform_weights() {
        let w: Vec<f32> = (0..512).map(|i| i as f32).collect();
        let g = GPrime::from_weights(&w);
        assert!(p_c(&g) < 1e-6);
    }

    #[test]
    fn positive_for_nonuniform_weights() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let g = GPrime::from_weights(&w);
        assert!(p_c(&g) > 0.1, "P_c={}", p_c(&g));
    }

    /// Jensen: P_c is the KL divergence KL(G' || uniform) ≥ 0.
    #[test]
    fn nonnegative_always() {
        let mut rng = Rng::new(2);
        for trial in 0..20 {
            let w: Vec<f32> = (0..256)
                .map(|_| rng.student_t(2.5) as f32 * (trial as f32 + 1.0))
                .collect();
            let g = GPrime::from_weights(&w);
            assert!(p_c(&g) >= 0.0);
        }
    }

    /// The paper's core empirical claim (§4.4): interval entropy separates
    /// uniform-ish weight distributions from clustered/Gaussian ones.
    #[test]
    fn separates_uniform_from_clustered() {
        let mut rng = Rng::new(3);
        let uniform: Vec<f32> = (0..8192).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let clustered: Vec<f32> = (0..8192)
            .map(|_| {
                let c = if rng.f64() < 0.5 { -0.5 } else { 0.5 };
                c + rng.normal_ms(0.0, 0.02) as f32
            })
            .collect();
        let pu = p_c(&GPrime::from_weights(&uniform));
        let pc = p_c(&GPrime::from_weights(&clustered));
        assert!(pc > pu * 1.5, "clustered {pc} should far exceed uniform {pu}");
    }

    /// Scale invariance: G' normalises out the weight scale.
    #[test]
    fn scale_invariant() {
        let mut rng = Rng::new(4);
        let w: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let w10: Vec<f32> = w.iter().map(|&x| x * 10.0).collect();
        let a = p_c(&GPrime::from_weights(&w));
        let b = p_c(&GPrime::from_weights(&w10));
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}
