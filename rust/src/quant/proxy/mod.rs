//! The coarse-to-fine proxy of §3.1.
//!
//! Both proxies operate on the transformed weight `G'`: the weight is
//! flattened, sorted ascending (`W'`), differenced into intervals
//! `G = W'[1:] - W'[:-1]` (Eq. 5), and normalised so `Σ G'_i = 1`
//! (Eq. 6) — turning the *spacing structure* of the weight values into a
//! discrete probability distribution whose uniformity mirrors the
//! uniformity of the original weight.
//!
//! * [`entropy`] — coarse proxy `P_c = H(Ĝ') − H(G') = ln n − H(G')`
//!   (Eq. 9): global uniformity.
//! * [`moments`] — fine proxy `P_f = Σ_{k≥2} v_k |M_k|` (Eq. 17): local
//!   outliers, from the Taylor expansion of `P_c` around the uniform
//!   point (Eqs. 10–16).
//! * [`baselines`] — the Table-6 comparison proxies (Variance, CV,
//!   Range, MAD) applied to the same `G'`.

pub mod baselines;
pub mod entropy;
pub mod moments;

/// The transformed weight: normalised sorted-interval distribution `G'`.
///
/// Stored as `t_i = n·G'_i` (scaled by `n`) because every downstream
/// formula is numerically stable in that variable: the uniform reference
/// is `t ≡ 1`, and the k-th proxy term is `mean((t-1)^k) / (k(k-1))`
/// without the `n^k` blow-up of the paper's raw `v_k` weights.
#[derive(Debug, Clone)]
pub struct GPrime {
    /// n·G'_i per interval (mean exactly 1 when total > 0)
    pub t: Vec<f64>,
}

impl GPrime {
    /// Build `G'` from a flat weight slice. O(n log n) for the sort.
    pub fn from_weights(w: &[f32]) -> GPrime {
        assert!(w.len() >= 2, "proxy needs at least 2 weights");
        let mut sorted: Vec<f32> = w.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len() - 1;
        let total = (sorted[n] - sorted[0]) as f64;
        let mut t = Vec::with_capacity(n);
        if total <= 0.0 {
            // degenerate constant weight: define G' as exactly uniform
            t.resize(n, 1.0);
            return GPrime { t };
        }
        for i in 0..n {
            let g = (sorted[i + 1] - sorted[i]) as f64;
            t.push(g / total * n as f64);
        }
        GPrime { t }
    }

    /// Number of intervals `n = numel − 1`.
    pub fn n(&self) -> usize {
        self.t.len()
    }
}

/// Both proxies for one weight, plus the decision inputs.
#[derive(Debug, Clone, Copy)]
pub struct ProxyPair {
    pub p_c: f64,
    pub p_f: f64,
}

/// Compute `(P_c, P_f)` for a flat weight with Taylor order `K`.
pub fn compute(w: &[f32], order: u32) -> ProxyPair {
    let g = GPrime::from_weights(w);
    ProxyPair { p_c: entropy::p_c(&g), p_f: moments::p_f(&g, order) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gprime_sums_to_n() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let g = GPrime::from_weights(&w);
        let sum: f64 = g.t.iter().sum();
        assert!((sum - g.n() as f64).abs() / (g.n() as f64) < 1e-6, "sum={sum} n={}", g.n());
    }

    #[test]
    fn uniform_grid_gives_constant_t() {
        let w: Vec<f32> = (0..100).map(|i| i as f32 * 0.01).collect();
        let g = GPrime::from_weights(&w);
        assert!(g.t.iter().all(|&t| (t - 1.0).abs() < 1e-4));
    }

    #[test]
    fn constant_weight_degenerate_uniform() {
        let w = vec![0.5f32; 64];
        let g = GPrime::from_weights(&w);
        assert!(g.t.iter().all(|&t| t == 1.0));
    }

    #[test]
    fn order_independent_of_input_permutation() {
        let mut rng = Rng::new(2);
        let mut w: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        let p1 = compute(&w, 4);
        rng.shuffle(&mut w);
        let p2 = compute(&w, 4);
        assert!((p1.p_c - p2.p_c).abs() < 1e-12);
        assert!((p1.p_f - p2.p_f).abs() < 1e-9);
    }
}
