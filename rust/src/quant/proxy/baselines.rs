//! The Table-6 baseline proxies: Variance, Coefficient of Variation,
//! Range, MAD, and the direct MSE selector. All statistical baselines are
//! applied to the transformed `G'` (in the stable `t = n·G'` variable),
//! "used in the same manner as described in our method" (§4.3).

use super::GPrime;
use crate::quant::{CalibData, LayerKind, QuantizedLayer};
use crate::tensor::Matrix;

/// Which single-statistic proxy to use in place of the coarse-to-fine pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineProxy {
    Variance,
    CV,
    Range,
    MAD,
    /// direct per-layer SQ-vs-VQ MSE comparison (the "local optimum")
    MSE,
    /// IE only (coarse proxy without the fine stage)
    IE,
}

impl BaselineProxy {
    pub fn name(&self) -> &'static str {
        match self {
            BaselineProxy::Variance => "Variance",
            BaselineProxy::CV => "CV",
            BaselineProxy::Range => "Range",
            BaselineProxy::MAD => "MAD",
            BaselineProxy::MSE => "MSE",
            BaselineProxy::IE => "IE",
        }
    }

    pub fn all() -> &'static [BaselineProxy] {
        &[
            BaselineProxy::Variance,
            BaselineProxy::CV,
            BaselineProxy::Range,
            BaselineProxy::MAD,
            BaselineProxy::MSE,
            BaselineProxy::IE,
        ]
    }
}

/// Statistic of `G'` for the given baseline (not defined for MSE, which
/// needs the quantizers — see [`mse_prefers_sq`]).
pub fn statistic(proxy: BaselineProxy, g: &GPrime) -> f64 {
    let n = g.n().max(1) as f64;
    match proxy {
        BaselineProxy::Variance => {
            // Var(t) = E[(t-1)^2]; mean of t is exactly 1
            g.t.iter().map(|&t| (t - 1.0) * (t - 1.0)).sum::<f64>() / n
        }
        BaselineProxy::CV => {
            let var = g.t.iter().map(|&t| (t - 1.0) * (t - 1.0)).sum::<f64>() / n;
            var.sqrt() // mean is 1, so CV = σ
        }
        BaselineProxy::Range => {
            let lo = g.t.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = g.t.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            hi - lo
        }
        BaselineProxy::MAD => g.t.iter().map(|&t| (t - 1.0).abs()).sum::<f64>() / n,
        BaselineProxy::IE => super::entropy::p_c(g),
        BaselineProxy::MSE => panic!("MSE baseline is decided per-layer, not a statistic"),
    }
}

/// The MSE selector: quantize both ways and keep whichever reconstructs
/// the layer with lower weight-space MSE (the per-layer "local optimum"
/// the paper shows is globally suboptimal in Table 6).
pub fn mse_prefers_sq(
    w: &Matrix,
    _kind: LayerKind,
    calib: Option<&CalibData>,
    cfg: &crate::config::QuantConfig,
    rng: &mut crate::util::rng::Rng,
) -> bool {
    let sq = QuantizedLayer::Sq(crate::quant::sq::gptq::quantize(
        w,
        cfg.sq_bits,
        cfg.group_size,
        calib,
        cfg.percdamp,
    ));
    let vq = QuantizedLayer::Vq(crate::quant::vq::kmeans::quantize(
        w,
        cfg.vq_bits,
        cfg.vq_dim,
        cfg.kmeans_iters.min(10),
        rng,
    ));
    sq.mse(w) <= vq.mse(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn statistics_zero_on_uniform() {
        let w: Vec<f32> = (0..128).map(|i| i as f32).collect();
        let g = GPrime::from_weights(&w);
        for p in [BaselineProxy::Variance, BaselineProxy::CV, BaselineProxy::MAD] {
            assert!(statistic(p, &g) < 1e-6, "{p:?}");
        }
        assert!(statistic(BaselineProxy::Range, &g) < 1e-4);
    }

    #[test]
    fn statistics_positive_on_gaussian() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
        let g = GPrime::from_weights(&w);
        for p in [
            BaselineProxy::Variance,
            BaselineProxy::CV,
            BaselineProxy::Range,
            BaselineProxy::MAD,
            BaselineProxy::IE,
        ] {
            assert!(statistic(p, &g) > 0.01, "{p:?}={}", statistic(p, &g));
        }
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<&str> = BaselineProxy::all().iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), BaselineProxy::all().len());
    }

    #[test]
    #[should_panic]
    fn mse_statistic_panics() {
        let g = GPrime::from_weights(&[0.0, 1.0]);
        statistic(BaselineProxy::MSE, &g);
    }
}
