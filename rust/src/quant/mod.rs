//! The RWKVQuant quantization library — the paper's core contribution.
//!
//! * [`sq`] — scalar quantization engines: RTN, GPTQ (second-order
//!   compensation), AWQ (activation-aware scaling), QuaRot (random
//!   Hadamard rotation baseline).
//! * [`vq`] — vector quantization engines: (weighted) K-Means codebooks,
//!   GPTVQ (VQ + GPTQ-style error propagation), VPTQ (Hessian-weighted
//!   codebooks).
//! * [`proxy`] — the coarse-to-fine proxy of §3.1: interval-entropy
//!   uniformity proxy `P_c` and the central-moment outlier proxy `P_f`,
//!   plus the Table-6 baseline proxies.
//! * [`hybrid`] — the Eq. 18 selector and τ auto-calibration.
//! * [`ewmul`] — §3.2 codebook optimisation for element-wise
//!   multiplication weights (X²-weighted K-Means with percentile-clipped
//!   batch integration).
//! * [`packing`] — bit-level storage for quantized payloads.
//! * [`exec`] — the [`exec::LinearOp`] serving contract plus streaming
//!   matvec kernels that run directly on the packed payloads (what the
//!   `QuantizedModel` provider and the whole serving stack consume).

pub mod ewmul;
pub mod exec;
pub mod hybrid;
pub mod packing;
pub mod proxy;
pub mod sq;
pub mod vq;

use crate::tensor::Matrix;
use packing::PackedInts;

/// How a weight participates in the model — matmul weights (`W·x`) vs the
/// RWKV element-wise weights (`μ ⊙ x`, token-shift interpolators). The
/// distinction drives the §3.2 codebook optimisation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    MatMul,
    ElementWise,
}

/// A scalar-quantized weight: `bits`-bit codes with one (scale, min) pair
/// per group of `group_size` consecutive elements (row-major order).
/// Dequantization: `w = min + scale * q`.
#[derive(Clone, Debug)]
pub struct SqLayer {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub group_size: usize,
    pub codes: PackedInts,
    pub scales: Vec<f32>,
    pub mins: Vec<f32>,
    /// extra runtime FLOPs this method forces per forward token (QuaRot's
    /// non-fusable rotations, AWQ's non-fusable activation scaling; 0 for
    /// everything else — the paper's §1 overhead argument)
    pub extra_flops_per_token: u64,
    /// optional inverse transform applied at dequant time (QuaRot)
    pub rotation: Option<RotationMeta>,
    /// optional per-column inverse scale applied at dequant time (AWQ:
    /// W was quantized as W·diag(s); reconstruct Ŵ = Q(W·diag(s))·diag(1/s))
    pub col_inv_scale: Option<Vec<f32>>,
}

/// Metadata for undoing a random-Hadamard rotation at dequant time.
#[derive(Clone, Debug)]
pub struct RotationMeta {
    /// ±1 signs of the diagonal, length = cols (power of two)
    pub signs: Vec<f32>,
}

impl SqLayer {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Reconstruct the dense weight.
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let n = self.numel();
        for i in 0..n {
            let g = i / self.group_size;
            m.data[i] = self.mins[g] + self.scales[g] * self.codes.get(i) as f32;
        }
        if let Some(inv) = &self.col_inv_scale {
            for r in 0..m.rows {
                let row = m.row_mut(r);
                for (v, s) in row.iter_mut().zip(inv) {
                    *v *= s;
                }
            }
        }
        if let Some(rot) = &self.rotation {
            // W was quantized in the rotated basis: W_rot = W · H_s.
            // Undo with the inverse (H_s is orthonormal): W = W_rot · H_sᵀ,
            // which for a sign-then-FWHT rotation is FWHT-then-sign per
            // row, applied blockwise for non-power-of-two widths.
            for r in 0..m.rows {
                crate::quant::sq::quarot::unrotate_row(m.row_mut(r), &rot.signs);
            }
        }
        m
    }

    /// Total storage in bits: codes + one fp16 scale per group (the grid
    /// is symmetric, so the min is derived — this is the paper's bpw
    /// accounting: 3-bit codes + 16/64 = 3.25, + 16/32 = 3.5).
    pub fn storage_bits(&self) -> usize {
        let groups = self.numel().div_ceil(self.group_size);
        self.codes.payload_bits() + groups * 16
    }

    pub fn bpw(&self) -> f64 {
        self.storage_bits() as f64 / self.numel() as f64
    }
}

/// A vector-quantized weight: the flat weight is split into `d`-sized
/// vectors, each replaced by a `k`-bit index into `codebook`
/// (shape `2^k × d`, stored flat). A trailing remainder of
/// `numel % d` elements is kept in fp16 (`tail`).
#[derive(Clone, Debug)]
pub struct VqLayer {
    pub rows: usize,
    pub cols: usize,
    pub d: usize,
    pub k: u32,
    /// flat codebook, length = n_entries * d
    pub codebook: Vec<f32>,
    pub indices: PackedInts,
    /// fp16-accounted remainder elements (numel % d of them)
    pub tail: Vec<f32>,
}

impl VqLayer {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    pub fn n_entries(&self) -> usize {
        self.codebook.len() / self.d
    }

    pub fn entry(&self, idx: usize) -> &[f32] {
        &self.codebook[idx * self.d..(idx + 1) * self.d]
    }

    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let nvec = self.numel() / self.d;
        for v in 0..nvec {
            let e = self.entry(self.indices.get(v) as usize);
            m.data[v * self.d..(v + 1) * self.d].copy_from_slice(e);
        }
        let tail_start = nvec * self.d;
        m.data[tail_start..].copy_from_slice(&self.tail);
        m
    }

    /// Storage: k bits per vector + fp16 codebook + fp16 tail.
    pub fn storage_bits(&self) -> usize {
        self.indices.payload_bits() + self.codebook.len() * 16 + self.tail.len() * 16
    }

    pub fn bpw(&self) -> f64 {
        self.storage_bits() as f64 / self.numel() as f64
    }
}

/// A quantized layer: SQ, VQ, or kept in fp16 (embeddings / heads /
/// 1-D norms are excluded from quantization, as in all the compared PTQ
/// frameworks).
#[derive(Clone, Debug)]
pub enum QuantizedLayer {
    Sq(SqLayer),
    Vq(VqLayer),
    Fp16 { rows: usize, cols: usize, data: Vec<f32> },
}

impl QuantizedLayer {
    pub fn dequantize(&self) -> Matrix {
        match self {
            QuantizedLayer::Sq(l) => l.dequantize(),
            QuantizedLayer::Vq(l) => l.dequantize(),
            QuantizedLayer::Fp16 { rows, cols, data } => {
                Matrix::from_vec(*rows, *cols, data.clone())
            }
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            QuantizedLayer::Sq(l) => l.numel(),
            QuantizedLayer::Vq(l) => l.numel(),
            QuantizedLayer::Fp16 { rows, cols, .. } => rows * cols,
        }
    }

    pub fn storage_bits(&self) -> usize {
        match self {
            QuantizedLayer::Sq(l) => l.storage_bits(),
            QuantizedLayer::Vq(l) => l.storage_bits(),
            QuantizedLayer::Fp16 { rows, cols, .. } => rows * cols * 16,
        }
    }

    pub fn bpw(&self) -> f64 {
        self.storage_bits() as f64 / self.numel() as f64
    }

    pub fn is_vq(&self) -> bool {
        matches!(self, QuantizedLayer::Vq(_))
    }

    /// Mean squared reconstruction error against the original weight.
    pub fn mse(&self, original: &Matrix) -> f64 {
        self.dequantize().sq_err(original) / original.numel() as f64
    }
}

/// Per-layer calibration inputs: activations feeding this layer,
/// one row per calibration token/sample (shape `samples × ic` for
/// matmul layers; `samples × n` for element-wise layers).
#[derive(Clone, Debug)]
pub struct CalibData {
    pub x: Matrix,
}

impl CalibData {
    /// Gram matrix XᵀX used as the GPTQ Hessian proxy.
    pub fn hessian(&self) -> Matrix {
        crate::tensor::linalg::gram(&self.x)
    }

    /// Per-column mean absolute activation (AWQ importance).
    pub fn col_abs_mean(&self) -> Vec<f32> {
        let mut out = vec![0.0f64; self.x.cols];
        for r in 0..self.x.rows {
            for (c, &v) in self.x.row(r).iter().enumerate() {
                out[c] += v.abs() as f64;
            }
        }
        out.iter().map(|v| (*v / self.x.rows.max(1) as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_w(seed: u64, r: usize, c: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(&mut m.data, 0.0, 0.05);
        m
    }

    #[test]
    fn sq_layer_bpw_accounting() {
        let w = rand_w(1, 16, 64);
        let l = sq::rtn::quantize(&w, 3, 32);
        // 3 bits + 16/group-of-32 = 3.5 bpw
        assert!((l.bpw() - 3.5).abs() < 1e-9, "bpw={}", l.bpw());
        let l2 = sq::rtn::quantize(&w, 3, 64);
        assert!((l2.bpw() - 3.25).abs() < 1e-9);
    }

    #[test]
    fn fp16_layer_identity() {
        let w = rand_w(2, 4, 4);
        let l = QuantizedLayer::Fp16 { rows: 4, cols: 4, data: w.data.clone() };
        assert_eq!(l.dequantize(), w);
        assert_eq!(l.bpw(), 16.0);
        assert!(l.mse(&w) < 1e-12);
    }

    #[test]
    fn calib_hessian_is_spd_diag_positive() {
        let x = rand_w(3, 32, 8);
        let h = CalibData { x }.hessian();
        for i in 0..8 {
            assert!(h.at(i, i) > 0.0);
        }
    }

    #[test]
    fn col_abs_mean_nonnegative() {
        let x = rand_w(4, 16, 8);
        let m = CalibData { x }.col_abs_mean();
        assert!(m.iter().all(|&v| v >= 0.0));
        assert_eq!(m.len(), 8);
    }
}
