//! Bit-packing of small unsigned integers into a dense `u64` word
//! stream. Used for SQ codes (3–8 bit) and VQ codebook indices (≤16 bit).
//! Packing is little-endian within each 64-bit word; values may straddle
//! word boundaries.
//!
//! The word stream itself lives behind [`PackedBytes`]: either an owned
//! `Vec<u64>` (the quantization pipeline's output) or a borrowed window
//! of a memory-mapped RWKVQ2 checkpoint — the zero-copy serving path,
//! where the packed payload is never copied out of the mapping and pages
//! fault in on first matvec.

use crate::util::mmap::Mmap;
use std::sync::Arc;

/// Backing storage for a packed word stream: owned words, or an aligned
/// window borrowed from a checkpoint mapping.
#[derive(Clone, Debug)]
pub enum PackedBytes {
    Owned(Vec<u64>),
    Mapped(MappedWords),
}

impl PackedBytes {
    /// View the payload as `u64` words (little-endian on disk; the
    /// mapped variant reinterprets in place and is only constructed on
    /// little-endian hosts — see `util::mmap::SUPPORTED`).
    #[inline]
    pub fn as_words(&self) -> &[u64] {
        match self {
            PackedBytes::Owned(v) => v,
            PackedBytes::Mapped(m) => m.as_words(),
        }
    }

    /// Is this payload borrowed from a checkpoint mapping?
    pub fn is_mapped(&self) -> bool {
        matches!(self, PackedBytes::Mapped(_))
    }
}

/// An 8-aligned `u64` window of a shared read-only mapping.
#[derive(Clone)]
pub struct MappedWords {
    map: Arc<Mmap>,
    offset: usize,
    words: usize,
}

impl MappedWords {
    /// Borrow `words` u64 words at byte `offset` of `map`. The offset
    /// must be 8-aligned and in bounds (the RWKVQ2 writer aligns every
    /// payload to 64 bytes).
    pub fn new(map: Arc<Mmap>, offset: usize, words: usize) -> MappedWords {
        assert_eq!(offset % 8, 0, "packed payload offset {offset} unaligned");
        // non-wrapping bounds check (u128: immune to crafted sizes)
        let end = offset as u128 + words as u128 * 8;
        assert!(end <= map.len() as u128, "packed payload at {offset} overruns the mapping");
        MappedWords { map, offset, words }
    }

    #[inline]
    fn as_words(&self) -> &[u64] {
        let bytes = &self.map.as_bytes()[self.offset..self.offset + self.words * 8];
        // SAFETY: 8-aligned in-bounds window of a live read-only mapping
        // (checked in `new`); u64 has no invalid bit patterns.
        unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const u64, self.words) }
    }
}

impl std::fmt::Debug for MappedWords {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedWords")
            .field("offset", &self.offset)
            .field("words", &self.words)
            .finish()
    }
}

/// A bit-packed array of `len` unsigned integers of `bits` bits each.
#[derive(Clone, Debug)]
pub struct PackedInts {
    pub bits: u32,
    pub len: usize,
    words: PackedBytes,
}

impl PartialEq for PackedInts {
    fn eq(&self, other: &Self) -> bool {
        self.bits == other.bits
            && self.len == other.len
            && self.words.as_words() == other.words.as_words()
    }
}

impl PackedInts {
    /// Pack `values`; every value must fit in `bits` bits.
    pub fn pack(values: &[u32], bits: u32) -> PackedInts {
        assert!((1..=32).contains(&bits), "bits must be 1..=32, got {bits}");
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let total_bits = values.len() * bits as usize;
        let mut words = vec![0u64; total_bits.div_ceil(64)];
        for (i, &v) in values.iter().enumerate() {
            debug_assert!(v <= mask, "value {v} does not fit in {bits} bits");
            let v = (v & mask) as u64;
            let bit = i * bits as usize;
            let word = bit / 64;
            let off = bit % 64;
            words[word] |= v << off;
            if off + bits as usize > 64 {
                words[word + 1] |= v >> (64 - off);
            }
        }
        PackedInts { bits, len: values.len(), words: PackedBytes::Owned(words) }
    }

    /// Reassemble from a deserialized word stream (RWKVQ2 loader). The
    /// word count must match `len` values of `bits` bits.
    pub fn from_raw(bits: u32, len: usize, words: PackedBytes) -> PackedInts {
        assert!((1..=32).contains(&bits), "bits must be 1..=32, got {bits}");
        // u64 product: len*bits must not wrap the word-count check on
        // 32-bit (buffered-fallback) hosts
        let need = (len as u64 * u64::from(bits)).div_ceil(64);
        let have = words.as_words().len() as u64;
        assert_eq!(need, have, "{len}x{bits}-bit payload needs {need} words, got {have}");
        PackedInts { bits, len, words }
    }

    /// Is the payload borrowed from a checkpoint mapping?
    pub fn is_mapped(&self) -> bool {
        self.words.is_mapped()
    }

    /// Read the i-th value.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let words = self.words.as_words();
        let bits = self.bits as usize;
        let mask = if self.bits == 32 { u64::from(u32::MAX) } else { (1u64 << self.bits) - 1 };
        let bit = i * bits;
        let word = bit / 64;
        let off = bit % 64;
        let mut v = words[word] >> off;
        if off + bits > 64 {
            v |= words[word + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    /// Unpack everything.
    pub fn unpack(&self) -> Vec<u32> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Decode a contiguous run into `out` (hot-path dequant helper).
    pub fn get_range(&self, start: usize, out: &mut [u32]) {
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = self.get(start + j);
        }
    }

    /// Storage consumed by the packed payload, in bytes (excluding the
    /// `len`/`bits` header, which is negligible and counted separately in
    /// the bpw accounting).
    pub fn payload_bytes(&self) -> usize {
        self.words.as_words().len() * 8
    }

    /// Exact payload size in bits (len * bits, before word rounding).
    pub fn payload_bits(&self) -> usize {
        self.len * self.bits as usize
    }

    /// Raw word storage (for sequential decoders and the RWKVQ2 writer).
    pub fn words(&self) -> &[u64] {
        self.words.as_words()
    }

    /// Sequential reader positioned at element `start` — much faster
    /// than repeated `get` for contiguous runs (the quantized-matvec
    /// hot path).
    pub fn reader(&self, start: usize) -> BitReader<'_> {
        BitReader {
            words: self.words.as_words(),
            bitpos: start * self.bits as usize,
            bits: self.bits,
        }
    }
}

/// Forward-only bit-stream decoder over a [`PackedInts`] payload.
pub struct BitReader<'a> {
    words: &'a [u64],
    bitpos: usize,
    bits: u32,
}

impl BitReader<'_> {
    /// Decode the next value.
    #[inline(always)]
    pub fn next(&mut self) -> u32 {
        let bits = self.bits as usize;
        let mask = if self.bits == 32 { u64::from(u32::MAX) } else { (1u64 << self.bits) - 1 };
        let word = self.bitpos >> 6;
        let off = self.bitpos & 63;
        let mut v = self.words[word] >> off;
        if off + bits > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        self.bitpos += bits;
        (v & mask) as u32
    }

    /// Decode a contiguous run into a `u8` buffer — the unpack pass of
    /// the SQ matvec kernels, which want byte-wide codes the SIMD lanes
    /// can widen directly. Codes must fit in 8 bits.
    #[inline]
    pub fn fill_u8(&mut self, out: &mut [u8]) {
        debug_assert!(self.bits <= 8, "fill_u8 needs codes ≤ 8 bits, got {}", self.bits);
        for slot in out.iter_mut() {
            *slot = self.next() as u8;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_3bit() {
        let vals: Vec<u32> = (0..100).map(|i| (i * 7) % 8).collect();
        let p = PackedInts::pack(&vals, 3);
        assert_eq!(p.unpack(), vals);
        assert_eq!(p.payload_bits(), 300);
    }

    #[test]
    fn round_trip_across_word_boundaries() {
        // 13-bit values guarantee straddling
        let mut rng = Rng::new(1);
        let vals: Vec<u32> = (0..1000).map(|_| rng.below(1 << 13) as u32).collect();
        let p = PackedInts::pack(&vals, 13);
        assert_eq!(p.unpack(), vals);
    }

    #[test]
    fn get_range_matches_get() {
        let mut rng = Rng::new(2);
        let vals: Vec<u32> = (0..257).map(|_| rng.below(32) as u32).collect();
        let p = PackedInts::pack(&vals, 5);
        let mut out = vec![0u32; 17];
        p.get_range(100, &mut out);
        assert_eq!(&out[..], &vals[100..117]);
    }

    #[test]
    fn fill_u8_matches_get() {
        let mut rng = Rng::new(5);
        let vals: Vec<u32> = (0..301).map(|_| rng.below(8) as u32).collect();
        let p = PackedInts::pack(&vals, 3);
        let mut out = vec![0u8; 40];
        p.reader(77).fill_u8(&mut out);
        for (j, &b) in out.iter().enumerate() {
            assert_eq!(u32::from(b), vals[77 + j]);
        }
    }

    #[test]
    fn payload_bytes_rounds_to_words() {
        let p = PackedInts::pack(&[1, 2, 3], 3); // 9 bits -> 1 word
        assert_eq!(p.payload_bytes(), 8);
    }

    #[test]
    fn empty_pack() {
        let p = PackedInts::pack(&[], 7);
        assert_eq!(p.len, 0);
        assert!(p.unpack().is_empty());
    }

    #[test]
    fn from_raw_owned_round_trips() {
        let vals: Vec<u32> = (0..200).map(|i| i % 32).collect();
        let p = PackedInts::pack(&vals, 5);
        let rebuilt = PackedInts::from_raw(5, vals.len(), PackedBytes::Owned(p.words().to_vec()));
        assert_eq!(rebuilt, p);
        assert!(!rebuilt.is_mapped());
        assert_eq!(rebuilt.unpack(), vals);
    }

    #[test]
    #[should_panic(expected = "words")]
    fn from_raw_word_count_mismatch_panics() {
        let _ = PackedInts::from_raw(5, 100, PackedBytes::Owned(vec![0u64; 2]));
    }

    #[test]
    fn mapped_words_round_trip() {
        if !Mmap::supported() {
            return;
        }
        let vals: Vec<u32> = (0..513).map(|i| (i * 3) % 8).collect();
        let p = PackedInts::pack(&vals, 3);
        let mut bytes = Vec::new();
        for w in p.words() {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        let path = std::env::temp_dir().join("rwkvq_packed_mapped_test.bin");
        std::fs::write(&path, &bytes).unwrap();
        let map = Arc::new(Mmap::open(&path).unwrap());
        let mapped = PackedInts::from_raw(
            3,
            vals.len(),
            PackedBytes::Mapped(MappedWords::new(map, 0, p.words().len())),
        );
        assert!(mapped.is_mapped());
        assert_eq!(mapped, p);
        assert_eq!(mapped.unpack(), vals);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn all_bit_widths_round_trip() {
        let mut rng = Rng::new(3);
        for bits in 1..=20u32 {
            let lim = 1u64 << bits;
            let vals: Vec<u32> =
                (0..131).map(|_| (rng.next_u64() % lim) as u32).collect();
            let p = PackedInts::pack(&vals, bits);
            assert_eq!(p.unpack(), vals, "bits={bits}");
        }
    }
}
