//! The Eq. 18 hybrid selector and the method dispatcher.
//!
//! `φ_m = 1` (SQ) iff `P_c < τ_c` **and** `P_f < τ_f`; otherwise VQ.
//! Following §4.1, the thresholds are auto-calibrated per model so the
//! SQ share of quantized layers hits a target fraction (nine-tenths by
//! default), with SQ run at 3.25 bpw (GPTQ, group 64) and VQ at 3.5 bpw
//! (GPTVQ, k=13) — averaging to the paper's 3.275 bpw.

use crate::config::{Method, QuantConfig};
use crate::quant::proxy::{self, ProxyPair};
use crate::quant::{sq, vq, CalibData, LayerKind, QuantizedLayer};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// The per-layer decision of Eq. 18.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Choice {
    Sq,
    Vq,
}

/// Eq. 18: SQ only when both proxies are below their thresholds.
pub fn decide(p: ProxyPair, tau_c: f64, tau_f: f64) -> Choice {
    if p.p_c < tau_c && p.p_f < tau_f {
        Choice::Sq
    } else {
        Choice::Vq
    }
}

/// Calibrated thresholds plus the realised SQ share.
#[derive(Debug, Clone, Copy)]
pub struct TauCalibration {
    pub tau_c: f64,
    pub tau_f: f64,
    pub sq_share: f64,
}

/// Auto-calibrate `(τ_c, τ_f)` on a model's proxy population so that the
/// SQ share approaches `sq_fraction` (§4.1: "dynamically set τ_c and τ_f
/// according to different models, ensuring that SQ ... is used in
/// nine-tenths of the layers").
///
/// Procedure: the VQ budget `B = round((1-f)·M)` is filled first by the
/// highest-`P_c` layers (globally non-uniform), then — among the
/// remainder — by the highest-`P_f` layers (uniform with local
/// outliers). τ_c and τ_f are placed at the midpoints of the resulting
/// cut so `decide` reproduces the assignment exactly.
pub fn calibrate_taus(proxies: &[ProxyPair], sq_fraction: f64) -> TauCalibration {
    let m = proxies.len();
    assert!(m > 0);
    let budget = (((1.0 - sq_fraction) * m as f64).round() as usize).min(m);
    if budget == 0 {
        return TauCalibration { tau_c: f64::INFINITY, tau_f: f64::INFINITY, sq_share: 1.0 };
    }

    // Phase 1: half the budget (rounded up) to the most non-uniform layers.
    let by_pc_budget = budget.div_ceil(2);
    let mut order_pc: Vec<usize> = (0..m).collect();
    order_pc.sort_by(|&a, &b| proxies[b].p_c.partial_cmp(&proxies[a].p_c).unwrap());
    let pc_cut = order_pc[by_pc_budget - 1];
    let tau_c = if by_pc_budget < m {
        0.5 * (proxies[pc_cut].p_c + proxies[order_pc[by_pc_budget]].p_c)
    } else {
        0.0
    };

    // Phase 2: the rest of the budget by P_f among layers below τ_c.
    let mut below: Vec<usize> = (0..m).filter(|&i| proxies[i].p_c < tau_c).collect();
    below.sort_by(|&a, &b| proxies[b].p_f.partial_cmp(&proxies[a].p_f).unwrap());
    let pf_budget = budget - by_pc_budget;
    let tau_f = if pf_budget == 0 || below.is_empty() {
        f64::INFINITY
    } else {
        let take = pf_budget.min(below.len());
        let lastin = proxies[below[take - 1]].p_f;
        let firstout = below.get(take).map(|&i| proxies[i].p_f).unwrap_or(0.0);
        0.5 * (lastin + firstout)
    };

    let sq_count = proxies
        .iter()
        .filter(|&&p| decide(p, tau_c, tau_f) == Choice::Sq)
        .count();
    TauCalibration { tau_c, tau_f, sq_share: sq_count as f64 / m as f64 }
}

/// Quantize one layer with the chosen baseline `method` or the hybrid
/// (when `method == Method::RwkvQuant` the caller resolves the proxy
/// decision first and passes the resulting `choice`).
pub fn quantize_with_method(
    w: &Matrix,
    kind: LayerKind,
    method: Method,
    calib: Option<&CalibData>,
    cfg: &QuantConfig,
    rng: &mut Rng,
) -> QuantizedLayer {
    match method {
        Method::Rtn => QuantizedLayer::Sq(sq::rtn::quantize(w, cfg.sq_bits, cfg.group_size)),
        Method::Gptq => QuantizedLayer::Sq(sq::gptq::quantize(
            w,
            cfg.sq_bits,
            cfg.group_size,
            calib,
            cfg.percdamp,
        )),
        Method::Awq => {
            QuantizedLayer::Sq(sq::awq::quantize(w, cfg.sq_bits, cfg.group_size, calib))
        }
        Method::QuaRot => {
            QuantizedLayer::Sq(sq::quarot::quantize(w, cfg.sq_bits, cfg.group_size, cfg.seed))
        }
        Method::KMeans => QuantizedLayer::Vq(vq::kmeans::quantize(
            w,
            cfg.vq_bits,
            cfg.vq_dim,
            cfg.kmeans_iters,
            rng,
        )),
        Method::Gptvq => QuantizedLayer::Vq(vq::gptvq::quantize(
            w,
            cfg.vq_bits,
            cfg.vq_dim,
            calib,
            cfg.percdamp,
            cfg.kmeans_iters,
            rng,
        )),
        Method::Vptq => QuantizedLayer::Vq(vq::vptq::quantize(
            w,
            cfg.vq_bits,
            cfg.vq_dim,
            calib,
            cfg.kmeans_iters,
            rng,
        )),
        Method::RwkvQuant => {
            // resolved by `quantize_hybrid`; direct call treats it as one
            // layer and applies Eq. 18 with configured/default thresholds
            let p = proxy::compute(&w.data, cfg.proxy_order);
            let tau_c = cfg.tau_c.unwrap_or(1.5);
            let tau_f = cfg.tau_f.unwrap_or(30.0);
            quantize_hybrid(w, kind, decide(p, tau_c, tau_f), calib, cfg, rng)
        }
    }
}

/// The hybrid's per-layer quantization given a resolved Eq. 18 choice:
/// SQ layers get GPTQ at 3.25 bpw (group 64); VQ layers get GPTVQ at
/// 3.5 bpw, with the §3.2 codebook optimisation for element-wise weights.
pub fn quantize_hybrid(
    w: &Matrix,
    kind: LayerKind,
    choice: Choice,
    calib: Option<&CalibData>,
    cfg: &QuantConfig,
    rng: &mut Rng,
) -> QuantizedLayer {
    match (choice, kind) {
        (Choice::Sq, _) => QuantizedLayer::Sq(sq::gptq::quantize(
            w,
            cfg.sq_bits,
            64, // 3.25 bpw share of the hybrid
            calib,
            cfg.percdamp,
        )),
        (Choice::Vq, LayerKind::ElementWise) if cfg.ewmul_opt => {
            QuantizedLayer::Vq(crate::quant::ewmul::quantize(w, calib, cfg, rng))
        }
        (Choice::Vq, _) => QuantizedLayer::Vq(vq::gptvq::quantize(
            w,
            cfg.vq_bits.max(13), // 3.5 bpw share
            cfg.vq_dim,
            calib,
            cfg.percdamp,
            cfg.kmeans_iters,
            rng,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pp(p_c: f64, p_f: f64) -> ProxyPair {
        ProxyPair { p_c, p_f }
    }

    #[test]
    fn eq18_truth_table() {
        // SQ only when both below threshold
        assert_eq!(decide(pp(1.0, 10.0), 1.5, 30.0), Choice::Sq);
        assert_eq!(decide(pp(1.0, 40.0), 1.5, 30.0), Choice::Vq); // outliers
        assert_eq!(decide(pp(2.0, 10.0), 1.5, 30.0), Choice::Vq); // non-uniform
        assert_eq!(decide(pp(2.0, 40.0), 1.5, 30.0), Choice::Vq);
    }

    #[test]
    fn calibration_hits_target_share() {
        let mut rng = Rng::new(1);
        let proxies: Vec<ProxyPair> = (0..200)
            .map(|_| pp(rng.gamma(2.0, 0.5), rng.gamma(2.0, 10.0)))
            .collect();
        let cal = calibrate_taus(&proxies, 0.9);
        assert!(
            (cal.sq_share - 0.9).abs() <= 0.02,
            "share={} τc={} τf={}",
            cal.sq_share,
            cal.tau_c,
            cal.tau_f
        );
    }

    #[test]
    fn calibration_all_sq_when_fraction_one() {
        let proxies = vec![pp(0.1, 1.0); 10];
        let cal = calibrate_taus(&proxies, 1.0);
        assert_eq!(cal.sq_share, 1.0);
    }

    #[test]
    fn calibration_reproducible_by_decide() {
        let mut rng = Rng::new(2);
        let proxies: Vec<ProxyPair> = (0..97)
            .map(|_| pp(rng.gamma(1.5, 1.0), rng.gamma(1.5, 20.0)))
            .collect();
        let cal = calibrate_taus(&proxies, 0.8);
        let share = proxies
            .iter()
            .filter(|&&p| decide(p, cal.tau_c, cal.tau_f) == Choice::Sq)
            .count() as f64
            / proxies.len() as f64;
        assert!((share - cal.sq_share).abs() < 1e-12);
    }

    #[test]
    fn hybrid_bpw_mix_is_about_3275() {
        // 9 SQ layers at 3.25 + 1 VQ at ~3.5 averages near 3.275
        let mut rng = Rng::new(3);
        let mut w = Matrix::zeros(64, 256);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let cfg = QuantConfig::default();
        let sq = quantize_hybrid(&w, LayerKind::MatMul, Choice::Sq, None, &cfg, &mut rng);
        let vqq = quantize_hybrid(&w, LayerKind::MatMul, Choice::Vq, None, &cfg, &mut rng);
        assert!((sq.bpw() - 3.25).abs() < 1e-6, "sq bpw {}", sq.bpw());
        assert!(vqq.bpw() >= 2.9 && vqq.bpw() < 4.3, "vq bpw {}", vqq.bpw());
        let avg = 0.9 * sq.bpw() + 0.1 * vqq.bpw();
        assert!(avg < 3.45, "hybrid avg {avg}");
    }

    #[test]
    fn dispatcher_covers_all_methods() {
        let mut rng = Rng::new(4);
        let mut w = Matrix::zeros(16, 64);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let cfg = QuantConfig { kmeans_iters: 5, ..QuantConfig::default() };
        for &m in Method::all_baselines() {
            let q = quantize_with_method(&w, LayerKind::MatMul, m, None, &cfg, &mut rng);
            assert!(q.dequantize().data.iter().all(|v| v.is_finite()), "{m:?}");
            assert_eq!(q.is_vq(), m.is_vq(), "{m:?}");
        }
    }
}
