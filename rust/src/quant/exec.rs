//! Quantized execution: the [`LinearOp`] abstraction and matvec kernels
//! that run directly on packed quantized weights.
//!
//! The deployment payoff of the paper (Table 4): RWKV decode is
//! memory-bound (Fig. 9), so reading 3-ish bits per weight instead of 32
//! converts directly into decode speed. These routines stream the packed
//! payload group-by-group, dequantize into a small stack buffer and
//! accumulate the dot product — never materialising the fp matrix
//! (llama.cpp-style).
//!
//! # The `LinearOp` contract
//!
//! Every weight that participates in the forward pass as a matmul is
//! served through [`LinearOp`] (mistralrs-quant's `QuantMethod` shape):
//!
//! * `matvec(x, y)` computes `y = W x` for `x.len() == cols()` and
//!   `y.len() == rows()`, without materialising a dense `W`.
//! * `storage_bits()` is the weight's storage footprint *as served* —
//!   the quantity the memory-bound decode model trades for speed.
//! * `flops_per_token()` is `2·rows·cols` plus any non-fusable
//!   per-activation overhead the method forces (AWQ's `1/s` multiply,
//!   QuaRot's rotations — the paper's §1 overhead argument).
//!
//! Implementations: dense [`Matrix`] (fp32 reference), [`SqLayer`]
//! (scalar grids, including AWQ's folded column scales), [`VqLayer`]
//! (codebook gather), and the [`QuantizedLayer`] dispatcher. The serving
//! stack ([`crate::model::qmodel::QuantizedModel`] → `RwkvRunner` →
//! `coordinator::serve`) consumes only this trait, so fp32, SQ, VQ and
//! hybrid checkpoints all run the identical forward-pass code.

use super::{QuantizedLayer, SqLayer, VqLayer};
use crate::tensor::{linalg, Matrix};

/// A weight served as a linear operator `y = W x`. See the module docs
/// for the contract.
pub trait LinearOp: Send + Sync {
    /// `y = W x`; `x.len()` must equal [`LinearOp::cols`], `y.len()`
    /// must equal [`LinearOp::rows`].
    fn matvec(&self, x: &[f32], y: &mut [f32]);
    /// Output dimension.
    fn rows(&self) -> usize;
    /// Input dimension.
    fn cols(&self) -> usize;
    /// Storage footprint in bits as served (packed codes + metadata for
    /// quantized layers, 32 bits/weight for dense fp32).
    fn storage_bits(&self) -> usize;
    /// FLOPs one decoded token pays through this op.
    fn flops_per_token(&self) -> u64;
}

impl LinearOp for Matrix {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        linalg::matvec_into(self, x, y);
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn storage_bits(&self) -> usize {
        self.numel() * 32
    }

    fn flops_per_token(&self) -> u64 {
        2 * self.numel() as u64
    }
}

impl LinearOp for SqLayer {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        matvec_sq(self, x, y);
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn storage_bits(&self) -> usize {
        SqLayer::storage_bits(self)
    }

    fn flops_per_token(&self) -> u64 {
        2 * self.numel() as u64 + self.extra_flops_per_token
    }
}

impl LinearOp for VqLayer {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        matvec_vq(self, x, y);
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn storage_bits(&self) -> usize {
        VqLayer::storage_bits(self)
    }

    fn flops_per_token(&self) -> u64 {
        2 * self.numel() as u64
    }
}

impl LinearOp for QuantizedLayer {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        matvec(self, x, y);
    }

    fn rows(&self) -> usize {
        match self {
            QuantizedLayer::Sq(l) => l.rows,
            QuantizedLayer::Vq(l) => l.rows,
            QuantizedLayer::Fp16 { rows, .. } => *rows,
        }
    }

    fn cols(&self) -> usize {
        match self {
            QuantizedLayer::Sq(l) => l.cols,
            QuantizedLayer::Vq(l) => l.cols,
            QuantizedLayer::Fp16 { cols, .. } => *cols,
        }
    }

    fn storage_bits(&self) -> usize {
        QuantizedLayer::storage_bits(self)
    }

    fn flops_per_token(&self) -> u64 {
        match self {
            QuantizedLayer::Sq(l) => LinearOp::flops_per_token(l),
            QuantizedLayer::Vq(l) => LinearOp::flops_per_token(l),
            QuantizedLayer::Fp16 { rows, cols, .. } => 2 * (rows * cols) as u64,
        }
    }
}

thread_local! {
    /// Scratch for the AWQ folded-scale input (hot path: one serve loop
    /// per thread, so a thread-local avoids a per-call allocation).
    static SCALED_X: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
    /// Scratch for the unpacked per-row codes of the aligned fast path.
    static CODES_ROW: std::cell::RefCell<Vec<u8>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// y = W x for an SQ layer, streaming packed codes.
///
/// AWQ layers (`col_inv_scale = Some`) are handled by folding the
/// per-column inverse scale into `x` once per call:
/// `Ŵ = Q(W·diag(s))·diag(1/s)` ⇒ `Ŵx = Q(W·diag(s)) · (x ⊙ 1/s)`.
/// QuaRot rotations cannot be fused this way (they mix columns) and
/// must go through `dequantize()`.
pub fn matvec_sq(l: &SqLayer, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), l.cols);
    assert_eq!(y.len(), l.rows);
    assert!(
        l.rotation.is_none(),
        "fused matvec cannot undo a QuaRot rotation — dequantize instead"
    );
    match &l.col_inv_scale {
        Some(inv) => SCALED_X.with(|scratch| {
            let mut scaled = scratch.borrow_mut();
            scaled.clear();
            scaled.extend(x.iter().zip(inv).map(|(&xv, &s)| xv * s));
            matvec_sq_plain(l, &scaled, y);
        }),
        None => matvec_sq_plain(l, x, y),
    }
}

/// The plain-grid kernel body (`x` already in the quantized basis).
fn matvec_sq_plain(l: &SqLayer, x: &[f32], y: &mut [f32]) {
    CODES_ROW.with(|scratch| {
        let mut codes_row = scratch.borrow_mut();
        codes_row.clear();
        codes_row.resize(l.cols, 0);
        matvec_sq_body(l, x, y, &mut codes_row);
    });
}

fn matvec_sq_body(l: &SqLayer, x: &[f32], y: &mut [f32], codes_row: &mut [u8]) {
    let group = l.group_size;
    // Pre-compute group-wise Σx once: Σ_g (m_g + s_g·q)·x = m_g·Σx_g + s_g·Σ q·x.
    // Row-major groups may straddle rows only when cols % group != 0; the
    // common serving shapes (cols multiple of 32/64) take the fast path.
    let aligned = l.cols % group == 0;
    let groups_per_row = l.cols / group.max(1);
    for r in 0..l.rows {
        let row_base = r * l.cols;
        let mut acc = 0.0f32;
        if aligned && l.bits <= 8 {
            // pass 1: scalar bit-stream unpack into u8 (cheap, branch-free)
            let mut reader = l.codes.reader(row_base);
            for slot in codes_row.iter_mut() {
                *slot = reader.next() as u8;
            }
            // pass 2: vectorisable dequant-dot per group
            for gc in 0..groups_per_row {
                let g = r * groups_per_row + gc;
                let (s, m) = (l.scales[g], l.mins[g]);
                let cs = &codes_row[gc * group..(gc + 1) * group];
                let xs = &x[gc * group..(gc + 1) * group];
                let mut d0 = 0.0f32;
                let mut d1 = 0.0f32;
                let mut q0 = 0.0f32;
                let mut q1 = 0.0f32;
                let half = group / 2;
                for j in 0..half {
                    d0 += cs[2 * j] as f32 * xs[2 * j];
                    d1 += cs[2 * j + 1] as f32 * xs[2 * j + 1];
                    q0 += xs[2 * j];
                    q1 += xs[2 * j + 1];
                }
                if group % 2 == 1 {
                    d0 += cs[group - 1] as f32 * xs[group - 1];
                    q0 += xs[group - 1];
                }
                acc += m * (q0 + q1) + s * (d0 + d1);
            }
        } else {
            // general path: straddling groups / wide codes
            let mut reader = l.codes.reader(row_base);
            let mut c = 0usize;
            while c < l.cols {
                let flat = row_base + c;
                let g = flat / group;
                let run = group.min(l.cols - c).min(group - flat % group);
                let (s, m) = (l.scales[g], l.mins[g]);
                let xs = &x[c..c + run];
                let mut dot = 0.0f32;
                let mut qsum = 0.0f32;
                for (j, &xv) in xs.iter().enumerate().take(run) {
                    let _ = j;
                    dot += reader.next() as f32 * xv;
                    qsum += xv;
                }
                acc += m * qsum + s * dot;
                c += run;
            }
        }
        y[r] = acc;
    }
}

/// y = W x for a VQ layer, gathering codebook entries by index.
pub fn matvec_vq(l: &VqLayer, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), l.cols);
    assert_eq!(y.len(), l.rows);
    let d = l.d;
    debug_assert_eq!(l.cols % d, 0, "vectors are row-aligned by construction");
    let vecs_per_row = l.cols / d;
    for r in 0..l.rows {
        let mut acc = 0.0f32;
        let vrow = r * vecs_per_row;
        for vb in 0..vecs_per_row {
            let e = l.indices.get(vrow + vb) as usize;
            let entry = l.entry(e);
            let xs = &x[vb * d..(vb + 1) * d];
            for j in 0..d {
                acc += entry[j] * xs[j];
            }
        }
        y[r] = acc;
    }
}

/// Dispatching matvec over any quantized layer (fp16 layers fall back to
/// the dense path).
pub fn matvec(layer: &QuantizedLayer, x: &[f32], y: &mut [f32]) {
    match layer {
        QuantizedLayer::Sq(l) => matvec_sq(l, x, y),
        QuantizedLayer::Vq(l) => matvec_vq(l, x, y),
        QuantizedLayer::Fp16 { rows, cols, data } => {
            assert_eq!(x.len(), *cols);
            for r in 0..*rows {
                let row = &data[r * cols..(r + 1) * cols];
                let mut acc = 0.0f32;
                for (w, xv) in row.iter().zip(x) {
                    acc += w * xv;
                }
                y[r] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{sq, vq, CalibData};
    use crate::util::rng::Rng;

    fn rand(seed: u64, r: usize, c: usize) -> (Matrix, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(r, c);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        (w, x)
    }

    #[test]
    fn sq_matvec_matches_dequant_then_matvec() {
        let (w, x) = rand(1, 48, 96);
        let q = sq::rtn::quantize(&w, 4, 32);
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 48];
        matvec_sq(&q, &x, &mut got);
        for i in 0..48 {
            assert!((got[i] - want[i]).abs() < 1e-3, "{i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn sq_matvec_handles_group_straddling_rows() {
        // cols=24 with group=32: groups straddle row boundaries
        let (w, x) = rand(2, 10, 24);
        let q = sq::rtn::quantize(&w, 3, 32);
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 10];
        matvec_sq(&q, &x, &mut got);
        for i in 0..10 {
            assert!((got[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn sq_matvec_folds_awq_col_inv_scale() {
        let (w, x) = rand(7, 24, 64);
        let mut calib_x = Matrix::zeros(64, 64);
        let mut rng = Rng::new(8);
        rng.fill_normal(&mut calib_x.data, 0.0, 1.0);
        for r in 0..calib_x.rows {
            for c in 0..4 {
                *calib_x.at_mut(r, c) *= 10.0; // hot channels force real scales
            }
        }
        let q = sq::awq::quantize(&w, 3, 32, Some(&CalibData { x: calib_x }));
        assert!(q.col_inv_scale.is_some(), "AWQ must produce column scales");
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 24];
        matvec_sq(&q, &x, &mut got);
        for i in 0..24 {
            assert!(
                (got[i] - want[i]).abs() < 1e-3 + want[i].abs() * 1e-4,
                "{i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn vq_matvec_matches_dequant_then_matvec() {
        let (w, x) = rand(3, 32, 64);
        let q = vq::kmeans::quantize(&w, 6, 4, 8, &mut Rng::new(9));
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 32];
        matvec_vq(&q, &x, &mut got);
        for i in 0..32 {
            assert!((got[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn dispatch_covers_fp16() {
        let (w, x) = rand(4, 8, 16);
        let l = crate::quant::QuantizedLayer::Fp16 {
            rows: 8,
            cols: 16,
            data: w.data.clone(),
        };
        let want = linalg::matvec(&w, &x);
        let mut got = vec![0.0f32; 8];
        matvec(&l, &x, &mut got);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn linear_op_trait_is_consistent_across_impls() {
        let (w, x) = rand(5, 16, 64);
        let sq = sq::rtn::quantize(&w, 3, 32);
        let vq = vq::kmeans::quantize(&w, 5, 4, 6, &mut Rng::new(11));
        let cases: Vec<(&dyn LinearOp, Matrix)> =
            vec![(&w, w.clone()), (&sq, sq.dequantize()), (&vq, vq.dequantize())];
        for (op, reference) in cases {
            assert_eq!(op.rows(), 16);
            assert_eq!(op.cols(), 64);
            assert!(op.storage_bits() > 0);
            assert!(op.flops_per_token() >= 2 * 16 * 64);
            let mut y = vec![0.0f32; 16];
            op.matvec(&x, &mut y);
            // every impl must agree with its own dequantized reference
            let want = linalg::matvec(&reference, &x);
            for i in 0..16 {
                assert!((y[i] - want[i]).abs() < 1e-3, "{i}: {} vs {}", y[i], want[i]);
            }
        }
        // dense storage is 32 bits/weight; packed is far smaller
        assert_eq!(LinearOp::storage_bits(&w), 16 * 64 * 32);
        assert!(LinearOp::storage_bits(&sq) < 16 * 64 * 8);
    }
}
