//! Quantized execution: the [`LinearOp`] abstraction and matvec kernels
//! that run directly on packed quantized weights.
//!
//! The deployment payoff of the paper (Table 4): RWKV decode is
//! memory-bound (Fig. 9), so reading 3-ish bits per weight instead of 32
//! converts directly into decode speed. These routines stream the packed
//! payload group-by-group, dequantize into a small stack buffer and
//! accumulate the dot product — never materialising the fp matrix
//! (llama.cpp-style).
//!
//! # The `LinearOp` contract
//!
//! Every weight that participates in the forward pass as a matmul is
//! served through [`LinearOp`] (mistralrs-quant's `QuantMethod` shape):
//!
//! * `matvec(x, y)` computes `y = W x` for `x.len() == cols()` and
//!   `y.len() == rows()`, without materialising a dense `W`.
//! * `storage_bits()` is the weight's storage footprint *as served* —
//!   the quantity the memory-bound decode model trades for speed.
//! * `flops_per_token()` is `2·rows·cols` plus any non-fusable
//!   per-activation overhead the method forces (AWQ's `1/s` multiply,
//!   QuaRot's rotations — the paper's §1 overhead argument).
//!
//! Implementations: dense [`Matrix`] (fp32 reference), [`SqLayer`]
//! (scalar grids, including AWQ's folded column scales), [`VqLayer`]
//! (codebook gather), and the [`QuantizedLayer`] dispatcher. The serving
//! stack ([`crate::model::qmodel::QuantizedModel`] → `RwkvRunner` →
//! `coordinator::serve`) consumes only this trait, so fp32, SQ, VQ and
//! hybrid checkpoints all run the identical forward-pass code.

use super::{QuantizedLayer, SqLayer, VqLayer};
use crate::tensor::f16::{f16_to_f32, F16Tensor};
use crate::tensor::{linalg, Matrix};
use std::sync::OnceLock;

/// Instruction-set specialisation of the packed decode kernels.
///
/// Detected once at startup ([`active_kernel`]) and threaded through
/// every matvec; the scalar code stays as the portable fallback and the
/// correctness reference (`prop_kernels` asserts SIMD ≡ scalar). A
/// variant that the host cannot run falls back to scalar at dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable fallback: auto-vectorisable two-pass scalar loops.
    Scalar,
    /// x86-64 AVX2+FMA: fused 8-lane unpack-widen-FMA dot.
    Avx2,
    /// AArch64 NEON: fused 4-lane widen-FMA dot.
    Neon,
}

impl Kernel {
    /// Runtime feature detection. AVX2 alone is not enough for the
    /// fused path — the kernels use FMA, so both must be present.
    pub fn detect() -> Kernel {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                return Kernel::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Scalar
    }

    /// Every kernel this host can run: scalar plus the detected SIMD
    /// variant, if any. The equivalence tests and the scalar-vs-SIMD
    /// bench sections iterate over this.
    pub fn available() -> Vec<Kernel> {
        let detected = Kernel::detect();
        if detected == Kernel::Scalar {
            vec![Kernel::Scalar]
        } else {
            vec![Kernel::Scalar, detected]
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Dense index into the [`kstats`] attribution grid.
    fn index(self) -> usize {
        match self {
            Kernel::Scalar => 0,
            Kernel::Avx2 => 1,
            Kernel::Neon => 2,
        }
    }
}

/// Per-kernel matvec attribution: call counts and accumulated wall time
/// split by payload kind (SQ / VQ / dense f16) × instruction set
/// (scalar / AVX2 / NEON). This is the measured answer to "where does
/// decode time go per quantization kind" — the CPU baseline the
/// accelerator backend will be judged against — surfaced as
/// `rwkvquant_kernel_matvec_*` Prometheus families and in the serve
/// summary.
///
/// Process-global (the kernels are free functions with no registry to
/// hang state on) and **gated**: while disabled — the default — every
/// matvec pays exactly one relaxed atomic load and no clock read, so
/// the counters can ship enabled-in-production without a fast-path tax
/// (`perf_hotpath` measures both states). Enabling is monotonic
/// counting only; it cannot change tokens.
pub mod kstats {
    use super::Kernel;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::time::Instant;

    /// Payload-kind axis of the grid, in index order.
    pub const OPS: [&str; 3] = ["sq", "vq", "f16"];
    /// Instruction-set axis of the grid, in index order
    /// ([`Kernel::name`] spellings).
    pub const KERNELS: [&str; 3] = ["scalar", "avx2", "neon"];

    /// Which matvec family a sample attributes to.
    #[derive(Clone, Copy, Debug)]
    pub enum Op {
        Sq = 0,
        Vq = 1,
        F16 = 2,
    }

    static ENABLED: AtomicBool = AtomicBool::new(false);

    const fn row() -> [AtomicU64; 3] {
        [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)]
    }
    /// `[op][kernel]` call counts.
    static CALLS: [[AtomicU64; 3]; 3] = [row(), row(), row()];
    /// `[op][kernel]` accumulated nanoseconds.
    static NANOS: [[AtomicU64; 3]; 3] = [row(), row(), row()];

    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// Start a sample — `None` (no clock read) while disabled.
    #[inline]
    pub(super) fn begin() -> Option<Instant> {
        enabled().then(Instant::now)
    }

    /// Land a sample started by [`begin`].
    #[inline]
    pub(super) fn finish(op: Op, kernel: Kernel, t0: Option<Instant>) {
        if let Some(t0) = t0 {
            let (o, k) = (op as usize, kernel.index());
            CALLS[o][k].fetch_add(1, Ordering::Relaxed);
            NANOS[o][k].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// The full grid as `(op, kernel, calls, seconds)` rows, zero rows
    /// included (a stable series set for the exposition).
    pub fn snapshot() -> Vec<(&'static str, &'static str, u64, f64)> {
        let mut out = Vec::with_capacity(9);
        for (o, op) in OPS.iter().enumerate() {
            for (k, kernel) in KERNELS.iter().enumerate() {
                out.push((
                    *op,
                    *kernel,
                    CALLS[o][k].load(Ordering::Relaxed),
                    NANOS[o][k].load(Ordering::Relaxed) as f64 * 1e-9,
                ));
            }
        }
        out
    }

    /// Zero the grid (tests and bench sections isolate their windows).
    pub fn reset() {
        for o in 0..3 {
            for k in 0..3 {
                CALLS[o][k].store(0, Ordering::Relaxed);
                NANOS[o][k].store(0, Ordering::Relaxed);
            }
        }
    }
}

/// The kernel the serving stack uses, selected once (first call) by
/// runtime feature detection.
pub fn active_kernel() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(Kernel::detect)
}

/// Does the host have both AVX2 and the VCVTPH2PS half-to-float
/// conversion (F16C)? Detected separately from [`Kernel::detect`]: F16C
/// is a distinct CPUID bit from AVX2+FMA, so an [`Kernel::Avx2`] host
/// without it still runs the packed kernels and only the f16 widen
/// falls back to scalar. AVX2 is re-checked here (not assumed from the
/// kernel value) because [`widen_f16_into`] is a safe public fn whose
/// callers may pass any [`Kernel`] — the dispatch guard, not the
/// caller, carries the whole target-feature precondition.
#[cfg(target_arch = "x86_64")]
fn f16c_available() -> bool {
    static F16C: OnceLock<bool> = OnceLock::new();
    *F16C.get_or_init(|| {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("f16c")
    })
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 0b01));
        _mm_cvtss_f32(s)
    }

    /// Σ cs[j]·xs[j]: 8 byte-wide codes widened to f32 per FMA step.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA support (see
    /// [`super::Kernel::detect`]); `cs` and `xs` must be equally long.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_codes(cs: &[u8], xs: &[f32]) -> f32 {
        debug_assert_eq!(cs.len(), xs.len());
        let n = cs.len();
        let mut acc = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 8 <= n {
            let raw = _mm_loadl_epi64(cs.as_ptr().add(j) as *const __m128i);
            let cf = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(raw));
            let xv = _mm256_loadu_ps(xs.as_ptr().add(j));
            acc = _mm256_fmadd_ps(cf, xv, acc);
            j += 8;
        }
        let mut dot = hsum(acc);
        while j < n {
            dot += f32::from(*cs.get_unchecked(j)) * *xs.get_unchecked(j);
            j += 1;
        }
        dot
    }

    /// Σ a[j]·b[j] over f32 slices (the VQ gathered-row accumulate).
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA support; `a` and `b` must be
    /// equally long.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 8 <= n {
            let av = _mm256_loadu_ps(a.as_ptr().add(j));
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            acc = _mm256_fmadd_ps(av, bv, acc);
            j += 8;
        }
        let mut dot = hsum(acc);
        while j < n {
            dot += *a.get_unchecked(j) * *b.get_unchecked(j);
            j += 1;
        }
        dot
    }

    /// Widen binary16 bits to f32, 8 lanes per VCVTPH2PS.
    ///
    /// # Safety
    /// Caller must have verified AVX2+F16C support (see
    /// [`super::f16c_available`]); `bits` and `out` must be equally long.
    #[target_feature(enable = "avx2,f16c")]
    pub unsafe fn widen_f16(bits: &[u16], out: &mut [f32]) {
        debug_assert_eq!(bits.len(), out.len());
        let n = bits.len();
        let mut j = 0usize;
        while j + 8 <= n {
            let h = _mm_loadu_si128(bits.as_ptr().add(j) as *const __m128i);
            _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_cvtph_ps(h));
            j += 8;
        }
        while j < n {
            *out.get_unchecked_mut(j) =
                crate::tensor::f16::f16_to_f32(*bits.get_unchecked(j));
            j += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Σ cs[j]·xs[j]: 8 byte-wide codes widened u8→u16→u32→f32, two
    /// 4-lane FMAs per step.
    ///
    /// # Safety
    /// Caller must have verified NEON support (see
    /// [`super::Kernel::detect`]); `cs` and `xs` must be equally long.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_codes(cs: &[u8], xs: &[f32]) -> f32 {
        debug_assert_eq!(cs.len(), xs.len());
        let n = cs.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut j = 0usize;
        while j + 8 <= n {
            let c16 = vmovl_u8(vld1_u8(cs.as_ptr().add(j)));
            let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(c16)));
            let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(c16)));
            acc = vfmaq_f32(acc, lo, vld1q_f32(xs.as_ptr().add(j)));
            acc = vfmaq_f32(acc, hi, vld1q_f32(xs.as_ptr().add(j + 4)));
            j += 8;
        }
        let mut dot = vaddvq_f32(acc);
        while j < n {
            dot += f32::from(*cs.get_unchecked(j)) * *xs.get_unchecked(j);
            j += 1;
        }
        dot
    }

    /// Σ a[j]·b[j] over f32 slices (the VQ gathered-row accumulate).
    ///
    /// # Safety
    /// Caller must have verified NEON support; `a` and `b` must be
    /// equally long.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut j = 0usize;
        while j + 4 <= n {
            let av = vld1q_f32(a.as_ptr().add(j));
            let bv = vld1q_f32(b.as_ptr().add(j));
            acc = vfmaq_f32(acc, av, bv);
            j += 4;
        }
        let mut dot = vaddvq_f32(acc);
        while j < n {
            dot += *a.get_unchecked(j) * *b.get_unchecked(j);
            j += 1;
        }
        dot
    }

    /// Widen binary16 bits to f32, 4 lanes per step.
    ///
    /// The stable NEON surface has no f16 vector types, so this is the
    /// branch-free integer widen done in lanes: shift the sign/exponent/
    /// mantissa into f32 position, rebias the exponent, then fix the two
    /// special exponent classes by compare-select — Inf/NaN get the
    /// remaining exponent distance, subnormals are renormalised by one
    /// exact float subtraction. Bit-exact against the scalar
    /// [`crate::tensor::f16::f16_to_f32`] for every non-NaN pattern
    /// (NaNs stay NaN; the scalar reference canonicalises the quiet bit,
    /// this path preserves the payload — both are NaN).
    ///
    /// # Safety
    /// Caller must have verified NEON support; `bits` and `out` must be
    /// equally long.
    #[target_feature(enable = "neon")]
    pub unsafe fn widen_f16(bits: &[u16], out: &mut [f32]) {
        debug_assert_eq!(bits.len(), out.len());
        let n = bits.len();
        let shifted_exp = vdupq_n_u32(0x7c00 << 13);
        let exp_adjust = vdupq_n_u32((127 - 15) << 23);
        let inf_adjust = vdupq_n_u32((128 - 16) << 23);
        let one_exp = vdupq_n_u32(1 << 23);
        // 2^-14: subtracting it renormalises a shifted f16 subnormal
        let sub_magic = vreinterpretq_f32_u32(vdupq_n_u32(113 << 23));
        let mut j = 0usize;
        while j + 4 <= n {
            let h = vmovl_u16(vld1_u16(bits.as_ptr().add(j)));
            let sign = vshlq_n_u32::<16>(vandq_u32(h, vdupq_n_u32(0x8000)));
            let om = vshlq_n_u32::<13>(vandq_u32(h, vdupq_n_u32(0x7fff)));
            let exp = vandq_u32(om, shifted_exp);
            let adjusted = vaddq_u32(om, exp_adjust);
            let inf_fixed = vaddq_u32(adjusted, inf_adjust);
            let sub_bits = vaddq_u32(adjusted, one_exp);
            let sub_fixed = vreinterpretq_u32_f32(vsubq_f32(
                vreinterpretq_f32_u32(sub_bits),
                sub_magic,
            ));
            let o = vbslq_u32(vceqq_u32(exp, shifted_exp), inf_fixed, adjusted);
            let o = vbslq_u32(vceqq_u32(exp, vdupq_n_u32(0)), sub_fixed, o);
            let o = vorrq_u32(o, sign);
            vst1q_f32(out.as_mut_ptr().add(j), vreinterpretq_f32_u32(o));
            j += 4;
        }
        while j < n {
            *out.get_unchecked_mut(j) =
                crate::tensor::f16::f16_to_f32(*bits.get_unchecked(j));
            j += 1;
        }
    }
}

/// Two-way-unrolled scalar code·x dot (written to auto-vectorise).
fn dot_codes_scalar(cs: &[u8], xs: &[f32]) -> f32 {
    let n = cs.len();
    let half = n / 2;
    let mut d0 = 0.0f32;
    let mut d1 = 0.0f32;
    for j in 0..half {
        d0 += f32::from(cs[2 * j]) * xs[2 * j];
        d1 += f32::from(cs[2 * j + 1]) * xs[2 * j + 1];
    }
    if n % 2 == 1 {
        d0 += f32::from(cs[n - 1]) * xs[n - 1];
    }
    d0 + d1
}

fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&av, &bv)| av * bv).sum()
}

/// Dispatch Σ cs[j]·xs[j] to the requested kernel (unsupported-on-host
/// variants fall back to scalar).
#[inline]
fn dot_codes(kernel: Kernel, cs: &[u8], xs: &[f32]) -> f32 {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only handed out by detect() on AVX2+FMA hosts.
        Kernel::Avx2 => unsafe { avx2::dot_codes(cs, xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only handed out by detect() on NEON hosts.
        Kernel::Neon => unsafe { neon::dot_codes(cs, xs) },
        _ => dot_codes_scalar(cs, xs),
    }
}

/// Dispatch Σ a[j]·b[j] to the requested kernel.
#[inline]
fn dot_f32(kernel: Kernel, a: &[f32], b: &[f32]) -> f32 {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only handed out by detect() on AVX2+FMA hosts.
        Kernel::Avx2 => unsafe { avx2::dot_f32(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only handed out by detect() on NEON hosts.
        Kernel::Neon => unsafe { neon::dot_f32(a, b) },
        _ => dot_f32_scalar(a, b),
    }
}

/// Widen binary16 bits into f32, dispatched to the requested kernel.
/// The scalar [`f16_to_f32`] stays the correctness reference; every SIMD
/// path is bit-exact against it for non-NaN inputs (asserted
/// exhaustively by the tests and `prop_kernels`).
#[inline]
pub fn widen_f16_into(kernel: Kernel, bits: &[u16], out: &mut [f32]) {
    assert_eq!(bits.len(), out.len());
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only handed out by detect() on AVX2+FMA hosts,
        // and the F16C bit is checked separately right here.
        Kernel::Avx2 if f16c_available() => unsafe { avx2::widen_f16(bits, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only handed out by detect() on NEON hosts.
        Kernel::Neon => unsafe { neon::widen_f16(bits, out) },
        _ => {
            for (dst, &b) in out.iter_mut().zip(bits) {
                *dst = f16_to_f32(b);
            }
        }
    }
}

/// A weight served as a linear operator `y = W x`. See the module docs
/// for the contract.
pub trait LinearOp: Send + Sync {
    /// `y = W x`; `x.len()` must equal [`LinearOp::cols`], `y.len()`
    /// must equal [`LinearOp::rows`].
    fn matvec(&self, x: &[f32], y: &mut [f32]);
    /// Output dimension.
    fn rows(&self) -> usize;
    /// Input dimension.
    fn cols(&self) -> usize;
    /// Storage footprint in bits as served (packed codes + metadata for
    /// quantized layers, 32 bits/weight for dense fp32).
    fn storage_bits(&self) -> usize;
    /// FLOPs one decoded token pays through this op.
    fn flops_per_token(&self) -> u64;
}

impl LinearOp for Matrix {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        linalg::matvec_into(self, x, y);
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn storage_bits(&self) -> usize {
        self.numel() * 32
    }

    fn flops_per_token(&self) -> u64 {
        2 * self.numel() as u64
    }
}

impl LinearOp for SqLayer {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        matvec_sq(self, x, y);
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn storage_bits(&self) -> usize {
        SqLayer::storage_bits(self)
    }

    fn flops_per_token(&self) -> u64 {
        2 * self.numel() as u64 + self.extra_flops_per_token
    }
}

impl LinearOp for VqLayer {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        matvec_vq(self, x, y);
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn storage_bits(&self) -> usize {
        VqLayer::storage_bits(self)
    }

    fn flops_per_token(&self) -> u64 {
        2 * self.numel() as u64
    }
}

impl LinearOp for QuantizedLayer {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        matvec(self, x, y);
    }

    fn rows(&self) -> usize {
        match self {
            QuantizedLayer::Sq(l) => l.rows,
            QuantizedLayer::Vq(l) => l.rows,
            QuantizedLayer::Fp16 { rows, .. } => *rows,
        }
    }

    fn cols(&self) -> usize {
        match self {
            QuantizedLayer::Sq(l) => l.cols,
            QuantizedLayer::Vq(l) => l.cols,
            QuantizedLayer::Fp16 { cols, .. } => *cols,
        }
    }

    fn storage_bits(&self) -> usize {
        QuantizedLayer::storage_bits(self)
    }

    fn flops_per_token(&self) -> u64 {
        match self {
            QuantizedLayer::Sq(l) => LinearOp::flops_per_token(l),
            QuantizedLayer::Vq(l) => LinearOp::flops_per_token(l),
            QuantizedLayer::Fp16 { rows, cols, .. } => 2 * (rows * cols) as u64,
        }
    }
}

/// Reusable scratch for the packed matvec kernels: one allocation set
/// per owner, reused across calls. The serve tick workers each own one
/// for the life of the pool (via this module's thread-local — persistent
/// worker threads keep it warm across ticks), and benches/tests can pass
/// an explicit instance through the `*_scratch` entry points to control
/// reuse precisely.
#[derive(Debug)]
pub struct MatvecScratch {
    /// AWQ folded-scale input (`x ⊙ 1/s`).
    pub scaled_x: Vec<f32>,
    /// Unpacked per-row codes of the aligned SQ fast path.
    pub codes_row: Vec<u8>,
    /// Row-invariant per-group Σx of the aligned SQ path.
    pub group_xsum: Vec<f32>,
    /// Gathered codebook row of the VQ kernel.
    pub vq_row: Vec<f32>,
    /// Widened row of the f16 dense matvec.
    pub f16_row: Vec<f32>,
}

impl MatvecScratch {
    pub const fn new() -> MatvecScratch {
        MatvecScratch {
            scaled_x: Vec::new(),
            codes_row: Vec::new(),
            group_xsum: Vec::new(),
            vq_row: Vec::new(),
            f16_row: Vec::new(),
        }
    }
}

impl Default for MatvecScratch {
    fn default() -> Self {
        MatvecScratch::new()
    }
}

thread_local! {
    /// Per-thread scratch behind the implicit matvec entry points. The
    /// hot path is one long-lived serve worker per thread (the tick pool
    /// keeps its threads across ticks precisely so this stays warm), so
    /// a thread-local avoids a per-call allocation.
    static SCRATCH: std::cell::RefCell<MatvecScratch> =
        const { std::cell::RefCell::new(MatvecScratch::new()) };
}

/// y = W x for an SQ layer, streaming packed codes with the
/// startup-detected kernel.
///
/// AWQ layers (`col_inv_scale = Some`) are handled by folding the
/// per-column inverse scale into `x` once per call:
/// `Ŵ = Q(W·diag(s))·diag(1/s)` ⇒ `Ŵx = Q(W·diag(s)) · (x ⊙ 1/s)`.
/// QuaRot rotations cannot be fused this way (they mix columns) and
/// must go through `dequantize()`.
pub fn matvec_sq(l: &SqLayer, x: &[f32], y: &mut [f32]) {
    matvec_sq_with(active_kernel(), l, x, y);
}

/// [`matvec_sq`] with an explicit kernel — the benches and the
/// SIMD-vs-scalar equivalence tests pick the variant themselves. Uses
/// the calling thread's scratch.
pub fn matvec_sq_with(kernel: Kernel, l: &SqLayer, x: &[f32], y: &mut [f32]) {
    SCRATCH.with(|s| matvec_sq_scratch(kernel, l, x, y, &mut s.borrow_mut()));
}

/// [`matvec_sq`] with an explicit kernel *and* caller-owned scratch —
/// the fully explicit form the tick pool workers and benches build on.
pub fn matvec_sq_scratch(
    kernel: Kernel,
    l: &SqLayer,
    x: &[f32],
    y: &mut [f32],
    scratch: &mut MatvecScratch,
) {
    let kt = kstats::begin();
    assert_eq!(x.len(), l.cols);
    assert_eq!(y.len(), l.rows);
    assert!(
        l.rotation.is_none(),
        "fused matvec cannot undo a QuaRot rotation — dequantize instead"
    );
    let MatvecScratch { scaled_x, codes_row, group_xsum, .. } = scratch;
    let x_eff: &[f32] = match &l.col_inv_scale {
        Some(inv) => {
            scaled_x.clear();
            scaled_x.extend(x.iter().zip(inv).map(|(&xv, &s)| xv * s));
            scaled_x
        }
        None => x,
    };
    codes_row.clear();
    codes_row.resize(l.cols, 0);
    matvec_sq_body(kernel, l, x_eff, y, codes_row, group_xsum);
    kstats::finish(kstats::Op::Sq, kernel, kt);
}

fn matvec_sq_body(
    kernel: Kernel,
    l: &SqLayer,
    x: &[f32],
    y: &mut [f32],
    codes_row: &mut [u8],
    xsum: &mut Vec<f32>,
) {
    let group = l.group_size;
    // Group-wise identity: Σ_g (m_g + s_g·q)·x = m_g·Σx_g + s_g·Σ q·x.
    // Row-major groups may straddle rows only when cols % group != 0; the
    // common serving shapes (cols multiple of 32/64) take the fast path.
    let aligned = l.cols % group == 0;
    if aligned && l.bits <= 8 {
        let groups_per_row = l.cols / group;
        // the per-group Σx is row-invariant — hoist it out of the row loop
        xsum.clear();
        xsum.extend(
            (0..groups_per_row).map(|gc| x[gc * group..(gc + 1) * group].iter().sum::<f32>()),
        );
        for r in 0..l.rows {
            // pass 1: bit-stream unpack into u8 (cheap, branch-free)
            l.codes.reader(r * l.cols).fill_u8(codes_row);
            // pass 2: per-group fused dequant-dot, SIMD where available
            let mut acc = 0.0f32;
            for gc in 0..groups_per_row {
                let g = r * groups_per_row + gc;
                let cs = &codes_row[gc * group..(gc + 1) * group];
                let xs = &x[gc * group..(gc + 1) * group];
                acc += l.mins[g] * xsum[gc] + l.scales[g] * dot_codes(kernel, cs, xs);
            }
            y[r] = acc;
        }
    } else {
        // general path: straddling groups / wide codes
        for r in 0..l.rows {
            let row_base = r * l.cols;
            let mut reader = l.codes.reader(row_base);
            let mut acc = 0.0f32;
            let mut c = 0usize;
            while c < l.cols {
                let flat = row_base + c;
                let g = flat / group;
                let run = group.min(l.cols - c).min(group - flat % group);
                let (s, m) = (l.scales[g], l.mins[g]);
                let mut dot = 0.0f32;
                let mut qsum = 0.0f32;
                for &xv in &x[c..c + run] {
                    dot += reader.next() as f32 * xv;
                    qsum += xv;
                }
                acc += m * qsum + s * dot;
                c += run;
            }
            y[r] = acc;
        }
    }
}

/// y = W x for a VQ layer with the startup-detected kernel.
pub fn matvec_vq(l: &VqLayer, x: &[f32], y: &mut [f32]) {
    matvec_vq_with(active_kernel(), l, x, y);
}

/// [`matvec_vq`] with an explicit kernel: codebook entries are gathered
/// into a contiguous row buffer, then accumulated with one full-width
/// vectorized dot (the d-sized entries are too short to feed the SIMD
/// lanes directly). Uses the calling thread's scratch.
pub fn matvec_vq_with(kernel: Kernel, l: &VqLayer, x: &[f32], y: &mut [f32]) {
    SCRATCH.with(|s| matvec_vq_scratch(kernel, l, x, y, &mut s.borrow_mut()));
}

/// [`matvec_vq`] with an explicit kernel *and* caller-owned scratch.
pub fn matvec_vq_scratch(
    kernel: Kernel,
    l: &VqLayer,
    x: &[f32],
    y: &mut [f32],
    scratch: &mut MatvecScratch,
) {
    let kt = kstats::begin();
    assert_eq!(x.len(), l.cols);
    assert_eq!(y.len(), l.rows);
    let d = l.d;
    debug_assert_eq!(l.cols % d, 0, "vectors are row-aligned by construction");
    let vecs_per_row = l.cols / d;
    let row = &mut scratch.vq_row;
    row.clear();
    row.resize(l.cols, 0.0);
    for r in 0..l.rows {
        let mut reader = l.indices.reader(r * vecs_per_row);
        for vb in 0..vecs_per_row {
            let e = reader.next() as usize;
            row[vb * d..(vb + 1) * d].copy_from_slice(l.entry(e));
        }
        y[r] = dot_f32(kernel, row, x);
    }
    kstats::finish(kstats::Op::Vq, kernel, kt);
}

/// y = W x for a half-precision dense tensor (RWKVQ2-resident
/// embeddings/heads/fallbacks): each row is widened f16→f32 into scratch
/// — through VCVTPH2PS / the NEON lane widen where the host has them —
/// then accumulated with the full-width vectorized dot, the dense twin
/// of the SQ unpack-then-dot two-pass shape. Works identically for owned
/// and mapped payloads (the mapped case faults checkpoint pages in on
/// first touch).
pub fn matvec_f16(t: &F16Tensor, x: &[f32], y: &mut [f32]) {
    matvec_f16_with(active_kernel(), t, x, y);
}

/// [`matvec_f16`] with an explicit kernel, on the calling thread's
/// scratch.
pub fn matvec_f16_with(kernel: Kernel, t: &F16Tensor, x: &[f32], y: &mut [f32]) {
    SCRATCH.with(|s| matvec_f16_scratch(kernel, t, x, y, &mut s.borrow_mut()));
}

/// [`matvec_f16`] with an explicit kernel *and* caller-owned scratch.
pub fn matvec_f16_scratch(
    kernel: Kernel,
    t: &F16Tensor,
    x: &[f32],
    y: &mut [f32],
    scratch: &mut MatvecScratch,
) {
    let kt = kstats::begin();
    assert_eq!(x.len(), t.cols);
    assert_eq!(y.len(), t.rows);
    let row = &mut scratch.f16_row;
    row.clear();
    row.resize(t.cols, 0.0);
    let bits = t.as_bits();
    for (r, slot) in y.iter_mut().enumerate() {
        widen_f16_into(kernel, &bits[r * t.cols..(r + 1) * t.cols], row);
        *slot = dot_f32(kernel, row, x);
    }
    kstats::finish(kstats::Op::F16, kernel, kt);
}

impl LinearOp for F16Tensor {
    fn matvec(&self, x: &[f32], y: &mut [f32]) {
        matvec_f16(self, x, y);
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn storage_bits(&self) -> usize {
        self.numel() * 16
    }

    fn flops_per_token(&self) -> u64 {
        2 * self.numel() as u64
    }
}

/// Dispatching matvec over any quantized layer (fp16 layers fall back to
/// the dense path).
pub fn matvec(layer: &QuantizedLayer, x: &[f32], y: &mut [f32]) {
    match layer {
        QuantizedLayer::Sq(l) => matvec_sq(l, x, y),
        QuantizedLayer::Vq(l) => matvec_vq(l, x, y),
        QuantizedLayer::Fp16 { rows, cols, data } => {
            assert_eq!(x.len(), *cols);
            assert_eq!(y.len(), *rows);
            let kernel = active_kernel();
            for (r, slot) in y.iter_mut().enumerate() {
                *slot = dot_f32(kernel, &data[r * cols..(r + 1) * cols], x);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{sq, vq, CalibData};
    use crate::util::rng::Rng;

    fn rand(seed: u64, r: usize, c: usize) -> (Matrix, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(r, c);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        (w, x)
    }

    #[test]
    fn sq_matvec_matches_dequant_then_matvec() {
        let (w, x) = rand(1, 48, 96);
        let q = sq::rtn::quantize(&w, 4, 32);
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 48];
        matvec_sq(&q, &x, &mut got);
        for i in 0..48 {
            assert!((got[i] - want[i]).abs() < 1e-3, "{i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn sq_matvec_handles_group_straddling_rows() {
        // cols=24 with group=32: groups straddle row boundaries
        let (w, x) = rand(2, 10, 24);
        let q = sq::rtn::quantize(&w, 3, 32);
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 10];
        matvec_sq(&q, &x, &mut got);
        for i in 0..10 {
            assert!((got[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn sq_matvec_folds_awq_col_inv_scale() {
        let (w, x) = rand(7, 24, 64);
        let mut calib_x = Matrix::zeros(64, 64);
        let mut rng = Rng::new(8);
        rng.fill_normal(&mut calib_x.data, 0.0, 1.0);
        for r in 0..calib_x.rows {
            for c in 0..4 {
                *calib_x.at_mut(r, c) *= 10.0; // hot channels force real scales
            }
        }
        let q = sq::awq::quantize(&w, 3, 32, Some(&CalibData { x: calib_x }));
        assert!(q.col_inv_scale.is_some(), "AWQ must produce column scales");
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 24];
        matvec_sq(&q, &x, &mut got);
        for i in 0..24 {
            assert!(
                (got[i] - want[i]).abs() < 1e-3 + want[i].abs() * 1e-4,
                "{i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn vq_matvec_matches_dequant_then_matvec() {
        let (w, x) = rand(3, 32, 64);
        let q = vq::kmeans::quantize(&w, 6, 4, 8, &mut Rng::new(9));
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 32];
        matvec_vq(&q, &x, &mut got);
        for i in 0..32 {
            assert!((got[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn dispatch_covers_fp16() {
        let (w, x) = rand(4, 8, 16);
        let l = crate::quant::QuantizedLayer::Fp16 {
            rows: 8,
            cols: 16,
            data: w.data.clone(),
        };
        let want = linalg::matvec(&w, &x);
        let mut got = vec![0.0f32; 8];
        matvec(&l, &x, &mut got);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn f16_matvec_matches_widened_dense() {
        let (w, x) = rand(6, 24, 48);
        let t = F16Tensor::from_matrix(&w);
        // reference: widen the whole tensor, then dense matvec
        let want = linalg::matvec(&t.to_matrix(), &x);
        let mut got = vec![0.0f32; 24];
        matvec_f16(&t, &x, &mut got);
        for i in 0..24 {
            assert!(
                (got[i] - want[i]).abs() <= 1e-5 * (1.0 + want[i].abs()),
                "{i}: {} vs {}",
                got[i],
                want[i]
            );
        }
        assert_eq!(LinearOp::storage_bits(&t), 24 * 48 * 16);
        assert_eq!(LinearOp::flops_per_token(&t), 2 * 24 * 48);
    }

    #[test]
    fn every_available_kernel_widens_f16_bit_exactly() {
        // exhaustive: all 65536 f16 patterns must widen to the same f32
        // bits as the scalar reference (NaNs only need to stay NaN — the
        // lane widen preserves payloads, the scalar canonicalises them)
        let bits: Vec<u16> = (0..=u16::MAX).collect();
        let mut want = vec![0.0f32; bits.len()];
        widen_f16_into(Kernel::Scalar, &bits, &mut want);
        for (i, (&b, &w)) in bits.iter().zip(&want).enumerate() {
            assert_eq!(w.to_bits(), crate::tensor::f16::f16_to_f32(b).to_bits(), "{i}");
        }
        for k in Kernel::available() {
            let mut got = vec![0.0f32; bits.len()];
            widen_f16_into(k, &bits, &mut got);
            for (&b, (&g, &w)) in bits.iter().zip(got.iter().zip(&want)) {
                if w.is_nan() {
                    assert!(g.is_nan(), "{}: {b:#06x} must stay NaN", k.name());
                } else {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "{}: {b:#06x} widened to {g} want {w}",
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    fn widen_handles_unaligned_tails() {
        // lengths that leave 1..7 scalar-tail elements after the lanes
        for n in [1usize, 3, 5, 7, 9, 12, 15] {
            let bits: Vec<u16> = (0..n as u16).map(|i| 0x3c00 + i * 7).collect();
            let mut want = vec![0.0f32; n];
            widen_f16_into(Kernel::Scalar, &bits, &mut want);
            for k in Kernel::available() {
                let mut got = vec![0.0f32; n];
                widen_f16_into(k, &bits, &mut got);
                assert_eq!(got, want, "{} len {n}", k.name());
            }
        }
    }

    #[test]
    fn every_available_kernel_matches_scalar_f16_matvec() {
        let (w, x) = rand(31, 24, 100); // 100 = 12 lanes of 8 + tail 4
        let t = F16Tensor::from_matrix(&w);
        let mut want = vec![0.0f32; 24];
        matvec_f16_with(Kernel::Scalar, &t, &x, &mut want);
        for k in Kernel::available() {
            let mut got = vec![0.0f32; 24];
            matvec_f16_with(k, &t, &x, &mut got);
            for i in 0..24 {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-5 * (1.0 + want[i].abs()),
                    "{}: row {i}: {} vs {}",
                    k.name(),
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn explicit_scratch_matches_thread_local_paths() {
        // one scratch reused across all three kernels and repeated calls
        let mut scratch = MatvecScratch::new();
        let (w, x) = rand(33, 20, 64);
        let sq = sq::rtn::quantize(&w, 4, 32);
        let vq = vq::kmeans::quantize(&w, 5, 4, 6, &mut Rng::new(34));
        let f = F16Tensor::from_matrix(&w);
        for _ in 0..2 {
            let k = active_kernel();
            let (mut a, mut b) = (vec![0.0f32; 20], vec![0.0f32; 20]);
            matvec_sq(&sq, &x, &mut a);
            matvec_sq_scratch(k, &sq, &x, &mut b, &mut scratch);
            assert_eq!(a, b);
            matvec_vq(&vq, &x, &mut a);
            matvec_vq_scratch(k, &vq, &x, &mut b, &mut scratch);
            assert_eq!(a, b);
            matvec_f16(&f, &x, &mut a);
            matvec_f16_scratch(k, &f, &x, &mut b, &mut scratch);
            assert_eq!(a, b);
        }
        // the buffers stayed allocated for reuse
        assert!(scratch.codes_row.capacity() >= 64);
        assert!(scratch.vq_row.capacity() >= 64);
        assert!(scratch.f16_row.capacity() >= 64);
    }

    #[test]
    fn kernel_detection_is_stable_and_listed() {
        let k = Kernel::detect();
        assert_eq!(k, Kernel::detect(), "detection must be deterministic");
        assert_eq!(active_kernel(), k);
        let avail = Kernel::available();
        assert_eq!(avail[0], Kernel::Scalar);
        assert!(avail.contains(&k));
        assert!(!k.name().is_empty());
    }

    #[test]
    fn every_available_kernel_matches_scalar_sq() {
        let (w, x) = rand(21, 40, 192);
        let q = sq::rtn::quantize(&w, 3, 64);
        let mut want = vec![0.0f32; 40];
        matvec_sq_with(Kernel::Scalar, &q, &x, &mut want);
        for k in Kernel::available() {
            let mut got = vec![0.0f32; 40];
            matvec_sq_with(k, &q, &x, &mut got);
            for i in 0..40 {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-5 * (1.0 + want[i].abs()),
                    "{}: row {i}: {} vs {}",
                    k.name(),
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn every_available_kernel_matches_scalar_vq() {
        let (w, x) = rand(22, 24, 96);
        let q = vq::kmeans::quantize(&w, 6, 4, 8, &mut Rng::new(23));
        let mut want = vec![0.0f32; 24];
        matvec_vq_with(Kernel::Scalar, &q, &x, &mut want);
        for k in Kernel::available() {
            let mut got = vec![0.0f32; 24];
            matvec_vq_with(k, &q, &x, &mut got);
            for i in 0..24 {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-5 * (1.0 + want[i].abs()),
                    "{}: row {i}: {} vs {}",
                    k.name(),
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn linear_op_trait_is_consistent_across_impls() {
        let (w, x) = rand(5, 16, 64);
        let sq = sq::rtn::quantize(&w, 3, 32);
        let vq = vq::kmeans::quantize(&w, 5, 4, 6, &mut Rng::new(11));
        let cases: Vec<(&dyn LinearOp, Matrix)> =
            vec![(&w, w.clone()), (&sq, sq.dequantize()), (&vq, vq.dequantize())];
        for (op, reference) in cases {
            assert_eq!(op.rows(), 16);
            assert_eq!(op.cols(), 64);
            assert!(op.storage_bits() > 0);
            assert!(op.flops_per_token() >= 2 * 16 * 64);
            let mut y = vec![0.0f32; 16];
            op.matvec(&x, &mut y);
            // every impl must agree with its own dequantized reference
            let want = linalg::matvec(&reference, &x);
            for i in 0..16 {
                assert!((y[i] - want[i]).abs() < 1e-3, "{i}: {} vs {}", y[i], want[i]);
            }
        }
        // dense storage is 32 bits/weight; packed is far smaller
        assert_eq!(LinearOp::storage_bits(&w), 16 * 64 * 32);
        assert!(LinearOp::storage_bits(&sq) < 16 * 64 * 8);
    }

    #[test]
    fn kstats_attributes_calls_when_enabled_only() {
        let (w, x) = rand(21, 8, 64);
        let sq = sq::rtn::quantize(&w, 4, 32);
        let mut y = vec![0.0f32; 8];
        let calls_at = |snap: &[(&str, &str, u64, f64)], op: &str| -> u64 {
            snap.iter().filter(|(o, _, _, _)| *o == op).map(|(_, _, c, _)| c).sum()
        };
        // disabled (the default): counters do not move
        let before = calls_at(&kstats::snapshot(), "sq");
        matvec_sq(&sq, &x, &mut y);
        // other tests may race an enabled window in this process, so only
        // the enabled direction asserts an exact lower bound
        kstats::set_enabled(true);
        let start = calls_at(&kstats::snapshot(), "sq");
        matvec_sq(&sq, &x, &mut y);
        matvec_sq(&sq, &x, &mut y);
        let after = calls_at(&kstats::snapshot(), "sq");
        kstats::set_enabled(false);
        assert!(after >= start + 2, "enabled calls must land: {start} -> {after}");
        assert!(start >= before, "counters are monotonic");
        // time accrues alongside calls
        let secs: f64 = kstats::snapshot()
            .iter()
            .filter(|(o, _, _, _)| *o == "sq")
            .map(|(_, _, _, s)| s)
            .sum();
        assert!(secs >= 0.0);
    }
}
