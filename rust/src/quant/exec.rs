//! Quantized execution: matvec directly on packed quantized weights.
//!
//! The deployment payoff of the paper (Table 4): RWKV decode is
//! memory-bound (Fig. 9), so reading 3-ish bits per weight instead of 32
//! converts directly into decode speed. These routines stream the packed
//! payload group-by-group, dequantize into a small stack buffer and
//! accumulate the dot product — never materialising the fp matrix
//! (llama.cpp-style). Used by the Table 4 bench and the serving example.

use super::{QuantizedLayer, SqLayer, VqLayer};

/// y = W x for an SQ layer, streaming packed codes.
pub fn matvec_sq(l: &SqLayer, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), l.cols);
    assert_eq!(y.len(), l.rows);
    assert!(
        l.rotation.is_none() && l.col_inv_scale.is_none(),
        "fused matvec supports plain grids (RTN/GPTQ) only"
    );
    let group = l.group_size;
    // Pre-compute group-wise Σx once: Σ_g (m_g + s_g·q)·x = m_g·Σx_g + s_g·Σ q·x.
    // Row-major groups may straddle rows only when cols % group != 0; the
    // common serving shapes (cols multiple of 32/64) take the fast path.
    let aligned = l.cols % group == 0;
    let mut codes_row = vec![0u8; l.cols];
    let groups_per_row = l.cols / group.max(1);
    for r in 0..l.rows {
        let row_base = r * l.cols;
        let mut acc = 0.0f32;
        if aligned && l.bits <= 8 {
            // pass 1: scalar bit-stream unpack into u8 (cheap, branch-free)
            let mut reader = l.codes.reader(row_base);
            for slot in codes_row.iter_mut() {
                *slot = reader.next() as u8;
            }
            // pass 2: vectorisable dequant-dot per group
            for gc in 0..groups_per_row {
                let g = r * groups_per_row + gc;
                let (s, m) = (l.scales[g], l.mins[g]);
                let cs = &codes_row[gc * group..(gc + 1) * group];
                let xs = &x[gc * group..(gc + 1) * group];
                let mut d0 = 0.0f32;
                let mut d1 = 0.0f32;
                let mut q0 = 0.0f32;
                let mut q1 = 0.0f32;
                let half = group / 2;
                for j in 0..half {
                    d0 += cs[2 * j] as f32 * xs[2 * j];
                    d1 += cs[2 * j + 1] as f32 * xs[2 * j + 1];
                    q0 += xs[2 * j];
                    q1 += xs[2 * j + 1];
                }
                if group % 2 == 1 {
                    d0 += cs[group - 1] as f32 * xs[group - 1];
                    q0 += xs[group - 1];
                }
                acc += m * (q0 + q1) + s * (d0 + d1);
            }
        } else {
            // general path: straddling groups / wide codes
            let mut reader = l.codes.reader(row_base);
            let mut c = 0usize;
            while c < l.cols {
                let flat = row_base + c;
                let g = flat / group;
                let run = group.min(l.cols - c).min(group - flat % group);
                let (s, m) = (l.scales[g], l.mins[g]);
                let xs = &x[c..c + run];
                let mut dot = 0.0f32;
                let mut qsum = 0.0f32;
                for (j, &xv) in xs.iter().enumerate().take(run) {
                    let _ = j;
                    dot += reader.next() as f32 * xv;
                    qsum += xv;
                }
                acc += m * qsum + s * dot;
                c += run;
            }
        }
        y[r] = acc;
    }
}

/// y = W x for a VQ layer, gathering codebook entries by index.
pub fn matvec_vq(l: &VqLayer, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), l.cols);
    assert_eq!(y.len(), l.rows);
    let d = l.d;
    debug_assert_eq!(l.cols % d, 0, "vectors are row-aligned by construction");
    let vecs_per_row = l.cols / d;
    for r in 0..l.rows {
        let mut acc = 0.0f32;
        let vrow = r * vecs_per_row;
        for vb in 0..vecs_per_row {
            let e = l.indices.get(vrow + vb) as usize;
            let entry = l.entry(e);
            let xs = &x[vb * d..(vb + 1) * d];
            for j in 0..d {
                acc += entry[j] * xs[j];
            }
        }
        y[r] = acc;
    }
}

/// Dispatching matvec over any quantized layer (fp16 layers fall back to
/// the dense path).
pub fn matvec(layer: &QuantizedLayer, x: &[f32], y: &mut [f32]) {
    match layer {
        QuantizedLayer::Sq(l) => matvec_sq(l, x, y),
        QuantizedLayer::Vq(l) => matvec_vq(l, x, y),
        QuantizedLayer::Fp16 { rows, cols, data } => {
            assert_eq!(x.len(), *cols);
            for r in 0..*rows {
                let row = &data[r * cols..(r + 1) * cols];
                let mut acc = 0.0f32;
                for (w, xv) in row.iter().zip(x) {
                    acc += w * xv;
                }
                y[r] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{sq, vq};
    use crate::tensor::{linalg, Matrix};
    use crate::util::rng::Rng;

    fn rand(seed: u64, r: usize, c: usize) -> (Matrix, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(r, c);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let x: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        (w, x)
    }

    #[test]
    fn sq_matvec_matches_dequant_then_matvec() {
        let (w, x) = rand(1, 48, 96);
        let q = sq::rtn::quantize(&w, 4, 32);
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 48];
        matvec_sq(&q, &x, &mut got);
        for i in 0..48 {
            assert!((got[i] - want[i]).abs() < 1e-3, "{i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn sq_matvec_handles_group_straddling_rows() {
        // cols=24 with group=32: groups straddle row boundaries
        let (w, x) = rand(2, 10, 24);
        let q = sq::rtn::quantize(&w, 3, 32);
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 10];
        matvec_sq(&q, &x, &mut got);
        for i in 0..10 {
            assert!((got[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn vq_matvec_matches_dequant_then_matvec() {
        let (w, x) = rand(3, 32, 64);
        let q = vq::kmeans::quantize(&w, 6, 4, 8, &mut Rng::new(9));
        let want = linalg::matvec(&q.dequantize(), &x);
        let mut got = vec![0.0f32; 32];
        matvec_vq(&q, &x, &mut got);
        for i in 0..32 {
            assert!((got[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn dispatch_covers_fp16() {
        let (w, x) = rand(4, 8, 16);
        let l = crate::quant::QuantizedLayer::Fp16 {
            rows: 8,
            cols: 16,
            data: w.data.clone(),
        };
        let want = linalg::matvec(&w, &x);
        let mut got = vec![0.0f32; 8];
        matvec(&l, &x, &mut got);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-5);
        }
    }
}
