//! §3.2 — codebook optimisation for element-wise multiplication.
//!
//! RWKV applies `μ ⊙ x` in every projection layer (token-shift
//! interpolation weights). For these weights the layer output error
//! (Eq. 19) is `Σ_ij X_ij² (Δμ'_ij)²` — so the VQ codebook should be fit
//! with **X² importance weights**.
//!
//! Batch integration: X must match μ's shape, so the calibration batch is
//! reduced to one representative row. Plain averaging is dominated by
//! activation outliers; since RWKV activations are approximately normal
//! (Fig. 4), a symmetric percentile clip is applied before averaging,
//! pulling the representative feature back to the distribution's centre.

use crate::config::QuantConfig;
use crate::quant::vq::kmeans;
use crate::quant::{CalibData, VqLayer};
use crate::tensor::{stats, Matrix};
use crate::util::rng::Rng;

/// Reduce a batch of activations (`samples × n`) to one representative
/// row by percentile clipping (`clip_pct` ∈ (50, 100]) then column-mean.
pub fn integrate_batch(x: &Matrix, clip_pct: f64) -> Vec<f32> {
    assert!(x.rows > 0 && clip_pct > 50.0 && clip_pct <= 100.0);
    let mut out = Vec::with_capacity(x.cols);
    let mut col = vec![0.0f32; x.rows];
    for c in 0..x.cols {
        for r in 0..x.rows {
            col[r] = x.at(r, c);
        }
        let hi = stats::percentile(&col, clip_pct);
        let lo = stats::percentile(&col, 100.0 - clip_pct);
        let mut sum = 0.0f64;
        for &v in &col {
            sum += v.clamp(lo, hi) as f64;
        }
        out.push((sum / x.rows as f64) as f32);
    }
    out
}

/// The X² importance map for a μ weight of shape `rows × n`, tiled from
/// the integrated representative activation row.
pub fn importance(mu: &Matrix, xbar: &[f32]) -> Vec<f32> {
    assert_eq!(mu.cols, xbar.len(), "activation width must match μ");
    let mut imp = Vec::with_capacity(mu.numel());
    for _r in 0..mu.rows {
        for &x in xbar {
            // ε floor keeps dead channels from collapsing the fit
            imp.push((x * x).max(1e-8));
        }
    }
    imp
}

/// Quantize an element-wise multiplication weight with the optimised
/// codebook. Falls back to unweighted K-Means without calibration.
pub fn quantize(
    mu: &Matrix,
    calib: Option<&CalibData>,
    cfg: &QuantConfig,
    rng: &mut Rng,
) -> VqLayer {
    let k = cfg.vq_bits.max(13); // VQ share of the hybrid runs at 3.5 bpw
    match calib {
        Some(c) => {
            let xbar = integrate_batch(&c.x, cfg.clip_percentile);
            let imp = importance(mu, &xbar);
            kmeans::quantize_weighted(mu, Some(&imp), k, cfg.vq_dim, cfg.kmeans_iters, rng)
        }
        None => kmeans::quantize(mu, k, cfg.vq_dim, cfg.kmeans_iters, rng),
    }
}

/// The Eq. 19 element-wise output loss `||X⊙μ − X⊙Deq(Q(μ))||²_F`
/// evaluated against a full calibration batch (diagnostic; the Table 7
/// ablation reports end-task metrics, the tests here use this directly).
pub fn ewmul_output_loss(mu: &Matrix, deq: &Matrix, x: &Matrix) -> f64 {
    assert_eq!(mu.cols, x.cols);
    let mut loss = 0.0f64;
    for r in 0..x.rows {
        let xr = x.row(r);
        for mr in 0..mu.rows {
            for c in 0..mu.cols {
                let e = (mu.at(mr, c) - deq.at(mr, c)) as f64 * xr[c] as f64;
                loss += e * e;
            }
        }
    }
    loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedLayer;

    /// Normal activations with a handful of extreme outliers, as Fig. 4.
    fn outlier_acts(rng: &mut Rng, samples: usize, n: usize) -> Matrix {
        let mut x = Matrix::zeros(samples, n);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        for _ in 0..samples * n / 100 {
            let i = rng.below(samples * n);
            x.data[i] = rng.normal_ms(0.0, 40.0) as f32;
        }
        x
    }

    #[test]
    fn clipping_suppresses_outliers_in_representative() {
        let mut rng = Rng::new(1);
        let x = outlier_acts(&mut rng, 64, 128);
        let clipped = integrate_batch(&x, 95.0);
        let raw = integrate_batch(&x, 100.0);
        // clipped representative has smaller extreme deviation from 0
        let m_c = clipped.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let m_r = raw.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(m_c < m_r, "clipped max {m_c} vs raw max {m_r}");
    }

    #[test]
    fn weighted_codebook_beats_plain_on_eq19_loss() {
        let mut rng = Rng::new(2);
        let n = 256;
        // μ in [0,1] as RWKV token-shift weights are
        let mut mu = Matrix::zeros(1, n);
        rng.fill_uniform(&mut mu.data, 0.0, 1.0);
        // activations with strongly non-uniform channel energy
        let mut x = Matrix::zeros(128, n);
        for r in 0..128 {
            for c in 0..n {
                let scale = if c < 16 { 20.0 } else { 0.3 };
                *x.at_mut(r, c) = rng.normal_ms(0.0, scale) as f32;
            }
        }
        let calib = CalibData { x: x.clone() };
        let cfg = QuantConfig { vq_bits: 4, vq_dim: 4, kmeans_iters: 20, ..Default::default() };

        let q_opt = quantize(&mu, Some(&calib), &cfg, &mut Rng::new(7));
        let q_plain = kmeans::quantize(&mu, 4, 4, 20, &mut Rng::new(7));
        let l_opt = ewmul_output_loss(&mu, &q_opt.dequantize(), &x);
        let l_plain = ewmul_output_loss(&mu, &q_plain.dequantize(), &x);
        assert!(l_opt < l_plain, "opt {l_opt} vs plain {l_plain}");
    }

    #[test]
    fn importance_tiles_rows() {
        let mu = Matrix::zeros(3, 4);
        let imp = importance(&mu, &[1.0, 2.0, 0.0, 3.0]);
        assert_eq!(imp.len(), 12);
        assert_eq!(imp[1], 4.0);
        assert_eq!(imp[5], 4.0); // row 1 repeats the pattern
        assert!(imp[2] > 0.0); // ε floor
    }

    #[test]
    fn no_calib_is_plain_kmeans() {
        let mut rng = Rng::new(3);
        let mut mu = Matrix::zeros(1, 64);
        rng.fill_uniform(&mut mu.data, 0.0, 1.0);
        let cfg = QuantConfig { vq_bits: 5, kmeans_iters: 10, ..Default::default() };
        let q = quantize(&mu, None, &cfg, &mut Rng::new(4));
        assert!(QuantizedLayer::Vq(q).mse(&mu) < 0.1);
    }

    #[test]
    #[should_panic]
    fn integrate_rejects_bad_percentile() {
        let x = Matrix::zeros(2, 2);
        integrate_batch(&x, 30.0);
    }
}
