//! RTN — plain round-to-nearest scalar quantization (the weakest SQ
//! baseline in Table 2). Groups of `group_size` consecutive row-major
//! elements share one asymmetric (scale, min) grid.

use super::{group_grid, quantize_value};
use crate::quant::{packing::PackedInts, SqLayer};
use crate::tensor::Matrix;

/// Quantize `w` at `bits` with `group_size` elements per scale group.
pub fn quantize(w: &Matrix, bits: u32, group_size: usize) -> SqLayer {
    assert!(group_size > 0);
    let n = w.numel();
    let groups = n.div_ceil(group_size);
    let mut scales = Vec::with_capacity(groups);
    let mut mins = Vec::with_capacity(groups);
    let mut codes = Vec::with_capacity(n);
    for g in 0..groups {
        let lo = g * group_size;
        let hi = (lo + group_size).min(n);
        let (s, m) = group_grid(&w.data[lo..hi], bits);
        for &v in &w.data[lo..hi] {
            codes.push(quantize_value(v, s, m, bits));
        }
        scales.push(s);
        mins.push(m);
    }
    SqLayer {
        rows: w.rows,
        cols: w.cols,
        bits,
        group_size,
        codes: PackedInts::pack(&codes, bits),
        scales,
        mins,
        extra_flops_per_token: 0,
        rotation: None,
        col_inv_scale: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let mut w = Matrix::zeros(8, 32);
        rng.fill_normal(&mut w.data, 0.0, 0.1);
        let q = quantize(&w, 4, 32);
        let deq = q.dequantize();
        for g in 0..(w.numel() / 32) {
            let s = q.scales[g];
            for i in g * 32..(g + 1) * 32 {
                assert!(
                    (deq.data[i] - w.data[i]).abs() <= s * 0.5 + 1e-6,
                    "idx {i}: {} vs {} (s={s})",
                    deq.data[i],
                    w.data[i]
                );
            }
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = Rng::new(2);
        let mut w = Matrix::zeros(16, 64);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let e3 = crate::quant::QuantizedLayer::Sq(quantize(&w, 3, 64)).mse(&w);
        let e8 = crate::quant::QuantizedLayer::Sq(quantize(&w, 8, 64)).mse(&w);
        assert!(e8 < e3 / 100.0, "e3={e3} e8={e8}");
    }

    #[test]
    fn ragged_tail_group() {
        let w = Matrix::from_vec(1, 5, vec![0.0, 0.5, 1.0, -1.0, 2.0]);
        let q = quantize(&w, 8, 4); // 5 elements, group 4 -> ragged tail of 1
        let deq = q.dequantize();
        assert!((deq.data[4] - 2.0).abs() < 1e-6); // singleton group exact
    }

    #[test]
    fn preserves_shape() {
        let w = Matrix::zeros(3, 7);
        let q = quantize(&w, 3, 8);
        let d = q.dequantize();
        assert_eq!((d.rows, d.cols), (3, 7));
        assert!(d.data.iter().all(|&v| v == 0.0));
    }
}
