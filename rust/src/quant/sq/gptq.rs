//! GPTQ (Frantar et al., 2022) — compensation-based scalar quantization.
//!
//! Columns of `W ∈ R^{oc×ic}` are quantized left-to-right; after fixing
//! column `j`, the rounding error is propagated into the not-yet-quantized
//! columns through the Cholesky factor of the inverse Hessian
//! `H = XᵀX + λI`, minimising the layer output error `||XW - XŴ||²`.
//! Grids are per-(row, column-group) and are recomputed from the *updated*
//! weights when the sweep enters a new group — this is the `group_size`
//! (g32 → 3.5 bpw, g64 → 3.25 bpw at 3 bits) configuration of §4.1.

use super::{group_grid, quantize_value};
use crate::quant::{packing::PackedInts, CalibData, SqLayer};
use crate::tensor::{linalg, Matrix};

/// Quantize with GPTQ compensation. `calib` provides the Hessian; if
/// `None`, the identity Hessian is used (degrades to RTN with grid
/// re-estimation, still a valid fallback for uncalibrated layers).
pub fn quantize(
    w: &Matrix,
    bits: u32,
    group_size: usize,
    calib: Option<&CalibData>,
    percdamp: f64,
) -> SqLayer {
    let (oc, ic) = (w.rows, w.cols);
    // Group boundaries must align with columns so grids are re-estimated
    // mid-sweep exactly as GPTQ does; shrink to a divisor if needed.
    let group = effective_group(ic, group_size);

    // Upper Cholesky factor of H^{-1}; hinv_u[j][j..] drives compensation.
    // Identity Hessian (no calibration) ⇒ identity factor ⇒ zero cross-
    // column compensation — skip the O(ic³) factorisation entirely.
    let hinv_u = match calib {
        Some(c) => {
            assert_eq!(c.x.cols, ic, "calibration width {} != ic {}", c.x.cols, ic);
            linalg::gptq_hinv_chol(&c.hessian(), percdamp)
        }
        None => Matrix::eye(ic),
    };

    let mut work = w.clone();
    let n_groups_per_row = ic / group;
    let mut scales = vec![0.0f32; oc * n_groups_per_row];
    let mut mins = vec![0.0f32; oc * n_groups_per_row];
    let mut codes = vec![0u32; oc * ic];

    for j in 0..ic {
        let gcol = j / group;
        if j % group == 0 {
            // (re-)fit grids for this column group from the updated weights
            for r in 0..oc {
                let seg = &work.row(r)[gcol * group..(gcol + 1) * group];
                let (s, m) = group_grid(seg, bits);
                scales[r * n_groups_per_row + gcol] = s;
                mins[r * n_groups_per_row + gcol] = m;
            }
        }
        let djj = hinv_u.at(j, j);
        for r in 0..oc {
            let gi = r * n_groups_per_row + gcol;
            let (s, m) = (scales[gi], mins[gi]);
            let v = work.at(r, j);
            let q = quantize_value(v, s, m, bits);
            codes[r * ic + j] = q;
            let dq = m + s * q as f32;
            // propagate the normalised error into the remaining columns
            if djj.abs() > 1e-20 && j + 1 < ic {
                let err = (v - dq) / djj;
                let row = work.row_mut(r);
                for jj in j + 1..ic {
                    row[jj] -= err * hinv_u.at(j, jj);
                }
            }
        }
    }

    // Re-emit scales/mins in the flat row-major group order expected by
    // SqLayer::dequantize (identical layout because group | ic).
    SqLayer {
        rows: oc,
        cols: ic,
        bits,
        group_size: group,
        codes: PackedInts::pack(&codes, bits),
        scales,
        mins,
        extra_flops_per_token: 0,
        rotation: None,
        col_inv_scale: None,
    }
}

/// Largest divisor of `ic` that is ≤ requested group size (keeps grids
/// column-aligned; equals `group_size` whenever `group_size | ic`).
pub fn effective_group(ic: usize, group_size: usize) -> usize {
    let g = group_size.min(ic).max(1);
    (1..=g).rev().find(|d| ic % d == 0).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sq::rtn;
    use crate::quant::QuantizedLayer;
    use crate::util::rng::Rng;

    fn setup(seed: u64, oc: usize, ic: usize, samples: usize) -> (Matrix, CalibData) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(oc, ic);
        rng.fill_normal(&mut w.data, 0.0, 0.08);
        let mut x = Matrix::zeros(samples, ic);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        // correlated activations make compensation matter
        for r in 0..samples {
            let base = x.at(r, 0);
            for c in 1..ic.min(4) {
                *x.at_mut(r, c) += 0.7 * base;
            }
        }
        (w, CalibData { x })
    }

    /// GPTQ's objective is the *output* error ||X W - X Ŵ||², not the
    /// weight error — it should beat RTN there.
    #[test]
    fn beats_rtn_on_output_error() {
        let (w, calib) = setup(1, 24, 64, 256);
        let g = quantize(&w, 3, 32, Some(&calib), 0.01);
        let r = rtn::quantize(&w, 3, 32);
        let xw = linalg::matmul(&calib.x, &w.transpose());
        let err_g = linalg::matmul(&calib.x, &g.dequantize().transpose()).sq_err(&xw);
        let err_r = linalg::matmul(&calib.x, &r.dequantize().transpose()).sq_err(&xw);
        assert!(
            err_g < err_r,
            "GPTQ {err_g} should beat RTN {err_r} on output MSE"
        );
    }

    #[test]
    fn identity_hessian_close_to_rtn_error() {
        let (w, _) = setup(2, 8, 32, 1);
        let g = QuantizedLayer::Sq(quantize(&w, 4, 32, None, 0.01)).mse(&w);
        let r = QuantizedLayer::Sq(rtn::quantize(&w, 4, 32)).mse(&w);
        // with identity H compensation is diagonal-only; errors are comparable
        assert!(g < r * 2.0 + 1e-12, "g={g} r={r}");
    }

    #[test]
    fn effective_group_divides() {
        assert_eq!(effective_group(64, 32), 32);
        assert_eq!(effective_group(96, 64), 48);
        assert_eq!(effective_group(7, 32), 7);
        assert_eq!(effective_group(13, 4), 1);
    }

    #[test]
    fn bpw_matches_paper_accounting() {
        let (w, calib) = setup(3, 16, 128, 64);
        let g32 = quantize(&w, 3, 32, Some(&calib), 0.01);
        let g64 = quantize(&w, 3, 64, Some(&calib), 0.01);
        assert!((g32.bpw() - 3.5).abs() < 1e-9); // 3 + 16/32
        assert!((g64.bpw() - 3.25).abs() < 1e-9); // 3 + 16/64
    }

    #[test]
    fn reconstruction_shape_and_finite() {
        let (w, calib) = setup(4, 8, 32, 32);
        let q = quantize(&w, 3, 32, Some(&calib), 0.01);
        let d = q.dequantize();
        assert_eq!((d.rows, d.cols), (8, 32));
        assert!(d.data.iter().all(|v| v.is_finite()));
    }
}
