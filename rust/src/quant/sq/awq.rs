//! AWQ (Lin et al., 2023) — activation-aware weight quantization.
//!
//! Per-input-channel scales `s_c = a_c^α` (with `a_c` the mean absolute
//! activation of channel `c`) move quantization-sensitive mass out of
//! important channels before RTN; α is grid-searched to minimise the
//! output-MSE proxy `Σ_c E[x_c²]·||ΔW_{:,c}||²`.
//!
//! In T-LLMs the scales fold into the preceding LayerNorm for free. In
//! RWKV the fusion path is blocked by token-shift / sigmoid / exp
//! (paper §1 finding ❶), so the runtime pays one extra multiply per
//! activation element — recorded in `extra_flops_per_token`.

use super::rtn;
use crate::quant::{CalibData, SqLayer};
use crate::tensor::Matrix;

const ALPHA_GRID: &[f64] = &[0.0, 0.15, 0.3, 0.45, 0.6, 0.75, 0.9];

/// AWQ quantization of `w` (oc×ic) with activation statistics from calib.
/// Falls back to plain RTN when no calibration is available.
pub fn quantize(
    w: &Matrix,
    bits: u32,
    group_size: usize,
    calib: Option<&CalibData>,
) -> SqLayer {
    let Some(calib) = calib else {
        return rtn::quantize(w, bits, group_size);
    };
    assert_eq!(calib.x.cols, w.cols);
    let a = calib.col_abs_mean();
    // E[x_c^2] for the output-error proxy
    let ex2: Vec<f64> = (0..w.cols)
        .map(|c| {
            let mut s = 0.0f64;
            for r in 0..calib.x.rows {
                let v = calib.x.at(r, c) as f64;
                s += v * v;
            }
            s / calib.x.rows.max(1) as f64
        })
        .collect();

    let mut best: Option<(f64, SqLayer, Vec<f32>)> = None;
    for &alpha in ALPHA_GRID {
        // normalise scales to geometric mean 1 so grids stay in range
        let raw: Vec<f64> = a.iter().map(|&v| (v as f64).max(1e-8).powf(alpha)).collect();
        let log_mean = raw.iter().map(|v| v.ln()).sum::<f64>() / raw.len() as f64;
        let norm = log_mean.exp();
        let s: Vec<f32> = raw.iter().map(|&v| (v / norm) as f32).collect();

        let mut scaled = w.clone();
        for r in 0..w.rows {
            let row = scaled.row_mut(r);
            for (v, sc) in row.iter_mut().zip(&s) {
                *v *= sc;
            }
        }
        let mut q = rtn::quantize(&scaled, bits, group_size);
        q.col_inv_scale = Some(s.iter().map(|&v| 1.0 / v).collect());
        // one multiply per activation element per token, not fusable in RWKV
        q.extra_flops_per_token = w.cols as u64;

        let deq = q.dequantize();
        let mut proxy = 0.0f64;
        for r in 0..w.rows {
            for c in 0..w.cols {
                let d = (deq.at(r, c) - w.at(r, c)) as f64;
                proxy += ex2[c] * d * d;
            }
        }
        if best.as_ref().map(|(b, _, _)| proxy < *b).unwrap_or(true) {
            best = Some((proxy, q, s));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg;
    use crate::util::rng::Rng;

    fn setup(seed: u64, oc: usize, ic: usize) -> (Matrix, CalibData) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(oc, ic);
        rng.fill_normal(&mut w.data, 0.0, 0.05);
        let mut x = Matrix::zeros(128, ic);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        // make a few channels much more active -> AWQ should protect them
        for r in 0..x.rows {
            for c in 0..4 {
                *x.at_mut(r, c) *= 12.0;
            }
        }
        (w, CalibData { x })
    }

    #[test]
    fn beats_rtn_on_activation_weighted_error() {
        let (w, calib) = setup(1, 16, 64);
        let q_awq = quantize(&w, 3, 32, Some(&calib));
        let q_rtn = rtn::quantize(&w, 3, 32);
        let xw = linalg::matmul(&calib.x, &w.transpose());
        let e_awq = linalg::matmul(&calib.x, &q_awq.dequantize().transpose()).sq_err(&xw);
        let e_rtn = linalg::matmul(&calib.x, &q_rtn.dequantize().transpose()).sq_err(&xw);
        assert!(e_awq < e_rtn, "AWQ {e_awq} vs RTN {e_rtn}");
    }

    #[test]
    fn records_unfusable_overhead() {
        let (w, calib) = setup(2, 8, 32);
        let q = quantize(&w, 3, 32, Some(&calib));
        assert_eq!(q.extra_flops_per_token, 32);
    }

    #[test]
    fn no_calib_falls_back_to_rtn() {
        let (w, _) = setup(3, 8, 32);
        let q = quantize(&w, 3, 32, None);
        assert!(q.col_inv_scale.is_none());
        assert_eq!(q.extra_flops_per_token, 0);
    }

    #[test]
    fn dequant_is_finite() {
        let (w, calib) = setup(4, 8, 32);
        let q = quantize(&w, 3, 32, Some(&calib));
        assert!(q.dequantize().data.iter().all(|v| v.is_finite()));
    }
}
