//! QuaRot-style rotation baseline (Ashkboos et al., 2024).
//!
//! Weights are quantized in a randomly-rotated basis `Ŵ = Q(W·H_s)`,
//! where `H_s` is a random-sign diagonal followed by a normalized
//! Walsh–Hadamard transform. Rotation spreads outliers, flattening the
//! distribution before RTN.
//!
//! **The paper's §1 finding ❶, reproduced structurally:** in T-LLMs the
//! rotation pair folds into neighbouring linear layers; in RWKV the
//! fusion path crosses token-shift, sigmoid and exp, so both the forward
//! rotation of activations and the inverse after the matmul must run
//! online — `2·n·log₂(n)` extra FLOPs per token per layer, reported in
//! `extra_flops_per_token` and aggregated by `model::flops` into the
//! ">99% FLOP increase" comparison of `benches/fig9_compute_memory.rs`.

use super::rtn;
use crate::quant::{RotationMeta, SqLayer};
use crate::tensor::{linalg, Matrix};
use crate::util::rng::Rng;

/// Largest power of two dividing `n` (Hadamard block size).
pub fn hadamard_block(n: usize) -> usize {
    if n == 0 {
        return 1;
    }
    1 << n.trailing_zeros()
}

/// Quantize in a random-Hadamard-rotated basis.
pub fn quantize(w: &Matrix, bits: u32, group_size: usize, seed: u64) -> SqLayer {
    let ic = w.cols;
    let block = hadamard_block(ic);
    let mut rng = Rng::new(seed ^ 0x5157_4152_4f54); // "QWAROT"
    let signs: Vec<f32> = (0..ic)
        .map(|_| if rng.f64() < 0.5 { -1.0 } else { 1.0 })
        .collect();

    // rotate each row blockwise: sign, then FWHT per power-of-two block
    let mut rotated = w.clone();
    for r in 0..w.rows {
        let row = rotated.row_mut(r);
        for (v, s) in row.iter_mut().zip(&signs) {
            *v *= s;
        }
        for chunk in row.chunks_exact_mut(block) {
            linalg::fwht_normalized(chunk);
        }
    }

    let mut q = rtn::quantize(&rotated, bits, group_size);
    q.rotation = Some(RotationMeta { signs });
    // Online rotation of the activations feeding this layer, per token.
    // Counted as a dense orthogonal multiply (2·ic²): RWKV's non-linear
    // operators block fusion, so the rotation runs on the request path —
    // this is the §1 ">99% FLOP increase" finding. (A fast in-kernel
    // Hadamard would lower the constant but still cannot be fused away.)
    q.extra_flops_per_token = 2 * (ic as u64) * (ic as u64);
    q
}

/// Inverse-rotate a dequantized row (helper for the dequant path; the
/// full inverse lives in `SqLayer::dequantize` via `RotationMeta`).
pub fn unrotate_row(row: &mut [f32], signs: &[f32]) {
    let block = hadamard_block(row.len());
    for chunk in row.chunks_exact_mut(block) {
        linalg::fwht_normalized(chunk);
    }
    for (v, s) in row.iter_mut().zip(signs) {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedLayer;
    use crate::util::rng::Rng;

    /// Build a weight with strong outliers — the regime rotation helps in.
    fn outlier_weight(seed: u64, oc: usize, ic: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(oc, ic);
        rng.fill_normal(&mut w.data, 0.0, 0.02);
        for _ in 0..(oc * ic / 50) {
            let i = rng.below(oc * ic);
            w.data[i] = rng.normal_ms(0.0, 0.6) as f32;
        }
        w
    }

    #[test]
    fn rotation_helps_on_outlier_weights() {
        let w = outlier_weight(1, 32, 128);
        let e_rot = QuantizedLayer::Sq(quantize(&w, 3, 128, 7)).mse(&w);
        // same budget RTN but per-full-row groups (so outliers blow the grid)
        let e_rtn = QuantizedLayer::Sq(rtn::quantize(&w, 3, 128)).mse(&w);
        assert!(e_rot < e_rtn, "rot {e_rot} vs rtn {e_rtn}");
    }

    #[test]
    fn round_trip_exact_at_high_bits() {
        let w = outlier_weight(2, 4, 64);
        let q = quantize(&w, 16, 64, 3);
        assert!(QuantizedLayer::Sq(q).mse(&w) < 1e-6);
    }

    #[test]
    fn records_rotation_overhead() {
        let w = outlier_weight(3, 4, 64);
        let q = quantize(&w, 3, 32, 3);
        assert_eq!(q.extra_flops_per_token, 2 * 64 * 64); // dense 2·ic² equivalent
    }

    #[test]
    fn non_power_of_two_uses_block() {
        assert_eq!(hadamard_block(96), 32);
        assert_eq!(hadamard_block(63), 1);
        let mut rng = Rng::new(4);
        let mut w = Matrix::zeros(3, 96);
        rng.fill_normal(&mut w.data, 0.0, 0.1);
        let q = quantize(&w, 4, 32, 5);
        assert!(q.dequantize().data.iter().all(|v| v.is_finite()));
    }
}
