//! Scalar quantization engines.
//!
//! All engines share the asymmetric uniform grid of Eq. 2:
//! `Q(x) = clamp(round(x/s) + z, 0, 2^b - 1)` with per-group `(s, min)`
//! pairs over groups of consecutive row-major elements.

pub mod awq;
pub mod gptq;
pub mod quarot;
pub mod rtn;

/// Compute the symmetric full-range (scale, min) grid for one group of
/// values at `bits` precision: `w ≈ s·(q − (2^b−1)/2)` with
/// `s = 2·max|w| / (2^b−1)`. Only the fp16 scale is stored per group —
/// `min = −s·(2^b−1)/2` is derived — matching the paper's bpw
/// accounting (3-bit, group 64 → 3.25 bpw; group 32 → 3.5 bpw).
/// A degenerate (all-zero) group gets scale 0 and is reproduced exactly.
pub fn group_grid(vals: &[f32], bits: u32) -> (f32, f32) {
    let mut absmax = 0.0f32;
    for &v in vals {
        absmax = absmax.max(v.abs());
    }
    if !absmax.is_finite() || absmax == 0.0 {
        return (0.0, 0.0);
    }
    let levels = ((1u64 << bits) - 1) as f32;
    let s = 2.0 * absmax / levels;
    (s, -s * levels * 0.5)
}

/// Quantize a single value on a grid; returns the integer code.
#[inline]
pub fn quantize_value(v: f32, scale: f32, min: f32, bits: u32) -> u32 {
    if scale == 0.0 {
        return 0;
    }
    let levels = (1u64 << bits) - 1;
    let q = ((v - min) / scale).round();
    (q.max(0.0) as u64).min(levels) as u32
}

/// Dequantize a code on a grid.
#[inline]
pub fn dequantize_value(q: u32, scale: f32, min: f32) -> f32 {
    min + scale * q as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_range() {
        let vals = [-1.0f32, 0.0, 1.0];
        let (s, m) = group_grid(&vals, 3);
        assert!((s - 2.0 / 7.0).abs() < 1e-6);
        assert!((m + 1.0).abs() < 1e-6);
        // endpoints map to extreme codes and back exactly
        assert_eq!(quantize_value(-1.0, s, m, 3), 0);
        assert_eq!(quantize_value(1.0, s, m, 3), 7);
        assert!((dequantize_value(7, s, m) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_group_exact() {
        let vals = [0.0f32; 8];
        let (s, m) = group_grid(&vals, 4);
        assert_eq!(s, 0.0);
        assert_eq!(dequantize_value(quantize_value(0.0, s, m, 4), s, m), 0.0);
    }

    #[test]
    fn symmetric_grid_is_zero_centred() {
        let (s, m) = group_grid(&[-0.3f32, 0.9], 4);
        // centre of the grid dequantizes to ~0
        let centre = m + s * 7.5;
        assert!(centre.abs() < 1e-6);
    }

    #[test]
    fn out_of_range_clamps() {
        let (s, m) = group_grid(&[0.0, 1.0], 2);
        assert_eq!(quantize_value(9.0, s, m, 2), 3);
        assert_eq!(quantize_value(-9.0, s, m, 2), 0);
    }
}
