//! Vector quantization engines.
//!
//! All engines share [`codebook`]: a `2^k × d` table fit by (weighted)
//! K-Means. [`kmeans`] is the plain VQ baseline of Table 2; [`gptvq`]
//! adds GPTQ-style second-order error propagation during assignment;
//! [`vptq`] weights the codebook fit by the Hessian diagonal.

pub mod codebook;
pub mod gptvq;
pub mod kmeans;
pub mod vptq;

/// Largest divisor of `cols` ≤ `d` (keeps VQ vectors row-aligned).
pub fn effective_dim(cols: usize, d: usize) -> usize {
    crate::quant::sq::gptq::effective_group(cols, d)
}

/// Effective codebook index width for a layer with `nvec` vectors: the
/// fp16 codebook must amortise over the layer, so entries are capped at
/// `nvec / 16` (⇒ codebook overhead ≤ 1 bpw for d-dim vectors). Large
/// layers (the paper's regime) keep the full requested `k`; tiny layers
/// degrade gracefully instead of ballooning past fp16.
pub fn effective_k(k: u32, nvec: usize) -> u32 {
    let cap = (nvec / 16).max(2);
    let max_k = (usize::BITS - 1 - cap.leading_zeros()).max(1);
    k.min(max_k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_k_caps_small_layers() {
        assert_eq!(effective_k(13, 1 << 20), 13); // big layer keeps k
        assert_eq!(effective_k(13, 4096), 8); // 4096/16 = 256 -> 8 bits
        assert_eq!(effective_k(13, 64), 2); // tiny layer
        assert_eq!(effective_k(3, 1 << 20), 3); // never raises k
    }

    #[test]
    fn effective_dim_divides() {
        assert_eq!(effective_dim(256, 4), 4);
        assert_eq!(effective_dim(10, 4), 2);
    }
}
