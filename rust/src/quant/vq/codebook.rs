//! Codebook fitting: weighted K-Means (Lloyd, 1982) with K-Means++
//! seeding, per-element importance weights, and sub-sampled fitting for
//! large layers. This is the common machinery behind the kMeans / GPTVQ /
//! VPTQ baselines and the §3.2 element-wise-multiplication optimisation.

use crate::util::rng::Rng;

/// A `n_entries × d` codebook stored flat.
#[derive(Clone, Debug)]
pub struct Codebook {
    pub d: usize,
    pub entries: Vec<f32>,
}

impl Codebook {
    pub fn n_entries(&self) -> usize {
        self.entries.len() / self.d
    }

    #[inline]
    pub fn entry(&self, i: usize) -> &[f32] {
        &self.entries[i * self.d..(i + 1) * self.d]
    }

    /// Index of the entry minimising the (optionally importance-weighted)
    /// squared distance to `v`.
    pub fn nearest(&self, v: &[f32], weights: Option<&[f32]>) -> usize {
        debug_assert_eq!(v.len(), self.d);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for e in 0..self.n_entries() {
            let c = self.entry(e);
            let mut dist = 0.0f32;
            match weights {
                None => {
                    for j in 0..self.d {
                        let diff = v[j] - c[j];
                        dist += diff * diff;
                    }
                }
                Some(w) => {
                    for j in 0..self.d {
                        let diff = v[j] - c[j];
                        dist += w[j] * diff * diff;
                    }
                }
            }
            if dist < best_d {
                best_d = dist;
                best = e;
            }
        }
        best
    }
}

/// Weighted squared distance between two d-vectors.
#[inline]
fn wdist(a: &[f32], b: &[f32], w: Option<&[f32]>) -> f64 {
    let mut s = 0.0f64;
    match w {
        None => {
            for j in 0..a.len() {
                let d = (a[j] - b[j]) as f64;
                s += d * d;
            }
        }
        Some(w) => {
            for j in 0..a.len() {
                let d = (a[j] - b[j]) as f64;
                s += w[j] as f64 * d * d;
            }
        }
    }
    s
}

/// Fit a codebook of `n_entries` d-vectors to `data` (flat, length
/// multiple of d) with optional per-element importance `weights`
/// (same layout as `data`). Fitting sub-samples at most `max_fit`
/// vectors for tractability on large layers; assignment of the full
/// layer is done separately by the callers.
pub fn fit(
    data: &[f32],
    weights: Option<&[f32]>,
    d: usize,
    n_entries: usize,
    iters: usize,
    max_fit: usize,
    rng: &mut Rng,
) -> Codebook {
    assert!(d > 0 && data.len() % d == 0);
    if let Some(w) = weights {
        assert_eq!(w.len(), data.len());
    }
    let nvec = data.len() / d;
    let k = n_entries.min(nvec.max(1));

    // sub-sample vectors for the fit
    let fit_n = nvec.min(max_fit.max(k));
    let mut idx: Vec<usize> = (0..nvec).collect();
    if fit_n < nvec {
        rng.shuffle(&mut idx);
        idx.truncate(fit_n);
    }
    let vec_at = |i: usize| &data[i * d..(i + 1) * d];
    let w_at = |i: usize| weights.map(|w| &w[i * d..(i + 1) * d]);

    // --- K-Means++ seeding ---
    let mut centers: Vec<f32> = Vec::with_capacity(k * d);
    let first = idx[rng.below(idx.len())];
    centers.extend_from_slice(vec_at(first));
    let mut min_d2: Vec<f64> = idx
        .iter()
        .map(|&i| wdist(vec_at(i), &centers[0..d], w_at(i)))
        .collect();
    while centers.len() / d < k {
        let total: f64 = min_d2.iter().sum();
        let chosen = if total <= 0.0 {
            idx[rng.below(idx.len())]
        } else {
            let mut r = rng.f64() * total;
            let mut pick = idx[idx.len() - 1];
            for (pos, &i) in idx.iter().enumerate() {
                r -= min_d2[pos];
                if r <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        let start = centers.len();
        centers.extend_from_slice(vec_at(chosen));
        let newc: Vec<f32> = centers[start..start + d].to_vec();
        for (pos, &i) in idx.iter().enumerate() {
            let dd = wdist(vec_at(i), &newc, w_at(i));
            if dd < min_d2[pos] {
                min_d2[pos] = dd;
            }
        }
    }
    let mut cb = Codebook { d, entries: centers };

    // --- Lloyd iterations (weighted) ---
    let mut assign = vec![0usize; idx.len()];
    for _ in 0..iters {
        let mut moved = false;
        for (pos, &i) in idx.iter().enumerate() {
            let a = cb.nearest(vec_at(i), w_at(i));
            if a != assign[pos] {
                moved = true;
                assign[pos] = a;
            }
        }
        // update: weighted mean per (cluster, dim)
        let mut num = vec![0.0f64; k * d];
        let mut den = vec![0.0f64; k * d];
        for (pos, &i) in idx.iter().enumerate() {
            let a = assign[pos];
            let v = vec_at(i);
            match w_at(i) {
                None => {
                    for j in 0..d {
                        num[a * d + j] += v[j] as f64;
                        den[a * d + j] += 1.0;
                    }
                }
                Some(w) => {
                    for j in 0..d {
                        num[a * d + j] += (w[j] as f64) * v[j] as f64;
                        den[a * d + j] += w[j] as f64;
                    }
                }
            }
        }
        for e in 0..k {
            for j in 0..d {
                if den[e * d + j] > 0.0 {
                    cb.entries[e * d + j] = (num[e * d + j] / den[e * d + j]) as f32;
                }
                // empty cluster in this dim: keep previous center
            }
        }
        if !moved {
            break;
        }
    }
    cb
}

/// Assign every d-vector of `data` to its nearest codebook entry,
/// with optional importance weighting. Returns the index stream.
pub fn assign_all(cb: &Codebook, data: &[f32], weights: Option<&[f32]>) -> Vec<u32> {
    let d = cb.d;
    let nvec = data.len() / d;
    let mut out = Vec::with_capacity(nvec);
    for i in 0..nvec {
        let w = weights.map(|w| &w[i * d..(i + 1) * d]);
        out.push(cb.nearest(&data[i * d..(i + 1) * d], w) as u32);
    }
    out
}

/// Mean relative cluster loss, as reported in the paper's Table 1:
/// within-cluster squared distortion divided by total variance, after
/// clustering the scalars of `data` into `k` clusters (d = 1).
pub fn relative_cluster_loss(data: &[f32], k: usize, iters: usize, rng: &mut Rng) -> f64 {
    let cb = fit(data, None, 1, k, iters, 50_000, rng);
    let idx = assign_all(&cb, data, None);
    let mut loss = 0.0f64;
    for (i, &a) in idx.iter().enumerate() {
        let d = (data[i] - cb.entries[a as usize]) as f64;
        loss += d * d;
    }
    let mean = data.iter().map(|&x| x as f64).sum::<f64>() / data.len() as f64;
    let var: f64 = data.iter().map(|&x| (x as f64 - mean).powi(2)).sum();
    if var <= 0.0 {
        return 0.0;
    }
    loss / var * 100.0 // percentage, matching Table 1's scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_separated_clusters() {
        let mut rng = Rng::new(1);
        let mut data = Vec::new();
        for _ in 0..200 {
            let pick = rng.below(2) as f32;
            data.push(pick * 10.0 + rng.normal_ms(0.0, 0.05) as f32);
            data.push(pick * -4.0 + rng.normal_ms(0.0, 0.05) as f32);
        }
        let cb = fit(&data, None, 2, 2, 30, 10_000, &mut rng);
        let idx = assign_all(&cb, &data, None);
        // distortion should be tiny relative to the separation
        let mut dist = 0.0f64;
        for i in 0..data.len() / 2 {
            dist += wdist(&data[i * 2..i * 2 + 2], cb.entry(idx[i] as usize), None);
        }
        assert!(dist / ((data.len() / 2) as f64) < 1.0, "distortion {dist}");
    }

    #[test]
    fn weighted_fit_prioritises_heavy_positions() {
        let mut rng = Rng::new(2);
        // vectors (a, b): position 0 has importance 100, position 1 has 0.01
        let mut data = Vec::new();
        let mut weights = Vec::new();
        for i in 0..400 {
            data.push(if i % 2 == 0 { 1.0 } else { -1.0 });
            data.push(rng.normal() as f32);
            weights.push(100.0);
            weights.push(0.01);
        }
        let cb = fit(&data, Some(&weights), 2, 2, 30, 10_000, &mut rng);
        let idx = assign_all(&cb, &data, Some(&weights));
        // position-0 error must be near zero
        let mut e0 = 0.0f64;
        for i in 0..data.len() / 2 {
            let c = cb.entry(idx[i] as usize);
            e0 += ((data[i * 2] - c[0]) as f64).powi(2);
        }
        assert!(e0 / ((data.len() / 2) as f64) < 1e-3, "e0={e0}");
    }

    #[test]
    fn k_clamped_to_data() {
        let mut rng = Rng::new(3);
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        let cb = fit(&data, None, 2, 100, 5, 100, &mut rng);
        assert!(cb.n_entries() <= 2);
    }

    #[test]
    fn relative_cluster_loss_lower_for_clustered_data() {
        let mut rng = Rng::new(4);
        // bimodal (clusterable)
        let clustered: Vec<f32> = (0..2000)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 } + rng.normal_ms(0.0, 0.05) as f32)
            .collect();
        // uniform (hard to cluster)
        let uniform: Vec<f32> = (0..2000).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let lc = relative_cluster_loss(&clustered, 8, 20, &mut rng);
        let lu = relative_cluster_loss(&uniform, 8, 20, &mut rng);
        assert!(lc < lu, "clustered {lc} vs uniform {lu}");
    }

    #[test]
    fn assign_all_within_bounds() {
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let cb = fit(&data, None, 4, 8, 10, 100, &mut rng);
        let idx = assign_all(&cb, &data, None);
        assert_eq!(idx.len(), 16);
        assert!(idx.iter().all(|&i| (i as usize) < cb.n_entries()));
    }
}
