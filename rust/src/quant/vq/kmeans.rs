//! Plain K-Means VQ — the "kMeans" baseline row of Table 2.
//!
//! One codebook per layer, unweighted Euclidean fit on the layer's
//! d-vectors, straight nearest-entry assignment.

use super::codebook::{self, Codebook};
use super::effective_dim;
use crate::quant::{packing::PackedInts, VqLayer};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Maximum vectors used in the Lloyd fit (full layer still assigned).
pub const MAX_FIT_VECTORS: usize = 8192;

/// Quantize `w` with a `2^k`-entry, `d`-dimensional codebook.
pub fn quantize(w: &Matrix, k: u32, d: usize, iters: usize, rng: &mut Rng) -> VqLayer {
    quantize_weighted(w, None, k, d, iters, rng)
}

/// Importance-weighted variant (shared by VPTQ and the §3.2 ewmul path).
/// `weights`, when given, has the same flat layout as `w.data`.
pub fn quantize_weighted(
    w: &Matrix,
    weights: Option<&[f32]>,
    k: u32,
    d: usize,
    iters: usize,
    rng: &mut Rng,
) -> VqLayer {
    let d = effective_dim(w.cols, d);
    let n = w.numel();
    let nvec = n / d;
    let body = &w.data[..nvec * d];
    let wbody = weights.map(|ws| &ws[..nvec * d]);
    let k = super::effective_k(k, nvec);
    let n_entries = 1usize << k;

    let cb: Codebook =
        codebook::fit(body, wbody, d, n_entries, iters, MAX_FIT_VECTORS, rng);
    let indices = codebook::assign_all(&cb, body, wbody);
    VqLayer {
        rows: w.rows,
        cols: w.cols,
        d,
        k,
        codebook: cb.entries,
        indices: PackedInts::pack(&indices, k),
        tail: w.data[nvec * d..].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedLayer;

    fn gaussian_w(seed: u64, r: usize, c: usize, std: f32) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(&mut m.data, 0.0, std);
        m
    }

    #[test]
    fn reconstruction_error_reasonable() {
        let w = gaussian_w(1, 32, 64, 0.05);
        let mut rng = Rng::new(9);
        let q = quantize(&w, 10, 4, 15, &mut rng);
        let mse = QuantizedLayer::Vq(q).mse(&w);
        // 10 bits over 4 dims ≈ 2.5 b/dim; expect clearly sub-variance error
        assert!(mse < 0.05f64.powi(2) * 0.3, "mse={mse}");
    }

    #[test]
    fn more_entries_help() {
        let w = gaussian_w(2, 64, 64, 0.05); // nvec=1024 -> k cap 6
        let mut rng = Rng::new(9);
        let e2 = QuantizedLayer::Vq(quantize(&w, 2, 4, 15, &mut rng)).mse(&w);
        let mut rng = Rng::new(9);
        let e6 = QuantizedLayer::Vq(quantize(&w, 10, 4, 15, &mut rng)).mse(&w);
        assert!(e6 < e2, "e6={e6} e2={e2}");
    }

    #[test]
    fn bpw_accounts_codebook() {
        let w = gaussian_w(3, 64, 64, 0.05);
        let q = quantize(&w, 8, 4, 5, &mut Rng::new(1));
        // effective k = min(8, log2(1024/16)) = 6: payload 6/4 = 1.5 bpw
        // + codebook 64*4*16 / 4096 = 1.0 bpw
        assert_eq!(q.k, 6);
        assert!((q.bpw() - 2.5).abs() < 1e-9, "bpw={}", q.bpw());
    }

    #[test]
    fn non_divisible_cols_fall_back() {
        let w = gaussian_w(4, 3, 10, 0.1); // cols=10, d=4 -> effective d=2
        let q = quantize(&w, 4, 4, 5, &mut Rng::new(2));
        assert_eq!(q.d, 2);
        assert_eq!(q.dequantize().cols, 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = gaussian_w(5, 8, 16, 0.1);
        let a = quantize(&w, 5, 4, 10, &mut Rng::new(7)).dequantize();
        let b = quantize(&w, 5, 4, 10, &mut Rng::new(7)).dequantize();
        assert_eq!(a, b);
    }
}
