//! VPTQ (Liu et al., 2024a) — second-order vector post-training
//! quantization: the codebook fit and the assignment are both weighted by
//! the Hessian diagonal (channel curvature), but no cross-column error
//! propagation is performed (assignments are independent), matching the
//! published method's layer-parallel design.

use super::codebook::{self, Codebook};
use super::effective_dim;
use crate::quant::{packing::PackedInts, CalibData, VqLayer};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// VPTQ quantization of `w` (oc×ic).
pub fn quantize(
    w: &Matrix,
    k: u32,
    d: usize,
    calib: Option<&CalibData>,
    iters: usize,
    rng: &mut Rng,
) -> VqLayer {
    let (oc, ic) = (w.rows, w.cols);
    let d = effective_dim(ic, d);
    let nvec = (oc * ic) / d;

    // Hessian-diagonal importance per column position.
    let diag: Vec<f32> = match calib {
        Some(c) => {
            assert_eq!(c.x.cols, ic);
            (0..ic)
                .map(|j| {
                    let mut s = 0.0f64;
                    for r in 0..c.x.rows {
                        let v = c.x.at(r, j) as f64;
                        s += v * v;
                    }
                    (s.max(1e-12)) as f32
                })
                .collect()
        }
        None => vec![1.0; ic],
    };
    let mut imp = vec![0.0f32; nvec * d];
    for i in 0..nvec {
        for c in 0..d {
            imp[i * d + c] = diag[(i * d + c) % ic];
        }
    }

    let k = super::effective_k(k, nvec);
    let n_entries = 1usize << k;
    let cb: Codebook = codebook::fit(
        &w.data[..nvec * d],
        Some(&imp),
        d,
        n_entries,
        iters,
        super::kmeans::MAX_FIT_VECTORS,
        rng,
    );
    let indices = codebook::assign_all(&cb, &w.data[..nvec * d], Some(&imp));
    VqLayer {
        rows: oc,
        cols: ic,
        d,
        k,
        codebook: cb.entries,
        indices: PackedInts::pack(&indices, k),
        tail: w.data[nvec * d..].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedLayer;
    use crate::tensor::linalg;

    fn setup(seed: u64, oc: usize, ic: usize) -> (Matrix, CalibData) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(oc, ic);
        rng.fill_normal(&mut w.data, 0.0, 0.08);
        let mut x = Matrix::zeros(128, ic);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        // a few hot channels
        for r in 0..x.rows {
            for c in 0..3 {
                *x.at_mut(r, c) *= 10.0;
            }
        }
        (w, CalibData { x })
    }

    #[test]
    fn hessian_weighting_helps_output_error() {
        let (w, calib) = setup(1, 16, 32);
        let xw = linalg::matmul(&calib.x, &w.transpose());
        let v = quantize(&w, 6, 4, Some(&calib), 15, &mut Rng::new(2));
        let p = crate::quant::vq::kmeans::quantize(&w, 6, 4, 15, &mut Rng::new(2));
        let e_v = linalg::matmul(&calib.x, &v.dequantize().transpose()).sq_err(&xw);
        let e_p = linalg::matmul(&calib.x, &p.dequantize().transpose()).sq_err(&xw);
        assert!(e_v < e_p, "vptq {e_v} vs kmeans {e_p}");
    }

    #[test]
    fn no_calib_reduces_to_plain_weighting() {
        let (w, _) = setup(2, 8, 16);
        let q = quantize(&w, 6, 4, None, 10, &mut Rng::new(3));
        assert!(QuantizedLayer::Vq(q).mse(&w) < 0.08f64.powi(2));
    }

    #[test]
    fn shape_preserved() {
        let (w, calib) = setup(3, 8, 16);
        let q = quantize(&w, 6, 4, Some(&calib), 10, &mut Rng::new(4));
        let m = q.dequantize();
        assert_eq!((m.rows, m.cols), (8, 16));
    }
}
