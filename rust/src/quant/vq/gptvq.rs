//! GPTVQ (van Baalen et al., 2024) — vector quantization with GPTQ-style
//! second-order error compensation.
//!
//! The column sweep of GPTQ is lifted to d-wide vector steps: for each
//! row, the d-vector at columns `[j, j+d)` is replaced by its nearest
//! codebook entry (nearness measured under the inverse-Hessian metric
//! diagonal), then the rounding error of each scalar column is propagated
//! into the not-yet-quantized columns through the Cholesky factor of
//! `H⁻¹`, exactly as in GPTQ.

use super::codebook::{self, Codebook};
use super::effective_dim;
use crate::quant::{packing::PackedInts, CalibData, VqLayer};
use crate::tensor::{linalg, Matrix};
use crate::util::rng::Rng;

/// GPTVQ quantization of `w` (oc×ic).
pub fn quantize(
    w: &Matrix,
    k: u32,
    d: usize,
    calib: Option<&CalibData>,
    percdamp: f64,
    iters: usize,
    rng: &mut Rng,
) -> VqLayer {
    let (oc, ic) = (w.rows, w.cols);
    let d = effective_dim(ic, d);
    let h = match calib {
        Some(c) => {
            assert_eq!(c.x.cols, ic);
            c.hessian()
        }
        None => Matrix::eye(ic),
    };
    // identity H => identity factor; skip the O(ic^3) path (see gptq.rs)
    let hinv_u = if calib.is_some() {
        linalg::gptq_hinv_chol(&h, percdamp)
    } else {
        Matrix::eye(ic)
    };

    // Codebook fit on the original vectors, importance = Hessian diagonal
    // per column position (protects high-curvature columns).
    let nvec = (oc * ic) / d;
    let k = super::effective_k(k, nvec);
    let n_entries = 1usize << k;
    let diag: Vec<f32> = (0..ic).map(|j| h.at(j, j).max(1e-12)).collect();
    let mut imp = vec![0.0f32; nvec * d];
    for i in 0..nvec {
        for c in 0..d {
            let col = (i * d + c) % ic;
            imp[i * d + c] = diag[col];
        }
    }
    let cb: Codebook = codebook::fit(
        &w.data[..nvec * d],
        Some(&imp),
        d,
        n_entries,
        iters,
        super::kmeans::MAX_FIT_VECTORS,
        rng,
    );

    // Compensated sweep over column blocks.
    let mut work = w.clone();
    let mut indices = vec![0u32; nvec];
    let vecs_per_row = ic / d;
    let mut jblock = 0usize;
    while jblock < ic {
        for r in 0..oc {
            let v: Vec<f32> = work.row(r)[jblock..jblock + d].to_vec();
            let wseg = &imp[(r * vecs_per_row + jblock / d) * d..(r * vecs_per_row + jblock / d) * d + d];
            let e = cb.nearest(&v, Some(wseg));
            indices[r * vecs_per_row + jblock / d] = e as u32;
            let entry: Vec<f32> = cb.entry(e).to_vec();
            // propagate each scalar error like GPTQ
            for c in 0..d {
                let j = jblock + c;
                let djj = hinv_u.at(j, j);
                if djj.abs() <= 1e-20 || j + 1 >= ic {
                    continue;
                }
                let err = (work.at(r, j) - entry[c]) / djj;
                let row = work.row_mut(r);
                for jj in j + 1..ic {
                    row[jj] -= err * hinv_u.at(j, jj);
                }
            }
        }
        jblock += d;
    }

    VqLayer {
        rows: oc,
        cols: ic,
        d,
        k,
        codebook: cb.entries,
        indices: PackedInts::pack(&indices, k),
        tail: Vec::new(), // d | ic by construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vq::kmeans;
    use crate::util::rng::Rng;

    fn setup(seed: u64, oc: usize, ic: usize, samples: usize) -> (Matrix, CalibData) {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::zeros(oc, ic);
        rng.fill_normal(&mut w.data, 0.0, 0.08);
        let mut x = Matrix::zeros(samples, ic);
        rng.fill_normal(&mut x.data, 0.0, 1.0);
        for r in 0..samples {
            let base = x.at(r, 0);
            for c in 1..6 {
                *x.at_mut(r, c) += 0.8 * base;
            }
        }
        (w, CalibData { x })
    }

    #[test]
    fn beats_plain_kmeans_on_output_error() {
        let (w, calib) = setup(1, 16, 32, 256);
        let xw = linalg::matmul(&calib.x, &w.transpose());
        let g = quantize(&w, 6, 4, Some(&calib), 0.01, 15, &mut Rng::new(3));
        let p = kmeans::quantize(&w, 6, 4, 15, &mut Rng::new(3));
        let e_g = linalg::matmul(&calib.x, &g.dequantize().transpose()).sq_err(&xw);
        let e_p = linalg::matmul(&calib.x, &p.dequantize().transpose()).sq_err(&xw);
        assert!(e_g < e_p, "gptvq {e_g} vs kmeans {e_p}");
    }

    #[test]
    fn works_without_calibration() {
        let (w, _) = setup(2, 8, 16, 1);
        let q = quantize(&w, 6, 4, None, 0.01, 10, &mut Rng::new(4));
        assert!(q.dequantize().data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn index_stream_length() {
        let (w, calib) = setup(3, 8, 16, 32);
        let q = quantize(&w, 6, 4, Some(&calib), 0.01, 10, &mut Rng::new(5));
        assert_eq!(q.indices.len, 8 * 16 / 4);
    }
}
