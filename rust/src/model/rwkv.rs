//! Pure-Rust RWKV reference forward pass.
//!
//! Implements the paper's Appendix A.1 block structure: token-shift
//! interpolation (`μ ⊙ x_t + (1−μ) ⊙ x_{t−1}`, Eqs. 20–22, 25–26), the
//! channel-wise WKV recurrence with bonus `u` and decay `w` (Eq. 23,
//! numerically stabilised with a running max exponent as in the
//! reference CUDA kernel), sigmoid receptance output (Eq. 24), and
//! squared-ReLU channel mixing (Eq. 27). The `rwkv7` variant adds the
//! output gate (`W_g`, `μ_g`) of the RWKV-7 time-mixing module.
//!
//! The runner is generic over [`WeightProvider`]: every projection goes
//! through the polymorphic [`LinearOp`] matvec, so the same forward-pass
//! code serves the dense fp32 store ([`ModelWeights`]) and the packed
//! quantized store ([`crate::model::QuantizedModel`]) — the latter never
//! materialises a dense weight matrix for its quantized matmul layers.
//!
//! This is the numeric oracle for the JAX/Pallas build path
//! (`python/compile/model.py` mirrors these equations) and the engine
//! behind the Rust-side eval harness and the generation server.
//!
//! Naming scheme (shared with `train.py` / `aot.py` via the binary
//! store): `emb`, `head`, `ln_out.{g,b}`, and per block `i`:
//! `blocks.i.ln1.{g,b}`, `blocks.i.att.{mu_r,mu_k,mu_v[,mu_g]}`,
//! `blocks.i.att.{w_r,w_k,w_v,w_o[,w_g]}`, `blocks.i.att.{decay,bonus}`,
//! `blocks.i.ln2.{g,b}`, `blocks.i.ffn.{mu_r,mu_k}`,
//! `blocks.i.ffn.{w_r,w_k,w_v}`.

use super::qmodel::WeightProvider;
use super::store::{ModelWeights, ParamClass};
use crate::config::ModelConfig;
use crate::quant::exec::LinearOp;
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Per-block recurrent state.
#[derive(Debug, Clone)]
pub struct BlockState {
    /// previous post-LN1 activation (token shift, time mixing)
    pub x_att: Vec<f32>,
    /// previous post-LN2 activation (token shift, channel mixing)
    pub x_ffn: Vec<f32>,
    /// WKV numerator accumulator
    pub aa: Vec<f32>,
    /// WKV denominator accumulator
    pub bb: Vec<f32>,
    /// running max exponent for stability
    pub pp: Vec<f32>,
}

impl BlockState {
    fn new(d: usize) -> Self {
        BlockState {
            x_att: vec![0.0; d],
            x_ffn: vec![0.0; d],
            aa: vec![0.0; d],
            bb: vec![0.0; d],
            pp: vec![-1e30; d],
        }
    }

    /// Restore the fresh-sequence values in place — the serve loop
    /// resets between sequences on the hot path, so this must not
    /// allocate.
    pub fn reset(&mut self) {
        self.x_att.fill(0.0);
        self.x_ffn.fill(0.0);
        self.aa.fill(0.0);
        self.bb.fill(0.0);
        self.pp.fill(-1e30);
    }
}

/// Records the input activation rows feeding each quantizable layer
/// during calibration forwards (the `X` of GPTQ/AWQ Hessians and of the
/// §3.2 element-wise loss). Bounded by `max_rows` per layer.
#[derive(Debug, Default)]
pub struct Capture {
    pub max_rows: usize,
    pub rows: HashMap<String, Vec<Vec<f32>>>,
}

impl Capture {
    pub fn new(max_rows: usize) -> Self {
        Capture { max_rows, rows: HashMap::new() }
    }

    fn push(&mut self, name: &str, row: &[f32]) {
        let v = self.rows.entry(name.to_string()).or_default();
        if v.len() < self.max_rows {
            v.push(row.to_vec());
        }
    }

    /// Drain into per-layer activation matrices.
    pub fn into_matrices(self) -> HashMap<String, Matrix> {
        self.rows
            .into_iter()
            .filter(|(_, rows)| !rows.is_empty())
            .map(|(name, rows)| {
                let cols = rows[0].len();
                let mut m = Matrix::zeros(rows.len(), cols);
                for (i, r) in rows.iter().enumerate() {
                    m.row_mut(i).copy_from_slice(r);
                }
                (name, m)
            })
            .collect()
    }
}

/// Runs a model from any [`WeightProvider`] (dense fp32 store or packed
/// quantized model).
pub struct RwkvRunner<'a, W: WeightProvider = ModelWeights> {
    pub weights: &'a W,
    index: HashMap<&'a str, usize>,
    pub state: Vec<BlockState>,
    gated: bool,
    /// when set, calibration activations are recorded per layer
    pub capture: Option<Capture>,
    // scratch buffers (hot path is allocation-free after construction)
    buf_d: Vec<f32>,
    buf_d2: Vec<f32>,
    buf_d3: Vec<f32>,
    buf_r: Vec<f32>,
    buf_k: Vec<f32>,
    buf_v: Vec<f32>,
    buf_g_in: Vec<f32>,
    buf_g: Vec<f32>,
    buf_ffn: Vec<f32>,
    buf_x: Vec<f32>,
    buf_wkv: Vec<f32>,
}

impl<'a, W: WeightProvider> RwkvRunner<'a, W> {
    pub fn new(weights: &'a W) -> Self {
        let index = (0..weights.n_entries())
            .map(|i| (weights.entry_name(i), i))
            .collect();
        let cfg = weights.config();
        let d = cfg.d_model;
        let n = cfg.n_layer;
        let ffn = cfg.ffn_dim();
        let gated = cfg.arch == "rwkv7";
        RwkvRunner {
            weights,
            index,
            state: (0..n).map(|_| BlockState::new(d)).collect(),
            gated,
            capture: None,
            buf_d: vec![0.0; d],
            buf_d2: vec![0.0; d],
            buf_d3: vec![0.0; d],
            buf_r: vec![0.0; d],
            buf_k: vec![0.0; d],
            buf_v: vec![0.0; d],
            buf_g_in: vec![0.0; if gated { d } else { 0 }],
            buf_g: vec![0.0; if gated { d } else { 0 }],
            buf_ffn: vec![0.0; ffn],
            buf_x: vec![0.0; d],
            buf_wkv: vec![0.0; d],
        }
    }

    pub fn reset(&mut self) {
        for s in &mut self.state {
            s.reset();
        }
    }

    fn pos(&self, name: &str) -> usize {
        *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("missing parameter '{name}'"))
    }

    /// Matmul view of a parameter (lifetime tied to the provider, not to
    /// `&self`, so ops can be held across state mutation).
    fn op(&self, name: &str) -> &'a dyn LinearOp {
        self.weights.linear_at(self.pos(name))
    }

    /// Dense row view of a 1-D parameter.
    fn vrow(&self, name: &str) -> &'a [f32] {
        self.weights.row_at(self.pos(name), 0)
    }

    /// Forward one token id; returns the next-token logits.
    pub fn forward_token(&mut self, token: usize) -> Vec<f32> {
        let mut logits = Vec::new();
        self.forward_token_into(token, &mut logits);
        logits
    }

    /// [`RwkvRunner::forward_token`] into a caller-owned logits buffer
    /// (resized to `vocab`) — with the runner's internal scratch this
    /// makes the decode step allocation-free after warm-up, which is
    /// what lets persistent serve workers reuse their buffers across
    /// ticks instead of re-allocating per token.
    pub fn forward_token_into(&mut self, token: usize, logits: &mut Vec<f32>) {
        let cfg = self.weights.config();
        let (d, vocab, n_layer) = (cfg.d_model, cfg.vocab, cfg.n_layer);
        assert!(token < vocab, "token {token} >= vocab {vocab}");
        let emb_pos = self.pos("emb");
        // reusable activation scratch, taken out of `self` so the many
        // short `&self` parameter lookups below stay borrow-compatible
        let mut x = std::mem::take(&mut self.buf_x);
        let mut wkv = std::mem::take(&mut self.buf_wkv);
        wkv.clear();
        wkv.resize(d, 0.0);
        // owned-row lookup: also serves f16-resident RWKVQ2 embeddings
        self.weights.row_f32_into(emb_pos, token, &mut x);

        for b in 0..n_layer {
            let p = |suffix: &str| format!("blocks.{b}.{suffix}");
            // ---- time mixing ----
            let xx = layer_norm(&x, self.vrow(&p("ln1.g")), self.vrow(&p("ln1.b")));
            // fetch all parameter views before borrowing state mutably
            let mu_r = self.vrow(&p("att.mu_r"));
            let mu_k = self.vrow(&p("att.mu_k"));
            let mu_v = self.vrow(&p("att.mu_v"));
            let w_r = self.op(&p("att.w_r"));
            let w_k = self.op(&p("att.w_k"));
            let w_v = self.op(&p("att.w_v"));
            let w_o = self.op(&p("att.w_o"));
            let decay = self.vrow(&p("att.decay"));
            let bonus = self.vrow(&p("att.bonus"));

            // token-shift interpolations + projections (packed or dense)
            lerp_into(&xx, &self.state[b].x_att, mu_r, &mut self.buf_d);
            w_r.matvec(&self.buf_d, &mut self.buf_r);
            lerp_into(&xx, &self.state[b].x_att, mu_k, &mut self.buf_d2);
            w_k.matvec(&self.buf_d2, &mut self.buf_k);
            lerp_into(&xx, &self.state[b].x_att, mu_v, &mut self.buf_d3);
            w_v.matvec(&self.buf_d3, &mut self.buf_v);
            if self.gated {
                // RWKV-7 output gate: token-shifted against the *previous*
                // x_att, like r/k/v (matches model.py's `mix(mu_g, xx, xa)`
                // — the state must not be overwritten first)
                let mu_g = self.vrow(&p("att.mu_g"));
                let w_g = self.op(&p("att.w_g"));
                lerp_into(&xx, &self.state[b].x_att, mu_g, &mut self.buf_g_in);
                w_g.matvec(&self.buf_g_in, &mut self.buf_g);
            }
            self.state[b].x_att.copy_from_slice(&xx);
            let gated = self.gated;
            if let Some(cap) = &mut self.capture {
                cap.push(&p("att.w_r"), &self.buf_d);
                cap.push(&p("att.w_k"), &self.buf_d2);
                cap.push(&p("att.w_v"), &self.buf_d3);
                // μ weights multiply the current activation x_t = xx (Eq. 20)
                cap.push(&p("att.mu_r"), &xx);
                cap.push(&p("att.mu_k"), &xx);
                cap.push(&p("att.mu_v"), &xx);
                if gated {
                    cap.push(&p("att.w_g"), &self.buf_g_in);
                    cap.push(&p("att.mu_g"), &xx);
                }
            }

            // WKV recurrence (channel-wise, stabilised); `wkv` is fully
            // overwritten below, so the cross-block reuse is safe
            {
                let st = &mut self.state[b];
                for c in 0..d {
                    let kc = self.buf_k[c];
                    let vc = self.buf_v[c];
                    let ww = bonus[c] + kc;
                    let p1 = st.pp[c].max(ww);
                    let e1 = (st.pp[c] - p1).exp();
                    let e2 = (ww - p1).exp();
                    wkv[c] = (e1 * st.aa[c] + e2 * vc) / (e1 * st.bb[c] + e2).max(1e-30);
                    // state update with decay
                    let ww2 = st.pp[c] - decay[c];
                    let p2 = ww2.max(kc);
                    let ea = (ww2 - p2).exp();
                    let eb = (kc - p2).exp();
                    st.aa[c] = ea * st.aa[c] + eb * vc;
                    st.bb[c] = ea * st.bb[c] + eb;
                    st.pp[c] = p2;
                }
            }

            // receptance gate, optional RWKV-7 output gate, output proj
            for c in 0..d {
                wkv[c] *= sigmoid(self.buf_r[c]);
            }
            if self.gated {
                for c in 0..d {
                    wkv[c] *= sigmoid(self.buf_g[c]) * 2.0;
                }
            }
            if let Some(cap) = &mut self.capture {
                cap.push(&p("att.w_o"), &wkv);
            }
            w_o.matvec(&wkv, &mut self.buf_d);
            for c in 0..d {
                x[c] += self.buf_d[c];
            }

            // ---- channel mixing ----
            let xc = layer_norm(&x, self.vrow(&p("ln2.g")), self.vrow(&p("ln2.b")));
            let mu_cr = self.vrow(&p("ffn.mu_r"));
            let mu_ck = self.vrow(&p("ffn.mu_k"));
            let w_cr = self.op(&p("ffn.w_r"));
            let w_ck = self.op(&p("ffn.w_k"));
            let w_cv = self.op(&p("ffn.w_v"));
            lerp_into(&xc, &self.state[b].x_ffn, mu_cr, &mut self.buf_d);
            w_cr.matvec(&self.buf_d, &mut self.buf_r);
            lerp_into(&xc, &self.state[b].x_ffn, mu_ck, &mut self.buf_d2);
            w_ck.matvec(&self.buf_d2, &mut self.buf_ffn);
            self.state[b].x_ffn.copy_from_slice(&xc);
            // squared ReLU
            for v in self.buf_ffn.iter_mut() {
                let relu = v.max(0.0);
                *v = relu * relu;
            }
            if let Some(cap) = &mut self.capture {
                cap.push(&p("ffn.w_r"), &self.buf_d);
                cap.push(&p("ffn.w_k"), &self.buf_d2);
                cap.push(&p("ffn.w_v"), &self.buf_ffn);
                cap.push(&p("ffn.mu_r"), &xc);
                cap.push(&p("ffn.mu_k"), &xc);
            }
            w_cv.matvec(&self.buf_ffn, &mut self.buf_v);
            for c in 0..d {
                x[c] += sigmoid(self.buf_r[c]) * self.buf_v[c];
            }
        }

        let xo = layer_norm(&x, self.vrow("ln_out.g"), self.vrow("ln_out.b"));
        logits.clear();
        logits.resize(vocab, 0.0);
        self.op("head").matvec(&xo, logits);
        self.buf_x = x;
        self.buf_wkv = wkv;
    }

    /// Forward a token sequence, returning logits at every position.
    pub fn forward_sequence(&mut self, tokens: &[usize]) -> Vec<Vec<f32>> {
        tokens.iter().map(|&t| self.forward_token(t)).collect()
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// `out = μ ⊙ a + (1−μ) ⊙ b` — the token-shift interpolation.
#[inline]
fn lerp_into(a: &[f32], b: &[f32], mu: &[f32], out: &mut [f32]) {
    for i in 0..out.len() {
        out[i] = mu[i] * a[i] + (1.0 - mu[i]) * b[i];
    }
}

/// LayerNorm with gain and bias.
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32]) -> Vec<f32> {
    let n = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    x.iter()
        .enumerate()
        .map(|(i, &v)| (((v as f64 - mean) * inv) as f32) * g[i] + b[i])
        .collect()
}

/// Initialise a fresh RWKV parameter set (used by tests and the
/// synthetic families; the trained tiny model comes from `train.py`).
pub fn init_params(cfg: &ModelConfig, rng: &mut Rng) -> ModelWeights {
    let d = cfg.d_model;
    let ffn = cfg.ffn_dim();
    let mut m = ModelWeights::new(cfg.clone());
    let gated = cfg.arch == "rwkv7";

    let mat = |rng: &mut Rng, rows: usize, cols: usize, std: f64| {
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.0, (std / (cols as f64).sqrt()) as f32);
        w
    };

    let mut emb = Matrix::zeros(cfg.vocab, d);
    rng.fill_normal(&mut emb.data, 0.0, 0.02);
    m.push("emb", ParamClass::Embedding, emb);

    for b in 0..cfg.n_layer {
        let p = |s: &str| format!("blocks.{b}.{s}");
        m.push(p("ln1.g"), ParamClass::Vector, Matrix::filled(1, d, 1.0));
        m.push(p("ln1.b"), ParamClass::Vector, Matrix::zeros(1, d));
        for mu in ["att.mu_r", "att.mu_k", "att.mu_v"] {
            let mut v = Matrix::zeros(1, d);
            // RWKV init: μ ramps with channel index and depth
            for c in 0..d {
                let ratio = c as f64 / d as f64;
                let depth = b as f64 / cfg.n_layer.max(1) as f64;
                v.data[c] = (ratio.powf(1.0 - depth * 0.5) * 0.9 + 0.05) as f32;
            }
            m.push(p(mu), ParamClass::ElementWise, v);
        }
        if gated {
            let mut v = Matrix::zeros(1, d);
            rng.fill_uniform(&mut v.data, 0.3, 0.7);
            m.push(p("att.mu_g"), ParamClass::ElementWise, v);
        }
        m.push(p("att.w_r"), ParamClass::MatMul, mat(rng, d, d, 1.0));
        m.push(p("att.w_k"), ParamClass::MatMul, mat(rng, d, d, 1.0));
        m.push(p("att.w_v"), ParamClass::MatMul, mat(rng, d, d, 1.0));
        m.push(p("att.w_o"), ParamClass::MatMul, mat(rng, d, d, 0.5));
        if gated {
            m.push(p("att.w_g"), ParamClass::MatMul, mat(rng, d, d, 0.5));
        }
        let mut decay = Matrix::zeros(1, d);
        for c in 0..d {
            // per-channel decay in (0.3, 6): slow channels keep context
            decay.data[c] = (0.3 + 5.7 * (c as f64 / d.max(1) as f64).powf(2.0)) as f32;
        }
        m.push(p("att.decay"), ParamClass::Vector, decay);
        let mut bonus = Matrix::zeros(1, d);
        rng.fill_uniform(&mut bonus.data, 0.0, 1.0);
        m.push(p("att.bonus"), ParamClass::Vector, bonus);

        m.push(p("ln2.g"), ParamClass::Vector, Matrix::filled(1, d, 1.0));
        m.push(p("ln2.b"), ParamClass::Vector, Matrix::zeros(1, d));
        for mu in ["ffn.mu_r", "ffn.mu_k"] {
            let mut v = Matrix::zeros(1, d);
            rng.fill_uniform(&mut v.data, 0.2, 0.9);
            m.push(p(mu), ParamClass::ElementWise, v);
        }
        m.push(p("ffn.w_r"), ParamClass::MatMul, mat(rng, d, d, 0.8));
        m.push(p("ffn.w_k"), ParamClass::MatMul, mat(rng, ffn, d, 1.0));
        m.push(p("ffn.w_v"), ParamClass::MatMul, mat(rng, d, ffn, 0.5));
    }
    m.push("ln_out.g", ParamClass::Vector, Matrix::filled(1, d, 1.0));
    m.push("ln_out.b", ParamClass::Vector, Matrix::zeros(1, d));
    m.push("head", ParamClass::Embedding, mat(rng, cfg.vocab, d, 0.5));
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelWeights {
        init_params(&ModelConfig::rwkv6(2, 16, 32), &mut Rng::new(42))
    }

    #[test]
    fn forward_produces_finite_logits() {
        let m = tiny();
        let mut run = RwkvRunner::new(&m);
        for t in [0usize, 5, 31] {
            let logits = run.forward_token(t);
            assert_eq!(logits.len(), 32);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn state_carries_information() {
        let m = tiny();
        let mut run = RwkvRunner::new(&m);
        let _ = run.forward_token(1);
        let with_ctx = run.forward_token(2);
        run.reset();
        let without_ctx = run.forward_token(2);
        let diff: f32 = with_ctx
            .iter()
            .zip(&without_ctx)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-4, "context must change logits (diff={diff})");
    }

    #[test]
    fn reset_restores_determinism() {
        let m = tiny();
        let mut run = RwkvRunner::new(&m);
        let a = run.forward_sequence(&[3, 1, 4, 1, 5]);
        run.reset();
        let b = run.forward_sequence(&[3, 1, 4, 1, 5]);
        assert_eq!(a, b);
    }

    #[test]
    fn rwkv7_has_gate_params_and_runs() {
        let m = init_params(&ModelConfig::rwkv7(2, 16, 32), &mut Rng::new(1));
        assert!(m.get("blocks.0.att.w_g").is_some());
        let mut run = RwkvRunner::new(&m);
        let logits = run.forward_token(7);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rwkv7_gate_mixes_with_previous_token() {
        // μ_g token-shifts against the previous x_att (model.py:
        // `mix(mu_g, xx, xa)`); perturbing μ_g must change the logits of
        // the second token (it was silently ignored when the gate read
        // the already-overwritten state)
        let m = init_params(&ModelConfig::rwkv7(2, 16, 32), &mut Rng::new(4));
        let mut other = m.clone();
        for v in other.get_mut("blocks.0.att.mu_g").unwrap().data.iter_mut() {
            *v = (*v * 0.2).clamp(0.0, 1.0);
        }
        let mut run_a = RwkvRunner::new(&m);
        let mut run_b = RwkvRunner::new(&other);
        let _ = (run_a.forward_token(3), run_b.forward_token(3));
        let a = run_a.forward_token(9);
        let b = run_b.forward_token(9);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-6, "μ_g must influence the gate (diff={diff})");
    }

    #[test]
    fn long_sequence_stays_stable() {
        let m = tiny();
        let mut run = RwkvRunner::new(&m);
        let toks: Vec<usize> = (0..200).map(|i| i % 32).collect();
        let out = run.forward_sequence(&toks);
        assert!(out.last().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn layer_norm_normalises() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let g = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        let y = layer_norm(&x, &g, &b);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
    }

    #[test]
    fn quantizable_layer_inventory_matches_structure() {
        let m = tiny();
        // per block: 3 att μ + 4 att W + 2 ffn μ + 3 ffn W = 12; 2 blocks
        assert_eq!(m.quantizable_indices().len(), 24);
    }

    #[test]
    fn runner_over_quantized_provider_matches_dense_on_fp16_layers() {
        use crate::model::QuantizedModel;
        use std::collections::HashMap;
        // a QuantizedModel with no quantized layers must reproduce the
        // dense forward exactly (all entries fall back to Dense copies)
        let m = tiny();
        let qm = QuantizedModel::from_parts(&m, &HashMap::new());
        let mut dense = RwkvRunner::new(&m);
        let mut served = RwkvRunner::new(&qm);
        for t in [1usize, 9, 30, 2] {
            assert_eq!(dense.forward_token(t), served.forward_token(t));
        }
    }
}
