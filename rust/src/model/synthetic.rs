//! Synthetic model families with controlled weight distributions.
//!
//! The paper's distribution-dependent findings (Table 1, Fig. 5–8, §4.4)
//! hinge on *how weight values are distributed*, not on what the weights
//! compute. This generator produces full RWKV / LLaMA-shaped weight
//! stores whose matmul layers are drawn from explicit archetypes with
//! family-calibrated proportions:
//!
//! * **RWKV-like** — predominantly uniform layers (the §4.4 finding:
//!   ~60 % of layers classified SQ-suitable at τ_c = 1.5, τ_f = 50),
//!   some uniform-with-local-outliers (Fig. 8), some non-uniform
//!   (Fig. 7); μ element-wise weights in [0, 1].
//! * **LLaMA-like** — predominantly Gaussian / clustered layers
//!   (~10 % SQ-suitable), matching the higher cluster-friendliness of
//!   Table 1.

use super::rwkv;
use super::store::{ModelWeights, ParamClass};
use crate::config::ModelConfig;

use crate::util::rng::Rng;

/// Weight-distribution archetypes (Figs. 6–8 of the paper's appendix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Archetype {
    /// evenly spread values, no outliers (Fig. 6) — SQ-friendly
    Uniform,
    /// evenly spread bulk with a few extreme values (Fig. 8) — needs VQ
    UniformOutliers,
    /// bell-shaped (tails create uneven spacing) — VQ-friendly
    Gaussian,
    /// multi-modal mixture (Fig. 7) — strongly VQ-friendly
    Clustered,
    /// heavy-tailed Student-t
    HeavyTail,
}

impl Archetype {
    /// Fill a buffer with `std`-scaled samples of this archetype.
    pub fn fill(&self, out: &mut [f32], std: f32, rng: &mut Rng) {
        match self {
            Archetype::Uniform => {
                let a = std * 1.732; // match variance of U(-a,a) to std²
                rng.fill_uniform(out, -a, a);
            }
            Archetype::UniformOutliers => {
                let a = std * 1.732;
                rng.fill_uniform(out, -a, a);
                let n_out = (out.len() / 500).max(2);
                for _ in 0..n_out {
                    let i = rng.below(out.len());
                    out[i] = (rng.student_t(2.0) * std as f64 * 12.0) as f32;
                }
            }
            Archetype::Gaussian => rng.fill_normal(out, 0.0, std),
            Archetype::Clustered => {
                let k = 3 + rng.below(4); // 3..6 modes
                let centers: Vec<f32> =
                    (0..k).map(|_| rng.normal_ms(0.0, std as f64 * 1.5) as f32).collect();
                for v in out.iter_mut() {
                    let c = centers[rng.below(k)];
                    *v = c + rng.normal_ms(0.0, std as f64 * 0.12) as f32;
                }
            }
            Archetype::HeavyTail => {
                for v in out.iter_mut() {
                    *v = (rng.student_t(3.0) * std as f64 * 0.7) as f32;
                }
            }
        }
    }
}

/// Which family's archetype mix to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Rwkv,
    Llama,
}

impl Family {
    /// (archetype, sampling weight) — calibrated so the proxy classifies
    /// ≈60 % of RWKV matmul layers as SQ-suitable vs ≈10 % for LLaMA
    /// (Fig. 5, τ_c = 1.5 / τ_f = 50).
    fn mix(&self) -> &'static [(Archetype, f64)] {
        match self {
            Family::Rwkv => &[
                (Archetype::Uniform, 0.55),
                (Archetype::UniformOutliers, 0.15),
                (Archetype::Gaussian, 0.18),
                (Archetype::Clustered, 0.07),
                (Archetype::HeavyTail, 0.05),
            ],
            Family::Llama => &[
                (Archetype::Uniform, 0.08),
                (Archetype::UniformOutliers, 0.04),
                (Archetype::Gaussian, 0.55),
                (Archetype::Clustered, 0.25),
                (Archetype::HeavyTail, 0.08),
            ],
        }
    }

    fn sample(&self, rng: &mut Rng) -> Archetype {
        let mix = self.mix();
        let weights: Vec<f64> = mix.iter().map(|(_, w)| *w).collect();
        mix[rng.categorical(&weights)].0
    }
}

/// Named synthetic model sizes roughly tracking the paper's lineup
/// (scaled down ~100×: the distributions are what matter, see DESIGN.md).
pub fn size_config(arch: &str, label: &str) -> ModelConfig {
    let (n_layer, d_model) = match label {
        "0.1B" => (4, 128),
        "0.5B" => (6, 192),
        "1B" | "1.47B" => (8, 256),
        "3B" => (10, 320),
        "7B" => (12, 384),
        "14B" => (14, 512),
        other => panic!("unknown size label '{other}'"),
    };
    let vocab = 512;
    match arch {
        "rwkv6" => ModelConfig::rwkv6(n_layer, d_model, vocab),
        "rwkv7" => ModelConfig::rwkv7(n_layer, d_model, vocab),
        "llama" => ModelConfig::llama(n_layer, d_model, vocab),
        other => panic!("unknown arch '{other}'"),
    }
}

/// Generate a full RWKV-shaped model whose quantizable matmul weights
/// follow the family's archetype mix. Element-wise μ weights follow the
/// RWKV convention (values in [0, 1], channel-ramped with a per-layer
/// chance of local outliers). Non-quantizable parameters come from the
/// standard init.
pub fn generate_rwkv(cfg: &ModelConfig, family: Family, seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    let mut m = rwkv::init_params(cfg, &mut rng);
    let mut arng = rng.fork("archetypes");
    for (desc, mat) in m.layers.iter_mut() {
        match desc.class {
            ParamClass::MatMul => {
                let arch = family.sample(&mut arng);
                let std = 1.0 / (mat.cols as f32).sqrt() * 0.7;
                arch.fill(&mut mat.data, std, &mut arng);
            }
            ParamClass::ElementWise => {
                // μ in [0,1]; occasionally a few pinned extremes (outliers)
                arng.fill_uniform(&mut mat.data, 0.02, 0.98);
                if arng.f64() < 0.3 {
                    for _ in 0..(mat.numel() / 64).max(1) {
                        let i = arng.below(mat.numel());
                        mat.data[i] = if arng.f64() < 0.5 { 0.0 } else { 1.0 };
                    }
                }
            }
            _ => {}
        }
    }
    m
}

/// Generate the LLaMA comparator's quantizable weight set (see
/// [`super::llama`] for the layer inventory).
pub fn generate_llama(cfg: &ModelConfig, seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    let mut m = super::llama::init_params(cfg, &mut rng);
    let mut arng = rng.fork("archetypes");
    for (desc, mat) in m.layers.iter_mut() {
        if desc.class == ParamClass::MatMul {
            let arch = Family::Llama.sample(&mut arng);
            let std = 1.0 / (mat.cols as f32).sqrt() * 0.7;
            arch.fill(&mut mat.data, std, &mut arng);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::proxy;

    #[test]
    fn archetypes_have_target_scale() {
        let mut rng = Rng::new(1);
        for a in [Archetype::Uniform, Archetype::Gaussian, Archetype::Clustered] {
            let mut buf = vec![0.0f32; 20_000];
            a.fill(&mut buf, 0.05, &mut rng);
            let var = crate::tensor::stats::variance(&buf);
            assert!(
                (var.sqrt() - 0.05).abs() < 0.04,
                "{a:?} std {}",
                var.sqrt()
            );
        }
    }

    #[test]
    fn uniform_low_pc_gaussian_high_pc() {
        let mut rng = Rng::new(2);
        let mut u = vec![0.0f32; 16_384];
        Archetype::Uniform.fill(&mut u, 0.05, &mut rng);
        let mut g = vec![0.0f32; 16_384];
        Archetype::Clustered.fill(&mut g, 0.05, &mut rng);
        let pu = proxy::compute(&u, 4);
        let pg = proxy::compute(&g, 4);
        assert!(pu.p_c < pg.p_c, "uniform {} vs clustered {}", pu.p_c, pg.p_c);
    }

    #[test]
    fn outlier_archetype_raises_pf_not_pc() {
        let mut rng = Rng::new(3);
        let mut clean = vec![0.0f32; 16_384];
        Archetype::Uniform.fill(&mut clean, 0.05, &mut rng);
        let mut dirty = vec![0.0f32; 16_384];
        Archetype::UniformOutliers.fill(&mut dirty, 0.05, &mut rng);
        let pc_ = proxy::compute(&clean, 4);
        let pd = proxy::compute(&dirty, 4);
        assert!(pd.p_f > pc_.p_f * 5.0, "P_f {} vs {}", pd.p_f, pc_.p_f);
    }

    /// Reproduces the Fig. 5 shape: RWKV family mostly SQ, LLaMA mostly VQ.
    #[test]
    fn family_sq_shares_separate() {
        let rcfg = size_config("rwkv6", "0.1B");
        let rwkv = generate_rwkv(&rcfg, Family::Rwkv, 7);
        let lcfg = size_config("llama", "0.1B");
        let llama = generate_llama(&lcfg, 7);
        let share = |m: &ModelWeights| {
            let idx = m.quantizable_indices();
            let sq = idx
                .iter()
                .filter(|&&i| {
                    let p = proxy::compute(&m.layers[i].1.data, 4);
                    p.p_c < 1.5 && p.p_f < 50.0
                })
                .count();
            sq as f64 / idx.len() as f64
        };
        let rs = share(&rwkv);
        let ls = share(&llama);
        assert!(rs > ls + 0.2, "RWKV share {rs} must exceed LLaMA {ls}");
    }

    #[test]
    fn size_configs_monotone() {
        let a = size_config("rwkv6", "0.1B");
        let b = size_config("rwkv6", "14B");
        assert!(b.n_layer > a.n_layer && b.d_model > a.d_model);
    }

    #[test]
    fn generation_deterministic() {
        let cfg = size_config("rwkv6", "0.1B");
        let a = generate_rwkv(&cfg, Family::Rwkv, 3);
        let b = generate_rwkv(&cfg, Family::Rwkv, 3);
        assert_eq!(a.layers[5].1, b.layers[5].1);
    }
}
