//! Serving-side weight providers.
//!
//! [`WeightProvider`] is the abstraction the reference runner (and the
//! PJRT session loader) consume instead of a concrete dense store: a
//! model is an ordered list of named entries, each usable either as a
//! polymorphic [`LinearOp`] (matmul weights) or as a dense row view
//! (embeddings, 1-D params). Two providers exist:
//!
//! * [`ModelWeights`] — the dense fp32 store (reference path).
//! * [`QuantizedModel`] — matmul weights kept in their **packed**
//!   quantized form and served through the streaming kernels of
//!   [`crate::quant::exec`]; element-wise/vector params are dequantized
//!   once at build time (they are `O(d)` and read per token anyway).
//!
//! This is what removes the old "dequantize the whole model to fp32
//! before running" pattern: the forward pass is written once against
//! `WeightProvider`, so fp32, SQ, VQ and hybrid checkpoints all serve
//! through the identical code while the quantized path streams 3-ish
//! bits per weight (the Table 4 memory-bound speedup).

use super::store::{LayerDesc, ModelWeights, ParamClass};
use crate::config::ModelConfig;
use crate::quant::exec::LinearOp;
use crate::quant::QuantizedLayer;
use crate::tensor::Matrix;
use std::borrow::Cow;
use std::collections::HashMap;

/// A named-weight source the forward pass can run over.
///
/// `Send + Sync` supertraits: providers are shared immutably across the
/// serve tick worker pool (one `RwkvRunner` borrow per tick thread), so
/// a provider must be safe to read concurrently — both existing
/// providers are plain data.
pub trait WeightProvider: Send + Sync {
    fn config(&self) -> &ModelConfig;
    /// Number of named entries.
    fn n_entries(&self) -> usize;
    /// Name of the i-th entry (construction order).
    fn entry_name(&self, i: usize) -> &str;
    /// The i-th entry as a matmul operator.
    fn linear_at(&self, i: usize) -> &dyn LinearOp;
    /// Dense row view of the i-th entry (`r = token` for embeddings,
    /// `r = 0` for 1-D params). Panics if the entry is packed.
    fn row_at(&self, i: usize, r: usize) -> &[f32];
    /// Dense fp32 view of the i-th entry, materialised transiently if
    /// the entry is packed (PJRT upload path — one layer at a time,
    /// never the whole model).
    fn materialize_at(&self, i: usize) -> Cow<'_, Matrix>;
    /// Total weight-storage bits as served (the memory side of Table 4).
    fn served_storage_bits(&self) -> usize;
}

impl WeightProvider for ModelWeights {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn n_entries(&self) -> usize {
        self.layers.len()
    }

    fn entry_name(&self, i: usize) -> &str {
        &self.layers[i].0.name
    }

    fn linear_at(&self, i: usize) -> &dyn LinearOp {
        &self.layers[i].1
    }

    fn row_at(&self, i: usize, r: usize) -> &[f32] {
        self.layers[i].1.row(r)
    }

    fn materialize_at(&self, i: usize) -> Cow<'_, Matrix> {
        Cow::Borrowed(&self.layers[i].1)
    }

    fn served_storage_bits(&self) -> usize {
        self.n_params() * 32
    }
}

/// How one entry of a [`QuantizedModel`] is stored and served.
#[derive(Clone, Debug)]
pub enum ServedParam {
    /// Packed quantized payload, served through the streaming kernels.
    Packed(QuantizedLayer),
    /// Dense fp32 (embeddings/heads/norms, dequantized-once element-wise
    /// weights, and QuaRot layers whose rotation cannot be fused).
    Dense(Matrix),
}

impl ServedParam {
    pub fn is_packed(&self) -> bool {
        matches!(self, ServedParam::Packed(_))
    }

    pub fn storage_bits(&self) -> usize {
        match self {
            ServedParam::Packed(q) => q.storage_bits(),
            ServedParam::Dense(m) => m.numel() * 32,
        }
    }

    fn as_linear(&self) -> &dyn LinearOp {
        match self {
            ServedParam::Packed(q) => q,
            ServedParam::Dense(m) => m,
        }
    }
}

/// Can this quantized layer run through the fused matvec kernels?
/// Excludes QuaRot (the rotation mixes columns and is explicitly
/// non-fusable — the paper's §1 overhead argument) and VQ layers whose
/// vector dimension does not tile the rows (`matvec_vq` gathers
/// per-row; a flat tail would be silently dropped in release builds).
fn servable_packed(q: &QuantizedLayer) -> bool {
    match q {
        QuantizedLayer::Sq(l) => l.rotation.is_none(),
        QuantizedLayer::Vq(l) => l.d > 0 && l.cols % l.d == 0 && l.tail.is_empty(),
        QuantizedLayer::Fp16 { .. } => true,
    }
}

/// A model whose matmul weights stay packed: the serving-side twin of a
/// [`ModelWeights`] store after the quantization pipeline ran.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub config: ModelConfig,
    pub entries: Vec<(LayerDesc, ServedParam)>,
    index: HashMap<String, usize>,
}

impl QuantizedModel {
    /// Assemble a servable model from the fp store and the pipeline's
    /// per-layer output ([`crate::coordinator::QuantizedLayers`]):
    ///
    /// * quantized **matmul** layers keep their packed payload,
    /// * quantized **element-wise** layers are dequantized once (1×d
    ///   vectors read per token — packing them buys nothing),
    /// * QuaRot layers fall back to a dequantized dense copy,
    /// * everything else (norms, embeddings, head) is copied dense.
    pub fn from_parts(
        fp: &ModelWeights,
        quantized: &HashMap<String, QuantizedLayer>,
    ) -> QuantizedModel {
        let mut entries = Vec::with_capacity(fp.layers.len());
        for (desc, m) in &fp.layers {
            let served = match quantized.get(&desc.name) {
                Some(q) if desc.class == ParamClass::MatMul && servable_packed(q) => {
                    ServedParam::Packed(q.clone())
                }
                Some(q) => ServedParam::Dense(q.dequantize()),
                None => ServedParam::Dense(m.clone()),
            };
            entries.push((desc.clone(), served));
        }
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, (d, _))| (d.name.clone(), i))
            .collect();
        QuantizedModel { config: fp.config.clone(), entries, index }
    }

    pub fn get(&self, name: &str) -> Option<&ServedParam> {
        self.index.get(name).map(|&i| &self.entries[i].1)
    }

    /// Number of entries served from packed payloads.
    pub fn n_packed(&self) -> usize {
        self.entries.iter().filter(|(_, p)| p.is_packed()).count()
    }

    /// Average bits per weight over the packed entries.
    pub fn packed_bpw(&self) -> f64 {
        let (bits, numel) = self.entries.iter().fold((0usize, 0usize), |(b, n), (_, p)| {
            if let ServedParam::Packed(q) = p {
                (b + q.storage_bits(), n + q.numel())
            } else {
                (b, n)
            }
        });
        bits as f64 / numel.max(1) as f64
    }
}

impl WeightProvider for QuantizedModel {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn n_entries(&self) -> usize {
        self.entries.len()
    }

    fn entry_name(&self, i: usize) -> &str {
        &self.entries[i].0.name
    }

    fn linear_at(&self, i: usize) -> &dyn LinearOp {
        self.entries[i].1.as_linear()
    }

    fn row_at(&self, i: usize, r: usize) -> &[f32] {
        match &self.entries[i].1 {
            ServedParam::Dense(m) => m.row(r),
            ServedParam::Packed(_) => panic!(
                "'{}' is packed — row views exist only for dense entries",
                self.entries[i].0.name
            ),
        }
    }

    fn materialize_at(&self, i: usize) -> Cow<'_, Matrix> {
        match &self.entries[i].1 {
            ServedParam::Dense(m) => Cow::Borrowed(m),
            ServedParam::Packed(q) => Cow::Owned(q.dequantize()),
        }
    }

    fn served_storage_bits(&self) -> usize {
        self.entries.iter().map(|(_, p)| p.storage_bits()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, ModelConfig, QuantConfig};
    use crate::coordinator::quantize_model;
    use crate::model::rwkv::init_params;
    use crate::util::rng::Rng;

    fn small() -> ModelWeights {
        init_params(&ModelConfig::rwkv6(1, 32, 64), &mut Rng::new(5))
    }

    #[test]
    fn from_parts_packs_matmuls_and_densifies_the_rest() {
        let m = small();
        let cfg = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        assert_eq!(qm.entries.len(), m.layers.len());
        for (desc, p) in &qm.entries {
            match desc.class {
                ParamClass::MatMul => assert!(p.is_packed(), "{} not packed", desc.name),
                _ => assert!(!p.is_packed(), "{} must be dense", desc.name),
            }
        }
        assert!(qm.n_packed() > 0);
        assert!(qm.packed_bpw() < 8.0);
        // packed serving must be far below the fp32 footprint
        assert!(qm.served_storage_bits() < m.served_storage_bits());
    }

    #[test]
    fn quarot_layers_fall_back_to_dense() {
        let m = small();
        let cfg = QuantConfig {
            method: Method::QuaRot,
            kmeans_iters: 4,
            ..QuantConfig::default()
        };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        for (desc, p) in &qm.entries {
            assert!(!p.is_packed(), "{} should have fallen back to dense", desc.name);
        }
    }

    #[test]
    fn provider_views_agree_between_dense_and_quantized() {
        let m = small();
        let cfg = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        assert_eq!(qm.n_entries(), m.n_entries());
        for i in 0..m.n_entries() {
            assert_eq!(m.entry_name(i), qm.entry_name(i));
            assert_eq!(m.linear_at(i).rows(), qm.linear_at(i).rows());
            assert_eq!(m.linear_at(i).cols(), qm.linear_at(i).cols());
            let a = m.materialize_at(i);
            let b = qm.materialize_at(i);
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
    }

    #[test]
    #[should_panic(expected = "is packed")]
    fn row_view_of_packed_entry_panics() {
        let m = small();
        let cfg = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        let i = (0..qm.n_entries())
            .find(|&i| qm.entries[i].1.is_packed())
            .expect("at least one packed entry");
        let _ = qm.row_at(i, 0);
    }
}
