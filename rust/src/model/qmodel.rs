//! Serving-side weight providers.
//!
//! [`WeightProvider`] is the abstraction the reference runner (and the
//! PJRT session loader) consume instead of a concrete dense store: a
//! model is an ordered list of named entries, each usable either as a
//! polymorphic [`LinearOp`] (matmul weights) or as a dense row view
//! (embeddings, 1-D params). Two providers exist:
//!
//! * [`ModelWeights`] — the dense fp32 store (reference path).
//! * [`QuantizedModel`] — matmul weights kept in their **packed**
//!   quantized form and served through the streaming kernels of
//!   [`crate::quant::exec`]; element-wise/vector params are dequantized
//!   once at build time (they are `O(d)` and read per token anyway).
//!   Built either in memory ([`QuantizedModel::from_parts`]) or straight
//!   from an RWKVQ2 packed checkpoint ([`QuantizedModel::open`]), where
//!   payloads are borrowed zero-copy from a memory mapping and dense
//!   entries are resident in binary16 ([`ServedParam::DenseF16`]).
//!
//! This is what removes the old "dequantize the whole model to fp32
//! before running" pattern: the forward pass is written once against
//! `WeightProvider`, so fp32, SQ, VQ and hybrid checkpoints all serve
//! through the identical code while the quantized path streams 3-ish
//! bits per weight (the Table 4 memory-bound speedup).

use super::store::{self, LayerDesc, LoadMode, ModelWeights, ParamClass};
use crate::config::ModelConfig;
use crate::quant::exec::LinearOp;
use crate::quant::QuantizedLayer;
use crate::tensor::f16::{round_via_f16, F16Tensor};
use crate::tensor::Matrix;
use std::borrow::Cow;
use std::collections::HashMap;

/// A named-weight source the forward pass can run over.
///
/// `Send + Sync` supertraits: providers are shared immutably across the
/// serve tick worker pool (one `RwkvRunner` per pool lane, each holding
/// a `&W` borrow for the life of the pool — the persistent workers in
/// `coordinator::serve` are scoped threads precisely so these borrows
/// need no `'static` bound), so a provider must be safe to read
/// concurrently — both existing providers are plain data.
pub trait WeightProvider: Send + Sync {
    fn config(&self) -> &ModelConfig;
    /// Number of named entries.
    fn n_entries(&self) -> usize;
    /// Name of the i-th entry (construction order).
    fn entry_name(&self, i: usize) -> &str;
    /// The i-th entry as a matmul operator.
    fn linear_at(&self, i: usize) -> &dyn LinearOp;
    /// Dense row view of the i-th entry (`r = token` for embeddings,
    /// `r = 0` for 1-D params). Panics if the entry is packed or
    /// f16-resident (use [`WeightProvider::row_f32`] for those).
    fn row_at(&self, i: usize, r: usize) -> &[f32];
    /// Row `r` of the i-th entry as owned f32 — like
    /// [`WeightProvider::row_at`] but also serves f16-resident entries
    /// by widening (the embedding-lookup path of RWKVQ2 models).
    fn row_f32(&self, i: usize, r: usize) -> Vec<f32> {
        self.row_at(i, r).to_vec()
    }
    /// [`WeightProvider::row_f32`] into a reusable buffer (resized as
    /// needed) — the per-token hot-path form: the runner's embedding
    /// lookup goes through this, so a warm decode step allocates
    /// nothing.
    fn row_f32_into(&self, i: usize, r: usize, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(self.row_at(i, r));
    }
    /// Dense fp32 view of the i-th entry, materialised transiently if
    /// the entry is packed (PJRT upload path — one layer at a time,
    /// never the whole model).
    fn materialize_at(&self, i: usize) -> Cow<'_, Matrix>;
    /// Total weight-storage bits as served (the memory side of Table 4).
    fn served_storage_bits(&self) -> usize;
}

impl WeightProvider for ModelWeights {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn n_entries(&self) -> usize {
        self.layers.len()
    }

    fn entry_name(&self, i: usize) -> &str {
        &self.layers[i].0.name
    }

    fn linear_at(&self, i: usize) -> &dyn LinearOp {
        &self.layers[i].1
    }

    fn row_at(&self, i: usize, r: usize) -> &[f32] {
        self.layers[i].1.row(r)
    }

    fn materialize_at(&self, i: usize) -> Cow<'_, Matrix> {
        Cow::Borrowed(&self.layers[i].1)
    }

    fn served_storage_bits(&self) -> usize {
        self.n_params() * 32
    }
}

/// How one entry of a [`QuantizedModel`] is stored and served.
#[derive(Clone, Debug)]
pub enum ServedParam {
    /// Packed quantized payload, served through the streaming kernels.
    Packed(QuantizedLayer),
    /// Dense fp32 (1-D norms/EW vectors read per token, and any dense
    /// entry before [`QuantizedModel::dense_to_f16`] runs).
    Dense(Matrix),
    /// Dense binary16 — the RWKVQ2-resident form of embeddings, heads
    /// and QuaRot fallbacks: 16 bits/element physical, widened to f32
    /// row-by-row at use ([`crate::quant::exec::matvec_f16`]).
    DenseF16(F16Tensor),
}

impl ServedParam {
    pub fn is_packed(&self) -> bool {
        matches!(self, ServedParam::Packed(_))
    }

    /// Is the payload borrowed zero-copy from a checkpoint mapping?
    pub fn is_mapped(&self) -> bool {
        match self {
            ServedParam::Packed(QuantizedLayer::Sq(l)) => l.codes.is_mapped(),
            ServedParam::Packed(QuantizedLayer::Vq(l)) => l.indices.is_mapped(),
            ServedParam::Packed(QuantizedLayer::Fp16 { .. }) => false,
            ServedParam::Dense(_) => false,
            ServedParam::DenseF16(t) => t.is_mapped(),
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            ServedParam::Packed(q) => q.numel(),
            ServedParam::Dense(m) => m.numel(),
            ServedParam::DenseF16(t) => t.numel(),
        }
    }

    pub fn storage_bits(&self) -> usize {
        match self {
            ServedParam::Packed(q) => q.storage_bits(),
            ServedParam::Dense(m) => m.numel() * 32,
            ServedParam::DenseF16(t) => t.numel() * 16,
        }
    }

    fn as_linear(&self) -> &dyn LinearOp {
        match self {
            ServedParam::Packed(q) => q,
            ServedParam::Dense(m) => m,
            ServedParam::DenseF16(t) => t,
        }
    }

    /// How the assembly path serves one quantized layer: packed iff it
    /// is a matmul whose payload the fused kernels accept
    /// ([`servable_packed`]), a one-time dequantized dense copy
    /// otherwise. Shared by [`QuantizedModel::from_parts`] and the
    /// streaming packer (`coordinator::pipeline::quantize_store_streaming`)
    /// so the two can never disagree on what ends up packed.
    pub fn from_quantized(desc: &LayerDesc, q: QuantizedLayer) -> ServedParam {
        if desc.class == ParamClass::MatMul && servable_packed(&q) {
            ServedParam::Packed(q)
        } else {
            ServedParam::Dense(q.dequantize())
        }
    }
}

/// Can this quantized layer run through the fused matvec kernels?
/// Excludes QuaRot (the rotation mixes columns and is explicitly
/// non-fusable — the paper's §1 overhead argument) and VQ layers whose
/// vector dimension does not tile the rows (`matvec_vq` gathers
/// per-row; a flat tail would be silently dropped in release builds).
fn servable_packed(q: &QuantizedLayer) -> bool {
    match q {
        QuantizedLayer::Sq(l) => l.rotation.is_none(),
        QuantizedLayer::Vq(l) => l.d > 0 && l.cols % l.d == 0 && l.tail.is_empty(),
        QuantizedLayer::Fp16 { .. } => true,
    }
}

/// A model whose matmul weights stay packed: the serving-side twin of a
/// [`ModelWeights`] store after the quantization pipeline ran.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub config: ModelConfig,
    pub entries: Vec<(LayerDesc, ServedParam)>,
    index: HashMap<String, usize>,
}

impl QuantizedModel {
    /// Assemble a servable model from the fp store and the pipeline's
    /// per-layer output ([`crate::coordinator::QuantizedLayers`]):
    ///
    /// * quantized **matmul** layers keep their packed payload,
    /// * quantized **element-wise** layers are dequantized once (1×d
    ///   vectors read per token — packing them buys nothing),
    /// * QuaRot layers fall back to a dequantized dense copy,
    /// * everything else (norms, embeddings, head) is copied dense.
    pub fn from_parts(
        fp: &ModelWeights,
        quantized: &HashMap<String, QuantizedLayer>,
    ) -> QuantizedModel {
        let mut entries = Vec::with_capacity(fp.layers.len());
        for (desc, m) in &fp.layers {
            let served = match quantized.get(&desc.name) {
                Some(q) => ServedParam::from_quantized(desc, q.clone()),
                None => ServedParam::Dense(m.clone()),
            };
            entries.push((desc.clone(), served));
        }
        QuantizedModel::from_entries(fp.config.clone(), entries)
    }

    /// Assemble from already-served entries (the RWKVQ2 loader path).
    pub fn from_entries(
        config: ModelConfig,
        entries: Vec<(LayerDesc, ServedParam)>,
    ) -> QuantizedModel {
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, (d, _))| (d.name.clone(), i))
            .collect();
        QuantizedModel { config, entries, index }
    }

    /// Make the fp16 dense accounting physical: 2-D dense entries
    /// (embeddings, heads, QuaRot fallbacks) become
    /// [`ServedParam::DenseF16`], and 1-D dense entries (norms, EW
    /// vectors, decay/bonus — kept f32-resident because the runner
    /// borrows their rows per token) are rounded through f16 in place.
    ///
    /// After this call the model serves **bit-identically** to itself
    /// after an RWKVQ2 save/open round trip — every dense value has
    /// already taken its on-disk f16 rounding.
    pub fn dense_to_f16(&mut self) {
        for (_, p) in &mut self.entries {
            let replacement = match &*p {
                ServedParam::Dense(m) if m.rows > 1 => {
                    Some(ServedParam::DenseF16(F16Tensor::from_matrix(m)))
                }
                ServedParam::Packed(QuantizedLayer::Fp16 { rows, cols, data }) => {
                    let m = Matrix::from_vec(*rows, *cols, data.clone());
                    Some(ServedParam::DenseF16(F16Tensor::from_matrix(&m)))
                }
                _ => None,
            };
            if let Some(r) = replacement {
                *p = r;
            } else if let ServedParam::Dense(m) = p {
                // 1-D vector: stays f32-resident, takes the disk rounding
                m.map_inplace(round_via_f16);
            }
        }
    }

    /// Serialize to an RWKVQ2 packed checkpoint (see
    /// [`crate::model::store`] for the layout). Dense f32 entries are
    /// narrowed to f16 on disk — run [`QuantizedModel::dense_to_f16`]
    /// first if this in-memory model must serve identically to the
    /// reopened one.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        store::save_rwkvq2(self, path)
    }

    /// Open an RWKVQ2 checkpoint, memory-mapped when the host supports
    /// it (falling back to a buffered read): packed payloads and 2-D
    /// dense f16 entries are borrowed zero-copy from the mapping, so
    /// open cost is O(TOC) and weight pages fault in on first use.
    pub fn open(path: &std::path::Path) -> crate::Result<QuantizedModel> {
        store::open_rwkvq2(path, LoadMode::Auto)
    }

    /// [`QuantizedModel::open`] with an explicit load mode.
    pub fn open_with(path: &std::path::Path, mode: LoadMode) -> crate::Result<QuantizedModel> {
        store::open_rwkvq2(path, mode)
    }

    /// Open an RWKVQ2 checkpoint from an in-memory byte buffer — the
    /// loader for hosts with no filesystem or mmap (wasm32 edge builds
    /// fetch or embed the pack and hand the bytes here). Payloads are
    /// copied out, so `bytes` may be dropped afterwards.
    pub fn open_bytes(bytes: &[u8]) -> crate::Result<QuantizedModel> {
        store::open_rwkvq2_bytes(bytes)
    }

    pub fn get(&self, name: &str) -> Option<&ServedParam> {
        self.index.get(name).map(|&i| &self.entries[i].1)
    }

    /// Number of entries served from packed payloads.
    pub fn n_packed(&self) -> usize {
        self.entries.iter().filter(|(_, p)| p.is_packed()).count()
    }

    /// Number of entries whose payload is borrowed from a checkpoint
    /// mapping (zero-copy).
    pub fn n_mapped(&self) -> usize {
        self.entries.iter().filter(|(_, p)| p.is_mapped()).count()
    }

    /// Resident storage of the dense (non-packed) entries, in bits —
    /// 16/elem once [`QuantizedModel::dense_to_f16`] or the RWKVQ2
    /// loader ran, 32/elem for f32 leftovers.
    pub fn dense_storage_bits(&self) -> usize {
        self.entries
            .iter()
            .filter(|(_, p)| !p.is_packed())
            .map(|(_, p)| p.storage_bits())
            .sum()
    }

    /// Average bits per weight over the packed entries.
    pub fn packed_bpw(&self) -> f64 {
        let (bits, numel) = self.entries.iter().fold((0usize, 0usize), |(b, n), (_, p)| {
            if let ServedParam::Packed(q) = p {
                (b + q.storage_bits(), n + q.numel())
            } else {
                (b, n)
            }
        });
        bits as f64 / numel.max(1) as f64
    }
}

impl WeightProvider for QuantizedModel {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn n_entries(&self) -> usize {
        self.entries.len()
    }

    fn entry_name(&self, i: usize) -> &str {
        &self.entries[i].0.name
    }

    fn linear_at(&self, i: usize) -> &dyn LinearOp {
        self.entries[i].1.as_linear()
    }

    fn row_at(&self, i: usize, r: usize) -> &[f32] {
        match &self.entries[i].1 {
            ServedParam::Dense(m) => m.row(r),
            ServedParam::DenseF16(_) => panic!(
                "'{}' is f16-resident — borrow-free row views exist only for f32 entries \
                 (use row_f32)",
                self.entries[i].0.name
            ),
            ServedParam::Packed(_) => panic!(
                "'{}' is packed — row views exist only for dense entries",
                self.entries[i].0.name
            ),
        }
    }

    fn row_f32(&self, i: usize, r: usize) -> Vec<f32> {
        match &self.entries[i].1 {
            ServedParam::Dense(m) => m.row(r).to_vec(),
            ServedParam::DenseF16(t) => t.row_f32(r),
            ServedParam::Packed(_) => panic!(
                "'{}' is packed — row views exist only for dense entries",
                self.entries[i].0.name
            ),
        }
    }

    fn row_f32_into(&self, i: usize, r: usize, out: &mut Vec<f32>) {
        match &self.entries[i].1 {
            ServedParam::Dense(m) => {
                out.clear();
                out.extend_from_slice(m.row(r));
            }
            ServedParam::DenseF16(t) => {
                out.clear();
                out.resize(t.cols, 0.0);
                // SIMD widen (VCVTPH2PS / NEON lanes) — this is the
                // per-token embedding lookup, the hottest DenseF16 row
                let bits = t.as_bits();
                crate::quant::exec::widen_f16_into(
                    crate::quant::exec::active_kernel(),
                    &bits[r * t.cols..(r + 1) * t.cols],
                    out,
                );
            }
            ServedParam::Packed(_) => panic!(
                "'{}' is packed — row views exist only for dense entries",
                self.entries[i].0.name
            ),
        }
    }

    fn materialize_at(&self, i: usize) -> Cow<'_, Matrix> {
        match &self.entries[i].1 {
            ServedParam::Dense(m) => Cow::Borrowed(m),
            ServedParam::DenseF16(t) => Cow::Owned(t.to_matrix()),
            ServedParam::Packed(q) => Cow::Owned(q.dequantize()),
        }
    }

    fn served_storage_bits(&self) -> usize {
        self.entries.iter().map(|(_, p)| p.storage_bits()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, ModelConfig, QuantConfig};
    use crate::coordinator::quantize_model;
    use crate::model::rwkv::init_params;
    use crate::util::rng::Rng;

    fn small() -> ModelWeights {
        init_params(&ModelConfig::rwkv6(1, 32, 64), &mut Rng::new(5))
    }

    #[test]
    fn from_parts_packs_matmuls_and_densifies_the_rest() {
        let m = small();
        let cfg = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        assert_eq!(qm.entries.len(), m.layers.len());
        for (desc, p) in &qm.entries {
            match desc.class {
                ParamClass::MatMul => assert!(p.is_packed(), "{} not packed", desc.name),
                _ => assert!(!p.is_packed(), "{} must be dense", desc.name),
            }
        }
        assert!(qm.n_packed() > 0);
        assert!(qm.packed_bpw() < 8.0);
        // packed serving must be far below the fp32 footprint
        assert!(qm.served_storage_bits() < m.served_storage_bits());
    }

    #[test]
    fn quarot_layers_fall_back_to_dense() {
        let m = small();
        let cfg = QuantConfig {
            method: Method::QuaRot,
            kmeans_iters: 4,
            ..QuantConfig::default()
        };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        for (desc, p) in &qm.entries {
            assert!(!p.is_packed(), "{} should have fallen back to dense", desc.name);
        }
    }

    #[test]
    fn provider_views_agree_between_dense_and_quantized() {
        let m = small();
        let cfg = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        assert_eq!(qm.n_entries(), m.n_entries());
        for i in 0..m.n_entries() {
            assert_eq!(m.entry_name(i), qm.entry_name(i));
            assert_eq!(m.linear_at(i).rows(), qm.linear_at(i).rows());
            assert_eq!(m.linear_at(i).cols(), qm.linear_at(i).cols());
            let a = m.materialize_at(i);
            let b = qm.materialize_at(i);
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        }
    }

    #[test]
    fn dense_to_f16_halves_dense_footprint_and_keeps_shapes() {
        let m = small();
        let cfg = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let mut qm = QuantizedModel::from_parts(&m, &q);
        let dense32 = qm.dense_storage_bits();
        qm.dense_to_f16();
        // 2-D dense entries (emb/head) dominate and drop to 16 bits/elem
        let dense16 = qm.dense_storage_bits();
        assert!(dense16 < dense32, "{dense16} !< {dense32}");
        let two_d: usize = qm
            .entries
            .iter()
            .filter(|(_, p)| matches!(p, ServedParam::DenseF16(_)))
            .map(|(_, p)| p.numel())
            .sum();
        assert!(two_d > 0, "emb/head must become DenseF16");
        for (desc, p) in &qm.entries {
            if let ServedParam::DenseF16(t) = p {
                assert!(t.rows > 1, "{} is 1-D and must stay f32", desc.name);
                assert_eq!(p.storage_bits(), p.numel() * 16);
            }
        }
        // nothing was mapped — this model was built in memory
        assert_eq!(qm.n_mapped(), 0);
        // the runner still serves it (f16 embedding lookup via row_f32)
        let mut run = crate::model::rwkv::RwkvRunner::new(&qm);
        assert!(run.forward_token(3).iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dense_to_f16_is_idempotent_on_values() {
        let m = small();
        let mut qm = QuantizedModel::from_parts(&m, &HashMap::new());
        qm.dense_to_f16();
        let once: Vec<Matrix> =
            (0..qm.n_entries()).map(|i| qm.materialize_at(i).into_owned()).collect();
        qm.dense_to_f16();
        for (i, want) in once.iter().enumerate() {
            assert_eq!(&qm.materialize_at(i).into_owned(), want);
        }
    }

    #[test]
    #[should_panic(expected = "f16-resident")]
    fn row_view_of_f16_entry_panics() {
        let m = small();
        let mut qm = QuantizedModel::from_parts(&m, &HashMap::new());
        qm.dense_to_f16();
        let i = (0..qm.n_entries())
            .find(|&i| matches!(qm.entries[i].1, ServedParam::DenseF16(_)))
            .expect("at least one f16 entry");
        let _ = qm.row_at(i, 0);
    }

    #[test]
    #[should_panic(expected = "is packed")]
    fn row_view_of_packed_entry_panics() {
        let m = small();
        let cfg = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = quantize_model(&m, None, &cfg, 2);
        let qm = QuantizedModel::from_parts(&m, &q);
        let i = (0..qm.n_entries())
            .find(|&i| qm.entries[i].1.is_packed())
            .expect("at least one packed entry");
        let _ = qm.row_at(i, 0);
    }
}
