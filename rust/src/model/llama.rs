//! Minimal LLaMA-like comparator model.
//!
//! Only what the paper's comparisons need: the layer inventory with
//! realistic shapes (attention q/k/v/o + gated FFN), weight generation,
//! and the op/byte accounting hooks. No Rust forward pass is required —
//! the LLaMA family appears in Table 1 (cluster loss), Fig. 5 (SQ
//! proportion), and Fig. 9 (compute-to-memory ratio) only.

use super::store::{ModelWeights, ParamClass};
use crate::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Initialise a LLaMA-shaped parameter set (Gaussian init; the synthetic
/// family generator overwrites the matmul weights with archetypes).
pub fn init_params(cfg: &ModelConfig, rng: &mut Rng) -> ModelWeights {
    let d = cfg.d_model;
    let ffn = cfg.ffn_dim();
    let mut m = ModelWeights::new(cfg.clone());

    let mat = |rng: &mut Rng, rows: usize, cols: usize| {
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.0, 1.0 / (cols as f32).sqrt());
        w
    };

    let mut emb = Matrix::zeros(cfg.vocab, d);
    rng.fill_normal(&mut emb.data, 0.0, 0.02);
    m.push("emb", ParamClass::Embedding, emb);
    for b in 0..cfg.n_layer {
        let p = |s: &str| format!("blocks.{b}.{s}");
        m.push(p("ln1.g"), ParamClass::Vector, Matrix::filled(1, d, 1.0));
        for w in ["attn.w_q", "attn.w_k", "attn.w_v", "attn.w_o"] {
            m.push(p(w), ParamClass::MatMul, mat(rng, d, d));
        }
        m.push(p("ln2.g"), ParamClass::Vector, Matrix::filled(1, d, 1.0));
        m.push(p("mlp.w_gate"), ParamClass::MatMul, mat(rng, ffn, d));
        m.push(p("mlp.w_up"), ParamClass::MatMul, mat(rng, ffn, d));
        m.push(p("mlp.w_down"), ParamClass::MatMul, mat(rng, d, ffn));
    }
    m.push("ln_out.g", ParamClass::Vector, Matrix::filled(1, d, 1.0));
    m.push("head", ParamClass::Embedding, mat(rng, cfg.vocab, d));
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_has_seven_matmuls_per_block() {
        let cfg = ModelConfig::llama(3, 64, 128);
        let m = init_params(&cfg, &mut Rng::new(1));
        let matmuls = m
            .layers
            .iter()
            .filter(|(d, _)| d.class == ParamClass::MatMul)
            .count();
        assert_eq!(matmuls, 3 * 7);
    }

    #[test]
    fn no_elementwise_weights_in_llama() {
        let cfg = ModelConfig::llama(2, 64, 128);
        let m = init_params(&cfg, &mut Rng::new(2));
        assert!(
            m.layers.iter().all(|(d, _)| d.class != ParamClass::ElementWise),
            "LLaMA has no μ ⊙ x weights — that is the RWKV-specific structure"
        );
    }
}
