//! Minimal LLaMA-like comparator model — weights **and** a serving
//! forward pass.
//!
//! The layer inventory (attention q/k/v/o + gated FFN, RMSNorm gains)
//! feeds the paper's comparisons (Table 1 cluster loss, Fig. 5 SQ
//! proportion, Fig. 9 op/byte accounting), and [`LlamaRunner`] runs the
//! same inventory end-to-end so a quantized-and-packed Llama store
//! serves through the identical `WeightProvider` → `LinearOp` stack as
//! RWKV — the cross-architecture parity leg of the serve path.
//!
//! **Fixed-size state.** The serve engine's slab state pool
//! ([`crate::coordinator::statepool`]) requires every sequence's state
//! to be a constant number of floats, so the runner uses a
//! **sliding-window KV cache**: per layer, ring buffers holding the
//! RoPE-rotated keys and values of the last [`ATTN_WINDOW`] positions.
//! Attention is exact while a sequence is shorter than the window and
//! windowed after (position information stays correct — RoPE is applied
//! at absolute positions before caching, so cache slot order is
//! irrelevant to the softmax). The flat state layout is
//! `n_layer × (K ring ‖ V ring)` followed by one float carrying the
//! absolute position (exact below 2^24, far beyond any window).
//!
//! Naming scheme (shared with [`init_params`] and the packed store):
//! `emb`, `head`, `ln_out.g`, and per block `i`: `blocks.i.ln1.g`,
//! `blocks.i.attn.{w_q,w_k,w_v,w_o}`, `blocks.i.ln2.g`,
//! `blocks.i.mlp.{w_gate,w_up,w_down}`.

use super::qmodel::WeightProvider;
use super::store::{ModelWeights, ParamClass};
use crate::config::ModelConfig;
use crate::quant::exec::LinearOp;
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Sliding-window length of the fixed-size KV cache (positions kept per
/// layer). Every decoder lane and every state-pool slab of one model
/// must agree on this, so it is a crate constant rather than a knob.
pub const ATTN_WINDOW: usize = 64;

/// Per-layer KV ring buffers (`window × d_model` floats each).
#[derive(Debug, Clone)]
pub struct LayerKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl LayerKv {
    fn new(window: usize, d: usize) -> Self {
        LayerKv { k: vec![0.0; window * d], v: vec![0.0; window * d] }
    }

    pub fn reset(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
    }
}

/// Runs a LLaMA-shaped model from any [`WeightProvider`] (dense fp32
/// store or packed quantized model), one token at a time with a
/// fixed-size sliding-window KV cache.
pub struct LlamaRunner<'a, W: WeightProvider = ModelWeights> {
    pub weights: &'a W,
    index: HashMap<&'a str, usize>,
    /// KV rings, one per layer.
    pub cache: Vec<LayerKv>,
    /// Absolute position of the next token to be fed.
    pub pos: usize,
    n_heads: usize,
    head_dim: usize,
    window: usize,
    // scratch buffers (hot path is allocation-free after construction)
    buf_h: Vec<f32>,
    buf_q: Vec<f32>,
    buf_k: Vec<f32>,
    buf_v: Vec<f32>,
    buf_att: Vec<f32>,
    buf_o: Vec<f32>,
    buf_gate: Vec<f32>,
    buf_up: Vec<f32>,
    buf_x: Vec<f32>,
    scores: Vec<f32>,
}

impl<'a, W: WeightProvider> LlamaRunner<'a, W> {
    pub fn new(weights: &'a W) -> Self {
        Self::with_window(weights, ATTN_WINDOW)
    }

    /// Runner with an explicit window (tests shrink it to hit the
    /// sliding edge cheaply; serving always uses [`ATTN_WINDOW`]).
    pub fn with_window(weights: &'a W, window: usize) -> Self {
        let index = (0..weights.n_entries())
            .map(|i| (weights.entry_name(i), i))
            .collect();
        let cfg = weights.config();
        let d = cfg.d_model;
        let ffn = cfg.ffn_dim();
        let n_heads = cfg.n_heads().max(1);
        assert!(
            d % n_heads == 0,
            "d_model {d} must split evenly across {n_heads} heads"
        );
        assert!(window > 0, "attention window must be positive");
        LlamaRunner {
            weights,
            index,
            cache: (0..cfg.n_layer).map(|_| LayerKv::new(window, d)).collect(),
            pos: 0,
            n_heads,
            head_dim: d / n_heads,
            window,
            buf_h: vec![0.0; d],
            buf_q: vec![0.0; d],
            buf_k: vec![0.0; d],
            buf_v: vec![0.0; d],
            buf_att: vec![0.0; d],
            buf_o: vec![0.0; d],
            buf_gate: vec![0.0; ffn],
            buf_up: vec![0.0; ffn],
            buf_x: vec![0.0; d],
            scores: vec![0.0; window],
        }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    pub fn reset(&mut self) {
        for c in &mut self.cache {
            c.reset();
        }
        self.pos = 0;
    }

    fn pos_of(&self, name: &str) -> usize {
        *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("missing parameter '{name}'"))
    }

    /// Matmul view of a parameter (lifetime tied to the provider, not to
    /// `&self`, so ops can be held across state mutation).
    fn op(&self, name: &str) -> &'a dyn LinearOp {
        self.weights.linear_at(self.pos_of(name))
    }

    /// Dense row view of a 1-D parameter.
    fn vrow(&self, name: &str) -> &'a [f32] {
        self.weights.row_at(self.pos_of(name), 0)
    }

    /// Forward one token id; returns the next-token logits.
    pub fn forward_token(&mut self, token: usize) -> Vec<f32> {
        let mut logits = Vec::new();
        self.forward_token_into(token, &mut logits);
        logits
    }

    /// [`LlamaRunner::forward_token`] into a caller-owned logits buffer
    /// (resized to `vocab`) — allocation-free after warm-up, matching
    /// the RWKV runner's serve contract.
    pub fn forward_token_into(&mut self, token: usize, logits: &mut Vec<f32>) {
        let cfg = self.weights.config();
        let (d, vocab, n_layer) = (cfg.d_model, cfg.vocab, cfg.n_layer);
        assert!(token < vocab, "token {token} >= vocab {vocab}");
        let emb_pos = self.pos_of("emb");
        let mut x = std::mem::take(&mut self.buf_x);
        // owned-row lookup: also serves f16-resident RWKVQ2 embeddings
        self.weights.row_f32_into(emb_pos, token, &mut x);

        let pos = self.pos;
        let slot = pos % self.window;
        let n_ctx = (pos + 1).min(self.window);
        let (heads, hd) = (self.n_heads, self.head_dim);
        let scale = 1.0 / (hd as f32).sqrt();

        for b in 0..n_layer {
            let p = |suffix: &str| format!("blocks.{b}.{suffix}");
            let w_q = self.op(&p("attn.w_q"));
            let w_k = self.op(&p("attn.w_k"));
            let w_v = self.op(&p("attn.w_v"));
            let w_o = self.op(&p("attn.w_o"));
            let w_gate = self.op(&p("mlp.w_gate"));
            let w_up = self.op(&p("mlp.w_up"));
            let w_down = self.op(&p("mlp.w_down"));

            // ---- attention ----
            rms_norm_into(&x, self.vrow(&p("ln1.g")), &mut self.buf_h);
            w_q.matvec(&self.buf_h, &mut self.buf_q);
            w_k.matvec(&self.buf_h, &mut self.buf_k);
            w_v.matvec(&self.buf_h, &mut self.buf_v);
            for h in 0..heads {
                rope_rotate(&mut self.buf_q[h * hd..(h + 1) * hd], pos);
                rope_rotate(&mut self.buf_k[h * hd..(h + 1) * hd], pos);
            }
            {
                let c = &mut self.cache[b];
                c.k[slot * d..(slot + 1) * d].copy_from_slice(&self.buf_k);
                c.v[slot * d..(slot + 1) * d].copy_from_slice(&self.buf_v);
            }
            // softmax attention per head over the cached window; keys
            // carry their absolute-position rotation, so ring order is
            // irrelevant to the weighted sum
            let c = &self.cache[b];
            for h in 0..heads {
                let off = h * hd;
                let q = &self.buf_q[off..off + hd];
                let mut max = f32::NEG_INFINITY;
                for j in 0..n_ctx {
                    let krow = &c.k[j * d + off..j * d + off + hd];
                    let mut s = 0.0f32;
                    for i in 0..hd {
                        s += q[i] * krow[i];
                    }
                    let s = s * scale;
                    self.scores[j] = s;
                    if s > max {
                        max = s;
                    }
                }
                let mut denom = 0.0f32;
                for j in 0..n_ctx {
                    self.scores[j] = (self.scores[j] - max).exp();
                    denom += self.scores[j];
                }
                let inv = 1.0 / denom.max(1e-30);
                self.buf_att[off..off + hd].fill(0.0);
                for j in 0..n_ctx {
                    let a = self.scores[j] * inv;
                    let vrow = &c.v[j * d + off..j * d + off + hd];
                    for i in 0..hd {
                        self.buf_att[off + i] += a * vrow[i];
                    }
                }
            }
            w_o.matvec(&self.buf_att, &mut self.buf_o);
            for i in 0..d {
                x[i] += self.buf_o[i];
            }

            // ---- gated FFN: w_down · (SiLU(w_gate·h) ⊙ (w_up·h)) ----
            rms_norm_into(&x, self.vrow(&p("ln2.g")), &mut self.buf_h);
            w_gate.matvec(&self.buf_h, &mut self.buf_gate);
            w_up.matvec(&self.buf_h, &mut self.buf_up);
            for i in 0..self.buf_gate.len() {
                let g = self.buf_gate[i];
                self.buf_gate[i] = g / (1.0 + (-g).exp()) * self.buf_up[i];
            }
            w_down.matvec(&self.buf_gate, &mut self.buf_o);
            for i in 0..d {
                x[i] += self.buf_o[i];
            }
        }

        rms_norm_into(&x, self.vrow("ln_out.g"), &mut self.buf_h);
        logits.clear();
        logits.resize(vocab, 0.0);
        self.op("head").matvec(&self.buf_h, logits);
        self.buf_x = x;
        self.pos = pos + 1;
    }

    /// Forward a token sequence, returning logits at every position.
    pub fn forward_sequence(&mut self, tokens: &[usize]) -> Vec<Vec<f32>> {
        tokens.iter().map(|&t| self.forward_token(t)).collect()
    }
}

/// RMSNorm with gain: `x_i / sqrt(mean(x²) + ε) · g_i` (LLaMA has no
/// bias or mean-centering).
pub fn rms_norm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    let n = x.len() as f64;
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = ((x[i] as f64 * inv) as f32) * g[i];
    }
}

/// Rotary position embedding over one head's slice: pair `(i, i+half)`
/// rotates by `pos · 10000^(-2i/hd)`. Angles go through f64 so every
/// platform (including wasm) computes bit-identical rotations.
fn rope_rotate(v: &mut [f32], pos: usize) {
    let hd = v.len();
    let half = hd / 2;
    for i in 0..half {
        let theta = (pos as f64) * 10000f64.powf(-2.0 * i as f64 / hd as f64);
        let (sin, cos) = (theta.sin() as f32, theta.cos() as f32);
        let (a, b) = (v[i], v[i + half]);
        v[i] = a * cos - b * sin;
        v[i + half] = a * sin + b * cos;
    }
}

/// Initialise a LLaMA-shaped parameter set (Gaussian init; the synthetic
/// family generator overwrites the matmul weights with archetypes).
pub fn init_params(cfg: &ModelConfig, rng: &mut Rng) -> ModelWeights {
    let d = cfg.d_model;
    let ffn = cfg.ffn_dim();
    let mut m = ModelWeights::new(cfg.clone());

    let mat = |rng: &mut Rng, rows: usize, cols: usize| {
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut w.data, 0.0, 1.0 / (cols as f32).sqrt());
        w
    };

    let mut emb = Matrix::zeros(cfg.vocab, d);
    rng.fill_normal(&mut emb.data, 0.0, 0.02);
    m.push("emb", ParamClass::Embedding, emb);
    for b in 0..cfg.n_layer {
        let p = |s: &str| format!("blocks.{b}.{s}");
        m.push(p("ln1.g"), ParamClass::Vector, Matrix::filled(1, d, 1.0));
        for w in ["attn.w_q", "attn.w_k", "attn.w_v", "attn.w_o"] {
            m.push(p(w), ParamClass::MatMul, mat(rng, d, d));
        }
        m.push(p("ln2.g"), ParamClass::Vector, Matrix::filled(1, d, 1.0));
        m.push(p("mlp.w_gate"), ParamClass::MatMul, mat(rng, ffn, d));
        m.push(p("mlp.w_up"), ParamClass::MatMul, mat(rng, ffn, d));
        m.push(p("mlp.w_down"), ParamClass::MatMul, mat(rng, d, ffn));
    }
    m.push("ln_out.g", ParamClass::Vector, Matrix::filled(1, d, 1.0));
    m.push("head", ParamClass::Embedding, mat(rng, cfg.vocab, d));
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelWeights {
        init_params(&ModelConfig::llama(2, 16, 32), &mut Rng::new(7))
    }

    #[test]
    fn forward_produces_finite_logits() {
        let m = tiny();
        let mut run = LlamaRunner::new(&m);
        for t in [0usize, 5, 31] {
            let logits = run.forward_token(t);
            assert_eq!(logits.len(), 32);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn attention_carries_context() {
        let m = tiny();
        let mut run = LlamaRunner::new(&m);
        let _ = run.forward_token(1);
        let with_ctx = run.forward_token(2);
        run.reset();
        let without_ctx = run.forward_token(2);
        let diff: f32 = with_ctx
            .iter()
            .zip(&without_ctx)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-5, "context must change logits (diff={diff})");
    }

    #[test]
    fn reset_restores_determinism() {
        let m = tiny();
        let mut run = LlamaRunner::new(&m);
        let a = run.forward_sequence(&[3, 1, 4, 1, 5]);
        run.reset();
        let b = run.forward_sequence(&[3, 1, 4, 1, 5]);
        assert_eq!(a, b);
    }

    #[test]
    fn position_matters_through_rope() {
        // the same token at positions 0 and 1 must attend differently —
        // RoPE rotates its key/query, so the logits cannot coincide
        let m = tiny();
        let mut run = LlamaRunner::new(&m);
        let first = run.forward_token(4);
        let second = run.forward_token(4);
        let diff: f32 = first.iter().zip(&second).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "RoPE must distinguish positions (diff={diff})");
    }

    #[test]
    fn sliding_window_stays_stable_past_the_window() {
        let m = tiny();
        let mut run = LlamaRunner::with_window(&m, 4);
        let toks: Vec<usize> = (0..40).map(|i| i % 32).collect();
        let out = run.forward_sequence(&toks);
        assert_eq!(run.pos, 40);
        assert!(out.last().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn window_edge_attends_only_to_cached_positions() {
        // once the window slides, an evicted position must stop
        // influencing the output. In a 1-layer model the cached K/V of
        // position j depend only on (token_j, j) — no attention feeds
        // them — so two different prefixes followed by the same
        // window-filling suffix must converge to identical logits the
        // moment the prefix is evicted.
        let m = init_params(&ModelConfig::llama(1, 16, 32), &mut Rng::new(11));
        let suffix: Vec<usize> = (0..4).map(|i| (i * 5 + 1) % 32).collect();
        let mut run_a = LlamaRunner::with_window(&m, 4);
        let mut run_b = LlamaRunner::with_window(&m, 4);
        let _ = run_a.forward_token(9);
        let _ = run_b.forward_token(23);
        let mut last_a = Vec::new();
        let mut last_b = Vec::new();
        for &t in &suffix {
            last_a = run_a.forward_token(t);
            last_b = run_b.forward_token(t);
        }
        // the final step sees an identical 4-token window at identical
        // absolute positions 1..=4 in both runs
        assert_eq!(last_a, last_b, "evicted positions must not leak into the window");
    }

    #[test]
    fn runner_over_quantized_provider_matches_dense_on_fp32_layers() {
        use crate::model::QuantizedModel;
        use std::collections::HashMap as Map;
        // a QuantizedModel with no quantized layers must reproduce the
        // dense forward exactly (all entries fall back to Dense copies)
        let m = tiny();
        let qm = QuantizedModel::from_parts(&m, &Map::new());
        let mut dense = LlamaRunner::new(&m);
        let mut served = LlamaRunner::new(&qm);
        for t in [1usize, 9, 30, 2] {
            assert_eq!(dense.forward_token(t), served.forward_token(t));
        }
    }

    #[test]
    fn rms_norm_scales_to_unit_rms() {
        let x = vec![3.0f32, -3.0, 3.0, -3.0];
        let g = vec![1.0f32; 4];
        let mut y = vec![0.0f32; 4];
        rms_norm_into(&x, &g, &mut y);
        let rms: f32 = (y.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-3, "rms={rms}");
    }

    #[test]
    fn inventory_has_seven_matmuls_per_block() {
        let cfg = ModelConfig::llama(3, 64, 128);
        let m = init_params(&cfg, &mut Rng::new(1));
        let matmuls = m
            .layers
            .iter()
            .filter(|(d, _)| d.class == ParamClass::MatMul)
            .count();
        assert_eq!(matmuls, 3 * 7);
    }

    #[test]
    fn no_elementwise_weights_in_llama() {
        let cfg = ModelConfig::llama(2, 64, 128);
        let m = init_params(&cfg, &mut Rng::new(2));
        assert!(
            m.layers.iter().all(|(d, _)| d.class != ParamClass::ElementWise),
            "LLaMA has no μ ⊙ x weights — that is the RWKV-specific structure"
        );
    }
}
