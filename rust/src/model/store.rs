//! Weight store: layer inventory + tensors + binary interchange formats.
//!
//! Two on-disk formats live here, both little-endian:
//!
//! **`RWKVQ1`** — dense fp32, written by `python/compile/train.py` after
//! the tiny-corpus training run and read here; the quantization pipeline
//! can also persist a dequantized store for the PJRT runtime. Layout:
//!
//! ```text
//! magic   8  b"RWKVQ1\0\0"
//! arch    u32 len + utf8
//! n_layer u32, d_model u32, vocab u32, head_dim u32, ffn_ratio f64
//! count   u32
//! per layer:
//!   name  u32 len + utf8
//!   class u8 (0=MatMul,1=ElementWise,2=Vector,3=Embedding)
//!   rows  u64, cols u64
//!   data  rows*cols f32
//! ```
//!
//! **`RWKVQ2`** — the packed checkpoint format: a
//! [`crate::model::QuantizedModel`] serialized as-is, so load never
//! re-quantizes and never materialises fp32 weights. Layout:
//!
//! ```text
//! magic   8  b"RWKVQ2\0\0"
//! header  arch/n_layer/d_model/vocab/head_dim/ffn_ratio/count (as v1)
//! TOC     count records: name, class, kind
//!           (0=DenseF16, 1=Sq, 2=Vq), rows, cols,
//!           kind-specific metadata + absolute payload offsets
//! payload 64-byte-aligned arrays: packed code/index bitstreams
//!           (u64 words), f16 dense data (u16), f32 scale/min/
//!           codebook/tail/col-scale metadata
//! ```
//!
//! Every payload offset is 64-byte aligned, so [`open_rwkvq2`] in mmap
//! mode ([`LoadMode`]) can borrow the bitstreams and f16 dense payloads
//! **zero-copy** out of the mapping (`PackedBytes::Mapped` /
//! `F16Tensor::from_mapped`): open cost is O(header + TOC + f32
//! metadata) and the weight pages fault in lazily on first matvec. The
//! buffered mode reads the file once and owns every payload — the
//! portable fallback (non-unix, big-endian). Scalar grids store one f32
//! scale/min pair per group on disk so a save→open round trip is
//! bit-exact against the in-memory model (the bpw *accounting* keeps the
//! paper's fp16-per-group convention).
//!
//! Writing goes through the **streaming** [`Rwkvq2Writer`]: entries are
//! declared up front (fixing the TOC size), payloads are appended one
//! entry at a time with dense f32 → f16 narrowing chunked through a
//! bounded buffer, and the TOC is backpatched on finish — so packing
//! never holds a second (narrowed) copy of the model in memory.

use crate::config::ModelConfig;
use crate::model::qmodel::{QuantizedModel, ServedParam};
use crate::quant::packing::{MappedWords, PackedBytes, PackedInts};
use crate::quant::{LayerKind, QuantizedLayer, SqLayer, VqLayer};
use crate::tensor::f16::{f16_to_f32, f32_to_f16, F16Tensor};
use crate::tensor::Matrix;
use crate::util::mmap::Mmap;
use crate::Result;
use anyhow::{bail, Context};
use std::io::{Read, Write};
use std::sync::Arc;

/// Parameter classification — drives quantizability and the §3.2 path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamClass {
    /// 2-D projection weight (quantizable, matmul semantics)
    MatMul,
    /// element-wise multiplication weight μ (quantizable, §3.2 semantics)
    ElementWise,
    /// 1-D auxiliary vector: LayerNorm gain/bias, decay w, bonus u
    /// (never quantized)
    Vector,
    /// token embedding / LM head (kept fp16, as in all compared PTQ work)
    Embedding,
}

impl ParamClass {
    pub fn quantizable(&self) -> bool {
        matches!(self, ParamClass::MatMul | ParamClass::ElementWise)
    }

    pub fn kind(&self) -> LayerKind {
        match self {
            ParamClass::ElementWise => LayerKind::ElementWise,
            _ => LayerKind::MatMul,
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            ParamClass::MatMul => 0,
            ParamClass::ElementWise => 1,
            ParamClass::Vector => 2,
            ParamClass::Embedding => 3,
        }
    }

    fn from_u8(v: u8) -> Result<ParamClass> {
        Ok(match v {
            0 => ParamClass::MatMul,
            1 => ParamClass::ElementWise,
            2 => ParamClass::Vector,
            3 => ParamClass::Embedding,
            other => bail!("bad ParamClass tag {other}"),
        })
    }
}

/// One named parameter.
#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub name: String,
    pub class: ParamClass,
}

/// A model: config + ordered named tensors.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: ModelConfig,
    pub layers: Vec<(LayerDesc, Matrix)>,
}

impl ModelWeights {
    pub fn new(config: ModelConfig) -> Self {
        ModelWeights { config, layers: Vec::new() }
    }

    pub fn push(&mut self, name: impl Into<String>, class: ParamClass, m: Matrix) {
        self.layers.push((LayerDesc { name: name.into(), class }, m));
    }

    pub fn get(&self, name: &str) -> Option<&Matrix> {
        self.layers.iter().find(|(d, _)| d.name == name).map(|(_, m)| m)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Matrix> {
        self.layers.iter_mut().find(|(d, _)| d.name == name).map(|(_, m)| m)
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|(_, m)| m.numel()).sum()
    }

    /// Quantizable parameter count (the denominator of the bpw average).
    pub fn n_quantizable(&self) -> usize {
        self.layers
            .iter()
            .filter(|(d, _)| d.class.quantizable())
            .map(|(_, m)| m.numel())
            .sum()
    }

    /// Indices of the quantizable layers.
    pub fn quantizable_indices(&self) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].0.class.quantizable())
            .collect()
    }

    // ---- binary interchange ----

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(b"RWKVQ1\0\0")?;
        write_str(&mut f, &self.config.arch)?;
        f.write_all(&(self.config.n_layer as u32).to_le_bytes())?;
        f.write_all(&(self.config.d_model as u32).to_le_bytes())?;
        f.write_all(&(self.config.vocab as u32).to_le_bytes())?;
        f.write_all(&(self.config.head_dim as u32).to_le_bytes())?;
        f.write_all(&self.config.ffn_ratio.to_le_bytes())?;
        f.write_all(&(self.layers.len() as u32).to_le_bytes())?;
        for (d, m) in &self.layers {
            write_str(&mut f, &d.name)?;
            f.write_all(&[d.class.to_u8()])?;
            f.write_all(&(m.rows as u64).to_le_bytes())?;
            f.write_all(&(m.cols as u64).to_le_bytes())?;
            // bulk f32 write
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(m.data.as_ptr() as *const u8, m.data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<ModelWeights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let (config, count) = read_v1_header(&mut f).with_context(|| format!("in {path:?}"))?;
        let mut layers = Vec::with_capacity(count);
        for _ in 0..count {
            layers.push(read_v1_entry(&mut f)?);
        }
        Ok(ModelWeights { config, layers })
    }
}

/// Parse the RWKVQ1 header (magic + config + entry count), leaving the
/// reader positioned at the first entry.
fn read_v1_header<R: Read>(f: &mut R) -> Result<(ModelConfig, usize)> {
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC_V1 {
        bail!("bad RWKVQ1 magic");
    }
    let arch = read_str(f)?;
    let n_layer = read_u32(f)? as usize;
    let d_model = read_u32(f)? as usize;
    let vocab = read_u32(f)? as usize;
    let head_dim = read_u32(f)? as usize;
    let mut fr = [0u8; 8];
    f.read_exact(&mut fr)?;
    let ffn_ratio = f64::from_le_bytes(fr);
    let config = ModelConfig { arch, n_layer, d_model, vocab, head_dim, ffn_ratio };
    let count = read_u32(f)? as usize;
    if count > 1 << 20 {
        bail!("entry count {count} implausible");
    }
    Ok((config, count))
}

/// Parse one RWKVQ1 entry (name/class/shape + fp32 data) at the reader's
/// current position.
fn read_v1_entry<R: Read>(f: &mut R) -> Result<(LayerDesc, Matrix)> {
    let name = read_str(f)?;
    let mut tag = [0u8; 1];
    f.read_exact(&mut tag)?;
    let class = ParamClass::from_u8(tag[0])?;
    let rows = read_u64(f)? as usize;
    let cols = read_u64(f)? as usize;
    let numel = rows
        .checked_mul(cols)
        .with_context(|| format!("'{name}': numel overflow"))?;
    if numel > 1 << 31 {
        bail!("'{name}': shape {rows}x{cols} implausible");
    }
    let mut data = vec![0f32; numel];
    let bytes: &mut [u8] = unsafe {
        std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, data.len() * 4)
    };
    f.read_exact(bytes)?;
    Ok((LayerDesc { name, class }, Matrix { rows, cols, data }))
}

/// Streaming entry-by-entry reader over an RWKVQ1 dense store.
///
/// `ModelWeights::load` materialises the whole model; this reader holds
/// **one layer's** fp32 data resident at a time — the O(one-layer) RSS
/// bound that lets `rwkvquant quantize --streaming` pack models larger
/// than RAM. The v1 layout (name/class/shape then data, entry after
/// entry) makes this trivial: each `next_entry` call reads exactly one
/// record. Multi-pass drivers (proxy scan, then quantize+write) simply
/// open the file once per pass.
pub struct Rwkvq1Reader {
    f: std::io::BufReader<std::fs::File>,
    config: ModelConfig,
    count: usize,
    next: usize,
}

impl Rwkvq1Reader {
    /// Open a v1 store and parse its header; no tensor data is read yet.
    pub fn open(path: &std::path::Path) -> Result<Rwkvq1Reader> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let (config, count) = read_v1_header(&mut f).with_context(|| format!("in {path:?}"))?;
        Ok(Rwkvq1Reader { f, config, count, next: 0 })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.config
    }

    /// Total entries declared in the header.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Read the next entry, or `None` once every declared entry was
    /// consumed. The returned matrix is the only tensor resident.
    pub fn next_entry(&mut self) -> Result<Option<(LayerDesc, Matrix)>> {
        if self.next >= self.count {
            return Ok(None);
        }
        self.next += 1;
        read_v1_entry(&mut self.f).map(Some)
    }
}

fn write_str<W: Write>(f: &mut W, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_u32<R: Read>(f: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(f: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_str<R: Read>(f: &mut R) -> Result<String> {
    let len = read_u32(f)? as usize;
    if len > 1 << 20 {
        bail!("string length {len} implausible");
    }
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

// ---- RWKVQ2: the packed checkpoint format ----

const MAGIC_V1: &[u8; 8] = b"RWKVQ1\0\0";
const MAGIC_V2: &[u8; 8] = b"RWKVQ2\0\0";
/// Every payload array starts on a 64-byte boundary: cache-line
/// friendly, and ≥ the 8-byte alignment the zero-copy `u64` word views
/// require.
const PAYLOAD_ALIGN: usize = 64;

const KIND_DENSE_F16: u8 = 0;
const KIND_SQ: u8 = 1;
const KIND_VQ: u8 = 2;

fn align_up(x: usize) -> usize {
    x.div_ceil(PAYLOAD_ALIGN) * PAYLOAD_ALIGN
}

/// How [`open_rwkvq2`] acquires the file bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadMode {
    /// Memory-map when the host supports it, else buffered.
    Auto,
    /// Memory-map (error on hosts without mmap support).
    Mmap,
    /// Read the whole file once; every payload is owned.
    Buffered,
}

/// Which on-disk format a store file carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreFormat {
    /// `RWKVQ1` — dense fp32 ([`ModelWeights`]).
    V1Dense,
    /// `RWKVQ2` — packed quantized ([`QuantizedModel`]).
    V2Packed,
}

/// Sniff the magic of a store file.
pub fn detect_format(path: &std::path::Path) -> Result<StoreFormat> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).with_context(|| format!("read magic of {path:?}"))?;
    match &magic {
        m if m == MAGIC_V1 => Ok(StoreFormat::V1Dense),
        m if m == MAGIC_V2 => Ok(StoreFormat::V2Packed),
        other => bail!("{path:?} is not an RWKVQ store (magic {other:?})"),
    }
}

fn w_u32<W: Write>(f: &mut W, v: u32) -> Result<()> {
    f.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u64<W: Write>(f: &mut W, v: u64) -> Result<()> {
    f.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64s<W: Write>(f: &mut W, v: &[u64]) -> Result<()> {
    for w in v {
        f.write_all(&w.to_le_bytes())?;
    }
    Ok(())
}

fn write_u16s<W: Write>(f: &mut W, v: &[u16]) -> Result<()> {
    for w in v {
        f.write_all(&w.to_le_bytes())?;
    }
    Ok(())
}

fn write_f32s<W: Write>(f: &mut W, v: &[f32]) -> Result<()> {
    for w in v {
        f.write_all(&w.to_le_bytes())?;
    }
    Ok(())
}

/// Values narrowed per chunk by the streaming f32 → f16 dense writer —
/// the writer's only transient buffer, bounded regardless of entry size.
const NARROW_CHUNK: usize = 8192;

/// What kind of RWKVQ2 entry a [`ServedParam`] serializes as.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryKind {
    DenseF16,
    Sq,
    Vq,
}

impl EntryKind {
    fn tag(self) -> u8 {
        match self {
            EntryKind::DenseF16 => KIND_DENSE_F16,
            EntryKind::Sq => KIND_SQ,
            EntryKind::Vq => KIND_VQ,
        }
    }

    /// TOC-record bytes past the common name/class/kind/shape prefix.
    fn meta_len(self) -> usize {
        match self {
            EntryKind::DenseF16 => 8,
            EntryKind::Sq => 61,
            EntryKind::Vq => 52,
        }
    }
}

/// Declaration of one upcoming entry: exactly what the TOC sizing needs
/// **before** any payload bytes exist, so [`Rwkvq2Writer`] can reserve
/// the table of contents up front and a caller can stream entries one
/// at a time without ever holding the whole model resident.
#[derive(Debug, Clone)]
pub struct EntryDecl {
    pub name: String,
    pub class: ParamClass,
    pub kind: EntryKind,
}

impl EntryDecl {
    /// Classify (and validate) how `p` will serialize — the write-side
    /// mirror of the loader's `servable_packed` gate.
    pub fn of(desc: &LayerDesc, p: &ServedParam) -> Result<EntryDecl> {
        let kind = match p {
            ServedParam::Dense(_)
            | ServedParam::DenseF16(_)
            | ServedParam::Packed(QuantizedLayer::Fp16 { .. }) => EntryKind::DenseF16,
            ServedParam::Packed(QuantizedLayer::Sq(l)) => {
                if l.rotation.is_some() {
                    bail!("'{}': QuaRot payloads are served dense and cannot be packed", desc.name);
                }
                let groups = l.numel().div_ceil(l.group_size);
                if l.scales.len() != groups || l.mins.len() != groups {
                    bail!("'{}': scale/min count does not match the group count", desc.name);
                }
                EntryKind::Sq
            }
            ServedParam::Packed(QuantizedLayer::Vq(l)) => {
                // mirror qmodel::servable_packed — matvec_vq gathers per
                // row and silently drops a flat tail in release builds
                if l.d == 0 || l.cols % l.d != 0 || !l.tail.is_empty() {
                    bail!("'{}': only row-tiling VQ layers (no tail) serve packed", desc.name);
                }
                EntryKind::Vq
            }
        };
        Ok(EntryDecl { name: desc.name.clone(), class: desc.class, kind })
    }

    /// Exact TOC record length in bytes (checked against the actual
    /// record in [`Rwkvq2Writer::write_entry`]).
    fn record_len(&self) -> usize {
        4 + self.name.len() + 1 + 1 + 8 + 8 + self.kind.meta_len()
    }
}

/// Streaming RWKVQ2 writer: declare every entry up front (names and
/// kinds only — that fixes the TOC size), then feed payloads **one
/// entry at a time** in declaration order, then [`Rwkvq2Writer::finish`]
/// seeks back and fills in the table of contents. Dense f32 entries are
/// narrowed to f16 through a bounded chunk buffer during their write,
/// so peak writer memory is O([`NARROW_CHUNK`]) + the entry currently
/// being written — never a second copy of the model (the PR-3 ROADMAP
/// leftover). [`save_rwkvq2`] is this writer driven over an in-memory
/// [`QuantizedModel`]; the byte output is identical either way
/// (asserted by `streaming_writer_bytes_identical_to_save`).
pub struct Rwkvq2Writer {
    file: std::io::BufWriter<std::fs::File>,
    decls: Vec<EntryDecl>,
    /// Accumulated real TOC records, backpatched over the placeholder
    /// on finish.
    toc: Vec<u8>,
    toc_start: usize,
    toc_len: usize,
    /// Bytes written to the file so far (absolute).
    pos: usize,
    /// Next aligned payload-offset assignment.
    cursor: usize,
    /// Next entry index expected by `write_entry`.
    next: usize,
    narrow_buf: Vec<u16>,
}

impl Rwkvq2Writer {
    /// Write the header and reserve the TOC region for `decls`.
    pub fn create(
        path: &std::path::Path,
        config: &ModelConfig,
        decls: Vec<EntryDecl>,
    ) -> Result<Rwkvq2Writer> {
        let mut head: Vec<u8> = Vec::new();
        head.write_all(MAGIC_V2)?;
        write_str(&mut head, &config.arch)?;
        w_u32(&mut head, config.n_layer as u32)?;
        w_u32(&mut head, config.d_model as u32)?;
        w_u32(&mut head, config.vocab as u32)?;
        w_u32(&mut head, config.head_dim as u32)?;
        head.write_all(&config.ffn_ratio.to_le_bytes())?;
        w_u32(&mut head, decls.len() as u32)?;
        let toc_start = head.len();
        let toc_len: usize = decls.iter().map(EntryDecl::record_len).sum();
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        file.write_all(&head)?;
        // placeholder TOC — finish() seeks back over it
        file.write_all(&vec![0u8; toc_len])?;
        let pos = toc_start + toc_len;
        Ok(Rwkvq2Writer {
            file,
            decls,
            toc: Vec::with_capacity(toc_len),
            toc_start,
            toc_len,
            pos,
            cursor: align_up(pos),
            next: 0,
            narrow_buf: Vec::new(),
        })
    }

    fn pad_to(&mut self, target: usize) -> Result<()> {
        const ZEROS: [u8; PAYLOAD_ALIGN] = [0u8; PAYLOAD_ALIGN];
        while self.pos < target {
            let n = (target - self.pos).min(PAYLOAD_ALIGN);
            self.file.write_all(&ZEROS[..n])?;
            self.pos += n;
        }
        Ok(())
    }

    /// Claim the next aligned payload window and pad up to it.
    fn begin_payload(&mut self, size: usize) -> Result<usize> {
        let off = self.cursor;
        self.cursor = align_up(off + size);
        self.pad_to(off)?;
        Ok(off)
    }

    fn payload_u64s(&mut self, v: &[u64]) -> Result<u64> {
        let off = self.begin_payload(v.len() * 8)?;
        write_u64s(&mut self.file, v)?;
        self.pos += v.len() * 8;
        Ok(off as u64)
    }

    fn payload_f32s(&mut self, v: &[f32]) -> Result<u64> {
        let off = self.begin_payload(v.len() * 4)?;
        write_f32s(&mut self.file, v)?;
        self.pos += v.len() * 4;
        Ok(off as u64)
    }

    fn payload_u16s(&mut self, v: &[u16]) -> Result<u64> {
        let off = self.begin_payload(v.len() * 2)?;
        write_u16s(&mut self.file, v)?;
        self.pos += v.len() * 2;
        Ok(off as u64)
    }

    /// Stream-narrow an f32 payload to on-disk f16 through the bounded
    /// chunk buffer — never a whole-entry u16 copy.
    fn payload_f16_from_f32(&mut self, data: &[f32]) -> Result<u64> {
        let size = data.len() * 2;
        let off = self.begin_payload(size)?;
        let mut buf = std::mem::take(&mut self.narrow_buf);
        for chunk in data.chunks(NARROW_CHUNK) {
            buf.clear();
            buf.extend(chunk.iter().map(|&v| f32_to_f16(v)));
            write_u16s(&mut self.file, &buf)?;
        }
        self.narrow_buf = buf;
        self.pos += size;
        Ok(off as u64)
    }

    /// Serialize the next declared entry. Entries must arrive in
    /// declaration order with matching name/class/kind.
    pub fn write_entry(&mut self, desc: &LayerDesc, p: &ServedParam) -> Result<()> {
        let decl = self
            .decls
            .get(self.next)
            .cloned()
            .with_context(|| format!("'{}': more entries written than declared", desc.name))?;
        anyhow::ensure!(
            decl.name == desc.name && decl.class == desc.class,
            "entry {} is '{}' but '{}' was declared",
            self.next,
            desc.name,
            decl.name
        );
        let actual = EntryDecl::of(desc, p)?;
        anyhow::ensure!(
            actual.kind == decl.kind,
            "'{}': declared {:?} but the payload serializes as {:?}",
            desc.name,
            decl.kind,
            actual.kind
        );
        self.next += 1;

        let record_start = self.toc.len();
        write_str(&mut self.toc, &decl.name)?;
        self.toc.push(decl.class.to_u8());
        self.toc.push(decl.kind.tag());
        match p {
            ServedParam::Dense(m) => {
                w_u64(&mut self.toc, m.rows as u64)?;
                w_u64(&mut self.toc, m.cols as u64)?;
                let off = self.payload_f16_from_f32(&m.data)?;
                w_u64(&mut self.toc, off)?;
            }
            ServedParam::DenseF16(t) => {
                w_u64(&mut self.toc, t.rows as u64)?;
                w_u64(&mut self.toc, t.cols as u64)?;
                let off = self.payload_u16s(t.as_bits())?;
                w_u64(&mut self.toc, off)?;
            }
            ServedParam::Packed(QuantizedLayer::Fp16 { rows, cols, data }) => {
                w_u64(&mut self.toc, *rows as u64)?;
                w_u64(&mut self.toc, *cols as u64)?;
                let off = self.payload_f16_from_f32(data)?;
                w_u64(&mut self.toc, off)?;
            }
            ServedParam::Packed(QuantizedLayer::Sq(l)) => {
                w_u64(&mut self.toc, l.rows as u64)?;
                w_u64(&mut self.toc, l.cols as u64)?;
                let codes_off = self.payload_u64s(l.codes.words())?;
                let scales_off = self.payload_f32s(&l.scales)?;
                let mins_off = self.payload_f32s(&l.mins)?;
                let col_inv_off = match &l.col_inv_scale {
                    Some(inv) => self.payload_f32s(inv)?,
                    None => 0,
                };
                w_u32(&mut self.toc, l.bits)?;
                w_u64(&mut self.toc, l.group_size as u64)?;
                w_u64(&mut self.toc, l.extra_flops_per_token)?;
                w_u64(&mut self.toc, codes_off)?;
                w_u64(&mut self.toc, l.scales.len() as u64)?;
                w_u64(&mut self.toc, scales_off)?;
                w_u64(&mut self.toc, mins_off)?;
                self.toc.push(u8::from(l.col_inv_scale.is_some()));
                w_u64(&mut self.toc, col_inv_off)?;
            }
            ServedParam::Packed(QuantizedLayer::Vq(l)) => {
                w_u64(&mut self.toc, l.rows as u64)?;
                w_u64(&mut self.toc, l.cols as u64)?;
                let cb_off = self.payload_f32s(&l.codebook)?;
                let idx_off = self.payload_u64s(l.indices.words())?;
                // EntryDecl::of only admits tail-free layers
                let tail_off = 0u64;
                w_u64(&mut self.toc, l.d as u64)?;
                w_u32(&mut self.toc, l.k)?;
                w_u64(&mut self.toc, l.n_entries() as u64)?;
                w_u64(&mut self.toc, cb_off)?;
                w_u64(&mut self.toc, idx_off)?;
                w_u64(&mut self.toc, l.tail.len() as u64)?;
                w_u64(&mut self.toc, tail_off)?;
            }
        }
        debug_assert_eq!(
            self.toc.len() - record_start,
            decl.record_len(),
            "TOC sizing drifted"
        );
        Ok(())
    }

    /// Backpatch the real TOC over the placeholder and flush. Errors if
    /// any declared entry was never written.
    pub fn finish(mut self) -> Result<()> {
        use std::io::Seek;
        anyhow::ensure!(
            self.next == self.decls.len(),
            "{} entries declared but only {} written",
            self.decls.len(),
            self.next
        );
        assert_eq!(self.toc.len(), self.toc_len, "TOC sizing drifted");
        self.file.flush()?;
        let f = self.file.get_mut();
        f.seek(std::io::SeekFrom::Start(self.toc_start as u64))?;
        f.write_all(&self.toc)?;
        f.flush()?;
        Ok(())
    }
}

/// Serialize a [`QuantizedModel`] to the RWKVQ2 packed format (see the
/// module docs for the layout and alignment guarantees) by driving
/// [`Rwkvq2Writer`] over its entries — one entry resident in the write
/// path at a time.
pub fn save_rwkvq2(qm: &QuantizedModel, path: &std::path::Path) -> Result<()> {
    let mut decls = Vec::with_capacity(qm.entries.len());
    for (desc, p) in &qm.entries {
        decls.push(EntryDecl::of(desc, p)?);
    }
    let mut w = Rwkvq2Writer::create(path, &qm.config, decls)?;
    for (desc, p) in &qm.entries {
        w.write_entry(desc, p)?;
    }
    w.finish()
}

/// Bounds-checked byte cursor over a loaded/mapped RWKVQ2 file.
struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("RWKVQ2 offset overflow")?;
        if end > self.buf.len() {
            bail!("RWKVQ2 file truncated at byte {} (need {})", self.buf.len(), end);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > 1 << 20 {
            bail!("string length {len} implausible");
        }
        Ok(String::from_utf8(self.take(len)?.to_vec())?)
    }
}

/// Validate an absolute `n × elem`-byte payload window against the file
/// and return its offset as `usize`. All size math runs in u64 so a
/// crafted TOC cannot wrap a bounds check on any pointer width; the
/// returned offset (and `n * elem` downstream, both ≤ file length) are
/// then safe in `usize`.
fn checked_window(buf: &[u8], off: u64, n: u64, elem: u64, what: &str) -> Result<usize> {
    let bytes = n.checked_mul(elem).with_context(|| format!("{what}: payload size overflow"))?;
    let end = off.checked_add(bytes).with_context(|| format!("{what}: payload end overflow"))?;
    if end > buf.len() as u64 {
        bail!("{what}: payload [{off}, {end}) overruns the {}-byte file", buf.len());
    }
    Ok(off as usize)
}

fn f32s_at(buf: &[u8], off: u64, n: u64, what: &str) -> Result<Vec<f32>> {
    let off = checked_window(buf, off, n, 4, what)?;
    Ok(buf[off..off + n as usize * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn u16s_at(buf: &[u8], off: u64, n: u64, what: &str) -> Result<Vec<u16>> {
    let off = checked_window(buf, off, n, 2, what)?;
    Ok(buf[off..off + n as usize * 2]
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn u64s_at(buf: &[u8], off: u64, n: u64, what: &str) -> Result<Vec<u64>> {
    let off = checked_window(buf, off, n, 8, what)?;
    Ok(buf[off..off + n as usize * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Packed word payload: borrowed zero-copy from the mapping when one is
/// given, owned otherwise.
fn words_payload(
    buf: &[u8],
    map: Option<&Arc<Mmap>>,
    off: u64,
    words: u64,
    what: &str,
) -> Result<PackedBytes> {
    let off_usize = checked_window(buf, off, words, 8, what)?;
    match map {
        Some(m) => {
            if off % 8 != 0 {
                bail!("{what}: payload offset {off} is not 8-aligned");
            }
            Ok(PackedBytes::Mapped(MappedWords::new(m.clone(), off_usize, words as usize)))
        }
        None => Ok(PackedBytes::Owned(u64s_at(buf, off, words, what)?)),
    }
}

/// Open an RWKVQ2 packed checkpoint as a servable [`QuantizedModel`].
///
/// In mmap mode the code/index bitstreams and 2-D f16 dense payloads are
/// borrowed zero-copy from the mapping (pages fault in on first use);
/// f32 metadata (scales/mins/codebooks/tails) and 1-D dense vectors are
/// materialised eagerly — they are the O(metadata) fraction the runner
/// reads per token anyway.
pub fn open_rwkvq2(path: &std::path::Path, mode: LoadMode) -> Result<QuantizedModel> {
    let use_mmap = match mode {
        LoadMode::Mmap => true,
        LoadMode::Buffered => false,
        LoadMode::Auto => Mmap::supported(),
    };
    if use_mmap {
        let map = Arc::new(Mmap::open(path)?);
        parse_rwkvq2(map.as_bytes(), Some(&map))
            .with_context(|| format!("parsing mapped {path:?}"))
    } else {
        let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        parse_rwkvq2(&bytes, None).with_context(|| format!("parsing {path:?}"))
    }
}

/// Parse an RWKVQ2 checkpoint from a caller-supplied byte buffer. This
/// is the filesystem-less entry point for hosts without `std::fs` or
/// mmap (wasm32: fetched over the network or embedded in the bundle) —
/// every payload is copied out of `bytes`, so the buffer may be dropped
/// after the call.
pub fn open_rwkvq2_bytes(bytes: &[u8]) -> Result<QuantizedModel> {
    parse_rwkvq2(bytes, None).context("parsing RWKVQ2 byte buffer")
}

fn parse_rwkvq2(buf: &[u8], map: Option<&Arc<Mmap>>) -> Result<QuantizedModel> {
    let mut r = ByteReader { buf, pos: 0 };
    if r.take(8)? != MAGIC_V2.as_slice() {
        bail!("not an RWKVQ2 file (bad magic)");
    }
    let arch = r.str()?;
    let n_layer = r.u32()? as usize;
    let d_model = r.u32()? as usize;
    let vocab = r.u32()? as usize;
    let head_dim = r.u32()? as usize;
    let ffn_ratio = r.f64()?;
    let config = ModelConfig { arch, n_layer, d_model, vocab, head_dim, ffn_ratio };
    let count = r.u32()? as usize;
    if count > 1 << 20 {
        bail!("entry count {count} implausible");
    }
    let mut entries = Vec::with_capacity(count);
    for _ in 0..count {
        let name = r.str()?;
        let class = ParamClass::from_u8(r.u8()?)?;
        let kind = r.u8()?;
        // shape fields stay u64 until validated: the per-entry element
        // cap (2^31) keeps every later byte-size product inside u64 (and
        // inside usize on 32-bit buffered-fallback hosts)
        let rows64 = r.u64()?;
        let cols64 = r.u64()?;
        let numel64 = rows64
            .checked_mul(cols64)
            .with_context(|| format!("'{name}': numel overflow"))?;
        if numel64 > 1 << 31 {
            bail!("'{name}': shape {rows64}x{cols64} implausible");
        }
        let (rows, cols, numel) = (rows64 as usize, cols64 as usize, numel64 as usize);
        let served = match kind {
            KIND_DENSE_F16 => {
                let off = r.u64()?;
                let off_usize = checked_window(buf, off, numel64, 2, &name)?;
                if rows <= 1 {
                    // 1-D vectors stay f32-resident: the runner borrows
                    // their rows per token (O(d) each, exact after the
                    // writer's f16 narrowing)
                    let data = u16s_at(buf, off, numel64, &name)?;
                    let wide = data.iter().map(|&b| f16_to_f32(b)).collect();
                    ServedParam::Dense(Matrix::from_vec(rows, cols, wide))
                } else {
                    let t = match map {
                        Some(m) => {
                            if off % 2 != 0 {
                                bail!("'{name}': f16 payload offset {off} is not 2-aligned");
                            }
                            F16Tensor::from_mapped(rows, cols, m.clone(), off_usize)
                        }
                        None => {
                            F16Tensor::from_bits(rows, cols, u16s_at(buf, off, numel64, &name)?)
                        }
                    };
                    ServedParam::DenseF16(t)
                }
            }
            KIND_SQ => {
                let bits = r.u32()?;
                if !(1..=32).contains(&bits) {
                    bail!("'{name}': SQ bit-width {bits} out of range");
                }
                let group_size = r.u64()?;
                if !(1..=1 << 24).contains(&group_size) {
                    bail!("'{name}': SQ group size {group_size} out of range");
                }
                let extra_flops_per_token = r.u64()?;
                let codes_off = r.u64()?;
                let n_groups = r.u64()?;
                let scales_off = r.u64()?;
                let mins_off = r.u64()?;
                let has_col_inv = r.u8()?;
                let col_inv_off = r.u64()?;
                if n_groups != numel64.div_ceil(group_size) {
                    bail!("'{name}': group count {n_groups} inconsistent with shape");
                }
                let words = (numel64 * u64::from(bits)).div_ceil(64);
                let codes = PackedInts::from_raw(
                    bits,
                    numel,
                    words_payload(buf, map, codes_off, words, &name)?,
                );
                let scales = f32s_at(buf, scales_off, n_groups, &name)?;
                let mins = f32s_at(buf, mins_off, n_groups, &name)?;
                let col_inv_scale = match has_col_inv {
                    0 => None,
                    1 => Some(f32s_at(buf, col_inv_off, cols64, &name)?),
                    other => bail!("'{name}': bad col_inv flag {other}"),
                };
                ServedParam::Packed(QuantizedLayer::Sq(SqLayer {
                    rows,
                    cols,
                    bits,
                    group_size: group_size as usize,
                    codes,
                    scales,
                    mins,
                    extra_flops_per_token,
                    rotation: None,
                    col_inv_scale,
                }))
            }
            KIND_VQ => {
                let d64 = r.u64()?;
                if !(1..=1 << 16).contains(&d64) {
                    bail!("'{name}': VQ vector dim {d64} out of range");
                }
                let k = r.u32()?;
                if !(1..=32).contains(&k) {
                    bail!("'{name}': VQ index width {k} out of range");
                }
                let n_entries = r.u64()?;
                let cb_off = r.u64()?;
                let idx_off = r.u64()?;
                let tail_len = r.u64()?;
                let tail_off = r.u64()?;
                if cols64 % d64 != 0 {
                    // matvec_vq gathers per row; a non-tiling dim would
                    // silently drop columns in release builds
                    bail!("'{name}': VQ dim {d64} does not tile the row width {cols64}");
                }
                if tail_len != numel64 % d64 {
                    bail!("'{name}': tail length {tail_len} inconsistent with shape");
                }
                let d = d64 as usize;
                let nvec64 = numel64 / d64;
                let words = (nvec64 * u64::from(k)).div_ceil(64);
                let cb_len = n_entries
                    .checked_mul(d64)
                    .with_context(|| format!("'{name}': codebook size overflow"))?;
                if n_entries == 0 && nvec64 > 0 {
                    bail!("'{name}': empty codebook with {nvec64} coded vectors");
                }
                let codebook = f32s_at(buf, cb_off, cb_len, &name)?;
                let indices = PackedInts::from_raw(
                    k,
                    nvec64 as usize,
                    words_payload(buf, map, idx_off, words, &name)?,
                );
                // semantic check, buffered mode only: the payload is
                // already resident, so rejecting out-of-codebook indices
                // here is free — the mmap path stays O(TOC) and a
                // corrupt mapped index instead panics at first matvec
                if map.is_none() {
                    for v in 0..indices.len {
                        if u64::from(indices.get(v)) >= n_entries {
                            bail!("'{name}': VQ index {} exceeds the codebook", indices.get(v));
                        }
                    }
                }
                let tail = f32s_at(buf, tail_off, tail_len, &name)?;
                ServedParam::Packed(QuantizedLayer::Vq(VqLayer {
                    rows,
                    cols,
                    d,
                    k,
                    codebook,
                    indices,
                    tail,
                }))
            }
            other => bail!("'{name}': unknown entry kind {other}"),
        };
        entries.push((LayerDesc { name, class }, served));
    }
    Ok(QuantizedModel::from_entries(config, entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn demo_model() -> ModelWeights {
        let cfg = ModelConfig::rwkv6(2, 8, 16);
        let mut m = ModelWeights::new(cfg);
        let mut rng = Rng::new(1);
        let mut w = Matrix::zeros(8, 8);
        rng.fill_normal(&mut w.data, 0.0, 0.1);
        m.push("blocks.0.att.w_r", ParamClass::MatMul, w.clone());
        m.push("blocks.0.att.mu_r", ParamClass::ElementWise, Matrix::filled(1, 8, 0.5));
        m.push("blocks.0.ln1.g", ParamClass::Vector, Matrix::filled(1, 8, 1.0));
        m.push("emb", ParamClass::Embedding, Matrix::zeros(16, 8));
        m
    }

    #[test]
    fn save_load_round_trip() {
        let m = demo_model();
        let path = std::env::temp_dir().join("rwkvq_store_test.bin");
        m.save(&path).unwrap();
        let l = ModelWeights::load(&path).unwrap();
        assert_eq!(l.config, m.config);
        assert_eq!(l.layers.len(), 4);
        for ((da, ma), (db, mb)) in m.layers.iter().zip(&l.layers) {
            assert_eq!(da.name, db.name);
            assert_eq!(da.class, db.class);
            assert_eq!(ma, mb);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn streaming_v1_reader_matches_bulk_load() {
        let m = demo_model();
        let path = std::env::temp_dir().join("rwkvq_stream_reader.bin");
        m.save(&path).unwrap();
        let bulk = ModelWeights::load(&path).unwrap();
        let mut r = Rwkvq1Reader::open(&path).unwrap();
        assert_eq!(r.config(), &m.config);
        assert_eq!(r.count(), m.layers.len());
        let mut seen = 0usize;
        while let Some((desc, mat)) = r.next_entry().unwrap() {
            let (want_desc, want_mat) = &bulk.layers[seen];
            assert_eq!(desc.name, want_desc.name);
            assert_eq!(desc.class, want_desc.class);
            assert_eq!(&mat, want_mat);
            seen += 1;
        }
        assert_eq!(seen, m.layers.len());
        // exhausted reader keeps returning None
        assert!(r.next_entry().unwrap().is_none());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn quantizable_filtering() {
        let m = demo_model();
        let qi = m.quantizable_indices();
        assert_eq!(qi, vec![0, 1]);
        assert_eq!(m.n_quantizable(), 64 + 8);
        assert_eq!(m.n_params(), 64 + 8 + 8 + 128);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = std::env::temp_dir().join("rwkvq_badmagic.bin");
        std::fs::write(&path, b"NOTMAGIC________").unwrap();
        assert!(ModelWeights::load(&path).is_err());
        assert!(detect_format(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn detect_format_distinguishes_v1_and_v2() {
        let m = demo_model();
        let p1 = std::env::temp_dir().join("rwkvq_detect_v1.bin");
        m.save(&p1).unwrap();
        assert_eq!(detect_format(&p1).unwrap(), StoreFormat::V1Dense);
        let qm = QuantizedModel::from_parts(&m, &std::collections::HashMap::new());
        let p2 = std::env::temp_dir().join("rwkvq_detect_v2.bin");
        save_rwkvq2(&qm, &p2).unwrap();
        assert_eq!(detect_format(&p2).unwrap(), StoreFormat::V2Packed);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn v2_truncated_file_errors_cleanly() {
        let m = demo_model();
        let mut qm = QuantizedModel::from_parts(&m, &std::collections::HashMap::new());
        qm.dense_to_f16();
        let path = std::env::temp_dir().join("rwkvq_truncated_v2.bin");
        qm.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in [7usize, 40, full.len() / 2, full.len() - 3] {
            std::fs::write(&path, &full[..cut]).unwrap();
            // both load paths must report an error, never panic
            assert!(open_rwkvq2(&path, LoadMode::Buffered).is_err(), "cut at {cut}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_round_trip_of_unquantized_model_is_f16_exact() {
        use crate::model::WeightProvider;
        let m = demo_model();
        let mut qm = QuantizedModel::from_parts(&m, &std::collections::HashMap::new());
        qm.dense_to_f16();
        let path = std::env::temp_dir().join("rwkvq_v2_dense_roundtrip.bin");
        qm.save(&path).unwrap();
        for mode in [LoadMode::Buffered, LoadMode::Auto] {
            let back = open_rwkvq2(&path, mode).unwrap();
            assert_eq!(back.config, qm.config);
            assert_eq!(back.entries.len(), qm.entries.len());
            for i in 0..qm.n_entries() {
                assert_eq!(qm.entry_name(i), back.entry_name(i));
                let a = qm.materialize_at(i).into_owned();
                let b = back.materialize_at(i).into_owned();
                assert_eq!(a, b, "entry {} drifted through the round trip", qm.entry_name(i));
            }
        }
        std::fs::remove_file(path).ok();
    }

    /// A quantized model with real SQ + VQ payloads for writer tests.
    fn quantized_demo() -> QuantizedModel {
        use crate::config::QuantConfig;
        let cfg = ModelConfig::rwkv6(1, 32, 64);
        let m = crate::model::rwkv::init_params(&cfg, &mut Rng::new(13));
        let qc = QuantConfig { kmeans_iters: 4, vq_bits: 6, ..QuantConfig::default() };
        let (q, _) = crate::coordinator::quantize_model(&m, None, &qc, 2);
        let mut qm = QuantizedModel::from_parts(&m, &q);
        qm.dense_to_f16();
        qm
    }

    #[test]
    fn streaming_writer_bytes_identical_to_save() {
        let qm = quantized_demo();
        let via_save = std::env::temp_dir().join("rwkvq_stream_a.rwkvq2");
        let via_writer = std::env::temp_dir().join("rwkvq_stream_b.rwkvq2");
        save_rwkvq2(&qm, &via_save).unwrap();

        // drive the streaming API explicitly: declare, then feed one
        // entry at a time
        let decls: Vec<EntryDecl> =
            qm.entries.iter().map(|(d, p)| EntryDecl::of(d, p).unwrap()).collect();
        assert!(
            decls.iter().any(|d| d.kind == EntryKind::Sq),
            "demo model must exercise SQ payloads"
        );
        assert!(decls.iter().any(|d| d.kind == EntryKind::DenseF16));
        let mut w = Rwkvq2Writer::create(&via_writer, &qm.config, decls).unwrap();
        for (desc, p) in &qm.entries {
            w.write_entry(desc, p).unwrap();
        }
        w.finish().unwrap();

        let a = std::fs::read(&via_save).unwrap();
        let b = std::fs::read(&via_writer).unwrap();
        assert_eq!(a, b, "streaming writer output must be byte-identical to save()");

        // and the streamed file round-trips to the same served values
        let back = open_rwkvq2(&via_writer, LoadMode::Buffered).unwrap();
        use crate::model::WeightProvider;
        assert_eq!(back.n_entries(), qm.n_entries());
        for i in 0..qm.n_entries() {
            assert_eq!(
                qm.materialize_at(i).into_owned(),
                back.materialize_at(i).into_owned(),
                "entry {} drifted through the streamed file",
                qm.entry_name(i)
            );
        }
        std::fs::remove_file(via_save).ok();
        std::fs::remove_file(via_writer).ok();
    }

    #[test]
    fn streaming_writer_rejects_declaration_drift() {
        let qm = quantized_demo();
        let path = std::env::temp_dir().join("rwkvq_stream_drift.rwkvq2");
        let decls: Vec<EntryDecl> =
            qm.entries.iter().map(|(d, p)| EntryDecl::of(d, p).unwrap()).collect();
        let mut w = Rwkvq2Writer::create(&path, &qm.config, decls).unwrap();
        // write entry 1 where entry 0 was declared → name mismatch
        let (desc, p) = &qm.entries[1];
        assert!(w.write_entry(desc, p).is_err(), "out-of-order entry must be rejected");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn streaming_writer_requires_every_declared_entry() {
        let qm = quantized_demo();
        let path = std::env::temp_dir().join("rwkvq_stream_short.rwkvq2");
        let decls: Vec<EntryDecl> =
            qm.entries.iter().map(|(d, p)| EntryDecl::of(d, p).unwrap()).collect();
        let mut w = Rwkvq2Writer::create(&path, &qm.config, decls).unwrap();
        let (desc, p) = &qm.entries[0];
        w.write_entry(desc, p).unwrap();
        assert!(w.finish().is_err(), "finish with missing entries must be rejected");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn param_class_round_trip() {
        for c in [
            ParamClass::MatMul,
            ParamClass::ElementWise,
            ParamClass::Vector,
            ParamClass::Embedding,
        ] {
            assert_eq!(ParamClass::from_u8(c.to_u8()).unwrap(), c);
        }
        assert!(ParamClass::from_u8(9).is_err());
    }
}
