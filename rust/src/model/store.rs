//! Weight store: layer inventory + tensors + binary interchange format.
//!
//! The format (`RWKVQ1`) is written by `python/compile/train.py` after
//! the tiny-corpus training run and read here; the quantization pipeline
//! can also persist a dequantized store for the PJRT runtime. Layout
//! (little-endian):
//!
//! ```text
//! magic   8  b"RWKVQ1\0\0"
//! arch    u32 len + utf8
//! n_layer u32, d_model u32, vocab u32, head_dim u32, ffn_ratio f64
//! count   u32
//! per layer:
//!   name  u32 len + utf8
//!   class u8 (0=MatMul,1=ElementWise,2=Vector,3=Embedding)
//!   rows  u64, cols u64
//!   data  rows*cols f32
//! ```

use crate::config::ModelConfig;
use crate::quant::LayerKind;
use crate::tensor::Matrix;
use crate::Result;
use anyhow::{bail, Context};
use std::io::{Read, Write};

/// Parameter classification — drives quantizability and the §3.2 path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamClass {
    /// 2-D projection weight (quantizable, matmul semantics)
    MatMul,
    /// element-wise multiplication weight μ (quantizable, §3.2 semantics)
    ElementWise,
    /// 1-D auxiliary vector: LayerNorm gain/bias, decay w, bonus u
    /// (never quantized)
    Vector,
    /// token embedding / LM head (kept fp16, as in all compared PTQ work)
    Embedding,
}

impl ParamClass {
    pub fn quantizable(&self) -> bool {
        matches!(self, ParamClass::MatMul | ParamClass::ElementWise)
    }

    pub fn kind(&self) -> LayerKind {
        match self {
            ParamClass::ElementWise => LayerKind::ElementWise,
            _ => LayerKind::MatMul,
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            ParamClass::MatMul => 0,
            ParamClass::ElementWise => 1,
            ParamClass::Vector => 2,
            ParamClass::Embedding => 3,
        }
    }

    fn from_u8(v: u8) -> Result<ParamClass> {
        Ok(match v {
            0 => ParamClass::MatMul,
            1 => ParamClass::ElementWise,
            2 => ParamClass::Vector,
            3 => ParamClass::Embedding,
            other => bail!("bad ParamClass tag {other}"),
        })
    }
}

/// One named parameter.
#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub name: String,
    pub class: ParamClass,
}

/// A model: config + ordered named tensors.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: ModelConfig,
    pub layers: Vec<(LayerDesc, Matrix)>,
}

impl ModelWeights {
    pub fn new(config: ModelConfig) -> Self {
        ModelWeights { config, layers: Vec::new() }
    }

    pub fn push(&mut self, name: impl Into<String>, class: ParamClass, m: Matrix) {
        self.layers.push((LayerDesc { name: name.into(), class }, m));
    }

    pub fn get(&self, name: &str) -> Option<&Matrix> {
        self.layers.iter().find(|(d, _)| d.name == name).map(|(_, m)| m)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Matrix> {
        self.layers.iter_mut().find(|(d, _)| d.name == name).map(|(_, m)| m)
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|(_, m)| m.numel()).sum()
    }

    /// Quantizable parameter count (the denominator of the bpw average).
    pub fn n_quantizable(&self) -> usize {
        self.layers
            .iter()
            .filter(|(d, _)| d.class.quantizable())
            .map(|(_, m)| m.numel())
            .sum()
    }

    /// Indices of the quantizable layers.
    pub fn quantizable_indices(&self) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].0.class.quantizable())
            .collect()
    }

    // ---- binary interchange ----

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(b"RWKVQ1\0\0")?;
        write_str(&mut f, &self.config.arch)?;
        f.write_all(&(self.config.n_layer as u32).to_le_bytes())?;
        f.write_all(&(self.config.d_model as u32).to_le_bytes())?;
        f.write_all(&(self.config.vocab as u32).to_le_bytes())?;
        f.write_all(&(self.config.head_dim as u32).to_le_bytes())?;
        f.write_all(&self.config.ffn_ratio.to_le_bytes())?;
        f.write_all(&(self.layers.len() as u32).to_le_bytes())?;
        for (d, m) in &self.layers {
            write_str(&mut f, &d.name)?;
            f.write_all(&[d.class.to_u8()])?;
            f.write_all(&(m.rows as u64).to_le_bytes())?;
            f.write_all(&(m.cols as u64).to_le_bytes())?;
            // bulk f32 write
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(m.data.as_ptr() as *const u8, m.data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<ModelWeights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"RWKVQ1\0\0" {
            bail!("bad magic in {path:?}");
        }
        let arch = read_str(&mut f)?;
        let n_layer = read_u32(&mut f)? as usize;
        let d_model = read_u32(&mut f)? as usize;
        let vocab = read_u32(&mut f)? as usize;
        let head_dim = read_u32(&mut f)? as usize;
        let mut fr = [0u8; 8];
        f.read_exact(&mut fr)?;
        let ffn_ratio = f64::from_le_bytes(fr);
        let config = ModelConfig { arch, n_layer, d_model, vocab, head_dim, ffn_ratio };
        let count = read_u32(&mut f)? as usize;
        let mut layers = Vec::with_capacity(count);
        for _ in 0..count {
            let name = read_str(&mut f)?;
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let class = ParamClass::from_u8(tag[0])?;
            let rows = read_u64(&mut f)? as usize;
            let cols = read_u64(&mut f)? as usize;
            let mut data = vec![0f32; rows * cols];
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, data.len() * 4)
            };
            f.read_exact(bytes)?;
            layers.push((LayerDesc { name, class }, Matrix { rows, cols, data }));
        }
        Ok(ModelWeights { config, layers })
    }
}

fn write_str<W: Write>(f: &mut W, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_u32<R: Read>(f: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(f: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_str<R: Read>(f: &mut R) -> Result<String> {
    let len = read_u32(f)? as usize;
    if len > 1 << 20 {
        bail!("string length {len} implausible");
    }
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn demo_model() -> ModelWeights {
        let cfg = ModelConfig::rwkv6(2, 8, 16);
        let mut m = ModelWeights::new(cfg);
        let mut rng = Rng::new(1);
        let mut w = Matrix::zeros(8, 8);
        rng.fill_normal(&mut w.data, 0.0, 0.1);
        m.push("blocks.0.att.w_r", ParamClass::MatMul, w.clone());
        m.push("blocks.0.att.mu_r", ParamClass::ElementWise, Matrix::filled(1, 8, 0.5));
        m.push("blocks.0.ln1.g", ParamClass::Vector, Matrix::filled(1, 8, 1.0));
        m.push("emb", ParamClass::Embedding, Matrix::zeros(16, 8));
        m
    }

    #[test]
    fn save_load_round_trip() {
        let m = demo_model();
        let path = std::env::temp_dir().join("rwkvq_store_test.bin");
        m.save(&path).unwrap();
        let l = ModelWeights::load(&path).unwrap();
        assert_eq!(l.config, m.config);
        assert_eq!(l.layers.len(), 4);
        for ((da, ma), (db, mb)) in m.layers.iter().zip(&l.layers) {
            assert_eq!(da.name, db.name);
            assert_eq!(da.class, db.class);
            assert_eq!(ma, mb);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn quantizable_filtering() {
        let m = demo_model();
        let qi = m.quantizable_indices();
        assert_eq!(qi, vec![0, 1]);
        assert_eq!(m.n_quantizable(), 64 + 8);
        assert_eq!(m.n_params(), 64 + 8 + 8 + 128);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = std::env::temp_dir().join("rwkvq_badmagic.bin");
        std::fs::write(&path, b"NOTMAGIC________").unwrap();
        assert!(ModelWeights::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn param_class_round_trip() {
        for c in [
            ParamClass::MatMul,
            ParamClass::ElementWise,
            ParamClass::Vector,
            ParamClass::Embedding,
        ] {
            assert_eq!(ParamClass::from_u8(c.to_u8()).unwrap(), c);
        }
        assert!(ParamClass::from_u8(9).is_err());
    }
}
