//! Analytic FLOP / memory-byte accounting (Fig. 9, §A.3, and the §1
//! QuaRot-overhead claim).
//!
//! Counts are per generated token. The compute-to-memory-access ratio is
//! `FLOPs / bytes-moved`; weights dominate the byte traffic in decode,
//! which is why weight quantization converts directly into decode
//! speed-up on RWKV (the paper's deployment argument).

use crate::config::ModelConfig;

/// Per-token cost model for one architecture at a given serving point.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// concurrent sequences sharing a weight pass
    pub batch: usize,
    /// context length (LLaMA KV-cache traffic; RWKV state is O(1))
    pub context: usize,
    /// bytes per weight element (2 = fp16, 0.41 = 3.275 bpw, ...)
    pub weight_bytes: f64,
}

impl CostModel {
    pub fn edge_decode() -> CostModel {
        CostModel { batch: 1, context: 1024, weight_bytes: 2.0 }
    }
}

/// FLOPs and bytes for one decode step of the whole batch.
#[derive(Debug, Clone, Copy)]
pub struct StepCost {
    pub flops: f64,
    pub bytes: f64,
}

impl StepCost {
    pub fn ratio(&self) -> f64 {
        self.flops / self.bytes
    }
}

/// Quantizable/projection parameter count for an RWKV config
/// (matches `rwkv::init_params` exactly).
pub fn rwkv_matmul_params(cfg: &ModelConfig) -> u64 {
    let d = cfg.d_model as u64;
    let ffn = cfg.ffn_dim() as u64;
    let gated = cfg.arch == "rwkv7";
    let att = if gated { 5 * d * d } else { 4 * d * d };
    let ffn_p = d * d + 2 * ffn * d;
    (cfg.n_layer as u64) * (att + ffn_p)
}

/// Matmul parameter count for the LLaMA comparator.
pub fn llama_matmul_params(cfg: &ModelConfig) -> u64 {
    let d = cfg.d_model as u64;
    let ffn = cfg.ffn_dim() as u64;
    (cfg.n_layer as u64) * (4 * d * d + 3 * ffn * d)
}

/// Decode-step cost for an RWKV model: every weight is read once per
/// step (batch-shared); FLOPs are 2·params per sequence; the recurrent
/// state (a few vectors per block) is read+written per sequence.
pub fn rwkv_step(cfg: &ModelConfig, cm: &CostModel) -> StepCost {
    let params = rwkv_matmul_params(cfg) as f64;
    let d = cfg.d_model as f64;
    let l = cfg.n_layer as f64;
    let flops_seq = 2.0 * params + l * d * 40.0; // wkv + mixing elementwise
    let state_bytes_seq = l * d * 5.0 * 4.0 * 2.0; // aa,bb,pp,x_att,x_ffn r+w
    let act_bytes_seq = l * d * 16.0 * 4.0;
    StepCost {
        flops: cm.batch as f64 * flops_seq,
        bytes: params * cm.weight_bytes
            + cm.batch as f64 * (state_bytes_seq + act_bytes_seq),
    }
}

/// Decode-step cost for the LLaMA comparator: weights read once per
/// step, plus per-sequence KV-cache read of `2·L·T·d` fp16 values and
/// the attention FLOPs `4·T·d·L`.
pub fn llama_step(cfg: &ModelConfig, cm: &CostModel) -> StepCost {
    let params = llama_matmul_params(cfg) as f64;
    let d = cfg.d_model as f64;
    let l = cfg.n_layer as f64;
    let t = cm.context as f64;
    let flops_seq = 2.0 * params + 4.0 * t * d * l;
    let kv_bytes_seq = 2.0 * l * t * d * 2.0 + 2.0 * l * d * 2.0; // read + append
    let act_bytes_seq = l * d * 16.0 * 4.0;
    StepCost {
        flops: cm.batch as f64 * flops_seq,
        bytes: params * cm.weight_bytes + cm.batch as f64 * (kv_bytes_seq + act_bytes_seq),
    }
}

/// Extra per-token FLOPs QuaRot-style online rotation forces on an RWKV
/// model. In T-LLMs the rotation pair folds into neighbouring linear /
/// norm layers for free; in RWKV the fusion path is blocked by
/// token-shift / sigmoid / exp (§1 finding ❶), so every projection input
/// must be rotated *online*. Counted as a dense orthogonal multiply
/// (`2·ic²` per projection per token) — the paper's measured ">99 % FLOP
/// increase" on RWKV-7 corresponds to exactly this: one extra
/// square-matrix multiply per square projection.
pub fn quarot_overhead_flops(cfg: &ModelConfig) -> u64 {
    let d = cfg.d_model as u64;
    let ffn = cfg.ffn_dim() as u64;
    let gated = cfg.arch == "rwkv7";
    // projections with d-dim inputs: att r/k/v(+g) and o, ffn r/k
    let n_proj_d = if gated { 6 + 2 } else { 5 + 2 };
    // ffn.w_v consumes an ffn-dim input
    (cfg.n_layer as u64) * (n_proj_d * 2 * d * d + 2 * ffn * ffn)
}

/// Baseline per-token matmul FLOPs (for the overhead percentage).
pub fn rwkv_base_flops(cfg: &ModelConfig) -> u64 {
    2 * rwkv_matmul_params(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rwkv_edge_decode_is_memory_bound_near_one() {
        let cfg = ModelConfig::rwkv6(12, 384, 512);
        let c = rwkv_step(&cfg, &CostModel::edge_decode());
        // fp16 weights, batch 1: ~2 flops per 2 bytes -> ratio ≈ 1 (paper: 0.97)
        assert!(c.ratio() > 0.7 && c.ratio() < 1.3, "ratio={}", c.ratio());
    }

    /// The paper's A.3 comparison point (Fig. 9): RWKV deployed at edge
    /// batch 1 sits at ratio ≈ 0.97; a transformer served at its normal
    /// batch (weights amortised over concurrent sequences) sits much
    /// higher (paper: 4.88 for LLaMA-2-7B decode).
    #[test]
    fn llama_serving_ratio_higher_than_rwkv_edge() {
        let rcfg = ModelConfig::rwkv6(12, 384, 512);
        let lcfg = ModelConfig::llama(12, 384, 512);
        let r = rwkv_step(&rcfg, &CostModel::edge_decode());
        let l = llama_step(&lcfg, &CostModel { batch: 8, context: 256, weight_bytes: 2.0 });
        assert!(r.ratio() < 1.3, "rwkv edge {}", r.ratio());
        assert!(l.ratio() > 2.0, "llama serving {}", l.ratio());
        assert!(r.ratio() < l.ratio() / 2.0, "rwkv {} llama {}", r.ratio(), l.ratio());
    }

    #[test]
    fn quantization_raises_ratio() {
        let cfg = ModelConfig::rwkv6(12, 384, 512);
        let fp = rwkv_step(&cfg, &CostModel { weight_bytes: 2.0, ..CostModel::edge_decode() });
        let q = rwkv_step(
            &cfg,
            &CostModel { weight_bytes: 3.275 / 8.0, ..CostModel::edge_decode() },
        );
        assert!(q.bytes < fp.bytes * 0.35, "q={} fp={}", q.bytes, fp.bytes);
        assert!(q.ratio() > fp.ratio() * 2.5);
    }

    /// The §1 claim: QuaRot online rotation increases RWKV-7 FLOPs by
    /// more than 99 % — one extra dense orthogonal multiply per
    /// projection roughly doubles the matmul work.
    #[test]
    fn quarot_overhead_exceeds_99_percent() {
        let cfg = ModelConfig::rwkv7(4, 128, 512);
        let over = quarot_overhead_flops(&cfg) as f64;
        let base = rwkv_base_flops(&cfg) as f64;
        assert!(over / base > 0.99, "overhead fraction {}", over / base);
    }

    #[test]
    fn param_counts_scale_quadratically() {
        let small = ModelConfig::rwkv6(4, 128, 512);
        let big = ModelConfig::rwkv6(4, 256, 512);
        let r = rwkv_matmul_params(&big) as f64 / rwkv_matmul_params(&small) as f64;
        assert!((r - 4.0).abs() < 0.3, "ratio {r}");
    }
}
