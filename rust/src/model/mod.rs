//! The RWKV model substrate.
//!
//! * [`store`] — layer descriptors, the in-memory weight store, and the
//!   binary interchange formats: dense fp32 `RWKVQ1` shared with the
//!   Python build path (`python/compile/train.py` writes it, this crate
//!   reads it) and the packed `RWKVQ2` checkpoint format, which
//!   serializes a [`QuantizedModel`] directly and loads zero-copy
//!   through a memory mapping ([`store::open_rwkvq2`]).
//! * [`qmodel`] — the serving-side weight providers: the
//!   [`WeightProvider`] abstraction the runner consumes, and
//!   [`QuantizedModel`], which keeps matmul weights **packed** and
//!   serves them through the [`crate::quant::exec::LinearOp`] kernels
//!   (see the `LinearOp` contract in `quant/exec.rs`). fp32, SQ, VQ and
//!   hybrid checkpoints all run the identical forward-pass code.
//! * [`rwkv`] — a pure-Rust reference forward pass for RWKV-6/7 blocks
//!   (token-shift mixing, the stabilised WKV recurrence, channel
//!   mixing), generic over `WeightProvider`. Used by the eval harness,
//!   the serving stack, and as the numeric oracle for the PJRT-executed
//!   HLO graphs.
//! * [`llama`] — a minimal LLaMA-like architecture: the comparator
//!   weights for the Table 1 / Fig. 5 distribution comparisons and the
//!   Fig. 9 op/byte accounting, plus a full sliding-window serving
//!   forward pass ([`llama::LlamaRunner`]: RoPE attention over a fixed
//!   KV ring, SiLU-gated FFN) generic over `WeightProvider` — the
//!   second architecture through the packed-serve path, dispatched by
//!   [`crate::coordinator::serve::decoder_for`].
//! * [`synthetic`] — weight-family generators with controlled
//!   distribution archetypes (uniform / uniform+outliers / Gaussian /
//!   clustered), calibrated to the paper's RWKV-vs-LLaMA findings.
//! * [`flops`] — analytic FLOP and byte accounting per architecture
//!   (Fig. 9, §A.3, and the QuaRot overhead aggregation).

pub mod flops;
pub mod llama;
pub mod qmodel;
pub mod rwkv;
pub mod store;
pub mod synthetic;

pub use qmodel::{QuantizedModel, ServedParam, WeightProvider};
pub use store::{
    EntryDecl, EntryKind, LayerDesc, LoadMode, ModelWeights, ParamClass, Rwkvq2Writer, StoreFormat,
};
