//! The RWKV model substrate.
//!
//! * [`store`] — layer descriptors, the in-memory weight store, and the
//!   binary interchange format shared with the Python build path
//!   (`python/compile/train.py` writes it, this crate reads it, and the
//!   quantization pipeline writes quantized stores back).
//! * [`rwkv`] — a pure-Rust reference forward pass for RWKV-6/7 blocks
//!   (token-shift mixing, the stabilised WKV recurrence, channel
//!   mixing). Used by the eval harness and as the numeric oracle for the
//!   PJRT-executed HLO graphs.
//! * [`llama`] — a minimal LLaMA-like comparator (weights + layer
//!   inventory only; used for the Table 1 / Fig. 5 distribution
//!   comparisons and the Fig. 9 op/byte accounting).
//! * [`synthetic`] — weight-family generators with controlled
//!   distribution archetypes (uniform / uniform+outliers / Gaussian /
//!   clustered), calibrated to the paper's RWKV-vs-LLaMA findings.
//! * [`flops`] — analytic FLOP and byte accounting per architecture
//!   (Fig. 9, §A.3, and the QuaRot overhead aggregation).

pub mod flops;
pub mod llama;
pub mod rwkv;
pub mod store;
pub mod synthetic;

pub use store::{LayerDesc, ModelWeights, ParamClass};
