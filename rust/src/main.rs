//! `rwkvquant` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   quantize   quantize a weight store (or a synthetic model) and report;
//!              with --streaming, two layer-by-layer passes over an
//!              RWKVQ1 store write a packed RWKVQ2 checkpoint with
//!              O(one layer) peak memory
//!   pack       quantize and serialize to an RWKVQ2 packed checkpoint
//!   eval       perplexity + zero-shot of a store on the corpus
//!   serve      batched generation over a store (RWKVQ1 quantized on the
//!              fly, or an RWKVQ2 checkpoint opened zero-copy via mmap);
//!              with --http it becomes the streaming HTTP gateway
//!              (SSE tokens, OpenAI-compatible /v1/completions and
//!              /v1/chat/completions with seeded sampling and
//!              disconnect cancellation, /healthz, /metrics, 429
//!              shedding, graceful SIGINT/SIGTERM drain); repeated
//!              --model name=path.rwkvq2 flags serve a whole fleet —
//!              the request's "model" field routes to a per-model
//!              engine, GET /v1/models lists the registry, and
//!              POST/DELETE /admin/models/{name} hot-swap models with
//!              zero downtime
//!   proxy      proxy-scan a model (SQ/VQ classification per layer)
//!   info       print artifact / environment status

use rwkvquant::calib::CalibSet;
use rwkvquant::config::{Method, QuantConfig};
use rwkvquant::coordinator::serve::{
    decoder_for, resolve_tick_threads, serve_collect_pool_with, PoolOpts, Request, ServeOpts,
    ServeStats,
};
use rwkvquant::coordinator::{
    quantize_model, quantize_store_streaming, Fleet, FleetConfig, ModelOverrides,
};
use rwkvquant::data::{make_task_from_corpus, BinCorpus};
use rwkvquant::eval::{ppl, zeroshot};
use rwkvquant::experiments::build_model;
use rwkvquant::model::store::{detect_format, StoreFormat};
use rwkvquant::model::{LoadMode, ModelWeights, QuantizedModel, WeightProvider};
use rwkvquant::report::{Cell, Table};
use rwkvquant::runtime::artifacts_dir;
use rwkvquant::util::cli::{Args, Help};
use std::time::Duration;

fn help() -> String {
    Help::new("rwkvquant", "proxy-guided hybrid SQ/VQ post-training quantization for RWKV")
        .sub("quantize", "quantize a store or synthetic model, print the pipeline report")
        .sub("pack", "quantize and write an RWKVQ2 packed checkpoint (--out)")
        .sub("eval", "perplexity + corpus zero-shot of a store")
        .sub("serve", "batched generation over a store (optionally quantized first)")
        .sub("proxy", "per-layer proxy scan (P_c, P_f, Eq.18 decision)")
        .sub("info", "artifact & environment status")
        .opt("store", "path to a RWKVQ1/RWKVQ2 store (default artifacts/tiny_rwkv.bin)")
        .opt("out", "pack/quantize --streaming: output path (default artifacts/model.rwkvq2)")
        .opt(
            "streaming",
            "quantize: stream an RWKVQ1 store to a packed RWKVQ2 checkpoint layer by \
             layer, O(one layer) peak memory (flag; requires --store)",
        )
        .opt(
            "model",
            "serve --http: register NAME=PATH.rwkvq2[,max_queue=N,tick_threads=N] in \
             the fleet (repeatable); requests route by their \"model\" field, \
             /admin/models/{name} hot-swaps; per-model options override the \
             fleet-wide flags",
        )
        .opt("mmap", "serve: force memory-mapped RWKVQ2 loading (flag)")
        .opt("buffered", "serve: force buffered RWKVQ2 loading (flag)")
        .opt("method", "rtn|gptq|awq|quarot|kmeans|gptvq|vptq|rwkvquant (default rwkvquant)")
        .opt("bpw", "target bits per weight for baselines (3.25/3.5)")
        .opt("size", "synthetic model size (0.1B..14B) when no store given")
        .opt("arch", "synthetic arch rwkv6|rwkv7 (default rwkv6)")
        .opt("requests", "serve: number of requests (default 16)")
        .opt("batch", "serve: max batch (default 8)")
        .opt("gen-len", "serve: tokens generated per request (default 12)")
        .opt("prompt", "serve: comma-separated token ids used as every request's prompt")
        .opt("print-tokens", "serve: print each response's token ids (flag)")
        .opt("tick-threads", "serve: decode lanes per batch tick (0 = auto-detect, default 1)")
        .opt("prefill-chunk", "serve: prompt tokens consumed per tick while prefilling (default 32)")
        .opt("state-slots", "serve: bounded state-arena slabs (0 = one per batch slot)")
        .opt("pin-workers", "serve: pin tick worker lanes to CPUs, Linux only (flag)")
        .opt("http", "serve: run the HTTP gateway on ADDR (bare flag = 127.0.0.1:8080)")
        .opt("max-queue", "serve --http: admission queue bound, overflow shed with 429 (default 64)")
        .opt("log-json", "serve --http: emit structured logs as JSON lines on stderr (flag)")
        .opt("log-level", "serve --http: log threshold debug|info|warn|error (default info)")
        .opt(
            "no-trace",
            "serve --http: disable per-request span tracing and kernel attribution \
             (/admin/trace returns 404; kernel counters stay zero) (flag)",
        )
        .opt("max-gen-len", "serve --http: per-request gen_len cap (default 512)")
        .opt("vocab", "serve --http: tokenizer vocab JSON for the text endpoints (default synthetic)")
        .opt("temperature", "serve: sampling temperature, 0 = greedy (default 0)")
        .opt("top-k", "serve: keep only the k most likely tokens, 0 = off (default 0)")
        .opt("top-p", "serve: nucleus sampling mass in (0,1] (default 1)")
        .opt("rep-penalty", "serve: repetition penalty, 1 = off (default 1)")
        .opt("sample-seed", "serve: base sampler seed; request i draws from seed+i (default 42)")
        .opt("seed", "rng seed (default 42)")
        .render()
}

fn load_model(args: &Args) -> rwkvquant::Result<ModelWeights> {
    match args.get("store") {
        Some(path) => ModelWeights::load(std::path::Path::new(path)),
        None => {
            let default = artifacts_dir().join("tiny_rwkv.bin");
            if default.exists() && args.get("size").is_none() {
                ModelWeights::load(&default)
            } else {
                let arch = args.get_or("arch", "rwkv6");
                let size = args.get_or("size", "0.5B");
                eprintln!("(no store — generating synthetic {arch}-{size})");
                Ok(build_model(arch, size, args.get_u64("seed", 42)))
            }
        }
    }
}

fn quant_config(args: &Args) -> rwkvquant::Result<QuantConfig> {
    let method = Method::parse(args.get_or("method", "rwkvquant"))?;
    let bpw = args.get_f64("bpw", if method == Method::RwkvQuant { 3.275 } else { 3.5 });
    let mut cfg = QuantConfig::baseline(method, bpw);
    cfg.method = method;
    cfg.vq_bits = cfg.vq_bits.min(args.get_usize("vq-bits", 9) as u32);
    cfg.seed = args.get_u64("seed", 42);
    if let Some(tc) = args.get("tau-c") {
        cfg.tau_c = Some(tc.parse()?);
    }
    if let Some(tf) = args.get("tau-f") {
        cfg.tau_f = Some(tf.parse()?);
    }
    Ok(cfg)
}

fn cmd_quantize(args: &Args) -> rwkvquant::Result<()> {
    if args.flag("streaming") {
        return cmd_quantize_streaming(args);
    }
    let model = load_model(args)?;
    let cfg = quant_config(args)?;
    let corpus_path = artifacts_dir().join("corpus.bin");
    let calib = if corpus_path.exists() && model.config.vocab <= 4096 {
        let corpus = BinCorpus::load(&corpus_path)?;
        if corpus.vocab == model.config.vocab {
            Some(CalibSet::capture(&model, &corpus.calib_windows(8, 16, 3), cfg.calib_samples))
        } else {
            None
        }
    } else {
        None
    };
    let (q, rep) = quantize_model(&model, calib.as_ref(), &cfg, 0);
    let mut t = Table::new(
        format!("pipeline report — {}", cfg.method.name()),
        &["Layer", "P_c", "P_f", "choice", "bpw", "mse"],
    );
    for l in &rep.layers {
        t.row(vec![
            Cell::s(l.name.clone()),
            l.proxies.map(|p| Cell::f(p.p_c, 3)).unwrap_or(Cell::Empty),
            l.proxies.map(|p| Cell::f(p.p_f, 2)).unwrap_or(Cell::Empty),
            Cell::s(l.choice.map(|c| format!("{c:?}")).unwrap_or_else(|| "-".into())),
            Cell::f(l.bpw, 3),
            Cell::F64(l.mse, 8),
        ]);
    }
    t.print();
    println!(
        "avg bpw {:.3} | SQ share {:.0}% | {:.2}s on {} workers | quantized bits {}",
        rep.avg_bpw,
        rep.sq_share() * 100.0,
        rep.wall_secs,
        rep.n_workers,
        q.values().map(|l| l.storage_bits()).sum::<usize>(),
    );
    Ok(())
}

/// `quantize --streaming`: two layer-by-layer passes over an on-disk
/// RWKVQ1 store (proxy scan, then quantize+pack) straight into an
/// RWKVQ2 writer — peak memory is one layer plus the scan's proxy
/// pairs, never the whole model. Byte-identical to `pack` of the same
/// store and config.
fn cmd_quantize_streaming(args: &Args) -> rwkvquant::Result<()> {
    let src = args
        .get("store")
        .ok_or_else(|| anyhow::anyhow!("--streaming reads from disk; pass --store <model.bin>"))?;
    let cfg = quant_config(args)?;
    let out = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => artifacts_dir().join("model.rwkvq2"),
    };
    if let Some(parent) = out.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    let rep = quantize_store_streaming(std::path::Path::new(src), &out, &cfg)?;
    let bytes = std::fs::metadata(&out)?.len();
    if let Some(taus) = &rep.taus {
        println!(
            "τ_c = {:.3}, τ_f = {:.2} (calibrated from the streaming proxy scan)",
            taus.tau_c, taus.tau_f
        );
    }
    println!(
        "streamed {} entries ({} packed payloads, avg {:.3} bpw{}) -> {} ({:.2} MB) \
         in {:.2}s — peak RSS stayed O(one layer)",
        rep.entries,
        rep.packed,
        rep.avg_bpw,
        if rep.sq_share.is_nan() {
            String::new()
        } else {
            format!(", SQ share {:.0}%", rep.sq_share * 100.0)
        },
        out.display(),
        bytes as f64 / 1e6,
        rep.wall_secs,
    );
    println!("serve it with: rwkvquant serve --store {} --mmap", out.display());
    Ok(())
}

fn cmd_eval(args: &Args) -> rwkvquant::Result<()> {
    let model = load_model(args)?;
    let corpus = BinCorpus::load(&artifacts_dir().join("corpus.bin"))?;
    anyhow::ensure!(corpus.vocab == model.config.vocab, "corpus/model vocab mismatch");
    let toks = &corpus.valid[..1000.min(corpus.valid.len())];
    let tasks = make_task_from_corpus(&corpus.valid, corpus.vocab, 60, 16, 2, 5);
    println!("ppl(valid[..{}]) = {:.3}", toks.len(), ppl::perplexity(&model, toks));
    println!("corpus 0-shot accuracy = {:.1}% (chance 25%)", zeroshot::accuracy(&model, &tasks));
    Ok(())
}

fn cmd_pack(args: &Args) -> rwkvquant::Result<()> {
    let model = load_model(args)?;
    let cfg = quant_config(args)?;
    let (q, rep) = quantize_model(&model, None, &cfg, 0);
    let mut qm = QuantizedModel::from_parts(&model, &q);
    // make the on-disk f16 rounding resident, so this process and any
    // later `serve --mmap` of the checkpoint are token-identical
    qm.dense_to_f16();
    let out = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => artifacts_dir().join("model.rwkvq2"),
    };
    if let Some(parent) = out.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    qm.save(&out)?;
    let bytes = std::fs::metadata(&out)?.len();
    println!(
        "packed {} entries ({} packed payloads, avg {:.3} bpw, SQ share {:.0}%) \
         -> {} ({:.2} MB: {:.2} MB packed + {:.2} MB dense f16)",
        qm.entries.len(),
        qm.n_packed(),
        rep.avg_bpw,
        rep.sq_share() * 100.0,
        out.display(),
        bytes as f64 / 1e6,
        (qm.served_storage_bits() - qm.dense_storage_bits()) as f64 / 8e6,
        qm.dense_storage_bits() as f64 / 8e6,
    );
    println!("serve it with: rwkvquant serve --store {} --mmap", out.display());
    Ok(())
}

/// `--log-json` / `--log-level` configure the process-wide structured
/// logger before any gateway thread starts emitting.
fn configure_logging(args: &Args) -> rwkvquant::Result<()> {
    use rwkvquant::util::log;
    log::set_json(args.flag("log-json"));
    if let Some(s) = args.get("log-level") {
        let level = log::Level::parse(s)
            .ok_or_else(|| anyhow::anyhow!("--log-level expects debug|info|warn|error, got '{s}'"))?;
        log::set_level(level);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> rwkvquant::Result<()> {
    configure_logging(args)?;
    let model_specs = args.get_all("model");
    if !model_specs.is_empty() {
        return cmd_serve_fleet(args, &model_specs);
    }
    let mode = if args.flag("mmap") {
        LoadMode::Mmap
    } else if args.flag("buffered") {
        LoadMode::Buffered
    } else {
        LoadMode::Auto
    };
    let packed_store = args
        .get("store")
        .map(std::path::PathBuf::from)
        .filter(|p| detect_format(p).ok() == Some(StoreFormat::V2Packed));
    let qm = match packed_store {
        Some(path) => {
            // zero-copy open: O(TOC) startup, pages fault in on demand
            let t0 = std::time::Instant::now();
            let qm = QuantizedModel::open_with(&path, mode)?;
            println!(
                "opened RWKVQ2 {} in {:.1} ms — {} entries, {} payloads borrowed \
                 zero-copy from the mapping",
                path.display(),
                t0.elapsed().as_secs_f64() * 1e3,
                qm.entries.len(),
                qm.n_mapped(),
            );
            qm
        }
        None => {
            let model = load_model(args)?;
            let cfg = quant_config(args)?;
            let (q, _) = quantize_model(&model, None, &cfg, 0);
            // serve straight from the packed payloads — no dense
            // materialisation
            QuantizedModel::from_parts(&model, &q)
        }
    };
    let batch = args.get_usize("batch", 8);
    let requested_threads = args.get_usize("tick-threads", 1);
    let tick_threads = resolve_tick_threads(requested_threads, batch);
    let prefill_chunk = args.get_usize("prefill-chunk", 32);
    let state_slots = args.get_usize("state-slots", 0);
    let pin_workers = args.flag("pin-workers");
    println!(
        "serving quantized model (avg {:.3} bpw packed, {} packed layers, {:.1} MB served, \
         {} kernel, {} tick thread{}{}, prefill chunk {prefill_chunk}, state slots {}{})",
        qm.packed_bpw(),
        qm.n_packed(),
        qm.served_storage_bits() as f64 / 8e6,
        rwkvquant::quant::exec::active_kernel().name(),
        tick_threads,
        if tick_threads == 1 { "" } else { "s" },
        if requested_threads == 0 { " — auto-detected" } else { "" },
        if state_slots == 0 { batch } else { state_slots },
        if pin_workers { ", pinned workers" } else { "" },
    );
    // arch-dispatched: any architecture with a serving decoder (RWKV
    // variants, LLaMA) drives the identical tick machinery
    let mut decoders = (0..tick_threads)
        .map(|_| decoder_for(&qm))
        .collect::<rwkvquant::Result<Vec<_>>>()?;
    let vocab = qm.config.vocab;

    // ---- HTTP gateway mode: serve real sockets until drained ----
    if let Some(addr) = args.flag_value("http", "127.0.0.1:8080") {
        use rwkvquant::data::tokenizer::Tokenizer;
        use rwkvquant::server::{signal, Gateway, GatewayConfig};
        let heeding = signal::install_shutdown_signals();
        signal::clear_shutdown_signal();
        let mut gcfg = GatewayConfig::new(addr);
        gcfg.max_batch = batch;
        gcfg.max_queue = args.get_usize("max-queue", 64);
        gcfg.max_gen_len = args.get_usize("max-gen-len", 512);
        gcfg.prefill_chunk = prefill_chunk;
        gcfg.state_slots = state_slots;
        gcfg.pin_workers = pin_workers;
        gcfg.heed_signals = heeding;
        gcfg.trace = !args.flag("no-trace");
        let mut gateway = Gateway::bind(gcfg, vocab)?;
        let vocab_note = match args.get("vocab") {
            Some(path) => {
                let tok = Tokenizer::load(std::path::Path::new(path))
                    .map_err(|e| anyhow::anyhow!("--vocab: {e}"))?;
                anyhow::ensure!(
                    tok.vocab() <= vocab,
                    "--vocab names {} ids but the model's vocab is {vocab}",
                    tok.vocab()
                );
                gateway = gateway.with_tokenizer(tok);
                format!("vocab {path}")
            }
            None => format!("synthetic vocab ({vocab} ids)"),
        };
        println!(
            "HTTP gateway on http://{} — POST /v1/generate (SSE), POST /v1/completions, \
             POST /v1/chat/completions ({vocab_note}), GET /healthz, GET /metrics; \
             max-queue {} (overflow → 429); {} to drain and exit",
            gateway.local_addr(),
            args.get_usize("max-queue", 64),
            if heeding { "Ctrl-C / SIGTERM" } else { "no signal handler — kill to stop" },
        );
        let stats = gateway.serve(&mut decoders)?;
        print_serve_summary(&stats);
        println!("drained cleanly — all in-flight requests completed");
        return Ok(());
    }

    // ---- in-process self-drive mode ----
    let n = args.get_usize("requests", 16);
    let prompt_override: Option<Vec<usize>> = args.get("prompt").map(|p| {
        p.split(',')
            .map(|t| {
                let tok: usize = t
                    .trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--prompt expects comma-separated ids, got '{t}'"));
                assert!(tok < vocab, "--prompt token {tok} is outside the vocab ({vocab})");
                tok
            })
            .collect()
    });
    let sample = rwkvquant::coordinator::sampler::SampleParams {
        temperature: args.get_f64("temperature", 0.0) as f32,
        top_k: args.get_usize("top-k", 0),
        top_p: args.get_f64("top-p", 1.0) as f32,
        repetition_penalty: args.get_f64("rep-penalty", 1.0) as f32,
        seed: 0, // per-request seed assigned below
    };
    sample.validate().map_err(|e| anyhow::anyhow!("sampling flags: {e}"))?;
    let sample_seed = args.get_u64("sample-seed", 42);
    let requests: Vec<Request> = (0..n as u64)
        .map(|id| {
            let prompt = prompt_override
                .clone()
                .unwrap_or_else(|| vec![(id as usize * 7) % vocab, 1, 2]);
            let req = Request::new(id, prompt, args.get_usize("gen-len", 12));
            if sample.is_greedy() {
                req
            } else {
                // independent but reproducible streams per request
                req.with_sampling(rwkvquant::coordinator::sampler::SampleParams {
                    seed: sample_seed.wrapping_add(id),
                    ..sample
                })
            }
        })
        .collect();
    let mut opts =
        ServeOpts::new(batch, Duration::from_millis(2)).with_prefill_chunk(prefill_chunk);
    if state_slots > 0 {
        opts = opts.with_state_slots(state_slots);
    }
    let popts = PoolOpts::default().with_pin_workers(pin_workers);
    let (stats, responses) = serve_collect_pool_with(&mut decoders, requests, &opts, popts)?;
    if args.flag("print-tokens") {
        for r in &responses {
            let list: Vec<String> = r.tokens.iter().map(|t| t.to_string()).collect();
            println!("tokens[{}]: {}", r.id, list.join(","));
        }
    }
    print_serve_summary(&stats);
    Ok(())
}

/// `serve --http --model a=a.rwkvq2 --model b=b.rwkvq2 …`: multi-model
/// fleet serving. Every model gets its own mmap'd store, serve engine
/// and metrics registry; requests route by their `model` field and the
/// admin API hot-swaps stores under live traffic. A `--store` given
/// alongside `--model` registers as the default model name.
fn cmd_serve_fleet(args: &Args, specs: &[&str]) -> rwkvquant::Result<()> {
    use rwkvquant::data::tokenizer::Tokenizer;
    use rwkvquant::server::gateway::DEFAULT_MODEL;
    use rwkvquant::server::{signal, Gateway, GatewayConfig};

    let addr = args
        .flag_value("http", "127.0.0.1:8080")
        .ok_or_else(|| anyhow::anyhow!("--model fleet serving is an HTTP feature; pass --http"))?;
    let mode = if args.flag("mmap") {
        LoadMode::Mmap
    } else if args.flag("buffered") {
        LoadMode::Buffered
    } else {
        LoadMode::Auto
    };
    let batch = args.get_usize("batch", 8);
    let tick_threads = resolve_tick_threads(args.get_usize("tick-threads", 1), batch);
    let prefill_chunk = args.get_usize("prefill-chunk", 32);
    let state_slots = args.get_usize("state-slots", 0);
    let pin_workers = args.flag("pin-workers");
    let max_queue = args.get_usize("max-queue", 64);
    let mut opts = ServeOpts::new(batch, Duration::from_millis(2))
        .with_max_queue(max_queue)
        .with_prefill_chunk(prefill_chunk);
    if state_slots > 0 {
        opts = opts.with_state_slots(state_slots);
    }
    let trace = !args.flag("no-trace");
    let fleet = Fleet::new(FleetConfig {
        lanes: tick_threads,
        opts,
        popts: PoolOpts::default().with_pin_workers(pin_workers),
        load_mode: mode,
        step_delay: Duration::ZERO,
        trace,
    });

    let mut named: Vec<(String, std::path::PathBuf, ModelOverrides)> = Vec::new();
    if let Some(store) = args.get("store") {
        named.push((
            DEFAULT_MODEL.to_string(),
            std::path::PathBuf::from(store),
            ModelOverrides::default(),
        ));
    }
    for spec in specs {
        let (name, rest) = spec.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("--model expects NAME=PATH.rwkvq2[,max_queue=N], got '{spec}'")
        })?;
        anyhow::ensure!(!name.is_empty(), "--model: empty model name in '{spec}'");
        // first comma-part is the path; the rest are per-model key=value
        // overrides on top of the fleet-wide flags
        let mut parts = rest.split(',');
        let path = parts.next().unwrap_or_default();
        anyhow::ensure!(!path.is_empty(), "--model: empty path in '{spec}'");
        let mut ov = ModelOverrides::default();
        for kv in parts {
            let (k, v) = kv.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("--model: expected key=value after the path, got '{kv}' in '{spec}'")
            })?;
            match k.trim() {
                "max_queue" => {
                    ov.max_queue = Some(v.trim().parse().map_err(|_| {
                        anyhow::anyhow!("--model: max_queue expects an integer, got '{v}' in '{spec}'")
                    })?);
                }
                "tick_threads" => {
                    ov.tick_threads = Some(v.trim().parse().map_err(|_| {
                        anyhow::anyhow!(
                            "--model: tick_threads expects an integer, got '{v}' in '{spec}'"
                        )
                    })?);
                }
                other => anyhow::bail!(
                    "--model: unknown per-model option '{other}' in '{spec}' \
                     (supported: max_queue, tick_threads)"
                ),
            }
        }
        named.push((name.to_string(), std::path::PathBuf::from(path), ov));
    }
    let mut vocab = 0usize;
    for (name, path, ov) in &named {
        anyhow::ensure!(
            detect_format(path)? == StoreFormat::V2Packed,
            "model '{name}': {} is not a packed RWKVQ2 checkpoint (run `rwkvquant pack` \
             or `rwkvquant quantize --streaming` first)",
            path.display(),
        );
        let entry = fleet.load_with(name, path, *ov)?;
        vocab = vocab.max(entry.vocab());
        println!(
            "loaded model '{name}' from {} (vocab {}, version {}{})",
            path.display(),
            entry.vocab(),
            entry.version(),
            match (ov.max_queue, ov.tick_threads) {
                (Some(q), Some(t)) => format!(", max_queue {q}, tick_threads {t}"),
                (Some(q), None) => format!(", max_queue {q}"),
                (None, Some(t)) => format!(", tick_threads {t}"),
                (None, None) => String::new(),
            },
        );
    }

    let heeding = signal::install_shutdown_signals();
    signal::clear_shutdown_signal();
    let mut gcfg = GatewayConfig::new(addr);
    gcfg.max_batch = batch;
    gcfg.max_queue = max_queue;
    gcfg.max_gen_len = args.get_usize("max-gen-len", 512);
    gcfg.prefill_chunk = prefill_chunk;
    gcfg.state_slots = state_slots;
    gcfg.pin_workers = pin_workers;
    gcfg.heed_signals = heeding;
    gcfg.trace = trace;
    let mut gateway = Gateway::bind(gcfg, vocab)?;
    if let Some(path) = args.get("vocab") {
        let tok = Tokenizer::load(std::path::Path::new(path))
            .map_err(|e| anyhow::anyhow!("--vocab: {e}"))?;
        gateway = gateway.with_tokenizer(tok);
    }
    println!(
        "HTTP fleet gateway on http://{} — {} model{} (route with the \"model\" field); \
         GET /v1/models, POST/DELETE /admin/models/{{name}} to hot-swap; \
         max-queue {max_queue} (overflow → 429); {} to drain and exit",
        gateway.local_addr(),
        named.len(),
        if named.len() == 1 { "" } else { "s" },
        if heeding { "Ctrl-C / SIGTERM" } else { "no signal handler — kill to stop" },
    );
    gateway.serve_fleet(&fleet)?;
    for (name, stats) in fleet.drain() {
        match stats {
            Ok(s) => {
                print!("model '{name}': ");
                print_serve_summary(&s);
            }
            Err(e) => eprintln!("model '{name}': engine error: {e:#}"),
        }
    }
    println!("drained cleanly — all in-flight requests completed");
    Ok(())
}

fn print_serve_summary(stats: &ServeStats) {
    println!(
        "{} requests ({} shed, {} cancelled) | {:.1} tok/s gen, {:.1} tok/s prefill | \
         p50 {:?} p95 {:?} p99 {:?} | ttft p50 {:?} p99 {:?} | \
         queue hwm {} | admission wait p50 {:?} p99 {:?} | \
         state parks {} resumes {}",
        stats.completed,
        stats.shed,
        stats.cancelled,
        stats.tokens_per_sec(),
        stats.prefill_tokens_per_sec(),
        stats.p50_latency,
        stats.p95_latency,
        stats.p99_latency,
        stats.p50_ttft,
        stats.p99_ttft,
        stats.queue_hwm,
        stats.p50_admission_wait,
        stats.p99_admission_wait,
        stats.state_parks,
        stats.state_resumes,
    );
    // per-kernel matvec attribution (process-global; populated only
    // while tracing is enabled)
    let rows: Vec<_> = rwkvquant::quant::exec::kstats::snapshot()
        .into_iter()
        .filter(|&(_, _, calls, _)| calls > 0)
        .collect();
    if !rows.is_empty() {
        let parts: Vec<String> = rows
            .iter()
            .map(|(op, kernel, calls, secs)| format!("{op}/{kernel} {calls} calls {secs:.3}s"))
            .collect();
        println!("kernel attribution: {}", parts.join(" | "));
    }
}

fn cmd_proxy(args: &Args) -> rwkvquant::Result<()> {
    let model = load_model(args)?;
    let idx = model.quantizable_indices();
    let pairs: Vec<_> = idx
        .iter()
        .map(|&i| rwkvquant::quant::proxy::compute(&model.layers[i].1.data, 4))
        .collect();
    let cal = rwkvquant::quant::hybrid::calibrate_taus(&pairs, args.get_f64("sq-fraction", 0.9));
    println!("τ_c = {:.3}, τ_f = {:.2}, SQ share {:.0}%", cal.tau_c, cal.tau_f, cal.sq_share * 100.0);
    let mut t = Table::new("proxy scan", &["Layer", "P_c", "P_f", "Eq.18"]);
    for (pos, &i) in idx.iter().enumerate() {
        let c = rwkvquant::quant::hybrid::decide(pairs[pos], cal.tau_c, cal.tau_f);
        t.row(vec![
            Cell::s(model.layers[i].0.name.clone()),
            Cell::f(pairs[pos].p_c, 3),
            Cell::f(pairs[pos].p_f, 2),
            Cell::s(format!("{c:?}")),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info() {
    let dir = artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    for f in [
        "tiny_rwkv.bin",
        "corpus.bin",
        "rwkv_step.hlo.txt",
        "rwkv_step.inputs.txt",
        "vq_matvec.hlo.txt",
        "smoke.hlo.txt",
        "train_log.txt",
    ] {
        let p = dir.join(f);
        let status = p
            .metadata()
            .map(|m| format!("{} bytes", m.len()))
            .unwrap_or_else(|_| "MISSING (run `make artifacts`)".into());
        println!("  {f:<24} {status}");
    }
    println!(
        "cores: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );
    println!("matvec kernel: {}", rwkvquant::quant::exec::active_kernel().name());
    println!(
        "mmap checkpoint loading: {}",
        if rwkvquant::util::mmap::Mmap::supported() {
            "supported"
        } else {
            "unsupported (buffered fallback)"
        }
    );
    println!("platform capabilities: {}", rwkvquant::util::caps::summary());
}

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("quantize") => cmd_quantize(&args),
        Some("pack") => cmd_pack(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("proxy") => cmd_proxy(&args),
        Some("info") => {
            cmd_info();
            Ok(())
        }
        _ => {
            print!("{}", help());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
