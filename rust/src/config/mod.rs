//! Configuration system.
//!
//! A TOML-subset parser ([`toml_lite`]) plus the typed configuration
//! structures for models, quantization runs, and serving, with defaults
//! matching the paper's experimental settings (§4.1).

pub mod toml_lite;

use crate::Result;
use anyhow::{bail, Context};
use toml_lite::Value;

/// Which quantization engine to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// round-to-nearest scalar quantization
    Rtn,
    /// GPTQ second-order compensated SQ
    Gptq,
    /// activation-aware weight scaling SQ
    Awq,
    /// random-Hadamard-rotation SQ (QuaRot-style)
    QuaRot,
    /// plain K-Means VQ
    KMeans,
    /// GPTVQ: VQ with GPTQ-style compensation
    Gptvq,
    /// VPTQ: second-order VQ
    Vptq,
    /// the paper's proxy-guided hybrid (ours)
    RwkvQuant,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rtn" => Method::Rtn,
            "gptq" => Method::Gptq,
            "awq" => Method::Awq,
            "quarot" => Method::QuaRot,
            "kmeans" => Method::KMeans,
            "gptvq" => Method::Gptvq,
            "vptq" => Method::Vptq,
            "rwkvquant" | "ours" | "hybrid" => Method::RwkvQuant,
            other => bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "RTN",
            Method::Gptq => "GPTQ",
            Method::Awq => "AWQ",
            Method::QuaRot => "QuaRot",
            Method::KMeans => "kMeans",
            Method::Gptvq => "GPTVQ",
            Method::Vptq => "VPTQ",
            Method::RwkvQuant => "RWKVQuant",
        }
    }

    pub fn is_vq(&self) -> bool {
        matches!(self, Method::KMeans | Method::Gptvq | Method::Vptq)
    }

    /// All baseline methods compared in Table 2.
    pub fn all_baselines() -> &'static [Method] {
        &[
            Method::Rtn,
            Method::Gptq,
            Method::Awq,
            Method::QuaRot,
            Method::KMeans,
            Method::Gptvq,
            Method::Vptq,
        ]
    }
}

/// Quantization run configuration. Defaults follow §4.1: group size 64
/// for 3.5 bpw SQ / 32 for 3.25 bpw SQ, 128 calibration samples, and the
/// paper's nine-tenths-SQ / one-tenth-VQ τ calibration for the hybrid.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    pub method: Method,
    /// target average bits per weight (3.25 / 3.5 for baselines, 3.275 ours)
    pub bpw: f64,
    /// SQ group size (weights per scale/zero pair)
    pub group_size: usize,
    /// SQ bit width
    pub sq_bits: u32,
    /// VQ codebook index bits (k) — 2^k entries
    pub vq_bits: u32,
    /// VQ vector dimension (d)
    pub vq_dim: usize,
    /// coarse proxy threshold τ_c (hybrid only; None = auto-calibrate)
    pub tau_c: Option<f64>,
    /// fine proxy threshold τ_f
    pub tau_f: Option<f64>,
    /// target fraction of layers sent to SQ when auto-calibrating τ
    pub sq_fraction: f64,
    /// Taylor truncation order K for the fine proxy
    pub proxy_order: u32,
    /// number of calibration samples
    pub calib_samples: usize,
    /// percentile clip for activation batch integration (§3.2), e.g. 99.0
    pub clip_percentile: f64,
    /// enable the element-wise-multiplication codebook optimisation (§3.2)
    pub ewmul_opt: bool,
    /// GPTQ Hessian damping fraction
    pub percdamp: f64,
    /// K-Means iterations
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            method: Method::RwkvQuant,
            bpw: 3.275,
            group_size: 64,
            sq_bits: 3,
            vq_bits: 12,
            vq_dim: 4,
            tau_c: None,
            tau_f: None,
            sq_fraction: 0.9,
            proxy_order: 4,
            calib_samples: 128,
            clip_percentile: 99.0,
            ewmul_opt: true,
            percdamp: 0.01,
            kmeans_iters: 25,
            seed: 42,
        }
    }
}

impl QuantConfig {
    /// Baseline config at a given bpw: group size 32 → 3.25 bpw,
    /// 64 → 3.5 bpw for 3-bit SQ (scale overhead 16/g bits), matching the
    /// paper's accounting.
    pub fn baseline(method: Method, bpw: f64) -> Self {
        let mut c = QuantConfig { method, bpw, ..Default::default() };
        if (bpw - 3.25).abs() < 1e-9 {
            c.group_size = 64;
            c.vq_bits = 12;
        } else if (bpw - 3.5).abs() < 1e-9 {
            c.group_size = 32;
            c.vq_bits = 13;
        }
        c
    }

    /// Load overrides from a parsed TOML table.
    pub fn from_toml(v: &Value) -> Result<Self> {
        let mut c = QuantConfig::default();
        if let Some(t) = v.get("quant") {
            if let Some(s) = t.get_str("method") {
                c.method = Method::parse(s)?;
            }
            if let Some(x) = t.get_f64("bpw") {
                c.bpw = x;
            }
            if let Some(x) = t.get_int("group_size") {
                c.group_size = x as usize;
            }
            if let Some(x) = t.get_int("sq_bits") {
                c.sq_bits = x as u32;
            }
            if let Some(x) = t.get_int("vq_bits") {
                c.vq_bits = x as u32;
            }
            if let Some(x) = t.get_int("vq_dim") {
                c.vq_dim = x as usize;
            }
            if let Some(x) = t.get_f64("tau_c") {
                c.tau_c = Some(x);
            }
            if let Some(x) = t.get_f64("tau_f") {
                c.tau_f = Some(x);
            }
            if let Some(x) = t.get_f64("sq_fraction") {
                c.sq_fraction = x;
            }
            if let Some(x) = t.get_int("calib_samples") {
                c.calib_samples = x as usize;
            }
            if let Some(x) = t.get_f64("clip_percentile") {
                c.clip_percentile = x;
            }
            if let Some(b) = t.get_bool("ewmul_opt") {
                c.ewmul_opt = b;
            }
            if let Some(x) = t.get_int("seed") {
                c.seed = x as u64;
            }
        }
        Ok(c)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let v = toml_lite::parse(&text)?;
        Self::from_toml(&v)
    }
}

/// Model architecture configuration (shared by the Rust reference model,
/// the synthetic generator, and — via the binary weight store — the JAX
/// build path).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// "rwkv6" | "rwkv7" | "vrwkv" | "llama"
    pub arch: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub vocab: usize,
    /// head dimension for the WKV state
    pub head_dim: usize,
    /// FFN expansion ratio (channel-mixing hidden = ratio * d_model)
    pub ffn_ratio: f64,
}

impl ModelConfig {
    pub fn rwkv6(n_layer: usize, d_model: usize, vocab: usize) -> Self {
        ModelConfig { arch: "rwkv6".into(), n_layer, d_model, vocab, head_dim: 64, ffn_ratio: 3.5 }
    }

    pub fn rwkv7(n_layer: usize, d_model: usize, vocab: usize) -> Self {
        ModelConfig { arch: "rwkv7".into(), n_layer, d_model, vocab, head_dim: 64, ffn_ratio: 4.0 }
    }

    pub fn llama(n_layer: usize, d_model: usize, vocab: usize) -> Self {
        ModelConfig { arch: "llama".into(), n_layer, d_model, vocab, head_dim: 64, ffn_ratio: 2.7 }
    }

    pub fn n_heads(&self) -> usize {
        self.d_model / self.head_dim
    }

    pub fn ffn_dim(&self) -> usize {
        ((self.d_model as f64 * self.ffn_ratio) as usize / 32).max(1) * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for m in Method::all_baselines() {
            assert_eq!(Method::parse(m.name()).unwrap(), *m);
        }
        assert_eq!(Method::parse("ours").unwrap(), Method::RwkvQuant);
        assert!(Method::parse("bogus").is_err());
    }

    #[test]
    fn default_matches_paper_settings() {
        let c = QuantConfig::default();
        assert_eq!(c.calib_samples, 128);
        assert!((c.bpw - 3.275).abs() < 1e-9);
        assert!((c.sq_fraction - 0.9).abs() < 1e-9);
    }

    #[test]
    fn baseline_group_sizes() {
        assert_eq!(QuantConfig::baseline(Method::Gptq, 3.25).group_size, 64);
        assert_eq!(QuantConfig::baseline(Method::Gptq, 3.5).group_size, 32);
    }

    #[test]
    fn from_toml_overrides() {
        let text = "[quant]\nmethod = \"gptq\"\nbpw = 3.5\nseed = 7\newmul_opt = false\n";
        let v = toml_lite::parse(text).unwrap();
        let c = QuantConfig::from_toml(&v).unwrap();
        assert_eq!(c.method, Method::Gptq);
        assert_eq!(c.seed, 7);
        assert!(!c.ewmul_opt);
    }

    #[test]
    fn ffn_dim_multiple_of_32() {
        let m = ModelConfig::rwkv6(4, 256, 1000);
        assert_eq!(m.ffn_dim() % 32, 0);
        assert_eq!(m.n_heads(), 4);
    }
}
