//! A TOML-subset parser: `[section]` / `[section.sub]` headers and
//! `key = value` pairs where value is a string, integer, float, boolean,
//! or a flat array of those. Comments (`#`) and blank lines are skipped.
//! This covers every config file the repo ships; it is not a general
//! TOML implementation.

use crate::Result;
use anyhow::bail;
use std::collections::BTreeMap;

/// Parsed value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Table(t) => t.get(key),
            _ => None,
        }
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.get(key) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(Value::Float(f)) => Some(*f),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but safe: we never put '#' inside our string values
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_scalar(tok: &str) -> Result<Value> {
    let t = tok.trim();
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value '{t}'")
}

fn parse_value(tok: &str) -> Result<Value> {
    let t = tok.trim();
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                if part.trim().is_empty() {
                    continue;
                }
                items.push(parse_scalar(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(t)
}

/// Parse a config document into a root [`Value::Table`].
pub fn parse(text: &str) -> Result<Value> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            let name = &line[1..line.len() - 1];
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
        };
        let key = line[..eq].trim().to_string();
        let val = parse_value(&line[eq + 1..])?;

        // descend/create section path
        let mut cur = &mut root;
        for part in &section {
            let entry = cur
                .entry(part.clone())
                .or_insert_with(|| Value::Table(BTreeMap::new()));
            match entry {
                Value::Table(t) => cur = t,
                _ => bail!("section '{part}' collides with a scalar key"),
            }
        }
        cur.insert(key, val);
    }
    Ok(Value::Table(root))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let v = parse(
            "# top comment\n\
             title = \"demo\"\n\
             [quant]\n\
             bpw = 3.275   # inline comment\n\
             seed = 42\n\
             ewmul_opt = true\n\
             [model.arch]\n\
             name = \"rwkv6\"\n",
        )
        .unwrap();
        assert_eq!(v.get_str("title"), Some("demo"));
        let q = v.get("quant").unwrap();
        assert_eq!(q.get_f64("bpw"), Some(3.275));
        assert_eq!(q.get_int("seed"), Some(42));
        assert_eq!(q.get_bool("ewmul_opt"), Some(true));
        assert_eq!(v.get("model").unwrap().get("arch").unwrap().get_str("name"), Some("rwkv6"));
    }

    #[test]
    fn parses_arrays() {
        let v = parse("sizes = [1, 2, 3]\nnames = [\"a\", \"b\"]\n").unwrap();
        match v.get("sizes") {
            Some(Value::Array(xs)) => assert_eq!(xs.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn int_promotes_to_f64() {
        let v = parse("x = 3\n").unwrap();
        assert_eq!(v.get_f64("x"), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("this is not toml\n").is_err());
        assert!(parse("x = @@@\n").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let v = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(v.get_str("s"), Some("a#b"));
    }
}
