//! Calibration management: run the fp model over calibration windows,
//! capture per-layer input activations, and expose them as
//! [`CalibData`] for the quantization engines (§4.1: 128 samples).

use crate::data::Corpus;
use crate::model::rwkv::{Capture, RwkvRunner};
use crate::model::ModelWeights;
use crate::quant::CalibData;
use crate::tensor::Matrix;
use std::collections::HashMap;

/// Per-layer calibration activations keyed by parameter name.
pub struct CalibSet {
    pub acts: HashMap<String, Matrix>,
}

impl CalibSet {
    /// Capture activations by running `model` over `windows`
    /// (state reset per window), keeping at most `max_rows` rows/layer.
    pub fn capture(model: &ModelWeights, windows: &[Vec<usize>], max_rows: usize) -> CalibSet {
        let mut runner = RwkvRunner::new(model);
        runner.capture = Some(Capture::new(max_rows));
        for w in windows {
            runner.reset();
            for &t in w {
                let _ = runner.forward_token(t);
            }
        }
        let cap = runner.capture.take().unwrap();
        CalibSet { acts: cap.into_matrices() }
    }

    /// Convenience: §4.1 settings from a corpus (128 windows).
    pub fn from_corpus(
        model: &ModelWeights,
        corpus: &Corpus,
        n_samples: usize,
        window: usize,
        seed: u64,
    ) -> CalibSet {
        let windows = corpus.calib_windows(n_samples.div_ceil(window.max(1)).max(4), window, seed);
        Self::capture(model, &windows, n_samples)
    }

    pub fn layer(&self, name: &str) -> Option<CalibData> {
        self.acts.get(name).map(|m| CalibData { x: m.clone() })
    }

    /// Synthetic fallback for models that have no runnable forward
    /// (the LLaMA comparator): unit-variance Gaussian activations with a
    /// few hot channels, matching typical transformer statistics.
    pub fn synthetic(model: &ModelWeights, samples: usize, seed: u64) -> CalibSet {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x7379_6e63);
        let mut acts = HashMap::new();
        for &i in &model.quantizable_indices() {
            let (desc, w) = &model.layers[i];
            let mut x = Matrix::zeros(samples, w.cols);
            rng.fill_normal(&mut x.data, 0.0, 1.0);
            for r in 0..samples {
                for c in 0..w.cols.min(4) {
                    *x.at_mut(r, c) *= 6.0; // hot channels
                }
            }
            acts.insert(desc.name.clone(), x);
        }
        CalibSet { acts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::rwkv::init_params;
    use crate::util::rng::Rng;

    #[test]
    fn capture_covers_every_quantizable_layer() {
        let cfg = ModelConfig::rwkv6(2, 16, 32);
        let m = init_params(&cfg, &mut Rng::new(1));
        let windows = vec![vec![1usize, 2, 3, 4, 5], vec![6, 7, 8, 9, 10]];
        let cs = CalibSet::capture(&m, &windows, 8);
        for &i in &m.quantizable_indices() {
            let name = &m.layers[i].0.name;
            let c = cs.layer(name).unwrap_or_else(|| panic!("no acts for {name}"));
            assert_eq!(c.x.cols, m.layers[i].1.cols, "{name}");
            assert!(c.x.rows > 0 && c.x.rows <= 8);
        }
    }

    #[test]
    fn capture_rows_bounded() {
        let cfg = ModelConfig::rwkv6(1, 16, 32);
        let m = init_params(&cfg, &mut Rng::new(2));
        let windows = vec![(0..50).map(|i| i % 32).collect::<Vec<_>>()];
        let cs = CalibSet::capture(&m, &windows, 10);
        for m in cs.acts.values() {
            assert!(m.rows <= 10);
        }
    }

    #[test]
    fn synthetic_fallback_matches_widths() {
        let cfg = ModelConfig::llama(2, 16, 32);
        let m = crate::model::llama::init_params(&cfg, &mut Rng::new(3));
        let cs = CalibSet::synthetic(&m, 16, 4);
        for &i in &m.quantizable_indices() {
            let (d, w) = &m.layers[i];
            assert_eq!(cs.layer(&d.name).unwrap().x.cols, w.cols);
        }
    }
}
