//! Shared machinery for the paper-reproduction benches
//! (`rust/benches/*.rs`): the synthetic model lineup, paper metric
//! anchors, and the quantize-and-measure drivers every table/figure
//! reuses. Bench binaries stay thin; all logic is here and unit-tested.

use crate::calib::CalibSet;
use crate::config::{Method, QuantConfig};
use crate::coordinator::{quantize_model, PipelineReport, QuantizedLayers};
use crate::eval::{dequantized_model, output_divergence, FidelityMap};
use crate::model::synthetic::{self, Family};
use crate::model::ModelWeights;
use crate::util::rng::Rng;

/// The paper's language-model lineup: (display label, arch, size label,
/// FP 0-shot⁹ average, FP LAMBADA ppl) — Table 2's FloatingPoint row.
pub const LANGUAGE_LINEUP: [(&str, &str, &str, f64, f64); 7] = [
    ("RWKV7-0.1B", "rwkv7", "0.1B", 43.02, 14.21),
    ("RWKV7-0.5B", "rwkv7", "0.5B", 48.67, 7.21),
    ("RWKV7-1.47B", "rwkv7", "1.47B", 55.08, 4.80),
    ("RWKV6-1B", "rwkv6", "1B", 54.39, 4.60),
    ("RWKV6-3B", "rwkv6", "3B", 58.32, 3.83),
    ("RWKV6-7B", "rwkv6", "7B", 61.69, 3.21),
    ("RWKV6-14B", "rwkv6", "14B", 63.65, 3.02),
];

/// Shrink factor for quick CI runs: set `RWKVQUANT_BENCH_FAST=1` to use
/// the first three models and fewer probes.
pub fn fast_mode() -> bool {
    std::env::var("RWKVQUANT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Build the synthetic stand-in for a lineup entry.
pub fn build_model(arch: &str, size: &str, seed: u64) -> ModelWeights {
    let cfg = synthetic::size_config(arch, size);
    synthetic::generate_rwkv(&cfg, Family::Rwkv, seed)
}

/// Grammar probe sequences shared by the divergence measurements.
pub fn probes(vocab: usize, n: usize, len: usize, seed: u64) -> Vec<Vec<usize>> {
    let g = crate::data::Grammar::new(vocab, 6, seed);
    let mut rng = Rng::new(seed ^ 0x70726f62);
    (0..n).map(|_| g.sample(len, &mut rng)).collect()
}

/// Synthetic calibration for lineup models where the O(ic³) Hessian
/// factorisations stay cheap on this testbed (d_model ≤ 256); larger
/// models run uncalibrated (the paper's method gaps also shrink with
/// size — see DESIGN.md). Returns None above the cutoff.
pub fn auto_calib(model: &ModelWeights) -> Option<CalibSet> {
    if model.config.d_model <= 192 {
        Some(CalibSet::synthetic(model, 96, 0xca11b))
    } else {
        None
    }
}

/// Bench-scale quantization config for a (method, bpw) cell. VQ index
/// width is bounded for bench wall-time (documented in DESIGN.md —
/// large-layer codebooks amortise identically at any k).
pub fn bench_config(method: Method, bpw: f64, seed: u64) -> QuantConfig {
    let mut cfg = QuantConfig::baseline(method, bpw);
    cfg.vq_bits = cfg.vq_bits.min(8);
    cfg.kmeans_iters = 6;
    cfg.seed = seed;
    if method == Method::RwkvQuant {
        cfg.bpw = 3.275;
    }
    cfg
}

/// One measured cell: quantize `model` with `cfg` and measure the output
/// divergence on `probes`.
pub struct CellResult {
    pub divergence: f64,
    pub avg_bpw: f64,
    pub report: PipelineReport,
    pub quantized: QuantizedLayers,
}

pub fn run_cell(
    model: &ModelWeights,
    calib: Option<&CalibSet>,
    cfg: &QuantConfig,
    probe_seqs: &[Vec<usize>],
) -> CellResult {
    let (q, report) = quantize_model(model, calib, cfg, 0);
    let dq = dequantized_model(model, &q);
    let divergence = output_divergence(model, &dq, probe_seqs);
    CellResult { divergence, avg_bpw: report.avg_bpw, report, quantized: q }
}

/// Fidelity map for a lineup entry (fixed gain across all methods so
/// orderings come from measured divergence — DESIGN.md §Substitutions).
pub fn language_map(fp_acc: f64, fp_ppl: f64) -> FidelityMap {
    FidelityMap { fp_acc, chance: 25.0, fp_ppl, gain: 2.2 }
}

/// The Table 2 method grid.
pub fn table2_methods() -> Vec<(Method, f64)> {
    let mut cells = Vec::new();
    for &bpw in &[3.25, 3.5] {
        for &m in Method::all_baselines() {
            cells.push((m, bpw));
        }
    }
    cells.push((Method::RwkvQuant, 3.275));
    cells
}

/// Quantize with a layer-choice vector produced by an arbitrary proxy
/// (the Table 6 ablation): `choices[i]` corresponds to the i-th
/// quantizable layer.
pub fn quantize_with_choices(
    model: &ModelWeights,
    calib: Option<&CalibSet>,
    cfg: &QuantConfig,
    choices: &[crate::quant::hybrid::Choice],
) -> QuantizedLayers {
    use crate::quant::hybrid::quantize_hybrid;
    let idx = model.quantizable_indices();
    assert_eq!(choices.len(), idx.len());
    let mut out = QuantizedLayers::new();
    for (pos, &i) in idx.iter().enumerate() {
        let (desc, w) = &model.layers[i];
        let ldata = calib.and_then(|c| c.layer(&desc.name));
        let mut rng = Rng::new(cfg.seed ^ ((i as u64) << 8));
        let q = quantize_hybrid(w, desc.class.kind(), choices[pos], ldata.as_ref(), cfg, &mut rng);
        out.insert(desc.name.clone(), q);
    }
    out
}

/// Choice vector from a single-statistic baseline proxy: the layers with
/// the highest statistic (least uniform `G'`) take the VQ budget.
pub fn choices_from_baseline(
    model: &ModelWeights,
    proxy: crate::quant::proxy::baselines::BaselineProxy,
    sq_fraction: f64,
    calib: Option<&CalibSet>,
    cfg: &QuantConfig,
) -> Vec<crate::quant::hybrid::Choice> {
    use crate::quant::hybrid::Choice;
    use crate::quant::proxy::baselines::BaselineProxy;
    use crate::quant::proxy::GPrime;
    let idx = model.quantizable_indices();
    let budget = (((1.0 - sq_fraction) * idx.len() as f64).round() as usize).min(idx.len());
    match proxy {
        BaselineProxy::MSE => idx
            .iter()
            .map(|&i| {
                let (desc, w) = &model.layers[i];
                let ldata = calib.and_then(|c| c.layer(&desc.name));
                let mut rng = Rng::new(cfg.seed ^ ((i as u64) << 8));
                if crate::quant::proxy::baselines::mse_prefers_sq(
                    w,
                    desc.class.kind(),
                    ldata.as_ref(),
                    cfg,
                    &mut rng,
                ) {
                    Choice::Sq
                } else {
                    Choice::Vq
                }
            })
            .collect(),
        stat => {
            let scores: Vec<f64> = idx
                .iter()
                .map(|&i| {
                    let g = GPrime::from_weights(&model.layers[i].1.data);
                    crate::quant::proxy::baselines::statistic(stat, &g)
                })
                .collect();
            let mut order: Vec<usize> = (0..idx.len()).collect();
            order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            let mut choices = vec![Choice::Sq; idx.len()];
            for &pos in order.iter().take(budget) {
                choices[pos] = Choice::Vq;
            }
            choices
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lineup_has_seven_models() {
        assert_eq!(LANGUAGE_LINEUP.len(), 7);
    }

    #[test]
    fn table2_grid_is_15_cells() {
        assert_eq!(table2_methods().len(), 15);
    }

    #[test]
    fn run_cell_produces_consistent_bpw() {
        let m = build_model("rwkv6", "0.1B", 1);
        let cfg = bench_config(Method::Rtn, 3.5, 1);
        let ps = probes(m.config.vocab, 2, 6, 3);
        let cell = run_cell(&m, None, &cfg, &ps);
        assert!(cell.divergence.is_finite());
        assert!((cell.avg_bpw - 3.5).abs() < 0.01, "bpw {}", cell.avg_bpw);
    }

    #[test]
    fn fidelity_anchors_recovered_at_zero_divergence() {
        let map = language_map(55.0, 4.8);
        assert!((map.acc(0.0) - 55.0).abs() < 1e-9);
        assert!((map.ppl(0.0) - 4.8).abs() < 1e-9);
    }
}
